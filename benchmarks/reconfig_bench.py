"""Reconfiguration plan-search benchmark: batched engine vs naive oracle.

The claim under test is PR 4's batched plan search itself: pre-scored
per-fold offset tables + the vectorized single-cube search + fresh-bound
pruning + dirty-cube cache refresh must beat the retained pure-python
offset scan at every cube granularity the paper evaluates (2^3 / 4^3 /
8^3 on the 4096-XPU cluster). Both engines run under the same gated
drain so the delta is the plan search, not the simulator; JCR equality
doubles as an in-bench parity check (the real parity suite is
``tests/test_reconfig_plan_search.py``).

  PYTHONPATH=src python -m benchmarks.reconfig_bench [--out BENCH_reconfig.json]
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict

from repro.core.allocator import make_policy
from repro.sim.simulator import Simulator
from repro.traces.generator import TraceConfig, generate_trace

CUBE_SIZES = (8, 4, 2)


def _run(cube_n: int, num_jobs: int, seed: int, naive: bool) -> Dict:
    pol = make_policy("rfold", num_xpus=4096, cube_n=cube_n)
    pol.use_naive = naive
    jobs = generate_trace(TraceConfig(num_jobs=num_jobs, seed=seed,
                                      target_load=1.5))
    t0 = time.perf_counter()
    res = Simulator(pol, jobs, gated=True).run()
    wall = time.perf_counter() - t0
    placed = sum(1 for j in res.jobs if j.scheduled)
    return {"sim_seconds": round(wall, 4), "placements": placed,
            "placements_per_sec": round(placed / wall, 1) if wall else None,
            "jcr": round(res.jcr, 4)}


def main(argv=None) -> Dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", type=str, default="BENCH_reconfig.json")
    ap.add_argument("--num-jobs", type=int, default=120)
    ap.add_argument("--seed", type=int, default=100)
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized run (60 jobs)")
    args = ap.parse_args(argv)
    num_jobs = 60 if args.quick else args.num_jobs

    results: Dict = {"config": {"num_jobs": num_jobs, "seed": args.seed,
                                "num_xpus": 4096, "policy": "rfold"},
                     "cube_sizes": {}}
    print(f"# reconfig plan-search bench, rfold @ {num_jobs} jobs "
          "(cube,batched_s,naive_s,speedup,jcr)")
    for cube_n in CUBE_SIZES:
        fast = _run(cube_n, num_jobs, args.seed, naive=False)
        naive = _run(cube_n, num_jobs, args.seed, naive=True)
        assert fast["jcr"] == naive["jcr"], (cube_n, fast, naive)
        speedup = round(naive["sim_seconds"] / fast["sim_seconds"], 2) \
            if fast["sim_seconds"] else None
        results["cube_sizes"][f"{cube_n}^3"] = {
            "batched": fast, "naive": naive, "speedup": speedup}
        print("%d^3,%.3f,%.3f,%.1fx,%.3f" % (
            cube_n, fast["sim_seconds"], naive["sim_seconds"], speedup,
            fast["jcr"]))

    speedups = {k: v["speedup"] for k, v in results["cube_sizes"].items()}
    results["headline"] = {
        "criterion": "batched plan search beats the naive oracle at "
                     "every cube size (>= 2x at 8^3)",
        "speedups": speedups,
        "pass": all(s and s > 1.0 for s in speedups.values())
                and speedups["8^3"] >= 2.0,
    }
    print(f"# headline: {speedups} pass={results['headline']['pass']}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"# wrote {args.out}")
    return results


if __name__ == "__main__":
    main()
