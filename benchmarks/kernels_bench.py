"""Microbenchmarks: kernels (oracle engines on CPU; the Pallas kernels
are TPU-targeted and only validated in interpret mode), allocator and
simulator throughput. Emits ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn: Callable, iters: int = 5, warmup: int = 2) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters * 1e6  # us


def bench_flash_attention(emit=print) -> None:
    from repro.kernels.flash_attention import ref as fa_ref
    rng = np.random.default_rng(0)
    for s in (256, 1024):
        q = jnp.array(rng.normal(size=(1, s, 8, 64)), jnp.bfloat16)
        k = jnp.array(rng.normal(size=(1, s, 2, 64)), jnp.bfloat16)
        v = jnp.array(rng.normal(size=(1, s, 2, 64)), jnp.bfloat16)
        f = jax.jit(lambda a, b, c: fa_ref.attention_reference(a, b, c))
        us = _time(lambda: jax.block_until_ready(f(q, k, v)))
        flops = 4 * s * s * 8 * 64 / 2  # causal
        emit(f"attention_ref_s{s},{us:.0f},{flops / us / 1e3:.1f}GFLOPs")


def bench_ssd(emit=print) -> None:
    from repro.kernels.ssd_scan import ref as ssd_ref
    rng = np.random.default_rng(1)
    B, S, H, P, N = 1, 2048, 8, 64, 64
    x = jnp.array(rng.normal(size=(B, S, H, P)), jnp.float32)
    dt = jnp.array(rng.uniform(0.01, 0.2, (B, S, H)), jnp.float32)
    a = jnp.array(-rng.uniform(0.5, 2, (H,)), jnp.float32)
    b = jnp.array(rng.normal(size=(B, S, H, N)), jnp.float32)
    c = jnp.array(rng.normal(size=(B, S, H, N)), jnp.float32)
    for chunk in (64, 256):
        f = jax.jit(lambda *t, ch=chunk: ssd_ref.ssd_reference(
            *t, chunk=ch)[0])
        us = _time(lambda: jax.block_until_ready(f(x, dt, a, b, c)))
        emit(f"ssd_chunk{chunk},{us:.0f},{S * B / (us / 1e6) / 1e6:.2f}Mtok/s")


def bench_fitmask(emit=print) -> None:
    from repro.core import fitmask as np_engine
    from repro.kernels.fitmask import ref as fit_ref
    rng = np.random.default_rng(2)
    occ = rng.uniform(size=(16, 16, 16)) < 0.3
    us = _time(lambda: np_engine.fit_mask(occ, (4, 4, 4)), iters=50)
    emit(f"fitmask_numpy_16cube,{us:.0f},{1e6 / us:.0f}searches/s")
    occ_b = jnp.array(rng.uniform(size=(64, 4, 4, 4)) < 0.3)
    f = jax.jit(lambda o: fit_ref.fitmask_reference(o, (2, 2, 2)))
    us = _time(lambda: jax.block_until_ready(f(occ_b)), iters=20)
    emit(f"fitmask_reduce_window_64cubes,{us:.0f},batched")


def bench_allocator(emit=print) -> None:
    from repro.core.allocator import make_policy
    from repro.traces.generator import TraceConfig, generate_trace
    jobs = generate_trace(TraceConfig(num_jobs=60, seed=0))
    for name, kw in (("firstfit", dict(dims=(16, 16, 16))),
                     ("rfold", dict(num_xpus=4096, cube_n=4))):
        pol = make_policy(name, **kw)
        t0 = time.perf_counter()
        placed = sum(1 for j in jobs
                     if pol.try_place(j.job_id, j.shape) is not None)
        dt = time.perf_counter() - t0
        emit(f"alloc_{name},{dt / len(jobs) * 1e6:.0f},"
             f"{placed}/{len(jobs)}placed")


def bench_simulator(emit=print) -> None:
    from repro.core.allocator import make_policy
    from repro.sim.simulator import Simulator
    from repro.traces.generator import TraceConfig, generate_trace
    jobs = generate_trace(TraceConfig(num_jobs=150, seed=1))
    pol = make_policy("rfold", num_xpus=4096, cube_n=4)
    t0 = time.perf_counter()
    Simulator(pol, jobs).run()
    dt = time.perf_counter() - t0
    emit(f"sim_rfold_150jobs,{dt * 1e6:.0f},{150 / dt:.0f}jobs/s")


def main(emit=print) -> None:
    emit("name,us_per_call,derived")
    bench_fitmask(emit)
    bench_allocator(emit)
    bench_simulator(emit)
    bench_flash_attention(emit)
    bench_ssd(emit)


if __name__ == "__main__":
    main()
