"""Crash-loop drill: the daemon dies 5 times mid-stream, the state
doesn't.

A deterministic op stream is derived from the ``node_churn`` chaos
scenario — the trace's submits, a retire-every-3rd ``done`` rule, and
the scenario's seeded fault/repair schedule, merged in time order —
and replayed against the allocator daemon twice:

* **Control run**: uninterrupted; the final ``state_digest`` is the
  oracle.
* **Crash run**: at 5 seeded points the daemon is ``kill``-ed (no
  final checkpoint — recovery is snapshot + WAL tail replay), a fresh
  daemon recovers on the same checkpoint dir, and the op that was in
  flight at the kill is **resent with its original request_id** — the
  journal-persisted dedup cache must absorb the retry (the state
  digest must not move), exactly what a reconnecting client does.

Pass criterion: the crash run's final digest and journal length are
byte-identical to the control run's, every resend was a no-op, and at
least one resend was answered from the dedup cache. The resilience
counters (dedup/lease/WAL) land in the JSON artifact for
``benchmarks/report.py``.

  PYTHONPATH=src python -m benchmarks.crash_loop [--kills 5] \
      [--out BENCH_crash_loop.json]
"""
from __future__ import annotations

import argparse
import json
import random
import shutil
import tempfile
import time
from typing import Dict, List, Optional, Tuple

from repro.api import (SCENARIOS, Scheduler, SchedulerClient,
                       SchedulerConfig, TraceConfig, fault_schedule,
                       generate_trace, make_policy)
from repro.serve.scheduler import protocol

POLICY_KW = dict(num_xpus=512, cube_n=4)


def build_op_stream(num_jobs: int, seed: int,
                    scenario: str = "node_churn") -> List[Dict]:
    """The deterministic op list both runs replay: submits at arrival,
    a ``done`` for the oldest-submitted job after every 3rd submit
    (already-finished/dropped targets answer a stateless error —
    deterministic either way), and the scenario's fault/repair events
    at their scheduled times."""
    sc = SCENARIOS[scenario]
    cfg = TraceConfig(num_jobs=num_jobs, seed=seed, cluster_xpus=512,
                      size_max=512, **sc.trace_kw)
    jobs = generate_trace(cfg)
    model = make_policy("rfold", **POLICY_KW).cluster
    faults = fault_schedule(sc, model, jobs, seed)

    timeline: List[Tuple[float, int, Dict]] = []
    fifo: List[int] = []
    for n, job in enumerate(jobs, start=1):
        timeline.append((job.arrival, len(timeline),
                         {"op": "submit", "job_id": job.job_id,
                          "shape": list(job.shape.dims)}))
        fifo.append(job.job_id)
        if n % 3 == 0:
            timeline.append((job.arrival, len(timeline),
                             {"op": "done", "job_id": fifo.pop(0)}))
    for ev in faults:
        timeline.append((ev.time, len(timeline),
                         {"op": ev.action, "kind": ev.kind,
                          "targets": [list(t) if isinstance(t, tuple)
                                      else t for t in ev.targets]}))
    timeline.sort(key=lambda e: (e[0], e[1]))
    return [msg for _, _, msg in timeline]


class _RawClient:
    """Fixed-identity wire driver: op ``i`` always goes out as
    ``request_id crash:<i>`` — across daemon restarts too — so a
    resend after a crash is the genuine idempotent-retry path."""

    def __init__(self, address):
        self._c = SchedulerClient(address, client_id="crash",
                                  max_retries=0)

    def send(self, i: int, msg: Dict) -> Dict:
        wire = dict(msg, seq=i, client="crash",
                    request_id=f"crash:{i}")
        self._c._sock.sendall(protocol.encode(wire))
        return self._c._await_reply(i, 60.0)

    def close(self) -> None:
        self._c.close()


def _run_stream(ops: List[Dict], ckpt_dir: str,
                kill_at: Optional[List[int]] = None) -> Dict:
    """Replay ``ops`` against a daemon on ``ckpt_dir``; with
    ``kill_at``, crash + recover + resend-at-same-rid at those op
    indices. Returns the final digest/journal plus drill stats."""
    cfg = SchedulerConfig(policy="rfold", policy_kw=dict(POLICY_KW),
                          checkpoint_dir=ckpt_dir, checkpoint_every=7)
    kill_at = sorted(kill_at or [])
    sched = Scheduler(cfg).start()
    client = _RawClient(sched.address)
    resends_clean = True
    try:
        for i, msg in enumerate(ops):
            reply = client.send(i, msg)
            if kill_at and i == kill_at[0]:
                kill_at.pop(0)
                client.close()
                sched.kill()  # crash: no final checkpoint
                sched = Scheduler(cfg).start()
                client = _RawClient(sched.address)
                # The retry a real client would issue after losing the
                # ack: same request_id. Journaled ops must dedup;
                # either way the state digest must not move.
                before = client.send(10_000_000 + i, {"op": "status"})
                client.send(i, msg)
                after = client.send(20_000_000 + i, {"op": "status"})
                resends_clean &= (before["state_digest"]
                                  == after["state_digest"])
        st = client.send(len(ops), {"op": "status"})
        return {"digest": st["state_digest"],
                "journal_ops": st["journal_ops"],
                "resilience": st["resilience"],
                "resends_clean": resends_clean}
    finally:
        client.close()
        sched.stop()


def run_drill(num_jobs: int, seed: int, kills: int) -> Dict:
    ops = build_op_stream(num_jobs, seed)
    # Kill only right after submits: submits journal (unless rejected),
    # so the resent op exercises the dedup cache, not just statelessness.
    submit_idx = [i for i, m in enumerate(ops) if m["op"] == "submit"]
    kill_at = sorted(random.Random(seed).sample(
        submit_idx[1:], min(kills, max(0, len(submit_idx) - 1))))

    tmp = tempfile.mkdtemp(prefix="crash_loop_")
    try:
        t0 = time.perf_counter()
        control = _run_stream(ops, tmp + "/control")
        crash = _run_stream(ops, tmp + "/crash", kill_at=kill_at)
        wall = time.perf_counter() - t0
    finally:
        shutil.rmtree(tmp, ignore_errors=True)

    identical = (control["digest"] == crash["digest"]
                 and control["journal_ops"] == crash["journal_ops"])
    return {
        "ops": len(ops), "num_jobs": num_jobs, "seed": seed,
        "kills": kill_at,
        "control": control, "crash": crash,
        "identical": identical,
        "wall_s": round(wall, 3),
        "pass": (identical and crash["resends_clean"]
                 and crash["resilience"]["dedup_hits"] >= 1),
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--num-jobs", type=int, default=60)
    ap.add_argument("--seed", type=int, default=17)
    ap.add_argument("--kills", type=int, default=5)
    ap.add_argument("--quick", action="store_true",
                    help="smaller stream for CI smoke")
    ap.add_argument("--out", default="BENCH_crash_loop.json")
    args = ap.parse_args(argv)
    if args.quick:
        args.num_jobs = min(args.num_jobs, 36)
        args.kills = min(args.kills, 3)

    res = run_drill(args.num_jobs, args.seed, args.kills)
    print(f"# crash loop: {res['ops']} ops, kills at {res['kills']}")
    print(f"  control digest {res['control']['digest'][:16]}... "
          f"({res['control']['journal_ops']} journal ops)")
    print(f"  crash   digest {res['crash']['digest'][:16]}... "
          f"({res['crash']['journal_ops']} journal ops, "
          f"recovered {res['crash']['resilience']['recovered_ops']} at "
          f"last boot, {res['crash']['resilience']['dedup_hits']} dedup "
          f"hits, wal tail {res['crash']['resilience']['wal_tail_ops']})")
    print(f"# identical={res['identical']} "
          f"resends_clean={res['crash']['resends_clean']} "
          f"pass={res['pass']} ({res['wall_s']}s)")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(res, f, indent=1)
        print(f"# wrote {args.out}")


if __name__ == "__main__":
    main()
