"""Allocator / simulator performance benchmark (placement hot path).

Tracks the perf trajectory of the incremental placement engine: per
policy, the end-to-end simulation wall-clock and the placement rate
(scheduled jobs per second of allocator time) at 80- and 200-job scale
on the paper's 4096-XPU cluster, plus the retained naive RFold path as
the speedup baseline.

  PYTHONPATH=src python -m benchmarks.allocator_bench
  PYTHONPATH=src python -m benchmarks.allocator_bench --out BENCH_allocator.json

Engine results are parity-checked against the naive oracle in
``tests/test_placement_engine.py``; this file only measures.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict

from repro.core.allocator import make_policy
from repro.sim.simulator import Simulator
from repro.traces.generator import TraceConfig, generate_trace

POLICIES = [
    ("firstfit_16c", "firstfit", dict(dims=(16, 16, 16))),
    ("folding_16c", "folding", dict(dims=(16, 16, 16))),
    ("reconfig_4c", "reconfig", dict(num_xpus=4096, cube_n=4)),
    ("rfold_4c", "rfold", dict(num_xpus=4096, cube_n=4)),
    ("rfold_be_4c", "rfold_be", dict(num_xpus=4096, cube_n=4)),
]


def _run_once(name: str, kw: dict, num_jobs: int, seed: int,
              naive: bool = False, gated: bool = True) -> Dict:
    pol = make_policy(name, **kw)
    if naive:
        pol.use_naive = True
    jobs = generate_trace(TraceConfig(num_jobs=num_jobs, seed=seed,
                                      target_load=1.5))
    t0 = time.perf_counter()
    res = Simulator(pol, jobs, gated=gated).run()
    wall = time.perf_counter() - t0
    placed = sum(1 for j in res.jobs if j.scheduled)
    return {
        "sim_seconds": round(wall, 4),
        "placements": placed,
        "placements_per_sec": round(placed / wall, 1) if wall else None,
        "jcr": round(res.jcr, 4),
    }


def main(argv=None) -> Dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", type=str, default="BENCH_allocator.json")
    ap.add_argument("--job-scales", type=int, nargs="+", default=[80, 200])
    ap.add_argument("--seed", type=int, default=100)
    ap.add_argument("--skip-naive", action="store_true",
                    help="skip the slow naive-RFold baseline run")
    args = ap.parse_args(argv)

    results: Dict = {"policies": {}, "baseline": {}}
    for scale in args.job_scales:
        print(f"# allocator bench @ {scale} jobs "
              f"(policy,sim_seconds,placements_per_sec,jcr)")
        for label, name, kw in POLICIES:
            r = _run_once(name, kw, scale, args.seed)
            results["policies"].setdefault(label, {})[str(scale)] = r
            print("%s,%.3f,%.0f,%.3f" % (label, r["sim_seconds"],
                                         r["placements_per_sec"], r["jcr"]))

    if not args.skip_naive:
        # Speedup anchor: the retained naive engine + ungated drain on the
        # acceptance workload (RFold 4^3, 80 jobs).
        naive = _run_once("rfold", dict(num_xpus=4096, cube_n=4), 80,
                          args.seed, naive=True, gated=False)
        fast = results["policies"]["rfold_4c"]["80"]
        results["baseline"] = {
            "naive_rfold_80": naive,
            "speedup_vs_naive": round(
                naive["sim_seconds"] / fast["sim_seconds"], 1),
        }
        print("naive_rfold_80,%.3f  speedup %.1fx" %
              (naive["sim_seconds"], results["baseline"]["speedup_vs_naive"]))

    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"# wrote {args.out}")
    return results


if __name__ == "__main__":
    main()
