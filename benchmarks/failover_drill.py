"""Partition/failover drill: kill -9 the primary, promote the warm
standby, lose nothing.

Three sections, one JSON artifact (``BENCH_failover.json``):

* **failover** — the acceptance scenario. The same deterministic
  ``node_churn`` op stream as ``crash_loop.py`` is replayed twice:
  once against an uninterrupted in-process daemon (the control
  oracle), and once against a *replicated pair* — the primary runs as
  a real subprocess (sync ack mode: every op is standby-durable
  before its ack) and a warm standby tails its journal over the wire.
  Mid-stream, right after a submit's ack, the primary is SIGKILLed,
  the standby is promoted (minting fencing epoch 2), the killed op's
  request_id is **resent** (the replicated dedup cache must absorb
  it), and the stream finishes against the new leader. Pass: the
  final state digest is byte-identical to the control, the resend
  moved nothing, and zero acked ops were missing from the standby at
  promotion. RTO (SIGKILL → resent op acked by the new leader) and
  the replication lag at the kill are the headline latencies.

* **resurrection** — the split-brain case. The dead primary is
  restarted from its own checkpoint store (it recovers to its
  pre-kill state, epoch 1, believing it leads). A client that has
  witnessed epoch 2 stamps it on its requests: the stale primary must
  fence itself and refuse (journal side), and a failover client must
  discard/redirect and land the op on the real leader exactly once
  (client side). Pass: **zero** fenced writes reach the stale
  journal.

* **ack_overhead** — sync vs async ack modes on a live pair: p50/p99
  submit latency, plus the fraction of sync acks that were actually
  standby-durable (must be 1.0 with a healthy follower).

  PYTHONPATH=src python -m benchmarks.failover_drill \
      [--num-jobs 60] [--out BENCH_failover.json] [--quick]
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import signal
import statistics
import subprocess
import sys
import tempfile
import time
from typing import Dict, List, Optional, Tuple

import repro
from repro.api import Scheduler, SchedulerConfig, SchedulerClient
from benchmarks.crash_loop import POLICY_KW, build_op_stream

REPL_KW = dict(checkpoint_every=7, repl_poll=0.1,
               ack_mode="sync", sync_timeout=2.0)

_PRIMARY = """\
import sys, time
from repro.api import Scheduler, SchedulerConfig
cfg = SchedulerConfig(policy="rfold",
                      policy_kw=dict(num_xpus=512, cube_n=4),
                      checkpoint_dir=sys.argv[1], checkpoint_every=7,
                      repl_poll=0.1, ack_mode="sync", sync_timeout=2.0)
s = Scheduler(cfg).start()
print("ADDR", s.address[0], s.address[1], flush=True)
while True:
    time.sleep(1)
"""


def _spawn_primary(ckpt_dir: str, script_dir: str):
    """The primary as a real OS process, so the kill is a genuine
    ``kill -9`` — no in-process shortcuts."""
    script = os.path.join(script_dir, "primary.py")
    with open(script, "w") as f:
        f.write(_PRIMARY)
    src = os.path.dirname(list(repro.__path__)[0])
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (src, env.get("PYTHONPATH")) if p)
    proc = subprocess.Popen([sys.executable, script, ckpt_dir],
                            stdout=subprocess.PIPE, text=True, env=env)
    for line in proc.stdout:
        if line.startswith("ADDR"):
            _, host, port = line.split()
            return proc, (host, int(port))
    raise RuntimeError("primary subprocess never printed its address")


def _drive(client: SchedulerClient, i: int, msg: Dict) -> Dict:
    """One stream op under its stable request_id ``drill:<i>`` — the
    id a resend must reuse for the retry to be idempotent."""
    fields = {k: v for k, v in msg.items() if k != "op"}
    return client._request(msg["op"], request_id=f"drill:{i}", **fields)


def _run_control(ops: List[Dict], ckpt_dir: str) -> Dict:
    cfg = SchedulerConfig(policy="rfold", policy_kw=dict(POLICY_KW),
                          checkpoint_dir=ckpt_dir, checkpoint_every=7)
    sched = Scheduler(cfg).start()
    client = SchedulerClient(sched.address, client_id="drill")
    try:
        for i, msg in enumerate(ops):
            _drive(client, i, msg)
        st = client.status()
        return {"digest": st["state_digest"],
                "journal_ops": st["journal_ops"],
                "data_ops": (st["journal_ops"]
                             - st["resilience"]["promotions"])}
    finally:
        client.close()
        sched.stop()


def _await_follower(client: SchedulerClient, deadline: float = 15.0):
    t0 = time.monotonic()
    while time.monotonic() - t0 < deadline:
        if client.status()["repl"]["follower_live"]:
            return
        time.sleep(0.05)
    raise RuntimeError("standby never pulled from the primary")


def run_failover(ops: List[Dict], seed: int,
                 tmp: str) -> Tuple[Dict, Scheduler]:
    """The kill -9 → promote → resend → digest-identical scenario.

    Returns the result dict plus the promoted standby, still live —
    the resurrection section needs it as the rightful leader."""
    pri_ckpt = os.path.join(tmp, "primary")
    proc, pri_addr = _spawn_primary(pri_ckpt, tmp)
    standby = Scheduler(SchedulerConfig(
        policy="rfold", policy_kw=dict(POLICY_KW),
        checkpoint_dir=os.path.join(tmp, "standby"),
        role="standby", replicate_from=pri_addr, **REPL_KW)).start()
    client = SchedulerClient([pri_addr, standby.address],
                             client_id="drill", op_timeout=20.0,
                             max_retries=8, backoff=0.05)
    submit_idx = [i for i, m in enumerate(ops) if m["op"] == "submit"]
    kill_at = submit_idx[int(len(submit_idx) * 0.6)]
    acked = 0
    sync_acked = 0
    try:
        _await_follower(client)
        rto_ms = lag_at_kill = acked_ops_lost = None
        resend_clean = resend_dedup = False
        for i, msg in enumerate(ops):
            r = _drive(client, i, msg)
            acked += 1
            sync_acked += bool(r.get("replicated"))
            if i == kill_at:
                pri_ops = client.status()["journal_ops"]
                lag_at_kill = standby.status()["repl"]["lag"]
                t_kill = time.perf_counter()
                os.kill(proc.pid, signal.SIGKILL)
                proc.wait(timeout=30)
                promoted = standby.promote()
                assert promoted["epoch"] == 2, promoted
                # The standby must already hold every acked op — sync
                # acks made them standby-durable before the client
                # ever saw them.
                acked_ops_lost = max(
                    0, pri_ops - standby.status()["resilience"]
                    ["repl_applied"])
                # Resend the killed op's rid: the client that never
                # saw its ack retries against the new leader, which
                # answers from the replicated dedup cache.
                before = client.status()
                r2 = _drive(client, i, msg)
                rto_ms = (time.perf_counter() - t_kill) * 1e3
                after = client.status()
                resend_clean = (before["state_digest"]
                                == after["state_digest"])
                resend_dedup = (after["resilience"]["dedup_hits"]
                                > before["resilience"]["dedup_hits"])
                assert r2.get("job_id") == r.get("job_id")
        st = client.status()
        return ({
            "digest": st["state_digest"],
            "journal_ops": st["journal_ops"],
            "data_ops": (st["journal_ops"]
                         - st["resilience"]["promotions"]),
            "epoch": st["epoch"],
            "kill_at_op": kill_at,
            "ops_acked": acked,
            "sync_acked_frac": round(sync_acked / max(1, acked), 4),
            "rto_ms": round(rto_ms, 2),
            "repl_lag_at_kill": lag_at_kill,
            "acked_ops_lost": acked_ops_lost,
            "resend_clean": resend_clean,
            "resend_dedup": resend_dedup,
            "client_redirects": client.redirects,
            "client_retries": client.retries,
        }, standby)
    except BaseException:
        standby.kill()
        raise
    finally:
        client.close()
        if proc.poll() is None:
            proc.kill()
            proc.wait()
        proc.stdout.close()


def run_resurrection(tmp: str, new_leader: Scheduler,
                     epoch: int) -> Dict:
    """Restart the dead primary from its own store: it recovers to
    its pre-kill state believing it still leads — and must land zero
    writes once fenced."""
    stale = Scheduler(SchedulerConfig(
        policy="rfold", policy_kw=dict(POLICY_KW),
        checkpoint_dir=os.path.join(tmp, "primary"),
        checkpoint_every=7)).start()
    try:
        at_boot = stale.status()
        # Journal side: a request stamped with the new epoch makes
        # the stale primary fence itself and refuse.
        c1 = SchedulerClient(stale.address, client_id="stale-probe",
                             max_retries=1, backoff=0.01)
        c1.epoch_seen = epoch
        journal_refused = False
        try:
            c1._request("submit", shape=[2, 2, 2])
        except (ConnectionError, TimeoutError):
            journal_refused = True
        c1.close()
        # Client side: a failover client that witnessed the new epoch
        # rejects the stale leader and lands the op on the real one —
        # exactly once.
        leader_ops = new_leader.status()["journal_ops"]
        c2 = SchedulerClient([stale.address, new_leader.address],
                             client_id="resurrect", backoff=0.02,
                             max_retries=6)
        c2.epoch_seen = epoch
        landed = c2._request("submit", request_id="resurrect:1",
                             shape=[2, 2, 2])
        redirected = c2.redirects + c2.stale_rejections
        c2.close()
        st = stale.status()
        return {
            "journal_ops_at_boot": at_boot["journal_ops"],
            "recovered_digest": at_boot["state_digest"],
            "journal_refused": journal_refused,
            "fenced": st["fenced"],
            "fenced_rejections": st["repl"]["fenced_rejections"],
            "fenced_writes_landed": (st["journal_ops"]
                                     - at_boot["journal_ops"]),
            "landed_on_leader": bool(landed.get("ok"))
            and landed.get("epoch") == epoch
            and new_leader.status()["journal_ops"] == leader_ops + 1,
            "client_rejections": redirected,
        }
    finally:
        stale.stop()


def run_ack_overhead(n: int, tmp: str) -> Dict:
    """p50/p99 submit latency, async vs sync ack mode, live pair."""
    out: Dict[str, Dict] = {}
    for mode in ("async", "sync"):
        kw = dict(REPL_KW, ack_mode=mode)
        pri = Scheduler(SchedulerConfig(
            policy="rfold", policy_kw=dict(POLICY_KW),
            checkpoint_dir=os.path.join(tmp, f"ack-{mode}-p"),
            **kw)).start()
        sby = Scheduler(SchedulerConfig(
            policy="rfold", policy_kw=dict(POLICY_KW),
            checkpoint_dir=os.path.join(tmp, f"ack-{mode}-s"),
            role="standby", replicate_from=pri.address, **kw)).start()
        client = SchedulerClient(pri.address, client_id=f"ack-{mode}")
        try:
            _await_follower(client)
            lat: List[float] = []
            replicated = 0
            for i in range(n):
                t0 = time.perf_counter()
                r = client.submit((2, 2, 2), job_id=i)
                lat.append((time.perf_counter() - t0) * 1e3)
                replicated += bool(r.get("replicated"))
                client.done(i)
            lat.sort()
            out[mode] = {
                "n": n,
                "p50_ms": round(statistics.median(lat), 3),
                "p99_ms": round(lat[min(len(lat) - 1,
                                        int(len(lat) * 0.99))], 3),
                "replicated_frac": round(replicated / n, 4),
            }
        finally:
            client.close()
            sby.stop()
            pri.stop()
    out["overhead_p50_ms"] = round(
        out["sync"]["p50_ms"] - out["async"]["p50_ms"], 3)
    return out


def run_drill(num_jobs: int, seed: int, ack_n: int) -> Dict:
    ops = build_op_stream(num_jobs, seed)
    tmp = tempfile.mkdtemp(prefix="failover_drill_")
    standby: Optional[Scheduler] = None
    try:
        t0 = time.perf_counter()
        control = _run_control(ops, os.path.join(tmp, "control"))
        failover, standby = run_failover(ops, seed, tmp)
        resurrection = run_resurrection(tmp, standby, failover["epoch"])
        standby.stop()
        standby = None
        ack = run_ack_overhead(ack_n, tmp)
        wall = time.perf_counter() - t0
    finally:
        if standby is not None:
            standby.kill()
        shutil.rmtree(tmp, ignore_errors=True)

    digest_identical = (control["digest"] == failover["digest"]
                        and control["data_ops"] == failover["data_ops"])
    headline = {
        "ops": len(ops),
        "digest_identical": digest_identical,
        "acked_ops_lost": failover["acked_ops_lost"],
        "resend_exactly_once": (failover["resend_clean"]
                                and failover["resend_dedup"]),
        "fenced_writes_landed": resurrection["fenced_writes_landed"],
        "fenced_client_and_journal": (resurrection["journal_refused"]
                                      and resurrection["fenced"]
                                      and resurrection[
                                          "landed_on_leader"]),
        "rto_ms": failover["rto_ms"],
        "repl_lag_at_kill": failover["repl_lag_at_kill"],
        "sync_overhead_p50_ms": ack["overhead_p50_ms"],
        "sync_replicated_frac": ack["sync"]["replicated_frac"],
    }
    headline["pass"] = bool(
        digest_identical
        and failover["acked_ops_lost"] == 0
        and headline["resend_exactly_once"]
        and resurrection["fenced_writes_landed"] == 0
        and headline["fenced_client_and_journal"]
        and ack["sync"]["replicated_frac"] == 1.0)
    return {"num_jobs": num_jobs, "seed": seed,
            "control": control, "failover": failover,
            "resurrection": resurrection, "ack_overhead": ack,
            "wall_s": round(wall, 3), "headline": headline,
            "pass": headline["pass"]}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--num-jobs", type=int, default=60)
    ap.add_argument("--seed", type=int, default=17)
    ap.add_argument("--ack-n", type=int, default=40)
    ap.add_argument("--quick", action="store_true",
                    help="smaller stream for CI smoke")
    ap.add_argument("--out", default="BENCH_failover.json")
    args = ap.parse_args(argv)
    if args.quick:
        args.num_jobs = min(args.num_jobs, 36)
        args.ack_n = min(args.ack_n, 20)

    res = run_drill(args.num_jobs, args.seed, args.ack_n)
    h = res["headline"]
    print(f"# failover drill: {h['ops']} ops, SIGKILL at op "
          f"{res['failover']['kill_at_op']}")
    print(f"  control  digest {res['control']['digest']} "
          f"({res['control']['data_ops']} data ops)")
    print(f"  failover digest {res['failover']['digest']} "
          f"({res['failover']['data_ops']} data ops, epoch "
          f"{res['failover']['epoch']})")
    print(f"  RTO {h['rto_ms']}ms, repl lag at kill "
          f"{h['repl_lag_at_kill']} ops, acked lost "
          f"{h['acked_ops_lost']}")
    print(f"  resurrection: fenced_writes_landed="
          f"{h['fenced_writes_landed']} "
          f"(journal+client fencing: "
          f"{h['fenced_client_and_journal']})")
    print(f"  ack overhead: sync p50 "
          f"{res['ack_overhead']['sync']['p50_ms']}ms vs async p50 "
          f"{res['ack_overhead']['async']['p50_ms']}ms "
          f"(+{h['sync_overhead_p50_ms']}ms, replicated "
          f"{h['sync_replicated_frac']:.0%})")
    print(f"# digest_identical={h['digest_identical']} "
          f"pass={res['pass']} ({res['wall_s']}s)")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(res, f, indent=1)
        print(f"# wrote {args.out}")
    if not res["pass"]:
        sys.exit(1)


if __name__ == "__main__":
    main()
