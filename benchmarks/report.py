"""Generate markdown tables for EXPERIMENTS.md from experiments/
artifacts (dry-run JSONs, roofline JSON, paper_eval JSON)."""
from __future__ import annotations

import argparse
import glob
import json
import os
from collections import defaultdict

ARCH_ORDER = [
    "phi4-mini-3.8b", "llama3-8b", "deepseek-v2-236b", "qwen1.5-110b",
    "zamba2-1.2b", "llama4-scout-17b-a16e", "olmo-1b", "musicgen-medium",
    "xlstm-1.3b", "qwen2-vl-7b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def _fmt_bytes(b):
    if b is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def dryrun_table(dryrun_dir: str) -> str:
    rows = {}
    for path in glob.glob(os.path.join(dryrun_dir, "*.json")):
        with open(path) as f:
            r = json.load(f)
        rows[(r["arch"], r["shape"], r["mesh"])] = r
    lines = ["| arch | shape | 16x16 compile | 2x16x16 compile | "
             "collective bytes/chip (1-pod) | HLO coll ops |",
             "|---|---|---|---|---|---|"]
    n_ok = 0
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r1 = rows.get((a, s, "single"))
            r2 = rows.get((a, s, "multi"))
            if r1:
                n_ok += 1
            coll = r1["collectives"]["total_bytes"] if r1 else None
            cnt = r1["collectives"]["total_count"] if r1 else "-"
            lines.append(
                f"| {a} | {s} | "
                f"{'%.0fs' % r1['compile_s'] if r1 else 'MISSING'} | "
                f"{'%.0fs' % r2['compile_s'] if r2 else 'MISSING'} | "
                f"{_fmt_bytes(coll)} | {cnt} |")
    lines.append(f"\n{n_ok}/40 single-pod + "
                 f"{sum(1 for k in rows if k[2] == 'multi')}/40 multi-pod "
                 "combinations compiled.")
    return "\n".join(lines)


def roofline_table(path: str) -> str:
    with open(path) as f:
        rows = json.load(f)
    by_key = {(r["arch"], r["shape"]): r for r in rows}
    lines = ["| arch | shape | compute (ms) | memory (ms) | "
             "collective (ms) | dominant | useful ratio |",
             "|---|---|---|---|---|---|---|"]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = by_key.get((a, s))
            if not r:
                continue
            lines.append(
                "| %s | %s | %.2f | %.2f | %.2f | **%s** | %.2f |" % (
                    a, s, 1e3 * r["t_compute_s"], 1e3 * r["t_memory_s"],
                    1e3 * r["t_collective_s"], r["dominant"],
                    r["useful_ratio"]))
    # summary of dominant terms
    counts = defaultdict(int)
    for r in rows:
        counts[r["dominant"]] += 1
    lines.append("\nDominant-term census: " + ", ".join(
        f"{k}: {v}" for k, v in sorted(counts.items())))
    return "\n".join(lines)


def paper_table(path: str) -> str:
    with open(path) as f:
        res = json.load(f)
    out = []
    if "table1" in res:
        # Paper reference numbers: read from the artifact itself (new
        # eval subsystem embeds them as table1_deltas); fall back to
        # the canonical dict for pre-subsystem JSONs.
        if "table1_deltas" in res:
            paper = {k: v["paper_jcr_pct"]
                     for k, v in res["table1_deltas"].items()}
        else:
            from repro.eval.aggregate import PAPER_TABLE1 as paper
        out.append("| Policy | Paper JCR % | Ours JCR % |")
        out.append("|---|---|---|")
        for k, v in res["table1"].items():
            out.append(f"| {k} | {paper[k]} | {100 * v['jcr']:.1f} |")
    if "fig3" in res:
        out.append("\n| Policy | JCT p50 | p90 | p99 |")
        out.append("|---|---|---|---|")
        for k, v in res["fig3"].items():
            out.append(f"| {k} | {v['jct_p50']:.0f} | {v['jct_p90']:.0f} "
                       f"| {v['jct_p99']:.0f} |")
    if "fig4" in res:
        out.append("\n| Policy | util mean | p50 | p90 |")
        out.append("|---|---|---|---|")
        for k, v in res["fig4"].items():
            a = v["agg"]
            out.append(f"| {k} | {a['util_mean']:.3f} | {a['util_p50']:.3f}"
                       f" | {a['util_p90']:.3f} |")
    return "\n".join(out)


def fitmask_table(path: str = "BENCH_fitmask.json") -> str:
    """Multi-box kernel sweep: one VMEM pass for K boxes vs K
    single-box pallas_calls (interpret mode), with the jitted CPU-jax
    and numpy engines for scale."""
    with open(path) as f:
        bench = json.load(f)
    lines = ["| grid | batch | K | multibox ms | single x K ms | "
             "speedup | jax ms | numpy ms |",
             "|---|---|---|---|---|---|---|---|"]
    for r in bench.get("sweep", []):
        lines.append(
            f"| {r['grid']} | {r['batch']} | {r['k']} | "
            f"{r['pallas_multibox_ms']:.1f} | "
            f"{r['pallas_singlebox_x_k_ms']:.1f} | "
            f"{r['multibox_speedup']}x | {r['jax_ms']:.2f} | "
            f"{r['numpy_ms']:.2f} |")
    head = bench.get("headline", {})
    if head:
        lines.append(
            f"\nHeadline ({head.get('criterion')}): "
            f"{head.get('min_speedup')}x-{head.get('max_speedup')}x, "
            f"pass={head.get('pass')}")
    return "\n".join(lines)


def reconfig_table(path: str = "BENCH_reconfig.json") -> str:
    """Batched plan search vs the naive oracle per cube granularity."""
    with open(path) as f:
        bench = json.load(f)
    lines = ["| cube | batched s | naive s | speedup | jcr |",
             "|---|---|---|---|---|"]
    for cube, r in bench.get("cube_sizes", {}).items():
        lines.append(
            f"| {cube} | {r['batched']['sim_seconds']:.2f} | "
            f"{r['naive']['sim_seconds']:.2f} | {r['speedup']}x | "
            f"{r['batched']['jcr']:.3f} |")
    head = bench.get("headline", {})
    if head:
        lines.append(f"\nHeadline ({head.get('criterion')}): "
                     f"{head.get('speedups')}, pass={head.get('pass')}")
    return "\n".join(lines)


def fleet_table(path: str = "BENCH_fleet.json") -> str:
    """Fleet-batched eval: broker-coalesced engine calls vs the
    sequential single-sim oracle (parity + dual headline)."""
    with open(path) as f:
        bench = json.load(f)
    lines = []
    par = bench.get("parity", {})
    if par:
        lines.append(
            f"Parity: {par.get('runs')}x{par.get('num_jobs')}x"
            f"{par.get('configs')} matrix identical="
            f"{par.get('identical')} — sequential "
            f"{par.get('sequential_s')}s vs fleet {par.get('fleet_s')}s "
            f"on the numpy host engine ({par.get('numpy_speedup')}x)")
    eng = bench.get("engine", {})
    if eng:
        b = eng.get("broker", {})
        lines.append(
            "\n| engine | sims | rounds | queries | sequential s | "
            "fleet s | speedup | mean B | batched calls |")
        lines.append("|---|---|---|---|---|---|---|---|---|")
        lines.append(
            f"| {eng.get('engine')} ({eng.get('grid')}, "
            f"K={eng.get('k_boxes')}) | {eng.get('sims')} | "
            f"{eng.get('rounds')} | {eng.get('queries')} | "
            f"{eng.get('sequential_s')} | {eng.get('fleet_s')} | "
            f"{eng.get('speedup')}x | {b.get('mean_grids_per_call')} | "
            f"{b.get('batched_calls')}/{b.get('engine_calls')} |")
        if b:
            lines.append(
                f"\nBroker: flush triggers all_parked="
                f"{b.get('flush_all_parked')} quorum="
                f"{b.get('flush_quorum')} timeout="
                f"{b.get('flush_timeout')}, requeued="
                f"{b.get('requeued')}, pad waste B="
                f"{b.get('b_pad_waste')} K={b.get('k_pad_waste')}, "
                f"free-count cache hits={b.get('fc_cache_hits')}")
            if "engine_failovers" in b:
                lines.append(
                    f"\nResilience: steppers reaped="
                    f"{b.get('steppers_reaped')}, engine retries="
                    f"{b.get('engine_retries')}, failovers="
                    f"{b.get('engine_failovers')} "
                    f"(to {b.get('failover_engine')}), canary checks="
                    f"{b.get('canary_checks')} mismatches="
                    f"{b.get('canary_mismatches')}")
    can = bench.get("canary", {})
    if can:
        lines.append(
            f"\nCanary drill: {can.get('start_engine')} -> "
            f"{can.get('adopted_engine')} after "
            f"{can.get('engine_failovers')} failover(s), "
            f"{can.get('canary_checks')} post-failover flushes "
            f"parity-checked, {can.get('canary_mismatches')} "
            f"mismatches (gate: must be 0)")
    head = bench.get("headline", {})
    if head:
        lines.append(
            f"\nHeadline: numpy {head.get('numpy_speedup')}x "
            f"(pass={head.get('pass_numpy')}), engine "
            f"{head.get('engine_speedup')}x "
            f"(pass={head.get('pass_engine')}), canary mismatches "
            f"{head.get('canary_mismatches')} "
            f"(pass={head.get('pass_canary')}) -> "
            f"pass={head.get('pass')}")
    return "\n".join(lines)


def service_table(path: str = "BENCH_service.json") -> str:
    """Allocator service: daemon parity, p99 placement latency under
    Poisson load, admission under overload."""
    with open(path) as f:
        bench = json.load(f)
    lines = []
    par = bench.get("parity", {})
    if par.get("configs"):
        lines.append("| policy | jobs | byte-identical | remote s |")
        lines.append("|---|---|---|---|")
        for r in par["configs"]:
            lines.append(f"| {r['label']} | {r['jobs']} | "
                         f"{r['identical']} | {r['remote_s']} |")
    lat = bench.get("latency", {})
    if lat:
        rem, loc = lat.get("remote", {}), lat.get("local", {})
        lines.append(
            f"\nLatency ({lat.get('jobs')} Poisson jobs, "
            f"{rem.get('rpcs')} RPCs): remote submit p50 "
            f"{rem.get('submit_p50_ms')}ms / p99 "
            f"{rem.get('submit_p99_ms')}ms vs in-process p99 "
            f"{loc.get('submit_p99_ms')}ms -> service overhead p99 "
            f"{lat.get('overhead_p99_ms')}ms")
    adm = bench.get("admission", {})
    if adm:
        c = adm.get("counts", {})
        lines.append(
            f"\nAdmission (flood {adm.get('flood')}, queue cap "
            f"{adm.get('max_queue')}): {c.get('placed')} placed / "
            f"{c.get('queued')} queued / {c.get('rejected')} rejected, "
            f"depth bounded={adm.get('depth_bounded')}, rejects "
            f"stateless={adm.get('rejects_stateless')}, status under "
            f"load {adm.get('status_under_load_ms')}ms")
    res = bench.get("resilience", {})
    if res:
        cnt = res.get("counters", {})
        lines.append(
            f"\nResilience crash drill ({res.get('ops')} ops, kills at "
            f"{res.get('kills')}): digest identical="
            f"{res.get('identical')}, resends clean="
            f"{res.get('resends_clean')}, dedup hits "
            f"{cnt.get('dedup_hits')}, WAL tail {cnt.get('wal_tail_ops')} "
            f"ops, recovered {cnt.get('recovered_ops')} ops at last boot, "
            f"lease expiries {cnt.get('lease_expiries')}")
    head = bench.get("headline", {})
    if head:
        line = (
            f"\nHeadline: p99 {head.get('p99_ms')}ms, service overhead "
            f"{head.get('overhead_p99_ms')}ms "
            f"(<= {head.get('threshold_ms')}ms), "
            f"parity={head.get('parity')}, "
            f"admission={head.get('admission')}")
        if "resilience" in head:
            line += f", resilience={head.get('resilience')}"
        lines.append(line + f" -> pass={head.get('pass')}")
    return "\n".join(lines)


def chaos_table(path: str = "BENCH_chaos.json") -> str:
    """Scenario x policy degradation/recovery matrix from the chaos
    bench (dip depth, recovered utilization, victim dispositions)."""
    with open(path) as f:
        bench = json.load(f)
    lines = ["| scenario | policy | jcr | util | dip | recovered util | "
             "preempted | migrated | deterministic |",
             "|---|---|---|---|---|---|---|---|---|"]
    for scenario in sorted(bench.get("scenarios", {})):
        for pol, cell in bench["scenarios"][scenario].items():
            ch = cell["chaos"]
            lines.append(
                f"| {scenario} | {cell.get('label', pol)} | "
                f"{cell['summary']['jcr']:.3f} | "
                f"{ch['util_overall']:.3f} | {ch['dip_depth']:.3f} | "
                f"{ch['recovered_util']:.3f} | {ch['preempted']} | "
                f"{ch['migrated']} | {cell['deterministic']} |")
    head = bench.get("headline", {})
    if head:
        lines.append(
            f"\nHeadline ({head.get('criterion')}): rfold util "
            f"{head.get('rfold_util')} vs static best "
            f"{head.get('static_best_util')}, recovered="
            f"{head.get('rfold_recovered')}, deterministic="
            f"{head.get('deterministic')} -> pass={head.get('pass')}")
    return "\n".join(lines)


def crash_table(path: str = "BENCH_crash_loop.json") -> str:
    """Crash-loop drill: SIGKILLed daemon vs uninterrupted control —
    the replay must be byte-identical and every resend a dedup hit."""
    with open(path) as f:
        bench = json.load(f)
    cnt = bench.get("crash", {}).get("resilience", {})
    lines = [
        f"Stream: {bench.get('ops')} ops, SIGKILL at {bench.get('kills')}",
        "\n| run | digest | journal ops |",
        "|---|---|---|",
        f"| control | `{bench.get('control', {}).get('digest', '')[:16]}` "
        f"| {bench.get('control', {}).get('journal_ops')} |",
        f"| crash-loop | `{bench.get('crash', {}).get('digest', '')[:16]}` "
        f"| {bench.get('crash', {}).get('journal_ops')} |",
        f"\nRecovery: {cnt.get('recovered_ops')} ops at last boot "
        f"({cnt.get('wal_tail_ops')} from the WAL tail), "
        f"{cnt.get('dedup_hits')} dedup hits on resend, identical="
        f"{bench.get('identical')} -> pass={bench.get('pass')}",
    ]
    return "\n".join(lines)


def failover_table(path: str = "BENCH_failover.json") -> str:
    """Failover drill: kill -9 the primary mid-stream, promote the
    standby, fence the resurrected stale primary."""
    with open(path) as f:
        bench = json.load(f)
    h = bench.get("headline", {})
    fo = bench.get("failover", {})
    ack = bench.get("ack_overhead", {})
    lines = [
        "| run | digest | data ops | epoch |",
        "|---|---|---|---|",
        f"| control | `{bench.get('control', {}).get('digest', '')}` | "
        f"{bench.get('control', {}).get('data_ops')} | 1 |",
        f"| failover | `{fo.get('digest', '')}` | {fo.get('data_ops')} | "
        f"{fo.get('epoch')} |",
        f"\nFailover ({h.get('ops')} ops, SIGKILL at op "
        f"{fo.get('kill_at_op')}): RTO {h.get('rto_ms')}ms, replication "
        f"lag at kill {h.get('repl_lag_at_kill')} ops, acked ops lost "
        f"{h.get('acked_ops_lost')}, resend exactly-once="
        f"{h.get('resend_exactly_once')}",
        f"\nFencing: stale-primary writes landed "
        f"{h.get('fenced_writes_landed')} (journal+client sides="
        f"{h.get('fenced_client_and_journal')})",
    ]
    if ack:
        lines.append(
            f"\nAck modes: sync p50 "
            f"{ack.get('sync', {}).get('p50_ms')}ms vs async p50 "
            f"{ack.get('async', {}).get('p50_ms')}ms "
            f"(+{ack.get('overhead_p50_ms')}ms; sync standby-durable "
            f"frac {ack.get('sync', {}).get('replicated_frac')})")
    lines.append(f"\nHeadline: digest_identical={h.get('digest_identical')}"
                 f" -> pass={bench.get('pass')}")
    return "\n".join(lines)


def bench_table(alloc_path: str = "BENCH_allocator.json",
                eval_path: str = "BENCH_paper_eval.json") -> str:
    """Perf trajectory: placement-engine rates (BENCH_allocator.json)
    alongside end-to-end eval wall-clock (BENCH_paper_eval.json)."""
    out = []
    if os.path.exists(alloc_path):
        with open(alloc_path) as f:
            alloc = json.load(f)
        out.append("| policy bench | scale | sim s | placements/s | JCR |")
        out.append("|---|---|---|---|---|")
        for label, scales in alloc.get("policies", {}).items():
            for scale, r in scales.items():
                out.append(f"| {label} | {scale} | {r['sim_seconds']:.2f} "
                           f"| {r['placements_per_sec']:.0f} "
                           f"| {r['jcr']:.3f} |")
        base = alloc.get("baseline", {})
        if "speedup_vs_naive" in base:
            out.append(f"\nIncremental engine speedup vs naive RFold "
                       f"baseline: {base['speedup_vs_naive']:.1f}x")
    if os.path.exists(eval_path):
        with open(eval_path) as f:
            ev = json.load(f)
        cfg, pool = ev.get("config", {}), ev.get("pool", {})
        out.append(f"\nPaper eval ({cfg.get('runs')} runs x "
                   f"{cfg.get('num_jobs')} jobs): {ev.get('wall_s')}s "
                   f"wall on {pool.get('workers')} workers "
                   f"({pool.get('sim_s_total')}s sim total, "
                   f"{pool.get('reused_from_checkpoint')}/"
                   f"{pool.get('tasks')} from checkpoints)")
        per_pol = ev.get("per_policy_sim_s", {})
        if per_pol:
            out.append("\n| policy | total sim s |")
            out.append("|---|---|")
            for label, s in sorted(per_pol.items(), key=lambda kv: -kv[1]):
                out.append(f"| {label} | {s:.1f} |")
    return "\n".join(out) if out else "(no BENCH_*.json artifacts yet)"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--which", default="all",
                    choices=["all", "dryrun", "roofline", "paper", "bench",
                             "fitmask", "reconfig", "fleet", "service",
                             "chaos", "crash", "failover"])
    args = ap.parse_args()
    if args.which in ("all", "dryrun"):
        print("### Dry-run matrix\n")
        print(dryrun_table("experiments/dryrun"))
    if args.which in ("all", "roofline") and \
            os.path.exists("experiments/roofline.json"):
        print("\n### Roofline baseline\n")
        print(roofline_table("experiments/roofline.json"))
    if args.which in ("all", "paper") and \
            os.path.exists("experiments/paper_eval.json"):
        print("\n### Paper validation\n")
        print(paper_table("experiments/paper_eval.json"))
    if args.which in ("all", "bench"):
        print("\n### Perf trajectory (BENCH_*.json)\n")
        print(bench_table())
    if args.which in ("all", "fitmask") and \
            os.path.exists("BENCH_fitmask.json"):
        print("\n### Fitmask multi-box kernel (BENCH_fitmask.json)\n")
        print(fitmask_table())
    if args.which in ("all", "reconfig") and \
            os.path.exists("BENCH_reconfig.json"):
        print("\n### Reconfiguration plan search (BENCH_reconfig.json)\n")
        print(reconfig_table())
    if args.which in ("all", "fleet") and \
            os.path.exists("BENCH_fleet.json"):
        print("\n### Fleet-batched eval (BENCH_fleet.json)\n")
        print(fleet_table())
    if args.which in ("all", "service") and \
            os.path.exists("BENCH_service.json"):
        print("\n### Allocator service (BENCH_service.json)\n")
        print(service_table())
    if args.which in ("all", "chaos") and \
            os.path.exists("BENCH_chaos.json"):
        print("\n### Chaos layer (BENCH_chaos.json)\n")
        print(chaos_table())
    if args.which in ("all", "crash") and \
            os.path.exists("BENCH_crash_loop.json"):
        print("\n### Crash-loop drill (BENCH_crash_loop.json)\n")
        print(crash_table())
    if args.which in ("all", "failover") and \
            os.path.exists("BENCH_failover.json"):
        print("\n### Failover drill (BENCH_failover.json)\n")
        print(failover_table())


if __name__ == "__main__":
    main()
