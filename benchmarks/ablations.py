"""Ablation sweeps from EXPERIMENTS.md, driven by the parallel eval
subsystem (``repro.eval``): each arm is a run-matrix of seeded sims
fanned across the process pool on paired traces.

Arms:
  * dedicate_chained — strand the unused sub-blocks of chained cubes
    (DESIGN.md "Cube ownership") vs the default shared-ownership OCS.
  * backfill — aggressive backfilling vs the paper's FIFO head-of-line
    blocking (paper §5 invites revisiting admission).
  * scatter — best-effort scatter slowdown sweep around the paper's
    measured contention factors (1.35 / 1.5 / 1.95, §3.1).

  PYTHONPATH=src python -m benchmarks.ablations --runs 10 --num-jobs 200
"""
from __future__ import annotations

import argparse
import json
import os

from repro.eval import EvalRunner, aggregate_by_label, make_tasks

CUBE = dict(num_xpus=4096, cube_n=4)

ARMS = {
    "dedicate_chained": [
        ("Reconfig (4^3)", "reconfig", CUBE, {}),
        ("Reconfig (4^3) dedicated", "reconfig",
         {**CUBE, "dedicate_chained": True}, {}),
        ("RFold (4^3)", "rfold", CUBE, {}),
        ("RFold (4^3) dedicated", "rfold",
         {**CUBE, "dedicate_chained": True}, {}),
    ],
    "backfill": [
        ("RFold FIFO", "rfold", CUBE, {}),
        ("RFold backfill", "rfold", CUBE, {"backfill": True}),
    ],
    "scatter": [
        ("RFold (no scatter)", "rfold", CUBE, {}),
        ("RFold-BE 1.35", "rfold_be", {**CUBE, "scatter_slowdown": 1.35}, {}),
        ("RFold-BE 1.5", "rfold_be", {**CUBE, "scatter_slowdown": 1.5}, {}),
        ("RFold-BE 1.95", "rfold_be", {**CUBE, "scatter_slowdown": 1.95}, {}),
    ],
}

COLS = ("jcr", "jct_p50", "jct_p90", "jct_p99", "util_mean")


def run_arm(arm: str, runs: int, num_jobs: int, load: float, seed0: int,
            workers, ckpt_dir) -> dict:
    print(f"# ablation: {arm}")
    print("variant," + ",".join(COLS))
    # One pool over the whole arm's run matrix (variants only differ in
    # policy/sim kwargs, so their tasks are independent and can
    # interleave); aggregate_by_label splits the records back out.
    tasks = []
    for label, policy, pkw, skw in ARMS[arm]:
        tasks += make_tasks([(label, policy, pkw)], runs, num_jobs, load,
                            seed0, sim_kw=skw)
    runner = EvalRunner(checkpoint_dir=ckpt_dir, workers=workers)
    aggs = aggregate_by_label(runner.run(tasks))
    out = {}
    for label, _, _, _ in ARMS[arm]:
        agg = aggs[label]["agg"]
        out[label] = agg
        print(label + "," + ",".join(
            f"{agg[c]:.3f}" if c in ("jcr", "util_mean") else f"{agg[c]:.0f}"
            for c in COLS))
    return out


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--runs", type=int, default=10)
    ap.add_argument("--num-jobs", type=int, default=200)
    ap.add_argument("--load", type=float, default=1.5)
    ap.add_argument("--seed0", type=int, default=100)
    ap.add_argument("--workers", type=int, default=None)
    ap.add_argument("--ckpt-dir", type=str,
                    default=os.path.join("experiments", "ablations_ckpt"))
    ap.add_argument("--arm", default="all",
                    choices=["all"] + sorted(ARMS))
    ap.add_argument("--out", type=str, default="")
    args = ap.parse_args(argv)
    results = {}
    for arm in (sorted(ARMS) if args.arm == "all" else [args.arm]):
        results[arm] = run_arm(arm, args.runs, args.num_jobs, args.load,
                               args.seed0, args.workers,
                               args.ckpt_dir or None)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1, default=float)


if __name__ == "__main__":
    main()
