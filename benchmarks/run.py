"""Benchmark orchestrator: one section per paper table/figure plus the
framework microbenches (``name,us_per_call,derived`` CSV) and the
roofline summary.

  PYTHONPATH=src python -m benchmarks.run            # CI-sized
  PYTHONPATH=src python -m benchmarks.run --full     # paper-scale
"""
from __future__ import annotations

import argparse
import os
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale averaging (100 runs)")
    ap.add_argument("--runs", type=int, default=2)
    ap.add_argument("--num-jobs", type=int, default=120)
    ap.add_argument("--workers", type=int, default=None,
                    help="eval process-pool width (default: cpu count)")
    ap.add_argument("--skip-paper", action="store_true")
    ap.add_argument("--skip-micro", action="store_true")
    ap.add_argument("--skip-alloc", action="store_true")
    ap.add_argument("--skip-fitmask", action="store_true")
    ap.add_argument("--skip-reconfig", action="store_true")
    ap.add_argument("--skip-fleet", action="store_true")
    ap.add_argument("--skip-service", action="store_true")
    ap.add_argument("--skip-chaos", action="store_true")
    ap.add_argument("--skip-crash", action="store_true")
    ap.add_argument("--skip-failover", action="store_true")
    args = ap.parse_args()
    t0 = time.time()

    from benchmarks import (allocator_bench, chaos_bench, crash_loop,
                            failover_drill, fitmask_bench, fleet_bench,
                            kernels_bench, paper_eval, reconfig_bench,
                            roofline, service_bench)

    os.makedirs("experiments", exist_ok=True)
    if not args.skip_paper:
        print("=" * 70)
        print("## Paper evaluation (Table 1 / Fig 3 / Fig 4)")
        eval_args = ["--runs", str(args.runs),
                     "--num-jobs", str(args.num_jobs)]
        if args.full:
            eval_args = ["--full"]
        if args.workers is not None:
            eval_args += ["--workers", str(args.workers)]
        # paper_eval fans the run x policy matrix across a process pool
        # with per-run checkpointing (see repro.eval); wall-clock stats
        # land in BENCH_paper_eval.json next to BENCH_allocator.json.
        paper_eval.main(eval_args + ["--out", "experiments/paper_eval.json",
                                     "--bench-out", "BENCH_paper_eval.json"])

    if not args.skip_alloc:
        print("=" * 70)
        print("## Allocator / placement-engine benchmark")
        allocator_bench.main(["--out", "BENCH_allocator.json"])

    if not args.skip_reconfig:
        print("=" * 70)
        print("## Reconfiguration plan-search benchmark (batched vs naive)")
        # Same snapshot policy as the fitmask bench: the tracked
        # BENCH_reconfig.json is the 120-job sweep; CI-sized runs smoke
        # the quick variant into experiments/.
        if args.full:
            reconfig_bench.main(["--out", "BENCH_reconfig.json"])
        else:
            reconfig_bench.main(["--quick", "--out",
                                 "experiments/BENCH_reconfig_quick.json"])

    if not args.skip_fleet:
        print("=" * 70)
        print("## Fleet-batched eval benchmark (broker-coalesced vs "
              "sequential)")
        # Snapshot policy as the other benches: the tracked
        # BENCH_fleet.json is the full parity+headline sweep; CI-sized
        # runs smoke the quick variant into experiments/.
        if args.full:
            fleet_bench.main(["--out", "BENCH_fleet.json"])
        else:
            fleet_bench.main(["--quick", "--out",
                              "experiments/BENCH_fleet_quick.json"])

    if not args.skip_service:
        print("=" * 70)
        print("## Allocator-service benchmark (daemon parity / p99 "
              "latency / admission)")
        # Same snapshot policy as the other benches: the tracked
        # BENCH_service.json is the full sweep; CI-sized runs smoke the
        # quick variant into experiments/.
        if args.full:
            service_bench.main(["--out", "BENCH_service.json"])
        else:
            service_bench.main(["--quick", "--out",
                                "experiments/BENCH_service_quick.json"])

    if not args.skip_chaos:
        print("=" * 70)
        print("## Chaos benchmark (scenario x policy degradation matrix)")
        # Snapshot policy as the other benches: the tracked
        # BENCH_chaos.json is the full 120-job matrix; CI-sized runs
        # smoke the quick variant into experiments/.
        if args.full:
            chaos_bench.main(["--out", "BENCH_chaos.json"])
        else:
            chaos_bench.main(["--quick", "--out",
                              "experiments/BENCH_chaos_quick.json"])

    if not args.skip_crash:
        print("=" * 70)
        print("## Crash-loop drill (SIGKILL recovery, digest-identical "
              "replay)")
        # Snapshot policy as the other benches: the tracked
        # BENCH_crash_loop.json is the full kill schedule; CI-sized
        # runs smoke the quick variant into experiments/.
        if args.full:
            crash_loop.main(["--out", "BENCH_crash_loop.json"])
        else:
            crash_loop.main(["--quick", "--out",
                             "experiments/BENCH_crash_loop_quick.json"])

    if not args.skip_failover:
        print("=" * 70)
        print("## Failover drill (kill -9 primary, fenced promotion, "
              "replication lag)")
        if args.full:
            failover_drill.main(["--out", "BENCH_failover.json"])
        else:
            failover_drill.main(["--quick", "--out",
                                 "experiments/BENCH_failover_quick.json"])

    if not args.skip_fitmask:
        print("=" * 70)
        print("## Fitmask engine benchmark (multi-box vs single-box)")
        # The committed BENCH_fitmask.json is the full batch x K x grid
        # sweep; CI-sized runs smoke the headline cell into experiments/
        # so they don't clobber the tracked snapshot.
        if args.full:
            fitmask_bench.main(["--out", "BENCH_fitmask.json"])
        else:
            fitmask_bench.main(["--quick", "--out",
                                "experiments/BENCH_fitmask_quick.json"])

    if not args.skip_micro:
        print("=" * 70)
        print("## Microbenchmarks (CPU; Pallas kernels are TPU-targeted)")
        kernels_bench.main()

    print("=" * 70)
    print("## Roofline summary (from dry-run artifacts)")
    if os.path.isdir("experiments/dryrun") and \
            os.listdir("experiments/dryrun"):
        roofline.main(["--dryrun-dir", "experiments/dryrun",
                       "--mesh", "single",
                       "--out", "experiments/roofline.json"])
    else:
        print("(no dry-run artifacts yet: run "
              "`python -m repro.launch.dryrun --all --mesh both`)")

    print(f"# benchmarks total {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
