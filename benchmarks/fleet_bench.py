"""Fleet-batched eval benchmark: one engine, many simulators.

Two claims under test (see DESIGN.md §Fleet-batched eval and
§Continuous-batching broker):

* **Parity + host headline.** The CI-sized eval matrix (3 runs x 200
  jobs x 8 policy configs; ``--quick`` shrinks it) is run per-task
  (``fleet_size=0`` — the retained sequential oracle path) and with
  the runner's *defaults* (fleet mode is unconditional now), both at
  ``workers=0`` so the delta is the fleet layer itself, not process
  parallelism. The Table 1 / Fig 3 / Fig 4 aggregates must be
  **byte-identical**, and on the default numpy engine the fleet side
  must be **no slower than sequential** (>= 1.0x): the broker's
  continuous quorum/deadline scheduling plus the genuinely batched
  host multibox (``fit_mask_multi_fast``) and inline free-counts must
  at least pay for their own coordination.

* **Engine headline.** On a batched engine — where a call costs real
  dispatch, which is the whole reason the multibox kernel exists —
  serving a fleet's *coalesced query stream* must beat answering the
  same stream with per-simulator batch-1 calls by >= 5x, with the
  broker demonstrably issuing batched (B > 1, multi-request) engine
  calls. The headline replays an eval-shaped query stream (per
  round, each of N simulators submits one multibox over its own
  16^3 occupancy against a shared candidate-box set, plus one
  free-counts query — the static-torus epoch pattern) through the
  *real* broker under the fleet's production flush policy, one
  thread per simulator, against the ``jax`` engine (the accelerator
  path that runs everywhere CI does; the Pallas kernel shares its
  batching axis). The same stream is then driven batch-1, and both
  sides are warmed before timing. Answers are asserted equivalent
  per round (same fit truth-planes, same free counts — the broker's
  bucketed path returns bool planes where the inline path returns
  int32 0/1).

  Where the 5x comes from: one fused program per flush (integral
  image + all K planes + free counts, written in-place into a single
  (B, K, X, Y, Z) buffer) replaces ~22 per-sim dispatches; the
  free-counts content cache answers the follow-up free query of
  every simulator from the planes flush; and the bucket's stable box
  table means the steady state re-runs one compiled program at exact
  K rather than retracing per flush union.

  This is deliberately an engine-serving measurement, like the
  multi-box kernel bench it extends (one VMEM pass for K boxes ->
  one engine pass for B simulators): end-to-end eval wall-clock on
  a CPU-only container is GIL-bound python simulation plus host
  numpy mask work, which batching cannot compress (Amdahl — the
  parity section reports that delta honestly). The stream replay is
  the fraction the fleet layer actually owns, and the fraction that
  turns into accelerator dispatch/occupancy on real hardware.

  PYTHONPATH=src python -m benchmarks.fleet_bench [--quick] \
      [--out BENCH_fleet.json]
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict

from repro.eval import (EvalRunner, aggregate_by_label, fig3, fig4,
                        make_tasks, table1)

# Dual headline floors: fleet mode may not slow the host path down,
# and must beat per-sim batch-1 driving on a compiled engine by 5x.
NUMPY_FLOOR = 1.0
ENGINE_FLOOR = 5.0

# The paper's full policy matrix (benchmarks.paper_eval.TABLE1_CONFIGS
# + the Fig-3 extras), inlined so the bench stays import-light.
EVAL_CONFIGS = [
    ("FirstFit (16^3)", "firstfit", dict(dims=(16, 16, 16))),
    ("Folding (16^3)", "folding", dict(dims=(16, 16, 16))),
    ("Reconfig (8^3)", "reconfig", dict(num_xpus=4096, cube_n=8)),
    ("RFold (8^3)", "rfold", dict(num_xpus=4096, cube_n=8)),
    ("Reconfig (4^3)", "reconfig", dict(num_xpus=4096, cube_n=4)),
    ("RFold (4^3)", "rfold", dict(num_xpus=4096, cube_n=4)),
    ("Reconfig (2^3)", "reconfig", dict(num_xpus=4096, cube_n=2)),
    ("RFold (2^3)", "rfold", dict(num_xpus=4096, cube_n=2)),
]


def _strip(records):
    return [{k: v for k, v in r.items() if k != "sim_s"} for r in records]


def _figures(records):
    aggs = aggregate_by_label(records)
    return {"table1": table1(aggs), "fig3": fig3(aggs),
            "fig4": fig4(aggs)}


def parity_section(runs: int, num_jobs: int, seed0: int) -> Dict:
    """Sequential oracle (``fleet_size=0``) vs the runner defaults on
    the default (numpy) engine: byte-equal figures required, and the
    fleet side must not be slower (the numpy half of the headline)."""
    tasks = make_tasks(EVAL_CONFIGS, runs=runs, num_jobs=num_jobs,
                       load=1.5, seed0=seed0)
    t0 = time.perf_counter()
    seq = EvalRunner(workers=0, fleet_size=0).run(tasks)
    seq_s = time.perf_counter() - t0

    fleet_runner = EvalRunner(workers=0)   # fleet mode is the default
    t0 = time.perf_counter()
    fl = fleet_runner.run(tasks)
    fleet_s = time.perf_counter() - t0

    figs_seq, figs_fl = _figures(seq), _figures(fl)
    identical = (
        _strip(seq) == _strip(fl)
        and json.dumps(figs_seq, sort_keys=True, default=float)
        == json.dumps(figs_fl, sort_keys=True, default=float))
    return {
        "runs": runs, "num_jobs": num_jobs, "configs": len(EVAL_CONFIGS),
        "tasks": len(tasks), "identical": identical,
        "sequential_s": round(seq_s, 3), "fleet_s": round(fleet_s, 3),
        "numpy_speedup": round(seq_s / fleet_s, 2) if fleet_s else None,
        "fleet": fleet_runner.last_stats.get("fleet"),
    }


# The static-torus epoch pattern: one multibox over the simulator's
# own grid against its candidate-box set, plus one free-counts query.
# K = 20 candidate boxes — the scale a folding policy's fold
# enumeration actually produces per step.
REPLAY_BOXES = ((1, 1, 8), (1, 2, 4), (1, 4, 8), (2, 2, 2), (2, 2, 8),
                (2, 4, 2), (2, 4, 8), (2, 8, 4), (4, 2, 2), (4, 4, 1),
                (4, 4, 4), (4, 8, 2), (8, 2, 1), (8, 4, 4), (8, 8, 2),
                (8, 8, 8), (16, 1, 1), (16, 2, 2), (16, 4, 1),
                (16, 16, 1))


def engine_section(sims: int, rounds: int, seed0: int,
                   engine: str = "jax") -> Dict:
    """The engine headline: replay ``rounds`` coalescing rounds of
    ``sims`` simulators' mask queries through the real broker under
    the fleet's production flush policy (one thread per simulator)
    vs driving the identical stream with per-simulator batch-1
    calls. Both sides warm; answers asserted equivalent."""
    import threading

    import numpy as np

    from repro.kernels.fitmask import ops
    from repro.sim.fleet import Fleet

    eng = ops.get_engine(engine)
    rng = np.random.default_rng(seed0)
    # Evolving occupancy per (simulator, round): fill drifts like a
    # loaded cluster's does.
    occ = rng.random((sims, rounds, 1, 16, 16, 16)) < \
        rng.uniform(0.1, 0.6, size=(sims, rounds, 1, 1, 1, 1))

    def drive_sequential():
        out = []
        for s in range(sims):
            row = []
            for t in range(rounds):
                row.append((np.asarray(eng.multibox(occ[s, t],
                                                    REPLAY_BOXES)),
                            np.asarray(eng.free_counts(occ[s, t]))))
            out.append(row)
        return out

    def drive_fleet():
        # The production broker policy: engine-aware quorum/deadline,
        # bucketed padded programs, fc content cache.
        broker = Fleet(eng).broker
        broker.pad_hint = sims
        out = [[None] * rounds for _ in range(sims)]

        def sim(s):
            try:
                for t in range(rounds):
                    mb = broker.multibox(occ[s, t], REPLAY_BOXES)
                    fc = broker.free_counts(occ[s, t])
                    out[s][t] = (mb, fc)
            finally:
                # Each simulator retires itself so survivors' rounds
                # keep flushing — exactly what Fleet.run does.
                broker.deactivate()

        for _ in range(sims):
            broker.register()
        threads = [threading.Thread(target=sim, args=(s,))
                   for s in range(sims)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        return out, broker.stats

    # Warm both sides (jit compiles at the bucket's padded and exact-K
    # table shapes, and at B=1 for the sequential path), then time
    # several passes and keep the best of each: dispatch timings on a
    # shared/loaded host are noisy, and best-of-N measures the
    # machinery rather than the scheduler.
    passes = 3
    drive_fleet()
    fleet_s, fleet_out, stats = None, None, None
    for _ in range(passes):
        t0 = time.perf_counter()
        out, st = drive_fleet()
        dt = time.perf_counter() - t0
        if fleet_s is None or dt < fleet_s:
            fleet_s, fleet_out, stats = dt, out, st

    drive_sequential()
    seq_s, seq_out = None, None
    for _ in range(passes):
        t0 = time.perf_counter()
        out = drive_sequential()
        dt = time.perf_counter() - t0
        if seq_s is None or dt < seq_s:
            seq_s, seq_out = dt, out

    # Truth-plane equivalence: inline multibox answers int32 0/1, the
    # broker's bucketed flush path answers bool — same fit truth.
    identical = all(
        np.array_equal(a[0] != 0, b[0] != 0)
        and np.array_equal(a[1], b[1])
        for srow, frow in zip(seq_out, fleet_out)
        for a, b in zip(srow, frow))
    return {
        "engine": engine, "sims": sims, "rounds": rounds,
        "k_boxes": len(REPLAY_BOXES), "grid": "16^3",
        "queries": sims * rounds * 2, "identical": identical,
        "sequential_s": round(seq_s, 3), "fleet_s": round(fleet_s, 3),
        "speedup": round(seq_s / fleet_s, 2) if fleet_s else None,
        "broker": stats.as_dict(),
    }


def canary_section(seed0: int, flushes: int = 6) -> Dict:
    """The post-failover parity canary as a first-class drill: two
    injected faults kill the pallas engine under the broker, which
    fails over to jax — and the first post-failover flushes are
    parity-checked against the host numpy oracle. Zero mismatches is
    a CI gate (the answers are pure functions of the inputs, so any
    mismatch is a real defect, not noise)."""
    import numpy as np

    from repro.sim.fleet import QueryBroker

    broker = QueryBroker("pallas")
    broker.inject_engine_faults(2)
    rng = np.random.default_rng(seed0)
    boxes = ((2, 2, 2), (4, 2, 1), (3, 3, 1))
    for _ in range(flushes):
        occ = rng.random((2, 16, 16, 16)) < 0.35
        broker.multibox(occ, boxes)
    st = broker.stats
    return {
        "start_engine": "pallas",
        "adopted_engine": broker.engine_name,
        "flushes": flushes,
        "engine_failovers": st.engine_failovers,
        "canary_checks": st.canary_checks,
        "canary_mismatches": st.canary_mismatches,
        "pass": bool(st.engine_failovers >= 1 and st.canary_checks >= 1
                     and st.canary_mismatches == 0),
    }


def main(argv=None) -> Dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", type=str, default="BENCH_fleet.json")
    ap.add_argument("--seed0", type=int, default=100)
    ap.add_argument("--quick", action="store_true",
                    help="smaller matrix for smoke runs")
    ap.add_argument("--engine", type=str, default="jax",
                    help="batched engine for the headline section")
    args = ap.parse_args(argv)

    runs, num_jobs = (2, 60) if args.quick else (3, 200)
    sims, rounds = (6, 80) if args.quick else (8, 120)

    print(f"# fleet bench: parity matrix {runs}x{num_jobs}x"
          f"{len(EVAL_CONFIGS)} (numpy), headline replay {sims} sims "
          f"x {rounds} rounds ({args.engine})")
    par = parity_section(runs, num_jobs, args.seed0)
    print(f"# parity: identical={par['identical']} "
          f"seq={par['sequential_s']}s fleet={par['fleet_s']}s "
          f"(numpy, {par['numpy_speedup']}x)")
    eng = engine_section(sims, rounds, args.seed0, engine=args.engine)
    print(f"# replay: identical={eng['identical']} "
          f"seq={eng['sequential_s']}s fleet={eng['fleet_s']}s "
          f"-> {eng['speedup']}x, broker {eng['broker']}")

    can = canary_section(args.seed0)
    print(f"# canary: {can['start_engine']} -> "
          f"{can['adopted_engine']} after "
          f"{can['engine_failovers']} failover(s), "
          f"{can['canary_checks']} checks "
          f"{can['canary_mismatches']} mismatches "
          f"(pass={can['pass']})")

    broker = eng["broker"]
    pass_numpy = bool(par["identical"] and par["numpy_speedup"]
                      and par["numpy_speedup"] >= NUMPY_FLOOR)
    pass_engine = bool(eng["identical"] and eng["speedup"]
                       and eng["speedup"] >= ENGINE_FLOOR
                       and broker["batched_calls"] > 0
                       and broker["mean_grids_per_call"] > 1)
    results = {
        "config": {"quick": args.quick, "seed0": args.seed0},
        "parity": par,
        "engine": eng,
        "canary": can,
        "headline": {
            "criterion": "fleet mode (the runner default) is >= "
                         f"{NUMPY_FLOOR}x sequential on the numpy host "
                         "engine with byte-identical eval aggregates, "
                         "AND the broker-coalesced query stream is >= "
                         f"{ENGINE_FLOOR}x faster than per-sim batch-1 "
                         f"driving on the batched ({args.engine}) "
                         "engine at CI size, broker issuing batched "
                         "(B > 1) engine calls, answers equivalent, "
                         "AND the post-failover parity canary records "
                         "zero mismatches",
            "numpy_speedup": par["numpy_speedup"],
            "engine_speedup": eng["speedup"],
            "batched_calls": broker["batched_calls"],
            "mean_grids_per_call": broker["mean_grids_per_call"],
            "flush_triggers": {
                "all_parked": broker["flush_all_parked"],
                "quorum": broker["flush_quorum"],
                "timeout": broker["flush_timeout"],
            },
            "requeued": broker["requeued"],
            "b_pad_waste": broker["b_pad_waste"],
            "k_pad_waste": broker["k_pad_waste"],
            "fc_cache_hits": broker["fc_cache_hits"],
            "canary_checks": can["canary_checks"],
            "canary_mismatches": can["canary_mismatches"],
            "pass_numpy": pass_numpy,
            "pass_engine": pass_engine,
            "pass_canary": can["pass"],
            "pass": pass_numpy and pass_engine and can["pass"],
        },
    }
    print(f"# headline: numpy {par['numpy_speedup']}x "
          f"(pass={pass_numpy}), {args.engine} {eng['speedup']}x "
          f"(pass={pass_engine}) -> pass={results['headline']['pass']}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"# wrote {args.out}")
    return results


if __name__ == "__main__":
    main()
