"""Paper evaluation reproductions: Table 1 (JCR), Fig 3 (JCT percentiles),
Fig 4 (utilization CDF). One function per paper table/figure.

Defaults are CI-sized (runs=3, 200 jobs); pass --full for the paper's
100-run averaging.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

import numpy as np

from repro.core.allocator import make_policy
from repro.sim.metrics import aggregate, summarize, utilization_cdf
from repro.sim.simulator import Simulator
from repro.traces.generator import TraceConfig, generate_trace

# Policy matrix as evaluated by the paper.
TABLE1_CONFIGS = [
    ("FirstFit (16^3)", "firstfit", dict(dims=(16, 16, 16))),
    ("Folding (16^3)", "folding", dict(dims=(16, 16, 16))),
    ("Reconfig (8^3)", "reconfig", dict(num_xpus=4096, cube_n=8)),
    ("RFold (8^3)", "rfold", dict(num_xpus=4096, cube_n=8)),
    ("Reconfig (4^3)", "reconfig", dict(num_xpus=4096, cube_n=4)),
    ("RFold (4^3)", "rfold", dict(num_xpus=4096, cube_n=4)),
]

# Fig 3 compares JCT only where JCR == 100%.
FIG3_CONFIGS = [
    ("Reconfig (4^3)", "reconfig", dict(num_xpus=4096, cube_n=4)),
    ("RFold (4^3)", "rfold", dict(num_xpus=4096, cube_n=4)),
    ("Reconfig (2^3)", "reconfig", dict(num_xpus=4096, cube_n=2)),
    ("RFold (2^3)", "rfold", dict(num_xpus=4096, cube_n=2)),
]

PAPER_TABLE1 = {   # paper-reported Avg JCR (%)
    "FirstFit (16^3)": 10.4, "Folding (16^3)": 44.11,
    "Reconfig (8^3)": 31.46, "RFold (8^3)": 73.35,
    "Reconfig (4^3)": 100.0, "RFold (4^3)": 100.0,
}


def _run_policy(label: str, name: str, kw: dict, runs: int,
                num_jobs: int, load: float, seed0: int):
    summaries, cdfs = [], []
    for r in range(runs):
        cfg = TraceConfig(num_jobs=num_jobs, seed=seed0 + r,
                          target_load=load)
        pol = make_policy(name, **kw)
        res = Simulator(pol, generate_trace(cfg)).run()
        summaries.append(summarize(res))
        cdfs.append(utilization_cdf(res))
    agg = aggregate(summaries)
    levels = cdfs[0][0]
    cdf = np.mean([c for _, c in cdfs], axis=0)
    return agg, (levels, cdf)


def table1_jcr(runs: int = 3, num_jobs: int = 200, load: float = 1.5,
               seed0: int = 100, emit=print) -> Dict[str, Dict]:
    emit("# Table 1 — Job Completion Rate (avg over %d runs)" % runs)
    emit("policy,jcr_pct,paper_jcr_pct")
    out = {}
    for label, name, kw in TABLE1_CONFIGS:
        agg, _ = _run_policy(label, name, kw, runs, num_jobs, load, seed0)
        out[label] = agg
        emit("%s,%.2f,%.2f" % (label, 100 * agg["jcr"], PAPER_TABLE1[label]))
    return out


def fig3_jct(runs: int = 3, num_jobs: int = 200, load: float = 1.5,
             seed0: int = 100, emit=print) -> Dict[str, Dict]:
    emit("# Fig 3 — JCT p50/p90/p99 (policies with 100%% JCR)")
    emit("policy,jct_p50_s,jct_p90_s,jct_p99_s")
    out = {}
    for label, name, kw in FIG3_CONFIGS:
        agg, _ = _run_policy(label, name, kw, runs, num_jobs, load, seed0)
        out[label] = agg
        emit("%s,%.0f,%.0f,%.0f" % (label, agg["jct_p50"], agg["jct_p90"],
                                    agg["jct_p99"]))
    for n in ("4^3", "2^3"):
        rc, rf = out.get(f"Reconfig ({n})"), out.get(f"RFold ({n})")
        if rc and rf:
            emit("ratio Reconfig/RFold (%s): p50=%.1fx p90=%.1fx p99=%.1fx "
                 "(paper 4^3: 11x/6x/2x, 2^3: <=1.3x)"
                 % (n, rc["jct_p50"] / rf["jct_p50"],
                    rc["jct_p90"] / rf["jct_p90"],
                    rc["jct_p99"] / rf["jct_p99"]))
    return out


def fig4_utilization(runs: int = 3, num_jobs: int = 200, load: float = 1.5,
                     seed0: int = 100, emit=print) -> Dict[str, Dict]:
    emit("# Fig 4 — cluster utilization (time-weighted)")
    emit("policy,util_mean,util_p50,util_p90")
    out = {}
    for label, name, kw in TABLE1_CONFIGS:
        agg, cdf = _run_policy(label, name, kw, runs, num_jobs, load, seed0)
        out[label] = {"agg": agg, "cdf": [list(map(float, c)) for c in cdf]}
        emit("%s,%.3f,%.3f,%.3f" % (label, agg["util_mean"], agg["util_p50"],
                                    agg["util_p90"]))
    ff = out["FirstFit (16^3)"]["agg"]["util_mean"]
    rc = out["Reconfig (4^3)"]["agg"]["util_mean"]
    rf = out["RFold (4^3)"]["agg"]["util_mean"]
    emit("RFold - FirstFit = +%.1f pts absolute (paper: +57)"
         % (100 * (rf - ff)))
    emit("RFold - Reconfig = +%.1f pts absolute (paper: +20)"
         % (100 * (rf - rc)))
    return out


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--runs", type=int, default=3)
    ap.add_argument("--num-jobs", type=int, default=200)
    ap.add_argument("--load", type=float, default=1.5)
    ap.add_argument("--full", action="store_true",
                    help="paper-scale averaging (100 runs, 500 jobs)")
    ap.add_argument("--out", type=str, default="")
    ap.add_argument("--which", type=str, default="all",
                    choices=["all", "table1", "fig3", "fig4"])
    args = ap.parse_args(argv)
    runs, n = (100, 500) if args.full else (args.runs, args.num_jobs)
    t0 = time.time()
    results = {}
    if args.which in ("all", "table1"):
        results["table1"] = table1_jcr(runs, n, args.load)
    if args.which in ("all", "fig3"):
        results["fig3"] = fig3_jct(runs, n, args.load)
    if args.which in ("all", "fig4"):
        results["fig4"] = fig4_utilization(runs, n, args.load)
    print(f"# total {time.time() - t0:.0f}s")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1, default=float)


if __name__ == "__main__":
    main()
