"""Paper evaluation reproductions: Table 1 (JCR), Fig 3 (JCT percentiles),
Fig 4 (utilization CDF), driven by the parallel evaluation subsystem
(``repro.eval``): the run x policy matrix fans out across a process
pool, every run is checkpointed, and the three tables are derived from
one shared set of per-run records (each config is simulated once, not
once per figure).

Defaults are CI-sized (runs=3, 200 jobs); pass --full for the paper's
100-run x 500-job averaging. An interrupted sweep resumes from
--ckpt-dir; pass --fresh to discard checkpoints. Runner wall-clock
stats land in BENCH_paper_eval.json (--bench-out).
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict

from repro.api import (PAPER_TABLE1, EvalRunner, aggregate_by_label, fig3,
                       fig4, make_tasks, table1)

# Policy matrix as evaluated by the paper.
TABLE1_CONFIGS = [
    ("FirstFit (16^3)", "firstfit", dict(dims=(16, 16, 16))),
    ("Folding (16^3)", "folding", dict(dims=(16, 16, 16))),
    ("Reconfig (8^3)", "reconfig", dict(num_xpus=4096, cube_n=8)),
    ("RFold (8^3)", "rfold", dict(num_xpus=4096, cube_n=8)),
    ("Reconfig (4^3)", "reconfig", dict(num_xpus=4096, cube_n=4)),
    ("RFold (4^3)", "rfold", dict(num_xpus=4096, cube_n=4)),
]

# Fig 3 compares JCT only where JCR == 100%; 4^3 overlaps Table 1,
# 2^3 is Fig-3-only.
FIG3_EXTRA_CONFIGS = [
    ("Reconfig (2^3)", "reconfig", dict(num_xpus=4096, cube_n=2)),
    ("RFold (2^3)", "rfold", dict(num_xpus=4096, cube_n=2)),
]
FIG3_LABELS = ["Reconfig (4^3)", "RFold (4^3)",
               "Reconfig (2^3)", "RFold (2^3)"]

DEFAULT_CKPT_DIR = os.path.join("experiments", "paper_eval_ckpt")


def _configs_for(which: str):
    if which == "fig3":
        table1_43 = [c for c in TABLE1_CONFIGS if "4^3" in c[0]]
        return table1_43 + FIG3_EXTRA_CONFIGS
    if which in ("table1", "fig4"):
        return list(TABLE1_CONFIGS)
    return list(TABLE1_CONFIGS) + FIG3_EXTRA_CONFIGS


def _run_matrix(configs, runs: int, num_jobs: int, load: float,
                seed0: int, workers, ckpt_dir, emit=print,
                trace_kw: Dict = None, fleet_size=None, scenario=None):
    tasks = make_tasks(configs, runs, num_jobs, load, seed0,
                       trace_kw=trace_kw, scenario=scenario)
    runner = EvalRunner(checkpoint_dir=ckpt_dir, workers=workers,
                        emit=emit, fleet_size=fleet_size)
    records = runner.run(tasks)
    return aggregate_by_label(records), runner.last_stats, tasks


def _legacy_aggs(aggs: Dict[str, Dict]) -> Dict[str, Dict]:
    """{label: metric means} — the schema the pre-subsystem emitters
    and experiments/paper_eval.json consumers expect."""
    return {label: a["agg"] for label, a in aggs.items()}


def _emit_table1(t1: Dict[str, Dict], runs: int, emit=print) -> None:
    emit("# Table 1 — Job Completion Rate (avg over %d runs)" % runs)
    emit("policy,jcr_pct,paper_jcr_pct")
    for label, row in t1.items():
        emit("%s,%.2f,%.2f" % (label, row["jcr_pct"], row["paper_jcr_pct"]))


def _emit_fig3(f3: Dict, emit=print) -> None:
    emit("# Fig 3 — JCT p50/p90/p99 (policies with 100%% JCR)")
    emit("policy,jct_p50_s,jct_p90_s,jct_p99_s")
    for label in FIG3_LABELS:
        p = f3["percentiles"].get(label)
        if p:
            emit("%s,%.0f,%.0f,%.0f" % (label, p["p50"], p["p90"], p["p99"]))
    for n, r in f3["ratios"].items():
        emit("ratio Reconfig/RFold (%s): p50=%.1fx p90=%.1fx p99=%.1fx "
             "(paper 4^3: 11x/6x/2x, 2^3: <=1.3x)"
             % (n, r["p50"], r["p90"], r["p99"]))


def _emit_fig4(f4: Dict, emit=print) -> None:
    emit("# Fig 4 — cluster utilization (time-weighted)")
    emit("policy,util_mean,util_p50,util_p90")
    for label, _, _ in TABLE1_CONFIGS:
        a = f4["per_policy"].get(label)
        if a:
            a = a["agg"]
            emit("%s,%.3f,%.3f,%.3f" % (label, a["util_mean"],
                                        a["util_p50"], a["util_p90"]))
    for key, d in f4["deltas"].items():
        emit("%s = +%.1f pts absolute (paper: +%.0f)"
             % (key, d["ours_pts"], d["paper_pts"]))


# -- pre-subsystem API kept for callers/tests --------------------------

def table1_jcr(runs: int = 3, num_jobs: int = 200, load: float = 1.5,
               seed0: int = 100, emit=print) -> Dict[str, Dict]:
    aggs, _, _ = _run_matrix(TABLE1_CONFIGS, runs, num_jobs, load, seed0,
                             workers=0, ckpt_dir=None)
    _emit_table1(table1(aggs), runs, emit)
    return _legacy_aggs(aggs)


def fig3_jct(runs: int = 3, num_jobs: int = 200, load: float = 1.5,
             seed0: int = 100, emit=print) -> Dict[str, Dict]:
    aggs, _, _ = _run_matrix(_configs_for("fig3"), runs, num_jobs, load,
                             seed0, workers=0, ckpt_dir=None)
    _emit_fig3(fig3(aggs), emit)
    return _legacy_aggs(aggs)


def fig4_utilization(runs: int = 3, num_jobs: int = 200, load: float = 1.5,
                     seed0: int = 100, emit=print) -> Dict[str, Dict]:
    aggs, _, _ = _run_matrix(TABLE1_CONFIGS, runs, num_jobs, load, seed0,
                             workers=0, ckpt_dir=None)
    f4 = fig4(aggs)
    _emit_fig4(f4, emit)
    return {label: {"agg": a["agg"], "cdf": a["cdf"]}
            for label, a in f4["per_policy"].items()}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--runs", type=int, default=3)
    ap.add_argument("--num-jobs", type=int, default=200)
    ap.add_argument("--load", type=float, default=1.5)
    ap.add_argument("--seed0", type=int, default=100)
    ap.add_argument("--full", action="store_true",
                    help="paper-scale averaging (100 runs, 500 jobs)")
    ap.add_argument("--workers", type=int, default=None,
                    help="process-pool width (default: auto-sized from "
                         "os.cpu_count(); <=1 runs inline)")
    ap.add_argument("--fleet-size", type=str, default="auto",
                    help="simulators per in-process fleet (continuous "
                         "engine-call batching, repro.sim.fleet). "
                         "'auto' (the default) always fleets — on "
                         "every engine, numpy host included — sizing "
                         "from the task backlog per worker; an "
                         "integer forces fleets of that size; 0/1 "
                         "selects the sequential per-task oracle path")
    ap.add_argument("--ckpt-dir", type=str, default=DEFAULT_CKPT_DIR,
                    help="per-run checkpoint dir ('' disables)")
    ap.add_argument("--fresh", action="store_true",
                    help="ignore + remove existing checkpoints")
    ap.add_argument("--prune-ckpt", action="store_true",
                    help="after the run, drop checkpoints whose "
                         "fingerprint is not in this invocation's task "
                         "set (keeps the actions/cache store bounded)")
    ap.add_argument("--ckpt-max-mb", type=int, default=None,
                    help="with --prune-ckpt: also cap the surviving "
                         "store size, evicting oldest first")
    ap.add_argument("--out", type=str, default="")
    ap.add_argument("--bench-out", type=str, default=None,
                    help="runner wall-clock stats JSON ('' disables; "
                         "default: BENCH_paper_eval.json for CI-sized "
                         "runs, experiments/BENCH_paper_eval_full.json "
                         "for --full, so paper-scale sweeps don't "
                         "clobber the committed CI-sized snapshot)")
    ap.add_argument("--which", type=str, default="all",
                    choices=["all", "table1", "fig3", "fig4"])
    ap.add_argument("--trace-preset", type=str, default=None,
                    help="named TraceConfig calibration preset (e.g. "
                         "'philly'); expanded into concrete trace fields "
                         "so checkpoint fingerprints stay value-based")
    ap.add_argument("--scenario", type=str, default=None,
                    help="run the matrix under a named chaos scenario "
                         "(repro.sim.scenarios: node_churn, "
                         "ocs_degraded, bursty, multi_tenant) — the "
                         "degraded-fabric paper eval. Default: healthy "
                         "baseline. Scenario runs fingerprint "
                         "differently, so give them their own "
                         "--ckpt-dir when checkpointing alongside the "
                         "healthy sweep")
    args = ap.parse_args(argv)
    if args.scenario:
        from repro.sim.scenarios import SCENARIOS
        if args.scenario not in SCENARIOS:
            ap.error(f"unknown scenario {args.scenario!r}; "
                     f"have {sorted(SCENARIOS)}")
    trace_kw = None
    if args.trace_preset:
        from repro.traces.generator import TRACE_PRESETS
        if args.trace_preset not in TRACE_PRESETS:
            ap.error(f"unknown trace preset {args.trace_preset!r}; "
                     f"have {sorted(TRACE_PRESETS)}")
        trace_kw = dict(TRACE_PRESETS[args.trace_preset])
    runs, n = (100, 500) if args.full else (args.runs, args.num_jobs)
    # Resolve the pool width explicitly (rather than inside EvalRunner)
    # so the bench artifact records the number actually used.
    workers = (os.cpu_count() or 1) if args.workers is None \
        else args.workers
    fleet_size = args.fleet_size
    if fleet_size not in ("auto",):
        fleet_size = int(fleet_size)
    bench_out = args.bench_out
    if bench_out is None:
        bench_out = (os.path.join("experiments", "BENCH_paper_eval_full.json")
                     if args.full else "BENCH_paper_eval.json")
    ckpt_dir = args.ckpt_dir or None
    if args.fresh and ckpt_dir and os.path.isdir(ckpt_dir):
        from repro.eval.runner import iter_checkpoints
        for path in iter_checkpoints(ckpt_dir):
            os.remove(path)

    t0 = time.time()
    aggs, stats, tasks = _run_matrix(_configs_for(args.which), runs, n,
                                     args.load, args.seed0, workers,
                                     ckpt_dir, trace_kw=trace_kw,
                                     fleet_size=fleet_size,
                                     scenario=args.scenario)
    if args.prune_ckpt and ckpt_dir and os.path.isdir(ckpt_dir):
        from repro.eval import prune_checkpoints
        max_bytes = (args.ckpt_max_mb * 1024 * 1024
                     if args.ckpt_max_mb else None)
        pstats = prune_checkpoints(ckpt_dir, tasks, max_bytes=max_bytes)
        print(f"# checkpoint prune: {pstats}")
    results: Dict = {}
    if args.which in ("all", "table1"):
        t1 = table1(aggs)
        _emit_table1(t1, runs)
        results["table1"] = {label: aggs[label]["agg"] for label in t1}
        results["table1_deltas"] = t1
    if args.which in ("all", "fig3"):
        f3 = fig3(aggs)
        _emit_fig3(f3)
        results["fig3"] = {label: aggs[label]["agg"]
                           for label in FIG3_LABELS if label in aggs}
        results["fig3_ratios"] = f3["ratios"]
    if args.which in ("all", "fig4"):
        f4 = fig4({label: a for label, a in aggs.items()
                   if label in PAPER_TABLE1})
        _emit_fig4(f4)
        results["fig4"] = {label: {"agg": a["agg"], "cdf": a["cdf"]}
                           for label, a in f4["per_policy"].items()}
        results["fig4_deltas"] = f4["deltas"]
    wall = time.time() - t0
    print(f"# total {wall:.0f}s (pool: {stats})")
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1, default=float)
    if bench_out:
        from repro.kernels.fitmask import ops
        bench = {
            "config": {"runs": runs, "num_jobs": n, "load": args.load,
                       "seed0": args.seed0, "which": args.which,
                       "full": args.full,
                       "scenario": args.scenario,
                       "trace_preset": args.trace_preset,
                       "workers": workers,
                       "fleet_size_arg": args.fleet_size,
                       # the resolved size actually used (None: the
                       # sequential per-task oracle path ran)
                       "fleet_size": stats.get("fleet", {}).get("size"),
                       "fitmask_engine": ops.default_engine_name()},
            "pool": stats,
            "wall_s": round(wall, 3),
            "per_policy_sim_s": {label: a["sim_s_total"]
                                 for label, a in aggs.items()},
        }
        os.makedirs(os.path.dirname(bench_out) or ".", exist_ok=True)
        with open(bench_out, "w") as f:
            json.dump(bench, f, indent=1)


if __name__ == "__main__":
    main()
