"""Allocator-service benchmark: parity, placement latency, admission.

Three sections over the live daemon (``repro.serve.scheduler``):

* **Parity.** A Poisson trace is simulated twice — in-process policy
  vs the daemon driven through :class:`RemotePolicy` over TCP — and
  the per-job schedules must be **byte-identical** on every policy.
  This is the CI smoke: the service is only a service if it is still
  the same allocator.

* **Latency headline.** The p99 wall-clock of a ``submit`` RPC while
  replaying a Poisson arrival trace against the daemon (completions
  retired between arrivals, so the occupancy grid churns like a loaded
  cluster's). The same op stream is replayed against an in-process
  :class:`AllocatorCore` — the identical state machine minus the
  socket and event loop — so the headline isolates what the service
  layer *owns*: protocol encode/decode, the loop hop, and event
  fan-out. Asserted: ``p99(remote) - p99(in-process) <= threshold``
  (default 25 ms — generous over the ~1 ms a local RPC costs, tight
  enough to catch an accidental O(n) in the daemon path). The
  placement work itself (tens to hundreds of ms at the p99 on the
  4096-XPU paper cluster — a fresh shape's feasibility probe places
  on an empty clone) is the allocator the other benches measure.

* **Admission under overload.** Flood a small cluster (bounded queue)
  with more feasible jobs than it can hold: every overflow submit must
  be REJECTED statelessly, the queue depth must never exceed the
  bound, and the daemon must still answer ``status`` promptly while
  overloaded.

* **Resilience (PR 9).** The crash-loop drill
  (``benchmarks.crash_loop``): the daemon is killed at seeded points
  mid-churn, recovers from snapshot + WAL tail, absorbs the resent
  in-flight ops through the journal-persisted dedup cache, and must
  land on a final state digest byte-identical to an uninterrupted
  control run. The dedup/lease/WAL counters land in the artifact.

  PYTHONPATH=src python -m benchmarks.service_bench [--quick] \
      [--out BENCH_service.json]
"""
from __future__ import annotations

import argparse
import json
import time
from heapq import heappop, heappush
from typing import Dict, List

import numpy as np

from repro.api import (Scheduler, SchedulerConfig, Simulator, TraceConfig,
                       generate_trace, make_policy, summarize)
from repro.serve.scheduler import PLACED, QUEUED, REJECTED, AllocatorCore

OVERHEAD_THRESHOLD_MS = 25.0

PARITY_CONFIGS = [
    ("FirstFit (8^3)", "firstfit", dict(dims=(8, 8, 8))),
    ("Folding (8^3)", "folding", dict(dims=(8, 8, 8))),
    ("Reconfig (4^3)", "reconfig", dict(num_xpus=512, cube_n=4)),
    ("RFold (4^3)", "rfold", dict(num_xpus=512, cube_n=4)),
    ("RFold-BE (4^3)", "rfold_be", dict(num_xpus=512, cube_n=4)),
]


def _job_record(jobs) -> str:
    return json.dumps(
        [[j.job_id, j.start, j.finish, j.dropped, j.slowdown,
          j.placement_meta] for j in jobs],
        sort_keys=True, default=list)


def parity_section(num_jobs: int, seed: int) -> Dict:
    """Drive the same trace through the in-process policy and through
    the daemon (simulator-as-client); schedules and summary metrics
    must match byte for byte."""
    trace_cfg = TraceConfig(num_jobs=num_jobs, cluster_xpus=512,
                            size_max=512, seed=seed)
    rows = []
    for label, policy, kw in PARITY_CONFIGS:
        local = Simulator(make_policy(policy, **kw),
                          generate_trace(trace_cfg)).run()
        t0 = time.perf_counter()
        with Scheduler(SchedulerConfig(policy=policy, policy_kw=kw)) as s:
            remote = Simulator(s.remote_policy(),
                               generate_trace(trace_cfg)).run()
        remote_s = time.perf_counter() - t0
        identical = (
            _job_record(local.jobs) == _job_record(remote.jobs)
            and json.dumps(summarize(local), sort_keys=True)
            == json.dumps(summarize(remote), sort_keys=True))
        rows.append({"label": label, "identical": identical,
                     "jobs": num_jobs,
                     "remote_s": round(remote_s, 3)})
    return {"configs": rows,
            "identical": all(r["identical"] for r in rows)}


def _replay(jobs, submit, done) -> Dict:
    """Poisson replay: retire completions between arrivals, time every
    submit. ``submit``/``done`` are callables returning reply dicts —
    the daemon client or the in-process core speak the same shape."""
    submit_ms: List[float] = []
    done_ms: List[float] = []
    outcomes: Dict[str, int] = {}
    finishing: List = []  # (finish_time, job_id) min-heap
    duration = {j.job_id: j.duration for j in jobs}
    for job in jobs:
        now = job.arrival
        while finishing and finishing[0][0] <= now:
            _, jid = heappop(finishing)
            t0 = time.perf_counter()
            d = done(jid)
            done_ms.append((time.perf_counter() - t0) * 1e3)
            for st in d["started"]:
                if st["outcome"] == PLACED:
                    heappush(finishing,
                             (now + duration[st["job_id"]],
                              st["job_id"]))
        t0 = time.perf_counter()
        r = submit(job)
        submit_ms.append((time.perf_counter() - t0) * 1e3)
        outcomes[r["outcome"]] = outcomes.get(r["outcome"], 0) + 1
        if r["outcome"] == PLACED:
            heappush(finishing, (now + job.duration, job.job_id))
    arr = np.asarray(submit_ms)
    return {
        "outcomes": outcomes,
        "submit_p50_ms": round(float(np.percentile(arr, 50)), 3),
        "submit_p99_ms": round(float(np.percentile(arr, 99)), 3),
        "submit_max_ms": round(float(arr.max()), 3),
        "done_p99_ms": round(float(np.percentile(done_ms, 99)), 3)
        if done_ms else None,
        "rpcs": len(submit_ms) + len(done_ms),
    }


def latency_section(num_jobs: int, seed: int) -> Dict:
    """The same Poisson op stream against the in-process core and the
    live daemon; the difference in p99 is the service layer's bill."""
    trace_cfg = TraceConfig(num_jobs=num_jobs, seed=seed)
    policy_kw = dict(num_xpus=4096, cube_n=4)

    def core_replay(core):
        return _replay(
            generate_trace(trace_cfg),
            lambda job: core.apply({"op": "submit", "job_id": job.job_id,
                                    "shape": list(job.shape.dims)})[0],
            lambda jid: core.apply({"op": "done", "job_id": jid})[0])

    # Warm-up pass on a throwaway core: fold enumeration and shape
    # factorization caches are process-global LRUs, and whichever side
    # runs first would otherwise pay every miss for both.
    core_replay(AllocatorCore(SchedulerConfig(policy="rfold",
                                              policy_kw=policy_kw)))

    core = AllocatorCore(SchedulerConfig(policy="rfold",
                                         policy_kw=policy_kw))
    local = core_replay(core)

    with Scheduler(SchedulerConfig(policy="rfold",
                                   policy_kw=policy_kw)) as sched:
        remote = _replay(
            generate_trace(trace_cfg),
            lambda job: sched.submit(job.shape, job_id=job.job_id),
            sched.done)

    assert remote["outcomes"] == local["outcomes"], (remote, local)
    return {
        "jobs": num_jobs,
        "outcomes": remote["outcomes"],
        "local": local,
        "remote": remote,
        "overhead_p50_ms": round(remote["submit_p50_ms"]
                                 - local["submit_p50_ms"], 3),
        "overhead_p99_ms": round(remote["submit_p99_ms"]
                                 - local["submit_p99_ms"], 3),
    }


def admission_section(flood: int) -> Dict:
    """Overload a one-cube cluster with a bounded queue: overflow must
    be rejected statelessly and the daemon must stay responsive."""
    max_queue = 8
    cfg = SchedulerConfig(policy="rfold",
                          policy_kw=dict(num_xpus=64, cube_n=4),
                          max_queue=max_queue)
    counts = {PLACED: 0, QUEUED: 0, REJECTED: 0}
    depth_ok = True
    with Scheduler(cfg) as sched:
        for _ in range(flood):
            r = sched.submit((4, 4, 4))  # whole-cube: one fits at a time
            counts[r["outcome"]] += 1
            depth_ok &= sched.status()["queue_depth"] <= max_queue
        t0 = time.perf_counter()
        st = sched.status()
        status_ms = (time.perf_counter() - t0) * 1e3
        journal_ops = st["journal_ops"]
    expected_rejects = flood - 1 - max_queue
    return {
        "flood": flood, "max_queue": max_queue, "counts": counts,
        "depth_bounded": depth_ok,
        "rejects_stateless": journal_ops == 1 + max_queue,
        "status_under_load_ms": round(status_ms, 3),
        "pass": (counts[REJECTED] == expected_rejects and depth_ok
                 and journal_ops == 1 + max_queue),
    }


def resilience_section(num_jobs: int, seed: int, kills: int) -> Dict:
    """Crash-loop drill + the recovered daemon's resilience counters
    (dedup hits, WAL tail length, recovered op count)."""
    from benchmarks.crash_loop import run_drill
    drill = run_drill(num_jobs, seed, kills)
    return {
        "ops": drill["ops"], "kills": drill["kills"],
        "identical": drill["identical"],
        "resends_clean": drill["crash"]["resends_clean"],
        "counters": drill["crash"]["resilience"],
        "pass": drill["pass"],
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized: 50-job parity, 150-job latency")
    ap.add_argument("--threshold-ms", type=float,
                    default=OVERHEAD_THRESHOLD_MS,
                    help="max p99 service overhead vs in-process")
    ap.add_argument("--out", default="BENCH_service.json")
    args = ap.parse_args(argv)

    parity_jobs = 50 if args.quick else 120
    latency_jobs = 150 if args.quick else 500
    flood = 40 if args.quick else 200
    drill_jobs, drill_kills = (36, 3) if args.quick else (60, 5)

    print(f"# service bench: parity {parity_jobs} jobs x "
          f"{len(PARITY_CONFIGS)} policies, latency {latency_jobs} jobs, "
          f"admission flood {flood}, crash drill {drill_jobs} jobs / "
          f"{drill_kills} kills")

    par = parity_section(parity_jobs, seed=3)
    for row in par["configs"]:
        print(f"  parity {row['label']:16s} identical={row['identical']} "
              f"({row['remote_s']}s remote)")

    lat = latency_section(latency_jobs, seed=11)
    print(f"  latency: remote p50 {lat['remote']['submit_p50_ms']}ms "
          f"p99 {lat['remote']['submit_p99_ms']}ms | in-process p99 "
          f"{lat['local']['submit_p99_ms']}ms | service overhead p99 "
          f"{lat['overhead_p99_ms']}ms ({lat['remote']['rpcs']} RPCs)")

    adm = admission_section(flood)
    print(f"  admission: {adm['counts']} depth_bounded="
          f"{adm['depth_bounded']} stateless={adm['rejects_stateless']}")

    res = resilience_section(drill_jobs, seed=17, kills=drill_kills)
    print(f"  resilience: kills at {res['kills']} identical="
          f"{res['identical']} dedup_hits="
          f"{res['counters']['dedup_hits']} "
          f"wal_tail={res['counters']['wal_tail_ops']}")

    headline = {
        "p99_ms": lat["remote"]["submit_p99_ms"],
        "local_p99_ms": lat["local"]["submit_p99_ms"],
        "overhead_p99_ms": lat["overhead_p99_ms"],
        "threshold_ms": args.threshold_ms,
        "parity": par["identical"],
        "admission": adm["pass"],
        "resilience": res["pass"],
        "pass": (par["identical"] and adm["pass"] and res["pass"]
                 and lat["overhead_p99_ms"] <= args.threshold_ms),
    }
    bench = {"parity": par, "latency": lat, "admission": adm,
             "resilience": res, "headline": headline}
    with open(args.out, "w") as f:
        json.dump(bench, f, indent=1)
    print(f"# headline: p99 {headline['p99_ms']}ms, service overhead "
          f"{headline['overhead_p99_ms']}ms "
          f"(<= {headline['threshold_ms']}ms) parity={headline['parity']} "
          f"admission={headline['admission']} "
          f"resilience={headline['resilience']} pass={headline['pass']}")
    print(f"# wrote {args.out}")


if __name__ == "__main__":
    main()
