"""Roofline analysis (deliverable g) from the dry-run JSON artifacts.

Per (arch x shape x mesh):
  compute    = HLO_FLOPs_per_chip / 197e12         [s]
  memory     = HLO_bytes_per_chip / 819e9          [s]
  collective = collective_bytes_per_chip / 50e9    [s]
(cost_analysis reports per-partition quantities under SPMD; scan-hidden
trip counts are recovered by the unrolled depth probes — see
launch/dryrun.py.)

MODEL_FLOPS = 6*N*D for training (2*N*D forward-only for prefill/decode),
with N = active params for MoE. The ratio MODEL_FLOPS / HLO_FLOPs exposes
remat / redundant compute.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Any, Dict, List, Optional

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link (conservative per-axis)

SUGGESTIONS = {
    "compute": ("compute-bound: raise MXU utilization (bigger per-chip "
                "tiles, fused kernels) or add chips"),
    "memory": ("HBM-bound: cut activation traffic (fusion, remat policy, "
               "bf16 masters) or raise arithmetic intensity with larger "
               "microbatches"),
    "collective": ("collective-bound: reshard to cut cross-chip traffic "
                   "(fewer all-gathers per layer, overlap collectives "
                   "with compute, or shrink the sharded axis)"),
}


def model_param_counts(arch: str):
    """(total_params, active_params) from the real param tree."""
    import jax
    from repro.configs import get_config
    from repro.models import model as lm
    cfg = get_config(arch)
    shapes = jax.eval_shape(lambda: lm.init_model(cfg, jax.random.PRNGKey(0)))
    total = 0
    routed = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
        keys = [str(getattr(p, "key", "")) for p in path]
        if cfg.n_experts and "moe" in keys and any(
                dim == cfg.n_experts for dim in leaf.shape):
            routed += n
    if cfg.n_experts:
        active = total - routed + routed * (cfg.moe_top_k / cfg.n_experts)
    else:
        active = total
    return float(total), float(active)


def tokens_for(shape_name: str) -> float:
    from repro.configs.shapes import SHAPES
    sh = SHAPES[shape_name]
    if sh.kind in ("train", "prefill"):
        return float(sh.global_batch * sh.seq_len)
    return float(sh.global_batch)  # decode: one token per sequence


def roofline_row(res: Dict[str, Any],
                 counts_cache: Dict[str, Any]) -> Dict[str, Any]:
    arch, shape_name = res["arch"], res["shape"]
    chips = res["chips"]
    probes = res.get("probes") or {}
    ex = probes.get("extrapolated") or {
        "flops": res.get("flops") or 0.0,
        "bytes": res.get("bytes_accessed") or 0.0,
        "collective_bytes": res["collectives"]["total_bytes"],
    }
    t_compute = ex["flops"] / PEAK_FLOPS
    t_memory = ex["bytes"] / HBM_BW
    t_coll = ex["collective_bytes"] / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)

    if arch not in counts_cache:
        counts_cache[arch] = model_param_counts(arch)
    total_p, active_p = counts_cache[arch]
    toks = tokens_for(shape_name)
    mult = 6.0 if shape_name.startswith("train") else 2.0
    model_flops_per_chip = mult * active_p * toks / chips
    ratio = model_flops_per_chip / ex["flops"] if ex["flops"] else 0.0

    return {
        "arch": arch, "shape": shape_name, "mesh": res["mesh"],
        "chips": chips,
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops_per_chip": model_flops_per_chip,
        "hlo_flops_per_chip": ex["flops"],
        "useful_ratio": ratio,
        "suggestion": SUGGESTIONS[dominant],
        "compile_s": res.get("compile_s"),
    }


def load_rows(dryrun_dir: str, mesh: Optional[str] = "single"
              ) -> List[Dict[str, Any]]:
    counts: Dict[str, Any] = {}
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            res = json.load(f)
        if mesh and res.get("mesh") != mesh:
            continue
        rows.append(roofline_row(res, counts))
    return rows


def format_table(rows: List[Dict[str, Any]]) -> str:
    hdr = ("arch,shape,mesh,t_compute_ms,t_memory_ms,t_collective_ms,"
           "dominant,useful_ratio")
    lines = [hdr]
    for r in rows:
        lines.append(
            "%s,%s,%s,%.3f,%.3f,%.3f,%s,%.2f" % (
                r["arch"], r["shape"], r["mesh"],
                1e3 * r["t_compute_s"], 1e3 * r["t_memory_s"],
                1e3 * r["t_collective_s"], r["dominant"],
                r["useful_ratio"]))
    return "\n".join(lines)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun-dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--out", default="experiments/roofline.json")
    args = ap.parse_args(argv)
    rows = load_rows(args.dryrun_dir, args.mesh or None)
    print(format_table(rows))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1, default=float)
        print(f"# wrote {args.out} ({len(rows)} rows)")


if __name__ == "__main__":
    main()
