"""Chaos benchmark: degradation and recovery across the scenario matrix.

Runs every (scenario x policy) cell of the chaos layer — the five named
scenarios from :mod:`repro.sim.scenarios` against the five paper
policies at 512 XPUs — and records each cell's degradation/recovery
block (:class:`~repro.sim.faults.ChaosObserver`).

Two asserts ride on top:

* **Determinism.** Every cell is run twice with the same seed; the two
  records must be byte-identical JSON. The whole chaos path — trace,
  fault timeline, eviction/replan order, observer metrics — is seeded
  and deterministic, and the scenario-matrix CI job gates on exactly
  this.

* **Headline.** Under ``node_churn``, RFold's recovered utilization
  (time-weighted tail after the last repair) must be at least the best
  static baseline's (FirstFit, Folding). Folding and reconfiguration
  are how the paper's allocator finds capacity on a degraded fabric;
  this is the recovery claim the chaos layer exists to measure.

  PYTHONPATH=src python -m benchmarks.chaos_bench [--quick] \
      [--scenario node_churn] [--out BENCH_chaos.json]
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List, Optional

from repro.api import SCENARIOS, run_scenario

# The service-bench parity matrix, reused: 512 XPUs per policy.
POLICY_CONFIGS = [
    ("firstfit", "FirstFit (8^3)", "firstfit", dict(dims=(8, 8, 8))),
    ("folding", "Folding (8^3)", "folding", dict(dims=(8, 8, 8))),
    ("reconfig", "Reconfig (4^3)", "reconfig",
     dict(num_xpus=512, cube_n=4)),
    ("rfold", "RFold (4^3)", "rfold", dict(num_xpus=512, cube_n=4)),
    ("rfold_be", "RFold-BE (4^3)", "rfold_be",
     dict(num_xpus=512, cube_n=4)),
]

STATIC_BASELINES = ("firstfit", "folding")
TRACE_KW = dict(cluster_xpus=512, size_max=512)


def run_cell(scenario: str, policy: str, policy_kw: dict,
             num_jobs: int, seed: int) -> Dict:
    """One (scenario, policy) cell, run twice with the same seed; the
    returned record carries the determinism verdict."""
    t0 = time.perf_counter()
    first = run_scenario(scenario, policy=policy, policy_kw=policy_kw,
                         num_jobs=num_jobs, seed=seed,
                         trace_kw=dict(TRACE_KW))
    second = run_scenario(scenario, policy=policy, policy_kw=policy_kw,
                          num_jobs=num_jobs, seed=seed,
                          trace_kw=dict(TRACE_KW))
    identical = (json.dumps(first, sort_keys=True)
                 == json.dumps(second, sort_keys=True))
    first["deterministic"] = identical
    first["cell_s"] = round(time.perf_counter() - t0, 3)
    return first


def run_matrix(scenarios: List[str], num_jobs: int,
               seed: int) -> Dict[str, Dict[str, Dict]]:
    out: Dict[str, Dict[str, Dict]] = {}
    for scenario in scenarios:
        out[scenario] = {}
        for key, label, policy, kw in POLICY_CONFIGS:
            cell = run_cell(scenario, policy, kw, num_jobs, seed)
            cell["label"] = label
            out[scenario][key] = cell
            ch = cell["chaos"]
            print(f"  {scenario:13s} {label:16s} "
                  f"det={cell['deterministic']} "
                  f"jcr={cell['summary']['jcr']:.3f} "
                  f"dip={ch['dip_depth']:.3f} "
                  f"recovered_util={ch['recovered_util']:.3f} "
                  f"pre={ch['preempted']} mig={ch['migrated']} "
                  f"({cell['cell_s']}s)")
    return out


def headline_from(matrix: Dict[str, Dict[str, Dict]],
                  tolerance: float) -> Dict:
    """The recovery claim: under ``node_churn`` RFold (a) sustains at
    least the best static baseline's time-weighted utilization over
    the whole degraded run, and (b) recovers — tail utilization back
    within the observer's tolerance of its pre-fault level. The
    comparison deliberately uses ``util_overall`` rather than the
    post-repair tail: a policy that stalls during degradation piles up
    a backlog whose drain saturates its tail window, so tail
    utilization alone rewards exactly the wrong behaviour. Determinism
    is always asserted, on every cell that ran. ``tolerance`` absorbs
    sub-fault noise (one 8-node fault on 512 XPUs is 1.6 % of
    capacity)."""
    det = all(cell["deterministic"]
              for cells in matrix.values() for cell in cells.values())
    head: Dict = {"deterministic": det, "tolerance": tolerance}
    churn = matrix.get("node_churn")
    if churn is None:
        head.update({"criterion": "determinism only "
                                  "(node_churn not in this run)",
                     "pass": det})
        return head
    rfold = churn["rfold"]["chaos"]["util_overall"]
    recovered = bool(churn["rfold"]["chaos"]["recovered"])
    static_best = max(churn[k]["chaos"]["util_overall"]
                      for k in STATIC_BASELINES)
    head.update({
        "criterion": "rfold util_overall >= max(static) - tolerance "
                     "under node_churn, rfold recovered, all cells "
                     "deterministic",
        "rfold_util": round(rfold, 4),
        "static_best_util": round(static_best, 4),
        "rfold_recovered": recovered,
        "util_ok": rfold >= static_best - tolerance,
        "pass": det and recovered and rfold >= static_best - tolerance,
    })
    return head


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized: 60-job cells")
    ap.add_argument("--scenario", default=None, choices=sorted(SCENARIOS),
                    help="run a single scenario (CI matrix cell); "
                         "default: all five")
    ap.add_argument("--num-jobs", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--tolerance", type=float, default=0.02,
                    help="absolute recovered-util slack for the "
                         "node_churn headline")
    ap.add_argument("--out", default="BENCH_chaos.json")
    args = ap.parse_args(argv)

    num_jobs = args.num_jobs or (60 if args.quick else 120)
    scenarios = [args.scenario] if args.scenario else sorted(SCENARIOS)
    print(f"# chaos bench: {len(scenarios)} scenario(s) x "
          f"{len(POLICY_CONFIGS)} policies, {num_jobs} jobs/cell, "
          f"every cell run twice (determinism)")

    t0 = time.time()
    matrix = run_matrix(scenarios, num_jobs, args.seed)
    head = headline_from(matrix, args.tolerance)

    bench = {"num_jobs": num_jobs, "seed": args.seed,
             "scenarios": matrix, "headline": head,
             "wall_s": round(time.time() - t0, 1)}
    with open(args.out, "w") as f:
        json.dump(bench, f, indent=1)
    print(f"# headline: deterministic={head['deterministic']}", end="")
    if "rfold_util" in head:
        print(f", rfold util {head['rfold_util']} vs static best "
              f"{head['static_best_util']} "
              f"(recovered={head['rfold_recovered']})", end="")
    print(f" -> pass={head['pass']}")
    print(f"# wrote {args.out} ({bench['wall_s']}s)")


if __name__ == "__main__":
    main()
