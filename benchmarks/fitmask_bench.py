"""Fitmask engine benchmark: single-box vs multi-box kernel.

The claim under test is the multi-box design itself: one VMEM
integral-image pass answering all K candidate fold boxes must beat K
independent single-box ``pallas_call``s (each rebuilding the 3-axis
cumsum). Sweeps batch x K x grid size over the Pallas kernel in
interpret mode (the only mode CI can run) and the jitted CPU-jax and
numpy engines for scale, and emits ``BENCH_fitmask.json``.

  PYTHONPATH=src python -m benchmarks.fitmask_bench [--out BENCH_fitmask.json]
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Callable, List, Tuple

import numpy as np

# Candidate pool in fold-enumeration spirit: the flat/compact box shapes
# RFold actually queries. Truncated to K and filtered per grid.
CANDIDATE_BOXES: List[Tuple[int, int, int]] = [
    (4, 4, 4), (8, 4, 2), (2, 2, 2), (16, 2, 2), (8, 8, 1), (4, 2, 1),
    (16, 4, 1), (2, 4, 8), (8, 2, 4), (1, 1, 1), (16, 16, 1), (4, 8, 2),
    (3, 3, 3), (6, 2, 2), (12, 2, 1), (2, 8, 4), (5, 2, 2), (2, 6, 2),
    (4, 4, 1), (7, 1, 1), (1, 8, 2), (2, 2, 5), (6, 4, 1), (3, 2, 4),
]


def boxes_for(grid: Tuple[int, int, int], k: int):
    out = [b for b in CANDIDATE_BOXES
           if all(e <= d for e, d in zip(b, grid))]
    assert len(out) >= k, (grid, k)
    return tuple(out[:k])


def _time_ms(fn: Callable, iters: int = 3, warmup: int = 1) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters * 1e3


def run_sweep(grids, batches, ks, iters: int = 3):
    import jax
    import jax.numpy as jnp

    from repro.core import fitmask as np_engine
    from repro.kernels.fitmask import kernel as _kernel
    from repro.kernels.fitmask import ops as _ops

    rng = np.random.default_rng(0)
    jax_engine = _ops.get_engine("jax")
    rows = []
    for grid in grids:
        for bsz in batches:
            occ_np = rng.uniform(size=(bsz,) + grid) < 0.3
            occ = jnp.asarray(occ_np)
            for k in ks:
                boxes = boxes_for(grid, k)
                multi = _time_ms(lambda: jax.block_until_ready(
                    _kernel.fitmask_multibox(occ, boxes, interpret=True)),
                    iters=iters)
                single = _time_ms(lambda: jax.block_until_ready(
                    _kernel.fitmask_multibox_singlepass_baseline(
                        occ, boxes, interpret=True)), iters=iters)
                jax_ms = _time_ms(lambda: jax.block_until_ready(
                    jax_engine.multibox(occ, boxes)), iters=iters)
                numpy_ms = _time_ms(
                    lambda: np_engine.fit_mask_multi(occ_np, boxes),
                    iters=iters)
                rows.append({
                    "grid": "x".join(map(str, grid)), "batch": bsz, "k": k,
                    "pallas_multibox_ms": round(multi, 3),
                    "pallas_singlebox_x_k_ms": round(single, 3),
                    "jax_ms": round(jax_ms, 3),
                    "numpy_ms": round(numpy_ms, 3),
                    "multibox_speedup": round(single / multi, 2)
                    if multi > 0 else None,
                })
                print(f"fitmask {rows[-1]['grid']} B={bsz} K={k}: "
                      f"multi {multi:.1f}ms single x K {single:.1f}ms "
                      f"({rows[-1]['multibox_speedup']}x) "
                      f"jax {jax_ms:.2f}ms numpy {numpy_ms:.2f}ms")
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", type=str, default="BENCH_fitmask.json")
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--quick", action="store_true",
                    help="headline cell only (16^3, B=8, K=4)")
    args = ap.parse_args(argv)

    if args.quick:
        grids, batches, ks = [(16, 16, 16)], [8], [4]
    else:
        grids = [(8, 8, 8), (16, 16, 16)]
        batches = [1, 8, 64]
        ks = [1, 4, 8, 16]
    rows = run_sweep(grids, batches, ks, iters=args.iters)

    # Headline: the acceptance cell — K>=4 on a 16^3 grid must favor
    # the multi-box kernel over K independent single-box calls.
    head = [r for r in rows if r["grid"] == "16x16x16" and r["k"] >= 4]
    headline = {
        "criterion": "multibox beats K single-box pallas_calls "
                     "(K>=4, 16^3, interpret)",
        "min_speedup": min(r["multibox_speedup"] for r in head),
        "max_speedup": max(r["multibox_speedup"] for r in head),
        "pass": all(r["multibox_speedup"] > 1.0 for r in head),
    } if head else {}
    out = {"sweep": rows, "headline": headline,
           "note": "interpret-mode wall clock (CI has no TPU); "
                   "jax/numpy engines jitted/host for scale"}
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    if headline:
        print(f"# headline: multibox {headline['min_speedup']}x-"
              f"{headline['max_speedup']}x vs single-box "
              f"(pass={headline['pass']})")
    print(f"# wrote {args.out}")


if __name__ == "__main__":
    main()
