"""Beyond-paper scheduler extensions (paper §5 future directions):
aggressive backfill and best-effort scatter placement, compared against
the faithful RFold baseline on identical traces.

  PYTHONPATH=src python -m benchmarks.beyond --runs 5 --num-jobs 300
"""
from __future__ import annotations

import argparse
import json

from repro.core.allocator import make_policy
from repro.sim.metrics import aggregate, summarize
from repro.sim.simulator import Simulator
from repro.traces.generator import TraceConfig, generate_trace

VARIANTS = [
    ("rfold (paper FIFO)", "rfold", {}, dict(backfill=False)),
    ("rfold + backfill", "rfold", {}, dict(backfill=True)),
    ("rfold + best-effort", "rfold_be", {}, dict(backfill=False)),
    ("rfold + both", "rfold_be", {}, dict(backfill=True)),
]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--runs", type=int, default=3)
    ap.add_argument("--num-jobs", type=int, default=200)
    ap.add_argument("--load", type=float, default=1.5)
    ap.add_argument("--out", default="")
    args = ap.parse_args(argv)
    print("variant,jcr,jct_p50,jct_p90,jct_p99,util_mean")
    results = {}
    for label, name, pkw, skw in VARIANTS:
        sums = []
        for r in range(args.runs):
            cfg = TraceConfig(num_jobs=args.num_jobs, seed=500 + r,
                              target_load=args.load)
            pol = make_policy(name, num_xpus=4096, cube_n=4, **pkw)
            res = Simulator(pol, generate_trace(cfg), **skw).run()
            sums.append(summarize(res))
        agg = aggregate(sums)
        results[label] = agg
        print("%s,%.3f,%.0f,%.0f,%.0f,%.3f" % (
            label, agg["jcr"], agg["jct_p50"], agg["jct_p90"],
            agg["jct_p99"], agg["util_mean"]))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
