"""AdamW + schedules + global-norm clipping, pure JAX pytrees."""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimConfig:
    lr: float = 3e-4
    betas: Tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_at(cfg: OptimConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) \
        * 0.5 * (1 + jnp.cos(math.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params: Any) -> Any:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return {"mu": zeros,
            "nu": jax.tree_util.tree_map(jnp.copy, zeros),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2)
                        for l in leaves))


def adamw_update(cfg: OptimConfig, params: Any, grads: Any,
                 state: Any) -> Tuple[Any, Any, dict]:
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    b1, b2 = cfg.betas
    lr = lr_at(cfg, step)
    c1 = 1 - b1 ** step.astype(jnp.float32)
    c2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        mu_hat = mu / c1
        nu_hat = nu / c2
        delta = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    out = jax.tree_util.tree_map(upd, params, grads, state["mu"],
                                 state["nu"])
    new_params = jax.tree_util.tree_map(lambda t: t[0], out,
                                        is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree_util.tree_map(lambda t: t[1], out,
                                    is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree_util.tree_map(lambda t: t[2], out,
                                    is_leaf=lambda t: isinstance(t, tuple))
    new_state = {"mu": new_mu, "nu": new_nu, "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
