"""Loss + train step (forward, backward, AdamW), grad-accum option."""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import model as lm
from repro.models.common import ModelConfig
from .optim import OptimConfig, adamw_update

AUX_WEIGHT = 0.01  # MoE load-balance loss weight


def cross_entropy(logits: jnp.ndarray, targets: jnp.ndarray) -> jnp.ndarray:
    """logits: (..., V); targets: int (...). Mean NLL in fp32."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None],
                               axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def loss_fn(cfg: ModelConfig, params: Any, batch: Dict,
            use_kernel: bool = False) -> Tuple[jnp.ndarray, Dict]:
    logits, aux = lm.forward(cfg, params, batch, use_kernel=use_kernel)
    targets = batch["targets"]
    if cfg.arch_type == "audio":
        # logits (B,S,K,V); targets (B,K,S)
        targets = jnp.moveaxis(targets, 1, 2)
    ce = cross_entropy(logits, targets)
    total = ce + AUX_WEIGHT * aux
    return total, {"loss": total, "ce": ce, "aux": aux}


def train_step(cfg: ModelConfig, opt_cfg: OptimConfig, params: Any,
               opt_state: Any, batch: Dict, use_kernel: bool = False
               ) -> Tuple[Any, Any, Dict]:
    grad_fn = jax.value_and_grad(
        lambda p: loss_fn(cfg, p, batch, use_kernel), has_aux=True)
    (_, metrics), grads = grad_fn(params)
    new_params, new_opt, opt_metrics = adamw_update(
        opt_cfg, params, grads, opt_state)
    metrics = dict(metrics)
    metrics.update(opt_metrics)
    return new_params, new_opt, metrics


def train_step_accum(cfg: ModelConfig, opt_cfg: OptimConfig, params: Any,
                     opt_state: Any, batch: Dict, n_micro: int
                     ) -> Tuple[Any, Any, Dict]:
    """Gradient accumulation over ``n_micro`` microbatches (batch dim
    split); reduces peak activation memory at the cost of re-running the
    forward pass per microbatch."""
    def micro(i):
        return jax.tree_util.tree_map(
            lambda t: t.reshape((n_micro, -1) + t.shape[1:])[i], batch)

    grad_fn = jax.value_and_grad(
        lambda p, mb: loss_fn(cfg, p, mb), has_aux=True)

    def body(carry, i):
        gsum, msum = carry
        (_, metrics), g = grad_fn(params, micro(i))
        gsum = jax.tree_util.tree_map(jnp.add, gsum, g)
        return (gsum, msum + metrics["ce"]), None

    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (gsum, ce_sum), _ = jax.lax.scan(body, (zeros, jnp.zeros((), jnp.float32)),
                                     jnp.arange(n_micro))
    grads = jax.tree_util.tree_map(lambda g: g / n_micro, gsum)
    new_params, new_opt, opt_metrics = adamw_update(
        opt_cfg, params, grads, opt_state)
    metrics = {"ce": ce_sum / n_micro}
    metrics.update(opt_metrics)
    return new_params, new_opt, metrics
