"""Pytree checkpointing without orbax: one .npz per save, with
path-encoded keys; restores exact structure onto the target pytree."""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = np.asarray(leaf)
    return out, treedef


def save_checkpoint(path: str, params: Any, opt_state: Optional[Any] = None,
                    step: int = 0, meta: Optional[Dict] = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    base = path[:-4] if path.endswith(".npz") else path
    arrays, _ = _flatten({"params": params, "opt": opt_state or {}})
    np.savez(base + ".npz", **arrays)
    with open(base + ".meta.json", "w") as f:
        json.dump({"step": step, **(meta or {})}, f)


def load_checkpoint(path: str, like_params: Any,
                    like_opt: Optional[Any] = None):
    """Restore into the structure of ``like_*`` (shapes must match)."""
    data = np.load(path if path.endswith(".npz") else path + ".npz")
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        {"params": like_params, "opt": like_opt or {}})
    leaves = []
    for p, leaf in flat:
        key = "/".join(str(getattr(q, "key", getattr(q, "idx", q)))
                       for q in p)
        arr = data[key]
        assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
        leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
    restored = jax.tree_util.tree_unflatten(treedef, leaves)
    meta = {}
    mp = (path[:-4] if path.endswith(".npz") else path) + ".meta.json"
    if os.path.exists(mp):
        with open(mp) as f:
            meta = json.load(f)
    return restored["params"], restored["opt"], meta
