"""Synthetic LM data pipeline: deterministic, shardable, host-fed.

Generates Zipf-distributed token streams (more realistic softmax stats
than uniform) with next-token targets; ``shard_batch`` places host
arrays onto the mesh with the batch-axis NamedSharding.
"""
from __future__ import annotations

from typing import Any, Dict, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import ModelConfig


def _zipf_tokens(rng: np.random.Generator, shape, vocab: int) -> np.ndarray:
    # Smooth Zipf via inverse-CDF on ranks (a ~ 1.1), capped at vocab.
    u = rng.uniform(size=shape)
    ranks = np.exp(u * np.log(vocab)) - 1.0
    return np.minimum(ranks.astype(np.int64), vocab - 1).astype(np.int32)


def synthetic_batches(cfg: ModelConfig, batch: int, seq: int,
                      seed: int = 0) -> Iterator[Dict[str, Any]]:
    """Infinite iterator of {tokens, targets} host batches."""
    rng = np.random.default_rng(seed)
    while True:
        if cfg.arch_type == "audio":
            shape = (batch, cfg.n_codebooks, seq + 1)
        else:
            shape = (batch, seq + 1)
        stream = _zipf_tokens(rng, shape, cfg.vocab_size)
        yield {"tokens": jnp.array(stream[..., :-1]),
               "targets": jnp.array(stream[..., 1:])}


def shard_batch(batch: Dict[str, Any], mesh, batch_axes=("pod", "data")):
    """Place host batch on the mesh, batch dim sharded over data axes."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    axes = tuple(a for a in batch_axes if a in mesh.axis_names)

    def put(x):
        spec = P(axes) if x.ndim >= 1 else P()
        return jax.device_put(x, NamedSharding(mesh, spec))

    return {k: put(v) for k, v in batch.items()}
