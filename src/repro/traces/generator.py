"""Philly-style synthetic trace generation (paper §4).

None of the public ML traces were collected on a torus cluster, so the
paper takes inter-arrival and duration statistics from the Microsoft
Philly trace and overrides the job size with a truncated exponential on
[1, 4096], then generates shapes with the rule of thumb:

  * small jobs (<= 256 XPUs) are mostly 1D or 2D (DP and/or TP),
  * large jobs (> 256) are mostly 2D or 3D,
  * among the factorizations of a size into the chosen class, one is
    picked uniformly at random.

The offline container has no Philly CSV, so inter-arrival is Poisson and
duration lognormal with parameters matching published Philly statistics
(median ~13 min, heavy tail up to days); both are overridable.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Optional

import numpy as np

from repro.core.geometry import JobShape, factor_pairs, factorizations3
from repro.sim.job import Job


@dataclass
class TraceConfig:
    num_jobs: int = 300
    seed: int = 0
    # size ~ TruncExp(scale) on [1, 4096]  (paper's override)
    size_scale: float = 256.0
    size_max: int = 4096
    # arrivals ~ Poisson; rate chosen for a target offered load unless
    # mean_interarrival is given explicitly.
    mean_interarrival: Optional[float] = None
    target_load: float = 1.2          # offered load vs 4096 XPUs
    cluster_xpus: int = 4096
    # duration ~ lognormal (Philly-like): median 13 min, sigma 1.4
    duration_median_s: float = 780.0
    duration_sigma: float = 1.4
    # Size-duration correlation (trace-calibration step 3's open
    # question: big jobs run longer in the real Philly trace, the
    # independent samplers ignore it). Sampled through a Gaussian
    # copula, so both marginals are exactly preserved and ``corr = 0``
    # keeps the legacy independent draws byte-identical.
    size_duration_corr: float = 0.0
    # Bursty arrivals: 0 keeps pure Poisson (legacy, byte-identical);
    # > 0 draws inter-arrivals from a two-phase hyperexponential with
    # the same mean (offered load unchanged) but CV > 1 — arrivals
    # clump, stressing queue depth and recovery.
    arrival_burstiness: float = 0.0
    # Multi-tenant priorities: > 1 assigns each job a uniform priority
    # in [0, levels); 1 keeps every job at priority 0 (legacy).
    priority_levels: int = 1
    small_threshold: int = 256
    p_1d_small: float = 0.5           # small: 1D vs 2D
    p_2d_large: float = 0.5           # large: 2D or 3D
    # Calibration knobs (see EXPERIMENTS.md §Paper-val):
    round_even: bool = True           # DP/TP degrees are even in practice
    # The paper reports Reconfig(4^3) JCR = 100%, which implies every
    # generated shape decomposes into at most 64 4^3 cubes; we enforce
    # the same feasibility envelope on the sampled factorization.
    cube4_decomposable: bool = True
    cube4_n: int = 4
    cube4_budget: int = 64

    @classmethod
    def preset(cls, name: str, **overrides) -> "TraceConfig":
        """A named calibration preset with optional field overrides:
        ``TraceConfig.preset("philly", num_jobs=500)``."""
        if name not in TRACE_PRESETS:
            raise KeyError(f"unknown trace preset {name!r}; "
                           f"have {sorted(TRACE_PRESETS)}")
        fields = dict(TRACE_PRESETS[name])
        fields.update(overrides)
        return cls(**fields)


# Named TraceConfig presets (field overrides on top of the defaults).
#
# ``philly`` is the trace-calibration first step (ROADMAP item): the
# paper samples inter-arrival and duration statistics from the
# Microsoft Philly trace (Jeon et al., ATC '19). Our default keeps the
# published ~13-minute median but its lognormal tail (sigma 1.4, so
# mean/median = exp(sigma^2/2) ~ 2.7) is far lighter than Philly's —
# the reported mean runtime is hours against the 13-minute median,
# i.e. mean/median ~ 10, which a lognormal matches at sigma =
# sqrt(2 ln 10) ~ 2.15. Philly's GPU-count distribution also puts most
# of its mass on single-machine (<= 8 GPU) jobs, which the default
# 256-XPU-mean truncated exponential underweights; scale 96 moves the
# small-job mass toward the Philly shares while keeping the paper's
# [1, 4096] support. The measured Table 1 / Fig 4 gaps this preset
# targets are recorded in EXPERIMENTS.md §Paper-scale.
TRACE_PRESETS = {
    "philly": {
        "duration_sigma": 2.15,       # mean/median ~ 10 (Philly-like tail)
        "size_scale": 96.0,           # small-job mass per Philly GPU counts
    },
}


def _trunc_exp_icdf(u: np.ndarray, scale: float, hi: int) -> np.ndarray:
    """Inverse CDF of Exp(scale) truncated to [1, hi] at quantiles
    ``u`` (the shared kernel of the independent and copula samplers)."""
    fmax = 1.0 - math.exp(-hi / scale)
    x = -scale * np.log(1.0 - u * fmax)
    return np.clip(np.ceil(x), 1, hi).astype(np.int64)


def _truncated_exp_sizes(rng: np.random.Generator, n: int, scale: float,
                         hi: int) -> np.ndarray:
    """Inverse-CDF sampling of Exp(scale) truncated to [1, hi]."""
    return _trunc_exp_icdf(rng.uniform(size=n), scale, hi)


def _std_normal_cdf(z: np.ndarray) -> np.ndarray:
    """Φ(z) via math.erf (no scipy in the container)."""
    return np.array([0.5 * (1.0 + math.erf(v / math.sqrt(2.0)))
                     for v in np.asarray(z, dtype=np.float64)])


def _correlated_size_duration(rng: np.random.Generator, cfg: "TraceConfig",
                              mu: float):
    """Gaussian-copula joint draw: sizes keep the truncated-exponential
    marginal (via Φ(z₁) pushed through the inverse CDF), durations keep
    the lognormal marginal (exp(μ + σ·z₂)), and corr(z₁, z₂) = ρ sets
    the rank correlation — the Philly-like "big jobs run longer"."""
    rho = float(np.clip(cfg.size_duration_corr, -0.999, 0.999))
    z = rng.standard_normal(size=(cfg.num_jobs, 2))
    z1 = z[:, 0]
    z2 = rho * z1 + math.sqrt(1.0 - rho * rho) * z[:, 1]
    sizes = _trunc_exp_icdf(_std_normal_cdf(z1), cfg.size_scale,
                            cfg.size_max)
    durations = np.exp(mu + cfg.duration_sigma * z2)
    return sizes, durations


def _cube_grid_size(dims, n: int) -> int:
    out = 1
    for d in dims:
        out *= -(-int(d) // n)
    return out


def sample_shape(rng: np.random.Generator, size: int,
                 cfg: TraceConfig) -> JobShape:
    """Paper's shape rule. Dimension sizes are deliberately allowed to
    exceed the static torus extent (that is the point: some shapes are
    incompatible with some clusters), but — matching the paper's
    Reconfig(4^3) JCR of exactly 100 % — every emitted shape decomposes
    into at most 64 4^3 cubes."""
    size = int(size)

    def feasible(dims) -> bool:
        if not cfg.cube4_decomposable:
            return True
        return _cube_grid_size(dims, cfg.cube4_n) <= cfg.cube4_budget

    for _ in range(64):  # resample/bump until a feasible shape exists
        small = size <= cfg.small_threshold
        if small:
            want = "1d" if rng.uniform() < cfg.p_1d_small else "2d"
        else:
            want = "2d" if rng.uniform() < cfg.p_2d_large else "3d"
        if want == "3d":
            triples = [t for t in factorizations3(size)
                       if min(t) > 1 and feasible(t)]
            if triples:
                a, b, c = triples[rng.integers(len(triples))]
                return JobShape((int(a), int(b), int(c)))
            want = "2d"
        if want == "2d":
            pairs = [p for p in factor_pairs(size)
                     if min(p) > 1 and feasible((p[0], p[1], 1))]
            if pairs:
                a, b = pairs[rng.integers(len(pairs))]
                return JobShape((int(a), int(b), 1))
            want = "1d"
        if feasible((size, 1, 1)):
            return JobShape((size, 1, 1))
        size += 2 if cfg.round_even else 1  # bump to a factorable size
    raise RuntimeError(f"no feasible shape for size {size}")


def generate_trace(cfg: TraceConfig) -> List[Job]:
    rng = np.random.default_rng(cfg.seed)
    mu = math.log(cfg.duration_median_s)
    # Every non-default knob below branches so the default draw
    # sequence — and therefore every legacy trace — stays
    # byte-identical (asserted in tests/test_trace_calibration.py).
    if cfg.size_duration_corr != 0.0:
        sizes, durations = _correlated_size_duration(rng, cfg, mu)
    else:
        sizes = _truncated_exp_sizes(rng, cfg.num_jobs, cfg.size_scale,
                                     cfg.size_max)
        durations = None
    if cfg.round_even:
        sizes = np.where(sizes > 1, (sizes + 1) // 2 * 2, sizes)
    if durations is None:
        durations = rng.lognormal(mean=mu, sigma=cfg.duration_sigma,
                                  size=cfg.num_jobs)
    if cfg.mean_interarrival is not None:
        mean_ia = cfg.mean_interarrival
    else:
        # offered load = rate * E[size * duration] / cluster_xpus
        demand = float(np.mean(sizes * durations))
        mean_ia = demand / (cfg.target_load * cfg.cluster_xpus)
    if cfg.arrival_burstiness > 0.0:
        # Two-phase hyperexponential, mean preserved exactly:
        # 0.75·(1-b) + 0.25·(1+3b) = 1.
        b = float(min(cfg.arrival_burstiness, 0.95))
        fast = rng.uniform(size=cfg.num_jobs) < 0.75
        phase_mean = np.where(fast, (1.0 - b) * mean_ia,
                              (1.0 + 3.0 * b) * mean_ia)
        gaps = rng.exponential(1.0, size=cfg.num_jobs) * phase_mean
    else:
        gaps = rng.exponential(mean_ia, size=cfg.num_jobs)
    arrivals = np.cumsum(gaps)
    if cfg.priority_levels > 1:
        priorities = rng.integers(cfg.priority_levels,
                                  size=cfg.num_jobs)
    else:
        priorities = np.zeros(cfg.num_jobs, dtype=np.int64)
    jobs = []
    for i in range(cfg.num_jobs):
        shape = sample_shape(rng, int(sizes[i]), cfg)
        jobs.append(Job(job_id=i, arrival=float(arrivals[i]),
                        duration=float(durations[i]), shape=shape,
                        priority=int(priorities[i])))
    return jobs


def generate_traces(cfg: TraceConfig, runs: int) -> List[List[Job]]:
    out = []
    for r in range(runs):
        c = TraceConfig(**{**cfg.__dict__, "seed": cfg.seed + r})
        out.append(generate_trace(c))
    return out
