"""The public API of the RFold reproduction.

One import surface for everything downstream code needs — examples,
``benchmarks/``, notebooks — so callers stop reaching into
``repro.core``/``repro.sim`` internals:

    from repro import api

    with api.Scheduler(policy="rfold") as sched:     # live service
        r = sched.submit((4, 4, 4))
        for ev in sched.events(max_wait=0.1):
            ...

    jobs = api.generate_trace(api.TraceConfig(num_jobs=100))
    result = api.Simulator(api.make_policy("rfold"), jobs).run()

Module-level :func:`submit` / :func:`events` operate on a default
process-wide scheduler (started on first use, configurable via
:func:`start_scheduler`) for scripts that just want a live allocator
without managing lifecycles.

Everything re-exported here is covered by the parity and round-trip
tests; internals not listed in ``__all__`` may move without notice.
"""
from __future__ import annotations

import atexit
import threading
from typing import Any, Dict, List, Optional

# Engine selection (the one resolution point for fitmask engines) and
# the runtime failover chain the fleet broker degrades down.
from repro.core.engineconfig import (FAILOVER_CHAIN, EngineConfig,
                                     default_engine_name,
                                     failover_candidates,
                                     set_default_engine)
# Placement policies + geometry.
from repro.core.allocator import POLICIES, Placement, PlacementPolicy, make_policy
from repro.core.events import EventLog, TopologyEvent
from repro.core.geometry import JobShape
# Discrete-event simulation + traces + metrics.
from repro.sim.job import Job
from repro.sim.metrics import summarize, utilization_cdf
from repro.sim.simulator import SimResult, Simulator
from repro.traces.generator import TraceConfig, generate_trace, generate_traces
# Chaos layer: fault injection, degraded-fabric scenarios.
from repro.sim.faults import (ChaosObserver, FaultConfig, FaultEvent,
                              FaultGenerator, FaultInjector)
from repro.sim.scenarios import (SCENARIOS, Scenario, fault_schedule,
                                 run_scenario)
# Paper-scale evaluation.
from repro.eval import (PAPER_FIG3_RATIOS, PAPER_FIG4_DELTAS, PAPER_TABLE1,
                        EvalRunner, EvalTask, aggregate_by_label, fig3, fig4,
                        make_tasks, table1)
# The allocator service (+ replication/fencing constants, PR 10).
from repro.serve.scheduler import (NOT_LEADER, ROLE_PRIMARY, ROLE_STANDBY,
                                   RemotePolicy, Scheduler, SchedulerClient,
                                   SchedulerConfig)

__all__ = [
    # service
    "Scheduler", "SchedulerConfig", "SchedulerClient", "RemotePolicy",
    "submit", "events", "start_scheduler", "stop_scheduler",
    "NOT_LEADER", "ROLE_PRIMARY", "ROLE_STANDBY",
    # engine selection + runtime failover
    "EngineConfig", "set_default_engine", "default_engine_name",
    "FAILOVER_CHAIN", "failover_candidates",
    # placement
    "POLICIES", "make_policy", "PlacementPolicy", "Placement", "JobShape",
    "TopologyEvent", "EventLog",
    # simulation
    "Simulator", "SimResult", "Job", "summarize", "utilization_cdf",
    "TraceConfig", "generate_trace", "generate_traces",
    # chaos layer
    "FaultConfig", "FaultEvent", "FaultGenerator", "FaultInjector",
    "ChaosObserver", "Scenario", "SCENARIOS", "run_scenario",
    "fault_schedule",
    # evaluation
    "EvalRunner", "EvalTask", "make_tasks", "aggregate_by_label",
    "table1", "fig3", "fig4",
    "PAPER_TABLE1", "PAPER_FIG3_RATIOS", "PAPER_FIG4_DELTAS",
]

# -- default process-wide scheduler ------------------------------------

_default_lock = threading.Lock()
_default_scheduler: Optional[Scheduler] = None


def start_scheduler(config: Optional[SchedulerConfig] = None,
                    **config_kw) -> Scheduler:
    """Start (or return) the process-wide default scheduler used by
    module-level :func:`submit`/:func:`events`. Explicit config is only
    honoured on first start — stop the old one to reconfigure."""
    global _default_scheduler
    with _default_lock:
        if _default_scheduler is None:
            _default_scheduler = Scheduler(config, **config_kw).start()
            atexit.register(stop_scheduler)
        elif config is not None or config_kw:
            raise RuntimeError(
                "default scheduler already running; stop_scheduler() "
                "before starting one with a different config")
        return _default_scheduler


def stop_scheduler() -> None:
    """Gracefully stop the default scheduler (idempotent)."""
    global _default_scheduler
    with _default_lock:
        if _default_scheduler is not None:
            _default_scheduler.stop()
            _default_scheduler = None


def submit(shape, job_id: Optional[int] = None) -> Dict[str, Any]:
    """Submit a job shape to the default scheduler (started on first
    use with default config: RFold on the paper's 4096-XPU cluster)."""
    return start_scheduler().submit(shape, job_id=job_id)


def events(max_wait: float = 0.0) -> List[Dict[str, Any]]:
    """Drain pushed SETUP/RECONFIG/RELEASE events from the default
    scheduler."""
    return start_scheduler().events(max_wait=max_wait)
