"""phi4-mini-3.8b [dense] — RoPE, SwiGLU, GQA, 200k vocab.
[arXiv:2412.08905 — Phi-4 Technical Report / phi-4-mini model card]"""
from repro.models.common import ModelConfig
from .base import register

CONFIG = register(ModelConfig(
    name="phi4-mini-3.8b",
    arch_type="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8,
    d_ff=8192, vocab_size=200_064, head_dim=128,
    norm_type="rmsnorm", act="swiglu", pos_type="rope",
    rope_theta=10_000.0,
    sliding_window=8192,          # long_500k decode variant only
    long_context_mode="window",
    source="arXiv:2412.08905",
))
