"""qwen1.5-110b [dense] — QKV bias (per Qwen1.5 family design).
[hf:Qwen/Qwen1.5-0.5B model card family]"""
from repro.models.common import ModelConfig
from .base import register

CONFIG = register(ModelConfig(
    name="qwen1.5-110b",
    arch_type="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=49152, vocab_size=152_064, head_dim=128,
    norm_type="rmsnorm", act="swiglu", pos_type="rope",
    rope_theta=1_000_000.0, qkv_bias=True,
    sliding_window=8192,
    long_context_mode="window",
    source="hf:Qwen/Qwen1.5-0.5B",
))
