"""qwen2-vl-7b [vlm] — M-RoPE (t/h/w sections), dynamic resolution.
The ViT vision encoder + projector is a STUB: input_specs() provides
precomputed patch embeddings spliced over image-placeholder tokens.
[arXiv:2409.12191 — Qwen2-VL]"""
from repro.models.common import ModelConfig
from .base import register

CONFIG = register(ModelConfig(
    name="qwen2-vl-7b",
    arch_type="vlm",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
    d_ff=18944, vocab_size=152_064, head_dim=128,
    norm_type="rmsnorm", act="swiglu", pos_type="mrope",
    rope_theta=1_000_000.0, mrope_sections=(16, 24, 24),
    qkv_bias=True, vision_stub=True,
    sliding_window=8192,
    long_context_mode="window",
    source="arXiv:2409.12191",
))
