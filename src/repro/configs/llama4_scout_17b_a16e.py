"""llama4-scout-17b-a16e [moe] — 16 experts, top-1 routing + shared
expert, early fusion. [hf:meta-llama/Llama-4-Scout-17B-16E]"""
from repro.models.common import ModelConfig
from .base import register

CONFIG = register(ModelConfig(
    name="llama4-scout-17b-a16e",
    arch_type="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=8192,                      # (dense-equivalent hidden per expert)
    vocab_size=202_048, head_dim=128,
    norm_type="rmsnorm", act="swiglu", pos_type="rope",
    rope_theta=500_000.0,
    n_experts=16, n_shared_experts=1, moe_top_k=1, moe_d_ff=8192,
    capacity_factor=1.5, router_type="sigmoid",
    moe_local_dispatch=True,   # gather-only per-row dispatch (§Perf)
    sliding_window=8192,            # chunked-attention-like long mode
    long_context_mode="window",
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
))
