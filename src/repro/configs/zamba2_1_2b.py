"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention block.
[arXiv:2411.15242 — Zamba2 suite]"""
from repro.models.common import ModelConfig
from .base import register

CONFIG = register(ModelConfig(
    name="zamba2-1.2b",
    arch_type="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=32_000, head_dim=64,
    norm_type="rmsnorm", act="swiglu", pos_type="rope",
    ssm_state=64, ssm_conv=4, ssm_expand=2, ssm_head_dim=64,
    ssm_chunk=128,
    shared_attn_every=6,           # shared (tied) attn block cadence
    sliding_window=8192,           # attention part in long context
    long_context_mode="recurrent", # SSM state is O(1); attn windowed
    source="arXiv:2411.15242",
))
