"""olmo-1b [dense] — non-parametric LayerNorm, SwiGLU.
[arXiv:2402.00838 — OLMo: Accelerating the Science of LMs]"""
from repro.models.common import ModelConfig
from .base import register

CONFIG = register(ModelConfig(
    name="olmo-1b",
    arch_type="dense",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab_size=50_304, head_dim=128,
    norm_type="nonparametric_ln", act="swiglu", pos_type="rope",
    rope_theta=10_000.0,
    sliding_window=8192,
    long_context_mode="window",
    source="arXiv:2402.00838",
))
