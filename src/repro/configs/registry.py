"""Importing this module registers every assigned architecture."""
from . import (deepseek_v2_236b, llama3_8b, llama4_scout_17b_a16e,  # noqa
               musicgen_medium, olmo_1b, phi4_mini_3_8b, qwen1_5_110b,
               qwen2_vl_7b, xlstm_1_3b, zamba2_1_2b)

ARCH_IDS = [
    "phi4-mini-3.8b", "llama3-8b", "deepseek-v2-236b", "qwen1.5-110b",
    "zamba2-1.2b", "llama4-scout-17b-a16e", "olmo-1b", "musicgen-medium",
    "xlstm-1.3b", "qwen2-vl-7b",
]
