"""deepseek-v2-236b [moe] — MLA (kv_lora=512), 2 shared + 160 routed
top-6 experts, first layer dense. [arXiv:2405.04434 — DeepSeek-V2]"""
from repro.models.common import ModelConfig
from .base import register

CONFIG = register(ModelConfig(
    name="deepseek-v2-236b",
    arch_type="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
    d_ff=12288,                    # dense FFN of the first layer
    vocab_size=102_400,
    head_dim=192,                  # qk_nope (128) + qk_rope (64)
    norm_type="rmsnorm", act="swiglu", pos_type="rope",
    rope_theta=10_000.0,
    use_mla=True, q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
    n_experts=160, n_shared_experts=2, moe_top_k=6, moe_d_ff=1536,
    first_k_dense=1, capacity_factor=1.25, router_type="softmax",
    sliding_window=8192,
    long_context_mode="window",
    source="arXiv:2405.04434",
))
