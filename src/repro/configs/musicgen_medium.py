"""musicgen-medium [audio] — decoder-only transformer over EnCodec
tokens (4 codebooks, 2048 entries each), sinusoidal positions, GELU.
The EnCodec conv codec frontend is a STUB: input_specs() provides
precomputed frame embeddings / token streams.
[arXiv:2306.05284 — Simple and Controllable Music Generation]"""
from repro.models.common import ModelConfig
from .base import register

CONFIG = register(ModelConfig(
    name="musicgen-medium",
    arch_type="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24,
    d_ff=6144, vocab_size=2048, head_dim=64,
    norm_type="layernorm", act="gelu", pos_type="sinusoidal",
    n_codebooks=4,
    sliding_window=8192,
    long_context_mode="window",
    source="arXiv:2306.05284",
))
