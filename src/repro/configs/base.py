"""Config helpers: smoke-variant reduction and the config registry."""
from __future__ import annotations

from typing import Dict

from repro.models.common import ModelConfig


def smoke_variant(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Reduced same-family variant for CPU smoke tests: 2 layers,
    d_model <= 512, <= 4 experts, tiny vocab — structure preserved."""
    kw = dict(
        name=cfg.name + "-smoke",
        n_layers=2,
        d_model=256,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads
        else 4,
        head_dim=64,
        d_ff=512 if cfg.d_ff else 0,
        vocab_size=512,
        dtype="float32",
    )
    if cfg.n_experts:
        kw.update(n_experts=4, moe_top_k=min(cfg.moe_top_k, 2),
                  moe_d_ff=128,
                  n_shared_experts=min(cfg.n_shared_experts, 1),
                  first_k_dense=min(cfg.first_k_dense, 1))
    if cfg.use_mla:
        kw.update(q_lora_rank=96, kv_lora_rank=64, qk_nope_dim=32,
                  qk_rope_dim=16, v_head_dim=32, head_dim=48)
    if cfg.ssm_state:
        kw.update(ssm_state=16, ssm_head_dim=32, ssm_chunk=16)
    if cfg.arch_type == "ssm":      # xlstm
        kw.update(slstm_every=2, xlstm_qk_dim=32)
    if cfg.arch_type == "hybrid":
        kw.update(shared_attn_every=2)
    if cfg.pos_type == "mrope":
        kw.update(mrope_sections=(8, 12, 12))   # sums to head_dim/2 = 32
    if cfg.sliding_window:
        kw.update(sliding_window=32)
    kw.update(overrides)
    return cfg.replace(**kw)


_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    from . import registry  # noqa: F401  (populates _REGISTRY)
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch '{name}'; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def all_configs() -> Dict[str, ModelConfig]:
    from . import registry  # noqa: F401
    return dict(_REGISTRY)
