from __future__ import annotations

from .base import all_configs, get_config, smoke_variant  # noqa: F401
