"""xlstm-1.3b [ssm] — sLSTM + mLSTM blocks (7:1 mix), matrix memory.
d_ff=0: xLSTM blocks carry their own up/down projections.
[arXiv:2405.04517 — xLSTM: Extended Long Short-Term Memory]"""
from repro.models.common import ModelConfig
from .base import register

CONFIG = register(ModelConfig(
    name="xlstm-1.3b",
    arch_type="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50_304, head_dim=512,
    norm_type="layernorm", act="gelu", pos_type="none",
    use_xlstm=True, slstm_every=8, xlstm_proj_factor=2.0,
    xlstm_qk_dim=256,
    long_context_mode="recurrent",  # O(1) recurrent state
    source="arXiv:2405.04517",
))
