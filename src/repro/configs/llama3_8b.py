"""llama3-8b [dense] — GQA kv=8, 128k vocab.
[arXiv:2407.21783 — The Llama 3 Herd of Models]"""
from repro.models.common import ModelConfig
from .base import register

CONFIG = register(ModelConfig(
    name="llama3-8b",
    arch_type="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=128_256, head_dim=128,
    norm_type="rmsnorm", act="swiglu", pos_type="rope",
    rope_theta=500_000.0,
    sliding_window=8192,
    long_context_mode="window",
    source="arXiv:2407.21783",
))
