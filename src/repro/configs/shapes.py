"""Assigned input shapes and their input specs.

Shapes drive different step functions:
  train_4k     -> train_step   (full forward + backward + optimizer)
  prefill_32k  -> prefill_step (full forward, no grad)
  decode_32k   -> serve_step   (ONE token, KV/recurrent state of seq_len)
  long_500k    -> serve_step   (ONE token; sub-quadratic state: sliding
                  window for attention archs, O(1) recurrent for SSM)

``batch_specs`` returns ShapeDtypeStructs (dry-run: no allocation);
``concrete_batch`` materializes small real arrays for smoke tests.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import ModelConfig


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def cache_window(cfg: ModelConfig, shape: InputShape) -> int:
    """KV-cache buffer length for decode shapes. long_500k must be
    sub-quadratic: attention archs use the sliding window; recurrent
    archs keep O(1) state (window only sizes any attention sub-blocks,
    e.g. zamba2's shared attention)."""
    if shape.name == "long_500k":
        w = cfg.sliding_window or 8192
        return min(w, shape.seq_len)
    return shape.seq_len


def _token_spec(cfg: ModelConfig, b: int, s: int):
    if cfg.arch_type == "audio":
        return jax.ShapeDtypeStruct((b, cfg.n_codebooks, s), jnp.int32)
    return jax.ShapeDtypeStruct((b, s), jnp.int32)


def batch_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for the step function's batch arg."""
    b = shape.global_batch
    if shape.kind in ("train", "prefill"):
        s = shape.seq_len
        out: Dict[str, Any] = {"tokens": _token_spec(cfg, b, s)}
        if shape.kind == "train":
            out["targets"] = _token_spec(cfg, b, s)
        if cfg.pos_type == "mrope":
            out["positions"] = jax.ShapeDtypeStruct((b, s, 3), jnp.int32)
        return out
    # decode: one new token at position seq_len-1
    out = {"tokens": _token_spec(cfg, b, 1)}
    if cfg.pos_type == "mrope":
        out["positions"] = jax.ShapeDtypeStruct((b, 1, 3), jnp.int32)
    else:
        out["positions"] = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    return out


def concrete_batch(cfg: ModelConfig, shape: InputShape,
                   seed: int = 0) -> Dict[str, Any]:
    """Small real arrays matching batch_specs (smoke tests)."""
    rng = np.random.default_rng(seed)
    specs = batch_specs(cfg, shape)
    out = {}
    for k, spec in specs.items():
        if k == "positions":
            if spec.shape[-1] == 3 and len(spec.shape) == 3:
                base = np.arange(spec.shape[1], dtype=np.int32)
                pos = np.broadcast_to(base[None, :, None], spec.shape)
                out[k] = jnp.array(pos)
            else:
                base = np.arange(spec.shape[1], dtype=np.int32)
                out[k] = jnp.array(np.broadcast_to(base[None], spec.shape))
        else:
            out[k] = jnp.array(rng.integers(
                0, cfg.vocab_size, size=spec.shape, dtype=np.int32))
    return out


def smoke_shape(kind: str = "train", seq: int = 32,
                batch: int = 2) -> InputShape:
    return InputShape(f"smoke_{kind}", seq, batch, kind)
