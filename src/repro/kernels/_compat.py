"""Cross-version jax/pallas compatibility aliases."""
from jax.experimental.pallas import tpu as _pltpu

# Renamed across jax releases: newer trees expose ``CompilerParams``,
# older ones ``TPUCompilerParams``. Alias locally instead of patching
# the shared jax namespace.
CompilerParams = getattr(_pltpu, "CompilerParams",
                         getattr(_pltpu, "TPUCompilerParams", None))
