"""Jit'd public wrapper: kernel on TPU, interpret-mode kernel or oracle
on CPU (selected by backend; override with force_*)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from . import kernel as _kernel
from . import ref as _ref


def flash_attention(q, k, v, causal: bool = True,
                    window: Optional[int] = None,
                    block_q: int = 128, block_k: int = 128,
                    force_ref: bool = False,
                    force_kernel: bool = False) -> jnp.ndarray:
    on_tpu = jax.default_backend() == "tpu"
    if force_ref or (not on_tpu and not force_kernel):
        return _ref.attention_reference(q, k, v, causal=causal,
                                        window=window)
    return _kernel.flash_attention(q, k, v, causal=causal, window=window,
                                   block_q=block_q, block_k=block_k,
                                   interpret=not on_tpu)
