"""Blockwise (flash) causal attention as a Pallas TPU kernel.

TPU-native design (not a CUDA port):
  * 4D grid (batch, q_head, q_block, k_block); the innermost k_block
    dimension is sequential ("arbitrary"), carrying the online-softmax
    accumulators (m, l, acc) in VMEM scratch across iterations.
  * GQA is expressed in the k/v BlockSpec index_map (kv_head = h // g):
    no materialized head repetition in HBM.
  * Block shapes default to (128, head_dim) — MXU-aligned; the softmax
    runs on the VPU in fp32.
  * Causal + sliding-window masking via block-position iota; fully
    masked blocks still run (correctness kernel; a production variant
    would clamp the k-grid per q_block).

Validated in interpret mode against ref.py; the TARGET is TPU v5e.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .._compat import CompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, block_q: int, block_k: int, seq_k: int,
                  causal: bool, window: Optional[int]):
    qi = pl.program_id(2)
    kj = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)            # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)            # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)            # (bk, d)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0)
    k_pos = kj * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
    mask = k_pos < seq_k
    if causal:
        mask &= k_pos <= q_pos
    if window is not None and window > 0:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                            # (bq,)
    m_cur = jnp.maximum(m_prev, s.max(axis=-1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    # fully-masked rows: keep numerics clean
    p = jnp.where(mask, p, 0.0)
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_cur

    @pl.when(kj == nk - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_k", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, window: Optional[int] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True) -> jnp.ndarray:
    """q: (B, S, H, D); k, v: (B, T, KH, D) with H % KH == 0.
    Returns (B, S, H, D). Positions are assumed to be arange (training
    layout)."""
    b, s, h, d = q.shape
    t, kh = k.shape[1], k.shape[2]
    g = h // kh
    scale = 1.0 / math.sqrt(d)

    bq = min(block_q, s)
    bk = min(block_k, t)
    nq = pl.cdiv(s, bq)
    nk = pl.cdiv(t, bk)

    qt = jnp.moveaxis(q, 2, 1)                     # (B, H, S, D)
    kt = jnp.moveaxis(k, 2, 1)                     # (B, KH, T, D)
    vt = jnp.moveaxis(v, 2, 1)

    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=bq, block_k=bk, seq_k=t,
        causal=causal, window=window)

    out = pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bi, hi, qi, ki, g=g: (bi, hi // g, ki, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bi, hi, qi, ki, g=g: (bi, hi // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),        # m (running max)
            pltpu.VMEM((bq,), jnp.float32),        # l (running sum)
            pltpu.VMEM((bq, d), jnp.float32),      # acc
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qt, kt, vt)
    return jnp.moveaxis(out, 1, 2)
