"""Pure-jnp oracle for blockwise causal attention (training layout:
positions are arange; optional sliding window)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_reference(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        causal: bool = True,
                        window: Optional[int] = None) -> jnp.ndarray:
    """q: (B,S,H,D); k,v: (B,T,KH,D). fp32 softmax, GQA by head groups."""
    b, s, h, d = q.shape
    t, kh = k.shape[1], k.shape[2]
    g = h // kh
    qg = q.reshape(b, s, kh, g, d).astype(jnp.float32)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg,
                        k.astype(jnp.float32)) / math.sqrt(d)
    q_pos = jnp.arange(s)[:, None]
    k_pos = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None and window > 0:
        mask &= (q_pos - k_pos) < window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v.astype(jnp.float32))
    return out.reshape(b, s, h, d).astype(q.dtype)
