"""jax.lax.reduce_window oracle for the fitmask kernel."""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp


def fitmask_reference(occ: jnp.ndarray,
                      box: Tuple[int, int, int]) -> jnp.ndarray:
    """occ: (B, X, Y, Z). Returns (B, X, Y, Z) int32, 1 where the box
    fits (un-wrapped), 0 elsewhere (including origins where the box
    would overhang)."""
    bsz, x, y, z = occ.shape
    a, b, c = box
    if a > x or b > y or c > z:
        return jnp.zeros((bsz, x, y, z), jnp.int32)
    sums = jax.lax.reduce_window(
        occ.astype(jnp.int32), 0, jax.lax.add,
        window_dimensions=(1, a, b, c),
        window_strides=(1, 1, 1, 1), padding="valid")
    fits = (sums == 0).astype(jnp.int32)
    pad = ((0, 0), (0, x - fits.shape[1]), (0, y - fits.shape[2]),
           (0, z - fits.shape[3]))
    return jnp.pad(fits, pad)


def fitmask_multibox_reference(occ: jnp.ndarray,
                               boxes: Sequence[Tuple[int, int, int]]
                               ) -> jnp.ndarray:
    """Multi-box oracle: (B, X, Y, Z) x K boxes -> (B, K, X, Y, Z)
    int32, one :func:`fitmask_reference` plane per box. This is the
    arbiter the batched engine paths (numpy ``fit_mask_multi_fast``,
    the jax fused bucket program, the Pallas kernel) are parity-tested
    against."""
    bsz, x, y, z = occ.shape
    if not boxes:
        return jnp.zeros((bsz, 0, x, y, z), jnp.int32)
    return jnp.stack(
        [fitmask_reference(occ, tuple(int(v) for v in b)) for b in boxes],
        axis=1)
