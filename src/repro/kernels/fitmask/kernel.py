"""Free-box search ("fitmask") as a Pallas TPU kernel.

The allocator's hot spot: for every origin of an occupancy grid, is the
(a, b, c) window entirely free? TPU-native formulation: one fused VMEM
pass per grid — 3D integral image via cumulative sums (VPU), window sums
via 8-corner inclusion/exclusion, batched over cubes/candidate grids on
the Pallas grid axis. Cluster grids are tiny (<= 64^3 int32 = 1 MiB), so
a whole grid fits VMEM comfortably; batching is the tiling axis.

Two entry points:

* :func:`fitmask_batched` — one box shape per call (kept as the K=1
  parity baseline and for callers with a single candidate).
* :func:`fitmask_multibox` — the fold-enumeration form: the integral
  image is built **once** per grid and answers all K candidate boxes in
  that single VMEM pass (K is static per trace epoch). RFold's
  ``enumerate_folds`` multiplies box queries per placement step, so this
  is the kernel the placement search runs on.
"""
from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Box = Tuple[int, int, int]


def _integral_image(occ: jnp.ndarray) -> jnp.ndarray:
    """(X, Y, Z) int32 -> (X+1, Y+1, Z+1) inclusive-prefix sums."""
    ii = jnp.pad(occ, ((1, 0), (1, 0), (1, 0)))
    ii = jnp.cumsum(ii, axis=0)
    ii = jnp.cumsum(ii, axis=1)
    ii = jnp.cumsum(ii, axis=2)
    return ii


def _window_fits(ii: jnp.ndarray, box: Box) -> jnp.ndarray:
    """Cropped (..., X-a+1, Y-b+1, Z-c+1) int32 fit mask for one box
    from a prebuilt integral image over the trailing 3 axes (leading
    axes, if any, are batch dims — the jax engine shares this with the
    kernel). Nested per-axis differencing — three slice-subtractions —
    is algebraically the 8-corner inclusion/exclusion but at less than
    half the op count, which is what the K-way unrolled loop
    amortizes."""
    a, b, c = box
    s = ii[..., a:, :, :] - ii[..., :-a, :, :]
    s = s[..., b:, :] - s[..., :-b, :]
    s = s[..., c:] - s[..., :-c]
    return (s == 0).astype(jnp.int32)


def _fitmask_kernel(occ_ref, out_ref, *, box: Box):
    occ = occ_ref[0].astype(jnp.int32)             # (X, Y, Z)
    x, y, z = occ.shape
    a, b, c = box
    ii = _integral_image(occ)                      # (X+1, Y+1, Z+1)
    # origins where the box overhangs stay 0
    out_ref[0] = jnp.zeros((x, y, z), jnp.int32)
    out_ref[0, :x - a + 1, :y - b + 1, :z - c + 1] = _window_fits(ii, box)


def _fitmask_multibox_kernel(occ_ref, out_ref, *, boxes: Tuple[Box, ...]):
    """One integral image in VMEM, K window extractions. ``boxes`` is
    static, so the K loop unrolls at trace time into pure VPU slicing —
    no per-box cumsum rebuild, which is the whole point."""
    occ = occ_ref[0].astype(jnp.int32)             # (X, Y, Z)
    x, y, z = occ.shape
    ii = _integral_image(occ)
    out_ref[0] = jnp.zeros((len(boxes), x, y, z), jnp.int32)
    for k, (a, b, c) in enumerate(boxes):
        if a <= x and b <= y and c <= z:           # else: all-zero plane
            out_ref[0, k, :x - a + 1, :y - b + 1, :z - c + 1] = \
                _window_fits(ii, (a, b, c))


@functools.partial(jax.jit, static_argnames=("box", "interpret"))
def fitmask_batched(occ: jnp.ndarray, box: Box,
                    interpret: bool = True) -> jnp.ndarray:
    """occ: (B, X, Y, Z) bool/int. Returns (B, X, Y, Z) int32 — 1 where
    an un-wrapped box fits with its origin at that cell."""
    bsz, x, y, z = occ.shape
    a, b, c = box
    if a > x or b > y or c > z:
        return jnp.zeros((bsz, x, y, z), jnp.int32)
    kern = functools.partial(_fitmask_kernel, box=box)
    return pl.pallas_call(
        kern,
        grid=(bsz,),
        in_specs=[pl.BlockSpec((1, x, y, z), lambda i: (i, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, x, y, z), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, x, y, z), jnp.int32),
        interpret=interpret,
    )(occ.astype(jnp.int32))


@functools.partial(jax.jit, static_argnames=("boxes", "interpret"))
def fitmask_multibox(occ: jnp.ndarray, boxes: Tuple[Box, ...],
                     interpret: bool = True) -> jnp.ndarray:
    """All K candidate boxes from one VMEM integral-image pass.

    occ: (B, X, Y, Z) bool/int; ``boxes``: static tuple of K (a, b, c)
    shapes (hash them per trace epoch). Returns (B, K, X, Y, Z) int32 —
    ``out[i, k]`` is the full-grid fit mask of ``boxes[k]`` on grid
    ``i``; boxes that cannot fit anywhere (including ones larger than
    the grid) are all-zero planes, so callers never special-case K.
    """
    boxes = tuple(tuple(int(v) for v in b) for b in boxes)
    bsz, x, y, z = occ.shape
    k = len(boxes)
    if k == 0:
        return jnp.zeros((bsz, 0, x, y, z), jnp.int32)
    kern = functools.partial(_fitmask_multibox_kernel, boxes=boxes)
    return pl.pallas_call(
        kern,
        grid=(bsz,),
        in_specs=[pl.BlockSpec((1, x, y, z), lambda i: (i, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, k, x, y, z), lambda i: (i, 0, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, k, x, y, z), jnp.int32),
        interpret=interpret,
    )(occ.astype(jnp.int32))


def _occupancy_counts_kernel(occ_ref, out_ref):
    out_ref[0, 0] = jnp.sum(occ_ref[0].astype(jnp.int32))


@functools.partial(jax.jit, static_argnames=("interpret",))
def occupancy_counts(occ: jnp.ndarray, interpret: bool = True) -> jnp.ndarray:
    """Occupied-cell count per grid: (B, X, Y, Z) bool/int -> (B,) int32.

    The engine registry's ``free_counts`` query runs on this (free =
    X*Y*Z - occupied): the reconfigurable-torus allocator needs per-cube
    free counts for its best-fit ordering every occupancy epoch, and
    answering them device-side is what lets accelerator engines drop the
    host integral-image pass entirely. One program per grid, whole grid
    in VMEM (same batching axis as the fitmask kernels), single VPU
    reduction."""
    bsz, x, y, z = occ.shape
    out = pl.pallas_call(
        _occupancy_counts_kernel,
        grid=(bsz,),
        in_specs=[pl.BlockSpec((1, x, y, z), lambda i: (i, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, 1), jnp.int32),
        interpret=interpret,
    )(occ.astype(jnp.int32))
    return out[:, 0]


def fitmask_multibox_singlepass_baseline(
        occ: jnp.ndarray, boxes: Sequence[Box],
        interpret: bool = True) -> jnp.ndarray:
    """K independent single-box ``pallas_call``s stacked on a new axis —
    the pre-multibox design, kept as the benchmark baseline (each call
    rebuilds the 3-axis cumsum)."""
    return jnp.stack([fitmask_batched(occ, tuple(b), interpret=interpret)
                      for b in boxes], axis=1)
