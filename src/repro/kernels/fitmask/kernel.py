"""Free-box search ("fitmask") as a Pallas TPU kernel.

The allocator's hot spot: for every origin of an occupancy grid, is the
(a, b, c) window entirely free? TPU-native formulation: one fused VMEM
pass per grid — 3D integral image via cumulative sums (VPU), window sums
via 8-corner inclusion/exclusion, batched over cubes/candidate grids on
the Pallas grid axis. Cluster grids are tiny (<= 64^3 int32 = 1 MiB), so
a whole grid fits VMEM comfortably; batching is the tiling axis.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fitmask_kernel(occ_ref, out_ref, *, box: Tuple[int, int, int]):
    a, b, c = box
    occ = occ_ref[0].astype(jnp.int32)             # (X, Y, Z)
    x, y, z = occ.shape
    ii = jnp.pad(occ, ((1, 0), (1, 0), (1, 0)))
    ii = jnp.cumsum(ii, axis=0)
    ii = jnp.cumsum(ii, axis=1)
    ii = jnp.cumsum(ii, axis=2)                    # (X+1, Y+1, Z+1)
    s = (ii[a:, b:, c:] - ii[:-a, b:, c:] - ii[a:, :-b, c:]
         - ii[a:, b:, :-c] + ii[:-a, :-b, c:] + ii[:-a, b:, :-c]
         + ii[a:, :-b, :-c] - ii[:-a, :-b, :-c])
    fits = (s == 0).astype(jnp.int32)
    # static padding back to the full grid extent (positions where the
    # box does not fit are 0)
    out = jnp.zeros((x, y, z), jnp.int32)
    out = jax.lax.dynamic_update_slice(out, fits, (0, 0, 0))
    out_ref[0] = out


@functools.partial(jax.jit, static_argnames=("box", "interpret"))
def fitmask_batched(occ: jnp.ndarray, box: Tuple[int, int, int],
                    interpret: bool = True) -> jnp.ndarray:
    """occ: (B, X, Y, Z) bool/int. Returns (B, X, Y, Z) int32 — 1 where
    an un-wrapped box fits with its origin at that cell."""
    bsz, x, y, z = occ.shape
    a, b, c = box
    if a > x or b > y or c > z:
        return jnp.zeros((bsz, x, y, z), jnp.int32)
    kern = functools.partial(_fitmask_kernel, box=box)
    return pl.pallas_call(
        kern,
        grid=(bsz,),
        in_specs=[pl.BlockSpec((1, x, y, z), lambda i: (i, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, x, y, z), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, x, y, z), jnp.int32),
        interpret=interpret,
    )(occ.astype(jnp.int32))
