"""Public wrapper for fitmask: numpy engine (sim hot path), reduce_window
oracle, and the Pallas kernel — all agree; tests sweep shapes."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fitmask as np_engine
from . import kernel as _kernel
from . import ref as _ref


def fitmask(occ, box: Tuple[int, int, int], engine: str = "auto"):
    """occ: (B, X, Y, Z) or (X, Y, Z). Returns int32 fit mask of the
    same (batched) shape."""
    squeeze = occ.ndim == 3
    if squeeze:
        occ = occ[None]
    if engine == "numpy":
        # One shared batched integral image for the whole batch (no
        # per-grid python loop) — same trick the allocator hot path uses.
        out = np_engine.fit_mask_batched(np.asarray(occ), box).astype(np.int32)
        x, y, z = occ.shape[1:]
        pad = [(0, 0), (0, x - out.shape[1]), (0, y - out.shape[2]),
               (0, z - out.shape[3])]
        out = jnp.asarray(np.pad(out, pad))
    elif engine == "ref":
        out = _ref.fitmask_reference(jnp.asarray(occ), box)
    else:
        on_tpu = jax.default_backend() == "tpu"
        out = _kernel.fitmask_batched(jnp.asarray(occ), box,
                                      interpret=not on_tpu)
    return out[0] if squeeze else out
