"""Pluggable fitmask engine layer.

Every placement policy reduces to the same primitive — "for each origin
of each grid, does box k fit in free space?" — so the engines live
behind one registry and the allocator picks at runtime:

  * ``numpy``  — batched integral-image window sums on the host
    (`repro.core.fitmask`). The simulator's default and the parity
    oracle for everything else. **Pure numpy**: no jax call, no device
    round-trip (tested).
  * ``jax``    — the same algorithm as jitted XLA ops; the CPU/GPU
    accelerator path and the apples-to-apples baseline for the kernel.
  * ``pallas`` — the Pallas TPU kernel: one VMEM integral-image pass
    per grid answering all K candidate boxes
    (`kernel.fitmask_multibox`); interpret mode off-TPU.
  * ``ref``    — `jax.lax.reduce_window` oracle.

Selection: an explicit ``engine=`` argument wins, then
:func:`set_default_engine`, then the ``REPRO_FITMASK_ENGINE``
environment variable, then ``numpy``. All engines share the contract
``multibox(occ, boxes) -> (B, K, X, Y, Z) int32`` with every plane
padded to the full grid (0 where the box overhangs or cannot fit), so
callers never special-case engine, K, or infeasible boxes — plus
``free_counts(occ) -> (B,)`` (free cells per grid), which the
reconfigurable torus uses for best-fit cube ordering so accelerator
runs never rebuild the host integral image.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Sequence, Tuple, Type

import numpy as np

from repro.core import engineconfig as _engineconfig
from repro.core import fitmask as np_engine

Box = Tuple[int, int, int]

# Selection order (explicit > set_default_engine > deprecated env var
# > numpy) lives in repro.core.engineconfig — the single resolution
# point; the names below are retained delegating spellings.
ENGINE_ENV = _engineconfig.ENGINE_ENV

# Compile-cache caps. Per-box window programs and per-bucket fused
# programs are cached per distinct key; a long multi-shape sweep keeps
# minting new keys, so the caches are LRU-bounded rather than unbounded
# ``functools.cache`` (evicting a program only costs a re-jit if the
# shape ever comes back — it cannot change results).
WINDOW_CACHE_SIZE = 256   # distinct boxes (allocator candidate sets)
BUCKET_CACHE_SIZE = 64    # distinct fused (box-table, grid) programs


def _canon_boxes(boxes: Sequence[Box]) -> Tuple[Box, ...]:
    return tuple(tuple(int(v) for v in b) for b in boxes)  # type: ignore


class FitmaskEngine:
    """One fitmask backend. Subclasses implement :meth:`multibox` and
    :meth:`free_counts`; :meth:`fitmask` is the single-box convenience
    on top of :meth:`multibox`.

    Two capability flags drive the fleet broker's per-bucket padding
    policy (``repro.sim.fleet``):

    ``pads_shapes``
        True for compiled backends, where every distinct (B, K) input
        shape traces/compiles a fresh XLA program — the broker then
        pads flushes to a small set of bucketed shapes. False for the
        host engine, where padding is pure wasted arithmetic.
    ``host_free``
        True when ``free_counts`` is a cheap host reduction that is
        faster answered inline than coalesced through a broker round.
    """

    name = "base"
    pads_shapes = False
    host_free = False

    def multibox(self, occ, boxes: Sequence[Box]):
        """(B, X, Y, Z) x K boxes -> (B, K, X, Y, Z) int32."""
        raise NotImplementedError

    def free_counts(self, occ):
        """Free-cell count per grid: (B, X, Y, Z) -> (B,) int. The
        reconfigurable torus orders cubes best-fit by this every
        occupancy epoch; engines answer it natively so accelerator runs
        never rebuild the host integral image (ROADMAP item)."""
        raise NotImplementedError

    def multibox_bucketed(self, occ, boxes: Sequence[Box]):
        """The fleet broker's flush entry: one engine pass answering
        all K boxes AND the per-grid free counts together, as
        ``(planes, free)`` — planes (B, K, X, Y, Z), *nonzero where
        the box fits* (any integer/bool dtype; the classic
        :meth:`multibox` int32 contract is one valid encoding), free
        (B,) integer. Engines with a fused program override this so a
        flush is a single dispatch; the default is the two classic
        calls, so every engine is broker-servable."""
        return self.multibox(occ, boxes), self.free_counts(occ)

    def fitmask(self, occ, box: Box):
        """(B, X, Y, Z) -> (B, X, Y, Z) int32 for one box."""
        return self.multibox(occ, (box,))[:, 0]


class NumpyEngine(FitmaskEngine):
    """Host integral-image engine — the sim hot path and the oracle
    arbiter. Deliberately references no jax symbol: results stay numpy
    unless the caller converts (regression-tested).

    ``multibox`` runs the genuinely batched (B, K) vectorized form
    (``fit_mask_multi_fast``: one stacked int16 integral image, nested
    per-axis differencing, no per-grid python loop); the straight-line
    ``fit_mask_multi`` is retained in ``repro.core.fitmask`` as its
    parity oracle."""

    name = "numpy"
    host_free = True

    def multibox(self, occ, boxes: Sequence[Box]) -> np.ndarray:
        return np_engine.fit_mask_multi_fast(np.asarray(occ),
                                             _canon_boxes(boxes))[0]

    def multibox_bucketed(self, occ, boxes: Sequence[Box]):
        masks, free = np_engine.fit_mask_multi_fast(
            np.asarray(occ), _canon_boxes(boxes), out_dtype=bool)
        return masks, free

    def free_counts(self, occ) -> np.ndarray:
        return np_engine.free_counts(np.asarray(occ))


class JaxEngine(FitmaskEngine):
    """Jitted XLA ops (no Pallas): the shared-integral-image algorithm,
    batched over grids. The integral image jits once per grid shape and
    each distinct box jits one small window-extraction program — so
    when the allocator's candidate set grows by a box, only that box
    compiles (a single K-static program would recompile the whole,
    ever-larger, unrolled loop on every growth).

    The fleet broker instead calls :meth:`multibox_bucketed`, whose
    box set is a *stable padded table* (one per bucket): there the
    whole-table fused single-dispatch program wins, because it is
    compiled once and re-run for every flush of the bucket."""

    name = "jax"
    pads_shapes = True

    @staticmethod
    @functools.cache
    def _ii_fn():
        import jax
        import jax.numpy as jnp

        def ii(occ):
            acc = jnp.pad(occ.astype(jnp.int32),
                          ((0, 0), (1, 0), (1, 0), (1, 0)))
            for ax in (1, 2, 3):
                acc = jnp.cumsum(acc, axis=ax)
            return acc

        return jax.jit(ii)

    @staticmethod
    @functools.lru_cache(maxsize=WINDOW_CACHE_SIZE)
    def _window_fn(box: Box):
        import jax
        import jax.numpy as jnp
        from .kernel import _window_fits
        a, b, c = box

        def window(ii):
            bsz = ii.shape[0]
            x, y, z = (d - 1 for d in ii.shape[1:])
            if a > x or b > y or c > z:
                return jnp.zeros((bsz, x, y, z), jnp.int32)
            fits = _window_fits(ii, box)
            out = jnp.zeros((bsz, x, y, z), jnp.int32)
            return jax.lax.dynamic_update_slice(out, fits, (0, 0, 0, 0))

        return jax.jit(window)

    def multibox(self, occ, boxes: Sequence[Box]):
        import jax.numpy as jnp
        boxes = _canon_boxes(boxes)
        occ = jnp.asarray(occ)
        if not boxes:
            bsz, x, y, z = occ.shape
            return jnp.zeros((bsz, 0, x, y, z), jnp.int32)
        ii = self._ii_fn()(occ)
        return jnp.stack([self._window_fn(b)(ii) for b in boxes], axis=1)

    @staticmethod
    @functools.lru_cache(maxsize=BUCKET_CACHE_SIZE)
    def _bucket_fn(boxes: Tuple[Box, ...]):
        """One fused jitted program for a *stable* box table: int16
        integral image (memory-bound halving; exact up to 31^3 cells),
        nested per-axis differencing (three subtractions, as the Pallas
        kernel does), bool planes, and the free counts read off the
        integral-image corner — a flush is a single XLA dispatch.
        Retraces per (B, cell) shape, which is exactly what the
        broker's bucketed padding keeps small.

        Three trace-time tricks keep the program lean on top of the
        shared integral image: partial differences are memoised per
        ``a`` and per ``(a, b)`` prefix (candidate tables cluster on
        shared extents, so most boxes pay only the final axis);
        duplicate boxes — the broker pads table capacity with repeats
        — reuse the already traced comparison instead of recomputing
        it; and every plane is written straight into one
        ``(B, K, X, Y, Z)`` output buffer through a chain of
        ``dynamic_update_slice`` ops that XLA turns into in-place
        writes — no per-plane zero template and no final ``stack``
        copy."""
        import jax
        import jax.numpy as jnp

        def run(occ):
            bsz, x, y, z = occ.shape
            vol = x * y * z
            dt = jnp.int16 if vol <= 32767 else jnp.int32
            ii = jnp.pad(occ.astype(dt),
                         ((0, 0), (1, 0), (1, 0), (1, 0)))
            for ax in (1, 2, 3):
                ii = jnp.cumsum(ii, axis=ax)
            sx, sxy, fits = {}, {}, {}
            out = jnp.zeros((bsz, len(boxes), x, y, z), jnp.bool_)
            for k, box in enumerate(boxes):
                if box not in fits:
                    a, b, c = box
                    if a > x or b > y or c > z:
                        fits[box] = None   # infeasible: stays zero
                    else:
                        if a not in sx:
                            sx[a] = ii[:, a:, :, :] - ii[:, :-a, :, :]
                        if (a, b) not in sxy:
                            s = sx[a]
                            sxy[(a, b)] = (s[:, :, b:, :]
                                           - s[:, :, :-b, :])
                        s = sxy[(a, b)]
                        s = s[:, :, :, c:] - s[:, :, :, :-c]
                        fits[box] = s == 0
                if fits[box] is not None:
                    out = jax.lax.dynamic_update_slice(
                        out, fits[box][:, None], (0, k, 0, 0, 0))
            free = vol - ii[:, -1, -1, -1].astype(jnp.int32)
            return out, free

        return jax.jit(run)

    def multibox_bucketed(self, occ, boxes: Sequence[Box]):
        import jax.numpy as jnp
        boxes = _canon_boxes(boxes)
        occ = jnp.asarray(occ)
        if not boxes:
            bsz, x, y, z = occ.shape
            return (jnp.zeros((bsz, 0, x, y, z), jnp.bool_),
                    self.free_counts(occ))
        return self._bucket_fn(boxes)(occ)

    @staticmethod
    @functools.cache
    def _free_counts_fn():
        import jax
        import jax.numpy as jnp

        def free(occ):
            n3 = occ.shape[1] * occ.shape[2] * occ.shape[3]
            return n3 - jnp.sum(occ.astype(jnp.int32), axis=(1, 2, 3))

        return jax.jit(free)

    def free_counts(self, occ):
        import jax.numpy as jnp
        return self._free_counts_fn()(jnp.asarray(occ))


class PallasEngine(FitmaskEngine):
    """The multi-box Pallas kernel: one VMEM pass for all K boxes,
    compiled on TPU, interpret mode elsewhere. ``multibox`` is already
    a single static-box-table program, so the default
    ``multibox_bucketed`` (multibox + free_counts) is two dispatches —
    both shape-stable under the broker's bucketed padding, hence
    ``pads_shapes``."""

    name = "pallas"
    pads_shapes = True

    def __init__(self, interpret: Optional[bool] = None):
        self._interpret = interpret

    def _interp(self) -> bool:
        if self._interpret is not None:
            return self._interpret
        import jax
        return jax.default_backend() != "tpu"

    def multibox(self, occ, boxes: Sequence[Box]):
        import jax.numpy as jnp
        from . import kernel as _kernel
        return _kernel.fitmask_multibox(jnp.asarray(occ),
                                        _canon_boxes(boxes),
                                        interpret=self._interp())

    def fitmask(self, occ, box: Box):
        import jax.numpy as jnp
        from . import kernel as _kernel
        return _kernel.fitmask_batched(jnp.asarray(occ),
                                       tuple(int(v) for v in box),
                                       interpret=self._interp())

    def free_counts(self, occ):
        import jax.numpy as jnp
        from . import kernel as _kernel
        occ = jnp.asarray(occ)
        n3 = occ.shape[1] * occ.shape[2] * occ.shape[3]
        return n3 - _kernel.occupancy_counts(occ, interpret=self._interp())


class RefEngine(FitmaskEngine):
    """reduce_window oracle (jax, unjitted per box)."""

    name = "ref"

    def multibox(self, occ, boxes: Sequence[Box]):
        import jax.numpy as jnp
        from . import ref as _ref
        occ = jnp.asarray(occ)
        boxes = _canon_boxes(boxes)
        if not boxes:
            bsz, x, y, z = occ.shape
            return jnp.zeros((bsz, 0, x, y, z), jnp.int32)
        return jnp.stack([_ref.fitmask_reference(occ, b) for b in boxes],
                         axis=1)

    def free_counts(self, occ):
        import jax.numpy as jnp
        occ = jnp.asarray(occ)
        n3 = occ.shape[1] * occ.shape[2] * occ.shape[3]
        return n3 - jnp.sum(occ.astype(jnp.int32), axis=(1, 2, 3))


_REGISTRY: Dict[str, Type[FitmaskEngine]] = {}
_INSTANCES: Dict[str, FitmaskEngine] = {}
# Back-compat spellings from the pre-registry wrapper.
_ALIASES = {"auto": "pallas", "kernel": "pallas"}


def register_engine(cls: Type[FitmaskEngine]) -> Type[FitmaskEngine]:
    _REGISTRY[cls.name] = cls
    _INSTANCES.pop(cls.name, None)
    return cls


for _cls in (NumpyEngine, JaxEngine, PallasEngine, RefEngine):
    register_engine(_cls)


def available_engines() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def set_default_engine(name: Optional[str]) -> None:
    """Process-wide default (overrides the deprecated env var); None
    resets to env-var/``numpy`` resolution. Delegates to
    ``repro.core.engineconfig`` — the single selection point."""
    _engineconfig.set_default_engine(name)


def default_engine_name() -> str:
    return _engineconfig.default_engine_name()


def get_engine(name: Optional[str] = None) -> FitmaskEngine:
    name = _ALIASES.get(name, name) if name else default_engine_name()
    if name not in _REGISTRY:
        raise KeyError(f"unknown fitmask engine {name!r}; "
                       f"have {available_engines()}")
    inst = _INSTANCES.get(name)
    if inst is None:
        inst = _INSTANCES[name] = _REGISTRY[name]()
    return inst


def fitmask(occ, box: Box, engine: Optional[str] = None):
    """occ: (B, X, Y, Z) or (X, Y, Z). Returns the int32 fit mask of
    the same (batched) shape. ``engine=None`` follows the registry's
    selection order (set_default_engine > env var > numpy). The numpy
    engine returns a numpy array — no device round-trip; callers that
    want a jax array either convert or pick a jax-backed engine."""
    squeeze = occ.ndim == 3
    if squeeze:
        occ = occ[None]
    out = get_engine(engine).fitmask(occ, box)
    return out[0] if squeeze else out


def fitmask_multi(occ, boxes: Sequence[Box], engine: Optional[str] = None):
    """All K candidate boxes in one engine pass: (B, X, Y, Z) or
    (X, Y, Z) -> (B, K, X, Y, Z) / (K, X, Y, Z) int32."""
    squeeze = occ.ndim == 3
    if squeeze:
        occ = occ[None]
    out = get_engine(engine).multibox(occ, boxes)
    return out[0] if squeeze else out


def free_counts(occ, engine: Optional[str] = None):
    """Free-cell count per grid: (B, X, Y, Z) -> (B,) int, or a single
    (X, Y, Z) grid -> scalar. Routed through the selected engine, so
    accelerator backends answer it without a host integral-image
    build."""
    squeeze = occ.ndim == 3
    if squeeze:
        occ = occ[None]
    out = get_engine(engine).free_counts(occ)
    return out[0] if squeeze else out
