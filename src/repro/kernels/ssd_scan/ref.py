"""Pure-jnp oracle for the Mamba2 SSD (state-space dual) chunked scan.

Semantics (per batch, head):
    S_t = exp(dt_t * A) * S_{t-1} + dt_t * x_t (outer) B_t
    y_t = C_t . S_t + D * x_t
with S in R^{P x N} (headdim x state). The chunked form computes
intra-chunk contributions with a causal quadratic form (MXU-friendly)
and carries inter-chunk state with a scan — this reference is the
ground truth for the Pallas kernel and the model layer.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def segsum(log_a: jnp.ndarray) -> jnp.ndarray:
    """Causal segment-sum: out[..., t, s] = sum_{r=s+1..t} log_a[..., r]
    for s <= t, -inf otherwise."""
    T = log_a.shape[-1]
    cs = jnp.cumsum(log_a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]          # t, s
    mask = jnp.tril(jnp.ones((T, T), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_reference(x: jnp.ndarray, dt: jnp.ndarray, a: jnp.ndarray,
                  b: jnp.ndarray, c: jnp.ndarray,
                  chunk: int = 64,
                  d_skip: Optional[jnp.ndarray] = None,
                  init_state: Optional[jnp.ndarray] = None
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD scan.

    x:  (B, S, H, P)   inputs per head
    dt: (B, S, H)      positive step sizes (already softplus'ed)
    a:  (H,)           negative decay rates (A = -exp(a_log))
    b:  (B, S, H, N)   input projections (already group-broadcast)
    c:  (B, S, H, N)   output projections
    returns y (B, S, H, P), final_state (B, H, P, N)
    """
    B_, S, H, P = x.shape
    N = b.shape[-1]
    assert S % chunk == 0, (S, chunk)
    K = S // chunk
    f32 = jnp.float32

    xs = x.reshape(B_, K, chunk, H, P).astype(f32)
    dts = dt.reshape(B_, K, chunk, H).astype(f32)
    bs = b.reshape(B_, K, chunk, H, N).astype(f32)
    cs = c.reshape(B_, K, chunk, H, N).astype(f32)

    log_a = dts * a.astype(f32)                         # (B,K,Q,H)
    log_a = jnp.moveaxis(log_a, -1, -2)                 # (B,K,H,Q)
    seg = segsum(log_a)                                 # (B,K,H,Q,Q)

    # intra-chunk quadratic form
    cb = jnp.einsum("bkqhn,bkshn->bkhqs", cs, bs)       # (B,K,H,Q,Q)
    m = cb * jnp.exp(seg) * jnp.moveaxis(dts, -1, -2)[..., None, :]
    y_intra = jnp.einsum("bkhqs,bkshp->bkqhp", m, xs)

    # per-chunk state contribution: decay from s to end of chunk
    cum = jnp.cumsum(log_a, axis=-1)                    # (B,K,H,Q)
    total = cum[..., -1:]                               # (B,K,H,1)
    decay_to_end = jnp.exp(total - cum)                 # (B,K,H,Q)
    # weight x by dt, decayed from position s to the chunk end
    w = (jnp.moveaxis(dts, -1, -2) * decay_to_end)      # (B,K,H,Q)
    chunk_state = jnp.einsum("bkhq,bkqhp,bkqhn->bkhpn", w, xs, bs)

    # inter-chunk recurrence over K
    chunk_decay = jnp.exp(total[..., 0])                # (B,K,H)

    def step(s_prev, inp):
        dec, st = inp                                   # (B,H), (B,H,P,N)
        s_new = s_prev * dec[..., None, None] + st
        return s_new, s_prev                            # emit state BEFORE

    s0 = (init_state.astype(f32) if init_state is not None
          else jnp.zeros((B_, H, P, N), f32))
    dec_seq = jnp.moveaxis(chunk_decay, 1, 0)           # (K,B,H)
    st_seq = jnp.moveaxis(chunk_state, 1, 0)            # (K,B,H,P,N)
    s_final, s_prevs = jax.lax.scan(step, s0, (dec_seq, st_seq))
    s_prevs = jnp.moveaxis(s_prevs, 0, 1)               # (B,K,H,P,N)

    # inter-chunk output: state entering the chunk, decayed to position t
    state_decay = jnp.exp(cum)                          # (B,K,H,Q)
    y_inter = jnp.einsum("bkqhn,bkhpn,bkhq->bkqhp", cs, s_prevs, state_decay)

    y = (y_intra + y_inter).reshape(B_, S, H, P)
    if d_skip is not None:
        y = y + x.astype(f32) * d_skip.astype(f32)[None, None, :, None]
    return y.astype(x.dtype), s_final


def ssd_step(state: jnp.ndarray, x: jnp.ndarray, dt: jnp.ndarray,
             a: jnp.ndarray, b: jnp.ndarray, c: jnp.ndarray,
             d_skip: Optional[jnp.ndarray] = None
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Single decode step.

    state: (B,H,P,N); x: (B,H,P); dt: (B,H); b,c: (B,H,N)
    returns (y (B,H,P), new_state)
    """
    f32 = jnp.float32
    decay = jnp.exp(dt.astype(f32) * a.astype(f32))     # (B,H)
    upd = (dt.astype(f32)[..., None, None]
           * x.astype(f32)[..., :, None] * b.astype(f32)[..., None, :])
    new_state = state.astype(f32) * decay[..., None, None] + upd
    y = jnp.einsum("bhpn,bhn->bhp", new_state, c.astype(f32))
    if d_skip is not None:
        y = y + x.astype(f32) * d_skip.astype(f32)[None, :, None]
    return y.astype(x.dtype), new_state.astype(state.dtype)


def ssd_sequential_reference(x, dt, a, b, c, d_skip=None, init_state=None):
    """O(S) sequential oracle (slowest, simplest) used to validate the
    chunked form itself."""
    B_, S, H, P = x.shape
    N = b.shape[-1]
    s = (init_state if init_state is not None
         else jnp.zeros((B_, H, P, N), jnp.float32))
    ys = []
    for t in range(S):
        y, s = ssd_step(s, x[:, t], dt[:, t], a, b[:, t], c[:, t], d_skip)
        ys.append(y)
    return jnp.stack(ys, axis=1), s
