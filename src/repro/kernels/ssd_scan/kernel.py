"""Mamba2 SSD chunked scan as a Pallas TPU kernel.

TPU-native design: grid (batch, head, chunk) with the chunk axis
sequential ("arbitrary") — the inter-chunk state (P x N, fp32) lives in
VMEM scratch and is carried across chunk iterations, while the
intra-chunk quadratic form (Q x Q) runs on the MXU. This replaces the
CUDA warp-level scan of the original Mamba2 kernels with a
grid-carried-scratch recurrence, which is the idiomatic TPU structure.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .._compat import CompilerParams

NEG_INF = -1e30


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref,
                y_ref, fs_ref, state_scr, *, chunk: int):
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    x = x_ref[0, 0, 0].astype(jnp.float32)          # (Q, P)
    dt = dt_ref[0, 0, 0].astype(jnp.float32)        # (Q,)
    a = a_ref[0].astype(jnp.float32)                # scalar
    b = b_ref[0, 0, 0].astype(jnp.float32)          # (Q, N)
    c = c_ref[0, 0, 0].astype(jnp.float32)          # (Q, N)
    d = d_ref[0].astype(jnp.float32)                # scalar

    @pl.when(ki == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    log_a = dt * a                                  # (Q,)
    cum = jnp.cumsum(log_a)                         # (Q,)
    total = cum[-1]

    # intra-chunk quadratic form (causal)
    seg = cum[:, None] - cum[None, :]               # (Q, Q): t, s
    ti = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    seg = jnp.where(si <= ti, seg, NEG_INF)
    cb = jnp.dot(c, b.T, preferred_element_type=jnp.float32)
    m = cb * jnp.exp(seg) * dt[None, :]
    y = jnp.dot(m, x, preferred_element_type=jnp.float32)

    # inter-chunk contribution from the carried state
    state = state_scr[...]                          # (P, N)
    y += jnp.exp(cum)[:, None] * jnp.dot(
        c, state.T, preferred_element_type=jnp.float32)

    # state update: decay + chunk contribution
    w = dt * jnp.exp(total - cum)                   # (Q,)
    contrib = jnp.dot((w[:, None] * x).T, b,
                      preferred_element_type=jnp.float32)   # (P, N)
    state_scr[...] = jnp.exp(total) * state + contrib

    y = y + x * d
    y_ref[0, 0, 0] = y.astype(y_ref.dtype)

    @pl.when(ki == nk - 1)
    def _emit_state():
        fs_ref[0, 0] = state_scr[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_kernel(x: jnp.ndarray, dt: jnp.ndarray, a: jnp.ndarray,
                    b: jnp.ndarray, c: jnp.ndarray,
                    d_skip: Optional[jnp.ndarray] = None,
                    chunk: int = 64, interpret: bool = True
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Same contract as ref.ssd_reference (init_state=None).

    x: (B,S,H,P); dt: (B,S,H); a: (H,); b,c: (B,S,H,N)."""
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    assert s % chunk == 0
    k = s // chunk
    d = d_skip if d_skip is not None else jnp.zeros((h,), jnp.float32)

    # (B, H, K, Q, ...)
    xt = jnp.moveaxis(x, 2, 1).reshape(bsz, h, k, chunk, p)
    dtt = jnp.moveaxis(dt, 2, 1).reshape(bsz, h, k, chunk)
    bt = jnp.moveaxis(b, 2, 1).reshape(bsz, h, k, chunk, n)
    ct = jnp.moveaxis(c, 2, 1).reshape(bsz, h, k, chunk, n)

    y, fs = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=(bsz, h, k),
        in_specs=[
            pl.BlockSpec((1, 1, 1, chunk, p),
                         lambda bi, hi, ki: (bi, hi, ki, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk),
                         lambda bi, hi, ki: (bi, hi, ki, 0)),
            pl.BlockSpec((1,), lambda bi, hi, ki: (hi,)),
            pl.BlockSpec((1, 1, 1, chunk, n),
                         lambda bi, hi, ki: (bi, hi, ki, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk, n),
                         lambda bi, hi, ki: (bi, hi, ki, 0, 0)),
            pl.BlockSpec((1,), lambda bi, hi, ki: (hi,)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, chunk, p),
                         lambda bi, hi, ki: (bi, hi, ki, 0, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, ki: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, h, k, chunk, p), x.dtype),
            jax.ShapeDtypeStruct((bsz, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xt, dtt, a, bt, ct, d)

    y = jnp.moveaxis(y.reshape(bsz, h, s, p), 1, 2)
    return y, fs
