"""Public wrapper for the SSD scan: Pallas kernel on TPU (interpret on
CPU when forced), chunked-jnp oracle otherwise."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import kernel as _kernel
from . import ref as _ref


def ssd_scan(x, dt, a, b, c, chunk: int = 64,
             d_skip: Optional[jnp.ndarray] = None,
             force_ref: bool = False,
             force_kernel: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    on_tpu = jax.default_backend() == "tpu"
    if force_ref or (not on_tpu and not force_kernel):
        return _ref.ssd_reference(x, dt, a, b, c, chunk=chunk,
                                  d_skip=d_skip)
    return _kernel.ssd_scan_kernel(x, dt, a, b, c, d_skip=d_skip,
                                   chunk=chunk, interpret=not on_tpu)
