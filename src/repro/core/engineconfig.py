"""Typed engine selection — resolved in exactly one place.

Engine choice used to be scattered: a ``fitmask_engine`` string kwarg
threaded through every torus/policy constructor, ``fleet_size``/
``fleet_engine``/``fleet_quorum``/``fleet_timeout`` kwargs on the eval
runner, the ``REPRO_FITMASK_ENGINE`` environment variable consulted
deep inside the registry, and a process-global ``set_default_engine``.
Each call site re-implemented the precedence order, and nothing typed
tied "which backend" to "how the fleet broker drives it".

:class:`EngineConfig` is the one value that carries both, and
:meth:`EngineConfig.resolve_name` is the **single** place the
precedence order lives:

    explicit ``engine`` field
      > :func:`set_default_engine` (process-wide programmatic default)
      > ``REPRO_FITMASK_ENGINE`` env var (**deprecated** alias — warns
        once per process)
      > ``"numpy"``

``repro.kernels.fitmask.ops`` delegates its historical
``set_default_engine``/``default_engine_name`` entry points here, so
the old spellings keep working while the logic exists once. This
module imports neither jax nor the engine registry at import time (the
registry is consulted lazily) so the numpy-purity of the host path is
preserved.
"""
from __future__ import annotations

import os
import warnings
from dataclasses import dataclass, replace
from typing import Optional, Union

ENGINE_ENV = "REPRO_FITMASK_ENGINE"

# Failover order (PR 9): when a compiled engine starts raising at
# runtime, the fleet broker degrades *down* this chain — each step
# strictly reduces the stack it depends on, ending at the pure-numpy
# host engine that cannot lose a backend. The chain is total over the
# compiled tiers; registry engines outside it (e.g. ``ref``) degrade
# straight to numpy.
FAILOVER_CHAIN = ("pallas", "jax", "numpy")


def failover_candidates(name: str) -> tuple:
    """Engines to try, in order, after ``name`` fails at runtime.
    Numpy is the floor (empty tuple — nothing left to fail over to);
    unknown names also return empty (a custom engine *instance* has no
    registry identity, so the broker never fails it over — errors
    propagate, preserving the historical contract)."""
    try:
        name = canonical_engine_name(name)
    except KeyError:
        return ()
    if name in FAILOVER_CHAIN:
        return FAILOVER_CHAIN[FAILOVER_CHAIN.index(name) + 1:]
    return ("numpy",)

# Process-wide programmatic default (the ``set_default_engine`` knob).
_default_engine: Optional[str] = None
# The env var warns once per process, not once per query.
_env_warned = False


def canonical_engine_name(name: str) -> str:
    """Alias-fold and validate an engine name against the registry.
    Raises ``KeyError`` (the registry's historical contract) on an
    unknown name."""
    from repro.kernels.fitmask import ops  # lazy: numpy-only either way
    name = ops._ALIASES.get(name, name)
    if name not in ops._REGISTRY:
        raise KeyError(f"unknown fitmask engine {name!r}; "
                       f"have {ops.available_engines()}")
    return name


def set_default_engine(name: Optional[str]) -> None:
    """Process-wide default engine (overrides the deprecated env var);
    ``None`` resets to env-var/``numpy`` resolution."""
    global _default_engine
    if name is not None:
        name = canonical_engine_name(name)
    _default_engine = name


def _env_engine() -> Optional[str]:
    """The deprecated ``REPRO_FITMASK_ENGINE`` escape hatch; warns on
    first use. An unknown value raises ``KeyError`` eagerly — a typo'd
    env var must not silently fall back to numpy."""
    env = os.environ.get(ENGINE_ENV, "").strip()
    if not env:
        return None
    global _env_warned
    if not _env_warned:
        warnings.warn(
            f"{ENGINE_ENV} is deprecated; pass "
            "EngineConfig(engine=...) (or engine=/fitmask_engine= "
            "kwargs) or call set_default_engine() instead",
            DeprecationWarning, stacklevel=3)
        _env_warned = True
    from repro.kernels.fitmask import ops
    name = ops._ALIASES.get(env, env)
    if name not in ops._REGISTRY:
        raise KeyError(f"{ENGINE_ENV}={env!r} names no engine; "
                       f"have {ops.available_engines()}")
    return name


def default_engine_name() -> str:
    """The registry's resolved default — ``EngineConfig().resolve_name()``."""
    if _default_engine is not None:
        return _default_engine
    return _env_engine() or "numpy"


@dataclass(frozen=True)
class EngineConfig:
    """One typed value for "which fitmask backend, driven how".

    ``engine``
        Registry name (``numpy``/``jax``/``pallas``/``ref`` or an
        alias). ``None`` defers to the process default / deprecated
        env var / ``numpy``.
    ``fleet_size`` / ``quorum`` / ``timeout`` / ``max_inflight``
        How the fleet/service layers drive the backend: simulators per
        broker and the broker's flush policy. ``"auto"`` defers to the
        engine-aware policy in ``repro.sim.fleet.Fleet``.
    """

    engine: Optional[str] = None
    fleet_size: Union[str, int, None] = "auto"
    quorum: Union[str, float, None] = "auto"
    timeout: Union[str, float, None] = "auto"
    max_inflight: Optional[int] = None

    @classmethod
    def coerce(cls, value) -> "EngineConfig":
        """Accept the spellings call sites already use: ``None`` (all
        defaults), a bare engine name string, or an EngineConfig."""
        if value is None:
            return cls()
        if isinstance(value, EngineConfig):
            return value
        if isinstance(value, str):
            return cls(engine=value)
        raise TypeError("engine selection must be None, an engine name "
                        f"or an EngineConfig, got {value!r}")

    def with_engine(self, name: Optional[str]) -> "EngineConfig":
        return replace(self, engine=name)

    # -- THE selection point ------------------------------------------
    def resolve_name(self) -> str:
        """Resolve to a concrete registry name. Explicit field first,
        then :func:`set_default_engine`, then the deprecated env var,
        then ``numpy``."""
        if self.engine is not None:
            return canonical_engine_name(self.engine)
        return default_engine_name()

    def get_engine(self):
        """The resolved :class:`~repro.kernels.fitmask.ops.FitmaskEngine`
        singleton."""
        from repro.kernels.fitmask import ops
        return ops.get_engine(self.resolve_name())

    def make_client(self):
        """Inline mask client for the resolved engine, or ``None`` for
        the numpy host integral-image path (which stays free of
        indirection — see ``repro.core.maskquery``)."""
        from .maskquery import resolve_mask_client
        return resolve_mask_client(self)

    def fleet_kwargs(self) -> dict:
        """Kwargs for ``repro.sim.fleet.Fleet``/``QueryBroker``."""
        kw = {"engine": self.engine, "quorum": self.quorum,
              "timeout": self.timeout}
        if self.max_inflight is not None:
            kw["max_inflight"] = self.max_inflight
        return kw
