"""Placement policies: FirstFit, Folding-only, Reconfig-only, RFold.

All four are evaluated in the paper (§4). Rotation is default behaviour
for every policy; folding and reconfiguration are the paper's two
techniques, and RFold composes them.

The sim contract:
  * ``can_ever_place(shape)`` — placeable on an EMPTY cluster? If not,
    the scheduler drops the job ("incompatible shape", counts against
    JCR) instead of head-of-line blocking forever.
  * ``try_place(job_id, shape)`` — attempt an allocation now; returns a
    ``Placement`` (with ring-quality metadata for the runtime model) or
    None if resources are currently insufficient.
  * ``release(job_id)`` — free the allocation.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from .folding import Fold, enumerate_folds, fold_links, verify_fold
from .geometry import Coord, Dims, JobShape, is_torus_neighbor, volume
from .reconfig import ReconfigPlan, ReconfigTorus
from .torus import StaticTorus, canon_link


def shape_key(shape: JobShape) -> Dims:
    """Canonical rotation-invariant key for a job shape.

    Every policy treats rotations of a shape as the same placement
    problem (rotation is default behaviour, §2), so feasibility — both
    ``can_ever_place`` and "does it fit the cluster *right now*" — is a
    function of the sorted extents only. Shared by the policies'
    admission cache and the simulator's backfill feasibility watermark.
    """
    return tuple(sorted(shape.dims, reverse=True))


@dataclass
class Placement:
    job_id: int
    shape: JobShape
    broken_rings: Tuple[int, ...]
    meta: dict = field(default_factory=dict)

    @property
    def rings_intact(self) -> bool:
        return not self.broken_rings


class PlacementPolicy:
    """Base class; owns its cluster model."""

    name = "base"

    def __init__(self) -> None:
        self._can_place_cache: Dict[Dims, bool] = {}

    # -- cluster state -------------------------------------------------
    @property
    def num_xpus(self) -> int:
        raise NotImplementedError

    @property
    def busy_xpus(self) -> int:
        raise NotImplementedError

    def utilization(self) -> float:
        return self.busy_xpus / self.num_xpus

    # -- scheduling API ------------------------------------------------
    def try_place(self, job_id: int, shape: JobShape) -> Optional[Placement]:
        raise NotImplementedError

    def release(self, job_id: int) -> None:
        raise NotImplementedError

    def can_ever_place(self, shape: JobShape) -> bool:
        key = shape_key(shape)
        hit = self._can_place_cache.get(key)
        if hit is None:
            hit = self._can_ever_place(shape)
            self._can_place_cache[key] = hit
        return hit

    def _can_ever_place(self, shape: JobShape) -> bool:
        fresh = self.empty_clone()
        return fresh.try_place(-1, shape) is not None

    def empty_clone(self) -> "PlacementPolicy":
        raise NotImplementedError


# ----------------------------------------------------------------------
# Static-torus policies
# ----------------------------------------------------------------------

class _StaticBase(PlacementPolicy):
    def __init__(self, dims: Dims = (16, 16, 16),
                 fitmask_engine: Optional[str] = None,
                 engine=None, mask_client=None):
        super().__init__()
        self.torus = StaticTorus(dims, fitmask_engine=fitmask_engine,
                                 engine=engine, mask_client=mask_client)

    def _candidate_boxes(self, folds) -> List[Dims]:
        """Distinct in-bounds fold boxes — one allocator step's fit-mask
        query set, declared up front so an accelerator fitmask engine
        answers them all in a single multi-box VMEM pass."""
        seen = set()
        for fold in folds:
            if all(b <= d for b, d in zip(fold.box, self.torus.dims)):
                seen.add(fold.box)
        return sorted(seen)

    @property
    def num_xpus(self) -> int:
        return self.torus.num_xpus

    @property
    def busy_xpus(self) -> int:
        return self.torus.busy_xpus

    def release(self, job_id: int) -> None:
        self.torus.release(job_id)

    def _wrap_for_box(self, box: Dims, origin: Coord):
        """Static torus: an axis has usable wrap-around for this job only
        when the box spans the full torus dimension."""
        return tuple(b == d for b, d in zip(box, self.torus.dims))

    def _commit_fold(self, job_id: int, fold: Fold, origin: Coord,
                     broken: Tuple[int, ...]) -> Placement:
        coords = []
        d0, d1, d2 = fold.job_dims
        for i in range(d0):
            for j in range(d1):
                for k in range(d2):
                    e = fold.embed((i, j, k))
                    coords.append(tuple(o + v for o, v in zip(origin, e)))
        # Links: ring edges that are physically realizable (direct or via
        # an available wrap link); broken closures consume no link. A
        # cut link (chaos layer) cannot be claimed — the ring routes
        # around it, so its axis joins the broken set (same 17% slowdown
        # the paper charges any broken ring).
        wrap = self._wrap_for_box(fold.box, origin)
        links = []
        cut = self.torus.cut_links
        extra_broken: set = set()
        for (u, v) in fold_links(fold, origin, self.torus.dims):
            if is_torus_neighbor(u, v, self.torus.dims, self.torus.wrap_flags()):
                # physical only if inside box or via full-span wrap
                direct = all(abs(a - b) <= 1 for a, b in zip(u, v))
                if direct or any(
                        wrap[ax] and abs(u[ax] - v[ax]) == self.torus.dims[ax] - 1
                        for ax in range(3)):
                    l = canon_link(u, v)
                    if cut and l in cut:
                        extra_broken.add(next(
                            ax for ax in range(3) if u[ax] != v[ax]))
                    else:
                        links.append(l)
        if extra_broken:
            broken = tuple(sorted(set(broken) | extra_broken))
        meta = {"fold": str(fold), "kind": fold.kind, "box": fold.box,
                "origin": origin, "broken_rings": broken}
        self.torus.commit(job_id, coords, links, meta)
        return Placement(job_id, JobShape(fold.job_dims), broken, meta)


class FirstFitPolicy(_StaticBase):
    """Paper baseline: contiguous box at the first free origin, rotations
    allowed, no ring guarantees (broken rings are recorded, not avoided)."""

    name = "firstfit"

    def empty_clone(self) -> "FirstFitPolicy":
        # Clones are throwaway feasibility probes: they inherit the
        # engine config but never the mask client (a brokered client
        # would park a query for a cluster nobody registered).
        return FirstFitPolicy(self.torus.dims,
                              engine=self.torus.engine_config)

    def try_place(self, job_id: int, shape: JobShape) -> Optional[Placement]:
        folds = [f for f in enumerate_folds(shape,
                                            max_dim=max(self.torus.dims),
                                            include_identity=True)
                 if f.kind == "identity"]
        self.torus.prefetch_boxes(self._candidate_boxes(folds))
        for fold in folds:
            if any(b > d for b, d in zip(fold.box, self.torus.dims)):
                continue
            origin = self.torus.find_free_box(fold.box)
            if origin is None:
                continue
            wrap = self._wrap_for_box(fold.box, origin)
            ok, broken = verify_fold(fold, wrap)
            if not ok:
                continue
            return self._commit_fold(job_id, fold, origin, tuple(broken))
        return None


class FoldingPolicy(_StaticBase):
    """Folding-only (static torus): evaluate every fold variant, prefer
    intact rings, then compact boxes; commit the first-fit origin."""

    name = "folding"

    def empty_clone(self) -> "FoldingPolicy":
        return FoldingPolicy(self.torus.dims,
                             engine=self.torus.engine_config)

    def try_place(self, job_id: int, shape: JobShape) -> Optional[Placement]:
        candidates = []
        folds = list(enumerate_folds(shape, max_dim=max(self.torus.dims)))
        self.torus.prefetch_boxes(self._candidate_boxes(folds))
        for fold in folds:
            if any(b > d for b, d in zip(fold.box, self.torus.dims)):
                continue
            origin = self.torus.find_free_box(fold.box)
            if origin is None:
                continue
            wrap = self._wrap_for_box(fold.box, origin)
            ok, broken = verify_fold(fold, wrap)
            if not ok:
                continue
            score = (len(broken), max(fold.box), volume(fold.box))
            candidates.append((score, fold, origin, tuple(broken)))
        if not candidates:
            return None
        candidates.sort(key=lambda t: t[0])
        _, fold, origin, broken = candidates[0]
        return self._commit_fold(job_id, fold, origin, broken)


# ----------------------------------------------------------------------
# Reconfigurable-torus policies
# ----------------------------------------------------------------------

class _ReconfigBase(PlacementPolicy):
    def __init__(self, num_xpus: int = 4096, cube_n: int = 4,
                 dedicate_chained: bool = False,
                 fitmask_engine: Optional[str] = None,
                 engine=None, mask_client=None):
        super().__init__()
        self.cluster = ReconfigTorus(num_xpus, cube_n,
                                     dedicate_chained=dedicate_chained,
                                     fitmask_engine=fitmask_engine,
                                     engine=engine, mask_client=mask_client)

    @property
    def num_xpus(self) -> int:
        return self.cluster.num_xpus

    @property
    def busy_xpus(self) -> int:
        return self.cluster.busy_xpus

    def release(self, job_id: int) -> None:
        self.cluster.release(job_id)

    def _folds(self, shape: JobShape) -> List[Fold]:
        raise NotImplementedError

    @staticmethod
    def _dedupe_rotations(folds: List[Fold]) -> List[Fold]:
        """Cubes are location-free behind the OCS crossbar, so folds whose
        boxes are rotations of each other produce identical plans; keep
        one representative per (kind, extent/wrap multiset)."""
        seen = set()
        out = []
        for f in folds:
            key = (f.kind, tuple(sorted(zip(f.box, f.wrap_required))))
            if key in seen:
                continue
            seen.add(key)
            out.append(f)
        return out

    offset_search = True
    # Parity escape hatch: route everything through the retained naive
    # engine (pure-python place_fold, clone-based can_ever_place).
    use_naive = False

    def try_place(self, job_id: int, shape: JobShape) -> Optional[Placement]:
        if self.use_naive:
            best: Optional[ReconfigPlan] = None
            for fold in self._folds(shape):
                plan = self.cluster.place_fold_naive(
                    fold, offset_search=self.offset_search)
                if plan is None:
                    continue
                if best is None or plan.score() < best.score():
                    best = plan
        elif shape.size > self.cluster.free_xpus:
            best = None  # every fold box has volume == job size
        else:
            # The batched plan-search engine: fold-level bound pruning
            # plus the per-fold pre-scored offset tables, all inside
            # the cluster model (repro.core.reconfig.plan_search).
            best = self.cluster.plan_search(
                self._folds(shape), offset_search=self.offset_search)
        if best is None:
            return None
        self.cluster.commit(job_id, best)
        meta = dict(self.cluster.alloc_meta[job_id])
        return Placement(job_id, shape, best.broken_rings, meta)

    def _can_ever_place(self, shape: JobShape) -> bool:
        """Empty-cluster feasibility without a clone or placement: a
        fold fits an empty cluster iff its extents are chainable and its
        minimal (offset-0) cube grid fits the cube budget — best-fit
        assignment cannot fail when every cube is free. Fold validity is
        wrap-independent (missing wrap only breaks rings, it never
        invalidates the embedding), so checking the offset-0 wrap flags
        is exact."""
        if self.use_naive:
            fresh = self.empty_clone()
            fresh.use_naive = True
            return fresh.try_place(-1, shape) is not None
        cl = self.cluster
        n = cl.cube_n
        for fold in self._folds(shape):
            if any(e > cl.max_extent for e in fold.box):
                continue
            if volume(tuple(-(-e // n) for e in fold.box)) > cl.num_cubes:
                continue
            wrap0 = tuple(e % n == 0 for e in fold.box)
            if verify_fold(fold, wrap0)[0]:  # type: ignore[arg-type]
                return True
        return False


class ReconfigPolicy(_ReconfigBase):
    """Reconfiguration-only: original shape (plus rotations) decomposed
    into corner-aligned cube pieces stitched by the OCS layer. Pieces
    are pinned to cube corners (no offset packing) — the naive baseline
    the paper contrasts against (its partial-cube fragmentation is the
    motivation for folding)."""

    name = "reconfig"
    offset_search = False

    def empty_clone(self) -> "ReconfigPolicy":
        return ReconfigPolicy(self.cluster.num_xpus, self.cluster.cube_n,
                              dedicate_chained=self.cluster.dedicate_chained,
                              engine=self.cluster.engine_config)

    def _folds(self, shape: JobShape) -> List[Fold]:
        return self._dedupe_rotations([
            f for f in enumerate_folds(shape, max_dim=self.cluster.max_extent)
            if f.kind == "identity"])


class RFoldPolicy(_ReconfigBase):
    """The paper's contribution: folding x reconfiguration, ranked by the
    fewest-cubes / fewest-OCS-links heuristic."""

    name = "rfold"

    def empty_clone(self) -> "RFoldPolicy":
        return RFoldPolicy(self.cluster.num_xpus, self.cluster.cube_n,
                           dedicate_chained=self.cluster.dedicate_chained,
                           engine=self.cluster.engine_config)

    def _folds(self, shape: JobShape) -> List[Fold]:
        return self._dedupe_rotations(
            enumerate_folds(shape, max_dim=self.cluster.max_extent))


class RFoldBestEffortPolicy(RFoldPolicy):
    """Beyond-paper (paper §5, "Revisiting best-effort placement"):
    when no contiguous/folded placement exists, start the job anyway on
    scattered free XPUs with a contention slowdown — worthwhile whenever
    the slowdown costs less than the queueing delay. The slowdown factor
    defaults to ~1.5, between the paper's measured 1.35 (one contending
    neighbour) and 1.95 (doubled load) on TPU v2 (§3.1)."""

    name = "rfold_be"

    def __init__(self, num_xpus: int = 4096, cube_n: int = 4,
                 dedicate_chained: bool = False,
                 scatter_slowdown: float = 1.5,
                 fitmask_engine: Optional[str] = None,
                 engine=None, mask_client=None):
        super().__init__(num_xpus, cube_n,
                         dedicate_chained=dedicate_chained,
                         fitmask_engine=fitmask_engine,
                         engine=engine, mask_client=mask_client)
        self.scatter_slowdown = scatter_slowdown

    def empty_clone(self) -> "RFoldBestEffortPolicy":
        return RFoldBestEffortPolicy(
            self.cluster.num_xpus, self.cluster.cube_n,
            dedicate_chained=self.cluster.dedicate_chained,
            scatter_slowdown=self.scatter_slowdown,
            engine=self.cluster.engine_config)

    def _can_ever_place(self, shape: JobShape) -> bool:
        if super()._can_ever_place(shape):
            return True
        if self.use_naive:
            return False  # the clone-based check already covered scatter
        # Scatter fallback on an empty cluster: every cell is free and
        # no cube is dedicated, so feasibility is just capacity.
        return shape.size <= self.num_xpus

    def try_place(self, job_id: int, shape: JobShape) -> Optional[Placement]:
        p = super().try_place(job_id, shape)
        if p is not None:
            return p
        cells = self.cluster.free_cells(limit=shape.size)
        if len(cells) < shape.size:
            return None
        self.cluster.commit_scatter(job_id, cells)
        meta = dict(self.cluster.alloc_meta[job_id])
        meta["slowdown_factor"] = self.scatter_slowdown
        return Placement(job_id, shape, broken_rings=(0, 1, 2), meta=meta)


POLICIES = {
    "firstfit": FirstFitPolicy,
    "folding": FoldingPolicy,
    "reconfig": ReconfigPolicy,
    "rfold": RFoldPolicy,
    "rfold_be": RFoldBestEffortPolicy,
}


def make_policy(name: str, **kw) -> PlacementPolicy:
    return POLICIES[name](**kw)
