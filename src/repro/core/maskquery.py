"""Request/response interface between torus models and fitmask engines.

The toruses used to call a resolved fitmask engine inline
(``engine.multibox(...)`` at the query site). That shape made every
simulator a private engine owner: batch-1 calls, one engine pass per
simulator per epoch, and the multi-box kernel's grid-batch axis (the
``B`` in ``(B, K, X, Y, Z)``) never saw more than one simulator's
occupancy. This module splits the call path into an explicit
request/response contract so a torus *submits* its per-epoch mask work
to whatever client is installed:

  * :class:`InlineMaskClient` — the default: answers immediately from
    one engine (exactly the old inline behaviour, same arrays).
  * ``repro.sim.fleet.QueryBroker`` — the fleet layer's client:
    blocks the submitting simulator, coalesces concurrent requests
    from many simulators, and answers them all with genuinely batched
    engine calls (grids stacked on the B axis, candidate boxes
    unioned on K).

The contract is deliberately tiny — the two primitives every policy
reduces to:

  ``multibox(occ, boxes) -> (B, K, X, Y, Z) integer/bool numpy``
      occ is a (B, X, Y, Z) bool grid batch; plane k is the full-grid
      fit mask of ``boxes[k]``, *nonzero where the box fits* (zero
      where it overhangs or cannot fit), in the *request's* box order.
      The dtype is the serving path's choice — classic engines return
      int32 0/1, the broker's bucketed flush path returns bool —
      so consumers test ``!= 0`` rather than comparing dtypes (both
      encodings carry identical truth values; parity-tested).
  ``free_counts(occ) -> (B,) int64 numpy``
      free cells per grid.

Both return host numpy arrays: callers index and cache them without
engine-specific conversions. Answers are a pure function of
``(occ[b], box)`` per plane, which is what makes any batching client
bit-exact with the inline path (see DESIGN.md §Fleet-batched eval).

The numpy *host* path (integral images built directly inside the
torus) is still represented by ``None`` — it is not an engine call
and stays free of this indirection unless a client is installed.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import numpy as np

Box = Tuple[int, int, int]


class MaskQueryClient:
    """The request/response contract a torus submits mask work to.

    ``host_free`` advertises that the backing engine computes on the
    host with cost linear in the number of boxes (numpy). Toruses use
    it to choose a *lazy* mask strategy (ask only for the shape in
    hand) instead of the prefetch-everything-seen strategy that
    amortizes dispatch on compiled engines."""

    host_free = False

    def multibox(self, occ, boxes: Sequence[Box]) -> np.ndarray:
        """(B, X, Y, Z) occupancy x K boxes -> (B, K, X, Y, Z) numpy,
        nonzero where the box fits (consumers test ``!= 0``)."""
        raise NotImplementedError

    def free_counts(self, occ) -> np.ndarray:
        """(B, X, Y, Z) occupancy -> (B,) int64 free-cell counts."""
        raise NotImplementedError


class InlineMaskClient(MaskQueryClient):
    """Answers requests immediately from one fitmask engine — the
    single-simulator path, byte-identical to the pre-client inline
    calls (it is the same engine invocation plus the same numpy
    conversion the call sites used to do)."""

    def __init__(self, engine):
        self.engine = engine
        self.host_free = bool(getattr(engine, "host_free", False))

    def multibox(self, occ, boxes: Sequence[Box]) -> np.ndarray:
        return np.asarray(self.engine.multibox(occ, boxes))

    def free_counts(self, occ) -> np.ndarray:
        return np.asarray(self.engine.free_counts(occ)).astype(np.int64)


# Inline clients are interned per engine instance: `client is` identity
# then doubles as "same backend as last epoch" in the torus caches
# (engines themselves are singletons in the registry).
_INLINE: Dict[int, InlineMaskClient] = {}


def resolve_mask_client(selection=None) -> Optional[InlineMaskClient]:
    """Resolve an engine selection to an inline client: ``None`` for
    the builtin numpy host path (which must stay free of indirection
    and jax imports), a cached :class:`InlineMaskClient` otherwise.
    ``selection`` is an engine name, an
    :class:`~repro.core.engineconfig.EngineConfig`, or ``None`` — all
    resolved through ``EngineConfig.resolve_name()``, the single
    selection point (set_default_engine > deprecated env var > numpy)."""
    from repro.core.engineconfig import EngineConfig
    from repro.kernels.fitmask import ops  # numpy-only at import time
    name = EngineConfig.coerce(selection).resolve_name()
    if name == "numpy":
        return None
    engine = ops.get_engine(name)
    client = _INLINE.get(id(engine))
    if client is None:
        client = _INLINE[id(engine)] = InlineMaskClient(engine)
    return client
