"""Structured topology events emitted by the cluster models.

The paper frames RFold as a *runtime* co-adapter: a job does not just
get XPUs, it gets a virtual topology (fold embedding + OCS wiring)
that the cluster sets up for it and tears down after it — and other
jobs' wiring can be affected when the OCS layer is re-chained. The
cluster models used to mutate silently, which was fine for batch
simulation but leaves a service nothing to push to connected clients.

``StaticTorus`` and ``ReconfigTorus`` now emit a
:class:`TopologyEvent` to registered listeners on every commit and
release. Emission is pure notification — listeners observe state, they
never change it — and costs one ``if`` when nobody listens, so the
batch-simulation hot path is untouched (parity-tested).

``reconfigured`` is the paper-relevant bit: True when the commit or
release changed OCS wiring (a multi-cube chain or a wrap-ring closure
through the switch layer), i.e. when a real deployment would push
``RECONFIG`` to affected jobs rather than just ``SETUP`` to the new
one. A static torus is hardwired, so it never sets it.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List

Listener = Callable[["TopologyEvent"], None]


@dataclass(frozen=True)
class TopologyEvent:
    """One committed topology change.

    ``kind``          — ``"setup"`` | ``"release"`` | ``"fault"`` |
                        ``"repair"``. The fault/repair kinds are emitted
                        by the chaos layer (``repro.sim.faults``) when
                        nodes, links or OCS ports fail or come back;
                        their ``job_id`` is ``-1`` (no owning job) and
                        ``detail`` carries the fault kind and targets.
    ``job_id``        — the job whose allocation changed.
    ``topology``      — ``"static"`` | ``"reconfig"``.
    ``reconfigured``  — OCS wiring changed (multi-cube chain or wrap
                        closure); always False on a static torus.
    ``detail``        — model-specific provenance (fold, box, cubes,
                        ocs_links, ...) — JSON-serializable scalars,
                        tuples and lists only.
    """

    kind: str
    job_id: int
    topology: str
    reconfigured: bool = False
    detail: Dict[str, Any] = field(default_factory=dict)


class EventLog:
    """Minimal listener: append every event (tests and debugging)."""

    def __init__(self) -> None:
        self.events: List[TopologyEvent] = []

    def __call__(self, ev: TopologyEvent) -> None:
        self.events.append(ev)


def emit(listeners: List[Listener], ev: TopologyEvent) -> None:
    """Deliver ``ev`` to every listener (exceptions propagate: a
    listener that throws is a programming error, not a condition the
    allocator should paper over)."""
    for fn in listeners:
        fn(ev)
