"""Static 3D torus occupancy model with link-exclusivity accounting.

The paper's central correctness property is that an allocation gives a
job *exclusive* XPUs and links (that is what "enforcing the job shape"
buys). We therefore track both node occupancy (a numpy grid — the hot
free-box search is delegated to the fitmask kernel wrapper) and link
ownership (a registry keyed by canonical link ids), and assert
exclusivity on every commit.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, field
from typing import Dict, FrozenSet, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from . import events as _events
from . import maskquery
from .engineconfig import EngineConfig
from .geometry import Coord, Dims, is_torus_neighbor, iter_box, volume

Link = Tuple[Coord, Coord]

# ``owner`` sentinel for a failed XPU: the cell is marked busy in the
# occupancy grid (so every fitmask engine naturally routes around it)
# but belongs to no job.
FAILED = -2


class FaultConflictError(RuntimeError):
    """A fault was injected into a resource still owned by a job.

    The orchestrator (``repro.sim.faults`` / the scheduler daemon) must
    evict victims *before* applying the fault to the model — this error
    is the defense-in-depth backstop that turns "silent corruption"
    into a loud failure."""


def canon_link(u: Coord, v: Coord) -> Link:
    return (u, v) if u <= v else (v, u)


@dataclass
class Allocation:
    """A committed placement.

    ``coords``  — the XPUs owned by the job (order is meaningful for
                  folded ring placements: it is the ring traversal).
    ``links``   — torus links owned by the job.
    ``meta``    — provenance: fold used, target box, cubes touched, etc.
    """

    job_id: int
    coords: Tuple[Coord, ...]
    links: FrozenSet[Link]
    meta: dict = field(default_factory=dict)

    @property
    def size(self) -> int:
        return len(self.coords)


def resolve_fitmask_engine(name: Optional[str]):
    """Resolve a fitmask engine selection for the placement hot path:
    ``None`` defers to the registry default (``REPRO_FITMASK_ENGINE``
    env var / ``set_default_engine``). Returns ``None`` for ``numpy`` —
    the builtin host integral-image fast path, which must stay free of
    jax imports — and the engine object otherwise."""
    client = maskquery.resolve_mask_client(name)
    return None if client is None else client.engine


class StaticTorus:
    """A D1×D2×D3 torus with full wrap-around on every axis whose size
    equals the torus dimension. Occupancy is a numpy bool grid.

    ``engine`` selects the free-box search backend — an
    :class:`~repro.core.engineconfig.EngineConfig`, a registry name, or
    None for the resolved default (``fitmask_engine`` is the retained
    legacy spelling): the default ``numpy`` engine keeps the host
    integral-image path; accelerator engines answer all candidate boxes
    of an epoch in one multi-box pass. ``mask_client`` injects a
    request/response client (e.g. the fleet broker) at construction —
    the post-hoc :meth:`set_mask_client` mutation is deprecated."""

    def __init__(self, dims: Dims, fitmask_engine: Optional[str] = None,
                 engine=None, mask_client=None, listeners=None):
        self.dims: Dims = tuple(int(d) for d in dims)  # type: ignore[assignment]
        self.engine_config = EngineConfig.coerce(
            engine if engine is not None else fitmask_engine)
        # Back-compat attribute: the raw engine selection (None = the
        # resolved registry default), as call sites historically read.
        self.fitmask_engine = self.engine_config.engine
        # Request/response client (repro.core.maskquery), injected at
        # construction. None: resolve per query from the engine config
        # (engine registry / numpy host path).
        self.mask_client: Optional[maskquery.MaskQueryClient] = mask_client
        # Topology-event listeners (repro.core.events): notified on
        # every commit/release so a scheduler service can push
        # SETUP/RELEASE messages. Empty list = zero-cost.
        self.listeners: List[_events.Listener] = list(listeners or [])
        self.occ = np.zeros(self.dims, dtype=bool)
        self.owner = np.full(self.dims, -1, dtype=np.int64)
        self.link_owner: Dict[Link, int] = {}
        self.allocations: Dict[int, Allocation] = {}
        # Fault state (chaos layer): failed XPUs are marked busy in
        # ``occ`` with ``owner == FAILED`` so the whole fitmask stack
        # avoids them without a second mask; cut links cannot be
        # claimed (the allocator routes the ring around them as an
        # extra broken axis).
        self.failed = np.zeros(self.dims, dtype=bool)
        self.num_failed = 0
        self.cut_links: set = set()
        # Occupancy epoch: bumped on every commit/release. Derived state
        # (integral image, per-box fit answers, busy count) is cached per
        # epoch so one allocator step reuses a single cumsum across all
        # fold-box queries. Direct writes to ``occ`` must be followed by
        # ``bump_epoch()``.
        self._epoch = 0
        self._busy = 0
        self._fit_epoch = -1
        self._fit_ii: Optional[np.ndarray] = None
        self._fit_origin: Dict[Dims, Optional[Coord]] = {}
        self._fit_count: Dict[Dims, int] = {}
        # Engine path: candidate boxes ever queried (the fold-box set
        # stabilizes after the first few jobs), and their per-epoch
        # full-grid fit masks — all filled by ONE multi-box pass.
        self._seen_boxes: set = set()
        self._box_masks: Dict[Dims, np.ndarray] = {}

    # ------------------------------------------------------------------
    def set_mask_client(self, client) -> None:
        """Deprecated: pass ``mask_client=`` to the constructor (or to
        ``make_policy``) instead. Delegates to the internal setter."""
        warnings.warn(
            "set_mask_client is deprecated; pass mask_client= to the "
            "StaticTorus/policy constructor", DeprecationWarning,
            stacklevel=2)
        self._set_mask_client(client)

    def _set_mask_client(self, client) -> None:
        """Swap the request/response mask client. With a client every
        mask query rides the engine path — *submitted* instead of
        computed inline — even when the registry default is the numpy
        host engine. ``None`` restores per-query engine resolution."""
        self.mask_client = client
        self._fit_epoch = -1   # cached masks belong to the old route

    def _resolve_client(self) -> Optional[maskquery.MaskQueryClient]:
        """The client this torus submits mask work to: the installed
        one, else the engine registry's inline client, else ``None``
        (the numpy host integral-image path below)."""
        if self.mask_client is not None:
            return self.mask_client
        return maskquery.resolve_mask_client(self.engine_config)

    def bump_epoch(self) -> None:
        """Invalidate cached occupancy-derived state (call after any
        direct mutation of ``occ``)."""
        self._epoch += 1
        self._busy = int(self.occ.sum())

    def _fit_state(self) -> None:
        """Roll the per-epoch caches. The host integral image itself is
        built lazily (:meth:`_host_ii`) so accelerator-engine runs never
        pay for a cumsum they won't read."""
        if self._fit_epoch != self._epoch:
            self._fit_ii = None
            self._fit_origin = {}
            self._fit_count = {}
            self._box_masks = {}
            self._fit_epoch = self._epoch

    def _host_ii(self) -> np.ndarray:
        from . import fitmask
        if self._fit_ii is None:
            self._fit_ii = fitmask.integral_image(self.occ)
        return self._fit_ii

    def _fit_mask_for(self, box: Dims) -> np.ndarray:
        """Full-grid bool fit mask for one box at the current epoch.
        With an accelerator engine, every box seen so far is answered
        by a single multi-box pass per epoch (one VMEM integral image
        shared across the whole candidate set); the numpy path extracts
        windows from the shared host integral image."""
        client = self._resolve_client()
        if client is None:
            from . import fitmask
            m = np.zeros(self.dims, dtype=bool)
            s = fitmask.window_sums_from_ii(self._host_ii(), box)
            if s.size:
                m[:s.shape[0], :s.shape[1], :s.shape[2]] = s == 0
            return m
        self._fit_state()  # epoch roll also resets _box_masks
        if box not in self._box_masks:
            # No prefetch declared this box: answer every seen-but-
            # uncomputed box in one pass (first miss of an epoch fills
            # the whole set; prefetched masks are never recomputed).
            self._seen_boxes.add(box)
            missing = sorted(b for b in self._seen_boxes
                             if b not in self._box_masks)
            out = client.multibox(self.occ[None], missing)[0]
            for k, b in enumerate(missing):
                self._box_masks[b] = out[k] != 0
        return self._box_masks[box]

    def prefetch_boxes(self, boxes) -> None:
        """Declare an allocator step's candidate boxes up front so an
        accelerator engine answers them all in one multi-box pass —
        exactly the step's missing boxes, not the historical union
        (stale candidates from other job shapes would only pad the K
        axis with work nobody reads this epoch). The numpy host path
        is already amortized by the shared integral image, so this is
        a no-op there."""
        client = self._resolve_client()
        if client is None:
            return
        self._fit_state()
        fresh = [tuple(int(v) for v in b) for b in boxes]
        self._seen_boxes.update(fresh)
        missing = sorted(b for b in set(fresh) if b not in self._box_masks)
        if missing:
            out = client.multibox(self.occ[None], missing)[0]
            for k, b in enumerate(missing):
                self._box_masks[b] = out[k] != 0

    # ------------------------------------------------------------------
    @property
    def num_xpus(self) -> int:
        return volume(self.dims)

    @property
    def busy_xpus(self) -> int:
        """XPUs owned by jobs (failed cells occupy the grid but are
        not *busy* — utilization dips, it does not lie)."""
        return self._busy - self.num_failed

    @property
    def free_xpus(self) -> int:
        """XPUs actually placeable right now (excludes failed cells)."""
        return self.num_xpus - self._busy

    def utilization(self) -> float:
        return self.busy_xpus / self.num_xpus

    def wrap_flags(self) -> Tuple[bool, bool, bool]:
        """A static torus has wrap-around links on every axis."""
        return (True, True, True)

    # ------------------------------------------------------------------
    def is_free(self, coords: Iterable[Coord]) -> bool:
        return not any(self.occ[c] for c in coords)

    def box_free(self, origin: Coord, box: Dims) -> bool:
        """Box fit without wrapping past the boundary."""
        if any(o + b > d for o, b, d in zip(origin, box, self.dims)):
            return False
        ox, oy, oz = origin
        a, b, c = box
        return not self.occ[ox:ox + a, oy:oy + b, oz:oz + c].any()

    def find_free_box(self, box: Dims) -> Optional[Coord]:
        """First (lexicographic) origin where an un-wrapped a×b×c box of
        free XPUs exists, or None. All queries at one occupancy epoch
        share a single integral image; repeated boxes are memoized."""
        box = tuple(int(b) for b in box)
        self._fit_state()
        if box not in self._fit_origin:
            m = self._fit_mask_for(box)
            if not m.any():
                self._fit_origin[box] = None
            else:
                flat = int(np.argmax(m))  # first True in C order
                self._fit_origin[box] = tuple(
                    int(v) for v in np.unravel_index(flat, m.shape))
        return self._fit_origin[box]

    def count_free_boxes(self, box: Dims) -> int:
        box = tuple(int(b) for b in box)
        self._fit_state()
        if box not in self._fit_count:
            self._fit_count[box] = int(self._fit_mask_for(box).sum())
        return self._fit_count[box]

    # ------------------------------------------------------------------
    def _links_for_box(self, origin: Coord, box: Dims) -> FrozenSet[Link]:
        """All internal links of a contiguous box, plus wrap-around links
        on axes where the box spans the full torus dimension."""
        links: set[Link] = set()
        ox, oy, oz = origin
        a, b, c = box
        for (x, y, z) in iter_box(origin, box):
            if x + 1 < ox + a:
                links.add(canon_link((x, y, z), (x + 1, y, z)))
            elif a == self.dims[0]:
                links.add(canon_link((ox, y, z), (x, y, z)))
            if y + 1 < oy + b:
                links.add(canon_link((x, y, z), (x, y + 1, z)))
            elif b == self.dims[1]:
                links.add(canon_link((x, oy, z), (x, y, z)))
            if z + 1 < oz + c:
                links.add(canon_link((x, y, z), (x, y, z + 1)))
            elif c == self.dims[2]:
                links.add(canon_link((x, y, oz), (x, y, z)))
        return frozenset(links)

    def links_for_ring(self, ring: Sequence[Coord]) -> FrozenSet[Link]:
        """Links used by an ordered ring of torus-neighbouring XPUs."""
        n = len(ring)
        links: set[Link] = set()
        wrap = self.wrap_flags()
        pairs = [(ring[i], ring[(i + 1) % n]) for i in range(n)] \
            if n > 2 else [(ring[0], ring[1])]
        for u, v in pairs:
            if not is_torus_neighbor(u, v, self.dims, wrap):
                raise ValueError(f"ring hop {u}->{v} is not a torus link")
            links.add(canon_link(u, v))
        return links

    # ------------------------------------------------------------------
    def commit(self, job_id: int, coords: Sequence[Coord],
               links: Iterable[Link], meta: Optional[dict] = None) -> Allocation:
        coords = tuple(coords)
        links = frozenset(links)
        if len(set(coords)) != len(coords):
            raise ValueError("duplicate XPUs in allocation")
        for c in coords:
            if self.occ[c]:
                raise ValueError(f"XPU {c} already owned by {self.owner[c]}")
        for l in links:
            if l in self.link_owner:
                raise ValueError(
                    f"link {l} already owned by job {self.link_owner[l]}")
            if l in self.cut_links:
                raise ValueError(f"link {l} is cut (fault injected)")
        for c in coords:
            self.occ[c] = True
            self.owner[c] = job_id
        for l in links:
            self.link_owner[l] = job_id
        self._epoch += 1
        self._busy += len(coords)
        alloc = Allocation(job_id, coords, links, dict(meta or {}))
        self.allocations[job_id] = alloc
        if self.listeners:
            _events.emit(self.listeners, _events.TopologyEvent(
                kind="setup", job_id=job_id, topology="static",
                detail={"num_xpus": len(coords),
                        "num_links": len(links), **alloc.meta}))
        return alloc

    def commit_box(self, job_id: int, origin: Coord, box: Dims,
                   meta: Optional[dict] = None) -> Allocation:
        coords = tuple(iter_box(origin, box))
        links = self._links_for_box(origin, box)
        m = {"kind": "box", "origin": origin, "box": box}
        m.update(meta or {})
        return self.commit(job_id, coords, links, m)

    def release(self, job_id: int) -> None:
        alloc = self.allocations.pop(job_id)
        for c in alloc.coords:
            self.occ[c] = False
            self.owner[c] = -1
        for l in alloc.links:
            del self.link_owner[l]
        self._epoch += 1
        self._busy -= len(alloc.coords)
        if self.listeners:
            _events.emit(self.listeners, _events.TopologyEvent(
                kind="release", job_id=job_id, topology="static",
                detail={"num_xpus": len(alloc.coords),
                        "num_links": len(alloc.links)}))

    # -- fault injection (chaos layer) ---------------------------------
    def jobs_on(self, coords: Iterable[Coord]) -> List[int]:
        """Job ids allocated on any of ``coords`` (fault victims),
        sorted for determinism."""
        return sorted({int(self.owner[tuple(c)]) for c in coords
                       if self.owner[tuple(c)] >= 0})

    def link_jobs(self, links: Iterable[Link]) -> List[int]:
        """Job ids owning any of ``links`` (link-cut victims)."""
        return sorted({self.link_owner[l] for l in links
                       if l in self.link_owner})

    def fail_nodes(self, coords: Iterable[Coord]) -> List[Coord]:
        """Mark XPUs failed. Returns the coords actually transitioned
        (already-failed cells are skipped — idempotent). Raises
        :class:`FaultConflictError` if any cell is still job-owned:
        the orchestrator must evict victims first."""
        applied: List[Coord] = []
        for c in coords:
            c = tuple(int(v) for v in c)
            if self.failed[c]:
                continue
            if self.owner[c] >= 0:
                raise FaultConflictError(
                    f"XPU {c} still owned by job {self.owner[c]}; "
                    "evict before failing")
            self.failed[c] = True
            self.occ[c] = True
            self.owner[c] = FAILED
            applied.append(c)
        if applied:
            self._epoch += 1
            self._busy += len(applied)
            self.num_failed += len(applied)
            if self.listeners:
                _events.emit(self.listeners, _events.TopologyEvent(
                    kind="fault", job_id=-1, topology="static",
                    detail={"fault": "node", "targets": applied}))
        return applied

    def repair_nodes(self, coords: Iterable[Coord]) -> List[Coord]:
        """Bring failed XPUs back. Repairing a never-failed cell is a
        no-op; returns the coords actually repaired."""
        applied: List[Coord] = []
        for c in coords:
            c = tuple(int(v) for v in c)
            if not self.failed[c]:
                continue
            self.failed[c] = False
            self.occ[c] = False
            self.owner[c] = -1
            applied.append(c)
        if applied:
            self._epoch += 1
            self._busy -= len(applied)
            self.num_failed -= len(applied)
            if self.listeners:
                _events.emit(self.listeners, _events.TopologyEvent(
                    kind="repair", job_id=-1, topology="static",
                    detail={"fault": "node", "targets": applied}))
        return applied

    def cut_link(self, u: Coord, v: Coord) -> bool:
        """Cut one torus link. Returns False if already cut (no-op).
        Raises :class:`FaultConflictError` if a job owns the link."""
        u = tuple(int(x) for x in u)
        v = tuple(int(x) for x in v)
        if not is_torus_neighbor(u, v, self.dims, self.wrap_flags()):
            raise ValueError(f"{u}->{v} is not a torus link")
        l = canon_link(u, v)
        if l in self.cut_links:
            return False
        if l in self.link_owner:
            raise FaultConflictError(
                f"link {l} still owned by job {self.link_owner[l]}; "
                "evict before cutting")
        self.cut_links.add(l)
        self._epoch += 1
        if self.listeners:
            _events.emit(self.listeners, _events.TopologyEvent(
                kind="fault", job_id=-1, topology="static",
                detail={"fault": "link", "targets": [l]}))
        return True

    def repair_link(self, u: Coord, v: Coord) -> bool:
        """Restore a cut link; no-op (False) if it was never cut."""
        l = canon_link(tuple(int(x) for x in u), tuple(int(x) for x in v))
        if l not in self.cut_links:
            return False
        self.cut_links.discard(l)
        self._epoch += 1
        if self.listeners:
            _events.emit(self.listeners, _events.TopologyEvent(
                kind="repair", job_id=-1, topology="static",
                detail={"fault": "link", "targets": [l]}))
        return True

    def link_failed(self, l: Link) -> bool:
        return l in self.cut_links

    # ------------------------------------------------------------------
    def check_invariants(self) -> None:
        """Exclusivity invariants (used by property tests)."""
        owned = np.zeros(self.dims, dtype=np.int64)
        for a in self.allocations.values():
            for c in a.coords:
                owned[c] += 1
        if (owned > 1).any():
            raise AssertionError("XPU double-booked")
        if (owned[self.failed] > 0).any():
            raise AssertionError("failed XPU owned by a job")
        if not (((owned == 1) | self.failed) == self.occ).all():
            raise AssertionError("occupancy grid out of sync")
        if not (self.owner[self.failed] == FAILED).all():
            raise AssertionError("failed cells must carry the FAILED owner")
        if self.num_failed != int(self.failed.sum()):
            raise AssertionError("failed counter out of sync")
        link_counts: Dict[Link, int] = {}
        for a in self.allocations.values():
            for l in a.links:
                link_counts[l] = link_counts.get(l, 0) + 1
        if any(v > 1 for v in link_counts.values()):
            raise AssertionError("link double-booked")
        if set(link_counts) != set(self.link_owner):
            raise AssertionError("link registry out of sync")
        if self._busy != int(self.occ.sum()):
            raise AssertionError("busy counter out of sync")
