"""Shape / coordinate algebra for 3D torus placement.

Everything here is plain-Python combinatorics used by the allocator; the
hot numeric path (free-box search over the occupancy grid) lives in
:mod:`repro.kernels.fitmask` and is wrapped by :mod:`repro.core.torus`.
"""
from __future__ import annotations

import functools
import itertools
from dataclasses import dataclass
from typing import Iterator, Sequence, Tuple

Coord = Tuple[int, int, int]
Dims = Tuple[int, int, int]


def volume(dims: Sequence[int]) -> int:
    out = 1
    for d in dims:
        out *= int(d)
    return out


def canonical(dims: Sequence[int]) -> Dims:
    """Sorted-descending canonical form of a shape (rotation class)."""
    a, b, c = sorted((int(d) for d in dims), reverse=True)
    return (a, b, c)


def rotations(dims: Sequence[int]) -> Tuple[Dims, ...]:
    """All distinct axis permutations (the paper treats rotation as a
    default behaviour of every placement policy, not as folding)."""
    seen = []
    for perm in itertools.permutations(tuple(int(d) for d in dims)):
        if perm not in seen:
            seen.append(perm)
    return tuple(seen)


def factorizations3(n: int, max_dim: int | None = None) -> Tuple[Dims, ...]:
    """All ordered (a, b, c) with a*b*c == n (optionally bounded)."""
    n = int(n)
    out = []
    for a in range(1, n + 1):
        if n % a:
            continue
        if max_dim is not None and a > max_dim:
            continue
        m = n // a
        for b in range(1, m + 1):
            if m % b:
                continue
            c = m // b
            if max_dim is not None and (b > max_dim or c > max_dim):
                continue
            out.append((a, b, c))
    return tuple(out)


def factor_pairs(n: int, max_dim: int | None = None) -> Tuple[Tuple[int, int], ...]:
    """All ordered (a, b) with a*b == n."""
    n = int(n)
    out = []
    for a in range(1, n + 1):
        if n % a:
            continue
        b = n // a
        if max_dim is not None and (a > max_dim or b > max_dim):
            continue
        out.append((a, b))
    return tuple(out)


def iter_box(origin: Coord, dims: Dims) -> Iterator[Coord]:
    ox, oy, oz = origin
    a, b, c = dims
    for x in range(a):
        for y in range(b):
            for z in range(c):
                yield (ox + x, oy + y, oz + z)


def wrap_coord(coord: Coord, torus_dims: Dims) -> Coord:
    return tuple(c % d for c, d in zip(coord, torus_dims))  # type: ignore[return-value]


def torus_delta(a: int, b: int, size: int, wrap: bool) -> int:
    """Minimal |a-b| along one axis, honouring wrap-around when present."""
    d = abs(a - b)
    if wrap:
        d = min(d, size - d)
    return d


def is_torus_neighbor(u: Coord, v: Coord, dims: Dims,
                      wrap: Tuple[bool, bool, bool]) -> bool:
    """True iff u and v are joined by a single torus link."""
    deltas = [torus_delta(a, b, s, w)
              for a, b, s, w in zip(u, v, dims, wrap)]
    return sorted(deltas) == [0, 0, 1]


@dataclass(frozen=True)
class JobShape:
    """A job's communication shape: product of rings of sizes dims.

    ``dims`` follows the paper's convention: ``4x6x1`` = four-way DP ×
    six-way TP. The number of dims > 1 classifies the job as 1D/2D/3D.
    """

    dims: Dims

    def __post_init__(self) -> None:
        if len(self.dims) != 3 or any(d < 1 for d in self.dims):
            raise ValueError(f"bad shape {self.dims}")

    @property
    def size(self) -> int:
        return volume(self.dims)

    @property
    def ndim(self) -> int:
        """1D/2D/3D classification per the paper (dims of size > 1)."""
        return max(1, sum(1 for d in self.dims if d > 1))

    @property
    def active_dims(self) -> Tuple[int, ...]:
        """Ring lengths > 1, descending (the communicating dimensions)."""
        act = tuple(sorted((d for d in self.dims if d > 1), reverse=True))
        return act if act else (1,)

    def rotations(self) -> Tuple[Dims, ...]:
        return rotations(self.dims)

    def __str__(self) -> str:  # pragma: no cover - cosmetic
        return "x".join(str(d) for d in self.dims)


def snake_order(dims2: Tuple[int, int]) -> Tuple[Tuple[int, int], ...]:
    """Boustrophedon order over an a×b grid (used by Hamiltonian cycles)."""
    a, b = dims2
    out = []
    for i in range(a):
        cols = range(b) if i % 2 == 0 else range(b - 1, -1, -1)
        for j in cols:
            out.append((i, j))
    return tuple(out)


@functools.lru_cache(maxsize=None)
def hamiltonian_cycle_2d(a: int, b: int) -> Tuple[Tuple[int, int], ...]:
    """Hamiltonian cycle of the a×b grid graph (requires a*b even,
    a, b >= 2). Returned as an ordered tuple of (i, j); consecutive
    entries (and last→first) are grid neighbours.

    Construction: pin column 0 as the "return rail"; snake through
    columns 1..b-1 across all rows, then come home down column 0.
    Needs ``a`` even when snaking rows (each row contributes one cell to
    the rail). We orient so the even dimension does the snaking.
    """
    if a < 2 or b < 2:
        raise ValueError("grid must be at least 2x2")
    if (a * b) % 2:
        raise ValueError("grid graphs are bipartite: no odd Hamiltonian cycle")
    if a % 2 == 0:
        # Snake rows over columns 1..b-1, rail = column 0.
        cyc = []
        for i in range(a):
            cols = range(1, b) if i % 2 == 0 else range(b - 1, 0, -1)
            for j in cols:
                cyc.append((i, j))
        for i in range(a - 1, -1, -1):
            cyc.append((i, 0))
        return tuple(cyc)
    # a odd => b must be even; transpose.
    cyc_t = hamiltonian_cycle_2d(b, a)
    return tuple((j, i) for (i, j) in cyc_t)


def hamiltonian_path_2d(b: int, c: int) -> Tuple[Tuple[int, int], ...]:
    """Row-major snake: Hamiltonian *path* of the b×c grid, any b,c >= 1,
    starting at (0, 0)."""
    return tuple(
        (i, j)
        for i in range(b)
        for j in (range(c) if i % 2 == 0 else range(c - 1, -1, -1))
    )


@functools.lru_cache(maxsize=None)
def hamiltonian_cycle_3d(dims: Dims) -> Tuple[Coord, ...]:
    """Hamiltonian cycle of an a×b×c box grid (even volume; at least two
    dims >= 2).

    Construction: orient so the X dimension is even; pair X-layers into
    2-layer slabs. Each slab 2×b×c is the prism over the b×c grid, which
    has a Hamiltonian cycle (snake path out on the lower layer, back on
    the upper). Adjacent slab cycles are then merged with a ladder-rung
    edge swap, yielding one cycle — valid for every even-volume box.
    """
    a, b, c = dims
    ones = sum(1 for d in dims if d == 1)
    if ones >= 2:
        raise ValueError("need at least a 2D box for a cycle")
    if (a * b * c) % 2:
        raise ValueError("odd volume: bipartite grid has no odd cycle")
    if ones == 1:
        # Degenerate to 2D in the plane of the non-1 dims.
        if a == 1:
            return tuple((0, i, j) for i, j in hamiltonian_cycle_2d(b, c))
        if b == 1:
            return tuple((i, 0, j) for i, j in hamiltonian_cycle_2d(a, c))
        return tuple((i, j, 0) for i, j in hamiltonian_cycle_2d(a, b))
    # Orient so the X dimension is even (always possible: volume even).
    if a % 2 == 0:
        pass
    elif b % 2 == 0:
        return tuple((x, y, z) for (y, x, z) in hamiltonian_cycle_3d((b, a, c)))
    else:
        return tuple((x, y, z) for (z, y, x) in hamiltonian_cycle_3d((c, b, a)))

    snake = hamiltonian_path_2d(b, c)  # S[0] == (0, 0), S[1] == (0, 1)
    # Adjacency map: vertex -> set of its two cycle neighbours.
    adj: dict[Coord, set[Coord]] = {}

    def _add_cycle(verts: Sequence[Coord]) -> None:
        n = len(verts)
        for i, v in enumerate(verts):
            adj.setdefault(v, set()).add(verts[(i + 1) % n])
            adj.setdefault(verts[(i + 1) % n], set()).add(v)

    def _swap(u1: Coord, v1: Coord, u2: Coord, v2: Coord) -> None:
        """Replace cycle edges (u1,v1),(u2,v2) with rungs (u1,u2),(v1,v2)."""
        adj[u1].remove(v1); adj[v1].remove(u1)
        adj[u2].remove(v2); adj[v2].remove(u2)
        adj[u1].add(u2); adj[u2].add(u1)
        adj[v1].add(v2); adj[v2].add(v1)

    for t in range(a // 2):
        lo, hi = 2 * t, 2 * t + 1
        slab = [(lo, y, z) for (y, z) in snake] + \
               [(hi, y, z) for (y, z) in reversed(snake)]
        _add_cycle(slab)
    for t in range(a // 2 - 1):
        # Merge slab t and t+1 via the rung at snake[0]/snake[1]: the
        # top layer of slab t traverses ...S[1],S[0] and the bottom
        # layer of slab t+1 traverses S[0],S[1]... — both are cycle
        # edges, and the two vertical links between the layers exist.
        (y0, z0), (y1, z1) = snake[0], snake[1]
        _swap((2 * t + 1, y0, z0), (2 * t + 1, y1, z1),
              (2 * t + 2, y0, z0), (2 * t + 2, y1, z1))
    # Walk the merged cycle.
    start: Coord = (0, 0, 0)
    cyc = [start]
    prev, cur = None, start
    while True:
        nxts = [n for n in adj[cur] if n != prev]
        nxt = nxts[0]
        if nxt == start:
            break
        cyc.append(nxt)
        prev, cur = cur, nxt
    if len(cyc) != a * b * c:
        raise AssertionError("cycle merge failed to cover the box")
    return tuple(cyc)


def cycle_is_valid(cycle: Sequence[Coord], dims: Dims,
                   wrap: Tuple[bool, bool, bool] = (False, False, False)) -> bool:
    """Check consecutive (and closing) entries are torus neighbours and
    all entries distinct."""
    n = len(cycle)
    if n < 2:
        return False
    if len(set(cycle)) != n:
        return False
    if n == 2:  # 2-ring = one duplex link
        return is_torus_neighbor(cycle[0], cycle[1], dims, wrap)
    return all(
        is_torus_neighbor(cycle[i], cycle[(i + 1) % n], dims, wrap)
        for i in range(n)
    )
