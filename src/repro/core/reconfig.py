"""Reconfigurable torus: hardwired N³ cubes stitched by OCS groups.

Model (paper §2 / §3.2, TPU-v4-like): the cluster is ``num_cubes``
hardwired N×N×N cubes. Each XPU has 6 ports; the two opposing ports at
the same face position connect to the same optical circuit switch, so a
cube face can either loop back onto itself (wrap-around) or chain to the
*same face position* of another cube. Consequences we model faithfully:

  * A job spanning cubes must use a **uniform corner offset** in every
    cube (the port-alignment constraint: face XPUs only connect to the
    corresponding XPU of the next cube).
  * Wrap-around links exist for a job dimension only when it spans a
    full chain of cubes (extent == k·N and offset 0 on that axis).
  * Only face XPUs can reach other cubes: a piece that crosses a cube
    boundary necessarily occupies the face cells there — free "core"
    XPUs behind occupied faces are unusable for multi-cube jobs.
  * The OCS layer is modelled as a full per-face-position crossbar
    (assumption noted in DESIGN.md): any free cube can occupy any
    position of the job's virtual cube grid.
  * **Cube ownership**: a cube chained into a multi-cube virtual torus
    has its face OCS wiring dedicated to that job — its leftover XPUs
    are *stranded* until the job completes. This is exactly the
    fragmentation the paper attributes to partially-used cubes ("it
    results in at least one partially used cube", §3.2), and what
    folding into fewer cubes avoids. A standalone cube keeps its
    loop-back wiring and behaves as a small static torus that several
    single-cube jobs may share.

Placement: decompose a fold's target box into per-cube pieces at a
uniform offset, assign physical cubes to grid positions (best-fit
packing), and score plans by the paper's heuristic — fewest cubes,
then fewest OCS links, then least new-cube fragmentation.
"""
from __future__ import annotations

import functools
import itertools
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from . import fitmask
from . import torus as _torus
from .folding import Fold, WrapFlags, verify_fold
from .geometry import Coord, Dims, volume

Slice3 = Tuple[Tuple[int, int], Tuple[int, int], Tuple[int, int]]  # half-open


@functools.lru_cache(maxsize=None)
def _offset_candidates_cached(extent: int, n: int) -> Tuple[int, ...]:
    ca = -(-extent // n)
    slack = ca * n - extent
    return tuple(range(0, slack + 1))


@functools.lru_cache(maxsize=None)
def _axis_spans(ext: int, off: int, n: int):
    """Per-cube spans of one axis at a corner offset: ((grid_i,
    (lo, hi), length), ...) — geometry only, cached forever."""
    spans = []
    lo_g, hi_g = off, off + ext
    for i in range(-(-hi_g // n)):
        lo = max(lo_g, i * n) - i * n
        hi = min(hi_g, (i + 1) * n) - i * n
        if hi > lo:
            spans.append((i, (lo, hi), hi - lo))
    return tuple(spans)


@functools.lru_cache(maxsize=131072)
def _pieces_cached(box: Dims, offsets: Coord, n: int):
    """Per-(box, offsets) span decomposition, computed once ever:
    (pieces_spec, best-fit assignment order, cube_grid). Geometry only —
    independent of occupancy."""
    spans = [_axis_spans(e, o, n) for e, o in zip(box, offsets)]
    pieces: List[Tuple[Coord, Slice3]] = []
    sizes: List[int] = []
    for ix, spx, lx in spans[0]:
        for iy, spy, ly in spans[1]:
            lxy = lx * ly
            for iz, spz, lz in spans[2]:
                pieces.append(((ix, iy, iz), (spx, spy, spz)))
                sizes.append(lxy * lz)
    cube_grid = tuple(ax_spans[-1][0] + 1 for ax_spans in spans)
    order = tuple(sorted(range(len(pieces)), key=lambda i: -sizes[i]))
    return tuple(pieces), order, cube_grid


@dataclass
class Piece:
    grid_pos: Coord          # position in the job's virtual cube grid
    cube_id: int             # physical cube assigned
    local: Slice3            # sub-block within the cube (half-open)

    @property
    def shape(self) -> Dims:
        return tuple(hi - lo for lo, hi in self.local)  # type: ignore

    @property
    def size(self) -> int:
        return volume(self.shape)


@dataclass
class ReconfigPlan:
    fold: Fold
    offsets: Coord                     # uniform corner offset per axis
    cube_grid: Dims                    # virtual cube-grid extents
    pieces: List[Piece]
    wrap: WrapFlags                    # wrap-around availability per axis
    broken_rings: Tuple[int, ...]      # job ring axes that cannot close
    num_ocs_links: int
    fresh_cubes: int                   # cubes that were previously empty

    @property
    def num_cubes(self) -> int:
        return len(self.pieces)

    def score(self) -> Tuple:
        """Paper heuristic: fewest cubes, then fewest OCS links; prefer
        plans with intact rings and less fresh-cube consumption."""
        return (len(self.broken_rings), self.num_cubes, self.num_ocs_links,
                self.fresh_cubes)


class ReconfigTorus:
    """Occupancy + placement over ``num_cubes`` reconfigurable cubes."""

    def __init__(self, num_xpus: int = 4096, cube_n: int = 4,
                 dedicate_chained: bool = False,
                 fitmask_engine: Optional[str] = None):
        if num_xpus % (cube_n ** 3):
            raise ValueError("num_xpus must be a multiple of cube volume")
        # Free-block search backend (repro.kernels.fitmask.ops registry).
        # None defers to REPRO_FITMASK_ENGINE / the registry default;
        # "numpy" keeps the pure-host path below.
        self.fitmask_engine = fitmask_engine
        # If True, a cube chained into a multi-cube job is exclusively
        # owned by it (strands leftover XPUs). Default False: the OCS is
        # per-face-position, so leftover sub-blocks stay usable — this
        # matches the paper's reported JCR/utilization bands best; the
        # dedicated variant is kept as an ablation (EXPERIMENTS.md).
        self.dedicate_chained = bool(dedicate_chained)
        self.cube_n = int(cube_n)
        self.num_cubes = num_xpus // (cube_n ** 3)
        # occupancy: (num_cubes, n, n, n)
        self.occ = np.zeros((self.num_cubes,) + (cube_n,) * 3, dtype=bool)
        # cube dedicated to a multi-cube job's virtual torus (-1 = no)
        self.dedicated = np.full(self.num_cubes, -1, dtype=np.int64)
        self.allocations: Dict[int, List[Piece]] = {}
        self.alloc_meta: Dict[int, dict] = {}
        # Occupancy epoch: bumped on every commit/release/scatter. All
        # occupancy-derived state consumed by ``place_fold`` is cached
        # per epoch and shared across every fold/offset query in one
        # allocator step. Direct writes to ``occ``/``dedicated`` must be
        # followed by ``bump_epoch()`` once any query has been issued.
        self._epoch = 0
        self._busy = 0
        self._cache_epoch = -1
        self._ii: Optional[np.ndarray] = None           # batched integral image
        self._free_cnt: Optional[np.ndarray] = None     # (C,) free cells/cube
        self._cube_empty: Optional[np.ndarray] = None   # (C,) bool
        self._order_key: Optional[np.ndarray] = None    # best-fit sort key
        self._block_masks: Dict[Slice3, np.ndarray] = {}
        self._sorted_cands: Dict[Tuple[Slice3, bool], np.ndarray] = {}
        # Engine path: piece shapes ever queried (stable after the first
        # few placements) and their per-epoch all-cube fit masks, filled
        # by one multi-box pass over the whole cube batch.
        self._seen_shapes: set = set()
        self._shape_masks: Dict[Dims, np.ndarray] = {}

    # ------------------------------------------------------------------
    def bump_epoch(self) -> None:
        """Invalidate cached occupancy-derived state (call after any
        direct mutation of ``occ``/``dedicated``)."""
        self._epoch += 1
        self._busy = int(self.occ.sum())

    def _derived(self) -> None:
        """Refresh per-epoch derived state: one batched integral image
        over all cubes plus per-cube free counts / best-fit sort keys."""
        if self._cache_epoch == self._epoch:
            return
        n3 = self.cube_n ** 3
        self._ii = fitmask.batched_integral_image(self.occ)
        self._free_cnt = n3 - self._ii[:, -1, -1, -1]
        self._cube_empty = self._free_cnt == n3
        # Best-fit ordering: least leftover first, non-empty cubes break
        # ties (the piece size shifts every key equally, so one key
        # serves all piece sizes); np.argmin's first-minimum rule becomes
        # a stable sort with index tiebreak.
        self._order_key = self._free_cnt * 2 + self._cube_empty
        self._block_masks = {}
        self._sorted_cands = {}
        self._shape_masks = {}
        self._cache_epoch = self._epoch

    # ------------------------------------------------------------------
    @property
    def num_xpus(self) -> int:
        return self.num_cubes * self.cube_n ** 3

    @property
    def busy_xpus(self) -> int:
        return self._busy

    def utilization(self) -> float:
        return self.busy_xpus / self.num_xpus

    @property
    def max_extent(self) -> int:
        """Largest placeable extent on one axis: a chain of all cubes."""
        return self.num_cubes * self.cube_n

    # ------------------------------------------------------------------
    def _offset_candidates(self, extent: int) -> List[int]:
        """Corner offsets on one axis that do not inflate the cube count
        beyond ceil(extent / n)."""
        return list(_offset_candidates_cached(extent, self.cube_n))

    def _pieces_for(self, box: Dims, offsets: Coord) -> List[Tuple[Coord, Slice3]]:
        """Virtual grid positions and per-cube local sub-blocks."""
        n = self.cube_n
        per_axis: List[List[Tuple[int, Tuple[int, int]]]] = []
        for ext, off in zip(box, offsets):
            spans = []
            lo_g, hi_g = off, off + ext
            ncubes = -(-hi_g // n)
            for i in range(ncubes):
                lo = max(lo_g, i * n) - i * n
                hi = min(hi_g, (i + 1) * n) - i * n
                if hi > lo:
                    spans.append((i, (lo, hi)))
            per_axis.append(spans)
        out = []
        for (ix, sx), (iy, sy), (iz, sz) in itertools.product(*per_axis):
            out.append(((ix, iy, iz), (sx, sy, sz)))
        return out

    def _block_free_mask(self, local: Slice3) -> np.ndarray:
        """Bool mask over cubes: sub-block ``local`` entirely free.
        Answered from the per-epoch batched integral image (numpy) or
        from the engine's per-epoch multi-box fit masks, and memoized
        per local slice (every fold/offset in a step reuses it)."""
        self._derived()
        m = self._block_masks.get(local)
        if m is None:
            engine = _torus.resolve_fitmask_engine(self.fitmask_engine)
            if engine is None:
                m = fitmask.block_free_from_ii(self._ii, local)
            else:
                shape = tuple(hi - lo for lo, hi in local)
                origin = tuple(lo for lo, _ in local)
                masks = self._shape_masks
                if shape not in masks:
                    # One multi-box pass answers every piece shape seen
                    # so far for ALL cubes of this epoch.
                    self._seen_shapes.add(shape)
                    shapes = sorted(self._seen_shapes)
                    out = np.asarray(engine.multibox(self.occ, shapes))
                    masks = self._shape_masks = {
                        s: out[:, k] != 0 for k, s in enumerate(shapes)}
                m = masks[shape][(slice(None),) + origin]
            self._block_masks[local] = m
        return m

    def _block_free_mask_naive(self, local: Slice3) -> np.ndarray:
        """Reference implementation (direct slice scan), retained for
        the parity tests."""
        (x0, x1), (y0, y1), (z0, z1) = local
        sub = self.occ[:, x0:x1, y0:y1, z0:z1]
        return ~sub.any(axis=(1, 2, 3))

    def _cands_for(self, local: Slice3, chained: bool) -> np.ndarray:
        """Cube ids eligible for a piece, pre-sorted by the best-fit key
        (stable, index tiebreak) — equivalent to np.argmin over the
        leftover key but computed once per (local, chained) per epoch."""
        self._derived()
        key = (local, chained)
        arr = self._sorted_cands.get(key)
        if arr is None:
            if chained:
                mask = self._cube_empty & (self.dedicated < 0)
            else:
                mask = self._block_free_mask(local) & (self.dedicated < 0)
            ids = np.nonzero(mask)[0]
            arr = ids[np.argsort(self._order_key[ids], kind="stable")]
            self._sorted_cands[key] = arr
        return arr

    @staticmethod
    def _ocs_links(box: Dims, offsets: Coord, cube_grid: Dims, n: int,
                   wrap: WrapFlags) -> int:
        """Inter-cube (OCS) links consumed: one per face-position at each
        cube-boundary crossing, plus wrap closures."""
        total = 0
        a, b, c = box
        cross_section = (b * c, a * c, a * b)
        for ax in range(3):
            crossings = cube_grid[ax] - 1
            if wrap[ax]:
                crossings += 1  # ring closure through the OCS
            total += crossings * cross_section[ax]
        return total

    # ------------------------------------------------------------------
    def place_fold(self, fold: Fold, offset_search: bool = True,
                   bound: Optional[Tuple] = None) -> Optional[ReconfigPlan]:
        """Best reconfiguration plan for one fold candidate, or None.

        ``offset_search=False`` pins every piece to the cube corner
        (offset 0) — the naive Reconfig baseline whose partial-cube
        fragmentation the paper criticises; RFold searches offsets as
        part of "virtually reconfiguring the topology to best match the
        shape".

        ``bound`` is an incumbent lexicographic score: only plans that
        strictly beat it are returned, and offsets whose optimistic
        score bound (exact broken/cubes/links, fresh=0) cannot beat the
        incumbent are skipped without running cube assignment. With
        ``bound=None`` the result equals :meth:`place_fold_naive`.
        """
        box = fold.box
        n = self.cube_n
        if any(ext > self.max_extent for ext in box):
            return None
        self._derived()
        cube_empty = self._cube_empty
        best: Optional[ReconfigPlan] = None
        single_cube = all(ext <= n for ext in box)
        # Port alignment only binds multi-cube chains; a single-cube job
        # is an ordinary within-cube box placement, so its offsets are
        # always searchable. The naive (Reconfig) baseline pins chained
        # pieces to the cube corner.
        if offset_search or single_cube:
            offset_space = itertools.product(
                *(_offset_candidates_cached(e, n) for e in box))
        else:
            offset_space = [(0, 0, 0)]
        for offsets in offset_space:
            # Everything needed to prune is arithmetic on (box, offsets):
            # cube grid, wrap flags, broken rings (memoized per fold) and
            # OCS links. The span decomposition is only fetched for
            # offsets that can still beat the incumbent.
            cube_grid = tuple(-(-(o + e) // n)
                              for o, e in zip(offsets, box))
            ncubes = volume(cube_grid)
            if ncubes > self.num_cubes:
                continue
            wrap = tuple(
                offsets[ax] == 0 and box[ax] == cube_grid[ax] * n
                for ax in range(3))
            valid, broken = verify_fold(fold, wrap)  # type: ignore[arg-type]
            if not valid:
                continue
            links = self._ocs_links(box, offsets, cube_grid, n,
                                    wrap)  # type: ignore[arg-type]
            incumbent = best.score() if best is not None else bound
            if incumbent is not None and \
                    (len(broken), ncubes, links, 0) >= incumbent:
                continue
            pieces_spec, order, cube_grid = _pieces_cached(box, offsets, n)
            multi = len(pieces_spec) > 1
            chained = multi and self.dedicate_chained
            taken: set = set()
            assignment: Dict[int, int] = {}
            ok = True
            for idx in order:
                local = pieces_spec[idx][1]
                chosen = -1
                for cid in self._cands_for(local, chained):
                    if cid not in taken:
                        chosen = int(cid)
                        break
                if chosen < 0:
                    ok = False
                    break
                assignment[idx] = chosen
                taken.add(chosen)
            if not ok:
                continue
            pieces = [Piece(pieces_spec[i][0], assignment[i],
                            pieces_spec[i][1]) for i in range(len(pieces_spec))]
            fresh = int(sum(cube_empty[p.cube_id] for p in pieces))
            plan = ReconfigPlan(
                fold=fold, offsets=offsets, cube_grid=cube_grid,  # type: ignore
                pieces=pieces, wrap=wrap,  # type: ignore[arg-type]
                broken_rings=tuple(broken),
                num_ocs_links=links, fresh_cubes=fresh)
            if incumbent is None or plan.score() < incumbent:
                best = plan
        return best

    def place_fold_naive(self, fold: Fold,
                         offset_search: bool = True) -> Optional[ReconfigPlan]:
        """Reference implementation of :meth:`place_fold` (pure-python
        offset loop, no caching/pruning). Retained as the parity oracle
        for the vectorized engine."""
        box = fold.box
        n = self.cube_n
        if any(ext > self.max_extent for ext in box):
            return None
        best: Optional[ReconfigPlan] = None
        cube_empty = ~self.occ.any(axis=(1, 2, 3))
        single_cube = all(ext <= n for ext in box)
        if offset_search or single_cube:
            offset_space = itertools.product(*(self._offset_candidates(e)
                                               for e in box))
        else:
            offset_space = [(0, 0, 0)]
        for offsets in offset_space:
            pieces_spec = self._pieces_for(box, offsets)
            cube_grid = tuple(
                max(p[0][ax] for p in pieces_spec) + 1 for ax in range(3))
            if volume(cube_grid) > self.num_cubes:
                continue
            multi = len(pieces_spec) > 1
            # Assign physical cubes: biggest pieces first, best-fit
            # (prefer partially-used cubes with least leftover).
            order = sorted(range(len(pieces_spec)),
                           key=lambda i: -volume(
                               tuple(hi - lo for lo, hi in pieces_spec[i][1])))
            free_cnt = (~self.occ).sum(axis=(1, 2, 3)).astype(np.int64)
            taken = np.zeros(self.num_cubes, dtype=bool)
            assignment: Dict[int, int] = {}
            ok = True
            for idx in order:
                _, local = pieces_spec[idx]
                if multi and self.dedicate_chained:
                    # chaining dedicates the cube: only fully-free,
                    # non-dedicated cubes are eligible
                    mask = cube_empty & (self.dedicated < 0) & ~taken
                else:
                    # per-face-position OCS: shareable; sub-block free
                    mask = (self._block_free_mask_naive(local)
                            & (self.dedicated < 0) & ~taken)
                if not mask.any():
                    ok = False
                    break
                cand = np.nonzero(mask)[0]
                piece_sz = volume(tuple(hi - lo for lo, hi in local))
                # best-fit: least leftover; among ties prefer non-empty cubes
                leftovers = free_cnt[cand] - piece_sz
                keys = leftovers * 2 + cube_empty[cand].astype(np.int64)
                chosen = int(cand[int(np.argmin(keys))])
                assignment[idx] = chosen
                taken[chosen] = True
            if not ok:
                continue
            wrap = tuple(
                offsets[ax] == 0 and box[ax] == cube_grid[ax] * n
                for ax in range(3))
            valid, broken = verify_fold(fold, wrap)  # type: ignore[arg-type]
            if not valid:
                continue
            pieces = [Piece(pieces_spec[i][0], assignment[i],
                            pieces_spec[i][1]) for i in range(len(pieces_spec))]
            fresh = int(sum(cube_empty[p.cube_id] for p in pieces))
            plan = ReconfigPlan(
                fold=fold, offsets=offsets, cube_grid=cube_grid,  # type: ignore
                pieces=pieces, wrap=wrap,  # type: ignore[arg-type]
                broken_rings=tuple(broken),
                num_ocs_links=self._ocs_links(box, offsets, cube_grid, n,
                                              wrap),  # type: ignore[arg-type]
                fresh_cubes=fresh)
            if best is None or plan.score() < best.score():
                best = plan
        return best

    # ------------------------------------------------------------------
    def commit(self, job_id: int, plan: ReconfigPlan) -> None:
        if job_id in self.allocations:
            raise ValueError(f"job {job_id} already allocated")
        multi = len(plan.pieces) > 1
        for p in plan.pieces:
            (x0, x1), (y0, y1), (z0, z1) = p.local
            blk = self.occ[p.cube_id, x0:x1, y0:y1, z0:z1]
            if blk.any():
                raise ValueError("sub-block no longer free at commit")
            if self.dedicated[p.cube_id] >= 0:
                raise ValueError("cube already dedicated at commit")
            if multi and self.dedicate_chained:
                if self.occ[p.cube_id].any():
                    raise ValueError("chained cube must be empty at commit")
                self.dedicated[p.cube_id] = job_id
            self.occ[p.cube_id, x0:x1, y0:y1, z0:z1] = True
        self._epoch += 1
        self._busy += sum(p.size for p in plan.pieces)
        self.allocations[job_id] = list(plan.pieces)
        self.alloc_meta[job_id] = {
            "fold": str(plan.fold), "kind": plan.fold.kind,
            "box": plan.fold.box, "cube_grid": plan.cube_grid,
            "offsets": plan.offsets, "wrap": plan.wrap,
            "broken_rings": plan.broken_rings,
            "num_cubes": plan.num_cubes, "ocs_links": plan.num_ocs_links,
        }

    def release(self, job_id: int) -> None:
        for p in self.allocations.pop(job_id):
            (x0, x1), (y0, y1), (z0, z1) = p.local
            self.occ[p.cube_id, x0:x1, y0:y1, z0:z1] = False
            if self.dedicated[p.cube_id] == job_id:
                self.dedicated[p.cube_id] = -1
            self._busy -= p.size
        self._epoch += 1
        self.alloc_meta.pop(job_id, None)

    # ------------------------------------------------------------------
    def free_cells(self, limit: int):
        """Up to ``limit`` free (cube_id, x, y, z) cells from
        non-dedicated cubes (best-effort scatter placement)."""
        out = []
        for cid in range(self.num_cubes):
            if self.dedicated[cid] >= 0:
                continue
            free = np.argwhere(~self.occ[cid])
            for (x, y, z) in free:
                out.append((cid, int(x), int(y), int(z)))
                if len(out) >= limit:
                    return out
        return out

    def commit_scatter(self, job_id: int, cells) -> None:
        """Best-effort non-contiguous allocation (paper §5): occupy the
        given cells as single-cell pieces (no shape/ring guarantee)."""
        if job_id in self.allocations:
            raise ValueError(f"job {job_id} already allocated")
        pieces = []
        for (cid, x, y, z) in cells:
            if self.occ[cid, x, y, z]:
                raise ValueError("cell busy at scatter commit")
            self.occ[cid, x, y, z] = True
            pieces.append(Piece((0, 0, 0), cid,
                                ((x, x + 1), (y, y + 1), (z, z + 1))))
        self._epoch += 1
        self._busy += len(pieces)
        self.allocations[job_id] = pieces
        self.alloc_meta[job_id] = {"kind": "scatter",
                                   "num_cubes": len({c[0] for c in cells})}

    # ------------------------------------------------------------------
    def check_invariants(self) -> None:
        ref = np.zeros_like(self.occ, dtype=np.int64)
        for pieces in self.allocations.values():
            for p in pieces:
                (x0, x1), (y0, y1), (z0, z1) = p.local
                ref[p.cube_id, x0:x1, y0:y1, z0:z1] += 1
        if (ref > 1).any():
            raise AssertionError("XPU double-booked across cubes")
        if not ((ref == 1) == self.occ).all():
            raise AssertionError("cube occupancy out of sync")
        ded = np.full(self.num_cubes, -1, dtype=np.int64)
        for jid, pieces in self.allocations.items():
            if len(pieces) > 1 and self.dedicate_chained:
                for p in pieces:
                    if ded[p.cube_id] != -1:
                        raise AssertionError("cube dedicated to two jobs")
                    ded[p.cube_id] = jid
        if not (ded == self.dedicated).all():
            raise AssertionError("dedication registry out of sync")
        if self._busy != int(self.occ.sum()):
            raise AssertionError("busy counter out of sync")
