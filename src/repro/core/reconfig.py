"""Reconfigurable torus: hardwired N³ cubes stitched by OCS groups.

Model (paper §2 / §3.2, TPU-v4-like): the cluster is ``num_cubes``
hardwired N×N×N cubes. Each XPU has 6 ports; the two opposing ports at
the same face position connect to the same optical circuit switch, so a
cube face can either loop back onto itself (wrap-around) or chain to the
*same face position* of another cube. Consequences we model faithfully:

  * A job spanning cubes must use a **uniform corner offset** in every
    cube (the port-alignment constraint: face XPUs only connect to the
    corresponding XPU of the next cube).
  * Wrap-around links exist for a job dimension only when it spans a
    full chain of cubes (extent == k·N and offset 0 on that axis).
  * Only face XPUs can reach other cubes: a piece that crosses a cube
    boundary necessarily occupies the face cells there — free "core"
    XPUs behind occupied faces are unusable for multi-cube jobs.
  * The OCS layer is modelled as a full per-face-position crossbar
    (assumption noted in DESIGN.md): any free cube can occupy any
    position of the job's virtual cube grid.
  * **Cube ownership**: a cube chained into a multi-cube virtual torus
    has its face OCS wiring dedicated to that job — its leftover XPUs
    are *stranded* until the job completes. This is exactly the
    fragmentation the paper attributes to partially-used cubes ("it
    results in at least one partially used cube", §3.2), and what
    folding into fewer cubes avoids. A standalone cube keeps its
    loop-back wiring and behaves as a small static torus that several
    single-cube jobs may share.

Placement: decompose a fold's target box into per-cube pieces at a
uniform offset, assign physical cubes to grid positions (best-fit
packing), and score plans by the paper's heuristic — fewest cubes,
then fewest OCS links, then least new-cube fragmentation.

The plan search is batched (see DESIGN.md §Batched reconfiguration
plan search): every (offset, cube-grid, wrap, OCS-link, broken-ring)
ingredient is occupancy-independent, so it is materialized once per
(fold, cube size) as numpy arrays, sorted by optimistic score prefix,
and the runtime loop only runs cube assignment for offsets that can
still beat the incumbent — visiting best-prefix-first makes the
score-bound prune a ``break``. ``place_fold_naive`` is the retained
pure-python oracle; parity is byte-identical by construction (both
searches return the feasible plan minimizing ``(score, offset
product index)``).
"""
from __future__ import annotations

import functools
import itertools
import warnings
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import events as _events
from . import fitmask
from .engineconfig import EngineConfig
from .folding import Fold, WrapFlags, verify_fold
from .geometry import Coord, Dims, volume
from .torus import FaultConflictError

Slice3 = Tuple[Tuple[int, int], Tuple[int, int], Tuple[int, int]]  # half-open


@functools.lru_cache(maxsize=None)
def _offset_candidates_cached(extent: int, n: int) -> Tuple[int, ...]:
    ca = -(-extent // n)
    slack = ca * n - extent
    return tuple(range(0, slack + 1))


@functools.lru_cache(maxsize=None)
def _axis_spans(ext: int, off: int, n: int):
    """Per-cube spans of one axis at a corner offset: ((grid_i,
    (lo, hi), length), ...) — geometry only, cached forever."""
    spans = []
    lo_g, hi_g = off, off + ext
    for i in range(-(-hi_g // n)):
        lo = max(lo_g, i * n) - i * n
        hi = min(hi_g, (i + 1) * n) - i * n
        if hi > lo:
            spans.append((i, (lo, hi), hi - lo))
    return tuple(spans)


@functools.lru_cache(maxsize=131072)
def _pieces_cached(box: Dims, offsets: Coord, n: int):
    """Per-(box, offsets) span decomposition, computed once ever:
    (pieces_spec, best-fit assignment order, cube_grid). Geometry only —
    independent of occupancy."""
    spans = [_axis_spans(e, o, n) for e, o in zip(box, offsets)]
    pieces: List[Tuple[Coord, Slice3]] = []
    sizes: List[int] = []
    for ix, spx, lx in spans[0]:
        for iy, spy, ly in spans[1]:
            lxy = lx * ly
            for iz, spz, lz in spans[2]:
                pieces.append(((ix, iy, iz), (spx, spy, spz)))
                sizes.append(lxy * lz)
    cube_grid = tuple(ax_spans[-1][0] + 1 for ax_spans in spans)
    order = tuple(sorted(range(len(pieces)), key=lambda i: -sizes[i]))
    return tuple(pieces), order, cube_grid


@functools.lru_cache(maxsize=131072)
def _offset_table_cached(box: Dims, n: int):
    """Occupancy-independent plan ingredients for every candidate corner
    offset of ``box`` at cube size ``n``, vectorized over the whole
    offset product (rows in ``itertools.product`` order): offsets
    (O, 3), cube grids (O, 3), cube counts (O,), OCS links (O,) and a
    3-bit per-row wrap code."""
    cands = [_offset_candidates_cached(e, n) for e in box]
    offs = np.array(list(itertools.product(*cands)),
                    dtype=np.int64).reshape(-1, 3)
    ext = np.asarray(box, dtype=np.int64)
    cube_grid = -(-(offs + ext) // n)
    ncubes = cube_grid.prod(axis=1)
    wrap = (offs == 0) & (ext[None, :] == cube_grid * n)
    a, b, c = box
    cross = np.array([b * c, a * c, a * b], dtype=np.int64)
    links = ((cube_grid - 1 + wrap) * cross).sum(axis=1)
    wrapcode = wrap[:, 0] * 4 + wrap[:, 1] * 2 + wrap[:, 2]
    return offs, ncubes, links, wrapcode


@dataclass
class _FoldPlanTable:
    """One fold's valid offset candidates at a fixed (cube size, cube
    budget), pre-sorted by optimistic score prefix ``(broken rings,
    cubes, OCS links)`` with the offset product index as the stable
    tiebreak — so a runtime search that walks rows in order and stops
    at the first row whose prefix cannot beat the incumbent reproduces
    the naive product-order scan exactly."""

    offsets: List[Coord]
    offs_arr: np.ndarray           # (O, 3) int64 — the same rows, batched
    ncubes: np.ndarray
    links: np.ndarray
    nbroken: np.ndarray
    broken: List[Tuple[int, ...]]
    wrap: List[WrapFlags]
    pinned_pos: Optional[int]      # row with offsets == (0, 0, 0), if valid
    # The same prefix columns as plain-int lists: the runtime loop
    # compares one row per iteration and python ints beat numpy
    # scalars there.
    prefix: List[Tuple[int, int, int]] = None  # type: ignore[assignment]

    def __post_init__(self):
        self.prefix = list(zip(self.nbroken.tolist(), self.ncubes.tolist(),
                               self.links.tolist()))


def fold_plan_table(fold: Fold, n: int,
                    num_cubes: int) -> Optional[_FoldPlanTable]:
    """Memoized per fold instance (folds are immutable and themselves
    memoized per shape, so tables are computed once per process)."""
    cache = getattr(fold, "_plan_table_cache", None)
    if cache is None:
        cache = {}
        object.__setattr__(fold, "_plan_table_cache", cache)
    key = (n, num_cubes)
    if key not in cache:
        cache[key] = _build_plan_table(fold, n, num_cubes)
    return cache[key]


def _build_plan_table(fold: Fold, n: int,
                      num_cubes: int) -> Optional[_FoldPlanTable]:
    offs, ncubes, links, wrapcode = _offset_table_cached(fold.box, n)
    keep = ncubes <= num_cubes
    if not keep.any():
        return None
    # Fold validity / broken rings depend only on the wrap flags: 8
    # possible codes, each certified once (and memoized on the fold).
    ok8 = np.zeros(8, dtype=bool)
    nb8 = np.zeros(8, dtype=np.int64)
    br8: List[Tuple[int, ...]] = [()] * 8
    for code in np.unique(wrapcode[keep]):
        w = (bool(code & 4), bool(code & 2), bool(code & 1))
        valid, br = verify_fold(fold, w)
        ok8[code], nb8[code], br8[code] = valid, len(br), tuple(br)
    rows = np.nonzero(keep & ok8[wrapcode])[0]
    if not rows.size:
        return None
    nbroken = nb8[wrapcode[rows]]
    order = np.lexsort((rows, links[rows], ncubes[rows], nbroken))
    rows = rows[order]
    offsets = [tuple(int(v) for v in offs[r]) for r in rows]
    pinned = next((i for i, o in enumerate(offsets) if o == (0, 0, 0)),
                  None)
    return _FoldPlanTable(
        offsets=offsets, offs_arr=offs[rows],
        ncubes=ncubes[rows], links=links[rows], nbroken=nbroken[order],
        broken=[br8[wrapcode[r]] for r in rows],
        wrap=[(bool(c & 4), bool(c & 2), bool(c & 1))
              for c in wrapcode[rows]],
        pinned_pos=pinned)


def fold_score_bound(fold: Fold, n: int) -> Tuple:
    """Optimistic lexicographic score bound for a fold, computed
    without placing it: the minimal broken-ring count (wrap on every
    axis whose extent admits it — wrap availability only ever shrinks
    the broken set), the minimal cube count (offset 0), the minimal
    OCS links (wrap only where the extent forces it), zero fresh
    cubes. Lower-bounds every plan the fold can produce, so a fold
    whose bound loses to the incumbent is skipped without placing."""
    cache = getattr(fold, "_bound_cache", None)
    if cache is None:
        cache = {}
        object.__setattr__(fold, "_bound_cache", cache)
    hit = cache.get(n)
    if hit is None:
        a, b, c = fold.box
        cross = (b * c, a * c, a * b)
        ca = tuple(-(-e // n) for e in fold.box)
        links = sum(
            (ca[ax] - 1 + (1 if fold.box[ax] == ca[ax] * n else 0))
            * cross[ax] for ax in range(3))
        wrap_max = tuple(e % n == 0 for e in fold.box)
        _, broken_min = verify_fold(fold, wrap_max)  # type: ignore[arg-type]
        hit = (len(broken_min), volume(ca), links, 0)
        cache[n] = hit
    return hit


@dataclass
class Piece:
    grid_pos: Coord          # position in the job's virtual cube grid
    cube_id: int             # physical cube assigned
    local: Slice3            # sub-block within the cube (half-open)

    @property
    def shape(self) -> Dims:
        return tuple(hi - lo for lo, hi in self.local)  # type: ignore

    @property
    def size(self) -> int:
        return volume(self.shape)


@dataclass
class ReconfigPlan:
    fold: Fold
    offsets: Coord                     # uniform corner offset per axis
    cube_grid: Dims                    # virtual cube-grid extents
    pieces: List[Piece]
    wrap: WrapFlags                    # wrap-around availability per axis
    broken_rings: Tuple[int, ...]      # job ring axes that cannot close
    num_ocs_links: int
    fresh_cubes: int                   # cubes that were previously empty

    @property
    def num_cubes(self) -> int:
        return len(self.pieces)

    def score(self) -> Tuple:
        """Paper heuristic: fewest cubes, then fewest OCS links; prefer
        plans with intact rings and less fresh-cube consumption."""
        return (len(self.broken_rings), self.num_cubes, self.num_ocs_links,
                self.fresh_cubes)


class ReconfigTorus:
    """Occupancy + placement over ``num_cubes`` reconfigurable cubes."""

    def __init__(self, num_xpus: int = 4096, cube_n: int = 4,
                 dedicate_chained: bool = False,
                 fitmask_engine: Optional[str] = None,
                 engine=None, mask_client=None, listeners=None):
        if num_xpus % (cube_n ** 3):
            raise ValueError("num_xpus must be a multiple of cube volume")
        # Free-block search backend: an EngineConfig / registry name /
        # None for the resolved default (``fitmask_engine`` is the
        # retained legacy spelling); "numpy" keeps the pure-host path.
        self.engine_config = EngineConfig.coerce(
            engine if engine is not None else fitmask_engine)
        self.fitmask_engine = self.engine_config.engine
        # Request/response client (repro.core.maskquery), injected at
        # construction; the fleet layer points many clusters at one
        # shared query broker.
        self.mask_client = mask_client
        # Topology-event listeners (repro.core.events): notified on
        # every commit/release; OCS-wiring changes (multi-cube chains,
        # wrap closures) are flagged ``reconfigured`` so a scheduler
        # service can push RECONFIG. Empty list = zero-cost.
        self.listeners: List[_events.Listener] = list(listeners or [])
        # If True, a cube chained into a multi-cube job is exclusively
        # owned by it (strands leftover XPUs). Default False: the OCS is
        # per-face-position, so leftover sub-blocks stay usable — this
        # matches the paper's reported JCR/utilization bands best; the
        # dedicated variant is kept as an ablation (EXPERIMENTS.md).
        self.dedicate_chained = bool(dedicate_chained)
        self.cube_n = int(cube_n)
        self.num_cubes = num_xpus // (cube_n ** 3)
        # occupancy: (num_cubes, n, n, n)
        self.occ = np.zeros((self.num_cubes,) + (cube_n,) * 3, dtype=bool)
        # cube dedicated to a multi-cube job's virtual torus (-1 = no)
        self.dedicated = np.full(self.num_cubes, -1, dtype=np.int64)
        self.allocations: Dict[int, List[Piece]] = {}
        self.alloc_meta: Dict[int, dict] = {}
        # Fault state (chaos layer): failed cells are marked busy in
        # ``occ`` so every fit mask routes around them; ``ocs_ok``
        # tracks per-cube OCS-port health — a cube with a dead port is
        # detached from the switch fabric, so it cannot join any
        # placement that needs OCS wiring (multi-cube chains or
        # wrap-ring closures) but still hosts OCS-free sub-blocks.
        self.failed = np.zeros(self.occ.shape, dtype=bool)
        self.num_failed = 0
        self.ocs_ok = np.ones(self.num_cubes, dtype=bool)
        # Occupancy epoch: bumped on every commit/release/scatter. All
        # occupancy-derived state consumed by ``place_fold`` is cached
        # per epoch and shared across every fold/offset query in one
        # allocator step. Place/release record which cubes they touched
        # so the next refresh updates only those rows; direct writes to
        # ``occ``/``dedicated`` must be followed by ``bump_epoch()``
        # once any query has been issued (full rebuild).
        self._epoch = 0
        self._busy = 0
        self._cache_epoch = -1
        self._dirty: Optional[set] = None               # None = rebuild all
        self._engine = None           # mask client resolved per refresh
        self._ii: Optional[np.ndarray] = None           # batched integral image
        self._free_cnt: Optional[np.ndarray] = None     # (C,) free cells/cube
        self._cube_empty: Optional[np.ndarray] = None   # (C,) bool
        self._order_key: Optional[np.ndarray] = None    # best-fit sort key
        self._global_order: Optional[np.ndarray] = None  # stable key argsort
        self._elig_order: Optional[np.ndarray] = None    # ...non-dedicated
        self._sorted_cands: Dict[Tuple[Slice3, bool, bool], List[int]] = {}
        # Per-epoch full-grid fit masks per sub-block shape (the shape
        # set stabilizes after the first few placements). On an engine,
        # all shapes seen so far are filled by one multi-box pass over
        # the whole cube batch; the host path extracts each from the
        # shared batched integral image.
        self._seen_shapes: set = set()
        self._shape_masks: Dict[Dims, np.ndarray] = {}

    # ------------------------------------------------------------------
    def set_mask_client(self, client) -> None:
        """Deprecated: pass ``mask_client=`` to the constructor (or to
        ``make_policy``) instead. Delegates to the internal setter."""
        warnings.warn(
            "set_mask_client is deprecated; pass mask_client= to the "
            "ReconfigTorus/policy constructor", DeprecationWarning,
            stacklevel=2)
        self._set_mask_client(client)

    def _set_mask_client(self, client) -> None:
        """Swap the request/response mask client: every sub-block
        freeness / free-count query is *submitted* to it instead of
        computed inline, even when the registry default is the numpy
        host engine. ``None`` restores per-query engine resolution."""
        self.mask_client = client
        self._cache_epoch = -1     # cached masks belong to the old route
        self._dirty = None

    def _resolve_client(self):
        """The client this cluster submits mask work to (None = the
        numpy host integral-image path)."""
        if self.mask_client is not None:
            return self.mask_client
        from .maskquery import resolve_mask_client
        return resolve_mask_client(self.engine_config)

    def bump_epoch(self) -> None:
        """Invalidate cached occupancy-derived state (call after any
        direct mutation of ``occ``/``dedicated``)."""
        self._epoch += 1
        self._dirty = None          # unknown mutation: rebuild everything
        self._busy = int(self.occ.sum())

    def _mark_dirty(self, cubes) -> None:
        """Start a new occupancy epoch, remembering which cubes changed
        so the refresh is incremental."""
        self._epoch += 1
        if self._dirty is not None:
            self._dirty.update(cubes)

    def _derived(self) -> None:
        """Refresh per-epoch derived state: per-cube free counts and
        best-fit sort keys, plus the batched integral image on the host
        path (an accelerator engine answers both sub-block freeness and
        free counts itself — no host integral image is ever built).
        When only a few cubes changed since the last refresh (tracked
        by place/release), just those rows are recomputed."""
        if self._cache_epoch == self._epoch:
            return
        n3 = self.cube_n ** 3
        client = self._resolve_client()
        dirty = self._dirty
        partial = (dirty is not None and self._cache_epoch >= 0
                   and client is self._engine
                   and len(dirty) * 4 <= self.num_cubes)
        if partial:
            d = np.fromiter(dirty, dtype=np.int64, count=len(dirty))
            d.sort()
            if d.size:
                if client is None:
                    self._ii[d] = fitmask.integral_image(self.occ[d])
                    self._free_cnt[d] = n3 - self._ii[d, -1, -1, -1]
                    for s, m in self._shape_masks.items():
                        m[d] = False
                        w = fitmask.window_sums_from_ii(self._ii[d], s)
                        if w.size:
                            m[d, :w.shape[1], :w.shape[2], :w.shape[3]] = \
                                w == 0
                else:
                    self._free_cnt[d] = client.free_counts(self.occ[d])
                    if self._shape_masks:
                        shapes = sorted(self._shape_masks)
                        out = client.multibox(self.occ[d], shapes)
                        for k, s in enumerate(shapes):
                            self._shape_masks[s][d] = out[:, k] != 0
                self._cube_empty[d] = self._free_cnt[d] == n3
        else:
            if client is None:
                self._ii = fitmask.batched_integral_image(self.occ)
                self._free_cnt = n3 - self._ii[:, -1, -1, -1]
            else:
                self._ii = None
                self._free_cnt = client.free_counts(self.occ)
            self._cube_empty = self._free_cnt == n3
            self._shape_masks = {}
        # Best-fit ordering: least leftover first, non-empty cubes break
        # ties (the piece size shifts every key equally, so one key
        # serves all piece sizes); np.argmin's first-minimum rule becomes
        # a stable sort with index tiebreak.
        self._order_key = self._free_cnt * 2 + self._cube_empty
        self._global_order = np.argsort(self._order_key, kind="stable")
        # Eligible non-empty cubes: any plan on nc cubes strands at
        # least nc - this many fresh (previously empty) cubes — the
        # per-row fresh lower bound the search prunes with.
        self._n_nonempty_elig = int(
            (~self._cube_empty & (self.dedicated < 0)).sum())
        self._elig_order = None
        self._engine = client
        self._sorted_cands = {}
        self._dirty = set()
        self._cache_epoch = self._epoch

    def _eligible_order(self) -> np.ndarray:
        """Non-dedicated cube ids in best-fit order (the per-epoch
        stable key argsort filtered to eligible cubes)."""
        if self._elig_order is None:
            go = self._global_order
            self._elig_order = go[(self.dedicated < 0)[go]]
        return self._elig_order

    # ------------------------------------------------------------------
    @property
    def num_xpus(self) -> int:
        return self.num_cubes * self.cube_n ** 3

    @property
    def busy_xpus(self) -> int:
        """XPUs owned by jobs (failed cells occupy the grid but are
        not *busy* — utilization dips, it does not lie)."""
        return self._busy - self.num_failed

    @property
    def free_xpus(self) -> int:
        """XPUs actually placeable right now (excludes failed cells)."""
        return self.num_xpus - self._busy

    def utilization(self) -> float:
        return self.busy_xpus / self.num_xpus

    @property
    def max_extent(self) -> int:
        """Largest placeable extent on one axis: a chain of all cubes."""
        return self.num_cubes * self.cube_n

    # ------------------------------------------------------------------
    def _offset_candidates(self, extent: int) -> List[int]:
        """Corner offsets on one axis that do not inflate the cube count
        beyond ceil(extent / n)."""
        return list(_offset_candidates_cached(extent, self.cube_n))

    def _pieces_for(self, box: Dims, offsets: Coord) -> List[Tuple[Coord, Slice3]]:
        """Virtual grid positions and per-cube local sub-blocks."""
        n = self.cube_n
        per_axis: List[List[Tuple[int, Tuple[int, int]]]] = []
        for ext, off in zip(box, offsets):
            spans = []
            lo_g, hi_g = off, off + ext
            ncubes = -(-hi_g // n)
            for i in range(ncubes):
                lo = max(lo_g, i * n) - i * n
                hi = min(hi_g, (i + 1) * n) - i * n
                if hi > lo:
                    spans.append((i, (lo, hi)))
            per_axis.append(spans)
        out = []
        for (ix, sx), (iy, sy), (iz, sz) in itertools.product(*per_axis):
            out.append(((ix, iy, iz), (sx, sy, sz)))
        return out

    def _shape_fit_mask(self, shape: Dims) -> np.ndarray:
        """Full-grid fit mask for one sub-block shape across ALL cubes:
        bool (C, n, n, n), True where the shape fits in free space with
        its corner at that cell. This is the one engine-vs-host routing
        point for sub-block freeness — the host path extracts window
        sums from the per-epoch batched integral image, an accelerator
        engine answers every shape seen so far in one multi-box pass —
        and every per-local query (:meth:`_block_free_mask`, the cube
        assignment, the vectorized single-cube search) is a view into
        it. Memoized per shape per epoch; place/release patch only the
        rows of cubes they touched."""
        self._derived()
        m = self._shape_masks.get(shape)
        if m is None:
            if self._engine is None:
                m = np.zeros(self.occ.shape, dtype=bool)
                w = fitmask.window_sums_from_ii(self._ii, shape)
                if w.size:
                    m[:, :w.shape[1], :w.shape[2], :w.shape[3]] = w == 0
                self._shape_masks[shape] = m
            else:
                # One multi-box pass answers every seen-but-uncomputed
                # shape for ALL cubes; masks already cached this epoch
                # are merged with, not recomputed. That prefetch only
                # pays on a compiled engine, where per-box cost is
                # nearly free and dispatch is what's amortized. A
                # host-backed client (numpy behind a broker) is the
                # opposite — multibox cost is linear in K, and most of
                # the hundreds of seen shapes are never queried in any
                # one epoch — so it stays lazy, like the no-client
                # host path: ask only for the shape in hand.
                self._seen_shapes.add(shape)
                if getattr(self._engine, "host_free", False):
                    missing = [shape]
                else:
                    missing = sorted(s for s in self._seen_shapes
                                     if s not in self._shape_masks)
                out = self._engine.multibox(self.occ, missing)
                for k, s in enumerate(missing):
                    self._shape_masks[s] = out[:, k] != 0
                m = self._shape_masks[shape]
        return m

    def _block_free_mask(self, local: Slice3) -> np.ndarray:
        """Bool mask over cubes: sub-block ``local`` entirely free."""
        shape = tuple(hi - lo for lo, hi in local)
        origin = tuple(lo for lo, _ in local)
        return self._shape_fit_mask(shape)[(slice(None),) + origin]

    def _block_free_mask_naive(self, local: Slice3) -> np.ndarray:
        """Reference implementation (direct slice scan), retained for
        the parity tests."""
        (x0, x1), (y0, y1), (z0, z1) = local
        sub = self.occ[:, x0:x1, y0:y1, z0:z1]
        return ~sub.any(axis=(1, 2, 3))

    def _cands_for(self, local: Slice3, chained: bool,
                   multi: bool = False) -> List[int]:
        """Cube ids eligible for a piece, pre-sorted by the best-fit key
        (stable, index tiebreak) — the per-epoch stable argsort of the
        key, filtered to eligible cubes, which equals sorting the
        eligible ids by ``(key, id)``. Computed once per (local,
        chained, multi) per epoch; returned as a plain list (the
        assignment scan is a tight python loop). Callers hold the epoch
        current (``place_fold`` refreshes before searching). ``multi``
        marks pieces of a multi-cube plan: chaining rides the OCS
        fabric, so cubes with a failed OCS port are excluded."""
        key = (local, chained, multi)
        arr = self._sorted_cands.get(key)
        if arr is None:
            if chained:
                mask = self._cube_empty & (self.dedicated < 0)
            else:
                mask = self._block_free_mask(local) & (self.dedicated < 0)
            if multi and not self.ocs_ok.all():
                mask = mask & self.ocs_ok
            go = self._global_order
            arr = go[mask[go]].tolist()
            self._sorted_cands[key] = arr
        return arr

    @staticmethod
    def _ocs_links(box: Dims, offsets: Coord, cube_grid: Dims, n: int,
                   wrap: WrapFlags) -> int:
        """Inter-cube (OCS) links consumed: one per face-position at each
        cube-boundary crossing, plus wrap closures."""
        total = 0
        a, b, c = box
        cross_section = (b * c, a * c, a * b)
        for ax in range(3):
            crossings = cube_grid[ax] - 1
            if wrap[ax]:
                crossings += 1  # ring closure through the OCS
            total += crossings * cross_section[ax]
        return total

    # ------------------------------------------------------------------
    def place_fold(self, fold: Fold, offset_search: bool = True,
                   bound: Optional[Tuple] = None) -> Optional[ReconfigPlan]:
        """Best reconfiguration plan for one fold candidate, or None.

        ``offset_search=False`` pins every piece to the cube corner
        (offset 0) — the naive Reconfig baseline whose partial-cube
        fragmentation the paper criticises; RFold searches offsets as
        part of "virtually reconfiguring the topology to best match the
        shape".

        ``bound`` is an incumbent lexicographic score: only plans that
        strictly beat it are returned. All offset candidates were
        pre-scored into the fold's plan table (vectorized, occupancy
        independent) and sorted by optimistic prefix, so the search
        runs cube assignment best-prefix-first and terminates at the
        first row that cannot beat the incumbent. With ``bound=None``
        the result equals :meth:`place_fold_naive`.
        """
        box = fold.box
        n = self.cube_n
        if any(ext > self.max_extent for ext in box):
            return None
        tab = fold_plan_table(fold, n, self.num_cubes)
        if tab is None:
            return None
        self._derived()
        # Port alignment only binds multi-cube chains; a single-cube job
        # is an ordinary within-cube box placement, so its offsets are
        # always searchable (and fully vectorizable). The naive
        # (Reconfig) baseline pins chained pieces to the cube corner.
        if all(ext <= n for ext in box):
            return self._place_single_cube(fold, tab, bound)
        if offset_search:
            positions = range(len(tab.offsets))
        elif tab.pinned_pos is not None:
            positions = (tab.pinned_pos,)
        else:
            return None
        best: Optional[ReconfigPlan] = None
        incumbent = bound
        dedic = self.dedicate_chained
        navail = self._n_nonempty_elig
        for t in positions:
            nb, nc, lk = p3 = tab.prefix[t]
            # Fresh-cube lower bound: a chained plan dedicates nc empty
            # cubes (fresh == nc exactly); otherwise at most ``navail``
            # of the nc cubes can be non-empty.
            fresh_lb = nc if (dedic and nc > 1) else max(0, nc - navail)
            if incumbent is not None:
                i3 = incumbent[:3]
                # Rows are prefix-sorted: once this row cannot strictly
                # beat the incumbent, no later row can either.
                if p3 > i3 or (p3 == i3 and incumbent[3] == 0):
                    break
                # Rows that cannot strictly beat the incumbent even at
                # their fresh bound skip cube assignment entirely.
                if (nb, nc, lk, fresh_lb) >= incumbent:
                    continue
            plan = self._assign_plan(fold, tab, t)
            if plan is None:
                continue
            score = plan.score()
            if incumbent is None or score < incumbent:
                best = plan
                incumbent = score
                # A plan at its own row's fresh bound is unbeatable:
                # same-prefix rows share the bound (ties never replace)
                # and later prefixes only score worse.
                if score[3] == fresh_lb:
                    break
        return best

    def _place_single_cube(self, fold: Fold, tab: _FoldPlanTable,
                           bound: Optional[Tuple]) -> Optional[ReconfigPlan]:
        """Fully vectorized search for a fold whose box fits inside one
        cube — the bulk of a Philly-like trace. Every (offset, cube)
        candidate is scored in one numpy pass: the full-grid fit mask
        answers sub-block freeness for all offsets of all cubes at
        once, the per-epoch best-fit cube order turns cube choice into
        a column argmax, and the winning row is a single lexicographic
        argmin over ``(broken, links, fresh, product index)`` — exactly
        the naive scan's ``(score, offset order)`` minimum."""
        shape = fold.box
        sub = self._shape_fit_mask(shape)
        elig = self._eligible_order()
        if not elig.size:
            return None
        offs = tab.offs_arr
        sub = sub[elig][:, offs[:, 0], offs[:, 1], offs[:, 2]]  # (E, O)
        if not self.ocs_ok.all():
            # Wrap-ring closures ride the OCS fabric even inside one
            # cube: offsets that close a ring (links > 0) are barred
            # from cubes with a failed OCS port.
            need_ocs = tab.links > 0
            sub = sub & (self.ocs_ok[elig][:, None] | ~need_ocs[None, :])
        feas = sub.any(axis=0)
        if not feas.any():
            return None
        chosen = elig[sub.argmax(axis=0)]       # first eligible per offset
        fresh = self._cube_empty[chosen].astype(np.int64)
        rows = np.nonzero(feas)[0]
        order = np.lexsort((rows, fresh[rows], tab.links[rows],
                            tab.nbroken[rows]))
        t = int(rows[order[0]])
        score = (int(tab.nbroken[t]), 1, int(tab.links[t]), int(fresh[t]))
        if bound is not None and score >= bound:
            return None
        cube = int(chosen[t])
        ox, oy, oz = tab.offsets[t]
        a, b, c = shape
        piece = Piece((0, 0, 0), cube,
                      ((ox, ox + a), (oy, oy + b), (oz, oz + c)))
        return ReconfigPlan(
            fold=fold, offsets=tab.offsets[t], cube_grid=(1, 1, 1),
            pieces=[piece], wrap=tab.wrap[t], broken_rings=tab.broken[t],
            num_ocs_links=int(tab.links[t]), fresh_cubes=int(fresh[t]))

    def _assign_plan(self, fold: Fold, tab: _FoldPlanTable,
                     t: int) -> Optional[ReconfigPlan]:
        """Best-fit cube assignment for one pre-scored offset row, or
        None if some piece has no eligible cube left."""
        offsets = tab.offsets[t]
        pieces_spec, order, cube_grid = _pieces_cached(fold.box, offsets,
                                                       self.cube_n)
        multi = len(pieces_spec) > 1
        chained = multi and self.dedicate_chained
        taken: set = set()
        assignment: Dict[int, int] = {}
        for idx in order:
            local = pieces_spec[idx][1]
            chosen = -1
            for cid in self._cands_for(local, chained, multi):
                if cid not in taken:
                    chosen = cid
                    break
            if chosen < 0:
                return None
            assignment[idx] = chosen
            taken.add(chosen)
        pieces = [Piece(pieces_spec[i][0], assignment[i], pieces_spec[i][1])
                  for i in range(len(pieces_spec))]
        cube_empty = self._cube_empty
        fresh = int(sum(cube_empty[p.cube_id] for p in pieces))
        return ReconfigPlan(
            fold=fold, offsets=offsets, cube_grid=cube_grid,
            pieces=pieces, wrap=tab.wrap[t],
            broken_rings=tab.broken[t],
            num_ocs_links=int(tab.links[t]), fresh_cubes=fresh)

    def plan_search(self, folds: Sequence[Fold], offset_search: bool = True,
                    ) -> Optional[ReconfigPlan]:
        """Best plan across a fold candidate list — the batched engine
        behind ``_ReconfigBase.try_place``. Folds are visited in caller
        order (scores tie-break on it); each fold's occupancy-free
        optimistic bound (:func:`fold_score_bound`) prunes whole folds
        against the incumbent before any table or occupancy state is
        consulted."""
        best: Optional[ReconfigPlan] = None
        bound: Optional[Tuple] = None
        n = self.cube_n
        for fold in folds:
            if bound is not None and fold_score_bound(fold, n) >= bound:
                continue  # cannot strictly beat the incumbent
            plan = self.place_fold(fold, offset_search=offset_search,
                                   bound=bound)
            if plan is None:
                continue
            if bound is None or plan.score() < bound:
                best = plan
                bound = plan.score()
        return best

    def place_fold_naive(self, fold: Fold,
                         offset_search: bool = True) -> Optional[ReconfigPlan]:
        """Reference implementation of :meth:`place_fold` (pure-python
        offset loop, no caching/pruning). Retained as the parity oracle
        for the vectorized engine."""
        box = fold.box
        n = self.cube_n
        if any(ext > self.max_extent for ext in box):
            return None
        best: Optional[ReconfigPlan] = None
        cube_empty = ~self.occ.any(axis=(1, 2, 3))
        single_cube = all(ext <= n for ext in box)
        if offset_search or single_cube:
            offset_space = itertools.product(*(self._offset_candidates(e)
                                               for e in box))
        else:
            offset_space = [(0, 0, 0)]
        for offsets in offset_space:
            pieces_spec = self._pieces_for(box, offsets)
            cube_grid = tuple(
                max(p[0][ax] for p in pieces_spec) + 1 for ax in range(3))
            if volume(cube_grid) > self.num_cubes:
                continue
            multi = len(pieces_spec) > 1
            wrap = tuple(
                offsets[ax] == 0 and box[ax] == cube_grid[ax] * n
                for ax in range(3))
            # OCS dependence is knowable before assignment: chains
            # (multi-cube) and wrap closures both ride the fabric.
            needs_ocs = multi or any(wrap)
            # Assign physical cubes: biggest pieces first, best-fit
            # (prefer partially-used cubes with least leftover).
            order = sorted(range(len(pieces_spec)),
                           key=lambda i: -volume(
                               tuple(hi - lo for lo, hi in pieces_spec[i][1])))
            free_cnt = (~self.occ).sum(axis=(1, 2, 3)).astype(np.int64)
            taken = np.zeros(self.num_cubes, dtype=bool)
            assignment: Dict[int, int] = {}
            ok = True
            for idx in order:
                _, local = pieces_spec[idx]
                if multi and self.dedicate_chained:
                    # chaining dedicates the cube: only fully-free,
                    # non-dedicated cubes are eligible
                    mask = cube_empty & (self.dedicated < 0) & ~taken
                else:
                    # per-face-position OCS: shareable; sub-block free
                    mask = (self._block_free_mask_naive(local)
                            & (self.dedicated < 0) & ~taken)
                if needs_ocs:
                    mask = mask & self.ocs_ok
                if not mask.any():
                    ok = False
                    break
                cand = np.nonzero(mask)[0]
                piece_sz = volume(tuple(hi - lo for lo, hi in local))
                # best-fit: least leftover; among ties prefer non-empty cubes
                leftovers = free_cnt[cand] - piece_sz
                keys = leftovers * 2 + cube_empty[cand].astype(np.int64)
                chosen = int(cand[int(np.argmin(keys))])
                assignment[idx] = chosen
                taken[chosen] = True
            if not ok:
                continue
            valid, broken = verify_fold(fold, wrap)  # type: ignore[arg-type]
            if not valid:
                continue
            pieces = [Piece(pieces_spec[i][0], assignment[i],
                            pieces_spec[i][1]) for i in range(len(pieces_spec))]
            fresh = int(sum(cube_empty[p.cube_id] for p in pieces))
            plan = ReconfigPlan(
                fold=fold, offsets=offsets, cube_grid=cube_grid,  # type: ignore
                pieces=pieces, wrap=wrap,  # type: ignore[arg-type]
                broken_rings=tuple(broken),
                num_ocs_links=self._ocs_links(box, offsets, cube_grid, n,
                                              wrap),  # type: ignore[arg-type]
                fresh_cubes=fresh)
            if best is None or plan.score() < best.score():
                best = plan
        return best

    # ------------------------------------------------------------------
    def commit(self, job_id: int, plan: ReconfigPlan) -> None:
        if job_id in self.allocations:
            raise ValueError(f"job {job_id} already allocated")
        multi = len(plan.pieces) > 1
        for p in plan.pieces:
            (x0, x1), (y0, y1), (z0, z1) = p.local
            blk = self.occ[p.cube_id, x0:x1, y0:y1, z0:z1]
            if blk.any():
                raise ValueError("sub-block no longer free at commit")
            if self.dedicated[p.cube_id] >= 0:
                raise ValueError("cube already dedicated at commit")
            if multi and self.dedicate_chained:
                if self.occ[p.cube_id].any():
                    raise ValueError("chained cube must be empty at commit")
                self.dedicated[p.cube_id] = job_id
            self.occ[p.cube_id, x0:x1, y0:y1, z0:z1] = True
        self._mark_dirty(p.cube_id for p in plan.pieces)
        self._busy += sum(p.size for p in plan.pieces)
        self.allocations[job_id] = list(plan.pieces)
        self.alloc_meta[job_id] = {
            "fold": str(plan.fold), "kind": plan.fold.kind,
            "box": plan.fold.box, "cube_grid": plan.cube_grid,
            "offsets": plan.offsets, "wrap": plan.wrap,
            "broken_rings": plan.broken_rings,
            "num_cubes": plan.num_cubes, "ocs_links": plan.num_ocs_links,
        }
        if self.listeners:
            _events.emit(self.listeners, _events.TopologyEvent(
                kind="setup", job_id=job_id, topology="reconfig",
                reconfigured=plan.num_ocs_links > 0,
                detail={"cubes": sorted(p.cube_id for p in plan.pieces),
                        **self.alloc_meta[job_id]}))

    def release(self, job_id: int) -> None:
        pieces = self.allocations.pop(job_id)
        meta = self.alloc_meta.get(job_id, {})
        for p in pieces:
            (x0, x1), (y0, y1), (z0, z1) = p.local
            self.occ[p.cube_id, x0:x1, y0:y1, z0:z1] = False
            if self.dedicated[p.cube_id] == job_id:
                self.dedicated[p.cube_id] = -1
            self._busy -= p.size
        self._mark_dirty(p.cube_id for p in pieces)
        self.alloc_meta.pop(job_id, None)
        if self.listeners:
            # Releasing a chained job frees its OCS wiring — that, too,
            # is a reconfiguration of the switch layer.
            _events.emit(self.listeners, _events.TopologyEvent(
                kind="release", job_id=job_id, topology="reconfig",
                reconfigured=int(meta.get("ocs_links", 0) or 0) > 0,
                detail={"cubes": sorted({p.cube_id for p in pieces}),
                        "ocs_links": meta.get("ocs_links", 0)}))

    # ------------------------------------------------------------------
    def free_cells(self, limit: int):
        """Up to ``limit`` free (cube_id, x, y, z) cells from
        non-dedicated cubes (best-effort scatter placement)."""
        out = []
        for cid in range(self.num_cubes):
            if self.dedicated[cid] >= 0:
                continue
            free = np.argwhere(~self.occ[cid])
            for (x, y, z) in free:
                out.append((cid, int(x), int(y), int(z)))
                if len(out) >= limit:
                    return out
        return out

    def commit_scatter(self, job_id: int, cells) -> None:
        """Best-effort non-contiguous allocation (paper §5): occupy the
        given cells as single-cell pieces (no shape/ring guarantee)."""
        if job_id in self.allocations:
            raise ValueError(f"job {job_id} already allocated")
        pieces = []
        for (cid, x, y, z) in cells:
            if self.occ[cid, x, y, z]:
                raise ValueError("cell busy at scatter commit")
            self.occ[cid, x, y, z] = True
            pieces.append(Piece((0, 0, 0), cid,
                                ((x, x + 1), (y, y + 1), (z, z + 1))))
        self._mark_dirty(c[0] for c in cells)
        self._busy += len(pieces)
        self.allocations[job_id] = pieces
        self.alloc_meta[job_id] = {"kind": "scatter",
                                   "num_cubes": len({c[0] for c in cells})}
        if self.listeners:
            _events.emit(self.listeners, _events.TopologyEvent(
                kind="setup", job_id=job_id, topology="reconfig",
                detail={"cubes": sorted({c[0] for c in cells}),
                        **self.alloc_meta[job_id]}))

    # -- fault injection (chaos layer) ---------------------------------
    def jobs_on(self, cells) -> List[int]:
        """Job ids whose pieces cover any of the (cube, x, y, z) cells
        (fault victims), sorted for determinism."""
        targets = {tuple(int(v) for v in c) for c in cells}
        hit = set()
        for jid, pieces in self.allocations.items():
            for p in pieces:
                (x0, x1), (y0, y1), (z0, z1) = p.local
                if any(c[0] == p.cube_id and x0 <= c[1] < x1
                       and y0 <= c[2] < y1 and z0 <= c[3] < z1
                       for c in targets):
                    hit.add(jid)
                    break
        return sorted(hit)

    def jobs_using_ocs(self, cube_ids) -> List[int]:
        """Job ids whose OCS wiring rides any of the given cubes: a job
        with ``ocs_links > 0`` (chain or wrap closure) touching the
        cube loses its virtual topology when the port dies."""
        cubes = {int(c) for c in cube_ids}
        hit = set()
        for jid, pieces in self.allocations.items():
            if int(self.alloc_meta.get(jid, {}).get("ocs_links", 0) or 0) <= 0:
                continue
            if any(p.cube_id in cubes for p in pieces):
                hit.add(jid)
        return sorted(hit)

    def fail_cells(self, cells) -> List[Tuple[int, int, int, int]]:
        """Mark (cube, x, y, z) cells failed: they read busy to every
        fit mask but belong to no job. Already-failed cells are skipped
        (idempotent); a still-owned cell raises
        :class:`FaultConflictError` — evict victims first."""
        applied: List[Tuple[int, int, int, int]] = []
        for c in cells:
            c = tuple(int(v) for v in c)
            if self.failed[c]:
                continue
            if self.occ[c]:
                raise FaultConflictError(
                    f"cell {c} still owned by a job; evict before failing")
            self.failed[c] = True
            self.occ[c] = True
            applied.append(c)
        if applied:
            self._mark_dirty({c[0] for c in applied})
            self._busy += len(applied)
            self.num_failed += len(applied)
            if self.listeners:
                _events.emit(self.listeners, _events.TopologyEvent(
                    kind="fault", job_id=-1, topology="reconfig",
                    detail={"fault": "node", "targets": applied}))
        return applied

    def repair_cells(self, cells) -> List[Tuple[int, int, int, int]]:
        """Bring failed cells back; repairing a never-failed cell is a
        no-op. Returns the cells actually repaired."""
        applied: List[Tuple[int, int, int, int]] = []
        for c in cells:
            c = tuple(int(v) for v in c)
            if not self.failed[c]:
                continue
            self.failed[c] = False
            self.occ[c] = False
            applied.append(c)
        if applied:
            self._mark_dirty({c[0] for c in applied})
            self._busy -= len(applied)
            self.num_failed -= len(applied)
            if self.listeners:
                _events.emit(self.listeners, _events.TopologyEvent(
                    kind="repair", job_id=-1, topology="reconfig",
                    detail={"fault": "node", "targets": applied}))
        return applied

    def fail_ocs_port(self, cube_ids) -> List[int]:
        """Detach cubes from the OCS fabric (dead switch port): they
        can no longer join multi-cube chains or close wrap rings, but
        keep hosting OCS-free sub-blocks. Raises
        :class:`FaultConflictError` while a job's wiring still rides
        the cube — evict via :meth:`jobs_using_ocs` first."""
        applied: List[int] = []
        for cid in cube_ids:
            cid = int(cid)
            if not self.ocs_ok[cid]:
                continue
            users = self.jobs_using_ocs([cid])
            if users:
                raise FaultConflictError(
                    f"cube {cid} OCS wiring still used by jobs {users}; "
                    "evict before failing the port")
            self.ocs_ok[cid] = False
            applied.append(cid)
        if applied:
            self._mark_dirty(())   # resets per-epoch candidate caches
            if self.listeners:
                _events.emit(self.listeners, _events.TopologyEvent(
                    kind="fault", job_id=-1, topology="reconfig",
                    reconfigured=True,
                    detail={"fault": "ocs_port", "targets": applied}))
        return applied

    def repair_ocs_port(self, cube_ids) -> List[int]:
        """Re-attach cubes to the OCS fabric; never-failed ports are a
        no-op. Returns the cubes actually repaired."""
        applied: List[int] = []
        for cid in cube_ids:
            cid = int(cid)
            if self.ocs_ok[cid]:
                continue
            self.ocs_ok[cid] = True
            applied.append(cid)
        if applied:
            self._mark_dirty(())
            if self.listeners:
                _events.emit(self.listeners, _events.TopologyEvent(
                    kind="repair", job_id=-1, topology="reconfig",
                    reconfigured=True,
                    detail={"fault": "ocs_port", "targets": applied}))
        return applied

    # ------------------------------------------------------------------
    def check_invariants(self) -> None:
        ref = np.zeros_like(self.occ, dtype=np.int64)
        for pieces in self.allocations.values():
            for p in pieces:
                (x0, x1), (y0, y1), (z0, z1) = p.local
                ref[p.cube_id, x0:x1, y0:y1, z0:z1] += 1
        if (ref > 1).any():
            raise AssertionError("XPU double-booked across cubes")
        if (ref[self.failed] > 0).any():
            raise AssertionError("failed cell owned by a job")
        if not (((ref == 1) | self.failed) == self.occ).all():
            raise AssertionError("cube occupancy out of sync")
        if self.num_failed != int(self.failed.sum()):
            raise AssertionError("failed counter out of sync")
        ded = np.full(self.num_cubes, -1, dtype=np.int64)
        for jid, pieces in self.allocations.items():
            if len(pieces) > 1 and self.dedicate_chained:
                for p in pieces:
                    if ded[p.cube_id] != -1:
                        raise AssertionError("cube dedicated to two jobs")
                    ded[p.cube_id] = jid
        if not (ded == self.dedicated).all():
            raise AssertionError("dedication registry out of sync")
        if self._busy != int(self.occ.sum()):
            raise AssertionError("busy counter out of sync")
