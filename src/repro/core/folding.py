"""Folding: homomorphic job-shape rewriting (the paper's §3.3).

A job's communication graph is the product of rings ``ring(d0) x ring(d1)
x ring(d2)``. A *fold* is an explicit injective mapping of that graph
into a target box such that every ring edge lands on a physical torus
link (possibly a wrap-around link, when the box spans a wrap-capable
extent). We implement the paper's constructions:

  * identity / rotation          (rotation is default policy behaviour)
  * 1D folding: ring(A) -> Hamiltonian cycle of any even-volume box
    (the 18x1x1 -> 2x9 example), or a full wrap line
  * 2D folding: ring(A) x ring(B) -> A kept on an axis, B folded onto a
    Hamiltonian cycle of a 2D sub-grid (the 1x6x4 -> 4x2x3 example)
  * 3D folding: (A, B, 2) -> (A, B/2, 4) via the paper's Y1'/Y2'
    wrap-around mapping (the 4x8x2 -> 4x4x4 example); requires B even
    and a wrap-capable doubled axis — the same rule that rejects the
    paper's impossibility example 4x8x3 -> 4x4x6.

Grid graphs are bipartite, so only even rings can be folded into cycles
(odd rings close only on full wrap lines) — a limitation the paper
acknowledges ("applicable to most jobs with even shape sizes").

Every fold carries its explicit mapping; ``verify_fold`` re-checks the
graph homomorphism edge by edge (this is our equivalent of the paper's
"invoke graph libraries to check for homomorphism", but constructive and
certifying).
"""
from __future__ import annotations

import functools
import itertools
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from .geometry import (Coord, Dims, JobShape, factor_pairs, factorizations3,
                       hamiltonian_cycle_2d, hamiltonian_cycle_3d,
                       is_torus_neighbor, volume)

WrapFlags = Tuple[bool, bool, bool]


@dataclass(frozen=True)
class Fold:
    """An explicit embedding of ``job_dims`` rings into ``box``.

    job_dims      — ring lengths, as requested (normalized descending).
    box           — target allocation box (a, b, c).
    kind          — construction used.
    wrap_required — per *box axis*: the embedding uses that axis's
                    wrap-around link for some ring edge.
    mapping       — tuple indexed by flattened logical coordinate
                    (C-order over job_dims) of box-local coords.
    """

    job_dims: Dims
    box: Dims
    kind: str
    wrap_required: WrapFlags
    mapping: Tuple[Coord, ...]

    def embed(self, logical: Coord) -> Coord:
        d0, d1, d2 = self.job_dims
        i, j, k = logical
        return self.mapping[(i * d1 + j) * d2 + k]

    @property
    def num_xpus(self) -> int:
        return volume(self.job_dims)

    def __str__(self) -> str:  # pragma: no cover - cosmetic
        return (f"{'x'.join(map(str, self.job_dims))}->"
                f"{'x'.join(map(str, self.box))}[{self.kind}]")


def _logical_coords(job_dims: Dims) -> List[Coord]:
    d0, d1, d2 = job_dims
    return [(i, j, k) for i in range(d0) for j in range(d1) for k in range(d2)]


def ring_edges(job_dims: Dims) -> List[Tuple[Coord, Coord, int]]:
    """All ring edges (u, v, axis) of the product-of-rings comm graph.

    A dim of size 1 has no edges; size 2 has a single edge (one duplex
    link); size >= 3 has d edges including the closing one.
    """
    edges = []
    d = list(job_dims)
    for (i, j, k) in _logical_coords(job_dims):
        u = (i, j, k)
        for ax in range(3):
            if d[ax] < 2:
                continue
            nxt = list(u)
            nxt[ax] = (u[ax] + 1) % d[ax]
            v = (nxt[0], nxt[1], nxt[2])
            if d[ax] == 2 and u[ax] == 1:
                continue  # avoid duplicating the single edge of a 2-ring
            edges.append((u, v, ax))
    return edges


def verify_fold(fold: Fold, wrap_available: WrapFlags) -> Tuple[bool, List[int]]:
    """Memoized per fold instance (folds are immutable)."""
    cache = getattr(fold, "_verify_cache", None)
    if cache is None:
        cache = {}
        object.__setattr__(fold, "_verify_cache", cache)
    key = tuple(wrap_available)
    hit = cache.get(key)
    if hit is None:
        hit = _verify_fold_impl(fold, wrap_available)
        cache[key] = hit
    return hit


def _verify_fold_impl(fold: Fold,
                      wrap_available: WrapFlags) -> Tuple[bool, List[int]]:
    """Certify the fold as a ring-product embedding (vectorized).

    Returns (mapping_valid, broken_ring_axes). ``mapping_valid`` means
    injective, in-bounds, and every ring edge maps to a physical link
    given ``wrap_available`` (per box axis). Ring axes whose closing
    edge fails only due to missing wrap are reported broken (the fold is
    then only usable by policies that tolerate broken rings).

    All ring edges of one job axis are checked as a single numpy batch
    (``np.roll`` of the C-order index grid gives the +1-mod-d neighbour
    of every logical node at once); the per-edge python loop survives as
    :func:`_verify_fold_reference`, the parity oracle.
    """
    d = fold.job_dims
    V = d[0] * d[1] * d[2]
    coords = np.asarray(fold.mapping, dtype=np.int64)  # (V, 3), C-order
    box = np.asarray(fold.box, dtype=np.int64)
    if (coords < 0).any() or (coords >= box[None, :]).any():
        return False, []
    flat = (coords[:, 0] * box[1] + coords[:, 1]) * box[2] + coords[:, 2]
    if np.unique(flat).size != V:
        return False, []
    broken: set[int] = set()
    idx = np.arange(V).reshape(d)
    for ax in range(3):
        if d[ax] < 2:
            continue
        iu, iv = idx, np.roll(idx, -1, axis=ax)  # v = u+1 (mod d[ax])
        if d[ax] == 2:
            # a 2-ring is a single duplex link: keep only the u[ax]==0 edge
            sel = [slice(None)] * 3
            sel[ax] = slice(0, 1)
            iu, iv = iu[tuple(sel)], iv[tuple(sel)]
        ad = np.abs(coords[iu.ravel()] - coords[iv.ravel()])  # (E, 3)
        # sorted(deltas) == [0, 0, 1]  <=>  sum(deltas) == 1  (non-neg ints)
        dw = ad.copy()
        for k in range(3):
            if wrap_available[k]:
                dw[:, k] = np.minimum(ad[:, k], box[k] - ad[:, k])
        ok = dw.sum(axis=1) == 1            # link given available wrap
        if ok.all():
            continue
        full = np.minimum(ad, box[None, :] - ad).sum(axis=1) == 1
        if (~ok & ~full).any():
            return False, []                # not a link at all
        broken.add(ax)                      # closes only through missing wrap
    return True, sorted(broken)


def _verify_fold_reference(fold: Fold,
                           wrap_available: WrapFlags) -> Tuple[bool, List[int]]:
    """Edge-by-edge reference implementation of ``_verify_fold_impl``
    (kept as the parity oracle for the vectorized certifier)."""
    coords = [fold.embed(l) for l in _logical_coords(fold.job_dims)]
    if len(set(coords)) != len(coords):
        return False, []
    for c in coords:
        if any(v < 0 or v >= s for v, s in zip(c, fold.box)):
            return False, []
    broken: set[int] = set()
    nowrap: WrapFlags = (False, False, False)
    for (u, v, ax) in ring_edges(fold.job_dims):
        eu, ev = fold.embed(u), fold.embed(v)
        if is_torus_neighbor(eu, ev, fold.box, nowrap):
            continue
        if is_torus_neighbor(eu, ev, fold.box, wrap_available):
            continue
        if is_torus_neighbor(eu, ev, fold.box, (True, True, True)):
            broken.add(ax)  # needs a wrap link that is not available
        else:
            return False, []  # not a link at all: invalid homomorphism
    return True, sorted(broken)


def fold_links(fold: Fold, origin: Coord,
               torus_dims: Dims) -> List[Tuple[Coord, Coord]]:
    """Physical links used by the fold placed at ``origin``. Wrap edges
    connect the two box faces; they are physical only when the box spans
    the full wrap extent (callers check wrap availability separately)."""
    links = []
    for (u, v, _ax) in ring_edges(fold.job_dims):
        pu = tuple(o + e for o, e in zip(origin, fold.embed(u)))
        pv = tuple(o + e for o, e in zip(origin, fold.embed(v)))
        links.append((pu, pv))  # type: ignore[arg-type]
    return links


# ----------------------------------------------------------------------
# Constructions
# ----------------------------------------------------------------------

def _identity_folds(job_dims: Dims) -> List[Fold]:
    """All axis rotations of the original shape."""
    folds = []
    logical = np.indices(job_dims).reshape(3, -1).T  # (V, 3), C-order
    for perm in set(itertools.permutations((0, 1, 2))):
        box = tuple(job_dims[perm.index(ax)] for ax in range(3))
        # logical axis a sits on box axis perm[a]
        c = np.empty_like(logical)
        for a in range(3):
            c[:, perm[a]] = logical[:, a]
        mapping = [tuple(row) for row in c.tolist()]
        wrap_req = [False, False, False]
        for a in range(3):
            if job_dims[a] > 2:
                wrap_req[perm[a]] = True  # ring closure needs wrap
        folds.append(Fold(job_dims, box, "identity",  # type: ignore[arg-type]
                          tuple(wrap_req), tuple(mapping)))
    # Dedup identical boxes+mapping signatures.
    uniq: Dict[Tuple, Fold] = {}
    for f in folds:
        uniq.setdefault((f.box, f.mapping), f)
    return list(uniq.values())


def _cycle_boxes(length: int, max_dim: Optional[int]) -> List[Dims]:
    """Boxes that admit a Hamiltonian cycle of exactly ``length`` nodes:
    even volume, at most one dim == 1."""
    if length % 2 or length < 4:
        return []
    out = []
    for box in factorizations3(length, max_dim):
        if sum(1 for d in box if d == 1) >= 2:
            continue
        out.append(box)
    return out


def _box_cycle(box: Dims) -> Tuple[Coord, ...]:
    return hamiltonian_cycle_3d(box)


def _fold_1d(job_dims: Dims, max_dim: Optional[int]) -> List[Fold]:
    """ring(A) -> Hamiltonian cycle of an even-volume box."""
    A = job_dims[0]
    folds = []
    for box in _cycle_boxes(A, max_dim):
        cyc = _box_cycle(box)
        folds.append(Fold(job_dims, box, "cycle1d",
                          (False, False, False), tuple(cyc)))
    return folds


def _fold_2d(job_dims: Dims, max_dim: Optional[int]) -> List[Fold]:
    """ring(A) x ring(B): keep one ring on an axis, fold the other onto
    a Hamiltonian cycle of a 2D grid spanning the remaining two axes."""
    A, B = job_dims[0], job_dims[1]
    folds = []
    for keep_first, (keep, foldd) in ((True, (A, B)), (False, (B, A))):
        if foldd % 2 or foldd < 4:
            continue
        for (b1, b2) in factor_pairs(foldd, max_dim):
            if b1 < 2 or b2 < 2:
                continue
            if max_dim is not None and keep > max_dim:
                continue
            cyc = hamiltonian_cycle_2d(b1, b2)
            box = (keep, b1, b2)
            mapping = []
            # logical order is C-order over (A, B, 1)
            if keep_first:
                for i in range(A):
                    for j in range(B):
                        y, z = cyc[j]
                        mapping.append((i, y, z))
            else:
                for i in range(A):
                    for j in range(B):
                        y, z = cyc[i]
                        mapping.append((j, y, z))
            wrap_req = (keep > 2, False, False)
            folds.append(Fold(job_dims, box, "ring_x_ham", wrap_req,
                              tuple(mapping)))
    return folds


def _fold_3d_halving(job_dims: Dims) -> List[Fold]:
    """(A, B, 2) -> (A, B/2, 4): the paper's constructive 3D fold.

    Mapping (x, y, z): y < B/2 -> (x, y, z); else (x, B-1-y, 3-z).
    The B-ring's two crossing edges land on the doubled axis's
    wrap-around link (Y1' in the paper), so wrap there is REQUIRED —
    which is exactly why 4x8x3 -> 4x4x6 is rejected (6 is not a
    wrap-capable extent at 4-cube granularity, and the middle layer has
    no cycle image).
    """
    folds = []
    for perm in set(itertools.permutations((0, 1, 2))):
        dims = tuple(job_dims[p] for p in perm)  # treat as (A, B, C)
        A, B, C = dims
        if C != 2 or B % 2 or B < 4:
            continue
        box = (A, B // 2, 4)
        # mapping from the *original* logical axes (i over job_dims[0]..)
        mapping = []
        d0, d1, d2 = job_dims
        for l in _logical_coords(job_dims):
            x, y, z = (l[perm[0]], l[perm[1]], l[perm[2]])
            if y < B // 2:
                c = (x, y, z)
            else:
                c = (x, B - 1 - y, 3 - z)
            mapping.append(c)
        folds.append(Fold(job_dims, box, "halving3d",
                          (A > 2, False, True), tuple(mapping)))
    return folds


def enumerate_folds(shape: JobShape, max_dim: Optional[int] = None,
                    include_identity: bool = True) -> List[Fold]:
    """All fold candidates for a job shape, most-structured first.

    ``max_dim`` bounds any box dimension (e.g. the torus extent, or the
    largest chainable cube extent for a reconfigurable torus).
    Memoized: fold construction (Hamiltonian cycles over up to 4096
    nodes) dominates allocator cost otherwise.
    """
    dims = tuple(sorted(shape.dims, reverse=True))
    return list(_enumerate_folds_cached(dims, max_dim, include_identity))


@functools.lru_cache(maxsize=4096)
def _enumerate_folds_cached(dims: Dims, max_dim: Optional[int],
                            include_identity: bool) -> Tuple[Fold, ...]:
    shape = JobShape(dims)
    nd = shape.ndim
    folds: List[Fold] = []
    if include_identity:
        folds.extend(_identity_folds(dims))
    if nd == 1:
        folds.extend(_fold_1d(dims, max_dim))
    elif nd == 2:
        folds.extend(_fold_2d(dims, max_dim))
        # a 2-ring in the third slot also admits the halving fold
        folds.extend(_fold_3d_halving(dims))
    else:
        folds.extend(_fold_3d_halving(dims))
    if max_dim is not None:
        folds = [f for f in folds if max(f.box) <= max_dim]
    # Dedup by (box, mapping).
    uniq: Dict[Tuple, Fold] = {}
    for f in folds:
        uniq.setdefault((f.box, f.mapping), f)
    return tuple(uniq.values())
