"""Free-box ("fit mask") search over an occupancy grid.

Given a bool occupancy grid and a box shape (a, b, c), compute for every
un-wrapped origin whether the a×b×c window is entirely free. This is the
allocator's hot spot: FirstFit, Folding and Reconfig all reduce to it.

Engine selection:
  * ``numpy`` (default here) — integral-image window sums; the simulator
    calls this thousands of times with *varying* box shapes, so a
    trace-free engine is the right choice on CPU.
  * ``repro.kernels.fitmask`` — the Pallas TPU kernel (one fused
    VMEM pass, batched over grids) with a ``jax.lax.reduce_window``
    oracle; tests assert all engines agree.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from .geometry import Coord, Dims


def window_sums(occ: np.ndarray, box: Dims) -> np.ndarray:
    """Sum of ``occ`` over every un-wrapped a×b×c window.

    occ: bool/int array (X, Y, Z). Returns int array of shape
    (X-a+1, Y-b+1, Z-c+1); empty if the box does not fit at all.
    """
    a, b, c = box
    X, Y, Z = occ.shape
    if a > X or b > Y or c > Z:
        return np.zeros((max(X - a + 1, 0), max(Y - b + 1, 0),
                         max(Z - c + 1, 0)), dtype=np.int64)
    ii = np.zeros((X + 1, Y + 1, Z + 1), dtype=np.int64)
    ii[1:, 1:, 1:] = occ.astype(np.int64)
    np.cumsum(ii, axis=0, out=ii)
    np.cumsum(ii, axis=1, out=ii)
    np.cumsum(ii, axis=2, out=ii)
    s = (ii[a:, b:, c:] - ii[:-a, b:, c:] - ii[a:, :-b, c:] - ii[a:, b:, :-c]
         + ii[:-a, :-b, c:] + ii[:-a, b:, :-c] + ii[a:, :-b, :-c]
         - ii[:-a, :-b, :-c])
    return s


def fit_mask(occ: np.ndarray, box: Dims) -> np.ndarray:
    """Bool mask over origins where the box fits in free space."""
    return window_sums(occ, box) == 0


def first_fit_origin(occ: np.ndarray, box: Dims) -> Optional[Coord]:
    """Lexicographically-first free origin, or None."""
    m = fit_mask(occ, box)
    if m.size == 0 or not m.any():
        return None
    flat = int(np.argmax(m))  # first True in C order == lexicographic
    return tuple(int(v) for v in np.unravel_index(flat, m.shape))  # type: ignore[return-value]


def count_fits(occ: np.ndarray, box: Dims) -> int:
    m = fit_mask(occ, box)
    return int(m.sum())
