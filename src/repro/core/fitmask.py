"""Free-box ("fit mask") search over an occupancy grid.

Given a bool occupancy grid and a box shape (a, b, c), compute for every
un-wrapped origin whether the a×b×c window is entirely free. This is the
allocator's hot spot: FirstFit, Folding and Reconfig all reduce to it.

Engine selection:
  * ``numpy`` (default here) — integral-image window sums; the simulator
    calls this thousands of times with *varying* box shapes, so a
    trace-free engine is the right choice on CPU.
  * ``repro.kernels.fitmask`` — the Pallas TPU kernel (one fused
    VMEM pass, batched over grids) with a ``jax.lax.reduce_window``
    oracle; tests assert all engines agree.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import numpy as np

from .geometry import Coord, Dims


def integral_image(occ: np.ndarray) -> np.ndarray:
    """3D integral image over the trailing axes: (..., X, Y, Z) ->
    int64 (..., X+1, Y+1, Z+1); leading axes (if any) are batch dims.

    ``ii[..., x, y, z]`` is the sum of ``occ[..., :x, :y, :z]``. Build
    it once per occupancy state and answer any number of box queries
    from it — this is the shared structure the allocator reuses across
    all fold-box queries within one placement step.
    """
    shape = occ.shape[:-3] + tuple(d + 1 for d in occ.shape[-3:])
    ii = np.zeros(shape, dtype=np.int64)
    ii[..., 1:, 1:, 1:] = occ.astype(np.int64)
    for ax in (-3, -2, -1):
        np.cumsum(ii, axis=ax, out=ii)
    return ii


def window_sums_from_ii(ii: np.ndarray, box: Dims) -> np.ndarray:
    """Window sums for every un-wrapped origin, from a precomputed
    (possibly batched) integral image (..., X+1, Y+1, Z+1). Empty along
    the window axes if the box does not fit at all."""
    a, b, c = box
    X, Y, Z = (d - 1 for d in ii.shape[-3:])
    if a > X or b > Y or c > Z:
        return np.zeros(ii.shape[:-3] + (max(X - a + 1, 0),
                                         max(Y - b + 1, 0),
                                         max(Z - c + 1, 0)), dtype=np.int64)
    s = (ii[..., a:, b:, c:] - ii[..., :-a, b:, c:] - ii[..., a:, :-b, c:]
         - ii[..., a:, b:, :-c] + ii[..., :-a, :-b, c:]
         + ii[..., :-a, b:, :-c] + ii[..., a:, :-b, :-c]
         - ii[..., :-a, :-b, :-c])
    return s


def window_sums(occ: np.ndarray, box: Dims) -> np.ndarray:
    """Sum of ``occ`` over every un-wrapped a×b×c window.

    occ: bool/int array (X, Y, Z). Returns int array of shape
    (X-a+1, Y-b+1, Z-c+1); empty if the box does not fit at all.
    """
    a, b, c = box
    X, Y, Z = occ.shape
    if a > X or b > Y or c > Z:
        return np.zeros((max(X - a + 1, 0), max(Y - b + 1, 0),
                         max(Z - c + 1, 0)), dtype=np.int64)
    return window_sums_from_ii(integral_image(occ), box)


def batched_integral_image(occ: np.ndarray) -> np.ndarray:
    """Per-grid integral images for a batch: (B, X, Y, Z) bool/int ->
    (B, X+1, Y+1, Z+1) int64. One fused pass for all grids (e.g. all
    cubes of a reconfigurable torus)."""
    return integral_image(occ)


Slice3 = Tuple[Tuple[int, int], Tuple[int, int], Tuple[int, int]]


def block_sums_from_ii(ii: np.ndarray, local: Slice3) -> np.ndarray:
    """Occupied-cell count of the fixed sub-block ``local`` in every grid
    of a batched integral image (B, X+1, Y+1, Z+1) -> int64 (B,)."""
    (x0, x1), (y0, y1), (z0, z1) = local
    return (ii[:, x1, y1, z1] - ii[:, x0, y1, z1] - ii[:, x1, y0, z1]
            - ii[:, x1, y1, z0] + ii[:, x0, y0, z1] + ii[:, x0, y1, z0]
            + ii[:, x1, y0, z0] - ii[:, x0, y0, z0])


def block_free_from_ii(ii: np.ndarray, local: Slice3) -> np.ndarray:
    """Bool (B,): sub-block ``local`` entirely free in each grid."""
    return block_sums_from_ii(ii, local) == 0


def block_sums_from_ii_multi(ii: np.ndarray,
                             locals_: Sequence[Slice3]) -> np.ndarray:
    """Occupied-cell counts for L sub-blocks in every grid at once:
    batched integral image (B, X+1, Y+1, Z+1) x L locals -> int64
    (L, B). One fancy-indexed gather per integral-image corner replaces
    L separate :func:`block_sums_from_ii` calls. Part of the batched
    sub-block query surface; note the allocator's plan search instead
    consumes per-*shape* full-grid masks (``window_sums_from_ii``),
    which amortize better when many origins of few shapes are queried
    — this helper is the right form when the L sub-blocks have many
    distinct shapes."""
    lo = np.array([[s[0] for s in loc] for loc in locals_],
                  dtype=np.int64)                       # (L, 3)
    hi = np.array([[s[1] for s in loc] for loc in locals_],
                  dtype=np.int64)                       # (L, 3)
    x0, y0, z0 = lo[:, 0], lo[:, 1], lo[:, 2]
    x1, y1, z1 = hi[:, 0], hi[:, 1], hi[:, 2]
    iit = np.moveaxis(ii, 0, -1)                        # (X+1, Y+1, Z+1, B)
    return (iit[x1, y1, z1] - iit[x0, y1, z1] - iit[x1, y0, z1]
            - iit[x1, y1, z0] + iit[x0, y0, z1] + iit[x0, y1, z0]
            + iit[x1, y0, z0] - iit[x0, y0, z0])


def block_free_from_ii_multi(ii: np.ndarray,
                             locals_: Sequence[Slice3]) -> np.ndarray:
    """Bool (L, B): each of L sub-blocks entirely free in each grid."""
    return block_sums_from_ii_multi(ii, locals_) == 0


def free_counts(occ: np.ndarray) -> np.ndarray:
    """Free-cell count per grid: (B, X, Y, Z) bool/int -> (B,) int64.
    The host half of the engine ``free_counts`` contract
    (``repro.kernels.fitmask.ops``)."""
    occ = np.asarray(occ)
    n3 = occ.shape[-3] * occ.shape[-2] * occ.shape[-1]
    return n3 - occ.reshape(occ.shape[0], -1).sum(axis=1).astype(np.int64)


def fit_mask(occ: np.ndarray, box: Dims) -> np.ndarray:
    """Bool mask over origins where the box fits in free space."""
    return window_sums(occ, box) == 0


def fit_mask_batched(occ: np.ndarray, box: Dims) -> np.ndarray:
    """Batched fit mask: (B, X, Y, Z) -> bool (B, X-a+1, Y-b+1, Z-c+1)
    via one shared batched integral image (no per-grid python loop)."""
    return window_sums_from_ii(integral_image(occ), box) == 0


def fit_mask_multi(occ: np.ndarray, boxes: Sequence[Dims]) -> np.ndarray:
    """All K candidate boxes from one shared batched integral image:
    (B, X, Y, Z) x K boxes -> (B, K, X, Y, Z) int32, each plane padded
    to the full grid (0 where the box overhangs or does not fit at
    all). Straight-line 8-corner arithmetic on an int64 integral
    image — the parity oracle for :func:`fit_mask_multi_fast` (which
    the numpy engine serves queries from) and for the Pallas multi-box
    kernel (``repro.kernels.fitmask.kernel.fitmask_multibox``).
    """
    occ = np.asarray(occ)
    bsz = occ.shape[0]
    X, Y, Z = occ.shape[-3:]
    out = np.zeros((bsz, len(boxes), X, Y, Z), dtype=np.int32)
    if not boxes:
        return out
    ii = integral_image(occ)
    for k, box in enumerate(boxes):
        s = window_sums_from_ii(ii, box)
        if s.size:
            a, b, c = box
            out[:, k, :X - a + 1, :Y - b + 1, :Z - c + 1] = s == 0
    return out


def fit_mask_multi_fast(occ: np.ndarray, boxes: Sequence[Dims],
                        out_dtype=np.int32) -> Tuple[np.ndarray, np.ndarray]:
    """The batched-(B, K) production form of :func:`fit_mask_multi`:
    one narrow integral image stacked over all grids answers every
    candidate box, and the per-grid free counts fall out of the same
    pass for free.

    Returns ``(masks, free)``: masks is (B, K, X, Y, Z) ``out_dtype``
    (nonzero where the box fits, full-grid padded exactly like
    :func:`fit_mask_multi`), free is (B,) int64 free-cell counts.

    Two deliberate departures from the oracle, both exact:

    * the integral image is int16 whenever the cell volume fits
      (every cluster grid up to 31^3) — cumsums and window diffs are
      memory-bound, so halving the element width roughly halves the
      pass;
    * window sums use nested per-axis differencing (three
      subtractions, as the Pallas kernel does) instead of 8-corner
      inclusion/exclusion, and each ``== 0`` writes straight into the
      padded output plane — no intermediate full-size temporaries.

    Parity with the oracle is property-tested in
    ``tests/test_fitmask_engines.py``.
    """
    occ = np.asarray(occ)
    bsz = occ.shape[0]
    X, Y, Z = occ.shape[-3:]
    out = np.zeros((bsz, len(boxes), X, Y, Z), dtype=out_dtype)
    vol = X * Y * Z
    dt = np.int16 if vol <= np.iinfo(np.int16).max else np.int64
    ii = np.zeros((bsz, X + 1, Y + 1, Z + 1), dtype=dt)
    ii[:, 1:, 1:, 1:] = occ
    for ax in (1, 2, 3):
        np.cumsum(ii, axis=ax, out=ii)
    for k, box in enumerate(boxes):
        a, b, c = (int(v) for v in box)
        if a > X or b > Y or c > Z:
            continue
        s = ii[:, a:, :, :] - ii[:, :-a, :, :]
        s = s[:, :, b:, :] - s[:, :, :-b, :]
        s = s[:, :, :, c:] - s[:, :, :, :-c]
        np.equal(s, 0, out=out[:, k, :X - a + 1, :Y - b + 1, :Z - c + 1],
                 casting="unsafe")
    free = vol - ii[:, -1, -1, -1].astype(np.int64)
    return out, free


def first_fit_origin(occ: np.ndarray, box: Dims) -> Optional[Coord]:
    """Lexicographically-first free origin, or None."""
    m = fit_mask(occ, box)
    if m.size == 0 or not m.any():
        return None
    flat = int(np.argmax(m))  # first True in C order == lexicographic
    return tuple(int(v) for v in np.unravel_index(flat, m.shape))  # type: ignore[return-value]


def count_fits(occ: np.ndarray, box: Dims) -> int:
    m = fit_mask(occ, box)
    return int(m.sum())
