"""Logical-axis sharding rules (MaxText-style) for params & activations.

Models annotate activations with *logical* names via ``constrain``;
a context-installed rule table maps logical names to mesh axes. With no
rules installed (CPU smoke tests) everything is a no-op.

Mesh axes:
  pod    — slow inter-pod DCN/ICI axis (pure data parallel)
  data   — intra-pod data parallel; doubles as the FSDP axis for params
  model  — tensor/expert parallel axis
"""
from __future__ import annotations

import contextlib
import re
import threading
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()

# logical name -> mesh axis (or tuple of axes); None = replicate
DEFAULT_RULES: Dict[str, Any] = {
    "batch": ("pod", "data"),
    "seq": None,
    "cache_seq": None,         # flipped to 'data' for long-context decode
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "ff": "model",
    "vocab": "model",
    "expert": "model",
    "fsdp": "data",
    "expert_fsdp": "data",   # FSDP axis of expert weights (separable)
    "tp": "model",
    "state": None,
}


def rules_for(mesh: Optional[Mesh], *, shard_cache_seq: bool = False,
              fsdp: bool = True) -> Dict[str, Any]:
    """Rule table adapted to the mesh actually in use."""
    rules = dict(DEFAULT_RULES)
    axes = set(mesh.axis_names) if mesh is not None else set()
    if "pod" not in axes:
        rules["batch"] = "data" if "data" in axes else None
    if "model" not in axes:
        for k in ("heads", "kv_heads", "ff", "vocab", "expert", "tp"):
            rules[k] = None
    if "data" not in axes or not fsdp:
        rules["fsdp"] = None
        rules["expert_fsdp"] = None
    if shard_cache_seq and "data" in axes:
        rules["cache_seq"] = "data"
    return rules


@contextlib.contextmanager
def logical_rules(rules: Optional[Dict[str, Any]]):
    prev = getattr(_STATE, "rules", None)
    _STATE.rules = rules
    try:
        yield
    finally:
        _STATE.rules = prev


def current_rules() -> Optional[Dict[str, Any]]:
    return getattr(_STATE, "rules", None)


def spec_for(names: Sequence[Optional[str]],
             rules: Optional[Dict[str, Any]] = None) -> P:
    rules = rules if rules is not None else (current_rules() or {})
    parts = []
    for n in names:
        parts.append(None if n is None else rules.get(n))
    return P(*parts)


def constrain(x: jnp.ndarray, *names: Optional[str]) -> jnp.ndarray:
    """with_sharding_constraint by logical names; no-op without rules."""
    rules = current_rules()
    if rules is None:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, spec_for(names, rules))
    except (ValueError, RuntimeError):
        return x  # outside jit/mesh context


# ----------------------------------------------------------------------
# Parameter specs by naming convention
# ----------------------------------------------------------------------

# key-name pattern -> logical axes per trailing dims (applied right-
# aligned; leading stacked-layer / expert dims handled separately).
_PARAM_PATTERNS: Tuple[Tuple[str, Tuple[Optional[str], ...]], ...] = (
    # projections into the sharded dimension: (d_model, out_tp)
    (r"(w_q|w_kv?|w_v|w_gate|w_up|w_in|in_proj|w_dq|w_uq|w_ukv|qkv|"
     r"w_shared_gate|w_shared_up|lm_head(_\d+)?)$", ("fsdp", "tp")),
    # projections out of the sharded dimension: (in_tp, d_model)
    (r"(w_o|w_out|w_down|out_proj|w_shared_down)$", ("tp", "fsdp")),
    # embeddings: (vocab, d_model)
    (r"embed(_\d+)?$", ("tp", "fsdp")),
    # router: small, replicate
    (r"router$", (None, None)),
    # kv low-rank down-proj (d_model, small): shard only d_model
    (r"w_dkv$", ("fsdp", None)),
    # conv kernels (k, channels): shard channels
    (r"conv_w$", (None, "tp")),
    (r"(conv_b|dt_bias|a_log|d_skip)$", ("tp",)),
    # biases on tp outputs
    (r"(b_q|b_kv|b_v|b_in|b_gate|b_up)$", ("tp",)),
    (r"(b_o|b_out|b_down)$", (None,)),
    # per-head gates / recurrent weights (xlstm)
    (r"(w_ig|w_fg|w_og|b_ig|b_fg|b_og|r_.*)$", (None,)),
    # norms & everything small: replicate
    (r"(scale|bias)$", (None,)),
)


def _match_param(name: str, ndim: int) -> Tuple[Optional[str], ...]:
    for pat, spec in _PARAM_PATTERNS:
        if re.search(pat, name):
            spec = tuple(spec)
            if len(spec) > ndim:
                spec = spec[-ndim:]
            if len(spec) < ndim:
                # leading dims: stacked layers (None) / experts ('expert')
                lead: Tuple[Optional[str], ...] = (None,) * (ndim - len(spec))
                spec = lead + spec
            return spec
    return (None,) * ndim


def param_logical_axes(params: Any, n_expert_hint: int = 0) -> Any:
    """Pytree of logical-axis tuples matching ``params``.

    Heuristics: the final key name selects the trailing-dim rule;
    a leading dim equal to the expert count is tagged 'expert'
    (stacked-layer leading dims stay replicated).
    """
    def visit(path, leaf):
        name = str(path[-1].key) if path else ""
        axes = list(_match_param(name, leaf.ndim))
        if n_expert_hint and leaf.ndim >= 3:
            # find the expert dim among leading dims; experts consume the
            # 'model' axis, so drop 'tp' from the matrix dims (a mesh
            # axis may appear only once per spec)
            for i in range(leaf.ndim - 2):
                if leaf.shape[i] == n_expert_hint and "expert" not in axes:
                    axes = [None if a == "tp" else
                            ("expert_fsdp" if a == "fsdp" else a)
                            for a in axes]
                    axes[i] = "expert"
                    break
        return tuple(axes)

    return jax.tree_util.tree_map_with_path(visit, params)


def param_specs(params: Any, rules: Dict[str, Any],
                n_expert_hint: int = 0) -> Any:
    axes = param_logical_axes(params, n_expert_hint)
    return jax.tree_util.tree_map(
        lambda a: spec_for(a, rules), axes,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))


def param_shardings(params: Any, mesh: Mesh, rules: Dict[str, Any],
                    n_expert_hint: int = 0) -> Any:
    specs = param_specs(params, rules, n_expert_hint)
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))


# ----------------------------------------------------------------------
# Decode-state / batch specs (divisibility-safe)
# ----------------------------------------------------------------------

def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        out = 1
        for a in axis:
            out *= mesh.shape[a]
        return out
    return mesh.shape[axis]


def safe_spec(shape: Tuple[int, ...], names: Sequence[Optional[str]],
              mesh: Mesh, rules: Dict[str, Any]) -> P:
    """spec_for, but drops any axis whose mesh extent does not divide
    the dim (guaranteed-lowerable sharding)."""
    parts = []
    used: set = set()
    for dim, n in zip(shape, names):
        axis = rules.get(n) if n else None
        flat = tuple(axis) if isinstance(axis, (list, tuple)) else (axis,)
        if axis is None or any(a in used for a in flat if a):
            parts.append(None)
            continue
        sz = _axis_size(mesh, axis)
        if sz > 1 and dim % sz == 0:
            parts.append(axis)
            used.update(a for a in flat if a)
        else:
            parts.append(None)
    return P(*parts)


def decode_state_specs(state: Any, mesh: Mesh, rules: Dict[str, Any]) -> Any:
    """Shardings for the decode state pytree (KV caches, SSM/xLSTM
    states), matched by leaf key name with divisibility fallbacks:
    KV caches prefer head sharding, then head_dim, then replicate."""
    def visit(path, leaf):
        name = str(path[-1].key) if path and hasattr(path[-1], "key") else ""
        shape = leaf.shape
        nd = leaf.ndim
        def sp(*names):
            # right-align names onto the trailing dims (leading dims are
            # stacked-layer axes from the scanned segments)
            pad = (None,) * (nd - len(names))
            return safe_spec(shape, pad + names, mesh, rules)
        if name in ("k", "v"):                  # (B, W, KH, D)
            s = sp("batch", "cache_seq", "kv_heads", None)
            if s[-2] is None:                   # heads didn't divide
                s = sp("batch", "cache_seq", None, "tp")
            return NamedSharding(mesh, s)
        if name in ("c_kv", "k_rope"):          # (B, W, r)
            return NamedSharding(mesh, sp("batch", "cache_seq", None))
        if name == "slot_pos":
            return NamedSharding(mesh, sp("batch", "cache_seq"))
        if name == "ssm":                       # (B, H, P, N)
            return NamedSharding(mesh, sp("batch", "heads", None, None))
        if name == "conv":                      # (B, k-1, C)
            return NamedSharding(mesh, sp("batch", None, "tp"))
        if name == "c" and nd >= 4:             # mlstm (B, H, dqk, dv)
            return NamedSharding(mesh, sp("batch", "heads", None, None))
        if name == "n" and nd >= 3:
            return NamedSharding(mesh, sp("batch", "heads", None))
        if name == "m" and nd >= 2:
            return NamedSharding(mesh, sp("batch", "heads"))
        if name in ("c", "n", "h") and nd >= 2:  # slstm (B, D)
            return NamedSharding(mesh, sp("batch", "tp"))
        return NamedSharding(mesh, sp(*([None] * nd)))

    return jax.tree_util.tree_map_with_path(visit, state)


def batch_specs_sharding(batch: Any, mesh: Mesh,
                         rules: Dict[str, Any]) -> Any:
    def visit(path, leaf):
        names: Tuple[Optional[str], ...] = \
            ("batch",) + (None,) * (leaf.ndim - 1)
        return NamedSharding(mesh, safe_spec(leaf.shape, names, mesh,
                                             rules))
    return jax.tree_util.tree_map_with_path(visit, batch)
