"""xLSTM blocks: mLSTM (matrix memory, parallel quadratic training form,
O(1) recurrent decode) and sLSTM (scalar memory with exponential gating,
recurrent scan). Layer pattern follows the paper's 7:1 mLSTM:sLSTM mix.

References: Beck et al., "xLSTM: Extended Long Short-Term Memory"
(arXiv:2405.04517), stabilized exponential gating (eqs. 15-27).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.parallel.sharding import constrain
from .common import ModelConfig, Params, dense_init

NEG_INF = -1e30


def mlstm_dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    d_inner = int(cfg.xlstm_proj_factor * cfg.d_model)
    h = cfg.n_heads
    d_v = d_inner // h
    d_qk = cfg.xlstm_qk_dim
    return d_inner, h, d_qk, d_v


# ----------------------------------------------------------------------
# mLSTM
# ----------------------------------------------------------------------

def init_mlstm(cfg: ModelConfig, key) -> Params:
    d = cfg.d_model
    di, h, dqk, dv = mlstm_dims(cfg)
    ks = jax.random.split(key, 7)
    return {
        "w_in": dense_init(ks[0], (d, 2 * di)),       # [mixer | gate]
        "w_q": dense_init(ks[1], (di, h * dqk)),
        "w_k": dense_init(ks[2], (di, h * dqk)),
        "w_v": dense_init(ks[3], (di, h * dv)),
        "w_ig": dense_init(ks[4], (di, h)),
        "w_fg": dense_init(ks[5], (di, h)),
        "b_ig": jnp.zeros((h,), jnp.float32),
        "b_fg": jnp.full((h,), 3.0, jnp.float32),     # open forget gates
        "norm_scale": jnp.ones((di,), jnp.float32),
        "w_out": dense_init(ks[6], (di, d)),
    }


def init_mlstm_state(cfg: ModelConfig, batch: int) -> Params:
    _, h, dqk, dv = mlstm_dims(cfg)
    return {
        "c": jnp.zeros((batch, h, dqk, dv), jnp.float32),
        "n": jnp.zeros((batch, h, dqk), jnp.float32),
        "m": jnp.full((batch, h), -jnp.inf, jnp.float32),
    }


def _mlstm_parallel(q, k, v, i_pre, f_pre):
    """Stabilized parallel form. q,k: (B,S,H,Dqk); v: (B,S,H,Dv);
    i_pre,f_pre: (B,S,H) gate pre-activations."""
    b, s, h, dqk = q.shape
    logf = jax.nn.log_sigmoid(f_pre.astype(jnp.float32))      # (B,S,H)
    logf_cum = jnp.cumsum(logf, axis=1)
    # D[t, s] = logf_cum[t] - logf_cum[s] + i[s]   (s <= t)
    dmat = (logf_cum[:, :, None, :] - logf_cum[:, None, :, :]
            + i_pre.astype(jnp.float32)[:, None, :, :])       # (B,T,S,H)
    tri = jnp.tril(jnp.ones((s, s), bool))
    dmat = jnp.where(tri[None, :, :, None], dmat, NEG_INF)
    m = jnp.max(dmat, axis=2)                                 # (B,T,H)
    dprime = jnp.exp(dmat - m[:, :, None, :])
    scores = jnp.einsum("bthd,bshd->btsh", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(dqk)
    w = scores * dprime
    norm = jnp.maximum(jnp.abs(w.sum(axis=2)), jnp.exp(-m))   # (B,T,H)
    y = jnp.einsum("btsh,bshv->bthv", w, v.astype(jnp.float32))
    y = y / (norm[..., None] + 1e-6)
    return y.astype(q.dtype)


def _mlstm_step(state, q, k, v, i_pre, f_pre):
    """q,k: (B,H,Dqk); v: (B,H,Dv); gates (B,H). Returns (y, state)."""
    f32 = jnp.float32
    logf = jax.nn.log_sigmoid(f_pre.astype(f32))
    m_new = jnp.maximum(logf + state["m"], i_pre.astype(f32))
    fg = jnp.exp(logf + state["m"] - m_new)
    ig = jnp.exp(i_pre.astype(f32) - m_new)
    kq_scale = 1.0 / math.sqrt(q.shape[-1])
    c_new = state["c"] * fg[..., None, None] + \
        ig[..., None, None] * (k.astype(f32)[..., :, None]
                               * v.astype(f32)[..., None, :])
    n_new = state["n"] * fg[..., None] + ig[..., None] * k.astype(f32)
    qf = q.astype(f32) * kq_scale
    num = jnp.einsum("bhd,bhdv->bhv", qf, c_new)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n_new)),
                      jnp.exp(-m_new))
    y = (num / (den[..., None] + 1e-6)).astype(q.dtype)
    return y, {"c": c_new, "n": n_new, "m": m_new}


def _rms(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


def mlstm_forward(cfg: ModelConfig, p: Params, x: jnp.ndarray,
                  state: Optional[Params] = None
                  ) -> Tuple[jnp.ndarray, Optional[Params]]:
    b, s, _ = x.shape
    di, h, dqk, dv = mlstm_dims(cfg)
    up = x @ p["w_in"].astype(x.dtype)
    xm, gate = jnp.split(up, 2, axis=-1)
    q = (xm @ p["w_q"].astype(x.dtype)).reshape(b, s, h, dqk)
    k = (xm @ p["w_k"].astype(x.dtype)).reshape(b, s, h, dqk)
    v = (xm @ p["w_v"].astype(x.dtype)).reshape(b, s, h, dv)
    q = constrain(q, "batch", "seq", "heads", None)
    i_pre = xm @ p["w_ig"].astype(x.dtype) + p["b_ig"].astype(x.dtype)
    f_pre = xm @ p["w_fg"].astype(x.dtype) + p["b_fg"].astype(x.dtype)

    if state is None:
        y = _mlstm_parallel(q, k, v, i_pre, f_pre)
        new_state = None
    else:
        assert s == 1
        y, new_state = _mlstm_step(state, q[:, 0], k[:, 0], v[:, 0],
                                   i_pre[:, 0], f_pre[:, 0])
        y = y[:, None]
    y = y.reshape(b, s, di)
    y = _rms(y, p["norm_scale"]) * jax.nn.silu(
        gate.astype(jnp.float32)).astype(x.dtype)
    out = y @ p["w_out"].astype(x.dtype)
    return constrain(out, "batch", "seq", "embed"), new_state


# ----------------------------------------------------------------------
# sLSTM
# ----------------------------------------------------------------------

def slstm_head_dim(cfg: ModelConfig) -> int:
    return cfg.d_model // cfg.n_heads


def init_slstm(cfg: ModelConfig, key) -> Params:
    d, h = cfg.d_model, cfg.n_heads
    dh = slstm_head_dim(cfg)
    ks = jax.random.split(key, 9)
    p = {"w_in": dense_init(ks[0], (d, 4 * d))}       # z, i, f, o pre-acts
    for name, kk in zip(("r_z", "r_i", "r_f", "r_o"), ks[1:5]):
        p[name] = (jax.random.normal(kk, (h, dh, dh)) / math.sqrt(dh)
                   ).astype(jnp.float32)
    p["b_z"] = jnp.zeros((d,), jnp.float32)
    p["b_i"] = jnp.zeros((d,), jnp.float32)
    p["b_f"] = jnp.full((d,), 3.0, jnp.float32)
    p["b_o"] = jnp.zeros((d,), jnp.float32)
    p["norm_scale"] = jnp.ones((d,), jnp.float32)
    p["w_out"] = dense_init(ks[5], (d, d))
    return p


def init_slstm_state(cfg: ModelConfig, batch: int) -> Params:
    d = cfg.d_model
    return {
        "c": jnp.zeros((batch, d), jnp.float32),
        "n": jnp.zeros((batch, d), jnp.float32),
        "h": jnp.zeros((batch, d), jnp.float32),
        "m": jnp.full((batch, d), -jnp.inf, jnp.float32),
    }


def _slstm_cell(cfg: ModelConfig, p: Params, state, zifo):
    """One timestep. zifo: (B, 4D) pre-activations from the input path."""
    f32 = jnp.float32
    b = zifo.shape[0]
    d, h = cfg.d_model, cfg.n_heads
    dh = d // h
    hprev = state["h"].reshape(b, h, dh)

    def rec(r):
        return jnp.einsum("bhd,hde->bhe", hprev, r).reshape(b, d)

    z_pre, i_pre, f_pre, o_pre = jnp.split(zifo.astype(f32), 4, axis=-1)
    z_pre = z_pre + rec(p["r_z"]) + p["b_z"]
    i_pre = i_pre + rec(p["r_i"]) + p["b_i"]
    f_pre = f_pre + rec(p["r_f"]) + p["b_f"]
    o_pre = o_pre + rec(p["r_o"]) + p["b_o"]

    logf = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(logf + state["m"], i_pre)
    fg = jnp.exp(logf + state["m"] - m_new)
    ig = jnp.exp(i_pre - m_new)
    z = jnp.tanh(z_pre)
    o = jax.nn.sigmoid(o_pre)
    c_new = fg * state["c"] + ig * z
    n_new = fg * state["n"] + ig
    h_new = o * c_new / jnp.maximum(n_new, 1e-6)
    return {"c": c_new, "n": n_new, "h": h_new, "m": m_new}


def slstm_forward(cfg: ModelConfig, p: Params, x: jnp.ndarray,
                  state: Optional[Params] = None
                  ) -> Tuple[jnp.ndarray, Optional[Params]]:
    b, s, d = x.shape
    zifo = x @ p["w_in"].astype(x.dtype)

    if state is None:
        st = init_slstm_state(cfg, b)

        def step(carry, zifo_t):
            new = _slstm_cell(cfg, p, carry, zifo_t)
            return new, new["h"]

        _, hs = jax.lax.scan(step, st, jnp.moveaxis(zifo, 1, 0))
        y = jnp.moveaxis(hs, 0, 1).astype(x.dtype)     # (B,S,D)
        new_state = None
    else:
        assert s == 1
        new_state = _slstm_cell(cfg, p, state, zifo[:, 0])
        y = new_state["h"][:, None].astype(x.dtype)

    y = _rms(y, p["norm_scale"])
    out = y @ p["w_out"].astype(x.dtype)
    return constrain(out, "batch", "seq", "embed"), new_state


def is_slstm_layer(cfg: ModelConfig, layer_idx: int) -> bool:
    return cfg.slstm_every > 0 and layer_idx % cfg.slstm_every == 0
