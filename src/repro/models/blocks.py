"""Per-layer blocks for every architecture family, with a uniform
(init_layer / apply_layer / init_layer_state) interface so model.py can
scan over stacked layer params regardless of family.

Kinds:
  dense       — norm -> attention (GQA) -> norm -> gated FFN
  moe         — norm -> attention (GQA or MLA) -> norm -> MoE FFN
  mamba       — norm -> Mamba2 mixer
  mlstm/slstm — xLSTM blocks
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import (attention_forward, init_attention, init_kv_cache,
                        init_mla, init_mla_cache, mla_forward)
from .common import ModelConfig, Params, apply_norm, init_norm
from .ffn import ffn_forward, init_ffn, init_moe, moe_forward
from .ssm import init_mamba2, init_mamba_state, mamba2_forward
from .xlstm import (init_mlstm, init_mlstm_state, init_slstm,
                    init_slstm_state, mlstm_forward, slstm_forward)

ZERO = jnp.zeros((), jnp.float32)


def init_layer(cfg: ModelConfig, key, kind: str) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    if kind in ("dense", "shared_attn"):
        return {
            "ln1": init_norm(cfg), "ln2": init_norm(cfg),
            "attn": init_attention(cfg, k1),
            "ffn": init_ffn(cfg, k2),
        }
    if kind == "moe":
        attn = init_mla(cfg, k1) if cfg.use_mla else init_attention(cfg, k1)
        return {
            "ln1": init_norm(cfg), "ln2": init_norm(cfg),
            "attn": attn, "moe": init_moe(cfg, k2),
        }
    if kind == "moe_dense":      # first-k-dense layers of DeepSeek-style
        attn = init_mla(cfg, k1) if cfg.use_mla else init_attention(cfg, k1)
        d_ff = cfg.d_ff if cfg.d_ff else cfg.moe_d_ff * (
            cfg.n_shared_experts + cfg.moe_top_k)
        return {
            "ln1": init_norm(cfg), "ln2": init_norm(cfg),
            "attn": attn, "ffn": init_ffn(cfg, k2, d_ff=d_ff),
        }
    if kind == "mamba":
        return {"ln1": init_norm(cfg), "mixer": init_mamba2(cfg, k1)}
    if kind == "mlstm":
        return {"ln1": init_norm(cfg), "mixer": init_mlstm(cfg, k1)}
    if kind == "slstm":
        return {"ln1": init_norm(cfg), "mixer": init_slstm(cfg, k1)}
    raise ValueError(kind)


def init_layer_state(cfg: ModelConfig, kind: str, batch: int, window: int,
                     dtype) -> Optional[Params]:
    """Decode-time state for one layer (None for stateless kinds)."""
    if kind in ("dense", "shared_attn"):
        return init_kv_cache(batch, window, cfg.n_kv_heads, cfg.head_dim,
                             dtype)
    if kind in ("moe", "moe_dense"):
        if cfg.use_mla:
            return init_mla_cache(cfg, batch, window, dtype)
        return init_kv_cache(batch, window, cfg.n_kv_heads, cfg.head_dim,
                             dtype)
    if kind == "mamba":
        return init_mamba_state(cfg, batch, dtype)
    if kind == "mlstm":
        return init_mlstm_state(cfg, batch)
    if kind == "slstm":
        return init_slstm_state(cfg, batch)
    raise ValueError(kind)


def apply_layer(cfg: ModelConfig, p: Params, x: jnp.ndarray,
                positions: jnp.ndarray, kind: str,
                state: Optional[Params] = None, window: int = 0,
                use_kernel: bool = False
                ) -> Tuple[jnp.ndarray, Optional[Params], jnp.ndarray]:
    """Returns (x_out, new_state, aux_loss)."""
    aux = ZERO
    if kind in ("dense", "shared_attn", "moe", "moe_dense"):
        h = apply_norm(cfg, p["ln1"], x)
        if cfg.use_mla and kind in ("moe", "moe_dense"):
            att, new_state = mla_forward(cfg, p["attn"], h, positions,
                                         cache=state, window=window)
        else:
            att, new_state = attention_forward(cfg, p["attn"], h, positions,
                                               cache=state, window=window,
                                               use_flash=use_kernel)
        x = x + att
        h = apply_norm(cfg, p["ln2"], x)
        if kind == "moe":
            ff, aux = moe_forward(cfg, p["moe"], h)
        else:
            ff = ffn_forward(cfg, p["ffn"], h)
        return x + ff, new_state, aux
    if kind == "mamba":
        h = apply_norm(cfg, p["ln1"], x)
        out, new_state = mamba2_forward(cfg, p["mixer"], h, state=state,
                                        use_kernel=use_kernel)
        return x + out, new_state, aux
    if kind == "mlstm":
        h = apply_norm(cfg, p["ln1"], x)
        out, new_state = mlstm_forward(cfg, p["mixer"], h, state=state)
        return x + out, new_state, aux
    if kind == "slstm":
        h = apply_norm(cfg, p["ln1"], x)
        out, new_state = slstm_forward(cfg, p["mixer"], h, state=state)
        return x + out, new_state, aux
    raise ValueError(kind)
