"""Full language-model assembly: embeddings -> scanned layer stack ->
final norm -> LM head(s); plus decode-state plumbing.

Layer stacks are ``jax.lax.scan``-over-stacked-params so that 512-way
SPMD dry-runs compile in seconds instead of hours. Heterogeneous
patterns are expressed as scans over *groups*:

  dense/audio/vlm : scan(n_layers x dense)
  moe             : first_k_dense unscanned + scan(rest x moe)
  ssm (xlstm)     : scan(G x [slstm ; (k-1) x mlstm]), k = slstm_every
  hybrid (zamba2) : scan(G x [shared_attn? ; k x mamba]) + leftover;
                    the attention block params are SHARED (closed over),
                    applied once at the start of each group.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.parallel.sharding import constrain
from .blocks import apply_layer, init_layer, init_layer_state
from .common import (ModelConfig, Params, apply_norm, embed_init, init_norm,
                     sinusoidal_positions)


# ----------------------------------------------------------------------
# Layer plan
# ----------------------------------------------------------------------

@dataclass(frozen=True)
class Segment:
    kind: str          # block kind for blocks.py
    count: int         # layers in this segment
    scanned: bool      # stacked params + lax.scan
    group: Tuple[str, ...] = ()   # for grouped scans: kinds within group


def layer_plan(cfg: ModelConfig) -> List[Segment]:
    return _finalize_plan(cfg, _layer_plan(cfg))


def _layer_plan(cfg: ModelConfig) -> List[Segment]:
    at = cfg.arch_type
    if at in ("dense", "audio", "vlm"):
        return [Segment("dense", cfg.n_layers, True)]
    if at == "moe":
        segs: List[Segment] = []
        if cfg.first_k_dense:
            segs.append(Segment("moe_dense", cfg.first_k_dense, False))
        segs.append(Segment("moe", cfg.n_layers - cfg.first_k_dense, True))
        return segs
    if at == "ssm":    # xLSTM
        k = cfg.slstm_every
        assert cfg.n_layers % k == 0, "n_layers must divide slstm_every"
        group = ("slstm",) + ("mlstm",) * (k - 1)
        return [Segment("xlstm_group", cfg.n_layers // k, True, group)]
    if at == "hybrid":  # zamba2
        k = cfg.shared_attn_every
        g, rem = divmod(cfg.n_layers, k)
        segs = [Segment("hybrid_group", g, True, ("mamba",) * k)]
        if rem:
            segs.append(Segment("mamba", rem, False))
        return segs
    raise ValueError(at)


def _finalize_plan(cfg: ModelConfig, segs: List[Segment]) -> List[Segment]:
    if cfg.force_unscanned:
        segs = [Segment(s.kind, s.count, False, s.group) for s in segs]
    return segs


# ----------------------------------------------------------------------
# Init
# ----------------------------------------------------------------------

def _stacked_init(fn, key, count: int):
    return jax.vmap(fn)(jax.random.split(key, count))


def init_model(cfg: ModelConfig, key) -> Params:
    keys = jax.random.split(key, 8)
    params: Dict[str, Any] = {}
    d = cfg.d_model

    if cfg.arch_type == "audio":
        params["embed"] = jnp.stack([
            embed_init(k, (cfg.vocab_size, d))
            for k in jax.random.split(keys[0], cfg.n_codebooks)])
        params["lm_head"] = jnp.stack([
            embed_init(k, (d, cfg.vocab_size))
            for k in jax.random.split(keys[1], cfg.n_codebooks)])
    else:
        params["embed"] = embed_init(keys[0], (cfg.vocab_size, d))
        if not cfg.tie_embeddings:
            params["lm_head"] = embed_init(keys[1], (d, cfg.vocab_size))

    segs = layer_plan(cfg)
    seg_params = []
    seg_keys = jax.random.split(keys[2], len(segs))
    for seg, sk in zip(segs, seg_keys):
        if seg.kind in ("xlstm_group", "hybrid_group"):
            def ginit(k, seg=seg):
                gk = jax.random.split(k, len(seg.group))
                return {f"{i}_{kind}": init_layer(cfg, gk[i], kind)
                        for i, kind in enumerate(seg.group)}
            if seg.scanned:
                seg_params.append(_stacked_init(ginit, sk, seg.count))
            else:
                gks = jax.random.split(sk, seg.count)
                seg_params.append([ginit(gks[i]) for i in range(seg.count)])
        elif seg.scanned:
            seg_params.append(_stacked_init(
                lambda k, seg=seg: init_layer(cfg, k, seg.kind),
                sk, seg.count))
        else:
            lk = jax.random.split(sk, seg.count)
            seg_params.append([init_layer(cfg, lk[i], seg.kind)
                               for i in range(seg.count)])
    params["segments"] = seg_params
    if cfg.arch_type == "hybrid":
        params["shared_attn"] = init_layer(cfg, keys[3], "shared_attn")
    params["final_norm"] = init_norm(cfg)
    return params


# ----------------------------------------------------------------------
# Embedding / head
# ----------------------------------------------------------------------

def embed_tokens(cfg: ModelConfig, params: Params, batch: Dict) -> jnp.ndarray:
    if "embeds" in batch and batch["embeds"] is not None:
        x = batch["embeds"]            # stub modality frontend (audio/vlm)
    elif cfg.arch_type == "audio":
        toks = batch["tokens"]         # (B, K, S)
        emb = params["embed"]          # (K, V, D)
        x = jnp.zeros(toks.shape[:1] + toks.shape[2:] + (cfg.d_model,),
                      cfg.activation_dtype)
        for k in range(cfg.n_codebooks):
            x = x + emb[k][toks[:, k]].astype(cfg.activation_dtype)
    else:
        x = params["embed"][batch["tokens"]].astype(cfg.activation_dtype)
        if cfg.arch_type == "vlm" and batch.get("patch_embeds") is not None:
            # stub ViT frontend: splice projected patch embeddings over
            # the image-placeholder positions (mask: (B, S) bool)
            pe = batch["patch_embeds"].astype(cfg.activation_dtype)
            mask = batch["patch_mask"][..., None]
            x = jnp.where(mask, pe, x)
    if cfg.pos_type == "sinusoidal":
        pos0 = batch.get("pos_offset", 0)
        sin = sinusoidal_positions(x.shape[1], cfg.d_model, pos0)
        x = x + sin[None].astype(x.dtype)
    return constrain(x, "batch", "seq", "embed")


def lm_logits(cfg: ModelConfig, params: Params, x: jnp.ndarray) -> jnp.ndarray:
    if cfg.arch_type == "audio":
        logits = jnp.einsum("bsd,kdv->bskv", x,
                            params["lm_head"].astype(x.dtype))
        return constrain(logits, "batch", "seq", None, "vocab")
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(x.dtype)
    return constrain(x @ head, "batch", "seq", "vocab")


# ----------------------------------------------------------------------
# Forward (train / prefill) and decode
# ----------------------------------------------------------------------

def _positions_from(cfg: ModelConfig, batch: Dict, seq: int,
                    bsz: int) -> jnp.ndarray:
    pos = batch.get("positions")
    if pos is None:
        pos = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32)[None],
                               (bsz, seq))
    return pos


def _apply_group(cfg, group_kinds, gp, x, positions, states, window,
                 use_kernel, shared_attn=None):
    """One group of a grouped scan; states is a dict or None."""
    aux = jnp.zeros((), jnp.float32)
    new_states = {} if states is not None else None
    if shared_attn is not None:
        st = states.get("shared") if states is not None else None
        x, ns, a = apply_layer(cfg, shared_attn, x, positions,
                               "shared_attn", state=st, window=window,
                               use_kernel=use_kernel)
        aux += a
        if new_states is not None:
            new_states["shared"] = ns
    for i, kind in enumerate(group_kinds):
        name = f"{i}_{kind}"
        st = states.get(name) if states is not None else None
        x, ns, a = apply_layer(cfg, gp[name], x, positions, kind,
                               state=st, window=window,
                               use_kernel=use_kernel)
        aux += a
        if new_states is not None:
            new_states[name] = ns
    return x, new_states, aux


def _run_stack(cfg: ModelConfig, params: Params, x: jnp.ndarray,
               positions: jnp.ndarray, states: Optional[List] = None,
               window: int = 0, use_kernel: bool = False):
    """states: list matching segments (stacked pytrees for scanned
    segments); None for train/prefill-without-cache."""
    segs = layer_plan(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    new_states: Optional[List] = [] if states is not None else None
    shared = params.get("shared_attn")

    for si, (seg, sp) in enumerate(zip(segs, params["segments"])):
        st_seg = states[si] if states is not None else None
        grouped = seg.kind in ("xlstm_group", "hybrid_group")
        shared_for_seg = shared if seg.kind == "hybrid_group" else None
        if not seg.scanned:
            seg_new = []
            for li in range(seg.count):
                st = st_seg[li] if st_seg is not None else None
                if grouped:
                    def fn(lp, h, st_):
                        return _apply_group(
                            cfg, seg.group, lp, h, positions, st_, window,
                            use_kernel, shared_attn=shared_for_seg)
                else:
                    def fn(lp, h, st_):
                        return apply_layer(
                            cfg, lp, h, positions, seg.kind, state=st_,
                            window=window, use_kernel=use_kernel)
                if cfg.remat == "full":
                    fn = jax.checkpoint(fn, prevent_cse=False)
                x, ns, a = fn(sp[li], x, st)
                aux_total += a
                seg_new.append(ns)
            if new_states is not None:
                new_states.append(seg_new)
            continue

        def body(carry, xs):
            h, aux = carry
            lp, lst = xs
            if grouped:
                h, ns, a = _apply_group(cfg, seg.group, lp, h, positions,
                                        lst, window, use_kernel,
                                        shared_attn=shared_for_seg)
            else:
                h, ns, a = apply_layer(cfg, lp, h, positions, seg.kind,
                                       state=lst, window=window,
                                       use_kernel=use_kernel)
            return (h, aux + a), ns

        if cfg.remat == "full":
            body = jax.checkpoint(body, prevent_cse=False)
        (x, aux_total), seg_new = jax.lax.scan(
            body, (x, aux_total), (sp, st_seg))
        if new_states is not None:
            new_states.append(seg_new)

    return x, new_states, aux_total


def forward(cfg: ModelConfig, params: Params, batch: Dict,
            use_kernel: bool = False
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence forward. Returns (logits, aux_loss)."""
    x = embed_tokens(cfg, params, batch)
    b, s = x.shape[:2]
    positions = _positions_from(cfg, batch, s, b)
    window = cfg.sliding_window
    x, _, aux = _run_stack(cfg, params, x, positions, None, window,
                           use_kernel)
    x = apply_norm(cfg, params["final_norm"], x)
    return lm_logits(cfg, params, x), aux


def init_decode_state(cfg: ModelConfig, batch: int, window: int,
                      dtype) -> List:
    """Per-segment decode state, stacked for scanned segments."""
    def one(kind):
        return init_layer_state(cfg, kind, batch, window, dtype)

    def stack(tree, count):
        return jax.tree_util.tree_map(
            lambda l: jnp.broadcast_to(l, (count,) + l.shape).copy(), tree)

    states: List[Any] = []
    for seg in layer_plan(cfg):
        if seg.kind in ("xlstm_group", "hybrid_group"):
            def gstate():
                g: Dict[str, Any] = {}
                if seg.kind == "hybrid_group":
                    g["shared"] = one("shared_attn")
                for i, kind in enumerate(seg.group):
                    g[f"{i}_{kind}"] = one(kind)
                return g
            if seg.scanned:
                states.append(stack(gstate(), seg.count))
            else:
                states.append([gstate() for _ in range(seg.count)])
        elif seg.scanned:
            states.append(stack(one(seg.kind), seg.count))
        else:
            states.append([one(seg.kind) for _ in range(seg.count)])
    return states


def decode_step(cfg: ModelConfig, params: Params, state: List,
                batch: Dict) -> Tuple[jnp.ndarray, List]:
    """One-token decode. batch['tokens']: (B, 1) (or (B,K,1) audio);
    batch['positions']: (B, 1) absolute positions. Returns (logits,
    new_state)."""
    x = embed_tokens(cfg, params, batch)
    b = x.shape[0]
    positions = batch["positions"]
    window = (cfg.sliding_window
              if cfg.long_context_mode == "window" else 0)
    x, new_state, _ = _run_stack(cfg, params, x, positions, state, window)
    x = apply_norm(cfg, params["final_norm"], x)
    return lm_logits(cfg, params, x), new_state
