"""Feed-forward layers: gated dense FFN (SwiGLU/GELU) and MoE with
sort-based top-k token-choice dispatch (GShard-style capacity, no giant
one-hot dispatch tensors — static-shape gathers that lower cleanly under
GSPMD with experts sharded on the 'model'/expert axis).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.parallel.sharding import constrain
from .common import ModelConfig, Params, dense_init, gated_act


# ----------------------------------------------------------------------
# Dense gated FFN
# ----------------------------------------------------------------------

def init_ffn(cfg: ModelConfig, key, d_ff: Optional[int] = None,
             d_model: Optional[int] = None) -> Params:
    d = d_model or cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], (d, f)),
        "w_up": dense_init(ks[1], (d, f)),
        "w_down": dense_init(ks[2], (f, d)),
    }


def ffn_forward(cfg: ModelConfig, p: Params, x: jnp.ndarray) -> jnp.ndarray:
    gate = x @ p["w_gate"].astype(x.dtype)
    up = x @ p["w_up"].astype(x.dtype)
    h = gated_act(cfg, gate, up)
    h = constrain(h, "batch", "seq", "ff")
    return h @ p["w_down"].astype(x.dtype)


# ----------------------------------------------------------------------
# Mixture of Experts
# ----------------------------------------------------------------------

def init_moe(cfg: ModelConfig, key) -> Params:
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    p: Dict[str, Any] = {
        "router": dense_init(ks[0], (d, e)),
        "w_gate": dense_init(ks[1], (e, d, f)),
        "w_up": dense_init(ks[2], (e, d, f)),
        "w_down": dense_init(ks[3], (e, f, d)),
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        ks2 = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": dense_init(ks2[0], (d, fs)),
            "w_up": dense_init(ks2[1], (d, fs)),
            "w_down": dense_init(ks2[2], (fs, d)),
        }
    return p


def router_probs(cfg: ModelConfig, logits: jnp.ndarray
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Top-k routing. Returns (weights (N,k), expert_ids (N,k))."""
    if cfg.router_type == "softmax":
        probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
        w, idx = jax.lax.top_k(probs, cfg.moe_top_k)
        w = w / jnp.clip(w.sum(-1, keepdims=True), 1e-9)
    elif cfg.router_type == "sigmoid":     # llama4-style top-1 sigmoid
        score, idx = jax.lax.top_k(logits.astype(jnp.float32), cfg.moe_top_k)
        w = jax.nn.sigmoid(score)
    else:
        raise ValueError(cfg.router_type)
    return w, idx


def aux_load_balance_loss(cfg: ModelConfig, logits: jnp.ndarray,
                          idx: jnp.ndarray) -> jnp.ndarray:
    """Switch-style load-balance loss (mean fraction * mean prob * E)."""
    e = cfg.n_experts
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    me = probs.mean(axis=tuple(range(probs.ndim - 1)))
    counts = jnp.zeros((e,), jnp.float32).at[idx.reshape(-1)].add(1.0)
    frac = counts / jnp.clip(counts.sum(), 1.0)
    return e * jnp.sum(me * frac)


def moe_forward(cfg: ModelConfig, p: Params, x: jnp.ndarray
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    if cfg.moe_local_dispatch:
        return moe_forward_local(cfg, p, x)
    return moe_forward_global(cfg, p, x)


def moe_forward_local(cfg: ModelConfig, p: Params, x: jnp.ndarray
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Hierarchical (per-batch-row) dispatch: route/sort/capacity WITHIN
    each sequence, so every dispatch array keeps the leading batch dim —
    which stays sharded on the data axis. The global-argsort path below
    gathers all tokens to sort them (SPMD cannot shard a global sort),
    turning MoE layers collective-bound; this variant removes that at
    the cost of per-row (instead of global) capacity smoothing.
    """
    b, s, d = x.shape
    k, e = cfg.moe_top_k, cfg.n_experts
    cap = int(cfg.capacity_factor * s * k / e) + 1

    logits = x @ p["router"].astype(x.dtype)               # (B,S,E)
    w, idx = router_probs(cfg, logits)                     # (B,S,k)
    aux = aux_load_balance_loss(cfg, logits, idx)

    # GATHER-ONLY dispatch: scatter-adds partition poorly under GSPMD
    # (the scattered operand gets replicated and all-reduced), so both
    # the dispatch and the combine are expressed as sorts + gathers +
    # an inverse-permutation gather, all of which keep the batch dim
    # sharded locally.
    flat_e = idx.reshape(b, s * k)
    flat_w = w.reshape(b, s * k)
    flat_tok = jnp.broadcast_to(jnp.repeat(jnp.arange(s), k)[None],
                                (b, s * k))
    order = jnp.argsort(flat_e, axis=-1, stable=True)
    se = jnp.take_along_axis(flat_e, order, -1)
    sw = jnp.take_along_axis(flat_w, order, -1)
    stok = jnp.take_along_axis(flat_tok, order, -1)
    seg_pos = jnp.broadcast_to(jnp.arange(s * k)[None], (b, s * k))
    seg_start = jax.vmap(
        lambda row: jnp.searchsorted(row, jnp.arange(e), side="left"))(se)
    seg_end = jax.vmap(
        lambda row: jnp.searchsorted(row, jnp.arange(e), side="right"))(se)
    counts = seg_end - seg_start                           # (B, E)
    pos_in_e = seg_pos - jnp.take_along_axis(seg_start, se, -1)
    keep = pos_in_e < cap                                  # (B, S*k)

    # dispatch: slot (e, c) reads sorted pair seg_start[e] + c
    x_sorted = jnp.take_along_axis(x, stok[..., None], axis=1)  # gather
    src = seg_start[:, :, None] + jnp.arange(cap)[None, None, :]
    valid = jnp.arange(cap)[None, None, :] < counts[:, :, None]
    src_c = jnp.clip(src, 0, s * k - 1).reshape(b, e * cap)
    buf = jnp.take_along_axis(x_sorted, src_c[..., None], axis=1)
    buf = buf.reshape(b, e, cap, d) * valid[..., None].astype(x.dtype)
    buf = constrain(buf, "batch", "expert", None, None)

    gate = jnp.einsum("becd,edf->becf", buf, p["w_gate"].astype(x.dtype))
    up = jnp.einsum("becd,edf->becf", buf, p["w_up"].astype(x.dtype))
    h = gated_act(cfg, gate, up)
    h = constrain(h, "batch", "expert", None, None)
    out_e = jnp.einsum("becf,efd->becd", h, p["w_down"].astype(x.dtype))
    out_e = out_e.reshape(b, e * cap, d)

    # combine: pair -> slot gather, weight, unsort (inverse perm), then
    # a static reshape-sum over each token's k routed pairs
    slot = se * cap + jnp.clip(pos_in_e, 0, cap - 1)       # (B, S*k)
    contrib = jnp.take_along_axis(out_e, slot[..., None], axis=1) \
        * (sw * keep).astype(x.dtype)[..., None]           # sorted order
    inv = jnp.argsort(order, axis=-1)                      # inverse perm
    contrib = jnp.take_along_axis(contrib, inv[..., None], axis=1)
    out = contrib.reshape(b, s, k, d).sum(axis=2)

    if cfg.n_shared_experts:
        sp = p["shared"]
        g2 = x @ sp["w_gate"].astype(x.dtype)
        u2 = x @ sp["w_up"].astype(x.dtype)
        out = out + gated_act(cfg, g2, u2) @ sp["w_down"].astype(x.dtype)
    return constrain(out, "batch", "seq", "embed"), aux


def moe_forward_global(cfg: ModelConfig, p: Params, x: jnp.ndarray
                       ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (out, aux_loss).

    Sort-based dispatch: flatten tokens, route, stable-sort by expert id,
    pad each expert segment to a static capacity C, batch the expert
    FFNs with an (E, C, D) einsum (expert dim shardable), and scatter
    back weighted by router probs. Overflow tokens beyond capacity fall
    through via the residual (standard token dropping).
    """
    b, s, d = x.shape
    n = b * s
    k = cfg.moe_top_k
    e = cfg.n_experts
    cap = int(cfg.capacity_factor * n * k / e) + 1

    xt = x.reshape(n, d)
    logits = xt @ p["router"].astype(x.dtype)              # (N, E)
    w, idx = router_probs(cfg, logits)                     # (N,k)
    aux = aux_load_balance_loss(cfg, logits, idx)

    flat_e = idx.reshape(-1)                               # (N*k,)
    flat_w = w.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(n), k)

    order = jnp.argsort(flat_e, stable=True)               # sort by expert
    se, sw, stok = flat_e[order], flat_w[order], flat_tok[order]
    # position of each routed pair within its expert segment
    ones = jnp.ones_like(se)
    seg_pos = jnp.cumsum(ones) - 1
    seg_start_per_e = jnp.searchsorted(se, jnp.arange(e), side="left")
    pos_in_e = seg_pos - seg_start_per_e[se]
    keep = pos_in_e < cap
    slot = se * cap + jnp.where(keep, pos_in_e, cap - 1)   # (N*k,)

    # Gather tokens into (E*C, D); dropped slots get zeros via mask.
    gathered = xt[stok] * keep[:, None].astype(x.dtype)
    buf = jnp.zeros((e * cap, d), x.dtype)
    buf = buf.at[slot].add(jnp.where(keep[:, None], gathered, 0))
    buf = buf.reshape(e, cap, d)
    buf = constrain(buf, "expert", None, "embed")

    # Expert FFNs, batched over the (sharded) expert dim.
    gate = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(x.dtype))
    up = jnp.einsum("ecd,edf->ecf", buf, p["w_up"].astype(x.dtype))
    h = gated_act(cfg, gate, up)
    # expert dim already consumes the model axis; ff stays unsharded
    h = constrain(h, "expert", None, None)
    out_e = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(x.dtype))
    out_e = out_e.reshape(e * cap, d)

    # Combine: weighted scatter back to tokens.
    contrib = out_e[slot] * (sw * keep).astype(x.dtype)[:, None]
    out = jnp.zeros((n, d), x.dtype).at[stok].add(contrib)

    if cfg.n_shared_experts:
        sp = p["shared"]
        gate = xt @ sp["w_gate"].astype(x.dtype)
        up = xt @ sp["w_up"].astype(x.dtype)
        out = out + gated_act(cfg, gate, up) @ sp["w_down"].astype(x.dtype)

    out = out.reshape(b, s, d)
    return constrain(out, "batch", "seq", "embed"), aux
