"""Mamba2 mixer layer (chunked SSD) with O(1) recurrent decode state.

Used by the xlstm/zamba2-family configs ('ssm' and 'hybrid' arch types).
The heavy intra-chunk math goes through repro.kernels.ssd_scan (ops
selects the Pallas kernel or the jnp oracle).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan import ref as ssd
from repro.parallel.sharding import constrain
from .common import ModelConfig, Params, dense_init


def mamba_dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_head_dim
    return d_inner, n_heads, cfg.ssm_state, cfg.n_ssm_groups


def init_mamba2(cfg: ModelConfig, key) -> Params:
    d = cfg.d_model
    di, h, n, g = mamba_dims(cfg)
    conv_ch = di + 2 * g * n
    ks = jax.random.split(key, 4)
    return {
        # order: [z (gate), x, B, C, dt]
        "in_proj": dense_init(ks[0], (d, 2 * di + 2 * g * n + h)),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, conv_ch))
                   * 0.1).astype(jnp.float32),
        "conv_b": jnp.zeros((conv_ch,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "norm_scale": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[2], (di, d)),
    }


def _causal_depthwise_conv(x: jnp.ndarray, w: jnp.ndarray,
                           b: jnp.ndarray) -> jnp.ndarray:
    """x: (B, S, C); w: (K, C) depthwise causal conv."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(k):  # k is tiny (4): unrolled taps, no conv op needed
        out = out + xp[:, i:i + x.shape[1], :].astype(jnp.float32) \
            * w[i].astype(jnp.float32)
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def _gated_rmsnorm(x: jnp.ndarray, gate: jnp.ndarray, scale: jnp.ndarray,
                   eps: float = 1e-6) -> jnp.ndarray:
    xf = (x * jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype)
          ).astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


def _split_proj(cfg: ModelConfig, zxbcdt: jnp.ndarray):
    di, h, n, g = mamba_dims(cfg)
    z, xc, bc, cc, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + g * n, 2 * di + 2 * g * n], axis=-1)
    return z, xc, bc, cc, dt


def init_mamba_state(cfg: ModelConfig, batch: int, dtype) -> Params:
    di, h, n, g = mamba_dims(cfg)
    return {
        "ssm": jnp.zeros((batch, h, cfg.ssm_head_dim, n), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, di + 2 * g * n), dtype),
    }


def mamba2_forward(cfg: ModelConfig, p: Params, x: jnp.ndarray,
                   state: Optional[Params] = None,
                   use_kernel: bool = False
                   ) -> Tuple[jnp.ndarray, Optional[Params]]:
    """x: (B, S, D). state=None -> full sequence; else single-token."""
    b, s, _ = x.shape
    di, h, n, g = mamba_dims(cfg)
    hp = cfg.ssm_head_dim

    zxbcdt = x @ p["in_proj"].astype(x.dtype)
    z, xc, bc, cc, dt_pre = _split_proj(cfg, zxbcdt)
    conv_in = jnp.concatenate([xc, bc, cc], axis=-1)

    if state is None:
        conv_out = _causal_depthwise_conv(conv_in, p["conv_w"], p["conv_b"])
        new_state = None
    else:
        assert s == 1
        hist = jnp.concatenate([state["conv"], conv_in], axis=1)
        out = jnp.einsum("bkc,kc->bc", hist.astype(jnp.float32),
                         p["conv_w"].astype(jnp.float32)) \
            + p["conv_b"].astype(jnp.float32)
        conv_out = out[:, None, :].astype(x.dtype)
        new_conv = hist[:, 1:, :]
        new_state = {"conv": new_conv}

    conv_out = jax.nn.silu(conv_out.astype(jnp.float32)).astype(x.dtype)
    xs, bs, cs = jnp.split(conv_out, [di, di + g * n], axis=-1)
    xs = xs.reshape(b, s, h, hp)
    xs = constrain(xs, "batch", "seq", "heads", None)
    # group-broadcast B, C to heads
    bs = jnp.repeat(bs.reshape(b, s, g, n), h // g, axis=2)
    cs = jnp.repeat(cs.reshape(b, s, g, n), h // g, axis=2)
    dt = jax.nn.softplus(dt_pre.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))

    if state is None:
        if use_kernel:
            from repro.kernels.ssd_scan import ops as ssd_ops
            y, _ = ssd_ops.ssd_scan(xs, dt, a, bs, cs, chunk=cfg.ssm_chunk,
                                    d_skip=p["d_skip"])
        else:
            chunk = min(cfg.ssm_chunk, s) if s % min(cfg.ssm_chunk, s) == 0 \
                else 1
            # pick the largest chunk that divides S
            chunk = max(c for c in (cfg.ssm_chunk, 64, 32, 16, 8, 4, 2, 1)
                        if s % c == 0 and c <= s)
            y, _ = ssd.ssd_reference(xs, dt, a, bs, cs, chunk=chunk,
                                     d_skip=p["d_skip"])
    else:
        y, new_ssm = ssd.ssd_step(state["ssm"], xs[:, 0], dt[:, 0],
                                  a, bs[:, 0], cs[:, 0], p["d_skip"])
        y = y[:, None]
        new_state["ssm"] = new_ssm

    y = y.reshape(b, s, di)
    y = _gated_rmsnorm(y, z, p["norm_scale"])
    out = y @ p["out_proj"].astype(x.dtype)
    return constrain(out, "batch", "seq", "embed"), new_state
