"""Attention variants: GQA (RoPE / M-RoPE / none, optional QKV bias,
sliding window) and MLA (DeepSeek-V2 multi-head latent attention), with
a unified circular-buffer KV cache for full and sliding-window decode.

The einsum path here is the oracle/dry-run path; the Pallas flash
kernel (repro.kernels.flash_attention) is an optional drop-in for the
training forward (see ops.use_flash).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.parallel.sharding import constrain
from .common import (ModelConfig, Params, apply_mrope, apply_rope,
                     dense_init)

NEG_INF = -1e30


# ----------------------------------------------------------------------
# Params
# ----------------------------------------------------------------------

def init_attention(cfg: ModelConfig, key, d_model: Optional[int] = None,
                   n_heads: Optional[int] = None,
                   n_kv_heads: Optional[int] = None,
                   head_dim: Optional[int] = None) -> Params:
    d = d_model or cfg.d_model
    h = n_heads or cfg.n_heads
    k = n_kv_heads or cfg.n_kv_heads
    hd = head_dim or cfg.head_dim
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {
        "w_q": dense_init(ks[0], (d, h * hd)),
        "w_k": dense_init(ks[1], (d, k * hd)),
        "w_v": dense_init(ks[2], (d, k * hd)),
        "w_o": dense_init(ks[3], (h * hd, d)),
    }
    if cfg.qkv_bias:
        p["b_q"] = jnp.zeros((h * hd,), jnp.float32)
        p["b_kv"] = jnp.zeros((k * hd,), jnp.float32)
        p["b_v"] = jnp.zeros((k * hd,), jnp.float32)
    return p


def init_mla(cfg: ModelConfig, key) -> Params:
    d, h = cfg.d_model, cfg.n_heads
    qk = cfg.qk_nope_dim + cfg.qk_rope_dim
    ks = jax.random.split(key, 6)
    return {
        "w_dq": dense_init(ks[0], (d, cfg.q_lora_rank)),
        "q_norm_scale": jnp.ones((cfg.q_lora_rank,), jnp.float32),
        "w_uq": dense_init(ks[1], (cfg.q_lora_rank, h * qk)),
        "w_dkv": dense_init(ks[2], (d, cfg.kv_lora_rank + cfg.qk_rope_dim)),
        "kv_norm_scale": jnp.ones((cfg.kv_lora_rank,), jnp.float32),
        "w_ukv": dense_init(ks[3], (cfg.kv_lora_rank,
                                    h * (cfg.qk_nope_dim + cfg.v_head_dim))),
        "w_o": dense_init(ks[4], (h * cfg.v_head_dim, d)),
    }


# ----------------------------------------------------------------------
# KV cache (circular buffer; window == buffer length)
# ----------------------------------------------------------------------

def init_kv_cache(batch: int, window: int, n_kv_heads: int, head_dim: int,
                  dtype) -> Params:
    return {
        "k": jnp.zeros((batch, window, n_kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, window, n_kv_heads, head_dim), dtype),
        # absolute position held by each slot; -1 = empty
        "slot_pos": jnp.full((batch, window), -1, jnp.int32),
        "next_pos": jnp.zeros((), jnp.int32),
    }


def init_mla_cache(cfg: ModelConfig, batch: int, window: int,
                   dtype) -> Params:
    return {
        "c_kv": jnp.zeros((batch, window, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, window, cfg.qk_rope_dim), dtype),
        "slot_pos": jnp.full((batch, window), -1, jnp.int32),
        "next_pos": jnp.zeros((), jnp.int32),
    }


def _cache_write(cache: Params, names: Tuple[str, ...], values,
                 pos: jnp.ndarray) -> Params:
    """Write one token (B, 1, ...) at slot ``pos % window``."""
    window = cache["slot_pos"].shape[1]
    slot = (pos % window).astype(jnp.int32)
    new = dict(cache)
    for name, val in zip(names, values):
        arr = cache[name]
        new[name] = jax.lax.dynamic_update_slice_in_dim(
            arr, val.astype(arr.dtype), slot, axis=1)
    b = cache["slot_pos"].shape[0]
    new["slot_pos"] = jax.lax.dynamic_update_slice_in_dim(
        cache["slot_pos"], jnp.broadcast_to(pos, (b, 1)).astype(jnp.int32),
        slot, axis=1)
    new["next_pos"] = (pos + 1).astype(jnp.int32)
    return new


# ----------------------------------------------------------------------
# Core attention math
# ----------------------------------------------------------------------

def _gqa_scores_mask(q, k, q_pos, k_pos, window: int):
    """q: (B,S,H,D) k: (B,T,K,D); returns weighted values via fp32
    softmax with causal + sliding-window + validity masking."""
    b, s, h, d = q.shape
    t, kh = k.shape[1], k.shape[2]
    g = h // kh
    qg = q.reshape(b, s, kh, g, d)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(d)
    causal = k_pos[:, None, :] <= q_pos[:, :, None]           # (B,S,T)
    valid = k_pos[:, None, :] >= 0
    mask = causal & valid
    if window:
        mask &= (q_pos[:, :, None] - k_pos[:, None, :]) < window
    scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return probs, g


def _gqa_attend(q, k, v, q_pos, k_pos, window: int) -> jnp.ndarray:
    probs, g = _gqa_scores_mask(q, k, q_pos, k_pos, window)
    b, s, h, _ = q.shape
    dv = v.shape[-1]  # may differ from the q/k dim (MLA)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v.astype(jnp.float32))
    return out.reshape(b, s, h, dv).astype(q.dtype)


# ----------------------------------------------------------------------
# GQA forward (train / prefill / decode)
# ----------------------------------------------------------------------

def attention_forward(cfg: ModelConfig, p: Params, x: jnp.ndarray,
                      positions: jnp.ndarray,
                      cache: Optional[Params] = None,
                      window: int = 0,
                      n_heads: Optional[int] = None,
                      n_kv_heads: Optional[int] = None,
                      head_dim: Optional[int] = None,
                      use_flash: bool = False
                      ) -> Tuple[jnp.ndarray, Optional[Params]]:
    """positions: (B, S) absolute token positions, or (B, S, 3) for
    M-RoPE. cache=None -> full-sequence (train/prefill); cache given ->
    single-token decode (S == 1)."""
    h = n_heads or cfg.n_heads
    kh = n_kv_heads or cfg.n_kv_heads
    hd = head_dim or cfg.head_dim
    b, s, _ = x.shape

    q = x @ p["w_q"].astype(x.dtype)
    k = x @ p["w_k"].astype(x.dtype)
    v = x @ p["w_v"].astype(x.dtype)
    if cfg.qkv_bias:
        q = q + p["b_q"].astype(x.dtype)
        k = k + p["b_kv"].astype(x.dtype)
        v = v + p["b_v"].astype(x.dtype)
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, kh, hd)
    v = v.reshape(b, s, kh, hd)
    q = constrain(q, "batch", "seq", "heads", "head_dim")
    k = constrain(k, "batch", "seq", "kv_heads", "head_dim")

    if cfg.pos_type == "rope":
        pos1 = positions if positions.ndim == 2 else positions[..., 0]
        q = apply_rope(q, pos1, cfg.rope_theta)
        k = apply_rope(k, pos1, cfg.rope_theta)
    elif cfg.pos_type == "mrope":
        pos3 = positions if positions.ndim == 3 else \
            jnp.repeat(positions[..., None], 3, axis=-1)
        q = apply_mrope(q, pos3, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, pos3, cfg.rope_theta, cfg.mrope_sections)

    pos1 = positions[..., 0] if positions.ndim == 3 else positions
    if cache is None:
        out = _flash_or_ref(cfg, q, k, v, pos1, pos1, window, use_flash)
        new_cache = None
    else:
        assert s == 1, "decode expects one new token"
        cur = pos1[:, 0]  # (B,)
        new_cache = _cache_write(cache, ("k", "v"),
                                 (k, v), cur[0].astype(jnp.int32))
        kc, vc = new_cache["k"], new_cache["v"]
        out = _gqa_attend(q, kc.astype(q.dtype), vc.astype(q.dtype),
                          pos1, new_cache["slot_pos"], window)
    out = constrain(out, "batch", "seq", "heads", "head_dim")
    out = out.reshape(b, s, h * hd) @ p["w_o"].astype(x.dtype)
    return constrain(out, "batch", "seq", "embed"), new_cache


def _flash_or_ref(cfg, q, k, v, q_pos, k_pos, window, use_flash):
    if use_flash:
        from repro.kernels.flash_attention import ops as flash_ops
        return flash_ops.flash_attention(q, k, v, causal=True,
                                         window=window or None)
    return _gqa_attend(q, k, v, q_pos, k_pos, window)


# ----------------------------------------------------------------------
# MLA forward (DeepSeek-V2)
# ----------------------------------------------------------------------

def _rms(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


def mla_forward(cfg: ModelConfig, p: Params, x: jnp.ndarray,
                positions: jnp.ndarray,
                cache: Optional[Params] = None,
                window: int = 0
                ) -> Tuple[jnp.ndarray, Optional[Params]]:
    b, s, _ = x.shape
    h = cfg.n_heads
    nope, rope_d, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    pos1 = positions[..., 0] if positions.ndim == 3 else positions

    # queries through the low-rank bottleneck
    cq = _rms(x @ p["w_dq"].astype(x.dtype), p["q_norm_scale"])
    q = (cq @ p["w_uq"].astype(x.dtype)).reshape(b, s, h, nope + rope_d)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, pos1, cfg.rope_theta)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    q = constrain(q, "batch", "seq", "heads", "head_dim")

    # compressed kv + shared rotary key
    dkv = x @ p["w_dkv"].astype(x.dtype)            # (B,S,lora+rope)
    c_kv = _rms(dkv[..., :cfg.kv_lora_rank], p["kv_norm_scale"])
    k_rope = apply_rope(dkv[..., None, cfg.kv_lora_rank:], pos1,
                        cfg.rope_theta)             # (B,S,1,rope)

    def expand_kv(c):
        kv = (c @ p["w_ukv"].astype(x.dtype)).reshape(
            c.shape[0], c.shape[1], h, nope + vd)
        return kv[..., :nope], kv[..., nope:]

    if cache is None:
        k_nope, v = expand_kv(c_kv)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope, k_nope.shape[:3] + (rope_d,))],
            axis=-1)
        out = _gqa_attend(q, k, v, pos1, pos1, window)
        new_cache = None
    elif not cfg.mla_absorb:
        assert s == 1
        cur = pos1[0, 0].astype(jnp.int32)
        new_cache = _cache_write(cache, ("c_kv", "k_rope"),
                                 (c_kv, k_rope[:, :, 0, :]), cur)
        k_nope, v = expand_kv(new_cache["c_kv"].astype(x.dtype))
        kr = new_cache["k_rope"].astype(x.dtype)[:, :, None, :]
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(kr, k_nope.shape[:3] + (rope_d,))],
            axis=-1)
        out = _gqa_attend(q, k, v, pos1, new_cache["slot_pos"], window)
    else:
        # Absorbed decode: score/attend directly in the compressed
        # c_kv space. q_nope.k_nope == (q_nope W_uk).c_kv, so results
        # are bit-for-bit the same math at O(kv_lora) per cached token
        # instead of re-expanding k/v over the whole cache each step.
        assert s == 1
        cur = pos1[0, 0].astype(jnp.int32)
        new_cache = _cache_write(cache, ("c_kv", "k_rope"),
                                 (c_kv, k_rope[:, :, 0, :]), cur)
        r = cfg.kv_lora_rank
        w_ukv = p["w_ukv"].astype(x.dtype).reshape(r, h, nope + vd)
        w_uk, w_uv = w_ukv[..., :nope], w_ukv[..., nope:]
        q_c = jnp.einsum("bshn,rhn->bshr", q_nope, w_uk)   # (B,1,H,r)
        ckv_cache = new_cache["c_kv"].astype(x.dtype)      # (B,T,r)
        kr_cache = new_cache["k_rope"].astype(x.dtype)     # (B,T,rope)
        scores = (jnp.einsum("bshr,btr->bhst", q_c.astype(jnp.float32),
                             ckv_cache.astype(jnp.float32))
                  + jnp.einsum("bshp,btp->bhst",
                               q_rope.astype(jnp.float32),
                               kr_cache.astype(jnp.float32)))
        scores = scores / math.sqrt(nope + rope_d)
        k_pos = new_cache["slot_pos"]
        mask = (k_pos[:, None, :] <= pos1[:, :, None]) \
            & (k_pos[:, None, :] >= 0)
        if window:
            mask &= (pos1[:, :, None] - k_pos[:, None, :]) < window
        scores = jnp.where(mask[:, None, :, :], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)            # (B,H,1,T)
        ctx = jnp.einsum("bhst,btr->bshr", probs,
                         ckv_cache.astype(jnp.float32))    # (B,1,H,r)
        out = jnp.einsum("bshr,rhv->bshv", ctx,
                         w_uv.astype(jnp.float32)).astype(x.dtype)

    out = out.reshape(b, s, h * vd) @ p["w_o"].astype(x.dtype)
    return constrain(out, "batch", "seq", "embed"), new_cache
