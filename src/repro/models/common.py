"""Shared model substrate: config dataclass, initializers, norms,
embeddings, rotary position encodings (incl. M-RoPE).

Everything is pure-functional JAX: a module is an ``init_*`` returning a
params pytree (nested dicts of jnp arrays) and an ``apply``-style
function. No flax dependency.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

Params = Any  # nested dict pytree


# ----------------------------------------------------------------------
# Config
# ----------------------------------------------------------------------

@dataclass(frozen=True)
class ModelConfig:
    """One config covers all assigned architecture families; unused
    fields are inert for a given ``arch_type``."""

    name: str
    arch_type: str                 # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // n_heads

    # norm / activation / embedding
    norm_type: str = "rmsnorm"     # rmsnorm | layernorm | nonparametric_ln
    act: str = "swiglu"            # swiglu | gelu
    tie_embeddings: bool = False
    pos_type: str = "rope"         # rope | mrope | sinusoidal | none
    rope_theta: float = 10_000.0
    mrope_sections: Tuple[int, ...] = (16, 24, 24)   # qwen2-vl (t, h, w)
    qkv_bias: bool = False
    sliding_window: int = 0        # 0 = full attention

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 1
    moe_d_ff: int = 0
    first_k_dense: int = 0
    # per-batch-row (hierarchical) dispatch keeps routing local to the
    # data shard — removes the global-sort all-gather (see ffn.py)
    moe_local_dispatch: bool = False
    capacity_factor: float = 1.25
    router_type: str = "softmax"   # softmax | sigmoid

    # MLA (DeepSeek-V2)
    use_mla: bool = False
    # absorbed-MLA decode (DeepSeek-V2 weight absorption): attend in the
    # compressed kv space instead of expanding k/v over the whole cache
    # every step — mathematically identical, O(r) per cached token.
    mla_absorb: bool = False
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128

    # SSM (Mamba2)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    n_ssm_groups: int = 1

    # hybrid (Zamba2): shared attention block applied every k SSM layers
    shared_attn_every: int = 0

    # xLSTM
    use_xlstm: bool = False
    slstm_every: int = 8           # 7:1 mLSTM:sLSTM ratio
    xlstm_proj_factor: float = 2.0
    xlstm_qk_dim: int = 256        # per-head q/k width (mLSTM)

    # audio (MusicGen): EnCodec codebooks
    n_codebooks: int = 0

    # vlm (Qwen2-VL): stub vision frontend supplies patch embeddings
    vision_stub: bool = False

    # numerics / training
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: str = "none"            # none | full
    # long-context decode mode: 'window' uses sliding-window KV cache,
    # 'recurrent' means O(1) state (ssm/xlstm), 'full' keeps everything
    long_context_mode: str = "window"

    # dry-run probe: disable scan-over-layers (XLA cost analysis counts
    # a scan body once; unrolled reduced-depth probes recover true
    # per-layer costs — see launch/dryrun.py)
    force_unscanned: bool = False

    # provenance
    source: str = ""

    def __post_init__(self) -> None:
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim",
                               self.d_model // max(self.n_heads, 1))

    @property
    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def is_recurrent(self) -> bool:
        return self.arch_type == "ssm" or self.use_xlstm

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ----------------------------------------------------------------------
# Initializers
# ----------------------------------------------------------------------

def dense_init(key, shape: Sequence[int], in_axis: int = -2,
               dtype=jnp.float32) -> jnp.ndarray:
    """Truncated-normal fan-in init (matches common LM practice)."""
    fan_in = shape[in_axis] if len(shape) > 1 else shape[0]
    std = 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, tuple(shape),
                                        dtype=jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32) -> jnp.ndarray:
    return (jax.random.normal(key, tuple(shape), dtype=jnp.float32)
            * 0.02).astype(dtype)


# ----------------------------------------------------------------------
# Norms
# ----------------------------------------------------------------------

def init_norm(cfg: ModelConfig, d: Optional[int] = None) -> Params:
    d = d or cfg.d_model
    if cfg.norm_type == "rmsnorm":
        return {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm_type == "layernorm":
        return {"scale": jnp.ones((d,), jnp.float32),
                "bias": jnp.zeros((d,), jnp.float32)}
    if cfg.norm_type == "nonparametric_ln":   # OLMo
        return {}
    raise ValueError(cfg.norm_type)


def apply_norm(cfg: ModelConfig, p: Params, x: jnp.ndarray,
               eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if cfg.norm_type == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps)
        if cfg.norm_type == "layernorm":
            out = out * p["scale"] + p["bias"]
    return out.astype(x.dtype)


# ----------------------------------------------------------------------
# Rotary embeddings
# ----------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    angles = angles[..., None, :]                      # (..., S, 1, D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions3: jnp.ndarray, theta: float,
                sections: Tuple[int, ...]) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE.

    x: (B, S, H, D); positions3: (B, S, 3) — (temporal, height, width)
    indices. The D/2 frequency slots are partitioned into ``sections``
    (t, h, w); each section rotates by its own position stream.
    """
    d = x.shape[-1]
    half = d // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(d, theta)                       # (half,)
    # Build per-slot position: (B, S, half)
    parts = []
    start = 0
    for i, sec in enumerate(sections):
        pos = positions3[..., i].astype(jnp.float32)   # (B, S)
        parts.append(jnp.broadcast_to(pos[..., None],
                                      pos.shape + (sec,)))
        start += sec
    slot_pos = jnp.concatenate(parts, axis=-1)         # (B, S, half)
    angles = slot_pos * freqs                          # (B, S, half)
    angles = angles[..., None, :]                      # (B, S, 1, half)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq_len: int, d_model: int,
                         offset: jnp.ndarray | int = 0) -> jnp.ndarray:
    """MusicGen-style sinusoidal embeddings, (S, D)."""
    pos = jnp.arange(seq_len, dtype=jnp.float32) + offset
    half = d_model // 2
    freqs = jnp.exp(-math.log(10_000.0)
                    * jnp.arange(half, dtype=jnp.float32) / half)
    ang = pos[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ----------------------------------------------------------------------
# Activations
# ----------------------------------------------------------------------

def gated_act(cfg: ModelConfig, gate: jnp.ndarray,
              up: jnp.ndarray) -> jnp.ndarray:
    if cfg.act == "swiglu":
        return jax.nn.silu(gate) * up
    if cfg.act == "gelu":
        return jax.nn.gelu(gate) * up
    raise ValueError(cfg.act)
