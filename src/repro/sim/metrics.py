"""Metrics: JCR, JCT percentiles, time-weighted utilization (paper §4)."""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np

from .simulator import SimResult


def jct_percentiles(result: SimResult,
                    qs: Sequence[float] = (50, 90, 99)) -> Dict[str, float]:
    jcts = np.array([j.jct for j in result.completed], dtype=np.float64)
    if jcts.size == 0:
        return {f"p{int(q)}": float("nan") for q in qs}
    return {f"p{int(q)}": float(np.percentile(jcts, q)) for q in qs}


def time_weighted_utilization(result: SimResult) -> Dict[str, float]:
    """Utilization sampled as a step function over event times; the paper
    plots the per-run time series as a CDF — we report its time-weighted
    mean and percentiles."""
    samples = result.utilization_samples
    if len(samples) < 2:
        return {"mean": 0.0, "p50": 0.0, "p90": 0.0}
    ts = np.array([t for t, _ in samples])
    us = np.array([u for _, u in samples])
    widths = np.diff(ts)
    vals, w = us[:-1], widths
    mask = w > 0
    vals, w = vals[mask], w[mask]
    if vals.size == 0:
        return {"mean": float(us.mean()), "p50": float(us.mean()),
                "p90": float(us.mean())}
    order = np.argsort(vals)
    vals, w = vals[order], w[order]
    cum = np.cumsum(w) / w.sum()

    def wq(q: float) -> float:
        return float(vals[np.searchsorted(cum, q)])

    return {"mean": float((vals * w).sum() / w.sum()),
            "p50": wq(0.50), "p90": wq(0.90)}


def utilization_cdf(result: SimResult, grid: int = 101) -> Tuple[np.ndarray, np.ndarray]:
    """(utilization levels, CDF) — time-weighted, for Fig-4-style output."""
    samples = result.utilization_samples
    ts = np.array([t for t, _ in samples])
    us = np.array([u for _, u in samples])
    w = np.diff(ts)
    vals = us[:-1]
    levels = np.linspace(0.0, 1.0, grid)
    cdf = np.array([(w[vals <= lv]).sum() for lv in levels]) / max(w.sum(), 1e-12)
    return levels, cdf


def summarize(result: SimResult) -> Dict[str, float]:
    out: Dict[str, float] = {"jcr": result.jcr}
    out.update({f"jct_{k}": v for k, v in jct_percentiles(result).items()})
    util = time_weighted_utilization(result)
    out.update({f"util_{k}": v for k, v in util.items()})
    out["num_jobs"] = len(result.jobs)
    out["num_dropped"] = len(result.dropped)
    return out


def aggregate(summaries: List[Dict[str, float]]) -> Dict[str, float]:
    """Average metric dicts across runs (paper averages 100 runs)."""
    keys = summaries[0].keys()
    return {k: float(np.nanmean([s[k] for s in summaries])) for k in keys}
