"""Chaos layer: seeded fault generation, injection, and observation.

The paper evaluates RFold on a *healthy* 4096-node torus; this module
opens the axis the eval was missing — how each policy degrades and
recovers when the fabric is not healthy. Three roles, split like an
orchestrator/evaluator pair:

* :class:`FaultGenerator` — turns a seeded :class:`FaultConfig` into a
  deterministic timeline of :class:`FaultEvent`\\ s (node failures,
  link cuts, OCS-port failures, each optionally followed by a repair).
  Targets are drawn as *flat node indices* and concretized per cluster
  model, so the same seed fails the same physical machines under every
  policy — the cross-policy comparison is apples to apples.

* **Injection** (:class:`FaultInjector`) — translates events into
  model operations: compute victims, let the caller evict them, apply
  the fault. The models emit ``fault``/``repair``
  :class:`~repro.core.events.TopologyEvent`\\ s on the same listener
  plumbing a scheduler service uses for SETUP/RELEASE, and refuse
  (``FaultConflictError``) to fail a resource that still hosts a job —
  eviction-before-fault is enforced, never assumed.

* :class:`ChaosObserver` — records degradation and recovery per run:
  utilization dip depth, re-queue depth, time-to-recover, jobs killed
  vs migrated. Pure observation: it never mutates simulator state, so
  attaching one cannot change a schedule (parity-tested).

Event flow (see DESIGN.md §Chaos layer for the full diagram)::

    FaultGenerator --(FaultEvent timeline)--> Simulator event heap
        Simulator --victims?--> FaultInjector --> model.jobs_on/...
        Simulator --evict victims--> policy.release (+ bookkeeping)
        Simulator --> FaultInjector.apply --> model.fail_* (TopologyEvent)
        Simulator --replan victims--> policy.try_place
            placed   -> migrated   (new completion, work preserved)
            unplaced -> preempted  (re-queued at the head)
            infeasible -> killed   (dropped)
        ChaosObserver <-- on_fault/on_repair/on_preempt/... hooks
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.reconfig import ReconfigTorus
from repro.core.torus import StaticTorus

NODE, LINK, OCS_PORT = "node", "link", "ocs_port"
FAULT, REPAIR = "fault", "repair"


def _detuple(x):
    """Recursively listify -> tuple-ize (JSON round-trip normalizer)."""
    if isinstance(x, (list, tuple)):
        return tuple(_detuple(v) for v in x)
    return int(x) if isinstance(x, (bool, np.integer)) else x


@dataclass(frozen=True)
class FaultEvent:
    """One injected fabric transition.

    ``action``  — ``"fault"`` | ``"repair"``.
    ``kind``    — ``"node"`` | ``"link"`` | ``"ocs_port"``.
    ``targets`` — canonical tuples: 3-coords (static nodes), 4-cells
                  (reconfig nodes, ``(cube, x, y, z)``), ``(u, v)``
                  coordinate pairs (links), or cube ids (OCS ports).
    """

    time: float
    action: str
    kind: str
    targets: Tuple = ()

    def to_wire(self) -> dict:
        """JSON-lines-protocol payload (tuples become lists)."""
        return {"time": self.time, "action": self.action,
                "kind": self.kind, "targets": list(self.targets)}

    @staticmethod
    def from_wire(d: dict) -> "FaultEvent":
        return FaultEvent(time=float(d["time"]), action=str(d["action"]),
                          kind=str(d["kind"]),
                          targets=_detuple(d.get("targets", ())))


@dataclass(frozen=True)
class FaultConfig:
    """Seeded chaos schedule. Counts are *events*, not nodes: one node
    fault takes down ``nodes_per_fault`` machines at once (a rack/PSU
    blast radius). ``mttr_frac`` is the repair delay as a fraction of
    the trace horizon; ``window`` bounds fault times to the middle of
    the trace so degradation and recovery are both observable."""

    seed: int = 0
    num_node_faults: int = 0
    nodes_per_fault: int = 4
    num_fabric_faults: int = 0       # OCS ports (reconfig) / link cuts (static)
    mttr_frac: float = 0.25
    window: Tuple[float, float] = (0.05, 0.6)
    repair: bool = True

    @property
    def total_events(self) -> int:
        return self.num_node_faults + self.num_fabric_faults


class FaultGenerator:
    """Deterministic fault-timeline sampler.

    The draw sequence is fixed (times, then targets, per event in
    order), so a (config, cluster geometry, horizon) triple always
    yields the identical timeline — the reproducibility the scenario
    determinism asserts in CI rest on."""

    def __init__(self, config: FaultConfig):
        self.config = config

    # -- target concretization -----------------------------------------
    @staticmethod
    def _node_targets(model, idxs: np.ndarray) -> Tuple:
        if isinstance(model, StaticTorus):
            return tuple(
                tuple(int(v) for v in np.unravel_index(int(i), model.dims))
                for i in idxs)
        n3 = model.cube_n ** 3
        return tuple(
            (int(i) // n3,) + tuple(
                int(v) for v in np.unravel_index(int(i) % n3,
                                                 (model.cube_n,) * 3))
            for i in idxs)

    @staticmethod
    def _link_target(model: StaticTorus, idx: int, axis: int) -> Tuple:
        u = tuple(int(v) for v in np.unravel_index(idx, model.dims))
        v = list(u)
        v[axis] = (v[axis] + 1) % model.dims[axis]
        return (u, tuple(v))

    def generate(self, model, horizon: float) -> List[FaultEvent]:
        """Timeline for one cluster model over ``[0, horizon]``,
        time-sorted with a stable draw-order tiebreak."""
        cfg = self.config
        if cfg.total_events == 0 or horizon <= 0:
            return []
        rng = np.random.default_rng(cfg.seed)
        lo, hi = cfg.window
        mttr = cfg.mttr_frac * horizon
        events: List[FaultEvent] = []
        num = model.num_xpus
        for _ in range(cfg.num_node_faults):
            t = float(horizon * rng.uniform(lo, hi))
            k = min(cfg.nodes_per_fault, num)
            idxs = np.sort(rng.choice(num, size=k, replace=False))
            targets = self._node_targets(model, idxs)
            events.append(FaultEvent(t, FAULT, NODE, targets))
            if cfg.repair:
                events.append(FaultEvent(t + mttr, REPAIR, NODE, targets))
        for _ in range(cfg.num_fabric_faults):
            t = float(horizon * rng.uniform(lo, hi))
            if isinstance(model, ReconfigTorus):
                cube = int(rng.integers(model.num_cubes))
                ev = FaultEvent(t, FAULT, OCS_PORT, (cube,))
            else:
                idx = int(rng.integers(num))
                axis = int(rng.integers(3))
                ev = FaultEvent(t, FAULT, LINK,
                                (self._link_target(model, idx, axis),))
            events.append(ev)
            if cfg.repair:
                events.append(replace(ev, time=t + mttr, action=REPAIR))
        order = sorted(range(len(events)),
                       key=lambda i: (events[i].time, i))
        return [events[i] for i in order]


class FaultInjector:
    """Model-side half of fault application: victim discovery and the
    actual state transition. The *caller* (simulator / scheduler core)
    owns eviction and replanning — this class never touches jobs."""

    def __init__(self, policy):
        self.policy = policy
        model = getattr(policy, "cluster", None)
        if model is None:
            model = getattr(policy, "torus", None)
        if model is None:
            raise TypeError(f"policy {policy!r} exposes no cluster model")
        self.model = model

    def victims(self, ev: FaultEvent) -> List[int]:
        """Job ids that must be evicted before ``ev`` can apply
        (sorted; empty for repairs)."""
        if ev.action != FAULT:
            return []
        m = self.model
        if ev.kind == NODE:
            return m.jobs_on(ev.targets)
        if ev.kind == LINK:
            return m.link_jobs([tuple(t) for t in ev.targets])
        if ev.kind == OCS_PORT:
            return m.jobs_using_ocs(ev.targets)
        raise ValueError(f"unknown fault kind {ev.kind!r}")

    def apply(self, ev: FaultEvent) -> List:
        """Apply the transition; returns the targets actually changed
        (idempotent: already-failed targets and never-failed repairs
        are skipped)."""
        m = self.model
        if ev.kind == NODE:
            if isinstance(m, StaticTorus):
                op = m.fail_nodes if ev.action == FAULT else m.repair_nodes
            else:
                op = m.fail_cells if ev.action == FAULT else m.repair_cells
            return op(ev.targets)
        if ev.kind == LINK:
            op = m.cut_link if ev.action == FAULT else m.repair_link
            return [t for t in ev.targets if op(tuple(t[0]), tuple(t[1]))]
        if ev.kind == OCS_PORT:
            op = (m.fail_ocs_port if ev.action == FAULT
                  else m.repair_ocs_port)
            return op(ev.targets)
        raise ValueError(f"unknown fault kind {ev.kind!r}")


@dataclass
class ChaosObserver:
    """Degradation/recovery recorder (pure observation).

    ``recovery_tolerance`` defines "recovered": utilization back within
    this absolute distance of the pre-fault time-weighted mean."""

    recovery_tolerance: float = 0.05

    faults: int = 0
    repairs: int = 0
    victims: int = 0
    preempted: int = 0
    migrated: int = 0
    killed: int = 0
    first_fault_t: Optional[float] = None
    last_fault_t: Optional[float] = None
    last_repair_t: Optional[float] = None
    max_queue_depth: int = 0
    requeue_depth_max: int = 0   # max queue depth while degraded
    _samples: List[Tuple[float, float, int]] = field(default_factory=list)

    # -- simulator hooks -----------------------------------------------
    def on_fault(self, t: float, ev: FaultEvent,
                 victims: Sequence[int]) -> None:
        self.faults += 1
        self.victims += len(victims)
        if self.first_fault_t is None:
            self.first_fault_t = t
        self.last_fault_t = t

    def on_repair(self, t: float, ev: FaultEvent, applied) -> None:
        self.repairs += 1
        self.last_repair_t = t

    def on_preempt(self, t: float, job) -> None:
        self.preempted += 1

    def on_migrate(self, t: float, job) -> None:
        self.migrated += 1

    def on_kill(self, t: float, job) -> None:
        self.killed += 1

    def on_sample(self, t: float, util: float, queue_depth: int) -> None:
        self._samples.append((t, util, queue_depth))
        self.max_queue_depth = max(self.max_queue_depth, queue_depth)
        if self.first_fault_t is not None and (
                self.last_repair_t is None or t <= self.last_repair_t):
            self.requeue_depth_max = max(self.requeue_depth_max,
                                         queue_depth)

    # -- metrics ---------------------------------------------------------
    @staticmethod
    def _tw_mean(samples: List[Tuple[float, float]]) -> Optional[float]:
        """Time-weighted mean of a step function given as (t, value)
        breakpoints."""
        if len(samples) < 2:
            return samples[0][1] if samples else None
        total = w = 0.0
        for (t0, u0), (t1, _) in zip(samples, samples[1:]):
            dt = t1 - t0
            total += u0 * dt
            w += dt
        return total / w if w > 0 else samples[0][1]

    def finalize(self, end_time: float) -> Dict:
        """Deterministic JSON-able degradation/recovery record."""
        us = [(t, u) for t, u, _ in self._samples]
        overall = self._tw_mean(us)
        out: Dict = {
            "faults": self.faults, "repairs": self.repairs,
            "victims": self.victims, "preempted": self.preempted,
            "migrated": self.migrated, "killed": self.killed,
            "max_queue_depth": self.max_queue_depth,
            "requeue_depth_max": self.requeue_depth_max,
            "util_overall": overall,
        }
        if self.first_fault_t is None:
            out.update({"util_pre_fault": overall, "util_dip_min": None,
                        "dip_depth": 0.0, "recovered_util": overall,
                        "time_to_recover": 0.0, "recovered": True})
            return out
        tf = self.first_fault_t
        # Recovery starts when the fabric is whole again (last repair),
        # or never does under a permanent fault — then the tail after
        # the last fault is what "recovered" means for that policy.
        t_rec = self.last_repair_t if self.last_repair_t is not None \
            else self.last_fault_t
        pre_samples = [(t, u) for t, u in us if t < tf]
        if pre_samples:
            pre_samples.append((tf, pre_samples[-1][1]))
        pre = self._tw_mean(pre_samples)
        pre = 0.0 if pre is None else pre
        degraded = [u for t, u in us if tf <= t <= t_rec]
        dip = min(degraded) if degraded else None
        tail = [(t, u) for t, u in us if t >= t_rec]
        if tail and end_time > tail[-1][0]:
            tail.append((end_time, tail[-1][1]))
        recovered_util = self._tw_mean(tail)
        if recovered_util is None:
            recovered_util = us[-1][1] if us else 0.0
        ttr = None
        thresh = pre - self.recovery_tolerance
        for t, u in tail:
            if u >= thresh:
                ttr = t - t_rec
                break
        out.update({
            "util_pre_fault": pre,
            "util_dip_min": dip,
            "dip_depth": max(0.0, pre - dip) if dip is not None else 0.0,
            "recovered_util": recovered_util,
            "time_to_recover": ttr,
            "recovered": ttr is not None,
        })
        return out
