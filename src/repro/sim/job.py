"""Job records for the discrete-event simulator."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from repro.core.geometry import JobShape


@dataclass
class Job:
    job_id: int
    arrival: float
    duration: float           # ideal contention-free runtime (seconds)
    shape: JobShape

    # Multi-tenant priority (chaos layer): larger = more important;
    # only consulted when the simulator runs with priority preemption.
    priority: int = 0

    # -- filled by the simulator --
    start: Optional[float] = None
    finish: Optional[float] = None
    dropped: bool = False
    slowdown: float = 1.0
    placement_meta: dict = field(default_factory=dict)
    # -- chaos bookkeeping (fault injection / preemption) --
    preemptions: int = 0      # evicted and re-queued
    migrations: int = 0       # evicted and immediately re-placed
    killed: bool = False      # evicted with no feasible home (dropped)
    remaining: Optional[float] = None  # ideal work left after eviction

    @property
    def size(self) -> int:
        return self.shape.size

    @property
    def scheduled(self) -> bool:
        return self.start is not None

    @property
    def jct(self) -> Optional[float]:
        """Completion time = queueing delay + (slowed) runtime."""
        if self.finish is None:
            return None
        return self.finish - self.arrival

    @property
    def queue_delay(self) -> Optional[float]:
        if self.start is None:
            return None
        return self.start - self.arrival
