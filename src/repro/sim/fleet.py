"""Fleet simulation layer: one engine, many simulators.

The eval harness runs matrices of independent seeded simulations
(runs x policies x seeds). Driven naively, each :class:`Simulator`
owns its engine call path and issues batch-1 fitmask queries — the
multi-box kernel's grid-batch axis (the ``B`` of ``(B, K, X, Y, Z)``)
never sees more than one simulator's occupancy, so the very
amortization that makes the kernel fast goes unused in production.

This module runs many simulators *concurrently inside one process* as
cooperatively-scheduled steppers and funnels their per-epoch mask work
through a shared :class:`QueryBroker`:

  * Each simulator runs on its own thread. Simulation itself is plain
    python/numpy (GIL-serialized — process pools provide CPU
    parallelism one level up, see ``repro.eval.runner``); the threads
    exist so a simulator can *block inside its placement hot path*,
    exactly at the point where it used to call the engine inline.
  * A blocked simulator's query parks in the broker. Flushes are
    **continuously scheduled** (iteration-level, in the batched-LLM-
    serving sense): a round is answered when a *quorum* of live
    steppers is parked, when *everyone* live is parked, or when the
    oldest parked query exceeds a *deadline* — the fleet never stalls
    on its slowest simulator. Queries arriving while a flush is in
    flight simply park into the next round (they are "re-queued", not
    lost), and up to ``max_inflight`` flushes may overlap: the engine
    releases the GIL (XLA runs on its own threadpool; numpy kernels
    drop it too), so overlapping flushes genuinely parallelize.
  * Coalescing rules: requests are bucketed by grid cell shape (a
    16^3 static torus never stacks with 4^3 cubes), same-bucket grids
    are concatenated on the B axis, and candidate box sets are
    unioned on K — each request gets exactly its own planes back, in
    its own box order.
  * Compiled engines see a *small, stable* set of program shapes: per
    bucket, B is padded to the fleet hint or the next power of two and
    the K axis is served from a monotone per-bucket **box table** —
    power-of-two padded while the table is still collecting boxes,
    exact-length once it stops growing — so XLA settles on one fused
    program per bucket instead of one per distinct flush union. The
    pad/no-pad decision is made per bucket from the engine's declared
    policy (``FitmaskEngine.pads_shapes``) plus bucket-local state;
    the host numpy engine is never padded (extra grids are pure waste
    there).

Why schedules stay byte-identical to the single-sim path: every
``multibox``/``free_counts`` answer is a pure per-grid-per-box
function of the submitted occupancy — batching concatenates inputs
and slices outputs, it never mixes grids — so a simulator cannot
observe whether its query was answered solo, in a quorum round of
three, or in a timeout round of one: *which* round answers a query
changes with interleaving, but the answer bytes cannot (parity-tested
across randomized interleavings, quorum fractions and timeout firings
in ``tests/test_fleet.py``; the per-sim epoch caches in the torus
models are untouched and keep deduplicating queries before they ever
reach the broker).

The broker implements the ``repro.core.maskquery`` client contract,
so installing it is one call per policy (:func:`install_mask_client`).

Containment & failover (PR 9): the broker tolerates the two ways a
fleet dies in practice.

  * **Dead steppers** — a registered simulator thread that exits
    without deactivating (killed, or a non-Python crash) would
    otherwise pin the live count forever: quorum never forms and the
    survivors hang. When ``register`` is given the thread handle (the
    :class:`Fleet` driver always passes it), parked waiters poll on a
    bounded watchdog tick, reap threads that are no longer alive
    (``steppers_reaped``), shrink the live quorum, and deliver an
    exception to any request the dead thread left parked — a killed
    stepper can delay a flush by at most the watchdog tick, never
    hang it.
  * **Dying engines** — an engine call that raises is retried once
    (``engine_retries``); if it raises again the broker fails over
    down the ``pallas → jax → numpy`` chain
    (:data:`repro.core.engineconfig.FAILOVER_CHAIN`), adopting the
    first backend that answers (``engine_failovers`` /
    ``failover_engine``) and resetting its compiled-shape bucket
    state. The first few post-failover multibox flushes are
    canary-checked against the host numpy oracle
    (``canary_checks``/``canary_mismatches``) — answers are a pure
    function of the inputs, so any mismatch is a real defect, not
    noise. Failover applies only to registry-named engines; a custom
    engine *instance* has no registry identity, so its errors
    propagate to the waiters unchanged (the historical contract).
    :meth:`QueryBroker.inject_engine_faults` arms synthetic failures
    for drills and tests.
"""
from __future__ import annotations

import hashlib
import math
import threading
import time
from collections import OrderedDict
from dataclasses import dataclass
from typing import (Any, Callable, Dict, List, Optional, Sequence,
                    Tuple)

import numpy as np

from repro.core.maskquery import Box, MaskQueryClient

# Engine-aware flush deadlines (seconds): the host engine answers a
# round in a few hundred microseconds, compiled engines in a few
# milliseconds — the deadline only exists to bound the wait for a
# quorum that never forms, so it sits a little above one flush cost.
_HOST_TIMEOUT = 0.002
_COMPILED_TIMEOUT = 0.005

_FC_CACHE_CAP = 4096       # content-addressed free-count entries
_PAD_BOX: Box = (1, 1, 1)  # K filler when a bucket's table is empty

# A bucket serves pow2-padded box tables while its table is growing
# (bounding shape churn during the growth burst) and switches to the
# exact-length table once this many consecutive flushes added no box —
# the exact program compiles once (the compile cache keys on the box
# tuple) and then every steady-state flush runs at exact K, paying
# zero pad-slot arithmetic.
_STABLE_FLUSHES = 3

# Bounded wait tick (seconds) for parked waiters while stepper threads
# are being watched: the reap latency for a dead stepper, and the
# upper bound on how long one can stall a flush.
_WATCHDOG_TICK = 0.05

# Post-failover parity canary: how many multibox flushes on the
# adopted engine are cross-checked against the host numpy oracle.
_CANARY_FLUSHES = 3


@dataclass
class BrokerStats:
    """Coalescing + scheduling counters (the fleet bench asserts
    batching really happened — ``batched_calls > 0``,
    ``mean_grids_per_call > 1`` — and reports the flush-trigger
    breakdown and padding-waste fractions)."""

    requests: int = 0          # queries submitted by simulators
    flushes: int = 0           # scheduled rounds answered
    engine_calls: int = 0      # engine invocations actually issued
    batched_calls: int = 0     # engine calls coalescing > 1 request
    grids: int = 0             # real grids stacked on the B axis
    max_grids: int = 0         # largest single-call B (real grids)
    max_coalesced: int = 0     # most requests answered by one call
    # -- continuous-scheduling breakdown --
    flush_all_parked: int = 0  # rounds triggered by everyone parked
    flush_quorum: int = 0      # rounds triggered by the quorum rule
    flush_timeout: int = 0     # rounds triggered by the deadline
    requeued: int = 0          # queries parked while a flush was live
    # -- padding accounting (compiled-engine buckets) --
    padded_grids: int = 0      # pad rows added to reach a stable B
    k_slots: int = 0           # K slots dispatched (tables, padded)
    k_needed: int = 0          # K slots actually requested
    # -- free-count fast paths --
    fc_inline: int = 0         # answered inline on the host engine
    fc_cache_hits: int = 0     # answered from the content cache
    fc_cache_misses: int = 0   # parked for a batched round
    # -- containment & failover (PR 9) --
    steppers_reaped: int = 0   # dead stepper threads reaped
    engine_retries: int = 0    # engine calls retried after an error
    engine_failovers: int = 0  # chain steps taken (engine adopted)
    canary_checks: int = 0     # post-failover flushes parity-checked
    canary_mismatches: int = 0  # canary disagreed with the host oracle
    failover_engine: Optional[str] = None  # engine currently adopted

    def record_call(self, n_requests: int, n_grids: int,
                    n_padded: int = 0) -> None:
        self.engine_calls += 1
        self.grids += n_grids
        self.padded_grids += n_padded
        self.max_grids = max(self.max_grids, n_grids)
        self.max_coalesced = max(self.max_coalesced, n_requests)
        if n_requests > 1:
            self.batched_calls += 1

    def as_dict(self) -> Dict[str, Any]:
        d = dict(self.__dict__)
        d["mean_grids_per_call"] = (
            round(self.grids / self.engine_calls, 2)
            if self.engine_calls else None)
        total_b = self.grids + self.padded_grids
        d["b_pad_waste"] = (round(self.padded_grids / total_b, 4)
                            if total_b else 0.0)
        d["k_pad_waste"] = (round(1.0 - self.k_needed / self.k_slots, 4)
                            if self.k_slots else 0.0)
        return d


class _Request:
    __slots__ = ("kind", "occ", "boxes", "result", "error", "done", "t",
                 "owner")

    def __init__(self, kind: str, occ: np.ndarray,
                 boxes: Optional[Tuple[Box, ...]] = None):
        self.kind = kind
        self.occ = occ
        self.boxes = boxes
        self.result: Optional[np.ndarray] = None
        self.error: Optional[BaseException] = None
        self.done = threading.Event()
        self.t = time.monotonic()
        # The submitting thread: lets the watchdog error out requests
        # a dead stepper left parked.
        self.owner = threading.current_thread()


class _Bucket:
    """Per-cell-shape flush state (compiled engines only): the monotone
    box table K answers are served from, and the largest padded B this
    bucket has dispatched (its stable batch shape)."""

    __slots__ = ("table", "index", "b_target", "since_growth")

    def __init__(self) -> None:
        self.table: List[Box] = []
        self.index: Dict[Box, int] = {}
        self.b_target = 0
        self.since_growth = 0  # flushes since the table last grew


class QueryBroker(MaskQueryClient):
    """Coalesces mask queries from concurrently running simulators
    into batched engine calls, scheduled continuously.

    Implements the :class:`~repro.core.maskquery.MaskQueryClient`
    contract, so a torus submits work to it exactly as it would to an
    inline client — the submitting thread just blocks until its round
    is answered. With no registered simulators (or only one live), a
    request flushes immediately: a broker is safe to use solo.

    ``engine`` is a registry name (``numpy``/``jax``/``pallas``/
    ``ref``), an engine instance, or ``None`` for the registry default
    — note the fleet path always rides an *engine*, there is no
    brokered variant of the in-torus host integral-image path (the
    numpy engine is the same arithmetic, batched).

    Flush policy — a parked round is answered when the first of these
    fires (the trigger breakdown lands in :class:`BrokerStats`):

      * **all parked**: every live stepper is waiting (the classic
        cooperative barrier; also fired by :meth:`deactivate`);
      * **quorum**: at least ``max(2, ceil(quorum * live))`` steppers
        are waiting. ``quorum=1.0`` (the default here) degenerates to
        the barrier; fleets run ``quorum < 1`` so a round never waits
        on its slowest member. ``quorum=0`` is *drain mode*: any
        parked query flushes the moment an inflight slot is free —
        batching arises from queries parking behind a live flush, not
        from timed waiting (the host-engine policy: one engine pass
        is so cheap that waiting on a timer always loses);
      * **timeout**: the oldest parked query is older than ``timeout``
        seconds (``None`` disables the deadline).

    Latecomers that park while a flush is in flight join the next
    round; up to ``max_inflight`` rounds may be answered concurrently
    (engine calls release the GIL).

    ``pad_b="auto"`` defers to the engine's ``pads_shapes`` policy:
    compiled engines get per-bucket stable shapes — B padded up to the
    fleet hint / bucket high-water power of two, K served from the
    bucket's padded box table — while the host engine always sees
    exact shapes. Padding rows and spare K slots are sliced off before
    answers are handed back, so results are unchanged.
    """

    def __init__(self, engine=None, quorum: Optional[float] = 1.0,
                 timeout: Optional[float] = None, pad_b="auto",
                 max_inflight: int = 2):
        from repro.core.engineconfig import (canonical_engine_name,
                                             default_engine_name)
        from repro.kernels.fitmask import ops
        if hasattr(engine, "multibox"):
            # Custom instance: no registry identity — never failed over.
            self.engine = engine
            self.engine_name: Optional[str] = None
        else:
            self.engine_name = (canonical_engine_name(engine)
                                if engine is not None
                                else default_engine_name())
            self.engine = ops.get_engine(engine)
        self._pad_auto = pad_b == "auto"
        self.pad_b = (bool(getattr(self.engine, "pads_shapes", False))
                      if self._pad_auto else bool(pad_b))
        self.quorum = quorum
        self.timeout = timeout
        self.max_inflight = max(1, int(max_inflight))
        self._host_free = bool(getattr(self.engine, "host_free", False))
        # Mirror the engine's host-ness on the client contract so
        # toruses can pick lazy (host) vs prefetch-all-seen (compiled)
        # mask strategies without reaching through the broker.
        self.host_free = self._host_free
        # With a hint (the fleet sets its simulator count), batches at
        # or below it pad exactly to it: single-grid-per-sim rounds —
        # the whole static-torus side — then share ONE compiled shape.
        # The *effective* hint shrinks with the live population (a
        # fleet of 8 down to 3 survivors pads to 3, not 8).
        self.pad_hint: Optional[int] = None
        self._lock = threading.Lock()
        self._active = 0
        self._pending: List[_Request] = []
        self._inflight = 0
        self._buckets: Dict[Tuple[int, ...], _Bucket] = {}
        self._fc_cache: "OrderedDict[bytes, np.ndarray]" = OrderedDict()
        # Containment & failover state (PR 9).
        self._watched: List[threading.Thread] = []  # stepper threads
        self._faults_left = 0        # armed synthetic engine failures
        self._canary_left = 0        # post-failover parity checks due
        self.stats = BrokerStats()

    # -- simulator lifecycle ------------------------------------------
    def register(self, thread: Optional[threading.Thread] = None) -> None:
        """Declare one more live simulator (call before it starts).
        With ``thread``, the watchdog tracks it: if it dies without
        deactivating, parked waiters reap it, shrink the quorum and
        error out any requests it left behind."""
        with self._lock:
            self._active += 1
            if thread is not None:
                self._watched.append(thread)

    def deactivate(self) -> None:
        """A simulator finished (or died): it submits no further
        queries. If the survivors' round is now ready (all parked, or
        quorum/deadline met), flush it — nobody else may trigger it."""
        cur = threading.current_thread()
        with self._lock:
            self._active -= 1
            # A clean exit from a watched thread unwatches it — the
            # watchdog must not double-decrement when it later dies.
            if cur in self._watched:
                self._watched.remove(cur)
            batch = self._take_round_locked(deadline_ok=True)
        if batch is not None:
            self._lead(batch)

    def _reap_locked(self) -> bool:
        """Reap watched threads that died without deactivating: shrink
        the live count (so quorum/all-parked reflect survivors only)
        and deliver an exception to any request they left parked.
        Returns True when anything was reaped."""
        dead = [t for t in self._watched
                if t.ident is not None and not t.is_alive()]
        for t in dead:
            self._watched.remove(t)
            self._active -= 1
            self.stats.steppers_reaped += 1
            for r in [r for r in self._pending if r.owner is t]:
                self._pending.remove(r)
                r.error = RuntimeError(
                    f"stepper thread {t.name!r} died with this query "
                    "parked")
                r.done.set()
        return bool(dead)

    # -- MaskQueryClient contract -------------------------------------
    def multibox(self, occ, boxes: Sequence[Box]) -> np.ndarray:
        boxes = tuple(tuple(int(v) for v in b) for b in boxes)
        return self._submit(_Request("multibox", np.asarray(occ), boxes))

    def free_counts(self, occ) -> np.ndarray:
        occ = np.asarray(occ)
        if occ.ndim != 4:
            raise ValueError("broker expects (B, X, Y, Z) occupancy, "
                             f"got shape {occ.shape}")
        if self._host_free:
            # Host reduction: cheaper than a park/flush round-trip.
            out = np.asarray(self.engine.free_counts(occ))
            with self._lock:
                self.stats.requests += 1
                self.stats.fc_inline += 1
                self.stats.record_call(1, occ.shape[0])
            return out.astype(np.int64)
        key = self._fc_key(occ)
        with self._lock:
            hit = self._fc_cache.get(key)
            if hit is not None:
                self._fc_cache.move_to_end(key)
                self.stats.requests += 1
                self.stats.fc_cache_hits += 1
                return hit.copy()
            self.stats.fc_cache_misses += 1
        return self._submit(_Request("free_counts", occ))

    @staticmethod
    def _fc_key(occ: np.ndarray) -> bytes:
        h = hashlib.blake2b(digest_size=16)
        h.update(repr(occ.shape).encode())
        h.update(np.ascontiguousarray(occ))
        return h.digest()

    def _submit(self, req: _Request) -> np.ndarray:
        if req.occ.ndim != 4:
            raise ValueError("broker expects (B, X, Y, Z) occupancy, "
                             f"got shape {req.occ.shape}")
        with self._lock:
            self._pending.append(req)
            self.stats.requests += 1
            if self._inflight:
                self.stats.requeued += 1
            batch = self._take_round_locked(deadline_ok=False)
        if batch is not None:
            self._lead(batch)
        # Park until answered; on each deadline tick, check whether a
        # waiting round (possibly ours, possibly a successor round) is
        # now flushable and lead it if so. With watched stepper threads
        # the tick is bounded by the watchdog period, so a killed
        # stepper delays a flush by at most _WATCHDOG_TICK — it can
        # never hang the broker.
        while not req.done.wait(self._wait_tick()):
            with self._lock:
                self._reap_locked()
                batch = self._take_round_locked(deadline_ok=True)
            if batch is not None:
                self._lead(batch)
        if req.error is not None:
            raise req.error
        assert req.result is not None
        return req.result

    def _wait_tick(self) -> Optional[float]:
        """Parked-waiter wakeup period: the flush deadline, bounded by
        the watchdog tick while stepper threads are being watched
        (``None`` — wait forever — only when neither applies)."""
        if self._watched:
            return (_WATCHDOG_TICK if self.timeout is None
                    else min(self.timeout, _WATCHDOG_TICK))
        return self.timeout

    # -- continuous scheduling ----------------------------------------
    def _take_round_locked(self,
                           deadline_ok: bool) -> Optional[List[_Request]]:
        """Decide (under the lock) whether a round flushes now; if so,
        claim the batch and an inflight slot and return it. The caller
        answers it outside the lock."""
        n = len(self._pending)
        if not n or self._inflight >= self.max_inflight:
            return None
        active = self._active
        if active <= 0 or n >= active:
            self.stats.flush_all_parked += 1
        elif (self.quorum is not None and self.quorum < 1.0
              and n >= max(1 if self.quorum <= 0.0 else 2,
                           math.ceil(self.quorum * active))):
            # quorum=0 is *drain mode*: any parked query flushes the
            # moment an inflight slot is free — batching arises from
            # queries that park while a flush is live, not from timed
            # waiting (the right trade when one engine pass is cheap).
            self.stats.flush_quorum += 1
        elif (deadline_ok and self.timeout is not None
              and time.monotonic() - self._pending[0].t >= self.timeout):
            self.stats.flush_timeout += 1
        else:
            return None
        batch, self._pending = self._pending, []
        self._inflight += 1
        self.stats.flushes += 1
        return batch

    def _lead(self, batch: List[_Request]) -> None:
        """Answer rounds until none is ready: the leader that finishes
        a flush immediately chains into any round that became flushable
        while it was computing (its own waiters were woken the moment
        their results landed)."""
        while batch is not None:
            try:
                self._answer(batch)
            except BaseException as e:  # noqa: BLE001 — must wake waiters
                for r in batch:
                    if r.result is None and r.error is None:
                        r.error = e
            for r in batch:
                r.done.set()
            with self._lock:
                self._inflight -= 1
                batch = self._take_round_locked(deadline_ok=True)

    # -- coalescing ----------------------------------------------------
    def _answer(self, batch: List[_Request]) -> None:
        for kind in ("multibox", "free_counts"):
            reqs = [r for r in batch if r.kind == kind]
            # Bucket by grid cell shape: only same-shape grids can
            # share an engine pass.
            by_cell: Dict[Tuple[int, ...], List[_Request]] = {}
            for r in reqs:
                by_cell.setdefault(r.occ.shape[1:], []).append(r)
            for cell, group in by_cell.items():
                if kind == "multibox":
                    self._answer_multibox(cell, group)
                else:
                    self._answer_free_counts(cell, group)

    # Per-bucket padding plan: the decision is bucket-local, not
    # engine-global — each bucket tracks its own stable B target (the
    # fleet hint capped by the live population, or its high-water
    # power of two) and its own box table.
    def _pad_target_locked(self, bucket: _Bucket, b: int) -> int:
        hint = self.pad_hint
        if hint and self._active > 0:
            hint = min(hint, self._active)
        if hint and b <= hint:
            target = hint
        else:
            target = 1 << (b - 1).bit_length()   # next power of two
        # Never shrink below the bucket's high-water shape while the
        # population is steady: reusing the compiled program beats
        # saving a pad row or two.
        if bucket.b_target >= target and (
                not hint or bucket.b_target <= max(hint, target)):
            target = bucket.b_target
        bucket.b_target = target
        return target

    def _stack(self, cell: Tuple[int, ...],
               group: List[_Request]) -> Tuple[np.ndarray, int, int]:
        """Concatenate a bucket's grids on B; returns (stacked, real_b,
        pad_rows). Compiled engines get the bucket's stable padded B."""
        occs = [r.occ for r in group]
        b = sum(o.shape[0] for o in occs)
        pad = 0
        if self.pad_b:
            with self._lock:
                bucket = self._buckets.setdefault(cell, _Bucket())
                target = self._pad_target_locked(bucket, b)
            if target > b:
                pad = target - b
                occs.append(np.zeros((pad,) + occs[0].shape[1:],
                                     dtype=occs[0].dtype))
        if len(occs) == 1:
            return occs[0], b, pad
        return np.concatenate(occs, axis=0), b, pad

    def _boxes_for(self, cell: Tuple[int, ...],
                   needed: Tuple[Box, ...]) -> Tuple[Tuple[Box, ...],
                                                     Dict[Box, int]]:
        """K plan for one flush. Host engines get exactly the needed
        union. Compiled engines are served from the bucket's monotone
        box table: power-of-two padded while the table is growing
        (spare slots filled with a *duplicate* of an existing box,
        which the fused program's trace-time dedup makes nearly free),
        then exact-length once the table has been stable for
        ``_STABLE_FLUSHES`` flushes — the steady state is one
        compiled program at exact K, reused for every flush."""
        if not self.pad_b:
            return needed, {b: k for k, b in enumerate(needed)}
        with self._lock:
            bucket = self._buckets.setdefault(cell, _Bucket())
            before = len(bucket.table)
            for b in needed:
                if b not in bucket.index:
                    bucket.index[b] = len(bucket.table)
                    bucket.table.append(b)
            if len(bucket.table) != before:
                bucket.since_growth = 0
            else:
                bucket.since_growth += 1
            table = tuple(bucket.table)
            if bucket.since_growth < _STABLE_FLUSHES:
                cap = max(1, 1 << (len(table) - 1).bit_length())
                filler = table[0] if table else _PAD_BOX
                table = table + (filler,) * (cap - len(table))
            kidx = dict(bucket.index)
            self.stats.k_slots += len(table)
            self.stats.k_needed += len(needed)
        return table, kidx

    # -- engine dispatch: retry, failover, canary ---------------------
    def inject_engine_faults(self, n: int) -> None:
        """Arm ``n`` synthetic engine failures (chaos drills / tests):
        the next ``n`` raw engine invocations raise. Two faults walk
        the full retry-then-failover path; more walk further down the
        chain."""
        with self._lock:
            self._faults_left = int(n)

    def _dispatch_engine(self, kind: str, occ: np.ndarray,
                         boxes: Optional[Tuple[Box, ...]] = None):
        """One raw invocation on the *current* engine — resolved per
        call, because failover swaps the engine underneath inflight
        flushes. Armed synthetic faults fire here, upstream of the
        real engine, so they exercise the identical recovery path."""
        with self._lock:
            if self._faults_left > 0:
                self._faults_left -= 1
                raise RuntimeError("injected engine fault")
        if kind == "multibox":
            fn = getattr(self.engine, "multibox_bucketed", None)
            if fn is not None:
                planes, free = fn(occ, boxes)
                return np.asarray(planes), np.asarray(free)
            return np.asarray(self.engine.multibox(occ, boxes)), None
        return np.asarray(self.engine.free_counts(occ)).astype(np.int64)

    def _failover_names(self) -> Tuple[str, ...]:
        if self.engine_name is None:
            return ()  # custom instance: errors propagate unchanged
        from repro.core.engineconfig import failover_candidates
        return failover_candidates(self.engine_name)

    def _adopt_engine(self, name: str) -> bool:
        """Switch to ``name`` after the current engine failed its
        retry. Compiled-shape bucket state is engine-specific and is
        dropped; the pad policy re-derives when it was ``"auto"``.
        Returns False when the backend cannot even be constructed
        (runtime not installed) — the chain just moves on."""
        from repro.kernels.fitmask import ops
        try:
            eng = ops.get_engine(name)
        except Exception:  # noqa: BLE001 — any backend boot failure
            return False
        with self._lock:
            self.engine = eng
            self.engine_name = name
            self._host_free = bool(getattr(eng, "host_free", False))
            self.host_free = self._host_free
            if self._pad_auto:
                self.pad_b = bool(getattr(eng, "pads_shapes", False))
            self._buckets = {}
            self._canary_left = _CANARY_FLUSHES
            self.stats.engine_failovers += 1
            self.stats.failover_engine = name
        return True

    def _engine_call(self, kind: str, occ: np.ndarray,
                     boxes: Optional[Tuple[Box, ...]] = None):
        """Engine invocation with containment: retry once on the same
        engine, then fail over down the chain; raises the last error
        only when the numpy floor itself failed (or the engine has no
        registry identity)."""
        last: Optional[BaseException] = None
        for attempt in range(2):
            try:
                return self._dispatch_engine(kind, occ, boxes)
            except Exception as e:  # noqa: BLE001 — contained below
                last = e
                if attempt == 0:
                    with self._lock:
                        self.stats.engine_retries += 1
        for name in self._failover_names():
            if not self._adopt_engine(name):
                continue
            try:
                return self._dispatch_engine(kind, occ, boxes)
            except Exception as e:  # noqa: BLE001 — keep walking
                last = e
        assert last is not None
        raise last

    def _maybe_canary(self, occ: np.ndarray, boxes: Tuple[Box, ...],
                      planes: np.ndarray) -> None:
        """Parity-check the first few post-failover flushes against
        the host numpy oracle. Engines agree on the fit *mask* (the
        nonzero pattern), so that is what is compared; any mismatch is
        a real defect — answers are pure functions of the inputs."""
        take = False
        with self._lock:
            if self._canary_left > 0 and self.engine_name != "numpy":
                self._canary_left -= 1
                take = True
        if not take:
            return
        from repro.kernels.fitmask import ops
        ref = np.asarray(ops.get_engine("numpy").multibox(occ, boxes))
        ok = np.array_equal(np.asarray(planes) != 0, ref != 0)
        with self._lock:
            self.stats.canary_checks += 1
            if not ok:
                self.stats.canary_mismatches += 1

    def _answer_multibox(self, cell: Tuple[int, ...],
                         group: List[_Request]) -> None:
        union = tuple(sorted({b for r in group for b in r.boxes}))
        boxes, kidx = self._boxes_for(cell, union)
        occ, real_b, pad = self._stack(cell, group)
        planes, free = self._engine_call("multibox", occ, boxes)
        self._maybe_canary(occ, boxes, planes)
        with self._lock:
            self.stats.record_call(len(group), real_b, pad)
        lo = 0
        fc_entries = []
        for r in group:
            hi = lo + r.occ.shape[0]
            sub = planes[lo:hi]
            perm = [kidx[b] for b in r.boxes]
            if perm != list(range(sub.shape[1])):
                sub = sub[:, perm]
            r.result = sub
            if free is not None and not self._host_free:
                fc_entries.append((self._fc_key(r.occ),
                                   free[lo:hi].astype(np.int64)))
            lo = hi
        if fc_entries:
            # The fused program computed free counts anyway; remember
            # them so a follow-up free_counts on the same occupancy is
            # answered without parking.
            with self._lock:
                for key, val in fc_entries:
                    self._fc_cache[key] = val
                    self._fc_cache.move_to_end(key)
                while len(self._fc_cache) > _FC_CACHE_CAP:
                    self._fc_cache.popitem(last=False)

    def _answer_free_counts(self, cell: Tuple[int, ...],
                            group: List[_Request]) -> None:
        occ, real_b, pad = self._stack(cell, group)
        out = self._engine_call("free_counts", occ)
        with self._lock:
            self.stats.record_call(len(group), real_b, pad)
        lo = 0
        for r in group:
            hi = lo + r.occ.shape[0]
            r.result = out[lo:hi]
            lo = hi


def install_mask_client(policy, client) -> None:
    """Deprecated: pass ``mask_client=`` to ``make_policy`` / the
    policy constructor instead (constructor injection). Retained as a
    delegating shim for callers holding an already-built policy."""
    model = getattr(policy, "torus", None) or getattr(policy, "cluster",
                                                      None)
    if model is None:
        raise TypeError(f"policy {policy!r} exposes no cluster model "
                        "to install a mask client on")
    import warnings
    warnings.warn("install_mask_client is deprecated; pass mask_client= "
                  "to make_policy/the policy constructor",
                  DeprecationWarning, stacklevel=2)
    model._set_mask_client(client)


class Fleet:
    """Run a set of simulation units concurrently, sharing one broker.

    Each *unit* is a callable receiving the broker (install it on your
    policy with :func:`install_mask_client`, then run the simulation)
    and returning an arbitrary result. Units run on daemon threads and
    are registered with the broker *before* any of them starts, so the
    first scheduled round already coalesces across the whole fleet.

    ``quorum``/``timeout``/``max_inflight`` default to ``"auto"`` /
    ``None``, which resolve engine-aware. The host engine gets drain
    mode (``quorum=0``, one inflight lane): its rounds are never
    padded and one engine pass is nearly free, so any parked query
    flushes as soon as the engine is idle and batching arises from
    queries parking behind the live flush — timed waiting on a cheap
    engine only ever stalls mismatched-pace fleets. Compiled engines
    keep the full barrier quorum with two inflight lanes (a
    quorum-split round is padded back up to the stable batch shape,
    doubling arithmetic for no latency win — bigger B per dispatch is
    what amortizes their overhead) plus a ~5 ms deadline: it is the
    deadline, not the quorum, that makes compiled fleets
    *continuously* scheduled — a straggler can delay a round by at
    most the timeout. Pass ``quorum=1.0, timeout=None`` for the
    strict all-parked barrier.

    ``run`` returns per-unit results in input order; the first unit
    exception (if any) is re-raised after every thread has stopped —
    a dying simulator deactivates itself, so survivors keep batching
    among themselves rather than deadlocking.
    """

    def __init__(self, engine=None, quorum="auto", timeout="auto",
                 max_inflight: Optional[int] = None):
        from repro.core.engineconfig import EngineConfig
        from repro.kernels.fitmask import ops
        if isinstance(engine, EngineConfig):
            # One typed value carries both backend and flush policy;
            # explicit kwargs (non-"auto") still win over its fields.
            if quorum == "auto":
                quorum = engine.quorum
            if timeout == "auto":
                timeout = engine.timeout
            if max_inflight is None:
                max_inflight = engine.max_inflight
            engine = engine.resolve_name()
        eng = (engine if hasattr(engine, "multibox")
               else ops.get_engine(engine))
        host = bool(getattr(eng, "host_free", False))
        if quorum == "auto":
            quorum = 0.0 if host else 1.0
        if timeout == "auto":
            timeout = _HOST_TIMEOUT if host else _COMPILED_TIMEOUT
        if max_inflight is None:
            # Host drain mode wants exactly one engine lane: queries
            # park behind the live flush and drain as one batch.
            # Compiled engines overlap two (dispatch releases the GIL).
            max_inflight = 1 if host else 2
        # Pass the *spec* (name/None/instance), not the resolved
        # singleton: a registry name gives the broker the identity the
        # failover chain keys on; an instance stays failover-exempt.
        self.broker = QueryBroker(engine, quorum=quorum, timeout=timeout,
                                  max_inflight=max_inflight)

    def run(self, units: Sequence[Callable[[QueryBroker], Any]]) -> List[Any]:
        results: List[Any] = [None] * len(units)
        errors: List[Optional[BaseException]] = [None] * len(units)
        broker = self.broker

        def work(i: int, unit: Callable[[QueryBroker], Any]) -> None:
            try:
                results[i] = unit(broker)
            except BaseException as e:  # noqa: BLE001 — reported below
                errors[i] = e
            finally:
                broker.deactivate()

        threads = [threading.Thread(target=work, args=(i, u), daemon=True)
                   for i, u in enumerate(units)]
        # Register with the thread handles *before* any unit starts:
        # the first round coalesces across the whole fleet, and the
        # watchdog can reap a unit that dies without deactivating.
        for t in threads:
            broker.register(thread=t)
        if broker.pad_hint is None:
            broker.pad_hint = len(units)
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        for e in errors:
            if e is not None:
                raise e
        return results
