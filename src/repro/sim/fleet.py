"""Fleet simulation layer: one engine, many simulators.

The eval harness runs matrices of independent seeded simulations
(runs x policies x seeds). Driven naively, each :class:`Simulator`
owns its engine call path and issues batch-1 fitmask queries — the
multi-box kernel's grid-batch axis (the ``B`` of ``(B, K, X, Y, Z)``)
never sees more than one simulator's occupancy, so the very
amortization that makes the kernel fast goes unused in production.

This module runs many simulators *concurrently inside one process* as
cooperatively-scheduled steppers and funnels their per-epoch mask work
through a shared :class:`QueryBroker`:

  * Each simulator runs on its own thread. Simulation itself is plain
    python/numpy (GIL-serialized — process pools provide CPU
    parallelism one level up, see ``repro.eval.runner``); the threads
    exist so a simulator can *block inside its placement hot path*,
    exactly at the point where it used to call the engine inline.
  * A blocked simulator's query parks in the broker. When every live
    simulator is parked (nobody runnable — the cooperative step
    boundary), the last to arrive becomes the flush leader and answers
    the whole round with genuinely batched engine calls.
  * Coalescing rules: requests are bucketed by grid cell shape (a
    16^3 static torus never stacks with 4^3 cubes), same-bucket grids
    are concatenated on the B axis, and candidate box sets are
    unioned on K — each request gets exactly its own planes back, in
    its own box order.

Why schedules stay byte-identical to the single-sim path: every
``multibox``/``free_counts`` answer is a pure per-grid-per-box
function of the submitted occupancy — batching concatenates inputs
and slices outputs, it never mixes grids — so a simulator cannot
observe whether its query was answered solo or in a round of twenty
(parity-tested in ``tests/test_fleet.py``; the per-sim epoch caches
in the torus models are untouched and keep deduplicating queries
before they ever reach the broker).

The broker implements the ``repro.core.maskquery`` client contract,
so installing it is one call per policy (:func:`install_mask_client`).
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.maskquery import Box, MaskQueryClient


@dataclass
class BrokerStats:
    """Coalescing counters (the fleet bench asserts batching really
    happened: ``batched_calls > 0`` and ``mean_grids_per_call > 1``)."""

    requests: int = 0        # queries submitted by simulators
    flushes: int = 0         # cooperative rounds answered
    engine_calls: int = 0    # engine invocations actually issued
    batched_calls: int = 0   # engine calls coalescing > 1 request
    grids: int = 0           # total grids stacked on the B axis
    max_grids: int = 0       # largest single-call B
    max_coalesced: int = 0   # most requests answered by one call

    def record_call(self, n_requests: int, n_grids: int) -> None:
        self.engine_calls += 1
        self.grids += n_grids
        self.max_grids = max(self.max_grids, n_grids)
        self.max_coalesced = max(self.max_coalesced, n_requests)
        if n_requests > 1:
            self.batched_calls += 1

    def as_dict(self) -> Dict[str, Any]:
        d = dict(self.__dict__)
        d["mean_grids_per_call"] = (
            round(self.grids / self.engine_calls, 2)
            if self.engine_calls else None)
        return d


class _Request:
    __slots__ = ("kind", "occ", "boxes", "result", "error")

    def __init__(self, kind: str, occ: np.ndarray,
                 boxes: Optional[Tuple[Box, ...]] = None):
        self.kind = kind
        self.occ = occ
        self.boxes = boxes
        self.result: Optional[np.ndarray] = None
        self.error: Optional[BaseException] = None


class QueryBroker(MaskQueryClient):
    """Coalesces mask queries from concurrently running simulators
    into batched engine calls.

    Implements the :class:`~repro.core.maskquery.MaskQueryClient`
    contract, so a torus submits work to it exactly as it would to an
    inline client — the submitting thread just blocks until the round
    is answered. With no registered simulators (or only one live), a
    request flushes immediately: a broker is safe to use solo.

    ``engine`` is a registry name (``numpy``/``jax``/``pallas``/
    ``ref``), an engine instance, or ``None`` for the registry default
    — note the fleet path always rides an *engine*, there is no
    brokered variant of the in-torus host integral-image path (the
    numpy engine is the same arithmetic, batched).

    ``pad_b`` pads each stacked batch with empty grids up to the next
    power of two, so compiled engines see a handful of stable B shapes
    instead of retracing/recompiling every jitted program per distinct
    flush size (coalescing round sizes vary as simulators drift apart
    — without padding a jax-backed fleet spends its time in XLA
    compiles). Padding rows are sliced off before answers are handed
    back, so results are unchanged. Default ``"auto"``: pad for every
    engine except host ``numpy``, where extra grids are pure waste.
    """

    def __init__(self, engine=None, pad_b="auto"):
        from repro.kernels.fitmask import ops
        self.engine = (engine if hasattr(engine, "multibox")
                       else ops.get_engine(engine))
        self.pad_b = (getattr(self.engine, "name", None) != "numpy"
                      if pad_b == "auto" else bool(pad_b))
        # With a hint (the fleet sets its simulator count), batches at
        # or below it pad exactly to it: single-grid-per-sim rounds —
        # the whole static-torus side — then share ONE compiled shape
        # instead of one per power of two.
        self.pad_hint: Optional[int] = None
        self._cv = threading.Condition()
        self._active = 0
        self._pending: List[_Request] = []
        self.stats = BrokerStats()

    # -- simulator lifecycle ------------------------------------------
    def register(self) -> None:
        """Declare one more live simulator (call before it starts)."""
        with self._cv:
            self._active += 1

    def deactivate(self) -> None:
        """A simulator finished (or died): it submits no further
        queries. If everyone still live is already parked, their round
        must flush now — nobody else will trigger it."""
        with self._cv:
            self._active -= 1
            if self._pending and len(self._pending) >= self._active:
                self._flush_locked()

    # -- MaskQueryClient contract -------------------------------------
    def multibox(self, occ, boxes: Sequence[Box]) -> np.ndarray:
        boxes = tuple(tuple(int(v) for v in b) for b in boxes)
        return self._submit(_Request("multibox", np.asarray(occ), boxes))

    def free_counts(self, occ) -> np.ndarray:
        return self._submit(_Request("free_counts", np.asarray(occ)))

    def _submit(self, req: _Request) -> np.ndarray:
        if req.occ.ndim != 4:
            raise ValueError("broker expects (B, X, Y, Z) occupancy, "
                             f"got shape {req.occ.shape}")
        with self._cv:
            self._pending.append(req)
            self.stats.requests += 1
            if len(self._pending) >= self._active:
                # Nobody left runnable: this thread is the flush leader.
                self._flush_locked()
            while req.result is None and req.error is None:
                self._cv.wait()
        if req.error is not None:
            raise req.error
        return req.result

    # -- coalescing ----------------------------------------------------
    def _flush_locked(self) -> None:
        batch, self._pending = self._pending, []
        self.stats.flushes += 1
        try:
            self._answer(batch)
        except BaseException as e:  # noqa: BLE001 — must wake waiters
            for r in batch:
                if r.result is None:
                    r.error = e
        self._cv.notify_all()

    def _answer(self, batch: List[_Request]) -> None:
        for kind in ("multibox", "free_counts"):
            reqs = [r for r in batch if r.kind == kind]
            # Bucket by grid cell shape: only same-shape grids can
            # share an engine pass.
            by_cell: Dict[Tuple[int, ...], List[_Request]] = {}
            for r in reqs:
                by_cell.setdefault(r.occ.shape[1:], []).append(r)
            for group in by_cell.values():
                if kind == "multibox":
                    self._answer_multibox(group)
                else:
                    self._answer_free_counts(group)

    def _stack(self, group: List[_Request]) -> np.ndarray:
        occs = [r.occ for r in group]
        b = sum(o.shape[0] for o in occs)
        if self.pad_b:
            if self.pad_hint and b <= self.pad_hint:
                target = self.pad_hint
            else:
                target = 1 << (b - 1).bit_length()   # next power of two
            if target > b:
                occs.append(np.zeros((target - b,) + occs[0].shape[1:],
                                     dtype=occs[0].dtype))
        if len(occs) == 1:
            return occs[0]
        return np.concatenate(occs, axis=0)

    def _answer_multibox(self, group: List[_Request]) -> None:
        union = tuple(sorted({b for r in group for b in r.boxes}))
        occ = self._stack(group)
        out = np.asarray(self.engine.multibox(occ, union))
        self.stats.record_call(len(group),
                              sum(r.occ.shape[0] for r in group))
        kidx = {b: k for k, b in enumerate(union)}
        lo = 0
        for r in group:
            hi = lo + r.occ.shape[0]
            sub = out[lo:hi]
            if r.boxes != union:   # this request's planes, its order
                sub = sub[:, [kidx[b] for b in r.boxes]]
            r.result = sub
            lo = hi

    def _answer_free_counts(self, group: List[_Request]) -> None:
        occ = self._stack(group)
        out = np.asarray(self.engine.free_counts(occ)).astype(np.int64)
        self.stats.record_call(len(group),
                              sum(r.occ.shape[0] for r in group))
        lo = 0
        for r in group:
            hi = lo + r.occ.shape[0]
            r.result = out[lo:hi]
            lo = hi


def install_mask_client(policy, client) -> None:
    """Point a placement policy's cluster model at a mask client.
    Policies expose their model as ``.torus`` (static) or ``.cluster``
    (reconfigurable); both models implement ``set_mask_client``."""
    model = getattr(policy, "torus", None) or getattr(policy, "cluster",
                                                      None)
    if model is None:
        raise TypeError(f"policy {policy!r} exposes no cluster model "
                        "to install a mask client on")
    model.set_mask_client(client)


class Fleet:
    """Run a set of simulation units concurrently, sharing one broker.

    Each *unit* is a callable receiving the broker (install it on your
    policy with :func:`install_mask_client`, then run the simulation)
    and returning an arbitrary result. Units run on daemon threads and
    are registered with the broker *before* any of them starts, so the
    first cooperative round already coalesces across the whole fleet.

    ``run`` returns per-unit results in input order; the first unit
    exception (if any) is re-raised after every thread has stopped —
    a dying simulator deactivates itself, so survivors keep batching
    among themselves rather than deadlocking.
    """

    def __init__(self, engine=None):
        self.broker = QueryBroker(engine)

    def run(self, units: Sequence[Callable[[QueryBroker], Any]]) -> List[Any]:
        results: List[Any] = [None] * len(units)
        errors: List[Optional[BaseException]] = [None] * len(units)
        broker = self.broker

        def work(i: int, unit: Callable[[QueryBroker], Any]) -> None:
            try:
                results[i] = unit(broker)
            except BaseException as e:  # noqa: BLE001 — reported below
                errors[i] = e
            finally:
                broker.deactivate()

        for _ in units:
            broker.register()
        if broker.pad_hint is None:
            broker.pad_hint = len(units)
        threads = [threading.Thread(target=work, args=(i, u), daemon=True)
                   for i, u in enumerate(units)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        for e in errors:
            if e is not None:
                raise e
        return results
