"""Topology-aware, job-level discrete-event simulator (paper §4).

Admission is fixed to FIFO with head-of-line blocking, exactly as in the
paper: an unschedulable-but-compatible job blocks all later jobs until
resources free up; a job whose *shape* is incompatible with the cluster
(cannot be placed even when empty) is removed from the system and the
scheduler proceeds.

Jobs occupy exclusive XPUs/links by construction (the policies enforce
shapes), so runtime is contention-free; placements whose rings cannot
close (no wrap-around available) run with a configurable slowdown,
defaulting to the 17 % penalty the paper measured for non-ideal
placements on TPU v2 (§3.1).

Chaos extensions (see ``repro.sim.faults``): a seeded fault timeline
rides the same event heap (``CHAOS`` events). A fault on resources
hosting jobs evicts the victims *before* the model transitions (the
models enforce this), preserves their remaining work (checkpoint-resume
assumption), and replans each through the policy: re-placed now →
**migrated**; re-queued at the head → **preempted**; in
``fault_mode="kill"`` victims are fail-stopped instead (**killed**).
``priority_preemption`` adds multi-tenant semantics: the queue orders
by priority and a blocked high-priority head may evict lower-priority
running jobs. All of it is pay-for-play — with no faults, no observer
and no priorities, schedules are byte-identical to the paper baseline
(parity-tested).
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.core.allocator import PlacementPolicy, shape_key
from repro.core.geometry import Dims
from .job import Job

ARRIVAL, COMPLETION, CHAOS = 0, 1, 2


@dataclass
class SimResult:
    jobs: List[Job]
    utilization_samples: List[Tuple[float, float]]  # (time, utilization)
    policy_name: str
    # Degradation/recovery record (ChaosObserver.finalize) when the run
    # carried an observer; None for plain paper-baseline runs.
    chaos: Optional[dict] = field(default=None)

    @property
    def completed(self) -> List[Job]:
        return [j for j in self.jobs if j.finish is not None]

    @property
    def dropped(self) -> List[Job]:
        return [j for j in self.jobs if j.dropped]

    @property
    def jcr(self) -> float:
        """Job completion rate: scheduled / total (paper Table 1)."""
        if not self.jobs:
            return 1.0
        return sum(1 for j in self.jobs if j.scheduled) / len(self.jobs)


class Simulator:
    """``backfill=True`` enables aggressive backfilling (beyond-paper,
    §5 of the paper invites revisiting admission): jobs behind a blocked
    head may start if they fit now. The paper's FIFO head-of-line
    blocking is the default.

    ``faults`` is a time-sorted :class:`~repro.sim.faults.FaultEvent`
    sequence (see :class:`~repro.sim.faults.FaultGenerator`);
    ``observer`` a :class:`~repro.sim.faults.ChaosObserver` (or
    anything with its hooks); ``fault_mode`` picks eviction semantics
    (``"migrate"``: work-preserving replan; ``"kill"``: fail-stop);
    ``priority_preemption`` orders the queue by ``Job.priority`` and
    lets a blocked head evict lower-priority running jobs."""

    def __init__(self, policy: PlacementPolicy, jobs: Sequence[Job],
                 broken_ring_slowdown: float = 1.17,
                 backfill: bool = False, gated: bool = True,
                 faults: Sequence = (), observer=None,
                 fault_mode: str = "migrate",
                 priority_preemption: bool = False):
        if fault_mode not in ("migrate", "kill"):
            raise ValueError(f"unknown fault_mode {fault_mode!r}")
        self.policy = policy
        self.jobs = sorted(jobs, key=lambda j: j.arrival)
        self.broken_ring_slowdown = broken_ring_slowdown
        self.backfill = backfill
        # Event-driven drain watermark: a head job that failed to place
        # can only be unblocked by a COMPLETION (arrivals never free
        # capacity under FIFO), so arrival events behind a blocked head
        # skip the placement retry entirely. Backfill mode gets the
        # per-shape analogue: a shape that failed to place stays
        # infeasible until the next completion (placements only consume
        # capacity, rotations share feasibility), so queued jobs whose
        # canonical shape already failed skip the retry. ``gated=False``
        # restores the naive retry-on-every-event behaviour (parity
        # oracle). Chaos events (faults, repairs, preemptions) all
        # reset the watermark: they change capacity in both directions.
        self.gated = gated
        self.faults = list(faults)
        self.observer = observer
        self.fault_mode = fault_mode
        self.priority_preemption = bool(priority_preemption)
        self._injector = None
        if self.faults:
            from .faults import FaultInjector
            self._injector = FaultInjector(policy)
        self._head_blocked = False
        self._infeasible_shapes: Set[Dims] = set()
        self.queue: List[Job] = []
        self.events: List[Tuple[float, int, int, object, int]] = []
        self._seq = itertools.count()
        # Completion generations: an eviction bumps the job's
        # generation so its stale COMPLETION event (still in the heap)
        # is discarded when popped.
        self._gen: Dict[int, int] = {}
        self._running: Dict[int, Job] = {}
        # Priority mode: stable enqueue sequence (first-arrival order)
        # so a preempted job resumes ahead of later equals.
        self._qseq: Dict[int, int] = {}
        self._qcount = itertools.count()
        self.util_samples: List[Tuple[float, float]] = []

    def _push(self, t: float, kind: int, payload, gen: int = 0) -> None:
        heapq.heappush(self.events,
                       (t, kind, next(self._seq), payload, gen))

    def _sample(self, t: float) -> None:
        u = self.policy.utilization()
        self.util_samples.append((t, u))
        if self.observer is not None:
            self.observer.on_sample(t, u, len(self.queue))

    def _enqueue(self, job: Job) -> None:
        if job.job_id not in self._qseq:
            self._qseq[job.job_id] = next(self._qcount)
        self.queue.append(job)
        if self.priority_preemption:
            self.queue.sort(
                key=lambda j: (-j.priority, self._qseq[j.job_id]))

    def _start(self, job: Job, now: float, placement) -> None:
        if job.start is None:
            job.start = now
        job.placement_meta = placement.meta
        job.slowdown = placement.meta.get("slowdown_factor") or (
            self.broken_ring_slowdown if placement.broken_rings else 1.0)
        work = job.remaining if job.remaining is not None else job.duration
        job.finish = now + work * job.slowdown
        gen = self._gen.get(job.job_id, 0) + 1
        self._gen[job.job_id] = gen
        self._running[job.job_id] = job
        self._push(job.finish, COMPLETION, job, gen)

    def _evict(self, job: Job, now: float) -> None:
        """Release a running job preserving its remaining ideal work
        (checkpoint-resume assumption) and invalidate its pending
        COMPLETION."""
        job.remaining = max(0.0, (job.finish - now) / job.slowdown)
        job.finish = None
        self.policy.release(job.job_id)
        self._running.pop(job.job_id, None)
        self._gen[job.job_id] = self._gen.get(job.job_id, 0) + 1

    # -- chaos ----------------------------------------------------------
    def _apply_fault(self, t: float, ev) -> None:
        inj = self._injector
        if ev.action == "repair":
            applied = inj.apply(ev)
            if self.observer is not None:
                self.observer.on_repair(t, ev, applied)
            # Capacity came back: every shape may be feasible again.
            self._infeasible_shapes.clear()
            return
        victims = [self._running[jid] for jid in inj.victims(ev)
                   if jid in self._running]
        for job in victims:
            self._evict(job, t)
        inj.apply(ev)
        if self.observer is not None:
            self.observer.on_fault(t, ev, [j.job_id for j in victims])
        requeue: List[Job] = []
        for job in victims:
            if self.fault_mode == "kill":
                job.dropped = True
                job.killed = True
                if self.observer is not None:
                    self.observer.on_kill(t, job)
                continue
            placement = self.policy.try_place(job.job_id, job.shape)
            if placement is not None:
                job.migrations += 1
                self._start(job, t, placement)
                if self.observer is not None:
                    self.observer.on_migrate(t, job)
            else:
                job.preemptions += 1
                requeue.append(job)
                if self.observer is not None:
                    self.observer.on_preempt(t, job)
        if requeue:
            # Evicted jobs go back to the *head* (they were already
            # admitted — FIFO order is by first admission).
            if self.priority_preemption:
                for job in requeue:
                    self._enqueue(job)
            else:
                self.queue[0:0] = requeue
        self._infeasible_shapes.clear()

    def _try_preempt_place(self, job: Job, now: float):
        """Multi-tenant preemption: evict lower-priority running jobs
        (lowest priority first, youngest first within a priority) until
        ``job`` places. Evicted jobs are re-planned like fault victims:
        re-placed immediately if the hole allows, else re-queued."""
        cands = sorted(
            (r for r in self._running.values()
             if r.priority < job.priority),
            key=lambda r: (r.priority, -r.job_id))
        free = self.policy.num_xpus - self.policy.busy_xpus
        if not cands or free + sum(r.size for r in cands) < job.size:
            return None
        placement = None
        evicted: List[Job] = []
        for r in cands:
            self._evict(r, now)
            r.preemptions += 1
            evicted.append(r)
            if self.observer is not None:
                self.observer.on_preempt(now, r)
            placement = self.policy.try_place(job.job_id, job.shape)
            if placement is not None:
                break
        for r in evicted:
            if placement is None:
                # The evictions were in vain: put the victim straight
                # back if its own hole still fits it.
                back = self.policy.try_place(r.job_id, r.shape)
                if back is not None:
                    self._start(r, now, back)
                    continue
            self._enqueue(r)
        self._infeasible_shapes.clear()
        return placement

    # -- scheduling -----------------------------------------------------
    def _drain_queue(self, now: float) -> None:
        """FIFO with head-of-line blocking + incompatible-shape removal
        (paper behaviour); with backfill, later jobs may start when the
        head is blocked; with priority preemption, a blocked head may
        evict lower-priority running jobs."""
        self._head_blocked = False
        i = 0
        while i < len(self.queue):
            job = self.queue[i]
            if not self.policy.can_ever_place(job.shape):
                job.dropped = True
                self.queue.pop(i)
                continue
            key = shape_key(job.shape)
            if (self.gated and self.backfill
                    and key in self._infeasible_shapes):
                i += 1  # same shape already failed since the last free
                continue
            placement = self.policy.try_place(job.job_id, job.shape)
            if placement is None and self.priority_preemption and i == 0:
                placement = self._try_preempt_place(job, now)
            if placement is None:
                if not self.backfill:
                    self._head_blocked = True
                    return  # head blocks
                self._infeasible_shapes.add(key)
                i += 1
                continue
            self.queue.pop(i)
            self._start(job, now, placement)

    def run(self) -> SimResult:
        for j in self.jobs:
            self._push(j.arrival, ARRIVAL, j)
        for f in self.faults:
            self._push(f.time, CHAOS, f)
        while self.events:
            t, kind, _, payload, gen = heapq.heappop(self.events)
            if kind == ARRIVAL:
                self._enqueue(payload)
                # A blocked head stays blocked across arrivals: cluster
                # state is unchanged, so the retry would fail again and
                # the new arrival cannot start ahead of it under FIFO.
                # (Priority mode excepted: a high-priority arrival may
                # preempt its way in.)
                if (self.gated and not self.backfill
                        and not self.priority_preemption
                        and self._head_blocked and len(self.queue) > 1):
                    self._sample(t)
                    continue
            elif kind == COMPLETION:
                job = payload
                if gen != self._gen.get(job.job_id, 0):
                    continue  # stale: the job was evicted after this push
                self.policy.release(job.job_id)
                self._running.pop(job.job_id, None)
                # Freed capacity may unblock any shape: reset the
                # backfill feasibility watermark.
                self._infeasible_shapes.clear()
            else:
                self._apply_fault(t, payload)
            self._drain_queue(t)
            self._sample(t)
        result = SimResult(self.jobs, self.util_samples,
                           getattr(self.policy, "name", "policy"))
        if self.observer is not None:
            end = self.util_samples[-1][0] if self.util_samples else 0.0
            result.chaos = self.observer.finalize(end)
        return result
