"""Topology-aware, job-level discrete-event simulator (paper §4).

Admission is fixed to FIFO with head-of-line blocking, exactly as in the
paper: an unschedulable-but-compatible job blocks all later jobs until
resources free up; a job whose *shape* is incompatible with the cluster
(cannot be placed even when empty) is removed from the system and the
scheduler proceeds.

Jobs occupy exclusive XPUs/links by construction (the policies enforce
shapes), so runtime is contention-free; placements whose rings cannot
close (no wrap-around available) run with a configurable slowdown,
defaulting to the 17 % penalty the paper measured for non-ideal
placements on TPU v2 (§3.1).
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass
from typing import List, Sequence, Set, Tuple

from repro.core.allocator import PlacementPolicy, shape_key
from repro.core.geometry import Dims
from .job import Job

ARRIVAL, COMPLETION = 0, 1


@dataclass
class SimResult:
    jobs: List[Job]
    utilization_samples: List[Tuple[float, float]]  # (time, utilization)
    policy_name: str

    @property
    def completed(self) -> List[Job]:
        return [j for j in self.jobs if j.finish is not None]

    @property
    def dropped(self) -> List[Job]:
        return [j for j in self.jobs if j.dropped]

    @property
    def jcr(self) -> float:
        """Job completion rate: scheduled / total (paper Table 1)."""
        if not self.jobs:
            return 1.0
        return sum(1 for j in self.jobs if j.scheduled) / len(self.jobs)


class Simulator:
    """``backfill=True`` enables aggressive backfilling (beyond-paper,
    §5 of the paper invites revisiting admission): jobs behind a blocked
    head may start if they fit now. The paper's FIFO head-of-line
    blocking is the default."""

    def __init__(self, policy: PlacementPolicy, jobs: Sequence[Job],
                 broken_ring_slowdown: float = 1.17,
                 backfill: bool = False, gated: bool = True):
        self.policy = policy
        self.jobs = sorted(jobs, key=lambda j: j.arrival)
        self.broken_ring_slowdown = broken_ring_slowdown
        self.backfill = backfill
        # Event-driven drain watermark: a head job that failed to place
        # can only be unblocked by a COMPLETION (arrivals never free
        # capacity under FIFO), so arrival events behind a blocked head
        # skip the placement retry entirely. Backfill mode gets the
        # per-shape analogue: a shape that failed to place stays
        # infeasible until the next completion (placements only consume
        # capacity, rotations share feasibility), so queued jobs whose
        # canonical shape already failed skip the retry. ``gated=False``
        # restores the naive retry-on-every-event behaviour (parity
        # oracle).
        self.gated = gated
        self._head_blocked = False
        self._infeasible_shapes: Set[Dims] = set()
        self.queue: List[Job] = []
        self.events: List[Tuple[float, int, int, Job]] = []
        self._seq = itertools.count()
        self.util_samples: List[Tuple[float, float]] = []

    def _push(self, t: float, kind: int, job: Job) -> None:
        heapq.heappush(self.events, (t, kind, next(self._seq), job))

    def _sample(self, t: float) -> None:
        self.util_samples.append((t, self.policy.utilization()))

    def _start(self, job: Job, now: float, placement) -> None:
        job.start = now
        job.placement_meta = placement.meta
        job.slowdown = placement.meta.get("slowdown_factor") or (
            self.broken_ring_slowdown if placement.broken_rings else 1.0)
        job.finish = now + job.duration * job.slowdown
        self._push(job.finish, COMPLETION, job)

    def _drain_queue(self, now: float) -> None:
        """FIFO with head-of-line blocking + incompatible-shape removal
        (paper behaviour); with backfill, later jobs may start when the
        head is blocked."""
        self._head_blocked = False
        i = 0
        while i < len(self.queue):
            job = self.queue[i]
            if not self.policy.can_ever_place(job.shape):
                job.dropped = True
                self.queue.pop(i)
                continue
            key = shape_key(job.shape)
            if (self.gated and self.backfill
                    and key in self._infeasible_shapes):
                i += 1  # same shape already failed since the last free
                continue
            placement = self.policy.try_place(job.job_id, job.shape)
            if placement is None:
                if not self.backfill:
                    self._head_blocked = True
                    return  # head blocks
                self._infeasible_shapes.add(key)
                i += 1
                continue
            self.queue.pop(i)
            self._start(job, now, placement)

    def run(self) -> SimResult:
        for j in self.jobs:
            self._push(j.arrival, ARRIVAL, j)
        while self.events:
            t, kind, _, job = heapq.heappop(self.events)
            if kind == ARRIVAL:
                self.queue.append(job)
                # A blocked head stays blocked across arrivals: cluster
                # state is unchanged, so the retry would fail again and
                # the new arrival cannot start ahead of it under FIFO.
                if (self.gated and not self.backfill and self._head_blocked
                        and len(self.queue) > 1):
                    self._sample(t)
                    continue
            else:
                self.policy.release(job.job_id)
                # Freed capacity may unblock any shape: reset the
                # backfill feasibility watermark.
                self._infeasible_shapes.clear()
            self._drain_queue(t)
            self._sample(t)
        return SimResult(self.jobs, self.util_samples,
                         getattr(self.policy, "name", "policy"))
