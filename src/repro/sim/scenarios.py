"""Named chaos scenarios: paper-eval configs for the degraded cluster.

Each scenario bundles the three chaos axes — trace shape
(:class:`~repro.traces.generator.TraceConfig` overrides), fault
schedule (:class:`~repro.sim.faults.FaultConfig` overrides) and
simulator semantics (priority preemption etc.) — into one named,
seeded, fully deterministic config. :func:`run_scenario` is the single
entry point (re-exported through ``repro.api``): it answers "how does
policy X degrade and recover under scenario Y?" with a JSON-able
record whose bytes depend only on (scenario, policy, sizes, seed) —
the determinism the scenario-matrix CI job asserts by running every
cell twice.

The five named scenarios:

* ``healthy``      — the paper's baseline: no faults, Poisson arrivals.
* ``node_churn``   — repeated multi-node failures with repair; the
  chaos-bench headline compares recovered utilization across policies
  here.
* ``ocs_degraded`` — OCS-port failures (reconfig clusters) / link cuts
  (static clusters): the fabric shrinks, not the machines.
* ``bursty``       — no faults, but hyperexponential arrival clumps
  and size-duration-correlated sampling stress queue depth.
* ``multi_tenant`` — three priority tiers with preemption enabled,
  plus light node churn.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass, field
from typing import Dict, Optional

from repro.core.allocator import make_policy
from repro.sim.faults import ChaosObserver, FaultConfig, FaultGenerator
from repro.sim.metrics import summarize
from repro.sim.simulator import SimResult, Simulator
from repro.traces.generator import TraceConfig, generate_trace


@dataclass(frozen=True)
class Scenario:
    name: str
    description: str
    trace_kw: dict = field(default_factory=dict)
    fault_kw: dict = field(default_factory=dict)
    sim_kw: dict = field(default_factory=dict)


SCENARIOS: Dict[str, Scenario] = {
    s.name: s for s in [
        Scenario(
            "healthy",
            "Paper baseline: healthy fabric, Poisson arrivals."),
        Scenario(
            "node_churn",
            "Repeated multi-node failures with repair (rack blast "
            "radius); victims migrate or re-queue at the head.",
            fault_kw=dict(num_node_faults=6, nodes_per_fault=8,
                          mttr_frac=0.15)),
        Scenario(
            "ocs_degraded",
            "Fabric faults: OCS ports die on reconfig clusters, links "
            "are cut on static tori; machines stay up.",
            fault_kw=dict(num_fabric_faults=4, mttr_frac=0.3)),
        Scenario(
            "bursty",
            "Hyperexponential arrival clumps + size-duration-"
            "correlated sampling; no faults.",
            trace_kw=dict(arrival_burstiness=0.7,
                          size_duration_corr=0.5)),
        Scenario(
            "multi_tenant",
            "Three priority tiers with preemption; light node churn.",
            trace_kw=dict(priority_levels=3),
            fault_kw=dict(num_node_faults=2, nodes_per_fault=4,
                          mttr_frac=0.2),
            sim_kw=dict(priority_preemption=True)),
    ]
}


def _fault_seed(seed: int, name: str) -> int:
    """Stable per-(seed, scenario) fault-stream seed (crc32 is
    content-defined, so it never drifts across processes/runs)."""
    return (int(seed) * 1000003 + zlib.crc32(name.encode())) % (2 ** 31)


def fault_schedule(scenario, model, jobs, seed: int) -> list:
    """The deterministic fault list for one (scenario, seed) against
    ``model`` (a cluster/torus): what :func:`run_scenario` injects, and
    what the eval runner injects when an :class:`~repro.eval.runner.
    EvalTask` carries a ``scenario`` — same seed derivation, so a
    paper-eval record and a ``run_scenario`` record of the same cell
    see byte-identical fault streams."""
    sc: Scenario = (SCENARIOS[scenario] if isinstance(scenario, str)
                    else scenario)
    horizon = max((j.arrival for j in jobs), default=0.0)
    cfg = FaultConfig(seed=_fault_seed(seed, sc.name), **sc.fault_kw)
    return FaultGenerator(cfg).generate(model, horizon)


def run_scenario(scenario, policy: str = "rfold",
                 policy_kw: Optional[dict] = None,
                 num_jobs: int = 120, seed: int = 0,
                 trace_kw: Optional[dict] = None,
                 keep_result: bool = False) -> dict:
    """Run one (scenario, policy) cell and return its deterministic
    record: trace/fault provenance, the paper summary metrics, and the
    chaos observer's degradation/recovery block.

    ``policy_kw``/``trace_kw`` size the cluster and trace (CI uses 512
    XPUs, the paper eval 4096); scenario-level overrides win over the
    caller's ``trace_kw`` for the knobs the scenario *is* (burstiness,
    correlation, priorities). ``keep_result=True`` attaches the raw
    :class:`SimResult` under the non-JSON key ``"_result"``."""
    sc: Scenario = (SCENARIOS[scenario] if isinstance(scenario, str)
                    else scenario)
    cfg = TraceConfig(**{"num_jobs": num_jobs, "seed": seed,
                         **(trace_kw or {}), **sc.trace_kw})
    jobs = generate_trace(cfg)
    pol = make_policy(policy, **(policy_kw or {}))
    injector_model = getattr(pol, "cluster", None)
    if injector_model is None:
        injector_model = pol.torus
    faults = fault_schedule(sc, injector_model, jobs, seed)
    observer = ChaosObserver()
    sim = Simulator(pol, jobs, faults=faults, observer=observer,
                    **sc.sim_kw)
    result: SimResult = sim.run()
    record = {
        "scenario": sc.name,
        "policy": getattr(pol, "name", policy),
        "seed": seed,
        "num_jobs": num_jobs,
        "num_faults": sum(1 for f in faults if f.action == "fault"),
        "summary": summarize(result),
        "chaos": result.chaos,
    }
    if keep_result:
        record["_result"] = result
    return record
