"""Asyncio allocator daemon: the long-lived scheduling service.

One :class:`AllocatorCore` behind an asyncio TCP server speaking the
JSON-lines protocol (``protocol.py``). Connections are cheap
line-loops; ops are applied on the event loop — the core is
single-threaded by construction, so op order (the thing the journal
persists) is exactly the order requests hit the loop.

The daemon can share a fleet :class:`~repro.sim.fleet.QueryBroker` as
its mask client: it registers itself like any simulator stepper, so
its placement queries coalesce into the same batched engine calls as
concurrently running simulations — serving and simulation share one
engine.

Liveness (PR 9): every request carrying a ``client`` id renews that
client's wall-clock lease; with ``lease_timeout`` configured, an
expiry loop journals a ``lease_expire`` op (resolved action included,
so replay is policy-independent) for clients that went silent, and
their jobs are requeued or released per ``lease_policy``. Pushed
events ride **bounded** per-subscriber queues drained by a writer
task each — a subscriber that stops reading is marked lagged and
dropped (connection closed) instead of buffering without bound or
stalling the dispatch path behind its dead socket.

Crash semantics: :meth:`kill` drops the server and every connection
without a final checkpoint (the crash the recovery tests simulate);
graceful ``shutdown`` (op or :meth:`stop`) writes the journal first.
Either way the WAL (``journal.py``) already holds every acknowledged
op, so even a kill loses nothing.
"""
from __future__ import annotations

import asyncio
import time
from typing import Dict, Optional, Set

from . import protocol
from .core import AllocatorCore, SchedulerConfig


class _Subscriber:
    """One event-stream consumer: its bounded queue and pump task."""

    __slots__ = ("writer", "queue", "task", "lagged")

    def __init__(self, writer: asyncio.StreamWriter, depth: int):
        self.writer = writer
        self.queue: "asyncio.Queue[dict]" = asyncio.Queue(
            maxsize=max(1, depth))
        self.task: Optional[asyncio.Task] = None
        self.lagged = False


class SchedulerDaemon:
    """Owns the core, the server socket and the subscriber set."""

    def __init__(self, config: SchedulerConfig, mask_client=None,
                 recover: bool = True):
        self.config = config
        self.mask_client = mask_client
        self.core = (AllocatorCore.recover(config, mask_client)
                     if recover else AllocatorCore(config, mask_client))
        self._server: Optional[asyncio.base_events.Server] = None
        self._subscribers: Dict[asyncio.StreamWriter, _Subscriber] = {}
        self._writers: Set[asyncio.StreamWriter] = set()
        self._closing = asyncio.Event()
        self._killed = False
        self.address: Optional[tuple] = None
        # Liveness: client id -> monotonic lease deadline.
        self._leases: Dict[str, float] = {}
        self._lease_task: Optional[asyncio.Task] = None
        self.subscribers_dropped = 0

    # -- lifecycle -----------------------------------------------------
    async def start(self) -> tuple:
        """Bind and serve; returns the (host, port) actually bound
        (``port=0`` requests an ephemeral port)."""
        self._server = await asyncio.start_server(
            self._handle, self.config.host, self.config.port)
        self.address = self._server.sockets[0].getsockname()[:2]
        if self.mask_client is not None \
                and hasattr(self.mask_client, "register"):
            # The daemon is one more live client of the shared broker.
            self.mask_client.register()
        if self.config.lease_timeout:
            self._lease_task = asyncio.get_running_loop().create_task(
                self._lease_loop())
        return self.address

    async def wait_closed(self) -> None:
        """Block until shutdown is requested, then tear down."""
        await self._closing.wait()
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
        if self._lease_task is not None:
            self._lease_task.cancel()
        for sub in list(self._subscribers.values()):
            if sub.task is not None:
                sub.task.cancel()
        for w in list(self._writers):
            w.close()
        if self.mask_client is not None \
                and hasattr(self.mask_client, "deactivate"):
            self.mask_client.deactivate()
        if not self._killed:
            self.core.sync_checkpoint()

    def stop(self) -> None:
        """Graceful stop (final checkpoint)."""
        self._closing.set()

    def kill(self) -> None:
        """Simulated crash: stop serving with NO final checkpoint —
        recovery must work from the last snapshot + the WAL tail."""
        self._killed = True
        self._closing.set()

    # -- liveness ------------------------------------------------------
    def _touch_lease(self, msg: dict) -> None:
        cid = msg.get("client")
        if cid is not None and self.config.lease_timeout:
            self._leases[str(cid)] = (time.monotonic()
                                      + self.config.lease_timeout)

    async def _lease_loop(self) -> None:
        """Expire clients that stopped sending. The expiry op is
        applied through the core exactly like a wire request — it
        journals the resolved action, so a recovered daemon replays
        the identical disposition."""
        period = max(0.01, self.config.lease_timeout / 4.0)
        while not self._closing.is_set():
            await asyncio.sleep(period)
            now = time.monotonic()
            expired = [cid for cid, dl in self._leases.items()
                       if dl <= now]
            for cid in expired:
                self._leases.pop(cid, None)
                reply, events = self.core.apply(
                    {"op": "lease_expire", "client": cid,
                     "action": self.config.lease_policy})
                if events:
                    self._broadcast(events)

    # -- connection handling -------------------------------------------
    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        self._writers.add(writer)
        try:
            while True:
                line = await reader.readline()
                if not line:
                    break
                try:
                    msg = protocol.decode(line)
                except ValueError:
                    writer.write(protocol.encode(
                        {"ok": False, "error": "bad json"}))
                    await writer.drain()
                    continue
                await self._dispatch(msg, writer)
                if self._closing.is_set():
                    break
        except (ConnectionResetError, asyncio.IncompleteReadError):
            pass
        finally:
            self._writers.discard(writer)
            self._drop_subscriber(writer, lagged=False)
            writer.close()

    async def _dispatch(self, msg: dict,
                        writer: asyncio.StreamWriter) -> None:
        op = msg.get("op")
        self._touch_lease(msg)
        if op == "subscribe":
            self._add_subscriber(writer)
            reply, events = {"ok": True, "subscribed": True}, []
        elif op == "shutdown":
            reply, events = {"ok": True, "shutdown": True}, []
        else:
            reply, events = self.core.apply(msg)
            if op == "status" and reply.get("ok"):
                # Daemon-side liveness/backpressure counters piggyback
                # on the core's snapshot.
                reply["leases"] = len(self._leases)
                reply["subscribers"] = len(self._subscribers)
                reply["subscribers_dropped"] = self.subscribers_dropped
        if "seq" in msg:
            reply["seq"] = msg["seq"]
        writer.write(protocol.encode(reply))
        await writer.drain()
        if events:
            self._broadcast(events)
        if op == "shutdown":
            self.stop()

    # -- subscribers (bounded queues, lagged-drop) ---------------------
    def _add_subscriber(self, writer: asyncio.StreamWriter) -> None:
        if writer in self._subscribers:
            return
        sub = _Subscriber(writer, self.config.subscriber_queue)
        sub.task = asyncio.get_running_loop().create_task(
            self._pump(sub))
        self._subscribers[writer] = sub

    async def _pump(self, sub: _Subscriber) -> None:
        """Per-subscriber writer: drains the bounded queue to the
        socket. Slow consumers exert backpressure *here* (the drain
        blocks this task only), never on the dispatch path."""
        try:
            while True:
                ev = await sub.queue.get()
                sub.writer.write(protocol.encode(ev))
                await sub.writer.drain()
        except (ConnectionResetError, RuntimeError, OSError,
                asyncio.CancelledError):
            pass

    def _offer(self, sub: _Subscriber, events) -> bool:
        """Enqueue events for one subscriber without ever blocking
        dispatch. Returns False when its queue overflowed — the
        subscriber is lagged and must be dropped (the alternative is
        unbounded buffering for a consumer that stopped reading)."""
        for ev in events:
            try:
                sub.queue.put_nowait(ev)
            except asyncio.QueueFull:
                sub.lagged = True
                return False
        return True

    def _broadcast(self, events) -> None:
        for writer, sub in list(self._subscribers.items()):
            if not self._offer(sub, events):
                self._drop_subscriber(writer, lagged=True)

    def _drop_subscriber(self, writer: asyncio.StreamWriter,
                         lagged: bool) -> None:
        sub = self._subscribers.pop(writer, None)
        if sub is None:
            return
        if lagged:
            self.subscribers_dropped += 1
            if sub.task is not None:
                sub.task.cancel()
            self._writers.discard(writer)
            writer.close()

    # -- convenience ---------------------------------------------------
    async def serve_forever(self) -> None:
        await self.start()
        await self.wait_closed()
