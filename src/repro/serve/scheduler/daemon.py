"""Asyncio allocator daemon: the long-lived scheduling service.

One :class:`AllocatorCore` behind an asyncio TCP server speaking the
JSON-lines protocol (``protocol.py``). Connections are cheap
line-loops; ops are applied on the event loop — the core is
single-threaded by construction, so op order (the thing the journal
persists) is exactly the order requests hit the loop.

The daemon can share a fleet :class:`~repro.sim.fleet.QueryBroker` as
its mask client: it registers itself like any simulator stepper, so
its placement queries coalesce into the same batched engine calls as
concurrently running simulations — serving and simulation share one
engine.

Liveness (PR 9): every request carrying a ``client`` id renews that
client's wall-clock lease; with ``lease_timeout`` configured, an
expiry loop journals a ``lease_expire`` op (resolved action included,
so replay is policy-independent) for clients that went silent, and
their jobs are requeued or released per ``lease_policy``. Pushed
events ride **bounded** per-subscriber queues drained by a writer
task each — a subscriber that stops reading is marked lagged and
dropped (connection closed) instead of buffering without bound or
stalling the dispatch path behind its dead socket.

Crash semantics: :meth:`kill` drops the server and every connection
without a final checkpoint (the crash the recovery tests simulate);
graceful ``shutdown`` (op or :meth:`stop`) writes the journal first.
Either way the WAL (``journal.py``) already holds every acknowledged
op, so even a kill loses nothing.

Replication & fencing (PR 10): a ``role="standby"`` daemon runs a
replication task that long-polls the primary's ``repl_pull`` op and
applies every framed record to its shadow core, so its state digest
tracks the primary record-for-record; until promoted it refuses
state-changing client ops with ``NOT_LEADER`` (redirecting to the
primary it tails). ``promote`` stops the tail, journals a new fencing
epoch and starts the lease loop — the standby *is* now the primary.
A superseded primary fences itself the moment it sees a higher epoch
(stamped on any request, or via an explicit ``fence`` op) and refuses
every write thereafter: nothing a stale leader acks can reach its
journal. In ``ack_mode="sync"`` the primary holds each journaled-op
reply until the standby's piggybacked ``acked`` cursor covers the
record (bounded by ``sync_timeout``), so an acked op survives even
primary disk loss.
"""
from __future__ import annotations

import asyncio
import base64
import time
from typing import Dict, List, Optional, Set, Tuple

from . import protocol
from .core import AllocatorCore, SchedulerConfig
from .journal import decode_frames


class _Subscriber:
    """One event-stream consumer: its bounded queue and pump task."""

    __slots__ = ("writer", "queue", "task", "lagged")

    def __init__(self, writer: asyncio.StreamWriter, depth: int):
        self.writer = writer
        self.queue: "asyncio.Queue[dict]" = asyncio.Queue(
            maxsize=max(1, depth))
        self.task: Optional[asyncio.Task] = None
        self.lagged = False


class SchedulerDaemon:
    """Owns the core, the server socket and the subscriber set."""

    def __init__(self, config: SchedulerConfig, mask_client=None,
                 recover: bool = True):
        self.config = config
        self.mask_client = mask_client
        self.core = (AllocatorCore.recover(config, mask_client)
                     if recover else AllocatorCore(config, mask_client))
        self._server: Optional[asyncio.base_events.Server] = None
        self._subscribers: Dict[asyncio.StreamWriter, _Subscriber] = {}
        self._writers: Set[asyncio.StreamWriter] = set()
        self._closing = asyncio.Event()
        self._killed = False
        self.address: Optional[tuple] = None
        # Liveness: client id -> monotonic lease deadline.
        self._leases: Dict[str, float] = {}
        self._lease_task: Optional[asyncio.Task] = None
        self.subscribers_dropped = 0
        # Replication & fencing (PR 10).
        self.role = config.role
        self.fenced = False
        # Best leader hint for NOT_LEADER redirects: a standby knows
        # the primary it tails; a fenced primary learns it from the
        # fence op (if sent) and otherwise redirects blind.
        self.known_leader: Optional[Tuple[str, int]] = config.replicate_from
        self.fenced_rejections = 0
        self.sync_timeouts = 0
        self.repl_lag = 0                 # standby: leader len - local len
        self.last_repl_error: Optional[str] = None
        self._repl_task: Optional[asyncio.Task] = None
        self._new_record = asyncio.Event()   # wakes repl_pull long-polls
        self._follower_acked = 0             # highest standby-durable len
        self._last_pull: Optional[float] = None   # follower liveness
        self._ack_waiters: List[Tuple[int, asyncio.Future]] = []

    # -- lifecycle -----------------------------------------------------
    async def start(self) -> tuple:
        """Bind and serve; returns the (host, port) actually bound
        (``port=0`` requests an ephemeral port)."""
        self._server = await asyncio.start_server(
            self._handle, self.config.host, self.config.port)
        self.address = self._server.sockets[0].getsockname()[:2]
        if self.mask_client is not None \
                and hasattr(self.mask_client, "register"):
            # The daemon is one more live client of the shared broker.
            self.mask_client.register()
        if self.config.lease_timeout and self.role == protocol.ROLE_PRIMARY:
            # A standby must not expire leases: expiries are journaled
            # ops, and only the leader writes. Started at promotion.
            self._lease_task = asyncio.get_running_loop().create_task(
                self._lease_loop())
        if self.role == protocol.ROLE_STANDBY:
            self._repl_task = asyncio.get_running_loop().create_task(
                self._replicate_loop())
        return self.address

    async def wait_closed(self) -> None:
        """Block until shutdown is requested, then tear down."""
        await self._closing.wait()
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
        if self._lease_task is not None:
            self._lease_task.cancel()
        if self._repl_task is not None:
            self._repl_task.cancel()
        for sub in list(self._subscribers.values()):
            if sub.task is not None:
                sub.task.cancel()
        for w in list(self._writers):
            w.close()
        if self.mask_client is not None \
                and hasattr(self.mask_client, "deactivate"):
            self.mask_client.deactivate()
        if not self._killed:
            self.core.sync_checkpoint()

    def stop(self) -> None:
        """Graceful stop (final checkpoint)."""
        self._closing.set()

    def kill(self) -> None:
        """Simulated crash: stop serving with NO final checkpoint —
        recovery must work from the last snapshot + the WAL tail."""
        self._killed = True
        self._closing.set()

    # -- liveness ------------------------------------------------------
    def _touch_lease(self, msg: dict) -> None:
        cid = msg.get("client")
        if cid is not None and self.config.lease_timeout:
            self._leases[str(cid)] = (time.monotonic()
                                      + self.config.lease_timeout)

    async def _lease_loop(self) -> None:
        """Expire clients that stopped sending. The expiry op is
        applied through the core exactly like a wire request — it
        journals the resolved action, so a recovered daemon replays
        the identical disposition."""
        period = max(0.01, self.config.lease_timeout / 4.0)
        while not self._closing.is_set():
            await asyncio.sleep(period)
            now = time.monotonic()
            expired = [cid for cid, dl in self._leases.items()
                       if dl <= now]
            for cid in expired:
                self._leases.pop(cid, None)
                before = len(self.core.journal)
                reply, events = self.core.apply(
                    {"op": "lease_expire", "client": cid,
                     "action": self.config.lease_policy})
                if len(self.core.journal) > before:
                    self._wake_repl()
                if events:
                    self._broadcast(events)

    # -- connection handling -------------------------------------------
    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        self._writers.add(writer)
        try:
            while True:
                line = await reader.readline()
                if not line:
                    break
                try:
                    msg = protocol.decode(line)
                except ValueError:
                    writer.write(protocol.encode(
                        {"ok": False, "error": "bad json"}))
                    await writer.drain()
                    continue
                await self._dispatch(msg, writer)
                if self._closing.is_set():
                    break
        except (ConnectionResetError, asyncio.IncompleteReadError):
            pass
        finally:
            self._writers.discard(writer)
            self._drop_subscriber(writer, lagged=False)
            writer.close()

    # Ops that journal — exactly what a non-leader must refuse. The
    # ``promote`` op is deliberately absent: it is how a standby
    # *becomes* the leader.
    _WRITE_OPS = frozenset(AllocatorCore.JOURNALED) - {"promote"}

    async def _dispatch(self, msg: dict,
                        writer: asyncio.StreamWriter) -> None:
        op = msg.get("op")
        self._touch_lease(msg)
        # Fencing: a request stamped with a higher epoch than ours is
        # proof a new leader was promoted while we were paused, dead
        # or partitioned — fence permanently before even looking at
        # the op.
        req_epoch = msg.get("epoch")
        if req_epoch is not None and int(req_epoch) > self.core.epoch:
            self.fenced = True
        if op == "subscribe":
            self._add_subscriber(writer)
            reply, events = {"ok": True, "subscribed": True}, []
        elif op == "shutdown":
            reply, events = {"ok": True, "shutdown": True}, []
        elif op == "promote":
            reply, events = await self._promote(msg)
        elif op == "fence":
            reply, events = self._fence(msg), []
        elif op == "repl_pull":
            reply, events = await self._repl_pull(msg), []
        elif op in self._WRITE_OPS and (
                self.fenced or self.role != protocol.ROLE_PRIMARY):
            # Journal-side fencing: nothing a stale or standby daemon
            # acks may reach its journal.
            self.fenced_rejections += 1
            reply, events = {"ok": False, "error": protocol.NOT_LEADER,
                             "not_leader": True, "role": self.role}, []
            if self.known_leader is not None:
                reply["leader"] = list(self.known_leader)
        else:
            before = len(self.core.journal)
            reply, events = self.core.apply(msg)
            if len(self.core.journal) > before:
                self._wake_repl()
                if self.config.ack_mode == "sync":
                    # Hold the ack until the standby has fsynced the
                    # record (or sync_timeout passes: availability
                    # over replication when the standby is down).
                    reply["replicated"] = await self._await_replicated(
                        len(self.core.journal))
            if op == "status" and reply.get("ok"):
                # Daemon-side liveness/backpressure/replication
                # counters piggyback on the core's snapshot.
                reply["leases"] = len(self._leases)
                reply["subscribers"] = len(self._subscribers)
                reply["subscribers_dropped"] = self.subscribers_dropped
                reply["role"] = self.role
                reply["fenced"] = self.fenced
                reply["repl"] = {
                    "lag": self.repl_lag,
                    "follower_acked": self._follower_acked,
                    "follower_live": self._last_pull is not None,
                    "fenced_rejections": self.fenced_rejections,
                    "sync_timeouts": self.sync_timeouts,
                    "ack_mode": self.config.ack_mode,
                    "last_error": self.last_repl_error,
                }
        # Every reply carries the fencing token: clients keep a
        # high-water mark and discard replies from superseded leaders.
        reply.setdefault("epoch", self.core.epoch)
        if "seq" in msg:
            reply["seq"] = msg["seq"]
        writer.write(protocol.encode(reply))
        await writer.drain()
        if events:
            self._broadcast(events)
        if op == "shutdown":
            self.stop()

    # -- replication & fencing (PR 10) ---------------------------------
    def _wake_repl(self) -> None:
        """New journal record: release every long-polling repl_pull."""
        ev, self._new_record = self._new_record, asyncio.Event()
        ev.set()

    def _note_acked(self, acked: int) -> None:
        """The follower's pull piggybacked its durable length; resolve
        any sync-mode acks now covered."""
        if acked <= self._follower_acked:
            return
        self._follower_acked = acked
        for target, fut in self._ack_waiters:
            if target <= acked and not fut.done():
                fut.set_result(True)
        self._ack_waiters = [(t, f) for t, f in self._ack_waiters
                             if not f.done()]

    async def _await_replicated(self, target: int) -> bool:
        """Sync ack mode: block until the standby has fsynced journal
        length ``target``, or sync_timeout (degraded ack). With no
        live follower (none ever pulled, or silent for longer than
        sync_timeout — e.g. right after a promotion) degrade
        immediately: availability over a wait nobody will satisfy."""
        if self._follower_acked >= target:
            return True
        if (self._last_pull is None
                or time.monotonic() - self._last_pull
                > self.config.sync_timeout):
            self.sync_timeouts += 1
            return False
        fut = asyncio.get_running_loop().create_future()
        self._ack_waiters.append((target, fut))
        try:
            await asyncio.wait_for(fut, self.config.sync_timeout)
            return True
        except asyncio.TimeoutError:
            self.sync_timeouts += 1
            return False

    async def _promote(self, msg: dict):
        """Become the leader: stop tailing, mint + journal a new
        fencing epoch, start expiring leases. Idempotent on a daemon
        that already leads (the core refuses a stale epoch)."""
        if self._repl_task is not None:
            self._repl_task.cancel()
            try:
                await self._repl_task
            except asyncio.CancelledError:
                pass
            self._repl_task = None
        self.role = protocol.ROLE_PRIMARY
        self.fenced = False
        reply, events = self.core.apply(
            {"op": "promote",
             **{k: msg[k] for k in ("epoch", "request_id", "client")
                if k in msg}})
        if reply.get("promoted"):
            self._wake_repl()
        # Our old follower-liveness state described the *previous*
        # leader's replication session, not ours.
        self._follower_acked = 0
        self._last_pull = None
        self.known_leader = tuple(self.address) if self.address else None
        if self.config.lease_timeout and self._lease_task is None:
            self._lease_task = asyncio.get_running_loop().create_task(
                self._lease_loop())
        reply["role"] = self.role
        return reply, events

    def _fence(self, msg: dict) -> dict:
        """Best-effort notice that a higher epoch exists. The stamped
        request already fenced us in _dispatch; this records the new
        leader's address for redirects."""
        if msg.get("leader"):
            h, p = msg["leader"]
            self.known_leader = (str(h), int(p))
        return {"ok": True, "fenced": self.fenced,
                "role": self.role}

    async def _repl_pull(self, msg: dict) -> dict:
        """Serve the replication stream: WAL-framed records from the
        follower's journal-index cursor. ``wait`` long-polls until a
        record lands (bounded by repl_poll); ``acked`` piggybacks the
        follower's durable length for sync ack mode."""
        fp = self.core.config.fingerprint()
        if msg.get("fingerprint") not in (None, fp):
            return {"ok": False, "error": "fingerprint mismatch",
                    "fingerprint": fp}
        self._last_pull = time.monotonic()
        if msg.get("acked") is not None:
            self._note_acked(int(msg["acked"]))
        index = int(msg.get("index", 0))
        if index > len(self.core.journal):
            # A follower ahead of us is tailing someone else's log
            # (or ours from a previous life): refuse, never rewind it.
            return {"ok": False, "error": "cursor past journal end",
                    "journal_len": len(self.core.journal)}
        if msg.get("wait") and index >= len(self.core.journal):
            ev = self._new_record
            try:
                await asyncio.wait_for(ev.wait(), self.config.repl_poll)
            except asyncio.TimeoutError:
                pass
        frames, nxt = self.core.journal_frames(index)
        return {"ok": True, "fingerprint": fp, "index": index,
                "next": nxt, "journal_len": len(self.core.journal),
                "role": self.role,
                "frames": base64.b64encode(frames).decode("ascii")}

    async def _replicate_loop(self) -> None:
        """Standby: tail the primary record-for-record. Long-polls
        ``repl_pull`` with our journal length as both cursor and
        durable-ack (our core fsyncs each applied record to its own
        WAL before the next pull), applies every intact frame, and
        reconnects with backoff across primary restarts — a dead
        primary leaves the standby warm and promotable, not crashed."""
        host, port = self.config.replicate_from
        fp = self.core.config.fingerprint()
        backoff = 0.05
        seq = 0
        read_timeout = self.config.repl_poll + 5.0
        while not self._closing.is_set():
            try:
                reader, writer = await asyncio.open_connection(host, port)
            except OSError as e:
                self.last_repl_error = f"{type(e).__name__}: {e}"
                await asyncio.sleep(backoff)
                backoff = min(1.0, backoff * 2)
                continue
            backoff = 0.05
            try:
                while not self._closing.is_set():
                    seq += 1
                    writer.write(protocol.encode(
                        {"op": "repl_pull", "seq": seq,
                         "fingerprint": fp,
                         "index": len(self.core.journal),
                         "acked": len(self.core.journal),
                         "wait": True}))
                    await writer.drain()
                    line = await asyncio.wait_for(reader.readline(),
                                                  read_timeout)
                    if not line:
                        break
                    resp = protocol.decode(line)
                    if not resp.get("ok"):
                        self.last_repl_error = str(resp.get("error"))
                        break
                    blob = base64.b64decode(resp.get("frames", ""))
                    records, torn = decode_frames(blob)
                    if torn:
                        self.last_repl_error = "torn frame in pull reply"
                        break   # reconnect and re-pull from our cursor
                    for rec in records:
                        if rec.get("i") != len(self.core.journal):
                            break   # gap/overlap: re-pull from cursor
                        self.core.apply_replicated(rec)
                    self.repl_lag = max(
                        0, int(resp.get("journal_len", 0))
                        - len(self.core.journal))
            except (OSError, ValueError, ConnectionResetError,
                    asyncio.TimeoutError, asyncio.IncompleteReadError) as e:
                self.last_repl_error = f"{type(e).__name__}: {e}"
            finally:
                writer.close()
            await asyncio.sleep(0.01)

    # -- subscribers (bounded queues, lagged-drop) ---------------------
    def _add_subscriber(self, writer: asyncio.StreamWriter) -> None:
        if writer in self._subscribers:
            return
        sub = _Subscriber(writer, self.config.subscriber_queue)
        sub.task = asyncio.get_running_loop().create_task(
            self._pump(sub))
        self._subscribers[writer] = sub

    async def _pump(self, sub: _Subscriber) -> None:
        """Per-subscriber writer: drains the bounded queue to the
        socket. Slow consumers exert backpressure *here* (the drain
        blocks this task only), never on the dispatch path."""
        try:
            while True:
                ev = await sub.queue.get()
                sub.writer.write(protocol.encode(ev))
                await sub.writer.drain()
        except (ConnectionResetError, RuntimeError, OSError,
                asyncio.CancelledError):
            pass

    def _offer(self, sub: _Subscriber, events) -> bool:
        """Enqueue events for one subscriber without ever blocking
        dispatch. Returns False when its queue overflowed — the
        subscriber is lagged and must be dropped (the alternative is
        unbounded buffering for a consumer that stopped reading)."""
        for ev in events:
            try:
                sub.queue.put_nowait(ev)
            except asyncio.QueueFull:
                sub.lagged = True
                return False
        return True

    def _broadcast(self, events) -> None:
        for writer, sub in list(self._subscribers.items()):
            if not self._offer(sub, events):
                self._drop_subscriber(writer, lagged=True)

    def _drop_subscriber(self, writer: asyncio.StreamWriter,
                         lagged: bool) -> None:
        sub = self._subscribers.pop(writer, None)
        if sub is None:
            return
        if lagged:
            self.subscribers_dropped += 1
            if sub.task is not None:
                sub.task.cancel()
            self._writers.discard(writer)
            writer.close()

    # -- convenience ---------------------------------------------------
    async def serve_forever(self) -> None:
        await self.start()
        await self.wait_closed()
