"""Asyncio allocator daemon: the long-lived scheduling service.

One :class:`AllocatorCore` behind an asyncio TCP server speaking the
JSON-lines protocol (``protocol.py``). Connections are cheap
line-loops; ops are applied on the event loop — the core is
single-threaded by construction, so op order (the thing the journal
persists) is exactly the order requests hit the loop.

The daemon can share a fleet :class:`~repro.sim.fleet.QueryBroker` as
its mask client: it registers itself like any simulator stepper, so
its placement queries coalesce into the same batched engine calls as
concurrently running simulations — serving and simulation share one
engine.

Crash semantics: :meth:`kill` drops the server and every connection
without a final checkpoint (the crash the recovery tests simulate);
graceful ``shutdown`` (op or :meth:`stop`) writes the journal first.
"""
from __future__ import annotations

import asyncio
from typing import Optional, Set

from . import protocol
from .core import AllocatorCore, SchedulerConfig


class SchedulerDaemon:
    """Owns the core, the server socket and the subscriber set."""

    def __init__(self, config: SchedulerConfig, mask_client=None,
                 recover: bool = True):
        self.config = config
        self.mask_client = mask_client
        self.core = (AllocatorCore.recover(config, mask_client)
                     if recover else AllocatorCore(config, mask_client))
        self._server: Optional[asyncio.base_events.Server] = None
        self._subscribers: Set[asyncio.StreamWriter] = set()
        self._writers: Set[asyncio.StreamWriter] = set()
        self._closing = asyncio.Event()
        self._killed = False
        self.address: Optional[tuple] = None

    # -- lifecycle -----------------------------------------------------
    async def start(self) -> tuple:
        """Bind and serve; returns the (host, port) actually bound
        (``port=0`` requests an ephemeral port)."""
        self._server = await asyncio.start_server(
            self._handle, self.config.host, self.config.port)
        self.address = self._server.sockets[0].getsockname()[:2]
        if self.mask_client is not None \
                and hasattr(self.mask_client, "register"):
            # The daemon is one more live client of the shared broker.
            self.mask_client.register()
        return self.address

    async def wait_closed(self) -> None:
        """Block until shutdown is requested, then tear down."""
        await self._closing.wait()
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
        for w in list(self._writers):
            w.close()
        if self.mask_client is not None \
                and hasattr(self.mask_client, "deactivate"):
            self.mask_client.deactivate()
        if not self._killed:
            self.core.sync_checkpoint()

    def stop(self) -> None:
        """Graceful stop (final checkpoint)."""
        self._closing.set()

    def kill(self) -> None:
        """Simulated crash: stop serving with NO final checkpoint —
        recovery must work from the last periodic snapshot alone."""
        self._killed = True
        self._closing.set()

    # -- connection handling -------------------------------------------
    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        self._writers.add(writer)
        try:
            while True:
                line = await reader.readline()
                if not line:
                    break
                try:
                    msg = protocol.decode(line)
                except ValueError:
                    writer.write(protocol.encode(
                        {"ok": False, "error": "bad json"}))
                    await writer.drain()
                    continue
                await self._dispatch(msg, writer)
                if self._closing.is_set():
                    break
        except (ConnectionResetError, asyncio.IncompleteReadError):
            pass
        finally:
            self._writers.discard(writer)
            self._subscribers.discard(writer)
            writer.close()

    async def _dispatch(self, msg: dict,
                        writer: asyncio.StreamWriter) -> None:
        op = msg.get("op")
        if op == "subscribe":
            self._subscribers.add(writer)
            reply, events = {"ok": True, "subscribed": True}, []
        elif op == "shutdown":
            reply, events = {"ok": True, "shutdown": True}, []
        else:
            reply, events = self.core.apply(msg)
        if "seq" in msg:
            reply["seq"] = msg["seq"]
        writer.write(protocol.encode(reply))
        await writer.drain()
        if events:
            await self._broadcast(events)
        if op == "shutdown":
            self.stop()

    async def _broadcast(self, events) -> None:
        dead = []
        # Snapshot: a connection may subscribe while we await a drain.
        for sub in list(self._subscribers):
            try:
                for ev in events:
                    sub.write(protocol.encode(ev))
                await sub.drain()
            except (ConnectionResetError, RuntimeError):
                dead.append(sub)
        for sub in dead:
            self._subscribers.discard(sub)
            self._writers.discard(sub)

    # -- convenience ---------------------------------------------------
    async def serve_forever(self) -> None:
        await self.start()
        await self.wait_closed()
