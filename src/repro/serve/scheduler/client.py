"""Blocking client for the allocator daemon + the simulator adapter.

:class:`SchedulerClient` is a plain-socket JSON-lines client: requests
are seq-tagged, replies matched by seq, and pushed events (``SETUP``/
``RECONFIG``/``RELEASE``) encountered while waiting are buffered for
:meth:`events`. One client = one connection; it is thread-safe for
request/reply (a lock serializes calls) and reconnectable — daemon
state is server-side, so a reconnected client resumes where it left
off.

:class:`RemotePolicy` adapts the client to the
:class:`~repro.core.allocator.PlacementPolicy` surface, which is what
rewires the discrete-event simulator as the service's first client:
``Simulator(RemotePolicy(client), jobs)`` runs the identical FIFO
discipline against the daemon-side allocator, and produces
byte-identical schedules to the in-process path (the daemon applies
the same deterministic ops in the same order — parity-tested and
asserted in CI).
"""
from __future__ import annotations

import socket
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

from repro.core.allocator import Placement, PlacementPolicy
from repro.core.geometry import JobShape

from . import protocol


class SchedulerClient:
    """JSON-lines request/reply + event stream over one TCP socket."""

    def __init__(self, address: Tuple[str, int], subscribe: bool = False,
                 connect_timeout: float = 5.0):
        self.address = (address[0], int(address[1]))
        self._want_subscribe = subscribe
        self._connect_timeout = connect_timeout
        self._lock = threading.Lock()
        self._seq = 0
        self._buf = bytearray()
        self._events: List[Dict[str, Any]] = []
        self._sock: Optional[socket.socket] = None
        self.connect()

    # -- connection ----------------------------------------------------
    def connect(self) -> None:
        """Dial (or re-dial) the daemon. Retries briefly so a client
        racing the daemon's bind — or reconnecting across a daemon
        restart — just works."""
        self.close()
        deadline = time.monotonic() + self._connect_timeout
        last: Optional[Exception] = None
        while time.monotonic() < deadline:
            try:
                self._sock = socket.create_connection(self.address,
                                                      timeout=2.0)
                self._sock.settimeout(None)
                break
            except OSError as e:
                last = e
                time.sleep(0.02)
        else:
            raise ConnectionError(
                f"cannot reach scheduler at {self.address}: {last}")
        self._buf = bytearray()
        if self._want_subscribe:
            self._call("subscribe")

    def close(self) -> None:
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
            self._sock = None

    # -- line transport ------------------------------------------------
    def _readline(self, timeout: Optional[float]) -> Optional[bytes]:
        """One protocol line, or None on timeout. Manual buffering so
        socket timeouts never corrupt a buffered reader."""
        assert self._sock is not None
        while True:
            nl = self._buf.find(b"\n")
            if nl >= 0:
                line = bytes(self._buf[:nl + 1])
                del self._buf[:nl + 1]
                return line
            self._sock.settimeout(timeout)
            try:
                chunk = self._sock.recv(65536)
            except socket.timeout:
                return None
            finally:
                self._sock.settimeout(None)
            if not chunk:
                raise ConnectionError("scheduler closed the connection")
            self._buf.extend(chunk)

    def _call(self, op: str, **fields) -> Dict[str, Any]:
        with self._lock:
            self._seq += 1
            seq = self._seq
            msg = {"op": op, "seq": seq, **fields}
            assert self._sock is not None, "client is closed"
            self._sock.sendall(protocol.encode(msg))
            while True:
                line = self._readline(None)
                assert line is not None
                resp = protocol.decode(line)
                if "event" in resp:
                    self._events.append(resp)
                    continue
                if resp.get("seq") == seq:
                    return resp
                # Stale reply from a pre-reconnect request: drop it.

    def call(self, op: str, **fields) -> Dict[str, Any]:
        """Raw op; raises on protocol-level errors."""
        resp = self._call(op, **fields)
        if not resp.get("ok", False):
            raise RuntimeError(f"scheduler {op} failed: "
                               f"{resp.get('error', resp)}")
        return resp

    # -- service surface -----------------------------------------------
    def submit(self, shape, job_id: Optional[int] = None) -> Dict[str, Any]:
        dims = list(shape.dims) if hasattr(shape, "dims") else list(shape)
        fields: Dict[str, Any] = {"shape": dims}
        if job_id is not None:
            fields["job_id"] = job_id
        return self.call("submit", **fields)

    def done(self, job_id: int) -> Dict[str, Any]:
        return self.call("done", job_id=job_id)

    def preempt(self, job_id: int) -> Dict[str, Any]:
        """Evict a running job back to the queue head."""
        return self.call("preempt", job_id=job_id)

    def migrate(self, job_id: int) -> Dict[str, Any]:
        """Evict + replan a running job; ``outcome`` is ``migrated``
        (with the new placement) or ``preempted`` (queued at head)."""
        return self.call("migrate", job_id=job_id)

    def fault(self, kind: str, targets) -> Dict[str, Any]:
        """Inject a fabric fault (kind = node|link|ocs_port); the
        reply lists each victim's disposition."""
        return self.call("fault", kind=kind, targets=list(targets))

    def repair(self, kind: str, targets) -> Dict[str, Any]:
        """Undo a fault; no-op for targets that never failed."""
        return self.call("repair", kind=kind, targets=list(targets))

    def status(self) -> Dict[str, Any]:
        return self.call("status")

    def sync(self) -> Dict[str, Any]:
        return self.call("sync")

    def shutdown(self) -> Dict[str, Any]:
        return self.call("shutdown")

    def events(self, max_wait: float = 0.0) -> List[Dict[str, Any]]:
        """Drain pushed events: everything buffered, plus whatever
        arrives within ``max_wait`` seconds (0 = only what is already
        here or in the socket buffer)."""
        out, self._events = self._events, []
        deadline = time.monotonic() + max_wait
        with self._lock:
            while True:
                remaining = deadline - time.monotonic()
                timeout = max(0.0, remaining) if max_wait else 0.0
                try:
                    line = self._readline(timeout or 0.000001)
                except ConnectionError:
                    break
                if line is None:
                    if remaining <= 0:
                        break
                    continue
                resp = protocol.decode(line)
                if "event" in resp:
                    out.append(resp)
        return out

    # -- raw policy ops ------------------------------------------------
    def try_place(self, job_id: int, shape) -> Dict[str, Any]:
        dims = list(shape.dims) if hasattr(shape, "dims") else list(shape)
        return self.call("try_place", job_id=job_id, shape=dims)

    def release(self, job_id: int) -> Dict[str, Any]:
        return self.call("release", job_id=job_id)

    def can_ever_place(self, shape) -> bool:
        dims = list(shape.dims) if hasattr(shape, "dims") else list(shape)
        return bool(self.call("can_ever_place", shape=dims)["feasible"])


class RemotePolicy(PlacementPolicy):
    """The in-process policy surface, served remotely.

    Plugs straight into :class:`repro.sim.simulator.Simulator` — the
    simulator becomes a client of the daemon and cannot tell the
    difference: ops arrive at the daemon in the simulator's own call
    order, the daemon-side policy is deterministic in op order, and
    placement metadata round-trips losslessly (tuples restored), so
    schedules and metrics are byte-identical to in-process runs.
    ``can_ever_place`` feasibility is cached per canonical shape by
    the base class, exactly like an in-process policy — the daemon's
    own cache makes the extra RPC cheap either way."""

    def __init__(self, client: SchedulerClient):
        super().__init__()
        self.client = client
        st = client.status()
        self.name = st["policy"]
        self._num_xpus = int(st["num_xpus"])

    @property
    def num_xpus(self) -> int:
        return self._num_xpus

    @property
    def busy_xpus(self) -> int:
        return int(self.client.status()["busy_xpus"])

    def utilization(self) -> float:
        st = self.client.status()
        return int(st["busy_xpus"]) / int(st["num_xpus"])

    def try_place(self, job_id: int, shape: JobShape) -> Optional[Placement]:
        resp = self.client.try_place(job_id, shape)
        if resp["outcome"] != protocol.PLACED:
            return None
        p = resp["placement"]
        return Placement(
            job_id=int(p["job_id"]),
            shape=JobShape(tuple(int(v) for v in p["shape"])),
            broken_rings=tuple(int(v) for v in p["broken_rings"]),
            meta=protocol.detuple(p["meta"]))

    def release(self, job_id: int) -> None:
        self.client.release(job_id)

    def _can_ever_place(self, shape: JobShape) -> bool:
        return self.client.can_ever_place(shape)
