"""Blocking client for the allocator daemon + the simulator adapter.

:class:`SchedulerClient` is a plain-socket JSON-lines client: requests
are seq-tagged, replies matched by seq, and pushed events (``SETUP``/
``RECONFIG``/``RELEASE``) encountered while waiting are buffered for
:meth:`events`. One client = one connection; it is thread-safe for
request/reply (a lock serializes calls) and reconnectable — daemon
state is server-side, so a reconnected client resumes where it left
off.

Retries are **idempotent** (PR 9): every request carries a
client-generated ``request_id`` (``"<client-id>:<seq>"``) which the
daemon dedups against its journal-backed cache, so a resent op after
a connection drop or timeout is applied exactly once. On a broken
socket or per-op timeout, :meth:`_request` reconnects with
exponential backoff + jitter and resends the *same* request_id up to
``max_retries`` times. The read buffer is cleared on every reconnect
— a half-received pre-reconnect line must never be parsed against
the new connection's stream (stale complete replies are additionally
dropped by seq). ``op_timeout`` bounds each attempt; exhausting all
attempts raises ``TimeoutError``/``ConnectionError``.

With ``lease_timeout`` configured daemon-side, call
:meth:`start_heartbeat` (the :class:`Scheduler` facade does this
automatically) so an idle client keeps its lease over submitted jobs.
Pass ``jitter`` to desynchronize a fleet of heartbeaters — after a
failover every surviving client reconnects at once, and identical
intervals would keep hammering the new leader in lockstep forever.

Failover (PR 10): the constructor accepts a single ``(host, port)``
or a *list* of servers. A connection failure rotates to the next
server; a ``NOT_LEADER`` refusal follows the reply's ``leader``
redirect when present. Every reply carries the leader's fencing
``epoch``: the client keeps the highest epoch it has witnessed,
stamps it on every request (which force-fences any stale primary it
reaches), and *discards* replies carrying a lower epoch — an ack
from a superseded leader must never be surfaced as success. Combined
with idempotent request_ids, an in-flight op rides out a leader kill
exactly-once: the resend lands on the new leader, which either
applies it fresh or serves the reply its replicated dedup cache
already holds.

:class:`RemotePolicy` adapts the client to the
:class:`~repro.core.allocator.PlacementPolicy` surface, which is what
rewires the discrete-event simulator as the service's first client:
``Simulator(RemotePolicy(client), jobs)`` runs the identical FIFO
discipline against the daemon-side allocator, and produces
byte-identical schedules to the in-process path (the daemon applies
the same deterministic ops in the same order — parity-tested and
asserted in CI).
"""
from __future__ import annotations

import random
import socket
import threading
import time
import uuid
from typing import Any, Dict, List, Optional, Tuple

from repro.core.allocator import Placement, PlacementPolicy
from repro.core.geometry import JobShape

from . import protocol


def jittered_interval(interval: float, jitter: float, u: float) -> float:
    """Scale ``interval`` into ``[1-jitter, 1+jitter]`` of itself,
    driven by a uniform draw ``u`` in [0, 1). Pure so the bounds are
    unit-testable; the heartbeat thread feeds it fresh draws."""
    jitter = max(0.0, min(1.0, jitter))
    return interval * (1.0 + jitter * (2.0 * u - 1.0))


def _server_list(address) -> List[Tuple[str, int]]:
    """Accept one ``(host, port)`` or a list of them."""
    if not address:
        raise ValueError("need at least one scheduler address")
    if isinstance(address[0], str):
        return [(address[0], int(address[1]))]
    return [(a[0], int(a[1])) for a in address]


class SchedulerClient:
    """JSON-lines request/reply + event stream over one TCP socket."""

    def __init__(self, address, subscribe: bool = False,
                 connect_timeout: float = 5.0,
                 op_timeout: Optional[float] = 30.0,
                 max_retries: int = 4, backoff: float = 0.05,
                 client_id: Optional[str] = None):
        # Failover: one address or a preference-ordered server list;
        # ``self.address`` is whichever server we are dialed into.
        self.servers = _server_list(address)
        self._si = 0
        self.address = self.servers[0]
        self._want_subscribe = subscribe
        self._connect_timeout = connect_timeout
        self.op_timeout = op_timeout
        self.max_retries = max(0, int(max_retries))
        self.backoff = backoff
        # Stable identity: the daemon keys leases and idempotency on
        # it. Survives reconnects by construction.
        self.client_id = client_id or uuid.uuid4().hex[:12]
        self._lock = threading.Lock()
        self._seq = 0
        self._buf = bytearray()
        self._events: List[Dict[str, Any]] = []
        self._sock: Optional[socket.socket] = None
        self.retries = 0          # resend attempts that reconnected
        # Fencing watermark: highest epoch seen in any reply. Stamped
        # on every request; replies below it are discarded.
        self.epoch_seen = 0
        self.redirects = 0        # NOT_LEADER redirects followed
        self.stale_rejections = 0  # replies dropped for a stale epoch
        self._hb_stop: Optional[threading.Event] = None
        self._hb_thread: Optional[threading.Thread] = None
        self.connect()

    # -- connection ----------------------------------------------------
    def connect(self) -> None:
        """Dial (or re-dial) a daemon. Retries briefly so a client
        racing the daemon's bind — or reconnecting across a daemon
        restart — just works; each failed dial rotates to the next
        server in the list (failover). The read buffer is cleared:
        bytes of a half-received line from the old connection must
        never prefix the new stream (regression-tested)."""
        self.close()
        deadline = time.monotonic() + self._connect_timeout
        last: Optional[Exception] = None
        while time.monotonic() < deadline:
            self.address = self.servers[self._si % len(self.servers)]
            try:
                self._sock = socket.create_connection(self.address,
                                                      timeout=2.0)
                self._sock.settimeout(None)
                break
            except OSError as e:
                last = e
                self._si += 1
                time.sleep(0.02)
        else:
            raise ConnectionError(
                f"cannot reach scheduler at any of {self.servers}: {last}")
        self._buf = bytearray()
        if self._want_subscribe:
            self._send_one("subscribe")

    def _set_leader(self, leader: Tuple[str, int]) -> None:
        """Follow a NOT_LEADER redirect: make ``leader`` the current
        (and preferred) server, learning it if it wasn't listed."""
        leader = (leader[0], int(leader[1]))
        if leader not in self.servers:
            self.servers.append(leader)
        self._si = self.servers.index(leader)

    def close(self) -> None:
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
            self._sock = None

    def stop_heartbeat(self) -> None:
        if self._hb_stop is not None:
            self._hb_stop.set()
            self._hb_stop = None
            self._hb_thread = None

    def start_heartbeat(self, interval: float,
                        jitter: float = 0.0) -> None:
        """Renew this client's lease every ``interval`` seconds from a
        daemon thread (any request renews too — the thread only
        matters while the client is otherwise idle). Errors are
        swallowed: a dead daemon fails the next real request.

        ``jitter`` (0..1) spreads each wait uniformly over
        ``interval * [1-jitter, 1+jitter]``: a fleet of clients that
        all reconnected at a failover would otherwise renew in
        lockstep against the new leader indefinitely."""
        self.stop_heartbeat()
        stop = self._hb_stop = threading.Event()
        rng = random.Random()   # per-thread phase, urandom-seeded

        def beat() -> None:
            while not stop.wait(jittered_interval(interval, jitter,
                                                  rng.random())):
                try:
                    self.heartbeat()
                except (ConnectionError, TimeoutError, OSError,
                        RuntimeError):
                    pass

        self._hb_thread = threading.Thread(
            target=beat, name="repro-scheduler-heartbeat", daemon=True)
        self._hb_thread.start()

    # -- line transport ------------------------------------------------
    def _readline(self, timeout: Optional[float]) -> Optional[bytes]:
        """One protocol line, or None on timeout. Manual buffering so
        socket timeouts never corrupt a buffered reader."""
        assert self._sock is not None
        while True:
            nl = self._buf.find(b"\n")
            if nl >= 0:
                line = bytes(self._buf[:nl + 1])
                del self._buf[:nl + 1]
                return line
            self._sock.settimeout(timeout)
            try:
                chunk = self._sock.recv(65536)
            except socket.timeout:
                return None
            finally:
                self._sock.settimeout(None)
            if not chunk:
                raise ConnectionError("scheduler closed the connection")
            self._buf.extend(chunk)

    def _await_reply(self, seq: int,
                     timeout: Optional[float]) -> Dict[str, Any]:
        """Read until the reply tagged ``seq`` arrives: pushed events
        are buffered, stale pre-reconnect replies dropped by seq."""
        deadline = (time.monotonic() + timeout
                    if timeout is not None else None)
        while True:
            remaining = None
            if deadline is not None:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise TimeoutError(
                        f"no reply from {self.address} within "
                        f"{self.op_timeout}s")
            line = self._readline(remaining)
            if line is None:
                raise TimeoutError(
                    f"no reply from {self.address} within "
                    f"{self.op_timeout}s")
            resp = protocol.decode(line)
            if "event" in resp:
                self._events.append(resp)
                continue
            if resp.get("seq") == seq:
                return resp
            # Stale reply from a pre-reconnect request: drop it.

    def _send_one(self, op: str, **fields) -> Dict[str, Any]:
        """One-shot request on the current socket — no retry loop.
        Used inside :meth:`connect` (re-subscribing a fresh
        connection), where the reconnect machinery must not recurse."""
        self._seq += 1
        seq = self._seq
        msg = {"op": op, "seq": seq, "client": self.client_id, **fields}
        assert self._sock is not None, "client is closed"
        self._sock.sendall(protocol.encode(msg))
        return self._await_reply(seq, self.op_timeout)

    def _request(self, op: str, _retries: Optional[int] = None,
                 **fields) -> Dict[str, Any]:
        """Send one op; on a broken connection or per-op timeout,
        reconnect (exponential backoff + jitter) and resend the same
        ``request_id`` — the daemon's dedup cache makes the retry
        exactly-once for journaled ops. ``_retries`` overrides
        ``max_retries`` for ops where retrying is pointless
        (``shutdown`` of a daemon that already went away).

        Failover semantics on top (PR 10): a ``NOT_LEADER`` refusal
        follows the reply's ``leader`` redirect (or rotates to the
        next server) and counts as a retry; a reply whose ``epoch``
        is *below* our watermark is discarded as if the connection
        had failed — a superseded leader's ack is not an ack. Each
        attempt re-stamps the request with the current watermark, so
        any stale primary we do reach fences itself on receipt."""
        retries = self.max_retries if _retries is None else _retries
        with self._lock:
            self._seq += 1
            seq = self._seq
            msg = {"op": op, "seq": seq, "client": self.client_id,
                   "request_id": f"{self.client_id}:{seq}", **fields}
            last: Optional[Exception] = None
            for attempt in range(retries + 1):
                if attempt:
                    self.retries += 1
                    delay = min(2.0, self.backoff * (2 ** (attempt - 1)))
                    time.sleep(delay * (0.5 + random.random()))
                if self.epoch_seen:
                    msg["epoch"] = self.epoch_seen
                try:
                    if self._sock is None:
                        self.connect()
                    self._sock.sendall(protocol.encode(msg))
                    resp = self._await_reply(seq, self.op_timeout)
                except (ConnectionError, TimeoutError, OSError) as e:
                    last = e
                    self.close()
                    if len(self.servers) > 1:
                        self._si += 1   # try the next server first
                    continue
                ep = resp.get("epoch")
                if ep is not None:
                    if int(ep) < self.epoch_seen:
                        self.stale_rejections += 1
                        last = ConnectionError(
                            f"discarded reply from {self.address}: "
                            f"epoch {ep} < watermark {self.epoch_seen}")
                        self.close()
                        if len(self.servers) > 1:
                            self._si += 1
                        continue
                    self.epoch_seen = int(ep)
                if resp.get("not_leader") \
                        or resp.get("error") == protocol.NOT_LEADER:
                    self.redirects += 1
                    last = ConnectionError(
                        f"{self.address} is not the leader")
                    self.close()
                    leader = resp.get("leader")
                    if leader and (leader[0], int(leader[1])) \
                            != self.address:
                        self._set_leader((leader[0], leader[1]))
                    elif len(self.servers) > 1:
                        self._si += 1
                    continue
                return resp
            assert last is not None
            raise last

    # Historical spelling (pre-PR 9); the retrying path is _request.
    _call = _request

    def call(self, op: str, **fields) -> Dict[str, Any]:
        """Raw op; raises on protocol-level errors."""
        resp = self._request(op, **fields)
        if not resp.get("ok", False):
            raise RuntimeError(f"scheduler {op} failed: "
                               f"{resp.get('error', resp)}")
        return resp

    def heartbeat(self) -> Dict[str, Any]:
        """Renew this client's lease (any request renews; this one
        exists for otherwise-idle clients)."""
        return self.call("heartbeat")

    # -- service surface -----------------------------------------------
    def submit(self, shape, job_id: Optional[int] = None) -> Dict[str, Any]:
        dims = list(shape.dims) if hasattr(shape, "dims") else list(shape)
        fields: Dict[str, Any] = {"shape": dims}
        if job_id is not None:
            fields["job_id"] = job_id
        return self.call("submit", **fields)

    def done(self, job_id: int) -> Dict[str, Any]:
        return self.call("done", job_id=job_id)

    def preempt(self, job_id: int) -> Dict[str, Any]:
        """Evict a running job back to the queue head."""
        return self.call("preempt", job_id=job_id)

    def migrate(self, job_id: int) -> Dict[str, Any]:
        """Evict + replan a running job; ``outcome`` is ``migrated``
        (with the new placement) or ``preempted`` (queued at head)."""
        return self.call("migrate", job_id=job_id)

    def fault(self, kind: str, targets) -> Dict[str, Any]:
        """Inject a fabric fault (kind = node|link|ocs_port); the
        reply lists each victim's disposition."""
        return self.call("fault", kind=kind, targets=list(targets))

    def repair(self, kind: str, targets) -> Dict[str, Any]:
        """Undo a fault; no-op for targets that never failed."""
        return self.call("repair", kind=kind, targets=list(targets))

    def status(self) -> Dict[str, Any]:
        return self.call("status")

    def sync(self) -> Dict[str, Any]:
        return self.call("sync")

    def shutdown(self) -> Dict[str, Any]:
        # No retries: re-dialing a daemon that is already gone only
        # stalls the caller's teardown path.
        resp = self._request("shutdown", _retries=0)
        if not resp.get("ok", False):
            raise RuntimeError(f"scheduler shutdown failed: "
                               f"{resp.get('error', resp)}")
        return resp

    def events(self, max_wait: float = 0.0) -> List[Dict[str, Any]]:
        """Drain pushed events: everything buffered, plus whatever
        arrives within ``max_wait`` seconds (0 = only what is already
        here or in the socket buffer)."""
        out, self._events = self._events, []
        deadline = time.monotonic() + max_wait
        with self._lock:
            while True:
                remaining = deadline - time.monotonic()
                timeout = max(0.0, remaining) if max_wait else 0.0
                try:
                    line = self._readline(timeout or 0.000001)
                except ConnectionError:
                    break
                if line is None:
                    if remaining <= 0:
                        break
                    continue
                resp = protocol.decode(line)
                if "event" in resp:
                    out.append(resp)
        return out

    # -- raw policy ops ------------------------------------------------
    def try_place(self, job_id: int, shape) -> Dict[str, Any]:
        dims = list(shape.dims) if hasattr(shape, "dims") else list(shape)
        return self.call("try_place", job_id=job_id, shape=dims)

    def release(self, job_id: int) -> Dict[str, Any]:
        return self.call("release", job_id=job_id)

    def can_ever_place(self, shape) -> bool:
        dims = list(shape.dims) if hasattr(shape, "dims") else list(shape)
        return bool(self.call("can_ever_place", shape=dims)["feasible"])


class RemotePolicy(PlacementPolicy):
    """The in-process policy surface, served remotely.

    Plugs straight into :class:`repro.sim.simulator.Simulator` — the
    simulator becomes a client of the daemon and cannot tell the
    difference: ops arrive at the daemon in the simulator's own call
    order, the daemon-side policy is deterministic in op order, and
    placement metadata round-trips losslessly (tuples restored), so
    schedules and metrics are byte-identical to in-process runs.
    ``can_ever_place`` feasibility is cached per canonical shape by
    the base class, exactly like an in-process policy — the daemon's
    own cache makes the extra RPC cheap either way."""

    def __init__(self, client: SchedulerClient):
        super().__init__()
        self.client = client
        st = client.status()
        self.name = st["policy"]
        self._num_xpus = int(st["num_xpus"])

    @property
    def num_xpus(self) -> int:
        return self._num_xpus

    @property
    def busy_xpus(self) -> int:
        return int(self.client.status()["busy_xpus"])

    def utilization(self) -> float:
        st = self.client.status()
        return int(st["busy_xpus"]) / int(st["num_xpus"])

    def try_place(self, job_id: int, shape: JobShape) -> Optional[Placement]:
        resp = self.client.try_place(job_id, shape)
        if resp["outcome"] != protocol.PLACED:
            return None
        p = resp["placement"]
        return Placement(
            job_id=int(p["job_id"]),
            shape=JobShape(tuple(int(v) for v in p["shape"])),
            broken_rings=tuple(int(v) for v in p["broken_rings"]),
            meta=protocol.detuple(p["meta"]))

    def release(self, job_id: int) -> None:
        self.client.release(job_id)

    def _can_ever_place(self, shape: JobShape) -> bool:
        return self.client.can_ever_place(shape)
