"""The allocator state machine behind the scheduler daemon.

:class:`AllocatorCore` owns one placement policy and gives it service
semantics: streaming submissions with FIFO queueing (head-of-line
blocking, optionally backfill — the simulator's admission discipline,
shared by construction), admission control under overload
(``max_queue``), pushed topology events, and crash recovery.

Persistence is **journal replay** over the fingerprinted checkpoint
store from ``repro.eval.runner``: placement is a deterministic
function of the op order (the same property that makes the fleet
broker bit-exact), so the durable state is simply the ordered list of
state-changing ops. :class:`SchedulerConfig` implements the
``fingerprint()``/``checkpoint_name()`` duck-type the store keys on,
which buys atomic tmp+rename writes, fingerprint-prefix sharding and
``prune_checkpoints`` compatibility for free — and means a daemon
restarted with a *different* config refuses to resume a stale journal
(the fingerprint gates the load, exactly as eval resume does).

Durability (PR 9) is snapshot + write-ahead tail: every journaled op
is appended to a CRC32+length-framed WAL (``journal.py``) and fsynced
**before** the reply is sent, so recovery is ``snapshot ⊕ WAL tail``
— a crash between snapshots loses nothing acknowledged, and a torn
trailing record is truncated away instead of poisoning recovery.
Requests may carry a client-generated ``request_id``; replies to
journaled ops are remembered in a bounded dedup cache (persisted via
the journal itself — replay regenerates the identical replies), so a
retried op after a reconnect is applied exactly once. Ops may also
carry a ``client`` id, which makes the submitting client the job's
*lease holder*: ``op_lease_expire`` (journaled with its resolved
action, so replay never depends on current config) requeues or
releases a dead client's jobs.

Replication (PR 10): the in-memory journal doubles as the replication
log — a standby's cursor is just a journal index, served as WAL-framed
bytes by :meth:`AllocatorCore.journal_frames` and applied on the
standby via :meth:`AllocatorCore.apply_replicated` (replay-mode apply
+ append to the standby's *own* WAL, so a promoted standby recovers
like any primary). Leadership is fenced by a monotonic ``epoch``
stamped on every journal record (``"e"``): promotion journals a
``promote`` op carrying the new epoch, so the fencing token survives
recovery and replication by the same mechanism as everything else.
The epoch is deliberately excluded from :meth:`state_digest` — an
uninterrupted control run and a failover run must digest-identically.
"""
from __future__ import annotations

import hashlib
import json
import os
from collections import OrderedDict
from dataclasses import asdict, dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from repro.core.allocator import make_policy
from repro.core.engineconfig import EngineConfig
from repro.core.events import TopologyEvent
from repro.core.geometry import JobShape
from repro.eval.runner import save_checkpoint, shard_dir, verify_record
from repro.sim.faults import FaultEvent, FaultInjector

from . import protocol
from .journal import JournalWriter, encode_frames, recover_journal


@dataclass
class SchedulerConfig:
    """Everything that determines the daemon's behaviour (and hence
    its checkpoint identity)."""

    policy: str = "rfold"
    policy_kw: Dict[str, Any] = field(default_factory=dict)
    backfill: bool = False
    # Admission: queue depth cap; None = queue without bound. A submit
    # arriving at a full queue is REJECTED (stateless — not journaled).
    max_queue: Optional[int] = None
    engine: EngineConfig = field(default_factory=EngineConfig)
    # Persistence: None disables checkpointing entirely.
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 64       # journaled ops between snapshots
    # fsync every WAL append (durability); False trades the last few
    # acknowledged ops for latency, crash *consistency* is unaffected.
    fsync: bool = True
    # Liveness: a client that stops heartbeating for lease_timeout
    # seconds loses its lease; its jobs are requeued (work-preserving)
    # or released, per lease_policy. None disables leases entirely.
    lease_timeout: Optional[float] = None
    lease_policy: str = "requeue"    # "requeue" | "release"
    # Idempotency: replies to journaled ops are remembered per
    # request_id so a retried op is applied exactly once. 0 disables.
    dedup_cache: int = 1024
    # Backpressure: per-subscriber pushed-event queue depth; a
    # subscriber whose queue overflows is marked lagged and dropped.
    subscriber_queue: int = 1024
    # Daemon bind address; port 0 = ephemeral (read it back after start).
    host: str = "127.0.0.1"
    port: int = 0
    # Replication (PR 10). A "standby" daemon tails the primary at
    # ``replicate_from`` = (host, port), refuses client writes with
    # NOT_LEADER until promoted, and keeps a shadow core whose digest
    # tracks the primary record-for-record.
    role: str = "primary"            # "primary" | "standby"
    replicate_from: Optional[Tuple[str, int]] = None
    # Ack mode of a *primary*: "sync" holds each journaled-op reply
    # until the standby has fsynced the record (bounded by
    # sync_timeout, after which the op acks degraded — availability
    # over replication when the standby is down); "async" acks after
    # the local fsync only.
    ack_mode: str = "async"          # "async" | "sync"
    sync_timeout: float = 2.0
    # Long-poll window (seconds) for follower repl_pull waits.
    repl_poll: float = 0.5

    def __post_init__(self):
        self.engine = EngineConfig.coerce(self.engine)
        if self.lease_policy not in ("requeue", "release"):
            raise ValueError("lease_policy must be 'requeue' or "
                             f"'release', got {self.lease_policy!r}")
        if self.role not in ("primary", "standby"):
            raise ValueError("role must be 'primary' or 'standby', "
                             f"got {self.role!r}")
        if self.ack_mode not in ("async", "sync"):
            raise ValueError("ack_mode must be 'async' or 'sync', "
                             f"got {self.ack_mode!r}")
        if self.role == "standby" and self.replicate_from is None:
            raise ValueError("a standby needs replicate_from=(host, "
                             "port) of the primary to tail")
        if self.replicate_from is not None:
            h, p = self.replicate_from
            self.replicate_from = (str(h), int(p))

    # -- checkpoint-store duck-type (repro.eval.runner) ----------------
    def fingerprint(self) -> str:
        """Hash of every field that affects placement outcomes. The
        transport fields (host/port), checkpoint cadence and the
        resilience knobs (fsync, leases, dedup, backpressure,
        role/replication/ack mode) are excluded: moving the daemon,
        retuning snapshot frequency or lease policy, or promoting a
        standby must not orphan its journal — lease expiries are
        journaled with their *resolved* action, so replay never
        consults the current lease_policy, and a primary and its
        standby share one fingerprint (the replication stream id)."""
        fields = {"policy": self.policy, "policy_kw": self.policy_kw,
                  "backfill": self.backfill, "max_queue": self.max_queue,
                  "engine": asdict(self.engine)}
        blob = json.dumps(fields, sort_keys=True, default=str)
        return hashlib.sha256(blob.encode()).hexdigest()[:16]

    def checkpoint_name(self) -> str:
        return f"scheduler_{self.policy}__r0__{self.fingerprint()}.json"


class AllocatorCore:
    """Single-threaded allocator behind the daemon (the event loop
    serializes ops, so no locking here). Every public op returns
    ``(reply, events)``: the tagged reply for the requester and the
    untagged event dicts to broadcast to subscribers."""

    JOURNALED = ("submit", "done", "try_place", "release",
                 "preempt", "migrate", "fault", "repair",
                 "lease_expire", "promote")

    def __init__(self, config: SchedulerConfig, mask_client=None):
        self.config = config
        self.policy = make_policy(config.policy,
                                  mask_client=mask_client,
                                  engine=config.engine,
                                  **config.policy_kw)
        self.model = (getattr(self.policy, "torus", None)
                      or getattr(self.policy, "cluster", None))
        self.model.listeners.append(self._on_topology)
        # FIFO queue of (job_id, shape-dims); mirrors the simulator's
        # head-of-line blocking (backfill optional).
        self.queue: List[Tuple[int, Tuple[int, int, int]]] = []
        # Shapes of *allocated* jobs — what preempt/migrate/fault
        # replanning re-places. Rebuilt by journal replay like every
        # other piece of state.
        self.shapes: Dict[int, Tuple[int, int, int]] = {}
        self._injector: Optional[FaultInjector] = None
        self.next_id = 0
        # Durable state: the ordered journal of state-changing ops.
        self.journal: List[Dict[str, Any]] = []
        self._ops_since_sync = 0
        self._replaying = False
        self._pending_topo: List[TopologyEvent] = []
        self.recovered_ops = 0
        # Lease ownership: job_id -> client id, rebuilt by replay from
        # the ``client`` field journaled ops carry.
        self.owners: Dict[int, str] = {}
        # Idempotency: request_id -> reply for journaled ops (bounded
        # LRU; replay regenerates identical entries from the journal).
        self._dedup: "OrderedDict[str, Dict[str, Any]]" = OrderedDict()
        self._current_rid: Optional[str] = None
        self._current_client: Optional[str] = None
        self._wal: Optional[JournalWriter] = None
        # Fencing token: monotonic leadership epoch. Stamped as "e" on
        # every journal record; promotion journals a bump, so the
        # epoch recovers and replicates like all other state. NOT part
        # of state_digest (a failover run must digest-match its
        # uninterrupted control).
        self.epoch = 1
        self.counters: Dict[str, int] = {
            "dedup_hits": 0, "lease_expiries": 0,
            "wal_tail_ops": 0, "wal_truncated": 0,
            "repl_applied": 0, "promotions": 0,
        }

    # -- topology listener --------------------------------------------
    def _on_topology(self, ev: TopologyEvent) -> None:
        if not self._replaying:
            self._pending_topo.append(ev)

    def _drain_topo(self) -> List[Dict[str, Any]]:
        """Convert buffered TopologyEvents into wire event dicts.
        A setup that changed OCS wiring pushes RECONFIG alongside
        SETUP (clients that only care about their own placement read
        SETUP; clients tracking the switch layer read RECONFIG)."""
        out: List[Dict[str, Any]] = []
        for ev in self._pending_topo:
            if ev.kind == "setup":
                out.append({"event": protocol.EV_SETUP,
                            "job_id": ev.job_id, "detail": ev.detail})
                if ev.reconfigured:
                    out.append({"event": protocol.EV_RECONFIG,
                                "job_id": ev.job_id,
                                "topology": ev.topology,
                                "detail": ev.detail})
            elif ev.kind in ("fault", "repair"):
                out.append({"event": (protocol.EV_FAULT
                                      if ev.kind == "fault"
                                      else protocol.EV_REPAIR),
                            "topology": ev.topology,
                            "detail": ev.detail})
            else:
                out.append({"event": protocol.EV_RELEASE,
                            "job_id": ev.job_id,
                            "reconfigured": ev.reconfigured,
                            "detail": ev.detail})
        self._pending_topo = []
        return out

    # -- journal / persistence ----------------------------------------
    def _journal_op(self, op: Dict[str, Any]) -> None:
        if self._replaying:
            return
        if self._current_rid is not None:
            op["rid"] = self._current_rid
        if self._current_client is not None:
            op["client"] = self._current_client
        # Fencing: every record carries the epoch it was written
        # under, so replication and recovery both restore the token.
        op["e"] = self.epoch
        self.journal.append(op)
        if not self.config.checkpoint_dir:
            return
        # WAL first: the op is durable (framed, CRC'd, fsynced) before
        # any reply can leave the daemon. ``i`` is the op's journal
        # index — recovery uses it to skip records the snapshot
        # already subsumes (crash between snapshot write and WAL
        # reset must not double-apply).
        self._wal_writer().append({"i": len(self.journal) - 1, **op})
        self._ops_since_sync += 1
        if (self.config.checkpoint_every
                and self._ops_since_sync >= self.config.checkpoint_every):
            self.sync_checkpoint()

    def _wal_path(self) -> str:
        cfg = self.config
        return os.path.join(shard_dir(cfg.checkpoint_dir,
                                      cfg.fingerprint()),
                            cfg.checkpoint_name() + ".wal")

    def _wal_writer(self) -> JournalWriter:
        if self._wal is None:
            self._wal = JournalWriter(self._wal_path(),
                                      fsync=self.config.fsync)
        return self._wal

    def sync_checkpoint(self) -> Optional[str]:
        """Write the journal snapshot now (atomic tmp+rename via the
        eval store), then reset the WAL it subsumes. Returns the
        checkpoint path, or None when persistence is off."""
        cfg = self.config
        if not cfg.checkpoint_dir:
            return None
        rec = {"fingerprint": cfg.fingerprint(), "format": 1,
               "next_id": self.next_id, "journal": self.journal}
        save_checkpoint(cfg.checkpoint_dir, cfg, rec)
        self._wal_writer().reset()
        self._ops_since_sync = 0
        return os.path.join(shard_dir(cfg.checkpoint_dir,
                                      cfg.fingerprint()),
                            cfg.checkpoint_name())

    @staticmethod
    def load_state(config: SchedulerConfig) -> Optional[Dict[str, Any]]:
        """The stored journal record for this config, or None (no
        store, no file, or fingerprint mismatch — a changed config
        must start fresh, never resume another config's journal)."""
        if not config.checkpoint_dir:
            return None
        fp = config.fingerprint()
        name = config.checkpoint_name()
        for path in (os.path.join(shard_dir(config.checkpoint_dir, fp),
                                  name),
                     os.path.join(config.checkpoint_dir, name)):
            if not os.path.exists(path):
                continue
            try:
                with open(path) as f:
                    rec = json.load(f)
            except (OSError, ValueError):
                continue
            if not verify_record(rec):
                continue   # bit-rot: a corrupt snapshot never replays
            rec.pop("_crc32", None)
            if rec.get("fingerprint") == fp:
                return rec
        return None

    @classmethod
    def recover(cls, config: SchedulerConfig,
                mask_client=None) -> "AllocatorCore":
        """Fresh core, or one rebuilt by replaying snapshot + WAL tail.
        Placement is deterministic in op order, so the replayed
        occupancy grid, queue and in-flight set are byte-identical to
        the pre-crash state (tested). A torn WAL tail is truncated at
        the first corrupt record — everything acknowledged before the
        crash precedes it by the fsync ordering."""
        core = cls(config, mask_client=mask_client)
        rec = cls.load_state(config)
        base = list(rec["journal"]) if rec else []
        tail: List[Dict[str, Any]] = []
        truncated = False
        if config.checkpoint_dir:
            wal_recs, truncated = recover_journal(core._wal_path())
            for w in wal_recs:
                i = w.pop("i", None)
                expected = len(base) + len(tail)
                if i is not None and i < expected:
                    continue   # already subsumed by the snapshot
                if i is not None and i > expected:
                    break      # gap — never replay past missing ops
                tail.append(w)
        full = base + tail
        if full:
            core._replay({"journal": full,
                          "next_id": (rec or {}).get("next_id", 0)})
        elif rec:
            core.next_id = max(core.next_id, int(rec.get("next_id", 0)))
        core.counters["wal_tail_ops"] = len(tail)
        core.counters["wal_truncated"] = int(truncated)
        return core

    def _replay(self, rec: Dict[str, Any]) -> None:
        self._replaying = True
        try:
            for op in rec["journal"]:
                reply, _ = self.apply(dict(op))
                rid = op.get("rid")
                if rid is not None:
                    # Replay regenerates the identical reply bytes
                    # (determinism), repopulating the dedup cache: a
                    # client retrying across a daemon crash still gets
                    # exactly-once semantics.
                    self._remember(rid, reply)
        finally:
            self._replaying = False
            self._pending_topo = []
        self.journal = [dict(op) for op in rec["journal"]]
        self.next_id = max(self.next_id, int(rec.get("next_id", 0)))
        self.recovered_ops = len(self.journal)
        # Restore the fencing token: promote ops replayed above already
        # bumped it; the per-record stamp covers journals whose last
        # promotion predates the snapshot horizon (pre-PR-10 records
        # carry no "e" — epoch 1 by definition).
        for op in self.journal:
            self.epoch = max(self.epoch, int(op.get("e", 1)))

    # -- op dispatch ---------------------------------------------------
    def apply(self, msg: Dict[str, Any]):
        """Dispatch one request dict -> (reply, events). Unknown ops
        and handler exceptions become error replies (the daemon must
        survive malformed clients).

        Idempotency: a request whose ``request_id`` already produced a
        journaled op returns the remembered reply without re-applying
        (and without re-broadcasting events — the originals were
        already pushed). Stateless outcomes (status, REJECTED, errors)
        are not cached: re-evaluating them is safe by construction."""
        op = msg.get("op")
        handler = getattr(self, f"op_{op}", None)
        if handler is None:
            return {"ok": False, "error": f"unknown op {op!r}"}, []
        rid = msg.get("request_id") or msg.get("rid")
        if rid is not None and self.config.dedup_cache:
            cached = self._dedup.get(rid)
            if cached is not None:
                self._dedup.move_to_end(rid)
                self.counters["dedup_hits"] += 1
                return dict(cached), []
        self._current_rid = rid
        self._current_client = msg.get("client")
        before = len(self.journal)
        try:
            reply, events = handler(msg)
        except Exception as e:  # noqa: BLE001 — protocol boundary
            self._pending_topo = []
            return {"ok": False, "error": f"{type(e).__name__}: {e}"}, []
        finally:
            self._current_rid = None
            self._current_client = None
        if rid is not None and len(self.journal) > before:
            self._remember(rid, reply)
        return reply, events

    def _remember(self, rid: str, reply: Dict[str, Any]) -> None:
        if not self.config.dedup_cache:
            return
        self._dedup[rid] = dict(reply)
        self._dedup.move_to_end(rid)
        while len(self._dedup) > self.config.dedup_cache:
            self._dedup.popitem(last=False)

    @staticmethod
    def _shape(msg: Dict[str, Any]) -> JobShape:
        dims = tuple(int(v) for v in msg["shape"])
        if len(dims) != 3 or any(d <= 0 for d in dims):
            raise ValueError(f"shape must be 3 positive extents, "
                             f"got {dims}")
        return JobShape(dims)

    # -- service ops ---------------------------------------------------
    def op_submit(self, msg: Dict[str, Any]):
        """Streaming arrival: place now, queue FIFO, drop (shape can
        never fit), or reject (queue full). Placement respects
        head-of-line blocking: with a non-empty queue and no backfill,
        a new arrival queues behind the blocked head even if it would
        fit — identical to the simulator's discipline."""
        shape = self._shape(msg)
        job_id = msg.get("job_id")
        if job_id is None:
            job_id = self.next_id
        job_id = int(job_id)
        if any(j == job_id for j, _ in self.queue) \
                or job_id in self.model.allocations:
            return {"ok": False,
                    "error": f"job {job_id} already known"}, []
        if (self.config.max_queue is not None
                and len(self.queue) >= self.config.max_queue):
            # Stateless outcome: not journaled, no id consumed.
            return {"ok": True, "outcome": protocol.REJECTED,
                    "job_id": job_id, "queue_depth": len(self.queue)}, []
        self.next_id = max(self.next_id, job_id + 1)
        self._journal_op({"op": "submit", "job_id": job_id,
                          "shape": list(shape.dims)})
        if not self.policy.can_ever_place(shape):
            return {"ok": True, "outcome": protocol.DROPPED,
                    "job_id": job_id}, []
        if self._current_client is not None:
            self.owners[job_id] = self._current_client
        placement = None
        if not self.queue or self.config.backfill:
            placement = self.policy.try_place(job_id, shape)
        if placement is None:
            self.queue.append((job_id, shape.dims))
            return {"ok": True, "outcome": protocol.QUEUED,
                    "job_id": job_id,
                    "queue_depth": len(self.queue)}, self._drain_topo()
        self.shapes[job_id] = shape.dims
        return ({"ok": True, "outcome": protocol.PLACED,
                 "job_id": job_id,
                 "placement": self._placement_fields(placement)},
                self._drain_topo())

    def op_done(self, msg: Dict[str, Any]):
        """A running job finished: release it, then drain the queue
        (FIFO; newly started jobs are announced via pushed SETUP —
        their owners subscribed for exactly this)."""
        job_id = int(msg["job_id"])
        queued = [j for j, _ in self.queue]
        if job_id in self.model.allocations:
            self._journal_op({"op": "done", "job_id": job_id})
            self.policy.release(job_id)
            self.shapes.pop(job_id, None)
            self.owners.pop(job_id, None)
            started = self._drain_fifo()
        elif job_id in queued:
            # Cancelled while queued.
            self._journal_op({"op": "done", "job_id": job_id})
            self.queue = [(j, s) for j, s in self.queue if j != job_id]
            self.owners.pop(job_id, None)
            started = []
        else:
            return {"ok": False, "error": f"job {job_id} not known"}, []
        return ({"ok": True, "job_id": job_id,
                 "started": started,
                 "queue_depth": len(self.queue)}, self._drain_topo())

    def _drain_fifo(self) -> List[Dict[str, Any]]:
        """The simulator's ``_drain_queue`` discipline: FIFO with
        head-of-line blocking; with backfill, later jobs may start
        past a blocked head. Drops queued jobs whose shape can never
        fit. Returns started/dropped notices (also pushed as events)."""
        started: List[Dict[str, Any]] = []
        i = 0
        while i < len(self.queue):
            job_id, dims = self.queue[i]
            shape = JobShape(dims)
            if not self.policy.can_ever_place(shape):
                self.queue.pop(i)
                started.append({"job_id": job_id,
                                "outcome": protocol.DROPPED})
                continue
            placement = self.policy.try_place(job_id, shape)
            if placement is None:
                if not self.config.backfill:
                    break
                i += 1
                continue
            self.queue.pop(i)
            self.shapes[job_id] = dims
            started.append({"job_id": job_id,
                            "outcome": protocol.PLACED,
                            "placement":
                                self._placement_fields(placement)})
        return started

    # -- raw policy ops (the simulator-as-client surface) -------------
    def op_try_place(self, msg: Dict[str, Any]):
        """Raw ``PlacementPolicy.try_place`` over the wire: no queue,
        no admission — the simulator client drives its own FIFO and
        needs exactly the in-process contract."""
        shape = self._shape(msg)
        job_id = int(msg["job_id"])
        placement = self.policy.try_place(job_id, shape)
        if placement is None:
            return {"ok": True, "outcome": "full"}, []
        self.next_id = max(self.next_id, job_id + 1)
        self._journal_op({"op": "try_place", "job_id": job_id,
                          "shape": list(shape.dims)})
        self.shapes[job_id] = shape.dims
        if self._current_client is not None:
            self.owners[job_id] = self._current_client
        return ({"ok": True, "outcome": protocol.PLACED,
                 "placement": self._placement_fields(placement)},
                self._drain_topo())

    def op_release(self, msg: Dict[str, Any]):
        job_id = int(msg["job_id"])
        if job_id not in self.model.allocations:
            return {"ok": False, "error": f"job {job_id} not allocated"}, []
        self._journal_op({"op": "release", "job_id": job_id})
        self.policy.release(job_id)
        self.shapes.pop(job_id, None)
        self.owners.pop(job_id, None)
        return {"ok": True, "job_id": job_id}, self._drain_topo()

    # -- chaos ops (preemption, migration, fault injection) ------------
    def op_preempt(self, msg: Dict[str, Any]):
        """Evict a running job back to the *head* of the queue (it was
        already admitted — FIFO order is by first admission). Work is
        assumed checkpointed; the service tracks placement, not
        progress. The freed hole is deliberately NOT drained: the
        preempted head itself would immediately re-place into it."""
        job_id = int(msg["job_id"])
        if job_id not in self.model.allocations:
            return {"ok": False, "error": f"job {job_id} not allocated"}, []
        self._journal_op({"op": "preempt", "job_id": job_id})
        dims = self.shapes.pop(job_id)
        self.policy.release(job_id)
        self.queue.insert(0, (job_id, dims))
        events = self._drain_topo()
        events.append({"event": protocol.EV_PREEMPT, "job_id": job_id,
                       "shape": list(dims)})
        return ({"ok": True, "job_id": job_id,
                 "outcome": protocol.PREEMPTED,
                 "queue_depth": len(self.queue)}, events)

    def op_migrate(self, msg: Dict[str, Any]):
        """Evict + replan through the allocator *now*: the job lands in
        a fresh placement (``migrated``) or, if the cluster cannot fit
        it at the moment (degraded fabric), falls back to the queue
        head (``preempted``). Deterministic in op order, so the journal
        records only the intent."""
        job_id = int(msg["job_id"])
        if job_id not in self.model.allocations:
            return {"ok": False, "error": f"job {job_id} not allocated"}, []
        self._journal_op({"op": "migrate", "job_id": job_id})
        dims = self.shapes[job_id]
        self.policy.release(job_id)
        placement = self.policy.try_place(job_id, JobShape(dims))
        if placement is None:
            self.shapes.pop(job_id, None)
            self.queue.insert(0, (job_id, dims))
            events = self._drain_topo()
            events.append({"event": protocol.EV_PREEMPT,
                           "job_id": job_id, "shape": list(dims)})
            return ({"ok": True, "job_id": job_id,
                     "outcome": protocol.PREEMPTED,
                     "queue_depth": len(self.queue)}, events)
        events = self._drain_topo()
        events.append({"event": protocol.EV_MIGRATE, "job_id": job_id,
                       "shape": list(dims)})
        return ({"ok": True, "job_id": job_id,
                 "outcome": protocol.MIGRATED,
                 "placement": self._placement_fields(placement)}, events)

    def _fault_injector(self) -> FaultInjector:
        if self._injector is None:
            self._injector = FaultInjector(self.policy)
        return self._injector

    @staticmethod
    def _fault_event(msg: Dict[str, Any], action: str) -> FaultEvent:
        return FaultEvent.from_wire({"time": 0.0, "action": action,
                                     "kind": msg["kind"],
                                     "targets": msg.get("targets", [])})

    def op_fault(self, msg: Dict[str, Any]):
        """Inject a fabric fault (``kind`` = node|link|ocs_port,
        ``targets`` as in :class:`repro.sim.faults.FaultEvent`).
        Victims are evicted *before* the model transitions (the models
        refuse otherwise), then replanned in job-id order: re-placed
        now → ``migrated``; no capacity → ``preempted`` at the queue
        head. Journaled as intent — replay recomputes victims and
        replans deterministically."""
        ev = self._fault_event(msg, "fault")
        inj = self._fault_injector()
        victims = [j for j in inj.victims(ev)
                   if j in self.model.allocations]
        self._journal_op({"op": "fault", "kind": ev.kind,
                          "targets": list(ev.targets)})
        evicted: List[Tuple[int, Tuple[int, int, int]]] = []
        for jid in victims:
            dims = self.shapes.pop(jid)
            self.policy.release(jid)
            evicted.append((jid, dims))
        applied = inj.apply(ev)
        events = self._drain_topo()
        dispositions: List[Dict[str, Any]] = []
        requeue: List[Tuple[int, Tuple[int, int, int]]] = []
        for jid, dims in evicted:
            placement = self.policy.try_place(jid, JobShape(dims))
            if placement is not None:
                self.shapes[jid] = dims
                dispositions.append(
                    {"job_id": jid, "outcome": protocol.MIGRATED,
                     "placement": self._placement_fields(placement)})
                events.append({"event": protocol.EV_MIGRATE,
                               "job_id": jid, "shape": list(dims)})
            else:
                requeue.append((jid, dims))
                dispositions.append({"job_id": jid,
                                     "outcome": protocol.PREEMPTED})
                events.append({"event": protocol.EV_PREEMPT,
                               "job_id": jid, "shape": list(dims)})
        self.queue[0:0] = requeue
        events.extend(self._drain_topo())
        return ({"ok": True, "kind": ev.kind,
                 "applied": list(applied), "victims": dispositions,
                 "queue_depth": len(self.queue)}, events)

    def op_repair(self, msg: Dict[str, Any]):
        """Undo a fault (no-op for targets that never failed) and
        drain the queue — capacity came back."""
        ev = self._fault_event(msg, "repair")
        inj = self._fault_injector()
        self._journal_op({"op": "repair", "kind": ev.kind,
                          "targets": list(ev.targets)})
        applied = inj.apply(ev)
        started = self._drain_fifo()
        return ({"ok": True, "kind": ev.kind, "applied": list(applied),
                 "started": started,
                 "queue_depth": len(self.queue)}, self._drain_topo())

    # -- liveness ops ---------------------------------------------------
    def op_heartbeat(self, msg: Dict[str, Any]):
        """Lease renewal. State-free at the core: wall-clock lease
        bookkeeping lives in the daemon (which touches the lease for
        *every* request carrying a ``client`` id, heartbeats
        included); the core only reports the configured policy so a
        client can size its heartbeat interval."""
        return {"ok": True, "client": msg.get("client"),
                "lease_timeout": self.config.lease_timeout,
                "lease_policy": self.config.lease_policy}, []

    def op_lease_expire(self, msg: Dict[str, Any]):
        """A client's lease lapsed: disposition every job it owns.
        Journaled as intent *with the resolved action* — replay
        re-executes the same disposition even if the configured
        lease_policy has changed since.

        ``requeue`` (work-preserving, the Borg eviction analogue):
        running jobs are evicted back to the queue head in job-id
        order; queued jobs simply stay queued. Ownership is retained —
        a client reconnecting under the same id resumes its lease.
        ``release``: running *and* queued jobs are dropped outright
        and the freed capacity drains the queue."""
        cid = str(msg["client"])
        action = msg.get("action") or self.config.lease_policy
        owned_alloc = sorted(j for j, c in self.owners.items()
                             if c == cid and j in self.model.allocations)
        owned_queued = [j for j, _ in self.queue
                        if self.owners.get(j) == cid]
        # A no-op expiry (nothing owned; or requeue with only queued
        # jobs, which stay queued) is not journaled — deterministic
        # to re-derive, and keeping it out of the journal keeps
        # heartbeat-less idle clients free.
        if not owned_alloc and (action != "release" or not owned_queued):
            return {"ok": True, "client": cid, "action": action,
                    "jobs": [], "queue_depth": len(self.queue)}, []
        self._journal_op({"op": "lease_expire", "client": cid,
                          "action": action})
        self.counters["lease_expiries"] += 1
        dispositions: List[Dict[str, Any]] = []
        events: List[Dict[str, Any]] = []
        started: List[Dict[str, Any]] = []
        if action == "release":
            for jid in owned_alloc:
                self.policy.release(jid)
                self.shapes.pop(jid, None)
                self.owners.pop(jid, None)
                dispositions.append({"job_id": jid, "outcome": "released"})
                events.append({"event": protocol.EV_LEASE,
                               "job_id": jid, "client": cid,
                               "action": "release"})
            drop = set(owned_queued)
            if drop:
                self.queue = [(j, s) for j, s in self.queue
                              if j not in drop]
                for jid in owned_queued:
                    self.owners.pop(jid, None)
                    dispositions.append({"job_id": jid,
                                         "outcome": "released"})
            started = self._drain_fifo()
        else:
            requeue: List[Tuple[int, Tuple[int, int, int]]] = []
            for jid in owned_alloc:
                dims = self.shapes.pop(jid)
                self.policy.release(jid)
                requeue.append((jid, dims))
                dispositions.append({"job_id": jid,
                                     "outcome": protocol.PREEMPTED})
                events.append({"event": protocol.EV_LEASE,
                               "job_id": jid, "client": cid,
                               "action": "requeue"})
            self.queue[0:0] = requeue
        events = self._drain_topo() + events
        return ({"ok": True, "client": cid, "action": action,
                 "jobs": dispositions, "started": started,
                 "queue_depth": len(self.queue)}, events)

    def op_can_ever_place(self, msg: Dict[str, Any]):
        shape = self._shape(msg)
        return {"ok": True,
                "feasible": bool(self.policy.can_ever_place(shape))}, []

    # -- replication & fencing (PR 10) ----------------------------------
    def op_promote(self, msg: Dict[str, Any]):
        """Mint a new fencing epoch and journal the promotion. The
        epoch is bumped *before* journaling, so the promotion record
        is the first op of the new epoch — every daemon or standby
        that replays or replicates it learns the new token.

        A live promote mints ``max(own epoch, request's fencing
        stamp) + 1`` — the stamp is the highest epoch the caller has
        witnessed anywhere, so the minted token supersedes leaders
        this daemon never heard of. Replay instead restores the
        journaled record's epoch verbatim."""
        if self._replaying:
            new_epoch = int(msg.get("epoch", self.epoch + 1))
        else:
            new_epoch = max(self.epoch, int(msg.get("epoch", 0))) + 1
        self.epoch = max(self.epoch, new_epoch)
        self._journal_op({"op": "promote", "epoch": self.epoch})
        self.counters["promotions"] += 1
        return {"ok": True, "epoch": self.epoch, "promoted": True}, []

    def journal_frames(self, index: int,
                       limit: int = 512) -> Tuple[bytes, int]:
        """Serve the replication stream: WAL-framed records from
        journal ``index`` (at most ``limit`` per pull), byte-identical
        to what the WAL holds for them. Returns ``(frames,
        next_index)`` — the follower's new cursor."""
        index = max(0, int(index))
        recs = [{"i": i, **op}
                for i, op in enumerate(self.journal[index:index + limit],
                                       start=index)]
        return encode_frames(recs), index + len(recs)

    def apply_replicated(self, rec: Dict[str, Any]) -> Dict[str, Any]:
        """Apply one record pulled from the leader (the standby path):
        run it through the normal handlers in replay mode —
        regenerating the identical reply for the dedup cache, pushing
        no events — then append it verbatim to this core's own journal
        *and WAL*, so a promoted standby recovers from its own disk
        exactly like a primary would. The caller guarantees contiguity
        (record index == len(journal))."""
        op = {k: v for k, v in rec.items() if k != "i"}
        self._replaying = True
        try:
            reply, _ = self.apply(dict(op))
            rid = op.get("rid")
            if rid is not None:
                self._remember(rid, reply)
        finally:
            self._replaying = False
            self._pending_topo = []
        self.epoch = max(self.epoch, int(op.get("e", 1)))
        self.journal.append(op)
        self.counters["repl_applied"] += 1
        if self.config.checkpoint_dir:
            self._wal_writer().append({"i": len(self.journal) - 1, **op})
            self._ops_since_sync += 1
            if (self.config.checkpoint_every
                    and self._ops_since_sync >= self.config.checkpoint_every):
                self.sync_checkpoint()
        return reply

    # -- introspection -------------------------------------------------
    def op_status(self, msg: Dict[str, Any]):
        return {"ok": True, **self.status()}, []

    def status(self) -> Dict[str, Any]:
        return {
            "policy": self.policy.name,
            "num_xpus": int(self.policy.num_xpus),
            "busy_xpus": int(self.policy.busy_xpus),
            "utilization": float(self.policy.utilization()),
            "allocated": len(self.model.allocations),
            "queue_depth": len(self.queue),
            "next_id": self.next_id,
            "journal_ops": len(self.journal),
            "epoch": self.epoch,
            "state_digest": self.state_digest(),
            "resilience": {**self.counters,
                           "dedup_entries": len(self._dedup),
                           "owned_jobs": len(self.owners),
                           "recovered_ops": self.recovered_ops},
        }

    def state_digest(self) -> str:
        """Content hash of the full allocator state (occupancy bytes,
        fault masks, allocation ids + shapes, queue, id counter) — the
        byte-identity oracle for the crash-recovery and parity tests."""
        h = hashlib.sha256()
        h.update(self.model.occ.tobytes())
        dedicated = getattr(self.model, "dedicated", None)
        if dedicated is not None:
            h.update(dedicated.tobytes())
        # Chaos state: failed nodes, dead OCS ports, cut links — a
        # faulted cluster must never digest-match a healthy one.
        h.update(self.model.failed.tobytes())
        ocs_ok = getattr(self.model, "ocs_ok", None)
        if ocs_ok is not None:
            h.update(ocs_ok.tobytes())
        cut = getattr(self.model, "cut_links", None)
        if cut is not None:
            h.update(json.dumps(sorted(cut)).encode())
        h.update(json.dumps(sorted(self.model.allocations)).encode())
        h.update(json.dumps(sorted(
            (j, list(d)) for j, d in self.shapes.items())).encode())
        h.update(json.dumps(self.queue).encode())
        h.update(str(self.next_id).encode())
        return h.hexdigest()[:16]

    def op_sync(self, msg: Dict[str, Any]):
        path = self.sync_checkpoint()
        return {"ok": True, "path": path,
                "journal_ops": len(self.journal)}, []

    @staticmethod
    def _placement_fields(placement) -> Dict[str, Any]:
        return {"job_id": placement.job_id,
                "shape": list(placement.shape.dims),
                "broken_rings": list(placement.broken_rings),
                "meta": placement.meta}
