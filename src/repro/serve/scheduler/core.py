"""The allocator state machine behind the scheduler daemon.

:class:`AllocatorCore` owns one placement policy and gives it service
semantics: streaming submissions with FIFO queueing (head-of-line
blocking, optionally backfill — the simulator's admission discipline,
shared by construction), admission control under overload
(``max_queue``), pushed topology events, and crash recovery.

Persistence is **journal replay** over the fingerprinted checkpoint
store from ``repro.eval.runner``: placement is a deterministic
function of the op order (the same property that makes the fleet
broker bit-exact), so the durable state is simply the ordered list of
state-changing ops. :class:`SchedulerConfig` implements the
``fingerprint()``/``checkpoint_name()`` duck-type the store keys on,
which buys atomic tmp+rename writes, fingerprint-prefix sharding and
``prune_checkpoints`` compatibility for free — and means a daemon
restarted with a *different* config refuses to resume a stale journal
(the fingerprint gates the load, exactly as eval resume does).
"""
from __future__ import annotations

import hashlib
import json
import os
from dataclasses import asdict, dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from repro.core.allocator import make_policy
from repro.core.engineconfig import EngineConfig
from repro.core.events import TopologyEvent
from repro.core.geometry import JobShape
from repro.eval.runner import save_checkpoint, shard_dir
from repro.sim.faults import FaultEvent, FaultInjector

from . import protocol


@dataclass
class SchedulerConfig:
    """Everything that determines the daemon's behaviour (and hence
    its checkpoint identity)."""

    policy: str = "rfold"
    policy_kw: Dict[str, Any] = field(default_factory=dict)
    backfill: bool = False
    # Admission: queue depth cap; None = queue without bound. A submit
    # arriving at a full queue is REJECTED (stateless — not journaled).
    max_queue: Optional[int] = None
    engine: EngineConfig = field(default_factory=EngineConfig)
    # Persistence: None disables checkpointing entirely.
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 64       # journaled ops between snapshots
    # Daemon bind address; port 0 = ephemeral (read it back after start).
    host: str = "127.0.0.1"
    port: int = 0

    def __post_init__(self):
        self.engine = EngineConfig.coerce(self.engine)

    # -- checkpoint-store duck-type (repro.eval.runner) ----------------
    def fingerprint(self) -> str:
        """Hash of every field that affects placement outcomes. The
        transport fields (host/port) and checkpoint cadence are
        excluded: moving the daemon or retuning snapshot frequency
        must not orphan its journal."""
        fields = {"policy": self.policy, "policy_kw": self.policy_kw,
                  "backfill": self.backfill, "max_queue": self.max_queue,
                  "engine": asdict(self.engine)}
        blob = json.dumps(fields, sort_keys=True, default=str)
        return hashlib.sha256(blob.encode()).hexdigest()[:16]

    def checkpoint_name(self) -> str:
        return f"scheduler_{self.policy}__r0__{self.fingerprint()}.json"


class AllocatorCore:
    """Single-threaded allocator behind the daemon (the event loop
    serializes ops, so no locking here). Every public op returns
    ``(reply, events)``: the tagged reply for the requester and the
    untagged event dicts to broadcast to subscribers."""

    JOURNALED = ("submit", "done", "try_place", "release",
                 "preempt", "migrate", "fault", "repair")

    def __init__(self, config: SchedulerConfig, mask_client=None):
        self.config = config
        self.policy = make_policy(config.policy,
                                  mask_client=mask_client,
                                  engine=config.engine,
                                  **config.policy_kw)
        self.model = (getattr(self.policy, "torus", None)
                      or getattr(self.policy, "cluster", None))
        self.model.listeners.append(self._on_topology)
        # FIFO queue of (job_id, shape-dims); mirrors the simulator's
        # head-of-line blocking (backfill optional).
        self.queue: List[Tuple[int, Tuple[int, int, int]]] = []
        # Shapes of *allocated* jobs — what preempt/migrate/fault
        # replanning re-places. Rebuilt by journal replay like every
        # other piece of state.
        self.shapes: Dict[int, Tuple[int, int, int]] = {}
        self._injector: Optional[FaultInjector] = None
        self.next_id = 0
        # Durable state: the ordered journal of state-changing ops.
        self.journal: List[Dict[str, Any]] = []
        self._ops_since_sync = 0
        self._replaying = False
        self._pending_topo: List[TopologyEvent] = []
        self.recovered_ops = 0

    # -- topology listener --------------------------------------------
    def _on_topology(self, ev: TopologyEvent) -> None:
        if not self._replaying:
            self._pending_topo.append(ev)

    def _drain_topo(self) -> List[Dict[str, Any]]:
        """Convert buffered TopologyEvents into wire event dicts.
        A setup that changed OCS wiring pushes RECONFIG alongside
        SETUP (clients that only care about their own placement read
        SETUP; clients tracking the switch layer read RECONFIG)."""
        out: List[Dict[str, Any]] = []
        for ev in self._pending_topo:
            if ev.kind == "setup":
                out.append({"event": protocol.EV_SETUP,
                            "job_id": ev.job_id, "detail": ev.detail})
                if ev.reconfigured:
                    out.append({"event": protocol.EV_RECONFIG,
                                "job_id": ev.job_id,
                                "topology": ev.topology,
                                "detail": ev.detail})
            elif ev.kind in ("fault", "repair"):
                out.append({"event": (protocol.EV_FAULT
                                      if ev.kind == "fault"
                                      else protocol.EV_REPAIR),
                            "topology": ev.topology,
                            "detail": ev.detail})
            else:
                out.append({"event": protocol.EV_RELEASE,
                            "job_id": ev.job_id,
                            "reconfigured": ev.reconfigured,
                            "detail": ev.detail})
        self._pending_topo = []
        return out

    # -- journal / persistence ----------------------------------------
    def _journal_op(self, op: Dict[str, Any]) -> None:
        if self._replaying:
            return
        self.journal.append(op)
        if not self.config.checkpoint_dir:
            return
        self._ops_since_sync += 1
        if (self.config.checkpoint_every
                and self._ops_since_sync >= self.config.checkpoint_every):
            self.sync_checkpoint()

    def sync_checkpoint(self) -> Optional[str]:
        """Write the journal snapshot now (atomic tmp+rename via the
        eval store). Returns the checkpoint path, or None when
        persistence is off."""
        cfg = self.config
        if not cfg.checkpoint_dir:
            return None
        rec = {"fingerprint": cfg.fingerprint(), "format": 1,
               "next_id": self.next_id, "journal": self.journal}
        save_checkpoint(cfg.checkpoint_dir, cfg, rec)
        self._ops_since_sync = 0
        return os.path.join(shard_dir(cfg.checkpoint_dir,
                                      cfg.fingerprint()),
                            cfg.checkpoint_name())

    @staticmethod
    def load_state(config: SchedulerConfig) -> Optional[Dict[str, Any]]:
        """The stored journal record for this config, or None (no
        store, no file, or fingerprint mismatch — a changed config
        must start fresh, never resume another config's journal)."""
        if not config.checkpoint_dir:
            return None
        fp = config.fingerprint()
        name = config.checkpoint_name()
        for path in (os.path.join(shard_dir(config.checkpoint_dir, fp),
                                  name),
                     os.path.join(config.checkpoint_dir, name)):
            if not os.path.exists(path):
                continue
            try:
                with open(path) as f:
                    rec = json.load(f)
            except (OSError, ValueError):
                continue
            if rec.get("fingerprint") == fp:
                return rec
        return None

    @classmethod
    def recover(cls, config: SchedulerConfig,
                mask_client=None) -> "AllocatorCore":
        """Fresh core, or one rebuilt by replaying the stored journal.
        Placement is deterministic in op order, so the replayed
        occupancy grid, queue and in-flight set are byte-identical to
        the pre-crash state (tested)."""
        core = cls(config, mask_client=mask_client)
        rec = cls.load_state(config)
        if rec:
            core._replay(rec)
        return core

    def _replay(self, rec: Dict[str, Any]) -> None:
        self._replaying = True
        try:
            for op in rec["journal"]:
                self.apply(dict(op))
        finally:
            self._replaying = False
            self._pending_topo = []
        self.journal = [dict(op) for op in rec["journal"]]
        self.next_id = max(self.next_id, int(rec.get("next_id", 0)))
        self.recovered_ops = len(self.journal)

    # -- op dispatch ---------------------------------------------------
    def apply(self, msg: Dict[str, Any]):
        """Dispatch one request dict -> (reply, events). Unknown ops
        and handler exceptions become error replies (the daemon must
        survive malformed clients)."""
        op = msg.get("op")
        handler = getattr(self, f"op_{op}", None)
        if handler is None:
            return {"ok": False, "error": f"unknown op {op!r}"}, []
        try:
            return handler(msg)
        except Exception as e:  # noqa: BLE001 — protocol boundary
            self._pending_topo = []
            return {"ok": False, "error": f"{type(e).__name__}: {e}"}, []

    @staticmethod
    def _shape(msg: Dict[str, Any]) -> JobShape:
        dims = tuple(int(v) for v in msg["shape"])
        if len(dims) != 3 or any(d <= 0 for d in dims):
            raise ValueError(f"shape must be 3 positive extents, "
                             f"got {dims}")
        return JobShape(dims)

    # -- service ops ---------------------------------------------------
    def op_submit(self, msg: Dict[str, Any]):
        """Streaming arrival: place now, queue FIFO, drop (shape can
        never fit), or reject (queue full). Placement respects
        head-of-line blocking: with a non-empty queue and no backfill,
        a new arrival queues behind the blocked head even if it would
        fit — identical to the simulator's discipline."""
        shape = self._shape(msg)
        job_id = msg.get("job_id")
        if job_id is None:
            job_id = self.next_id
        job_id = int(job_id)
        if any(j == job_id for j, _ in self.queue) \
                or job_id in self.model.allocations:
            return {"ok": False,
                    "error": f"job {job_id} already known"}, []
        if (self.config.max_queue is not None
                and len(self.queue) >= self.config.max_queue):
            # Stateless outcome: not journaled, no id consumed.
            return {"ok": True, "outcome": protocol.REJECTED,
                    "job_id": job_id, "queue_depth": len(self.queue)}, []
        self.next_id = max(self.next_id, job_id + 1)
        self._journal_op({"op": "submit", "job_id": job_id,
                          "shape": list(shape.dims)})
        if not self.policy.can_ever_place(shape):
            return {"ok": True, "outcome": protocol.DROPPED,
                    "job_id": job_id}, []
        placement = None
        if not self.queue or self.config.backfill:
            placement = self.policy.try_place(job_id, shape)
        if placement is None:
            self.queue.append((job_id, shape.dims))
            return {"ok": True, "outcome": protocol.QUEUED,
                    "job_id": job_id,
                    "queue_depth": len(self.queue)}, self._drain_topo()
        self.shapes[job_id] = shape.dims
        return ({"ok": True, "outcome": protocol.PLACED,
                 "job_id": job_id,
                 "placement": self._placement_fields(placement)},
                self._drain_topo())

    def op_done(self, msg: Dict[str, Any]):
        """A running job finished: release it, then drain the queue
        (FIFO; newly started jobs are announced via pushed SETUP —
        their owners subscribed for exactly this)."""
        job_id = int(msg["job_id"])
        queued = [j for j, _ in self.queue]
        if job_id in self.model.allocations:
            self._journal_op({"op": "done", "job_id": job_id})
            self.policy.release(job_id)
            self.shapes.pop(job_id, None)
            started = self._drain_fifo()
        elif job_id in queued:
            # Cancelled while queued.
            self._journal_op({"op": "done", "job_id": job_id})
            self.queue = [(j, s) for j, s in self.queue if j != job_id]
            started = []
        else:
            return {"ok": False, "error": f"job {job_id} not known"}, []
        return ({"ok": True, "job_id": job_id,
                 "started": started,
                 "queue_depth": len(self.queue)}, self._drain_topo())

    def _drain_fifo(self) -> List[Dict[str, Any]]:
        """The simulator's ``_drain_queue`` discipline: FIFO with
        head-of-line blocking; with backfill, later jobs may start
        past a blocked head. Drops queued jobs whose shape can never
        fit. Returns started/dropped notices (also pushed as events)."""
        started: List[Dict[str, Any]] = []
        i = 0
        while i < len(self.queue):
            job_id, dims = self.queue[i]
            shape = JobShape(dims)
            if not self.policy.can_ever_place(shape):
                self.queue.pop(i)
                started.append({"job_id": job_id,
                                "outcome": protocol.DROPPED})
                continue
            placement = self.policy.try_place(job_id, shape)
            if placement is None:
                if not self.config.backfill:
                    break
                i += 1
                continue
            self.queue.pop(i)
            self.shapes[job_id] = dims
            started.append({"job_id": job_id,
                            "outcome": protocol.PLACED,
                            "placement":
                                self._placement_fields(placement)})
        return started

    # -- raw policy ops (the simulator-as-client surface) -------------
    def op_try_place(self, msg: Dict[str, Any]):
        """Raw ``PlacementPolicy.try_place`` over the wire: no queue,
        no admission — the simulator client drives its own FIFO and
        needs exactly the in-process contract."""
        shape = self._shape(msg)
        job_id = int(msg["job_id"])
        placement = self.policy.try_place(job_id, shape)
        if placement is None:
            return {"ok": True, "outcome": "full"}, []
        self.next_id = max(self.next_id, job_id + 1)
        self._journal_op({"op": "try_place", "job_id": job_id,
                          "shape": list(shape.dims)})
        self.shapes[job_id] = shape.dims
        return ({"ok": True, "outcome": protocol.PLACED,
                 "placement": self._placement_fields(placement)},
                self._drain_topo())

    def op_release(self, msg: Dict[str, Any]):
        job_id = int(msg["job_id"])
        if job_id not in self.model.allocations:
            return {"ok": False, "error": f"job {job_id} not allocated"}, []
        self._journal_op({"op": "release", "job_id": job_id})
        self.policy.release(job_id)
        self.shapes.pop(job_id, None)
        return {"ok": True, "job_id": job_id}, self._drain_topo()

    # -- chaos ops (preemption, migration, fault injection) ------------
    def op_preempt(self, msg: Dict[str, Any]):
        """Evict a running job back to the *head* of the queue (it was
        already admitted — FIFO order is by first admission). Work is
        assumed checkpointed; the service tracks placement, not
        progress. The freed hole is deliberately NOT drained: the
        preempted head itself would immediately re-place into it."""
        job_id = int(msg["job_id"])
        if job_id not in self.model.allocations:
            return {"ok": False, "error": f"job {job_id} not allocated"}, []
        self._journal_op({"op": "preempt", "job_id": job_id})
        dims = self.shapes.pop(job_id)
        self.policy.release(job_id)
        self.queue.insert(0, (job_id, dims))
        events = self._drain_topo()
        events.append({"event": protocol.EV_PREEMPT, "job_id": job_id,
                       "shape": list(dims)})
        return ({"ok": True, "job_id": job_id,
                 "outcome": protocol.PREEMPTED,
                 "queue_depth": len(self.queue)}, events)

    def op_migrate(self, msg: Dict[str, Any]):
        """Evict + replan through the allocator *now*: the job lands in
        a fresh placement (``migrated``) or, if the cluster cannot fit
        it at the moment (degraded fabric), falls back to the queue
        head (``preempted``). Deterministic in op order, so the journal
        records only the intent."""
        job_id = int(msg["job_id"])
        if job_id not in self.model.allocations:
            return {"ok": False, "error": f"job {job_id} not allocated"}, []
        self._journal_op({"op": "migrate", "job_id": job_id})
        dims = self.shapes[job_id]
        self.policy.release(job_id)
        placement = self.policy.try_place(job_id, JobShape(dims))
        if placement is None:
            self.shapes.pop(job_id, None)
            self.queue.insert(0, (job_id, dims))
            events = self._drain_topo()
            events.append({"event": protocol.EV_PREEMPT,
                           "job_id": job_id, "shape": list(dims)})
            return ({"ok": True, "job_id": job_id,
                     "outcome": protocol.PREEMPTED,
                     "queue_depth": len(self.queue)}, events)
        events = self._drain_topo()
        events.append({"event": protocol.EV_MIGRATE, "job_id": job_id,
                       "shape": list(dims)})
        return ({"ok": True, "job_id": job_id,
                 "outcome": protocol.MIGRATED,
                 "placement": self._placement_fields(placement)}, events)

    def _fault_injector(self) -> FaultInjector:
        if self._injector is None:
            self._injector = FaultInjector(self.policy)
        return self._injector

    @staticmethod
    def _fault_event(msg: Dict[str, Any], action: str) -> FaultEvent:
        return FaultEvent.from_wire({"time": 0.0, "action": action,
                                     "kind": msg["kind"],
                                     "targets": msg.get("targets", [])})

    def op_fault(self, msg: Dict[str, Any]):
        """Inject a fabric fault (``kind`` = node|link|ocs_port,
        ``targets`` as in :class:`repro.sim.faults.FaultEvent`).
        Victims are evicted *before* the model transitions (the models
        refuse otherwise), then replanned in job-id order: re-placed
        now → ``migrated``; no capacity → ``preempted`` at the queue
        head. Journaled as intent — replay recomputes victims and
        replans deterministically."""
        ev = self._fault_event(msg, "fault")
        inj = self._fault_injector()
        victims = [j for j in inj.victims(ev)
                   if j in self.model.allocations]
        self._journal_op({"op": "fault", "kind": ev.kind,
                          "targets": list(ev.targets)})
        evicted: List[Tuple[int, Tuple[int, int, int]]] = []
        for jid in victims:
            dims = self.shapes.pop(jid)
            self.policy.release(jid)
            evicted.append((jid, dims))
        applied = inj.apply(ev)
        events = self._drain_topo()
        dispositions: List[Dict[str, Any]] = []
        requeue: List[Tuple[int, Tuple[int, int, int]]] = []
        for jid, dims in evicted:
            placement = self.policy.try_place(jid, JobShape(dims))
            if placement is not None:
                self.shapes[jid] = dims
                dispositions.append(
                    {"job_id": jid, "outcome": protocol.MIGRATED,
                     "placement": self._placement_fields(placement)})
                events.append({"event": protocol.EV_MIGRATE,
                               "job_id": jid, "shape": list(dims)})
            else:
                requeue.append((jid, dims))
                dispositions.append({"job_id": jid,
                                     "outcome": protocol.PREEMPTED})
                events.append({"event": protocol.EV_PREEMPT,
                               "job_id": jid, "shape": list(dims)})
        self.queue[0:0] = requeue
        events.extend(self._drain_topo())
        return ({"ok": True, "kind": ev.kind,
                 "applied": list(applied), "victims": dispositions,
                 "queue_depth": len(self.queue)}, events)

    def op_repair(self, msg: Dict[str, Any]):
        """Undo a fault (no-op for targets that never failed) and
        drain the queue — capacity came back."""
        ev = self._fault_event(msg, "repair")
        inj = self._fault_injector()
        self._journal_op({"op": "repair", "kind": ev.kind,
                          "targets": list(ev.targets)})
        applied = inj.apply(ev)
        started = self._drain_fifo()
        return ({"ok": True, "kind": ev.kind, "applied": list(applied),
                 "started": started,
                 "queue_depth": len(self.queue)}, self._drain_topo())

    def op_can_ever_place(self, msg: Dict[str, Any]):
        shape = self._shape(msg)
        return {"ok": True,
                "feasible": bool(self.policy.can_ever_place(shape))}, []

    # -- introspection -------------------------------------------------
    def op_status(self, msg: Dict[str, Any]):
        return {"ok": True, **self.status()}, []

    def status(self) -> Dict[str, Any]:
        return {
            "policy": self.policy.name,
            "num_xpus": int(self.policy.num_xpus),
            "busy_xpus": int(self.policy.busy_xpus),
            "utilization": float(self.policy.utilization()),
            "allocated": len(self.model.allocations),
            "queue_depth": len(self.queue),
            "next_id": self.next_id,
            "journal_ops": len(self.journal),
            "state_digest": self.state_digest(),
        }

    def state_digest(self) -> str:
        """Content hash of the full allocator state (occupancy bytes,
        fault masks, allocation ids + shapes, queue, id counter) — the
        byte-identity oracle for the crash-recovery and parity tests."""
        h = hashlib.sha256()
        h.update(self.model.occ.tobytes())
        dedicated = getattr(self.model, "dedicated", None)
        if dedicated is not None:
            h.update(dedicated.tobytes())
        # Chaos state: failed nodes, dead OCS ports, cut links — a
        # faulted cluster must never digest-match a healthy one.
        h.update(self.model.failed.tobytes())
        ocs_ok = getattr(self.model, "ocs_ok", None)
        if ocs_ok is not None:
            h.update(ocs_ok.tobytes())
        cut = getattr(self.model, "cut_links", None)
        if cut is not None:
            h.update(json.dumps(sorted(cut)).encode())
        h.update(json.dumps(sorted(self.model.allocations)).encode())
        h.update(json.dumps(sorted(
            (j, list(d)) for j, d in self.shapes.items())).encode())
        h.update(json.dumps(self.queue).encode())
        h.update(str(self.next_id).encode())
        return h.hexdigest()[:16]

    def op_sync(self, msg: Dict[str, Any]):
        path = self.sync_checkpoint()
        return {"ok": True, "path": path,
                "journal_ops": len(self.journal)}, []

    @staticmethod
    def _placement_fields(placement) -> Dict[str, Any]:
        return {"job_id": placement.job_id,
                "shape": list(placement.shape.dims),
                "broken_rings": list(placement.broken_rings),
                "meta": placement.meta}
