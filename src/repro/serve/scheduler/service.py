"""Thread-hosted allocator service: daemon + client in one handle.

:class:`Scheduler` runs a :class:`SchedulerDaemon` on a private asyncio
loop in a background thread and keeps one subscribed
:class:`SchedulerClient` for the caller — so synchronous code (the
public ``repro.api`` facade, tests, benchmarks) gets submit/done/events
without touching asyncio. It is also the crash-recovery harness:
:meth:`kill` tears the daemon down *without* a final checkpoint, and a
new ``Scheduler`` on the same ``checkpoint_dir`` recovers by journal
replay.

Replication (PR 10): construct with ``role="standby"`` and
``replicate_from=primary.address`` for a warm standby that tails the
primary's journal; :meth:`promote` makes it the fenced leader. The
facade's auto-heartbeat is jittered (``HEARTBEAT_JITTER``) so a fleet
of facade clients that reconnect together after a failover spreads
its renewals instead of hitting the new leader in lockstep.
"""
from __future__ import annotations

import threading
from typing import Any, Dict, List, Optional

from .client import RemotePolicy, SchedulerClient
from .core import SchedulerConfig
from .daemon import SchedulerDaemon

# Fractional spread of the auto-heartbeat interval (see
# SchedulerClient.start_heartbeat): each wait is drawn uniformly from
# interval * [1-J, 1+J]. 0.25 keeps the shortest wait well above the
# lease-renewal deadline (interval is lease_timeout / 3).
HEARTBEAT_JITTER = 0.25


class Scheduler:
    """Start a daemon, talk to it, stop (or crash) it."""

    def __init__(self, config: Optional[SchedulerConfig] = None,
                 mask_client=None, recover: bool = True, **config_kw):
        if config is None:
            config = SchedulerConfig(**config_kw)
        elif config_kw:
            raise TypeError("pass either a SchedulerConfig or kwargs, "
                            "not both")
        self.config = config
        self._mask_client = mask_client
        self._recover = recover
        self._daemon: Optional[SchedulerDaemon] = None
        self._loop = None
        self._thread: Optional[threading.Thread] = None
        self._ready = threading.Event()
        self._boot_error: Optional[BaseException] = None
        self._client: Optional[SchedulerClient] = None
        self.address: Optional[tuple] = None

    # -- lifecycle -----------------------------------------------------
    def start(self) -> "Scheduler":
        if self._thread is not None:
            raise RuntimeError("scheduler already started")
        self._thread = threading.Thread(target=self._run,
                                        name="repro-scheduler", daemon=True)
        self._thread.start()
        if not self._ready.wait(timeout=30.0):
            raise RuntimeError("scheduler daemon failed to come up")
        if self._boot_error is not None:
            raise self._boot_error
        self._client = SchedulerClient(self.address, subscribe=True)
        self._auto_heartbeat(self._client)
        return self

    def _auto_heartbeat(self, client: SchedulerClient) -> None:
        """With leases on, every facade-owned client heartbeats at a
        third of the lease timeout — an idle handle must not lose its
        jobs to the expiry loop."""
        if self.config.lease_timeout:
            client.start_heartbeat(self.config.lease_timeout / 3.0,
                                   jitter=HEARTBEAT_JITTER)

    def _run(self) -> None:
        import asyncio

        async def main() -> None:
            self._daemon = SchedulerDaemon(self.config, self._mask_client,
                                           recover=self._recover)
            try:
                self.address = await self._daemon.start()
            except BaseException as e:
                self._boot_error = e
                self._ready.set()
                return
            self._loop = asyncio.get_running_loop()
            self._ready.set()
            await self._daemon.wait_closed()

        asyncio.run(main())

    def _shut(self, crash: bool) -> None:
        if self._thread is None:
            return
        if self._client is not None:
            self._client.stop_heartbeat()
            try:
                if crash:
                    self._client.close()
                else:
                    self._client.shutdown()
            except (RuntimeError, ConnectionError, OSError,
                    TimeoutError):
                pass
            if crash:
                self._client = None
        if self._loop is not None and self._daemon is not None:
            target = self._daemon.kill if crash else self._daemon.stop
            try:
                self._loop.call_soon_threadsafe(target)
            except RuntimeError:
                pass  # loop already gone
        self._thread.join(timeout=30.0)
        self._thread = None
        if self._client is not None:
            self._client.close()
            self._client = None

    def stop(self) -> None:
        """Graceful shutdown: daemon writes a final checkpoint."""
        self._shut(crash=False)

    def kill(self) -> None:
        """Simulated crash: NO final checkpoint — the next Scheduler on
        this checkpoint_dir must recover from the last periodic one."""
        self._shut(crash=True)

    def __enter__(self) -> "Scheduler":
        return self.start() if self._thread is None else self

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- client surface ------------------------------------------------
    @property
    def client(self) -> SchedulerClient:
        if self._client is None:
            raise RuntimeError("scheduler not started")
        return self._client

    def new_client(self, subscribe: bool = False) -> SchedulerClient:
        """An independent connection (e.g. to drive a Simulator via
        RemotePolicy while this handle watches events)."""
        if self.address is None:
            raise RuntimeError("scheduler not started")
        client = SchedulerClient(self.address, subscribe=subscribe)
        self._auto_heartbeat(client)
        return client

    def remote_policy(self) -> RemotePolicy:
        """A PlacementPolicy adapter over a fresh connection."""
        return RemotePolicy(self.new_client())

    def submit(self, shape, job_id: Optional[int] = None) -> Dict[str, Any]:
        return self.client.submit(shape, job_id=job_id)

    def done(self, job_id: int) -> Dict[str, Any]:
        return self.client.done(job_id)

    def preempt(self, job_id: int) -> Dict[str, Any]:
        return self.client.preempt(job_id)

    def migrate(self, job_id: int) -> Dict[str, Any]:
        return self.client.migrate(job_id)

    def fault(self, kind: str, targets) -> Dict[str, Any]:
        return self.client.fault(kind, targets)

    def repair(self, kind: str, targets) -> Dict[str, Any]:
        return self.client.repair(kind, targets)

    def events(self, max_wait: float = 0.0) -> List[Dict[str, Any]]:
        return self.client.events(max_wait=max_wait)

    def status(self) -> Dict[str, Any]:
        return self.client.status()

    def sync(self) -> Dict[str, Any]:
        return self.client.sync()

    def promote(self) -> Dict[str, Any]:
        """Make this daemon the leader: stop tailing (if a standby),
        mint + journal a new fencing epoch, start expiring leases."""
        return self.client.call("promote")
