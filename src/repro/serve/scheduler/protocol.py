"""Wire protocol of the allocator service (JSON lines over TCP).

One message per line, UTF-8 JSON. Two message classes share the
stream:

  * **Requests/replies** — a client tags each request with a
    monotonically increasing ``seq``; the daemon's reply echoes it.
    Replies always carry ``ok`` (bool) and, on failure, ``error``.
  * **Pushed events** — untagged messages carrying an ``event`` key
    (``SETUP``/``RECONFIG``/``RELEASE``), delivered to connections
    that issued ``subscribe``. This mirrors the Configurator →
    ``Job.send_setup``/``send_reconfig`` protocol of
    models-on-the-move (SNIPPETS.md §1), with JSON lines instead of
    ``SETUP-``-prefixed byte blobs.

Resilience fields (PR 9) — every request may additionally carry:

  ``request_id``      client-generated idempotency token (the stock
                      client sends ``"<client-id>:<seq>"``). The
                      daemon remembers the reply to every *journaled*
                      op per request_id (bounded LRU, persisted via
                      the journal), so a retry after a reconnect — or
                      even across a daemon crash + recovery — returns
                      the original reply instead of double-applying.
                      Stateless replies (status, REJECTED, errors) are
                      recomputed, which is safe by construction.
  ``client``          stable client identity. Carrying it makes this
                      client the *lease holder* of the jobs it
                      submits/places; with ``lease_timeout`` set, the
                      daemon expires clients that stop sending (any
                      request renews the lease) and requeues or
                      releases their jobs per ``lease_policy``.

Replication & fencing fields (PR 10):

  ``epoch``           the monotonic **fencing token**. Every reply
                      carries the daemon's current epoch; clients
                      remember the highest epoch they have witnessed
                      and stamp it on every request. A daemon that
                      receives a request stamped with a *higher* epoch
                      than its own has provably been superseded (a new
                      leader was promoted while it was paused, dead,
                      or partitioned): it fences itself and refuses
                      every state-changing op with ``NOT_LEADER`` —
                      nothing reaches its journal, so a stale primary
                      can never double-place. Symmetrically a client
                      that sees a reply with a *lower* epoch than its
                      watermark discards it and fails over.
  ``NOT_LEADER``      error code on refused writes; the reply carries
                      ``leader`` = [host, port] when the daemon knows
                      where the current leader lives, so clients can
                      follow the redirect instead of scanning their
                      server list.

Replication ops:

  ``repl_pull``       fingerprint, index, acked, wait — a follower's
                      cursor into the leader's op log. The reply holds
                      ``frames`` (base64 of WAL-framed records from
                      ``index``; the PR 9 on-disk framing *is* the
                      replication format), ``next`` (the follower's
                      new cursor) and the leader's ``epoch``.
                      ``acked`` piggybacks the follower's durable
                      index — in sync ack mode the leader holds client
                      acks until the standby has fsynced the op.
                      ``wait`` long-polls: the reply is deferred until
                      new records exist (or a timeout), so a warm
                      standby tails record-for-record without busy
                      polling. A fingerprint mismatch is refused: a
                      follower must never apply another config's log.
  ``promote``         mint a new fencing epoch (old + 1, journaled) and
                      become leader. On a standby this stops the
                      replication tail first; the promotion record is
                      the first op of the new epoch.
  ``fence``           epoch, leader — best-effort notice to an old
                      primary that a higher epoch exists; it fences
                      itself exactly as a stamped request would force.

Request ops (``{"op": ..., "seq": n, ...fields}``):

  ``submit``          shape=[a,b,c], optional job_id → outcome
                      ``placed``/``queued``/``dropped``/``rejected``
  ``done``            job_id — the job finished; frees its allocation
                      and drains the queue
  ``try_place``       job_id, shape — raw policy op (the simulator
                      client path; no queueing/admission semantics)
  ``release``         job_id — raw policy op
  ``can_ever_place``  shape → feasible on an empty cluster?
  ``preempt``         job_id — evict a running job back to the queue
                      head (checkpoint-resume assumed) → ``preempted``
  ``migrate``         job_id — evict + replan through the allocator
                      now → ``migrated`` (new placement) or
                      ``preempted`` (no capacity: queued at the head)
  ``fault``           kind=node|link|ocs_port, targets — inject a
                      fabric fault; victims are evicted first, then
                      replanned (each → ``migrated``/``preempted``)
  ``repair``          kind, targets — undo a fault (no-op for targets
                      that never failed) and drain the queue
  ``heartbeat``       lease renewal (any request renews too; this one
                      exists so an idle client can stay alive) →
                      echoes the daemon's lease_timeout/lease_policy
  ``lease_expire``    client, action=requeue|release — disposition a
                      dead client's jobs now (normally issued by the
                      daemon's own expiry loop, journaled with the
                      resolved action so replay is policy-independent)
  ``status``          → policy/occupancy/queue snapshot + state digest
                      + resilience counters (dedup/lease/WAL)
  ``events``? no      (events are pushed, never polled)
  ``subscribe``       register this connection for pushed events
                      (bounded per-subscriber queue: a subscriber that
                      stops reading is marked lagged and dropped,
                      never buffered unboundedly)
  ``sync``            force a checkpoint write now
  ``shutdown``        graceful stop (final checkpoint, then close)

Values are JSON-native: tuples become lists on the wire; the client
converts shape-like fields back (`broken_rings`, meta tuples) where
the in-process API promises tuples.
"""
from __future__ import annotations

import json
from typing import Any, Dict

import numpy as np

# Submit outcomes.
PLACED = "placed"        # allocation committed, SETUP pushed
QUEUED = "queued"        # feasible but no capacity now: FIFO-queued
DROPPED = "dropped"      # shape incompatible with the cluster (ever)
REJECTED = "rejected"    # admission control: queue full (overload)
# Eviction outcomes (preempt/migrate/fault victims).
PREEMPTED = "preempted"  # evicted, re-queued at the head
MIGRATED = "migrated"    # evicted and re-placed immediately

# Fencing: error code a superseded (or standby) daemon answers
# state-changing ops with; the reply may carry ``leader`` = [host,
# port] for the client to follow.
NOT_LEADER = "NOT_LEADER"

# Daemon roles.
ROLE_PRIMARY = "primary"
ROLE_STANDBY = "standby"

# Pushed event names (models-on-the-move spelling).
EV_SETUP = "SETUP"
EV_RECONFIG = "RECONFIG"
EV_RELEASE = "RELEASE"
# Chaos-layer events: fabric transitions and victim dispositions.
EV_FAULT = "FAULT"
EV_REPAIR = "REPAIR"
EV_PREEMPT = "PREEMPT"
EV_MIGRATE = "MIGRATE"
# Liveness: a dead client's lease lapsed; one event per owned job
# with its disposition (action=requeue|release).
EV_LEASE = "LEASE_EXPIRED"


def _jsonable(obj: Any):
    """numpy scalars leak out of occupancy math; flatten them."""
    if isinstance(obj, np.integer):
        return int(obj)
    if isinstance(obj, np.floating):
        return float(obj)
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    raise TypeError(f"not JSON-serializable: {obj!r}")


def encode(msg: Dict[str, Any]) -> bytes:
    """One protocol line (terminated), ready for the socket."""
    return (json.dumps(msg, default=_jsonable) + "\n").encode()


def decode(line: bytes) -> Dict[str, Any]:
    return json.loads(line)


def detuple(obj):
    """JSON turned every tuple into a list; restore tuples for the
    shape-like values the in-process API returns as tuples (lists and
    nested lists become tuples recursively — placement meta contains
    only scalars, strings and shape tuples, so this is lossless)."""
    if isinstance(obj, list):
        return tuple(detuple(v) for v in obj)
    if isinstance(obj, dict):
        return {k: detuple(v) for k, v in obj.items()}
    return obj
