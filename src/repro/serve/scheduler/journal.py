"""Crash-safe write-ahead journal for the allocator daemon.

The snapshot store (``repro.eval.runner``'s atomic tmp+rename
checkpoints) makes *whole* snapshots durable, but anything between two
snapshots dies with the process. This module adds the missing tail: an
append-only WAL where every journaled op is framed, checksummed and
fsynced **before** its reply leaves the daemon, so recovery replays
``snapshot + WAL tail`` and loses nothing that was acknowledged.

Framing (little-endian, one record per committed op)::

    file   := MAGIC(8) record*
    record := length:u32 crc32:u32 payload[length]

``payload`` is canonical JSON (``sort_keys=True``) of the op dict. The
magic doubles as the format version: an unrecognized header is treated
as an incompatible (foreign) file and ignored wholesale rather than
misparsed.

Torn-write semantics — the entire point of the framing: a crash (or
SIGKILL) mid-``write`` leaves a trailing record that is short, fails
its CRC, or is not valid JSON. :func:`recover_journal` stops at the
first such record and **truncates the file back to the last good
offset**, so the journal is again well-formed for subsequent appends;
it never raises on a corrupt tail. Every acknowledged op precedes the
torn one by the fsync ordering, so truncation only ever discards
unacknowledged work.

Replication (PR 10): the framing doubles as the **over-the-wire
replication format**. :func:`encode_frames` / :func:`decode_frames`
are the pure-bytes halves of the writer/recovery above — the primary
daemon answers a follower's cursor with a run of framed records (no
MAGIC; the stream id travels as the config fingerprint instead), and
the follower applies every frame that checks out, ignoring a torn
tail exactly as crash recovery would. One format, one parser, one set
of torn-tail semantics for disk and wire.
"""
from __future__ import annotations

import json
import os
import struct
import zlib
from typing import Any, Dict, List, Tuple

MAGIC = b"RPROWAL1"
_HEADER = struct.Struct("<II")   # payload length, crc32(payload)


def frame_record(rec: Dict[str, Any]) -> bytes:
    """One framed record: ``length u32 | crc32 u32 | canonical JSON``."""
    payload = json.dumps(rec, sort_keys=True).encode()
    return _HEADER.pack(len(payload), zlib.crc32(payload)) + payload


def encode_frames(records: List[Dict[str, Any]]) -> bytes:
    """Frame a run of records for the replication stream (no MAGIC —
    the stream identity is negotiated separately)."""
    return b"".join(frame_record(r) for r in records)


def scan_frames(data: bytes,
                offset: int = 0) -> Tuple[List[Dict[str, Any]], int]:
    """Parse framed records starting at ``offset``; stops at the first
    short/corrupt frame. Returns ``(records, end_offset)`` where
    ``end_offset`` is the byte just past the last intact record."""
    records: List[Dict[str, Any]] = []
    off = offset
    good = off
    while off + _HEADER.size <= len(data):
        length, crc = _HEADER.unpack_from(data, off)
        payload = data[off + _HEADER.size:off + _HEADER.size + length]
        if len(payload) < length or zlib.crc32(payload) != crc:
            break
        try:
            rec = json.loads(payload)
        except ValueError:
            break
        records.append(rec)
        off += _HEADER.size + length
        good = off
    return records, good


def decode_frames(data: bytes) -> Tuple[List[Dict[str, Any]], bool]:
    """Wire-side frame parse: every intact record plus a flag for
    trailing garbage (a torn frame in a replication reply means a
    corrupted reply — the follower re-pulls from its cursor)."""
    records, good = scan_frames(data, 0)
    return records, good != len(data)


class JournalWriter:
    """Append-only framed writer with fsync-on-commit.

    ``fsync=False`` trades durability of the last few ops for write
    latency (tests and benchmarks that only need crash *consistency*,
    not durability, use it); framing and torn-tail recovery are
    unaffected either way.
    """

    def __init__(self, path: str, fsync: bool = True):
        self.path = path
        self.fsync = fsync
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self._f = open(path, "ab")
        if self._f.tell() == 0:
            self._f.write(MAGIC)
            self._commit()

    def _commit(self) -> None:
        self._f.flush()
        if self.fsync:
            os.fsync(self._f.fileno())

    def append(self, rec: Dict[str, Any]) -> None:
        """Frame + write + (optionally) fsync one record. On return
        the record is durable: a crash after ``append`` replays it."""
        self._f.write(frame_record(rec))
        self._commit()

    def reset(self) -> None:
        """Truncate back to an empty (header-only) journal — called
        right after a snapshot subsumes the tail."""
        self._f.close()
        self._f = open(self.path, "wb")
        self._f.write(MAGIC)
        self._commit()

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()


def recover_journal(path: str,
                    repair: bool = True) -> Tuple[List[Dict[str, Any]],
                                                  bool]:
    """Read every intact record; returns ``(records, truncated)``.

    ``truncated`` is True when a torn/corrupt tail (short frame, CRC
    mismatch, bad JSON — or a foreign/garbage header) was found; with
    ``repair=True`` the file is truncated back to the last good record
    so future appends land on a well-formed journal. Missing file =
    ``([], False)``: never an error.
    """
    try:
        with open(path, "rb") as f:
            data = f.read()
    except FileNotFoundError:
        return [], False
    if not data.startswith(MAGIC):
        # Unknown version or garbage: nothing salvageable.
        if repair and data:
            with open(path, "wb") as f:
                f.write(MAGIC)
        return [], bool(data)
    records, good = scan_frames(data, len(MAGIC))
    truncated = good != len(data)
    if truncated and repair:
        with open(path, "r+b") as f:
            f.truncate(good)
    return records, truncated
