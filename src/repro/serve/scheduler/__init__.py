"""Allocator-as-a-service: long-lived scheduling daemon + clients.

Layers (each importable on its own):

  * :mod:`.protocol` — JSON-lines wire format and outcome constants.
  * :mod:`.core`     — :class:`SchedulerConfig` + :class:`AllocatorCore`
                       (policy, FIFO queue, admission, op journal,
                       checkpoint recovery via the eval store).
  * :mod:`.daemon`   — :class:`SchedulerDaemon`, the asyncio server.
  * :mod:`.client`   — :class:`SchedulerClient` (blocking socket) and
                       :class:`RemotePolicy` (simulator adapter).
  * :mod:`.service`  — :class:`Scheduler`, the thread-hosted facade.

Most callers want :class:`Scheduler` via :mod:`repro.api`.
"""
from __future__ import annotations

from .client import RemotePolicy, SchedulerClient, jittered_interval
from .core import AllocatorCore, SchedulerConfig
from .daemon import SchedulerDaemon
from .protocol import (DROPPED, EV_FAULT, EV_MIGRATE, EV_PREEMPT,
                       EV_RECONFIG, EV_RELEASE, EV_REPAIR, EV_SETUP,
                       MIGRATED, NOT_LEADER, PLACED, PREEMPTED, QUEUED,
                       REJECTED, ROLE_PRIMARY, ROLE_STANDBY)
from .service import HEARTBEAT_JITTER, Scheduler

__all__ = [
    "HEARTBEAT_JITTER",
    "NOT_LEADER",
    "ROLE_PRIMARY",
    "ROLE_STANDBY",
    "jittered_interval",
    "AllocatorCore",
    "RemotePolicy",
    "Scheduler",
    "SchedulerClient",
    "SchedulerConfig",
    "SchedulerDaemon",
    "PLACED",
    "QUEUED",
    "DROPPED",
    "REJECTED",
    "PREEMPTED",
    "MIGRATED",
    "EV_SETUP",
    "EV_RECONFIG",
    "EV_RELEASE",
    "EV_FAULT",
    "EV_REPAIR",
    "EV_PREEMPT",
    "EV_MIGRATE",
]
