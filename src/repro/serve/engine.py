"""Serving engine: prefill + batched single-token decode over the
unified KV/recurrent decode state.

``prefill`` runs the full-sequence forward while *also* populating the
decode state (by replaying the cache writes token-group-wise this would
be the fused path on TPU; here we populate by running decode_step over
the prompt — exact, simple, and the dry-run lowers ``serve_step``, which
is the shape that matters).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.configs.shapes import InputShape, cache_window
from repro.models import model as lm
from repro.models.common import ModelConfig


def init_state(cfg: ModelConfig, batch: int, window: int,
               dtype=None) -> List:
    return lm.init_decode_state(cfg, batch, window,
                                dtype or cfg.activation_dtype)


def serve_step(cfg: ModelConfig, params: Any, state: List,
               batch: Dict) -> Tuple[jnp.ndarray, List]:
    """One decode step for a batch of sequences (the dry-run target)."""
    return lm.decode_step(cfg, params, state, batch)


def greedy_decode(cfg: ModelConfig, params: Any, prompt: jnp.ndarray,
                  steps: int, window: int = 0) -> jnp.ndarray:
    """Greedy generation (CPU-scale demo): prompt (B, S0) -> (B, S0+steps).

    Prompt ingestion uses decode_step per position (exact cache
    population); generation continues greedily.
    """
    b, s0 = prompt.shape[0], prompt.shape[-1]
    window = window or cache_window(
        cfg, InputShape("gen", s0 + steps, b, "decode"))
    state = init_state(cfg, b, window)

    step_fn = jax.jit(partial(serve_step, cfg))

    def make_batch(tok, pos):
        bt: Dict[str, Any] = {"tokens": tok}
        if cfg.pos_type == "mrope":
            bt["positions"] = jnp.broadcast_to(
                pos[:, :, None], pos.shape + (3,))
        else:
            bt["positions"] = pos
        return bt

    toks = prompt
    logits = None
    for t in range(s0):
        pos = jnp.full((b, 1), t, jnp.int32)
        cur = toks[..., t:t + 1]
        logits, state = step_fn(params, state, make_batch(cur, pos))
    for t in range(steps):
        last = logits[:, -1]                       # (B,V) | (B,K,V) audio
        nxt = jnp.argmax(last, axis=-1).astype(jnp.int32)[..., None]
        toks = jnp.concatenate([toks, nxt], axis=-1)
        pos = jnp.full((b, 1), s0 + t, jnp.int32)
        logits, state = step_fn(params, state, make_batch(nxt, pos))
    return toks
