import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# XLA_FLAGS must be set before any other import (see dryrun.py).

r"""Perf-iteration harness (§Perf): run named optimization variants of a
(arch x shape) pair through the dry-run cost pipeline and report the
three roofline terms per variant, so each hypothesis -> change ->
before/after cycle is one CLI call.

  PYTHONPATH=src python -m repro.launch.perf --arch qwen1.5-110b \
      --shape train_4k --variants baseline,remat_full,no_fsdp
"""
import argparse
import json
import time
from typing import Any, Callable, Dict

from repro.configs import get_config
from repro.configs.shapes import SHAPES
from repro.launch import dryrun as dr

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def _v_baseline(cfg, rules):
    return cfg, rules


def _v_remat_full(cfg, rules):
    """Hypothesis: full activation remat cuts HBM traffic (memory term)
    at ~+1/3 compute."""
    return cfg.replace(remat="full"), rules


def _v_no_fsdp(cfg, rules):
    """Hypothesis: replicating params over the data axis removes the
    per-layer all-gathers (collective term) at the cost of per-chip
    parameter memory."""
    rules = dict(rules)
    rules["fsdp"] = None
    return cfg, rules


def _v_seq_shard(cfg, rules):
    """Hypothesis: sharding activations along sequence (context
    parallelism) moves batch-axis pressure to the data axis for
    long-sequence prefill."""
    rules = dict(rules)
    rules["seq"] = "data"
    rules["batch"] = None
    return cfg, rules


def _v_cap_tight(cfg, rules):
    """Hypothesis (MoE): capacity factor 1.0 cuts expert-dispatch
    compute/all-to-all bytes proportionally (more drops)."""
    return cfg.replace(capacity_factor=1.0), rules


def _v_cap_loose(cfg, rules):
    return cfg.replace(capacity_factor=2.0), rules


def _v_window_4k(cfg, rules):
    """Hypothesis (long-context decode): halving the sliding window
    halves KV bytes per step (memory term) without touching params."""
    return cfg.replace(sliding_window=4096), rules


def _v_window_16k(cfg, rules):
    return cfg.replace(sliding_window=16384), rules


def _v_mla_absorb(cfg, rules):
    """Hypothesis (MLA decode): weight absorption attends in the
    compressed c_kv space, removing the per-step re-expansion of k/v
    over the whole cache — expect order-of-magnitude drops in the
    compute AND memory terms at identical math."""
    return cfg.replace(mla_absorb=True), rules


def _v_cache_seq_model(cfg, rules):
    """Hypothesis (decode): sharding the KV/c_kv cache's sequence dim
    over the model axis (flash-decode style partial softmax) splits the
    dominant cache-read bytes across the model axis at the cost of an
    all-reduce over partial softmax stats."""
    rules = dict(rules)
    rules["cache_seq"] = "model"
    return cfg, rules


def _v_absorb_plus_cacheshard(cfg, rules):
    rules = dict(rules)
    rules["cache_seq"] = "model"
    return cfg.replace(mla_absorb=True), rules


def _v_absorb_cacheshard_nofsdp(cfg, rules):
    """Hypothesis: with compute/memory crushed, decode's collective
    term is dominated by per-step param all-gathers (FSDP); replicating
    params over the data axis removes them."""
    rules = dict(rules)
    rules["cache_seq"] = "model"
    rules["fsdp"] = None
    return cfg.replace(mla_absorb=True), rules


def _v_moe_local(cfg, rules):
    """Hypothesis (MoE train): the global-argsort dispatch forces XLA
    to all-gather every token per MoE layer (a sort cannot be sharded);
    per-batch-row dispatch keeps routing local to the data shard —
    expect the collective term to collapse by >10x."""
    return cfg.replace(moe_local_dispatch=True), rules


def _v_moe_local_noefsdp(cfg, rules):
    """Hypothesis: local dispatch removed the token all-gather, but the
    expert matmuls' contraction dim is FSDP-sharded on 'data', forcing
    an all-reduce of the (B,E,cap,f) expert outputs every layer.
    Un-sharding ONLY the expert weights' fsdp dim (experts stay
    expert-parallel on 'model') should collapse the collective term."""
    rules = dict(rules)
    rules["expert_fsdp"] = None
    return cfg.replace(moe_local_dispatch=True), rules


VARIANTS: Dict[str, Callable] = {
    "moe_local": _v_moe_local,
    "moe_local_noefsdp": _v_moe_local_noefsdp,
    "cache_seq_model": _v_cache_seq_model,
    "absorb_cacheshard": _v_absorb_plus_cacheshard,
    "absorb_cs_nofsdp": _v_absorb_cacheshard_nofsdp,
    "baseline": _v_baseline,
    "remat_full": _v_remat_full,
    "no_fsdp": _v_no_fsdp,
    "seq_shard": _v_seq_shard,
    "cap_tight": _v_cap_tight,
    "cap_loose": _v_cap_loose,
    "window_4k": _v_window_4k,
    "window_16k": _v_window_16k,
    "mla_absorb": _v_mla_absorb,
}


def run_variant(arch: str, shape_name: str, variant: str,
                multi_pod: bool = False) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    # Build with variant-modified cfg/rules: reuse dryrun.build_dryrun by
    # monkey-patching rules through cfg — simpler: inline a modified copy.
    from repro.launch.mesh import make_production_mesh
    from repro.parallel.sharding import rules_for
    mesh = make_production_mesh(multi_pod=multi_pod)
    long_ctx = shape.name == "long_500k"
    rules = rules_for(mesh, shard_cache_seq=long_ctx)
    if long_ctx:
        rules["batch"] = None
    cfg2, rules2 = VARIANTS[variant](cfg, rules)

    l_small, l_big = dr.probe_depths(cfg2)
    t0 = time.time()
    c_small = _probe(cfg2, rules2, shape, multi_pod, l_small)
    c_big = _probe(cfg2, rules2, shape, multi_pod, l_big)
    span = l_big - l_small
    L = cfg2.n_layers

    def extrap(key):
        return c_small[key] + (c_big[key] - c_small[key]) / span \
            * (L - l_small)

    flops, byts, coll = (extrap("flops"), extrap("bytes"),
                         extrap("collective_bytes"))
    terms = {"compute": flops / PEAK_FLOPS, "memory": byts / HBM_BW,
             "collective": coll / ICI_BW}
    return {
        "arch": arch, "shape": shape_name, "variant": variant,
        "flops_per_chip": flops, "bytes_per_chip": byts,
        "collective_bytes_per_chip": coll,
        "t_compute_s": terms["compute"], "t_memory_s": terms["memory"],
        "t_collective_s": terms["collective"],
        "dominant": max(terms, key=terms.get),
        "wall_s": round(time.time() - t0, 1),
    }


def _probe(cfg, rules, shape, multi_pod, depth):
    """dryrun._compile_cost with explicit rules (variant may change
    them)."""
    cfg_p = cfg.replace(n_layers=depth, force_unscanned=True)
    # Temporarily swap rules_for used by build_dryrun via the logical
    # rules the step function reads; build_dryrun computes its own rule
    # table, so patch it here.
    orig = dr.rules_for

    def patched(mesh, **kw):
        return dict(rules)

    dr.rules_for = patched
    try:
        out = dr._compile_cost(cfg_p, shape, multi_pod)
    finally:
        dr.rules_for = orig
    return out


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=list(SHAPES))
    ap.add_argument("--variants", default="baseline")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--out", default="")
    args = ap.parse_args(argv)
    rows = []
    for v in args.variants.split(","):
        r = run_variant(args.arch, args.shape, v,
                        multi_pod=args.mesh == "multi")
        rows.append(r)
        print(json.dumps(r), flush=True)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
