"""RFold-scheduled multi-tenant cluster driver — the paper's technique
as a first-class feature of the training framework.

Jobs (arch + parallelism shape) are submitted to an RFold scheduler
managing a (simulated) reconfigurable torus. Each admitted job gets a
folded, contention-free allocation; the launcher then builds a JAX mesh
whose device order follows the allocation's ring traversal
(mesh_from_allocation), and runs training steps for the job on that
mesh. On this CPU container the torus XPUs are host-platform
placeholder devices; on a TPU deployment the same coordinates map to
``jax.devices()[i].coords``.

  XLA_FLAGS=--xla_force_host_platform_device_count=64 \
  PYTHONPATH=src python -m repro.launch.cluster --jobs 4 --steps 3
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List, Optional

import jax

from repro.configs import get_config, smoke_variant
from repro.core.allocator import RFoldPolicy
from repro.core.geometry import JobShape
from repro.models import model as lm
from repro.parallel.sharding import logical_rules, rules_for
from repro.train.data import shard_batch, synthetic_batches
from repro.train.optim import OptimConfig, init_opt_state
from repro.train.train_step import train_step
from .mesh import mesh_from_allocation


class RFoldCluster:
    """Thin runtime wrapper: RFold placement -> JAX mesh -> job steps."""

    def __init__(self, num_xpus: int = 64, cube_n: int = 2):
        self.policy = RFoldPolicy(num_xpus=num_xpus, cube_n=cube_n)
        self.num_xpus = num_xpus
        self.jobs: Dict[int, dict] = {}

    def submit(self, job_id: int, arch: str, shape: JobShape,
               seed: int = 0) -> Optional[dict]:
        placement = self.policy.try_place(job_id, shape)
        if placement is None:
            return None
        # Device mesh: (data, model) = (product of non-model dims, model)
        # The fold's ring order is the device order, so the model axis
        # ring maps onto torus-neighbour links.
        dims = sorted(shape.dims, reverse=True)
        model_par = dims[1] if dims[1] > 1 else 1
        data_par = shape.size // model_par
        mesh = mesh_from_allocation(
            [(0, 0, i) for i in range(shape.size)],  # placeholder coords
            (data_par, model_par), ("data", "model"))
        cfg = smoke_variant(get_config(arch)).replace(dtype="float32")
        params = lm.init_model(cfg, jax.random.PRNGKey(seed))
        job = {
            "id": job_id, "arch": arch, "shape": str(shape),
            "placement": placement.meta, "mesh": mesh, "cfg": cfg,
            "params": params,
            "opt": init_opt_state(params),
            "opt_cfg": OptimConfig(lr=1e-3, warmup_steps=1,
                                   total_steps=100),
            "data": synthetic_batches(cfg, batch=max(data_par, 1),
                                      seq=32, seed=seed),
        }
        self.jobs[job_id] = job
        return job

    def run_steps(self, job_id: int, steps: int) -> List[float]:
        job = self.jobs[job_id]
        cfg, mesh = job["cfg"], job["mesh"]
        rules = rules_for(mesh)

        def fn(p, o, b):
            with logical_rules(rules):
                return train_step(cfg, job["opt_cfg"], p, o, b)

        step = jax.jit(fn)
        losses = []
        with mesh:
            for _ in range(steps):
                batch = shard_batch(next(job["data"]), mesh)
                job["params"], job["opt"], m = step(job["params"],
                                                    job["opt"], batch)
                losses.append(float(m["ce"]))
        return losses

    def finish(self, job_id: int) -> None:
        self.policy.release(job_id)
        self.jobs.pop(job_id, None)

    def utilization(self) -> float:
        return self.policy.utilization()


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--jobs", type=int, default=3)
    ap.add_argument("--steps", type=int, default=3)
    ap.add_argument("--num-xpus", type=int, default=0,
                    help="default: len(jax.devices())")
    ap.add_argument("--cube-n", type=int, default=2)
    args = ap.parse_args(argv)

    n_dev = len(jax.devices())
    num_xpus = args.num_xpus or n_dev
    cluster = RFoldCluster(num_xpus=num_xpus, cube_n=args.cube_n)
    submissions = [
        ("olmo-1b", JobShape((2, 2, 1))),
        ("llama3-8b", JobShape((4, 1, 1))),
        ("xlstm-1.3b", JobShape((2, 1, 1))),
        ("musicgen-medium", JobShape((2, 2, 1))),
        ("zamba2-1.2b", JobShape((6, 1, 1))),
    ][:args.jobs]
    for jid, (arch, shape) in enumerate(submissions):
        if shape.size > n_dev:
            print(f"job {jid}: {arch} {shape} skipped (needs {shape.size} "
                  f"devices, have {n_dev})")
            continue
        job = cluster.submit(jid, arch, shape, seed=jid)
        if job is None:
            print(f"job {jid}: {arch} {shape} -> queued (no allocation)")
            continue
        print(f"job {jid}: {arch} shape={shape} -> fold="
              f"{job['placement'].get('fold')} cubes="
              f"{job['placement'].get('num_cubes')} "
              f"util={cluster.utilization():.2f}")
        losses = cluster.run_steps(jid, args.steps)
        print(f"  losses: {[round(l, 3) for l in losses]}")
        cluster.finish(jid)
    print(json.dumps({"final_utilization": cluster.utilization()}))


if __name__ == "__main__":
    main()
