"""Training launcher: end-to-end driver over the synthetic pipeline.

CPU demo scale by default (smoke variants); on a pod the same flags
drive the full configs under the production mesh.

  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --smoke \
      --steps 50 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import json
import time

import jax

from repro.configs import get_config, smoke_variant
from repro.models import model as lm
from repro.parallel.sharding import (logical_rules, param_shardings,
                                     rules_for)
from repro.train.checkpoint import save_checkpoint
from repro.train.data import shard_batch, synthetic_batches
from repro.train.optim import OptimConfig, init_opt_state
from repro.train.train_step import train_step
from .mesh import make_mesh


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--d-model", type=int, default=0,
                    help="override smoke d_model (e.g. ~100M params)")
    ap.add_argument("--n-layers", type=int, default=0)
    ap.add_argument("--mesh", type=str, default="",
                    help="e.g. '4x2' => data x model over local devices")
    ap.add_argument("--ckpt", type=str, default="")
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    over = {}
    if args.d_model:
        over["d_model"] = args.d_model
    if args.n_layers:
        over["n_layers"] = args.n_layers
    if over:
        cfg = cfg.replace(**over)
    cfg = cfg.replace(dtype="float32")  # CPU numerics

    mesh = None
    rules = None
    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split("x"))
        axes = ("data", "model")[:len(shape)]
        mesh = make_mesh(shape, axes)
        rules = rules_for(mesh)

    params = lm.init_model(cfg, jax.random.PRNGKey(args.seed))
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"layers={cfg.n_layers} d={cfg.d_model}")

    opt_cfg = OptimConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                          total_steps=args.steps)
    opt_state = init_opt_state(params)

    def step_fn(p, o, b):
        with logical_rules(rules):
            return train_step(cfg, opt_cfg, p, o, b)

    if mesh is not None:
        p_sh = param_shardings(params, mesh, rules,
                               n_expert_hint=cfg.n_experts)
        params = jax.device_put(params, p_sh)
        step = jax.jit(step_fn)
    else:
        step = jax.jit(step_fn)

    data = synthetic_batches(cfg, args.batch, args.seq, seed=args.seed)
    t0 = time.time()
    history = []
    ctx = mesh if mesh is not None else _nullcontext()
    with ctx:
        for i in range(args.steps):
            batch = next(data)
            if mesh is not None:
                batch = shard_batch(batch, mesh)
            params, opt_state, m = step(params, opt_state, batch)
            if i % args.log_every == 0 or i == args.steps - 1:
                loss = float(m["ce"])
                history.append({"step": i, "ce": loss,
                                "lr": float(m["lr"]),
                                "grad_norm": float(m["grad_norm"]),
                                "elapsed_s": round(time.time() - t0, 1)})
                print(json.dumps(history[-1]), flush=True)
    if args.ckpt:
        save_checkpoint(args.ckpt, params, opt_state, step=args.steps,
                        meta={"arch": cfg.name, "ce": history[-1]["ce"]})
        print(f"saved checkpoint to {args.ckpt}")
    assert history[-1]["ce"] < history[0]["ce"] + 0.5, "training diverged"


class _nullcontext:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False


if __name__ == "__main__":
    main()
