"""Serving launcher: batched greedy decoding demo over the public API.

  PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --smoke \
      --batch 4 --prompt-len 16 --gen 16
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.models import model as lm
from repro.serve import engine


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    cfg = cfg.replace(dtype="float32")
    params = lm.init_model(cfg, jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    if cfg.arch_type == "audio":
        prompt = jnp.array(rng.integers(
            0, cfg.vocab_size,
            (args.batch, cfg.n_codebooks, args.prompt_len)), jnp.int32)
    else:
        prompt = jnp.array(rng.integers(
            0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32)

    t0 = time.time()
    out = engine.greedy_decode(cfg, params, prompt, steps=args.gen)
    dt = time.time() - t0
    n_new = args.gen * args.batch
    print(json.dumps({
        "arch": cfg.name, "batch": args.batch,
        "prompt_len": args.prompt_len, "generated": args.gen,
        "wall_s": round(dt, 2),
        "tok_per_s": round(n_new / dt, 1),
        "output_shape": list(out.shape),
    }))
    assert out.shape[-1] == args.prompt_len + args.gen


if __name__ == "__main__":
    main()
