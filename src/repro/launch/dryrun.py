import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# The two lines above MUST run before any other import (jax locks the
# device count on first init). Everything else follows.

r"""Multi-pod dry-run (deliverable e).

For every (architecture x input shape x mesh) combination, build the
production mesh from 512 host-platform placeholder devices, lower the
appropriate step function with ShapeDtypeStruct inputs (no allocation),
compile it, and record memory_analysis / cost_analysis / per-collective
byte counts for the roofline (benchmarks/roofline.py reads the JSON).

Usage:
  python -m repro.launch.dryrun --arch llama3-8b --shape train_4k \
      --mesh single --out experiments/dryrun
  python -m repro.launch.dryrun --all [--mesh both] [--jobs 2]
"""
import argparse
import json
import re
import subprocess
import sys
import time
from functools import partial
from typing import Any, Dict

import jax

from repro.configs import get_config
from repro.configs.registry import ARCH_IDS
from repro.configs.shapes import SHAPES, InputShape, batch_specs, cache_window
from repro.models import model as lm
from repro.models.common import ModelConfig
from repro.parallel.sharding import (batch_specs_sharding,
                                     decode_state_specs, logical_rules,
                                     param_shardings, rules_for)
from repro.serve import engine
from repro.train.optim import OptimConfig, init_opt_state
from repro.train.train_step import train_step
from .mesh import make_production_mesh

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter",
                  "all-to-all", "collective-permute")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _bytes_of_type_str(s: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(s):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collective_bytes(hlo_text: str) -> Dict[str, Any]:
    """Sum result bytes of every collective op in the HLO text, keyed by
    op kind, plus op counts."""
    out = {op: {"bytes": 0, "count": 0} for op in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        if "=" not in line:
            continue
        lhs, _, rhs = line.partition("=")
        rhs = rhs.strip()
        for op in COLLECTIVE_OPS:
            # match op name at the call position: "<type> opname("
            mm = re.match(r"(.+?)\s(%?" + op + r")[.\d]*\(", rhs)
            if mm and not rhs.startswith("fusion"):
                out[op]["bytes"] += _bytes_of_type_str(mm.group(1))
                out[op]["count"] += 1
                break
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items()
                             if isinstance(v, dict))
    out["total_count"] = sum(v["count"] for k, v in out.items()
                             if isinstance(v, dict))
    return out


def _eval_shapes(fn, *args):
    return jax.eval_shape(fn, *args)


def build_dryrun(cfg: ModelConfig, shape: InputShape, multi_pod: bool):
    """Returns (jitted_fn, arg_specs, in_shardings) ready to lower."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    long_ctx = shape.name == "long_500k"
    rules = rules_for(mesh, shard_cache_seq=long_ctx)
    if long_ctx:
        rules["batch"] = None      # global_batch=1: nothing to shard

    param_shapes = _eval_shapes(
        lambda: lm.init_model(cfg, jax.random.PRNGKey(0)))
    p_shardings = param_shardings(param_shapes, mesh, rules,
                                  n_expert_hint=cfg.n_experts)
    b_specs = batch_specs(cfg, shape)
    b_shardings = batch_specs_sharding(b_specs, mesh, rules)

    if shape.kind == "train":
        opt_cfg = OptimConfig()
        opt_shapes = _eval_shapes(partial(init_opt_state), param_shapes)
        o_shardings = {
            "mu": p_shardings, "nu": jax.tree_util.tree_map(
                lambda s: s, p_shardings),
            "step": jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec())}

        def fn(params, opt_state, batch):
            with logical_rules(rules):
                new_p, new_o, metrics = train_step(cfg, opt_cfg, params,
                                                   opt_state, batch)
            return new_p, new_o, metrics["loss"]

        jitted = jax.jit(fn, in_shardings=(p_shardings, o_shardings,
                                           b_shardings),
                         out_shardings=(p_shardings, o_shardings, None))
        args = (param_shapes, opt_shapes, b_specs)
    elif shape.kind == "prefill":
        def fn(params, batch):
            with logical_rules(rules):
                logits, _ = lm.forward(cfg, params, batch)
            return logits

        jitted = jax.jit(fn, in_shardings=(p_shardings, b_shardings))
        args = (param_shapes, b_specs)
    else:  # decode
        window = cache_window(cfg, shape)
        state_shapes = _eval_shapes(
            lambda: engine.init_state(cfg, shape.global_batch, window))
        s_shardings = decode_state_specs(state_shapes, mesh, rules)

        def fn(params, state, batch):
            with logical_rules(rules):
                logits, new_state = engine.serve_step(cfg, params, state,
                                                      batch)
            return logits, new_state

        jitted = jax.jit(fn, in_shardings=(p_shardings, s_shardings,
                                           b_shardings),
                         out_shardings=(None, s_shardings))
        args = (param_shapes, state_shapes, b_specs)
    return mesh, jitted, args


def probe_depths(cfg: ModelConfig):
    """Two reduced depths for the unrolled cost probes (XLA counts scan
    bodies once, so true per-layer cost comes from the probe slope)."""
    if cfg.arch_type == "ssm":
        return (cfg.slstm_every, 2 * cfg.slstm_every)
    if cfg.arch_type == "hybrid":
        return (cfg.shared_attn_every, 2 * cfg.shared_attn_every)
    if cfg.arch_type == "moe":
        k = cfg.first_k_dense
        return (k + 1, k + 3)
    return (2, 4)


def _cost_dict(compiled):
    """Normalize ``Compiled.cost_analysis()`` across jax versions: older
    releases return a one-element list of dicts, newer ones the dict."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}


def _compile_cost(cfg: ModelConfig, shape: InputShape, multi_pod: bool):
    mesh, jitted, args = build_dryrun(cfg, shape, multi_pod)
    with mesh:
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
        cost = _cost_dict(compiled)
        try:
            hlo = compiled.as_text()
        except Exception:
            hlo = lowered.as_text()
    coll = parse_collective_bytes(hlo)
    return {
        "flops": float(cost.get("flops", 0.0)) if cost else 0.0,
        "bytes": float(cost.get("bytes accessed", 0.0)) if cost else 0.0,
        "collective_bytes": coll["total_bytes"],
        "collectives": coll,
    }


def run_one(arch: str, shape_name: str, mesh_kind: str,
            probe: bool = True) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    multi_pod = mesh_kind == "multi"
    t0 = time.time()
    mesh, jitted, args = build_dryrun(cfg, shape, multi_pod)
    with mesh:
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = _cost_dict(compiled)
        try:
            hlo = compiled.as_text()
        except Exception:
            hlo = lowered.as_text()
    coll = parse_collective_bytes(hlo)
    chips = 512 if multi_pod else 256

    probes = None
    if probe:
        l_small, l_big = probe_depths(cfg)
        c_small = _compile_cost(
            cfg.replace(n_layers=l_small, force_unscanned=True),
            shape, multi_pod)
        c_big = _compile_cost(
            cfg.replace(n_layers=l_big, force_unscanned=True),
            shape, multi_pod)
        span = l_big - l_small
        L = cfg.n_layers

        def extrap(key):
            slope = (c_big[key] - c_small[key]) / span
            return c_small[key] + slope * (L - l_small), slope

        flops_t, flops_slope = extrap("flops")
        bytes_t, bytes_slope = extrap("bytes")
        coll_t, coll_slope = extrap("collective_bytes")
        probes = {
            "depths": [l_small, l_big],
            "small": c_small, "big": c_big,
            "per_layer": {"flops": flops_slope, "bytes": bytes_slope,
                          "collective_bytes": coll_slope},
            "extrapolated": {"flops": flops_t, "bytes": bytes_t,
                             "collective_bytes": coll_t},
        }

    def _mem_field(name):
        try:
            v = getattr(mem, name)
            return int(v) if v is not None else None
        except Exception:
            return None

    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "chips": chips,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "flops": float(cost.get("flops", -1)) if cost else None,
        "bytes_accessed": float(cost.get("bytes accessed", -1))
        if cost else None,
        "memory_analysis": {
            k: _mem_field(k) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "generated_code_size_in_bytes")},
        "collectives": coll,
        "hlo_ops": len(hlo.splitlines()),
        "probes": probes,
    }
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default="")
    ap.add_argument("--shape", type=str, default="",
                    choices=[""] + list(SHAPES))
    ap.add_argument("--mesh", type=str, default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", type=str, default="experiments/dryrun")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-probe", action="store_true",
                    help="skip the unrolled cost probes (multi-pod gate)")
    ap.add_argument("--archs", type=str, default="",
                    help="comma-separated arch filter for --all")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args(argv)
    os.makedirs(args.out, exist_ok=True)

    if not args.all:
        assert args.arch and args.shape and args.mesh != "both"
        res = run_one(args.arch, args.shape, args.mesh,
                      probe=not args.no_probe)
        path = os.path.join(
            args.out, f"{args.arch}__{args.shape}__{args.mesh}.json")
        with open(path, "w") as f:
            json.dump(res, f, indent=1)
        print(json.dumps({k: res[k] for k in
                          ("arch", "shape", "mesh", "compile_s", "flops",
                           "bytes_accessed")}))
        print("collectives:", json.dumps(res["collectives"]))
        return 0

    # --all: one subprocess per combo (isolates XLA state and failures)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    archs = [a for a in ARCH_IDS
             if not args.archs or a in args.archs.split(",")]
    failures = []
    for arch in archs:
        for shape_name in SHAPES:
            for mk in meshes:
                path = os.path.join(
                    args.out, f"{arch}__{shape_name}__{mk}.json")
                if os.path.exists(path) and not args.force:
                    print(f"skip {path}")
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape_name,
                       "--mesh", mk, "--out", args.out]
                if mk == "multi" or args.no_probe:
                    cmd.append("--no-probe")  # roofline is single-pod
                print(">>", arch, shape_name, mk, flush=True)
                t0 = time.time()
                r = subprocess.run(cmd, capture_output=True, text=True)
                dt = time.time() - t0
                if r.returncode != 0:
                    failures.append((arch, shape_name, mk))
                    print(f"FAIL ({dt:.0f}s)\n{r.stdout[-2000:]}"
                          f"\n{r.stderr[-4000:]}", flush=True)
                else:
                    print(f"ok ({dt:.0f}s) {r.stdout.strip()[:300]}",
                          flush=True)
    if failures:
        print("FAILURES:", failures)
        return 1
    print("all dry-runs passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
