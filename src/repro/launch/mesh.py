"""Mesh construction: the production meshes for the dry-run, and
RFold-driven meshes whose device order follows a folded allocation.

NOTE: ``make_production_mesh`` is a function (never a module-level
constant) so importing this module touches no jax device state.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Single pod: 16x16 (256 chips) over ("data", "model"); multi-pod:
    2x16x16 (512 chips) over ("pod", "data", "model")."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Sequence[int], axes: Sequence[str]) -> Mesh:
    return jax.make_mesh(tuple(shape), tuple(axes))


def mesh_from_allocation(coords: Sequence[Tuple[int, int, int]],
                         mesh_shape: Sequence[int],
                         axes: Sequence[str],
                         devices: Optional[List] = None) -> Mesh:
    """Build a Mesh whose device order follows an RFold allocation.

    ``coords`` is the ordered XPU list of a committed placement (ring
    traversal order for folded placements — Allocation.coords). The
    devices assigned to those torus coordinates are laid out in that
    order and reshaped to ``mesh_shape``; collectives along the fastest-
    varying mesh axis then run on torus-neighbour rings, which is
    exactly the property folding preserves.

    On this CPU container, ``devices`` defaults to jax.devices() taken
    in index order as stand-ins for the torus grid; on a real TPU
    deployment the caller maps torus coordinates to device ids via
    ``jax.devices()[i].coords``.
    """
    coords = list(coords)
    n = int(np.prod(list(mesh_shape)))
    if len(coords) != n:
        raise ValueError(f"allocation has {len(coords)} XPUs, mesh "
                         f"needs {n}")
    devs = devices if devices is not None else jax.devices()
    if len(devs) < n:
        raise ValueError(f"only {len(devs)} devices for {n}-XPU mesh")
    # torus coordinate -> device (index order stand-in / coords on TPU)
    by_coord = {}
    have_coords = all(hasattr(d, "coords") and d.coords is not None
                      for d in devs[:1]) and getattr(
                          devs[0], "platform", "") == "tpu"
    if have_coords:
        for d in devs:
            by_coord[tuple(d.coords)[:3]] = d
        chosen = [by_coord[c] for c in coords]
    else:
        chosen = [devs[i] for i in range(n)]
    arr = np.array(chosen, dtype=object).reshape(tuple(mesh_shape))
    return Mesh(arr, tuple(axes))


def allocation_mesh_shape(num_xpus: int,
                          prefer_model: int = 0) -> Tuple[int, int]:
    """Factor an allocation size into a (data, model) mesh shape: the
    model axis gets the largest power-of-two divisor <= prefer_model
    (default: sqrt-ish split)."""
    n = num_xpus
    if prefer_model:
        m = prefer_model
        while n % m:
            m -= 1
        return (n // m, m)
    m = 1
    while (m * 2) * (m * 2) <= n or (n % (m * 2) == 0 and m * 2 * m * 2 <= n):
        if n % (m * 2):
            break
        m *= 2
        if m * m >= n:
            break
    m = max(1, m)
    while n % m:
        m //= 2
    return (n // m, m)
