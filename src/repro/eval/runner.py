"""Two-level evaluation runner: process pool x in-process fleets,
with per-run JSON checkpointing.

A unit of work (:class:`EvalTask`) is one seeded simulator run of one
policy configuration. Tasks are independent, so the runner fans them
out across worker processes; every finished task is checkpointed as one
JSON file, keyed by a fingerprint of the task's full configuration, so
an interrupted sweep resumes from the completed subset instead of
restarting.

Fleet mode is the default: each worker runs its slice of the matrix
as a continuously-batched *fleet* (``repro.sim.fleet``) — the
simulators' fitmask/free-counts queries coalesce through a shared
query broker into genuinely batched engine calls (grids stacked on
the multibox ``B`` axis), with rounds flushed on quorum or deadline
so a fleet never stalls on its slowest member. Chunks group tasks
whose grids share a cell shape so the broker actually gets to stack
them. Records and checkpoints are byte-identical to the per-task path
(the broker is bit-exact; the per-task path is retained below as the
parity oracle, selected with ``fleet_size=0``).

Checkpoint layout: files are bucketed into fingerprint-prefix
subdirectories (``<dir>/<fp[:2]>/<name>.json``, 256 shards) so
10k+-task parameter scans never put every file in one flat directory.
Resume stays backward-compatible with flat stores: lookups fall back
to the un-sharded path, so a pre-shard checkpoint dir keeps resuming
(new writes land sharded).

Determinism contract: the per-run seed depends only on ``(seed0,
run_idx)`` — never on the worker count, the executor schedule, or which
checkpoints already exist — so pool runs, serial runs and resumed runs
all produce identical records.
"""
from __future__ import annotations

import glob
import hashlib
import json
import os
import re
import time
import zlib
from concurrent.futures import FIRST_COMPLETED, ProcessPoolExecutor, wait
from dataclasses import asdict, dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple


def derive_seed(seed0: int, run_idx: int) -> int:
    """Seed for run ``run_idx`` of a sweep rooted at ``seed0``.

    A pure function of ``(seed0, run_idx)``: stable across worker
    counts and completion order, and shared by every policy in the
    matrix so policies are compared on *paired* traces (the paper
    averages each policy over the same 100 traces). Kept as the
    affine form the pre-subsystem sequential harness used, so
    historical CI-sized numbers remain reproducible.
    """
    return seed0 + run_idx


@dataclass
class EvalTask:
    """One seeded simulator run of one policy configuration."""

    label: str                 # display label, e.g. "RFold (4^3)"
    policy: str                # repro.core.allocator.make_policy name
    policy_kw: Dict = field(default_factory=dict)
    run_idx: int = 0
    seed: int = 0
    num_jobs: int = 200
    load: float = 1.5
    trace_kw: Dict = field(default_factory=dict)   # extra TraceConfig fields
    sim_kw: Dict = field(default_factory=dict)     # extra Simulator kwargs
    # Named chaos scenario (repro.sim.scenarios) to run this task
    # under: its trace/fault/sim overrides are applied worker-side and
    # the record gains the chaos degradation block. None = healthy.
    scenario: Optional[str] = None

    def fingerprint(self) -> str:
        """Hash of every field that affects the run's outcome. The
        display label is deliberately excluded: renaming a config, or
        evaluating one config under two labels (the ablation arms do),
        must neither invalidate nor duplicate checkpoints. A None
        scenario is dropped before hashing so every pre-scenario
        checkpoint store keeps resuming."""
        fields = asdict(self)
        fields.pop("label")
        if fields.get("scenario") is None:
            fields.pop("scenario", None)
        blob = json.dumps(fields, sort_keys=True, default=str)
        return hashlib.sha256(blob.encode()).hexdigest()[:16]

    def checkpoint_name(self) -> str:
        slug = re.sub(r"[^A-Za-z0-9]+", "_", self.label).strip("_").lower()
        return f"{slug}__r{self.run_idx}__{self.fingerprint()}.json"


SHARD_CHARS = 2   # 16^2 = 256 buckets; plenty below any fs dir limit

# <slug>__r<idx>__<16-hex-fingerprint>.json — what checkpoint_name()
# emits; prune only ever deletes files matching this.
CKPT_NAME_RE = re.compile(r"__r\d+__([0-9a-f]{16})\.json$")


def shard_dir(checkpoint_dir: str, fingerprint: str) -> str:
    """Fingerprint-prefix bucket for one checkpoint."""
    return os.path.join(checkpoint_dir, fingerprint[:SHARD_CHARS])


def iter_checkpoints(checkpoint_dir: str):
    """All checkpoint JSON paths in a store, sharded or legacy-flat."""
    for root, _dirs, files in os.walk(checkpoint_dir):
        for name in files:
            if name.endswith(".json"):
                yield os.path.join(root, name)


def record_crc(rec: Dict) -> int:
    """Content CRC of a checkpoint record (over canonical JSON, the
    ``_crc32`` field itself excluded) — file formatting and key order
    don't matter, payload bytes do."""
    body = {k: v for k, v in rec.items() if k != "_crc32"}
    return zlib.crc32(json.dumps(body, sort_keys=True,
                                 default=str).encode())


def verify_record(rec: Dict) -> bool:
    """True when the record's self-CRC matches (or when it predates
    CRC framing — legacy checkpoints keep loading)."""
    crc = rec.get("_crc32")
    if crc is None:
        return True
    try:
        return int(crc) == record_crc(rec)
    except (TypeError, ValueError):
        return False


def save_checkpoint(checkpoint_dir: str, task: "EvalTask",
                    rec: Dict) -> None:
    """Atomically + durably write one task's record into the (sharded)
    store: the record carries a self-CRC (loaders reject bit-rot
    instead of trusting it), the tmp file is fsynced before the rename
    (a crash can't publish a half-written file under the final name),
    and the rename is atomic (a checkpoint is whole or absent)."""
    path = os.path.join(shard_dir(checkpoint_dir, task.fingerprint()),
                        task.checkpoint_name())
    os.makedirs(os.path.dirname(path), exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump({**rec, "_crc32": record_crc(rec)}, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def prune_checkpoints(checkpoint_dir: str, tasks: Sequence["EvalTask"],
                      max_bytes: Optional[int] = None) -> Dict:
    """Compact a checkpoint store so the actions/cache entry backing
    the scheduled full sweep stops growing unboundedly: drop every
    checkpoint whose fingerprint is absent from the current task set
    (stale configs, old seeds, bumped job counts), then optionally cap
    the survivors' total size, evicting oldest-mtime first. Works on
    sharded and legacy-flat stores alike (fingerprints are parsed
    from the file name, which both layouts share); files that don't
    look like checkpoints are never touched, and emptied shard
    directories are removed."""
    keep = {t.fingerprint() for t in tasks}
    stats = {"scanned": 0, "removed": 0, "kept": 0, "bytes_freed": 0}
    survivors = []
    for path in list(iter_checkpoints(checkpoint_dir)):
        m = CKPT_NAME_RE.search(os.path.basename(path))
        if m is None:
            continue   # not ours — leave foreign files alone
        stats["scanned"] += 1
        if m.group(1) in keep:
            survivors.append(path)
        else:
            stats["bytes_freed"] += os.path.getsize(path)
            os.remove(path)
            stats["removed"] += 1
    if max_bytes is not None:
        survivors.sort(key=os.path.getmtime, reverse=True)  # newest first
        total = 0
        evicting = False
        for path in survivors:
            size = os.path.getsize(path)
            # Strictly oldest-first: once the cumulative (newest-first)
            # budget is exceeded, everything older goes too — never
            # keep an older file in place of an evicted newer one.
            evicting = evicting or total + size > max_bytes
            if evicting:
                os.remove(path)
                stats["removed"] += 1
                stats["bytes_freed"] += size
            else:
                total += size
    stats["kept"] = stats["scanned"] - stats["removed"]
    for name in os.listdir(checkpoint_dir):
        sub = os.path.join(checkpoint_dir, name)
        if os.path.isdir(sub) and not os.listdir(sub):
            os.rmdir(sub)
    return stats


def make_tasks(configs: Sequence[Tuple[str, str, dict]], runs: int,
               num_jobs: int, load: float, seed0: int,
               trace_kw: Optional[dict] = None,
               sim_kw: Optional[dict] = None,
               scenario: Optional[str] = None) -> List[EvalTask]:
    """Expand ``(label, policy, policy_kw)`` configs into the run
    matrix, with paired per-run seeds across configs. ``scenario``
    runs every cell under a named chaos scenario (degraded-fabric
    paper eval); ``None`` is the healthy paper baseline."""
    return [
        EvalTask(label=label, policy=policy, policy_kw=dict(kw),
                 run_idx=r, seed=derive_seed(seed0, r),
                 num_jobs=num_jobs, load=load,
                 trace_kw=dict(trace_kw or {}), sim_kw=dict(sim_kw or {}),
                 scenario=scenario)
        for label, policy, kw in configs for r in range(runs)
    ]


def run_task(task: EvalTask, mask_client=None) -> Dict:
    """Execute one task (worker-side) and return its record.

    ``mask_client`` routes the policy's fitmask/free-counts queries
    through a request/response client (the fleet path installs its
    query broker here); ``None`` keeps the inline engine path. Either
    way the record is byte-identical apart from ``sim_s``.

    Imports are local so that pool workers forked before the simulator
    stack is loaded stay cheap, and so this module stays importable in
    minimal tooling contexts (e.g. CI lint steps).
    """
    from repro.core.allocator import make_policy
    from repro.sim.metrics import summarize, utilization_cdf
    from repro.sim.simulator import Simulator
    from repro.traces.generator import TraceConfig, generate_trace

    sc = None
    if task.scenario is not None:
        from repro.sim.scenarios import SCENARIOS
        sc = SCENARIOS[task.scenario]
    cfg = TraceConfig(num_jobs=task.num_jobs, seed=task.seed,
                      target_load=task.load,
                      **{**task.trace_kw, **(sc.trace_kw if sc else {})})
    jobs = generate_trace(cfg)
    # Constructor injection: the client rides in with the policy
    # rather than being bolted on post-construction (the deprecated
    # install_mask_client dance).
    policy = make_policy(task.policy, mask_client=mask_client,
                         **task.policy_kw)
    sim_kw = dict(task.sim_kw)
    observer = None
    if sc is not None:
        # Scenario cells inject the same deterministic fault stream
        # run_scenario would (seed derivation shared), and watch it
        # with a chaos observer for the degradation block.
        from repro.sim.faults import ChaosObserver
        from repro.sim.scenarios import fault_schedule
        model = getattr(policy, "cluster", None)
        if model is None:
            model = policy.torus
        observer = ChaosObserver()
        sim_kw.update(sc.sim_kw)
        sim_kw["faults"] = fault_schedule(sc, model, jobs, task.seed)
        sim_kw["observer"] = observer
    t0 = time.perf_counter()
    res = Simulator(policy, jobs, **sim_kw).run()
    wall = time.perf_counter() - t0
    levels, cdf = utilization_cdf(res)
    rec = {
        "fingerprint": task.fingerprint(),
        "label": task.label,
        "run_idx": task.run_idx,
        "seed": task.seed,
        "summary": summarize(res),
        "cdf_levels": [float(x) for x in levels],
        "cdf": [float(x) for x in cdf],
        "sim_s": round(wall, 4),
    }
    if sc is not None:
        rec["scenario"] = sc.name
        rec["chaos"] = res.chaos
    return rec


# -- fleet path --------------------------------------------------------

def task_grid_bucket(task: EvalTask) -> Tuple:
    """Cell shape of the occupancy grids this task's mask queries
    carry. The query broker can only stack same-shape grids on the
    multibox B axis, so fleet chunks group tasks by this key
    (mirrors the ``make_policy`` defaults)."""
    kw = task.policy_kw
    if task.policy in ("firstfit", "folding"):
        return ("static", tuple(int(d) for d in kw.get("dims",
                                                       (16, 16, 16))))
    return ("cube", int(kw.get("cube_n", 4)))


def make_fleet_chunks(tasks: Sequence[EvalTask], pending: Sequence[int],
                      fleet_size: int) -> List[List[int]]:
    """Group pending task indices into fleets of at most
    ``fleet_size``, never mixing grid buckets within one fleet (a
    mixed fleet is *correct* — the broker buckets again at flush time
    — it just coalesces worse). Stable within a bucket, so the
    configs x runs task order keeps same-config runs together."""
    by_bucket: Dict[Tuple, List[int]] = {}
    for i in pending:
        by_bucket.setdefault(task_grid_bucket(tasks[i]), []).append(i)
    chunks = []
    for _, idxs in sorted(by_bucket.items()):
        chunks.extend(idxs[o:o + fleet_size]
                      for o in range(0, len(idxs), fleet_size))
    return chunks


def run_fleet_tasks(tasks: Sequence[EvalTask],
                    checkpoint_dir: Optional[str] = None,
                    engine=None, quorum="auto",
                    timeout="auto") -> Tuple[List[Dict], Dict]:
    """Worker-side: run a chunk of tasks as one continuously-batched
    fleet sharing a query broker (``repro.sim.fleet``). Each simulator
    checkpoints itself the moment it finishes, so per-run resume
    granularity survives a worker dying mid-fleet. Returns the
    records (task order) and the broker's coalescing stats.

    ``engine`` selects the broker's engine (registry name or
    instance); the default follows the registry's selection order,
    matching what the per-task path would have resolved. Note the
    broker is the fleet's single engine: a per-task
    ``fitmask_engine`` in ``policy_kw`` is overridden on this path
    (answers are bit-identical across engines, so records don't
    change — only where the masks get computed).

    ``quorum``/``timeout`` tune the broker's flush policy
    (``"auto"``: half-fleet quorum, engine-aware deadline; see
    :class:`repro.sim.fleet.Fleet`) — schedules are invariant to them
    by the broker's parity contract, only wall-time moves.
    """
    from repro.sim.fleet import Fleet

    fleet = Fleet(engine, quorum=quorum, timeout=timeout)

    def unit(task: EvalTask):
        def go(broker):
            rec = run_task(task, mask_client=broker)
            if checkpoint_dir:
                save_checkpoint(checkpoint_dir, task, rec)
            return rec
        return go

    records = fleet.run([unit(t) for t in tasks])
    return records, fleet.broker.stats.as_dict()


class EvalRunner:
    """Fan tasks across a process pool, checkpointing each result.

    ``workers=None`` uses ``os.cpu_count()``; ``workers <= 1`` runs
    inline (no pool) — useful for tests and debugging. With
    ``checkpoint_dir`` set, completed tasks are skipped on re-run when
    their stored fingerprint matches the requested configuration;
    mismatching or unreadable checkpoints are ignored and re-executed.

    ``fleet_size`` controls the second pool level: pending tasks are
    chunked into in-process fleets of at most that many simulators
    (the default ``"auto"`` sizes chunks from the pending count and
    worker width, keeping several chunks per worker for load
    balance), and each chunk's mask queries ride one shared query
    broker as continuously-batched engine calls — on *every* engine,
    the host numpy path included (its multibox is genuinely (B, K)
    vectorized, see BENCH_fleet.json). ``None``/``0``/``1`` selects
    the per-task oracle path — records are byte-identical either way.
    ``fleet_engine`` picks the brokers' engine (default: the
    registry's selection order); ``fleet_quorum``/``fleet_timeout``
    tune the brokers' flush policy (``"auto"``: half-fleet quorum,
    engine-aware deadline).
    """

    def __init__(self, checkpoint_dir: Optional[str] = None,
                 workers: Optional[int] = None, emit=None,
                 fleet_size="auto", fleet_engine: Optional[str] = None,
                 fleet_quorum="auto", fleet_timeout="auto",
                 engine=None):
        self.checkpoint_dir = checkpoint_dir
        self.workers = os.cpu_count() if workers is None else workers
        self.emit = emit or (lambda *a: None)
        # ``engine`` is the typed spelling (repro.core.engineconfig.
        # EngineConfig): one value for backend + fleet drive. The four
        # scattered fleet_* kwargs are retained as legacy aliases; an
        # explicit EngineConfig wins over all of them.
        if engine is not None:
            from repro.core.engineconfig import EngineConfig
            cfg = EngineConfig.coerce(engine)
            fleet_size = cfg.fleet_size
            fleet_engine = cfg.engine
            fleet_quorum = cfg.quorum
            fleet_timeout = cfg.timeout
        self.fleet_size = fleet_size
        self.fleet_engine = fleet_engine
        self.fleet_quorum = fleet_quorum
        self.fleet_timeout = fleet_timeout
        self.last_stats: Dict = {}

    # -- checkpoint store ---------------------------------------------
    def _ckpt_path(self, task: EvalTask) -> Optional[str]:
        if not self.checkpoint_dir:
            return None
        return os.path.join(shard_dir(self.checkpoint_dir,
                                      task.fingerprint()),
                            task.checkpoint_name())

    def _load_checkpoint(self, task: EvalTask) -> Optional[Dict]:
        if not self.checkpoint_dir:
            return None
        fp = task.fingerprint()
        shard = shard_dir(self.checkpoint_dir, fp)
        # Sharded location first, then the legacy flat layout (stores
        # written before sharding keep resuming).
        path = next((p for p in (
            os.path.join(shard, task.checkpoint_name()),
            os.path.join(self.checkpoint_dir, task.checkpoint_name()))
            if os.path.exists(p)), None)
        if path is None:
            # Same config may have been checkpointed under another
            # label (fingerprints are label-independent).
            pattern = f"*__r{task.run_idx}__{fp}.json"
            hits = (glob.glob(os.path.join(shard, pattern))
                    or glob.glob(os.path.join(self.checkpoint_dir,
                                              pattern)))
            path = hits[0] if hits else None
            if path is None:
                return None
        try:
            with open(path) as f:
                rec = json.load(f)
        except (OSError, ValueError):
            return None
        if not verify_record(rec):
            return None   # bit-rot: ignored and re-executed
        rec.pop("_crc32", None)
        if rec.get("fingerprint") != task.fingerprint():
            return None
        rec["label"] = task.label   # restamp: label is display-only
        return rec

    def _save_checkpoint(self, task: EvalTask, rec: Dict) -> None:
        if self.checkpoint_dir:
            save_checkpoint(self.checkpoint_dir, task, rec)

    # -- execution -----------------------------------------------------
    def _resolve_fleet_size(self, n_pending: int) -> Optional[int]:
        fs = self.fleet_size
        if fs in (None, 0, 1):
            return None
        if fs == "auto":
            # Fleet mode is unconditional: with the broker's
            # continuous flush scheduling and the genuinely batched
            # numpy multibox, the fleet path beats per-task on every
            # engine, host numpy included (the parity section of
            # BENCH_fleet.json tracks the margin). Several chunks per
            # worker (rebalancing headroom for the wildly different
            # per-policy sim costs), batching benefit saturating
            # around 8 simulators per broker round.
            workers = max(1, self.workers or 1)
            return max(2, min(8, -(-n_pending // (4 * workers))))
        return int(fs)

    def run(self, tasks: Sequence[EvalTask]) -> List[Dict]:
        """Run the matrix; returns records ordered like ``tasks``."""
        t0 = time.perf_counter()
        records: List[Optional[Dict]] = [None] * len(tasks)
        pending: List[int] = []
        for i, task in enumerate(tasks):
            rec = self._load_checkpoint(task)
            if rec is not None:
                records[i] = rec
            else:
                pending.append(i)
        reused = len(tasks) - len(pending)
        if reused:
            self.emit(f"# resume: {reused}/{len(tasks)} tasks "
                      "from checkpoints")

        fleet_size = self._resolve_fleet_size(len(pending))
        if pending and fleet_size:
            self._run_fleets(tasks, pending, records, fleet_size)
        elif pending:
            if self.workers and self.workers > 1:
                self._run_pool(tasks, pending, records)
            else:
                for i in pending:
                    records[i] = run_task(tasks[i])
                    self._save_checkpoint(tasks[i], records[i])

        self.last_stats = {
            "tasks": len(tasks),
            "reused_from_checkpoint": reused,
            "executed": len(pending),
            "workers": self.workers,
            "wall_s": round(time.perf_counter() - t0, 3),
            "sim_s_total": round(sum(r["sim_s"] for r in records
                                     if r is not None), 3),
        }
        if pending and fleet_size:
            self.last_stats["fleet"] = self._fleet_stats
        return [r for r in records if r is not None]

    def _run_fleets(self, tasks: Sequence[EvalTask], pending: List[int],
                    records: List[Optional[Dict]],
                    fleet_size: int) -> None:
        """Two-level pool: fan task chunks across worker processes,
        each chunk running as one cooperatively-batched fleet.
        Checkpoints are written worker-side as each simulator
        finishes, so resume granularity stays per-run."""
        chunks = make_fleet_chunks(tasks, pending, fleet_size)
        broker_totals: List[Dict] = []

        def account(chunk: List[int], result) -> None:
            recs, stats = result
            for i, rec in zip(chunk, recs):
                records[i] = rec
            broker_totals.append(stats)
            self.emit(f"# eval fleet {len(broker_totals)}/{len(chunks)}: "
                      f"{len(chunk)} sims "
                      f"({sum(r['sim_s'] for r in recs):.1f}s sim, "
                      f"B~{stats['mean_grids_per_call']})")

        if self.workers and self.workers > 1 and len(chunks) > 1:
            import multiprocessing as mp
            ctx = (mp.get_context("fork")
                   if "fork" in mp.get_all_start_methods() else None)
            with ProcessPoolExecutor(max_workers=self.workers,
                                     mp_context=ctx) as pool:
                futs = {pool.submit(run_fleet_tasks,
                                    [tasks[i] for i in chunk],
                                    self.checkpoint_dir,
                                    self.fleet_engine,
                                    self.fleet_quorum,
                                    self.fleet_timeout): chunk
                        for chunk in chunks}
                remaining = set(futs)
                while remaining:
                    finished, remaining = wait(remaining,
                                               return_when=FIRST_COMPLETED)
                    for fut in finished:
                        account(futs[fut], fut.result())
        else:
            for chunk in chunks:
                account(chunk, run_fleet_tasks(
                    [tasks[i] for i in chunk], self.checkpoint_dir,
                    self.fleet_engine, self.fleet_quorum,
                    self.fleet_timeout))

        count_keys = ("requests", "flushes", "engine_calls",
                      "batched_calls", "grids", "flush_all_parked",
                      "flush_quorum", "flush_timeout", "requeued",
                      "padded_grids", "k_slots", "k_needed",
                      "fc_inline", "fc_cache_hits", "fc_cache_misses",
                      "steppers_reaped", "engine_retries",
                      "engine_failovers", "canary_checks",
                      "canary_mismatches")
        agg = {k: sum(s.get(k, 0) for s in broker_totals)
               for k in count_keys}
        agg["max_grids"] = max((s["max_grids"] for s in broker_totals),
                               default=0)
        agg["max_coalesced"] = max((s["max_coalesced"]
                                    for s in broker_totals), default=0)
        agg["mean_grids_per_call"] = (
            round(agg["grids"] / agg["engine_calls"], 2)
            if agg["engine_calls"] else None)
        total_b = agg["grids"] + agg["padded_grids"]
        agg["b_pad_waste"] = (round(agg["padded_grids"] / total_b, 4)
                              if total_b else 0.0)
        agg["k_pad_waste"] = (round(1.0 - agg["k_needed"] / agg["k_slots"],
                                    4) if agg["k_slots"] else 0.0)
        self._fleet_stats = {"size": fleet_size, "fleets": len(chunks),
                             "broker": agg}

    def _run_pool(self, tasks: Sequence[EvalTask], pending: List[int],
                  records: List[Optional[Dict]]) -> None:
        import multiprocessing as mp

        # fork (Linux default) inherits sys.path, so workers resolve the
        # repro package regardless of how the parent set PYTHONPATH.
        ctx = (mp.get_context("fork")
               if "fork" in mp.get_all_start_methods() else None)
        done = 0
        with ProcessPoolExecutor(max_workers=self.workers,
                                 mp_context=ctx) as pool:
            futs = {pool.submit(run_task, tasks[i]): i for i in pending}
            remaining = set(futs)
            while remaining:
                finished, remaining = wait(remaining,
                                           return_when=FIRST_COMPLETED)
                for fut in finished:
                    i = futs[fut]
                    records[i] = fut.result()
                    self._save_checkpoint(tasks[i], records[i])
                    done += 1
                    self.emit(f"# eval {done}/{len(pending)}: "
                              f"{tasks[i].label} run {tasks[i].run_idx} "
                              f"({records[i]['sim_s']:.1f}s)")
