"""Process-pool evaluation runner with per-run JSON checkpointing.

A unit of work (:class:`EvalTask`) is one seeded simulator run of one
policy configuration. Tasks are independent, so the runner fans them
out across worker processes; every finished task is checkpointed as one
JSON file, keyed by a fingerprint of the task's full configuration, so
an interrupted sweep resumes from the completed subset instead of
restarting.

Checkpoint layout: files are bucketed into fingerprint-prefix
subdirectories (``<dir>/<fp[:2]>/<name>.json``, 256 shards) so
10k+-task parameter scans never put every file in one flat directory.
Resume stays backward-compatible with flat stores: lookups fall back
to the un-sharded path, so a pre-shard checkpoint dir keeps resuming
(new writes land sharded).

Determinism contract: the per-run seed depends only on ``(seed0,
run_idx)`` — never on the worker count, the executor schedule, or which
checkpoints already exist — so pool runs, serial runs and resumed runs
all produce identical records.
"""
from __future__ import annotations

import glob
import hashlib
import json
import os
import re
import time
from concurrent.futures import FIRST_COMPLETED, ProcessPoolExecutor, wait
from dataclasses import asdict, dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple


def derive_seed(seed0: int, run_idx: int) -> int:
    """Seed for run ``run_idx`` of a sweep rooted at ``seed0``.

    A pure function of ``(seed0, run_idx)``: stable across worker
    counts and completion order, and shared by every policy in the
    matrix so policies are compared on *paired* traces (the paper
    averages each policy over the same 100 traces). Kept as the
    affine form the pre-subsystem sequential harness used, so
    historical CI-sized numbers remain reproducible.
    """
    return seed0 + run_idx


@dataclass
class EvalTask:
    """One seeded simulator run of one policy configuration."""

    label: str                 # display label, e.g. "RFold (4^3)"
    policy: str                # repro.core.allocator.make_policy name
    policy_kw: Dict = field(default_factory=dict)
    run_idx: int = 0
    seed: int = 0
    num_jobs: int = 200
    load: float = 1.5
    trace_kw: Dict = field(default_factory=dict)   # extra TraceConfig fields
    sim_kw: Dict = field(default_factory=dict)     # extra Simulator kwargs

    def fingerprint(self) -> str:
        """Hash of every field that affects the run's outcome. The
        display label is deliberately excluded: renaming a config, or
        evaluating one config under two labels (the ablation arms do),
        must neither invalidate nor duplicate checkpoints."""
        fields = asdict(self)
        fields.pop("label")
        blob = json.dumps(fields, sort_keys=True, default=str)
        return hashlib.sha256(blob.encode()).hexdigest()[:16]

    def checkpoint_name(self) -> str:
        slug = re.sub(r"[^A-Za-z0-9]+", "_", self.label).strip("_").lower()
        return f"{slug}__r{self.run_idx}__{self.fingerprint()}.json"


SHARD_CHARS = 2   # 16^2 = 256 buckets; plenty below any fs dir limit


def shard_dir(checkpoint_dir: str, fingerprint: str) -> str:
    """Fingerprint-prefix bucket for one checkpoint."""
    return os.path.join(checkpoint_dir, fingerprint[:SHARD_CHARS])


def iter_checkpoints(checkpoint_dir: str):
    """All checkpoint JSON paths in a store, sharded or legacy-flat."""
    for root, _dirs, files in os.walk(checkpoint_dir):
        for name in files:
            if name.endswith(".json"):
                yield os.path.join(root, name)


def make_tasks(configs: Sequence[Tuple[str, str, dict]], runs: int,
               num_jobs: int, load: float, seed0: int,
               trace_kw: Optional[dict] = None,
               sim_kw: Optional[dict] = None) -> List[EvalTask]:
    """Expand ``(label, policy, policy_kw)`` configs into the run
    matrix, with paired per-run seeds across configs."""
    return [
        EvalTask(label=label, policy=policy, policy_kw=dict(kw),
                 run_idx=r, seed=derive_seed(seed0, r),
                 num_jobs=num_jobs, load=load,
                 trace_kw=dict(trace_kw or {}), sim_kw=dict(sim_kw or {}))
        for label, policy, kw in configs for r in range(runs)
    ]


def run_task(task: EvalTask) -> Dict:
    """Execute one task (worker-side) and return its record.

    Imports are local so that pool workers forked before the simulator
    stack is loaded stay cheap, and so this module stays importable in
    minimal tooling contexts (e.g. CI lint steps).
    """
    from repro.core.allocator import make_policy
    from repro.sim.metrics import summarize, utilization_cdf
    from repro.sim.simulator import Simulator
    from repro.traces.generator import TraceConfig, generate_trace

    cfg = TraceConfig(num_jobs=task.num_jobs, seed=task.seed,
                      target_load=task.load, **task.trace_kw)
    jobs = generate_trace(cfg)
    policy = make_policy(task.policy, **task.policy_kw)
    t0 = time.perf_counter()
    res = Simulator(policy, jobs, **task.sim_kw).run()
    wall = time.perf_counter() - t0
    levels, cdf = utilization_cdf(res)
    return {
        "fingerprint": task.fingerprint(),
        "label": task.label,
        "run_idx": task.run_idx,
        "seed": task.seed,
        "summary": summarize(res),
        "cdf_levels": [float(x) for x in levels],
        "cdf": [float(x) for x in cdf],
        "sim_s": round(wall, 4),
    }


class EvalRunner:
    """Fan tasks across a process pool, checkpointing each result.

    ``workers=None`` uses ``os.cpu_count()``; ``workers <= 1`` runs
    inline (no pool) — useful for tests and debugging. With
    ``checkpoint_dir`` set, completed tasks are skipped on re-run when
    their stored fingerprint matches the requested configuration;
    mismatching or unreadable checkpoints are ignored and re-executed.
    """

    def __init__(self, checkpoint_dir: Optional[str] = None,
                 workers: Optional[int] = None, emit=None):
        self.checkpoint_dir = checkpoint_dir
        self.workers = os.cpu_count() if workers is None else workers
        self.emit = emit or (lambda *a: None)
        self.last_stats: Dict = {}

    # -- checkpoint store ---------------------------------------------
    def _ckpt_path(self, task: EvalTask) -> Optional[str]:
        if not self.checkpoint_dir:
            return None
        return os.path.join(shard_dir(self.checkpoint_dir,
                                      task.fingerprint()),
                            task.checkpoint_name())

    def _load_checkpoint(self, task: EvalTask) -> Optional[Dict]:
        if not self.checkpoint_dir:
            return None
        fp = task.fingerprint()
        shard = shard_dir(self.checkpoint_dir, fp)
        # Sharded location first, then the legacy flat layout (stores
        # written before sharding keep resuming).
        path = next((p for p in (
            os.path.join(shard, task.checkpoint_name()),
            os.path.join(self.checkpoint_dir, task.checkpoint_name()))
            if os.path.exists(p)), None)
        if path is None:
            # Same config may have been checkpointed under another
            # label (fingerprints are label-independent).
            pattern = f"*__r{task.run_idx}__{fp}.json"
            hits = (glob.glob(os.path.join(shard, pattern))
                    or glob.glob(os.path.join(self.checkpoint_dir,
                                              pattern)))
            path = hits[0] if hits else None
            if path is None:
                return None
        try:
            with open(path) as f:
                rec = json.load(f)
        except (OSError, ValueError):
            return None
        if rec.get("fingerprint") != task.fingerprint():
            return None
        rec["label"] = task.label   # restamp: label is display-only
        return rec

    def _save_checkpoint(self, task: EvalTask, rec: Dict) -> None:
        path = self._ckpt_path(task)
        if not path:
            return
        os.makedirs(os.path.dirname(path), exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(rec, f)
        os.replace(tmp, path)   # atomic: a checkpoint is whole or absent

    # -- execution -----------------------------------------------------
    def run(self, tasks: Sequence[EvalTask]) -> List[Dict]:
        """Run the matrix; returns records ordered like ``tasks``."""
        t0 = time.perf_counter()
        records: List[Optional[Dict]] = [None] * len(tasks)
        pending: List[int] = []
        for i, task in enumerate(tasks):
            rec = self._load_checkpoint(task)
            if rec is not None:
                records[i] = rec
            else:
                pending.append(i)
        reused = len(tasks) - len(pending)
        if reused:
            self.emit(f"# resume: {reused}/{len(tasks)} tasks "
                      "from checkpoints")

        if pending:
            if self.workers and self.workers > 1:
                self._run_pool(tasks, pending, records)
            else:
                for i in pending:
                    records[i] = run_task(tasks[i])
                    self._save_checkpoint(tasks[i], records[i])

        self.last_stats = {
            "tasks": len(tasks),
            "reused_from_checkpoint": reused,
            "executed": len(pending),
            "workers": self.workers,
            "wall_s": round(time.perf_counter() - t0, 3),
            "sim_s_total": round(sum(r["sim_s"] for r in records
                                     if r is not None), 3),
        }
        return [r for r in records if r is not None]

    def _run_pool(self, tasks: Sequence[EvalTask], pending: List[int],
                  records: List[Optional[Dict]]) -> None:
        import multiprocessing as mp

        # fork (Linux default) inherits sys.path, so workers resolve the
        # repro package regardless of how the parent set PYTHONPATH.
        ctx = (mp.get_context("fork")
               if "fork" in mp.get_all_start_methods() else None)
        done = 0
        with ProcessPoolExecutor(max_workers=self.workers,
                                 mp_context=ctx) as pool:
            futs = {pool.submit(run_task, tasks[i]): i for i in pending}
            remaining = set(futs)
            while remaining:
                finished, remaining = wait(remaining,
                                           return_when=FIRST_COMPLETED)
                for fut in finished:
                    i = futs[fut]
                    records[i] = fut.result()
                    self._save_checkpoint(tasks[i], records[i])
                    done += 1
                    self.emit(f"# eval {done}/{len(pending)}: "
                              f"{tasks[i].label} run {tasks[i].run_idx} "
                              f"({records[i]['sim_s']:.1f}s)")
