"""Parallel paper-scale evaluation subsystem.

The paper's headline numbers (Table 1 JCR, Fig 3 JCT percentiles,
Fig 4 utilization CDF) average 100 independent seeded simulator runs
per policy configuration — an embarrassingly parallel run x policy
matrix. This package fans that matrix out across a process pool with
per-run JSON checkpointing (an interrupted sweep resumes instead of
restarting) and aggregates the per-run records into the paper's
tables/figures with deltas against the paper-reported values.

Layout:
  runner.py     EvalTask, deterministic seed derivation, the process-
                pool runner and the checkpoint store.
  aggregate.py  per-label aggregation + Table 1 / Fig 3 / Fig 4
                builders with the paper-reported reference numbers.
"""
from .aggregate import (PAPER_FIG3_RATIOS, PAPER_FIG4_DELTAS,  # noqa: F401
                        PAPER_TABLE1, aggregate_by_label, fig3, fig4, table1)
from .runner import (EvalRunner, EvalTask, derive_seed,  # noqa: F401
                     make_tasks, prune_checkpoints, run_fleet_tasks,
                     run_task)

__all__ = [
    "EvalRunner", "EvalTask", "derive_seed", "make_tasks", "run_task",
    "run_fleet_tasks", "prune_checkpoints",
    "aggregate_by_label", "table1", "fig3", "fig4",
    "PAPER_TABLE1", "PAPER_FIG3_RATIOS", "PAPER_FIG4_DELTAS",
]
