"""Aggregate per-run eval records into the paper's tables/figures.

Input: the per-run record dicts produced by ``runner.run_task``
(summary metrics + utilization CDF per seeded run). Output: Table 1
(JCR), Fig 3 (JCT percentiles + Reconfig/RFold ratios) and Fig 4
(utilization CDF + headline deltas), each annotated with the
paper-reported reference values so reproduction drift is visible in
one place.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.sim.metrics import aggregate

# Paper-reported Avg JCR % (Table 1).
PAPER_TABLE1 = {
    "FirstFit (16^3)": 10.4,
    "Folding (16^3)": 44.11,
    "Reconfig (8^3)": 31.46,
    "RFold (8^3)": 73.35,
    "Reconfig (4^3)": 100.0,
    "RFold (4^3)": 100.0,
}

# Paper-reported Reconfig/RFold JCT ratios (Fig 3); 2^3 is reported
# only as "at most ~1.3x", kept as an upper bound.
PAPER_FIG3_RATIOS = {
    "4^3": {"p50": 11.0, "p90": 6.0, "p99": 2.0},
    "2^3": {"p50": 1.3, "p90": 1.3, "p99": 1.3},
}

# Paper-reported absolute utilization gains (Fig 4), percentage points.
PAPER_FIG4_DELTAS = {
    "RFold (4^3) - FirstFit (16^3)": 57.0,
    "RFold (4^3) - Reconfig (4^3)": 20.0,
}


def aggregate_by_label(records: Sequence[Dict]) -> Dict[str, Dict]:
    """Group per-run records by label; average summaries and CDFs.

    Returns ``{label: {"agg": metric means, "cdf_levels": [...],
    "cdf": [...], "runs": n, "sim_s_total": s}}``.
    """
    by_label: Dict[str, List[Dict]] = {}
    for rec in records:
        by_label.setdefault(rec["label"], []).append(rec)
    out: Dict[str, Dict] = {}
    for label, recs in by_label.items():
        recs = sorted(recs, key=lambda r: r["run_idx"])
        out[label] = {
            "agg": aggregate([r["summary"] for r in recs]),
            "cdf_levels": recs[0]["cdf_levels"],
            "cdf": [float(x) for x in
                    np.mean([r["cdf"] for r in recs], axis=0)],
            "runs": len(recs),
            "sim_s_total": round(sum(r["sim_s"] for r in recs), 3),
        }
    return out


def table1(aggs: Dict[str, Dict],
           labels: Optional[Sequence[str]] = None) -> Dict[str, Dict]:
    """Table 1: measured vs paper JCR per policy, delta in points."""
    out = {}
    for label in (labels or PAPER_TABLE1):
        if label not in aggs:
            continue
        jcr_pct = 100.0 * aggs[label]["agg"]["jcr"]
        paper = PAPER_TABLE1.get(label)
        out[label] = {
            "jcr_pct": round(jcr_pct, 2),
            "paper_jcr_pct": paper,
            "delta_pts": None if paper is None else round(jcr_pct - paper, 2),
        }
    return out


def fig3(aggs: Dict[str, Dict],
         cube_sizes: Sequence[str] = ("4^3", "2^3")) -> Dict:
    """Fig 3: JCT percentiles for the 100%-JCR policies, plus the
    Reconfig/RFold speedup ratios the paper headlines (up to 11x)."""
    percentiles = {
        label: {k: agg["agg"][f"jct_{k}"] for k in ("p50", "p90", "p99")}
        for label, agg in aggs.items()
    }
    ratios = {}
    for n in cube_sizes:
        rc = percentiles.get(f"Reconfig ({n})")
        rf = percentiles.get(f"RFold ({n})")
        if not rc or not rf:
            continue
        ratios[n] = {
            k: round(rc[k] / rf[k], 2) if rf[k] else None
            for k in ("p50", "p90", "p99")
        }
        ratios[n]["paper"] = PAPER_FIG3_RATIOS.get(n)
    return {"percentiles": percentiles, "ratios": ratios}


def fig4(aggs: Dict[str, Dict]) -> Dict:
    """Fig 4: time-weighted utilization stats + mean CDF per policy,
    and the paper's two headline absolute deltas."""
    per_policy = {
        label: {"agg": agg["agg"],
                "cdf": [agg["cdf_levels"], agg["cdf"]]}
        for label, agg in aggs.items()
    }
    deltas = {}
    for key, paper in PAPER_FIG4_DELTAS.items():
        hi, lo = (s.strip() for s in key.split(" - "))
        if hi in aggs and lo in aggs:
            ours = 100.0 * (aggs[hi]["agg"]["util_mean"]
                            - aggs[lo]["agg"]["util_mean"])
            deltas[key] = {"ours_pts": round(ours, 2), "paper_pts": paper}
    return {"per_policy": per_policy, "deltas": deltas}
