"""Allocator-as-a-service demo: a live scheduling daemon, streaming
submissions, and pushed SETUP/RECONFIG/RELEASE topology events.

  PYTHONPATH=src python examples/scheduler_service.py
"""
from repro.api import Scheduler, TraceConfig, generate_trace


def main():
    trace = generate_trace(TraceConfig(num_jobs=12, seed=7,
                                       cluster_xpus=512, size_max=512))
    with Scheduler(policy="rfold",
                   policy_kw=dict(num_xpus=512, cube_n=4),
                   max_queue=4) as sched:
        print("daemon listening on %s:%d" % tuple(sched.address))
        running = []
        for job in trace:
            r = sched.submit(job.shape, job_id=job.job_id)
            print(f"submit job {job.job_id} {'x'.join(map(str, job.shape.dims))}"
                  f" -> {r['outcome']}")
            if r["outcome"] == "placed":
                running.append(job.job_id)
            elif r["outcome"] == "rejected" and running:
                # Overloaded: retire the oldest running job, retry once.
                done = sched.done(running.pop(0))
                for st in done["started"]:
                    print(f"  queue drained: job {st['job_id']} "
                          f"-> {st['outcome']}")
                r = sched.submit(job.shape, job_id=job.job_id)
                print(f"  resubmit -> {r['outcome']}")
                if r["outcome"] == "placed":
                    running.append(job.job_id)
        for ev in sched.events(max_wait=0.2):
            detail = ev.get("detail", {})
            extra = (f" ocs_links={detail['ocs_links']}"
                     if "ocs_links" in detail else "")
            print(f"event {ev['event']:8s} job {ev['job_id']}{extra}")
        st = sched.status()
        print(f"final: {st['allocated']} allocated, "
              f"{st['queue_depth']} queued, util={st['utilization']:.2f}")


if __name__ == "__main__":
    main()
