"""The paper's core demo: RFold vs baselines on a generated trace, plus
one folded placement inspected end to end.

  PYTHONPATH=src python examples/rfold_scheduling.py
"""
from repro.api import (JobShape, Simulator, TraceConfig, generate_trace,
                       make_policy, summarize)


def main():
    # 1. One job, inspected: the paper's 18x1x1 example.
    rf = make_policy("rfold", num_xpus=4096, cube_n=4)
    p = rf.try_place(0, JobShape((18, 1, 1)))
    print("18x1x1 placed as:", p.meta["fold"],
          "| cubes:", p.meta["num_cubes"],
          "| OCS links:", p.meta["ocs_links"],
          "| rings intact:", not p.broken_rings)
    rf.release(0)

    # 2. The paper's impossible-in-static shape.
    ff = make_policy("firstfit", dims=(16, 16, 16))
    print("4x4x32 on static 16^3:",
          "placeable" if ff.can_ever_place(JobShape((4, 4, 32)))
          else "never placeable (paper, Sec 3.2)")
    p2 = rf.try_place(1, JobShape((4, 4, 32)))
    print("4x4x32 on RFold(4^3): cubes =", p2.meta["num_cubes"],
          "wrap =", p2.meta["wrap"])
    rf.release(1)

    # 3. Mini trace comparison (Table-1-style).
    cfg = TraceConfig(num_jobs=120, seed=0, target_load=1.5)
    for name, kw in [("firstfit", dict(dims=(16, 16, 16))),
                     ("folding", dict(dims=(16, 16, 16))),
                     ("reconfig", dict(num_xpus=4096, cube_n=4)),
                     ("rfold", dict(num_xpus=4096, cube_n=4))]:
        pol = make_policy(name, **kw)
        s = summarize(Simulator(pol, generate_trace(cfg)).run())
        print(f"{name:9s} JCR={s['jcr']:.2f} "
              f"JCT(p50)={s['jct_p50']:8.0f}s util={s['util_mean']:.2f}")


if __name__ == "__main__":
    main()
