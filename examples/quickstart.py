"""Quickstart: train a tiny model for a few steps, then generate.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_variant
from repro.models import model as lm
from repro.serve import engine
from repro.train.data import synthetic_batches
from repro.train.optim import OptimConfig, init_opt_state
from repro.train.train_step import train_step


def main():
    cfg = smoke_variant(get_config("olmo-1b")).replace(dtype="float32")
    params = lm.init_model(cfg, jax.random.PRNGKey(0))
    opt_cfg = OptimConfig(lr=3e-3, warmup_steps=2, total_steps=20)
    opt = init_opt_state(params)
    data = synthetic_batches(cfg, batch=4, seq=64, seed=0)
    step = jax.jit(lambda p, o, b: train_step(cfg, opt_cfg, p, o, b))
    for i in range(10):
        params, opt, m = step(params, opt, next(data))
        print(f"step {i}: ce={float(m['ce']):.3f} "
              f"grad_norm={float(m['grad_norm']):.2f}")
    prompt = jnp.array([[1, 2, 3, 4, 5, 6, 7, 8]], jnp.int32)
    out = engine.greedy_decode(cfg, params, prompt, steps=8)
    print("generated:", out[0, 8:].tolist())


if __name__ == "__main__":
    main()
