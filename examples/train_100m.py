"""End-to-end driver: train a ~100M-parameter OLMo-family model on the
synthetic pipeline for a few hundred steps (CPU-runnable; the same
driver runs full configs under the production mesh on a pod).

  PYTHONPATH=src python examples/train_100m.py --steps 300
"""
import argparse

from repro.launch import train as train_launcher


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()
    # ~105M params: 4 layers, d=768, OLMo vocab (50304) dominates.
    train_launcher.main([
        "--arch", "olmo-1b", "--smoke",
        "--d-model", "768", "--n-layers", "4",
        "--steps", str(args.steps),
        "--batch", str(args.batch), "--seq", str(args.seq),
        "--lr", "3e-4",
        "--ckpt", "experiments/train_100m/ckpt.npz",
        "--log-every", "10",
    ])


if __name__ == "__main__":
    main()
