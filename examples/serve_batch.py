"""Batched serving demo: KV-cache decode over mixed request lengths.

  PYTHONPATH=src python examples/serve_batch.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.models import model as lm
from repro.serve import engine


def main():
    cfg = smoke_variant(get_config("llama3-8b")).replace(dtype="float32")
    params = lm.init_model(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch, prompt_len, gen = 8, 32, 32
    prompts = jnp.array(rng.integers(0, cfg.vocab_size,
                                     (batch, prompt_len)), jnp.int32)
    t0 = time.time()
    out = engine.greedy_decode(cfg, params, prompts, steps=gen)
    dt = time.time() - t0
    print(f"served {batch} requests x {gen} new tokens in {dt:.1f}s "
          f"({batch * gen / dt:.1f} tok/s on CPU)")
    print("first output:", out[0, prompt_len:prompt_len + 8].tolist())
    # sliding-window variant (long-context serving mode)
    cfg_w = cfg.replace(sliding_window=16)
    out_w = engine.greedy_decode(cfg_w, params, prompts, steps=4,
                                 window=16)
    print("sliding-window decode ok:", out_w.shape)


if __name__ == "__main__":
    main()
