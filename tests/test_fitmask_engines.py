"""Fitmask engine registry + allocator routing tests: engine
selection (explicit / set_default_engine / env var), cross-engine
parity on the multibox contract, the numpy engine's no-jax guarantee,
and the placement engines (StaticTorus / ReconfigTorus / policies)
producing identical decisions on every backend."""
import sys

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import fitmask as core_fitmask
from repro.core.allocator import make_policy
from repro.core.reconfig import ReconfigTorus
from repro.core.torus import StaticTorus, resolve_fitmask_engine
from repro.kernels.fitmask import ops

ENGINES = ("numpy", "jax", "pallas", "ref")
BOXES = ((1, 1, 1), (2, 2, 2), (4, 2, 1), (3, 3, 3), (9, 1, 1),
         (8, 8, 8))


@pytest.fixture(autouse=True)
def _reset_default_engine():
    yield
    ops.set_default_engine(None)


def _occ(seed=0, shape=(4, 8, 8, 8), p=0.35):
    return np.random.default_rng(seed).uniform(size=shape) < p


# ------------------------------------------------------- registry
def test_registry_lists_all_engines():
    assert set(ENGINES) <= set(ops.available_engines())


def test_legacy_aliases_resolve():
    assert ops.get_engine("auto") is ops.get_engine("pallas")
    assert ops.get_engine("kernel") is ops.get_engine("pallas")


def test_unknown_engine_raises():
    with pytest.raises(KeyError):
        ops.get_engine("tpu-v7")
    with pytest.raises(KeyError):
        ops.set_default_engine("nope")


def test_default_is_numpy():
    assert ops.default_engine_name() == "numpy"
    assert resolve_fitmask_engine(None) is None


def test_env_var_selects_default(monkeypatch):
    monkeypatch.setenv(ops.ENGINE_ENV, "jax")
    assert ops.default_engine_name() == "jax"
    assert resolve_fitmask_engine(None) is ops.get_engine("jax")
    monkeypatch.setenv(ops.ENGINE_ENV, "bogus")
    with pytest.raises(KeyError):
        ops.default_engine_name()


def test_set_default_engine_overrides_env(monkeypatch):
    monkeypatch.setenv(ops.ENGINE_ENV, "ref")
    ops.set_default_engine("pallas")
    assert ops.default_engine_name() == "pallas"
    ops.set_default_engine(None)
    assert ops.default_engine_name() == "ref"


# ------------------------------------------------------- parity
def test_all_engines_agree_on_free_counts():
    occ = _occ(seed=7)
    ref = np.asarray(ops.get_engine("numpy").free_counts(occ))
    assert ref.shape == (occ.shape[0],)
    assert np.array_equal(ref, [(~occ[i]).sum()
                                for i in range(occ.shape[0])])
    for name in ENGINES:
        out = np.asarray(ops.get_engine(name).free_counts(occ))
        assert np.array_equal(out, ref), name
    assert np.array_equal(np.asarray(ops.free_counts(occ, engine="jax")),
                          ref)


def test_all_engines_agree_on_multibox():
    occ = _occ()
    ref = ops.get_engine("numpy").multibox(occ, BOXES)
    assert ref.dtype == np.int32
    for name in ENGINES:
        out = np.asarray(ops.get_engine(name).multibox(occ, BOXES))
        assert (out == ref).all(), name


def test_all_engines_agree_on_single_box():
    occ = _occ(seed=1)
    ref = np.asarray(ops.fitmask(occ, (2, 3, 2), engine="numpy"))
    for name in ENGINES:
        out = np.asarray(ops.fitmask(occ, (2, 3, 2), engine=name))
        assert (out == ref).all(), name


# ---------------------------------------------- batched numpy fast path
@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000),
       st.tuples(st.integers(1, 6), st.integers(3, 9), st.integers(3, 9),
                 st.integers(3, 9)),
       st.integers(1, 6))
def test_fit_mask_multi_fast_matches_oracle(seed, shape, k):
    """The (B, K)-vectorized numpy multibox (int16 integral images,
    nested differencing) is exact against the straight-line oracle,
    including overhanging/infeasible boxes, and its fused free counts
    match the host reduction."""
    rng = np.random.default_rng(seed)
    occ = rng.uniform(size=shape) < 0.4
    boxes = tuple(tuple(int(v) for v in rng.integers(1, 11, size=3))
                  for _ in range(k))
    ref = core_fitmask.fit_mask_multi(occ, boxes)
    fast, free = core_fitmask.fit_mask_multi_fast(occ, boxes)
    assert fast.dtype == np.int32
    np.testing.assert_array_equal(fast, ref)
    np.testing.assert_array_equal(free, core_fitmask.free_counts(occ))


def test_fit_mask_multi_fast_matches_reduce_window_reference():
    """Batched numpy multibox vs the jax.lax.reduce_window oracle in
    ref.py (the satellite parity contract)."""
    import jax.numpy as jnp
    from repro.kernels.fitmask import ref as refmod
    occ = _occ(seed=9, shape=(3, 7, 6, 5))
    boxes = ((1, 1, 1), (2, 3, 2), (7, 6, 5), (8, 1, 1), (3, 3, 3))
    fast, _ = core_fitmask.fit_mask_multi_fast(occ, boxes)
    oracle = np.asarray(
        refmod.fitmask_multibox_reference(jnp.asarray(occ), boxes))
    np.testing.assert_array_equal(fast, oracle)


def test_fit_mask_multi_fast_large_grid_uses_wide_accumulator():
    """32^3 cells overflow int16 — the wide-accumulator fallback stays
    exact."""
    rng = np.random.default_rng(5)
    occ = rng.uniform(size=(2, 32, 32, 32)) < 0.5
    boxes = ((5, 5, 5), (32, 32, 32), (1, 1, 33))
    ref = core_fitmask.fit_mask_multi(occ, boxes)
    fast, free = core_fitmask.fit_mask_multi_fast(occ, boxes)
    np.testing.assert_array_equal(fast, ref)
    np.testing.assert_array_equal(free, core_fitmask.free_counts(occ))


def test_all_engines_agree_on_multibox_bucketed():
    """The broker's fused flush entry: planes are nonzero-where-fits
    (dtype is the engine's choice) and the free counts ride along."""
    occ = _occ(seed=12)
    ref = ops.get_engine("numpy").multibox(occ, BOXES)
    fc = np.asarray(ops.get_engine("numpy").free_counts(occ))
    for name in ENGINES:
        planes, free = ops.get_engine(name).multibox_bucketed(occ, BOXES)
        np.testing.assert_array_equal(np.asarray(planes) != 0, ref != 0,
                                      err_msg=name)
        np.testing.assert_array_equal(np.asarray(free).astype(np.int64),
                                      fc, err_msg=name)


def test_jax_compile_caches_are_bounded():
    """Satellite: the per-box and per-bucket program caches are LRU
    with a size cap, not unbounded functools.cache — long multi-shape
    sweeps cannot grow them without limit."""
    info = ops.JaxEngine._window_fn.cache_info()
    assert info.maxsize == ops.WINDOW_CACHE_SIZE
    info = ops.JaxEngine._bucket_fn.cache_info()
    assert info.maxsize == ops.BUCKET_CACHE_SIZE


# ------------------------------------------------------- numpy purity
class _Poison:
    """Stand-in for the jax modules: any attribute access fails the
    test, so the numpy path provably never calls into jax."""

    def __getattr__(self, name):
        raise AssertionError(f"numpy engine touched jax (.{name})")


def test_numpy_engine_makes_no_jax_calls(monkeypatch):
    """Regression for the old wrapper's host round-trip (np.pad ->
    jnp.asarray on every call): the numpy engine must return numpy
    arrays without a single jax call."""
    poison = _Poison()
    for mod in ("jax", "jax.numpy", "jax.experimental.pallas"):
        monkeypatch.setitem(sys.modules, mod, poison)
    occ = _occ(seed=2, shape=(2, 6, 6, 6))
    out = ops.fitmask(occ, (2, 2, 2), engine="numpy")
    assert isinstance(out, np.ndarray) and out.dtype == np.int32
    out3 = ops.fitmask(occ[0], (2, 2, 2), engine="numpy")
    assert isinstance(out3, np.ndarray) and out3.shape == (6, 6, 6)
    multi = ops.fitmask_multi(occ, BOXES, engine="numpy")
    assert isinstance(multi, np.ndarray)
    assert multi.shape == (2, len(BOXES), 6, 6, 6)


def test_numpy_allocator_path_makes_no_jax_calls(monkeypatch):
    """The default placement hot path (policies -> torus -> fitmask)
    stays jax-free too."""
    poison = _Poison()
    for mod in ("jax", "jax.numpy", "jax.experimental.pallas"):
        monkeypatch.setitem(sys.modules, mod, poison)
    from repro.core.geometry import JobShape
    pol = make_policy("rfold", num_xpus=128, cube_n=4)
    assert pol.try_place(1, JobShape((4, 4, 2))) is not None
    pol2 = make_policy("folding", dims=(8, 8, 8))
    assert pol2.try_place(1, JobShape((2, 2, 2))) is not None


# ------------------------------------------------------- torus routing
def test_static_torus_engine_parity():
    """find_free_box / count_free_boxes identical across engines on a
    randomly occupied torus, with and without prefetch."""
    rng = np.random.default_rng(3)
    boxes = [(2, 2, 2), (4, 1, 1), (3, 2, 2), (8, 8, 8), (2, 4, 2)]
    toruses = {name: StaticTorus((8, 8, 8), fitmask_engine=name)
               for name in ENGINES}
    mask = rng.uniform(size=(8, 8, 8)) < 0.4
    for t in toruses.values():
        t.occ[:] = mask
        t.bump_epoch()
    toruses["pallas"].prefetch_boxes(boxes)    # batch path
    ref = toruses["numpy"]
    for box in boxes:
        for name, t in toruses.items():
            assert t.find_free_box(box) == ref.find_free_box(box), \
                (name, box)
            assert t.count_free_boxes(box) == ref.count_free_boxes(box), \
                (name, box)


def test_static_torus_engine_epoch_invalidation():
    """Engine-cached masks refresh when occupancy changes."""
    t = StaticTorus((6, 6, 6), fitmask_engine="pallas")
    assert t.find_free_box((2, 2, 2)) == (0, 0, 0)
    t.commit_box(1, (0, 0, 0), (2, 2, 2))
    origin = t.find_free_box((2, 2, 2))
    assert origin is not None and origin != (0, 0, 0)
    t.release(1)
    assert t.find_free_box((2, 2, 2)) == (0, 0, 0)


def test_reconfig_block_free_engine_parity():
    """ReconfigTorus sub-block freeness via the engine equals the host
    integral-image path, across cube occupancy states."""
    rng = np.random.default_rng(4)
    locals_ = [((0, 2), (0, 2), (0, 2)), ((1, 4), (0, 4), (2, 3)),
               ((0, 4), (0, 4), (0, 4)), ((3, 4), (3, 4), (3, 4))]
    rts = {name: ReconfigTorus(512, 4, fitmask_engine=name)
           for name in ENGINES}
    mask = rng.uniform(size=(8, 4, 4, 4)) < 0.3
    for rt in rts.values():
        rt.occ[:] = mask
        rt.bump_epoch()
    ref = rts["numpy"]
    for local in locals_:
        expect = ref._block_free_mask(local)
        naive = ref._block_free_mask_naive(local)
        assert (expect == naive).all()
        for name, rt in rts.items():
            assert (rt._block_free_mask(local) == expect).all(), \
                (name, local)


@pytest.mark.parametrize("engine", ["jax", "pallas"])
def test_engine_runs_build_no_host_integral_image(engine, monkeypatch):
    """ROADMAP item closed by PR 4: with an accelerator engine active,
    the reconfigurable torus answers BOTH sub-block freeness and
    per-cube free counts from the engine — zero host integral-image
    builds on the placement path (same poison pattern as the numpy
    engine's no-jax guarantee)."""
    from repro.core import fitmask as core_fitmask
    from repro.core.geometry import JobShape

    def _poisoned(*a, **kw):
        raise AssertionError("engine run built a host integral image")

    monkeypatch.setattr(core_fitmask, "integral_image", _poisoned)
    monkeypatch.setattr(core_fitmask, "batched_integral_image", _poisoned)
    for policy in ("reconfig", "rfold"):
        pol = make_policy(policy, num_xpus=256, cube_n=4,
                          fitmask_engine=engine)
        assert pol.try_place(1, JobShape((4, 4, 2))) is not None
        assert pol.try_place(2, JobShape((8, 2, 2))) is not None
        pol.release(1)
        assert pol.try_place(3, JobShape((4, 4, 4))) is not None
        assert pol.cluster._ii is None


def test_policy_engine_parity_small_sim():
    """End-to-end: a seeded trace schedules identically on every
    engine for a static-torus and a reconfigurable policy."""
    from repro.sim.metrics import summarize
    from repro.sim.simulator import Simulator
    from repro.traces.generator import TraceConfig, generate_trace

    jobs = generate_trace(TraceConfig(num_jobs=18, seed=11,
                                      target_load=1.5))
    for policy, kw in (("folding", dict(dims=(8, 8, 8))),
                       ("rfold", dict(num_xpus=512, cube_n=4))):
        base = None
        for name in ENGINES:
            pol = make_policy(policy, fitmask_engine=name, **kw)
            s = summarize(Simulator(pol, list(jobs)).run())
            if base is None:
                base = s
            else:
                assert s == base, (policy, name)


def test_policy_engine_from_env(monkeypatch):
    """REPRO_FITMASK_ENGINE routes a default-constructed policy's
    placement queries through the named engine."""
    from repro.core.geometry import JobShape
    monkeypatch.setenv(ops.ENGINE_ENV, "pallas")
    pol = make_policy("folding", dims=(6, 6, 6))
    assert pol.torus.fitmask_engine is None
    assert resolve_fitmask_engine(None) is ops.get_engine("pallas")
    assert pol.try_place(1, JobShape((2, 2, 2))) is not None
    # the placement actually consulted the engine-side mask cache
    assert pol.torus._box_masks


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
