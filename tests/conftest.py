"""Test-suite bootstrap.

Two jobs:

1. Register custom marks (``slow``) so ``pytest`` runs warning-clean.
2. Provide a graceful fallback when ``hypothesis`` is not installed
   (see requirements-dev.txt): a deterministic miniature stand-in that
   implements the tiny surface this suite uses (``given`` / ``settings``
   / ``strategies.integers|tuples|sampled_from``). Property tests then
   run a fixed, seeded sample sweep instead of erroring at collection.
   With the real hypothesis available, the shim is never installed.
"""
from __future__ import annotations

import inspect
import sys
import types
import zlib


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running test (subprocess compiles etc.)")


def _install_hypothesis_stub() -> None:
    import numpy as np

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    def integers(min_value, max_value):
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)))

    def sampled_from(elements):
        seq = list(elements)
        return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))])

    def tuples(*strategies):
        return _Strategy(
            lambda rng: tuple(s.draw(rng) for s in strategies))

    def settings(max_examples: int = 25, deadline=None, **_kw):
        def deco(fn):
            fn._stub_max_examples = max_examples
            return fn
        return deco

    class _StubAssume(Exception):
        pass

    def given(*strategies):
        def deco(fn):
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_stub_max_examples",
                            getattr(fn, "_stub_max_examples", 25))
                # Deterministic per-test seed: same draws every run.
                rng = np.random.default_rng(
                    zlib.crc32(fn.__qualname__.encode()))
                for _ in range(n):
                    drawn = tuple(s.draw(rng) for s in strategies)
                    try:
                        fn(*args, *drawn, **kwargs)
                    except _StubAssume:
                        continue  # rejected example, draw another
            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            # No fixture params: the strategies supply every argument.
            wrapper.__signature__ = inspect.Signature()
            return wrapper
        return deco

    def assume(condition) -> bool:  # minimal: skip rest of one example
        if not condition:
            raise _StubAssume()
        return True

    strat = types.ModuleType("hypothesis.strategies")
    strat.integers = integers
    strat.sampled_from = sampled_from
    strat.tuples = tuples

    mod = types.ModuleType("hypothesis")
    mod.strategies = strat
    mod.given = given
    mod.settings = settings
    mod.assume = assume
    mod.HealthCheck = types.SimpleNamespace(too_slow="too_slow",
                                            filter_too_much="filter_too_much")
    mod.__stub__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strat


try:  # pragma: no cover - environment probe
    import hypothesis  # noqa: F401
except ImportError:  # pragma: no cover
    _install_hypothesis_stub()
