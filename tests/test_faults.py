"""Chaos-layer tests: fault models, injection, generation, simulator
eviction semantics.

The load-bearing guarantees:

* A fault on resources hosting a job always evicts the victim first —
  the models *refuse* (``FaultConflictError``) to fail owned
  resources, so silent corruption is structurally impossible.
* Repairing a never-failed resource is a no-op.
* :class:`FaultEvent` round-trips the JSON-lines wire format.
* A seeded :class:`FaultGenerator` is reproducible (hypothesis sweep).
* Batched and naive reconfig plan search agree under OCS degradation.
* The full chaos simulation is deterministic, and attaching an
  observer never changes the schedule.
"""
import json

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.allocator import make_policy
from repro.core.geometry import JobShape
from repro.core.reconfig import ReconfigTorus
from repro.core.torus import FAILED, FaultConflictError, StaticTorus
from repro.sim.faults import (ChaosObserver, FaultConfig, FaultEvent,
                              FaultGenerator, FaultInjector)
from repro.sim.simulator import Simulator
from repro.traces.generator import TraceConfig, generate_trace

SMALL = dict(num_xpus=64, cube_n=4)
MEDIUM = dict(num_xpus=512, cube_n=4)
TRACE_512 = dict(cluster_xpus=512, size_max=512)


# ---------------------------------------------------- static torus model
def test_static_fail_marks_occupied_and_unplaceable():
    t = StaticTorus((4, 4, 4))
    applied = t.fail_nodes([(0, 0, 0), (1, 1, 1)])
    assert applied == [(0, 0, 0), (1, 1, 1)]
    assert t.occ[0, 0, 0] and t.owner[0, 0, 0] == FAILED
    assert t.num_failed == 2
    # busy_xpus excludes failed nodes; free_xpus shrinks by them.
    assert t.busy_xpus == 0 and t.free_xpus == 64 - 2
    t.check_invariants()
    # repairing restores everything
    assert t.repair_nodes([(0, 0, 0), (1, 1, 1)]) == [(0, 0, 0), (1, 1, 1)]
    assert t.num_failed == 0 and t.free_xpus == 64
    t.check_invariants()


def test_static_fail_owned_node_refused():
    pol = make_policy("firstfit", dims=(4, 4, 4))
    p = pol.try_place(0, JobShape((4, 4, 4)))
    assert p is not None
    with pytest.raises(FaultConflictError):
        pol.torus.fail_nodes([(0, 0, 0)])
    pol.torus.check_invariants()  # the refused fault changed nothing
    # after eviction the same fault applies cleanly
    pol.release(0)
    assert pol.torus.fail_nodes([(0, 0, 0)]) == [(0, 0, 0)]


def test_static_repair_of_never_failed_node_is_noop():
    t = StaticTorus((4, 4, 4))
    assert t.repair_nodes([(2, 2, 2)]) == []
    assert t.num_failed == 0
    t.check_invariants()


def test_static_fail_is_idempotent():
    t = StaticTorus((4, 4, 4))
    t.fail_nodes([(0, 0, 0)])
    assert t.fail_nodes([(0, 0, 0)]) == []  # second fault: no-op
    assert t.num_failed == 1


def test_cut_link_blocks_commit_and_repair_restores():
    t = StaticTorus((4, 4, 4))
    assert t.cut_link((0, 0, 0), (0, 0, 1))
    assert not t.cut_link((0, 0, 0), (0, 0, 1))  # already cut
    coords = [(0, 0, z) for z in range(4)]
    links = [((0, 0, z), (0, 0, (z + 1) % 4)) for z in range(4)]
    with pytest.raises(ValueError, match="cut"):
        t.commit(1, coords, links)
    assert t.repair_link((0, 0, 0), (0, 0, 1))
    t.commit(1, coords, links)  # repairable after repair
    t.check_invariants()


def test_cut_link_under_job_refused():
    pol = make_policy("firstfit", dims=(4, 4, 4))
    pol.try_place(0, JobShape((4, 4, 4)))
    alloc = pol.torus.allocations[0]
    u, v = next(iter(sorted(alloc.links)))
    with pytest.raises(FaultConflictError):
        pol.torus.cut_link(u, v)


def test_cut_link_routes_fold_around_as_broken_axis():
    """A fold whose ring would traverse a cut link still places, but
    with that axis counted broken (the 17 % slowdown path) instead of
    silently using the dead wire."""
    pol = make_policy("folding", dims=(4, 4, 4))
    healthy = pol.try_place(0, JobShape((4, 4, 4)))
    assert healthy.broken_rings == ()
    pol.release(0)
    pol.torus.cut_link((0, 0, 0), (0, 0, 1))
    degraded = pol.try_place(1, JobShape((4, 4, 4)))
    assert degraded is not None
    assert 2 in degraded.broken_rings  # the cut z-axis ring is broken
    pol.torus.check_invariants()


# -------------------------------------------------- reconfig torus model
def test_reconfig_fail_cells_and_repair():
    pol = make_policy("rfold", **SMALL)
    m = pol.cluster
    applied = m.fail_cells([(0, 0, 0, 0), (0, 1, 1, 1)])
    assert applied == [(0, 0, 0, 0), (0, 1, 1, 1)]
    assert m.busy_xpus == 0 and m.free_xpus == 64 - 2
    m.check_invariants()
    # whole-cube job no longer fits; smaller still does
    assert pol.try_place(0, JobShape((4, 4, 4))) is None
    assert pol.try_place(1, JobShape((2, 2, 2))) is not None
    pol.release(1)
    assert m.repair_cells([(0, 0, 0, 0), (0, 1, 1, 1)]) == applied
    assert pol.try_place(2, JobShape((4, 4, 4))) is not None
    m.check_invariants()


def test_reconfig_fail_owned_cell_refused():
    pol = make_policy("rfold", **SMALL)
    pol.try_place(0, JobShape((4, 4, 4)))
    with pytest.raises(FaultConflictError):
        pol.cluster.fail_cells([(0, 0, 0, 0)])
    pol.cluster.check_invariants()


def test_reconfig_repair_never_failed_noop():
    pol = make_policy("rfold", **SMALL)
    assert pol.cluster.repair_cells([(0, 3, 3, 3)]) == []
    pol.cluster.check_invariants()


def _cubes_of(model, job_id):
    return sorted({piece.cube_id for piece in model.allocations[job_id]})


def test_ocs_port_fault_excludes_cube_from_chains():
    """With a dead OCS port, the cube can still host OCS-free local
    jobs but never participates in multi-cube chains."""
    pol = make_policy("rfold", **MEDIUM)
    m = pol.cluster
    assert m.fail_ocs_port([0]) == [0]
    # A 2-cube job must avoid cube 0 (8 cubes, 7 usable).
    p = pol.try_place(0, JobShape((8, 4, 4)))
    assert p is not None and p.meta["num_cubes"] >= 2
    assert 0 not in _cubes_of(m, 0)
    # Full-cube jobs also avoid cube 0: their wrap closure rides the
    # OCS loopback (ocs_links=48), which the dead port can't provide.
    for jid in range(1, 6):  # job 0 holds 2 cubes; 5 of 8 remain usable
        q = pol.try_place(jid, JobShape((4, 4, 4)))
        assert q is not None and 0 not in _cubes_of(m, jid)
    assert pol.try_place(8, JobShape((4, 4, 4))) is None
    # OCS-free local placement in cube 0 still works.
    q = pol.try_place(9, JobShape((2, 2, 2)))
    assert q is not None and _cubes_of(m, 9) == [0]
    assert q.meta["ocs_links"] == 0
    m.check_invariants()


def test_ocs_port_fault_with_chained_job_refused():
    pol = make_policy("rfold", **MEDIUM)
    p = pol.try_place(0, JobShape((8, 4, 4)))  # spans >= 2 cubes via OCS
    assert p is not None and p.meta["ocs_links"] > 0
    cube = _cubes_of(pol.cluster, 0)[0]
    with pytest.raises(FaultConflictError):
        pol.cluster.fail_ocs_port([cube])
    assert pol.cluster.jobs_using_ocs([cube]) == [0]
    pol.cluster.check_invariants()


def test_ocs_repair_never_failed_noop():
    pol = make_policy("rfold", **MEDIUM)
    assert pol.cluster.repair_ocs_port([3]) == []


def test_ocs_degraded_batched_matches_naive():
    """Plan search under OCS degradation: the batched engine and the
    naive oracle must pick identical plans (same candidate filtering
    for wrap closures and multi-cube chains)."""
    from repro.core.folding import enumerate_folds
    rt = ReconfigTorus(512, 4)
    rt.fail_ocs_port([0, 3])
    rt.fail_cells([(1, 0, 0, 0), (1, 1, 0, 0)])
    jid = 0
    for dims in [(8, 4, 4), (4, 4, 4), (2, 2, 4), (8, 8, 4), (4, 4, 8),
                 (2, 4, 2), (16, 4, 4)]:
        for f in enumerate_folds(JobShape(dims), max_dim=rt.max_extent):
            plan = rt.place_fold(f)
            assert plan == rt.place_fold_naive(f), (dims, f)
            if plan is not None:
                rt.commit(jid, plan)
                jid += 1
                break
    rt.check_invariants()


# ------------------------------------------------------- FaultEvent wire
def test_fault_event_wire_roundtrip():
    for ev in [
        FaultEvent(1.5, "fault", "node", ((0, 1, 2), (3, 0, 1))),
        FaultEvent(2.0, "repair", "node", ((2, 1, 2, 3),)),
        FaultEvent(0.25, "fault", "link", (((0, 0, 0), (0, 0, 1)),)),
        FaultEvent(9.0, "fault", "ocs_port", (5,)),
    ]:
        wire = json.loads(json.dumps(ev.to_wire()))  # through JSON bytes
        back = FaultEvent.from_wire(wire)
        assert back == ev


# ----------------------------------------------------- FaultGenerator
@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 5), st.integers(0, 3),
       st.integers(1, 8))
def test_generator_reproducible_and_well_formed(seed, node_faults,
                                                fabric_faults, blast):
    cfg = FaultConfig(seed=seed, num_node_faults=node_faults,
                      num_fabric_faults=fabric_faults,
                      nodes_per_fault=blast)
    model = make_policy("rfold", **SMALL).cluster
    a = FaultGenerator(cfg).generate(model, horizon=100.0)
    b = FaultGenerator(cfg).generate(model, horizon=100.0)
    assert a == b  # same seed, same timeline
    assert len([e for e in a if e.action == "fault"]) == cfg.total_events
    assert all(a[i].time <= a[i + 1].time for i in range(len(a) - 1))
    for ev in a:
        assert ev.kind in ("node", "link", "ocs_port")
        if ev.kind == "node":
            assert len(ev.targets) == min(blast, 64)
            assert all(len(t) == 4 for t in ev.targets)  # reconfig cells
    # every fault has a matching repair (repair=True default)
    faults = [e for e in a if e.action == "fault"]
    repairs = [e for e in a if e.action == "repair"]
    assert sorted((f.targets for f in faults), key=repr) == \
        sorted((r.targets for r in repairs), key=repr)


def test_generator_static_vs_reconfig_target_concretization():
    cfg = FaultConfig(seed=7, num_node_faults=2, nodes_per_fault=3)
    static = FaultGenerator(cfg).generate(
        make_policy("firstfit", dims=(8, 8, 8)).torus, horizon=50.0)
    reconf = FaultGenerator(cfg).generate(
        make_policy("rfold", **MEDIUM).cluster, horizon=50.0)
    # same flat draws, concretized per model: 3-coords vs 4-cells
    assert all(len(t) == 3 for e in static for t in e.targets)
    assert all(len(t) == 4 for e in reconf for t in e.targets)
    assert [e.time for e in static] == [e.time for e in reconf]


# ------------------------------------------------- simulator + injector
def _chaos_sim(policy="rfold", policy_kw=MEDIUM, num_jobs=50, seed=0,
               fault_cfg=None, observer=None, **sim_kw):
    jobs = generate_trace(TraceConfig(num_jobs=num_jobs, seed=seed,
                                      **TRACE_512))
    pol = make_policy(policy, **policy_kw)
    model = getattr(pol, "cluster", None) or pol.torus
    horizon = max(j.arrival for j in jobs)
    faults = FaultGenerator(
        fault_cfg or FaultConfig(seed=seed, num_node_faults=4,
                                 nodes_per_fault=8)
    ).generate(model, horizon)
    return Simulator(pol, jobs, faults=faults, observer=observer,
                     **sim_kw), faults


def test_fault_on_hosting_node_preempts_or_migrates_never_corrupts():
    obs = ChaosObserver()
    sim, faults = _chaos_sim(observer=obs)
    result = sim.run()
    model = getattr(sim.policy, "cluster", None) or sim.policy.torus
    model.check_invariants()
    # every victim was preempted or migrated — accounted, never lost
    assert obs.victims == obs.preempted + obs.migrated
    assert obs.killed == 0
    for j in result.jobs:
        assert (j.preemptions + j.migrations == 0) or j.scheduled
        # evicted work was preserved: jobs never finish before the
        # remaining-work replan says they can
        if j.finish is not None and j.migrations + j.preemptions == 0:
            assert j.finish == pytest.approx(
                j.start + j.duration * j.slowdown)


def test_fault_mode_kill_fail_stops_victims():
    obs = ChaosObserver()
    sim, _ = _chaos_sim(observer=obs, fault_mode="kill",
                        fault_cfg=FaultConfig(seed=1, num_node_faults=6,
                                              nodes_per_fault=16))
    result = sim.run()
    assert obs.victims == obs.killed
    assert obs.preempted == obs.migrated == 0
    killed = [j for j in result.jobs if j.killed]
    assert len(killed) == obs.killed
    assert all(j.dropped and j.finish is None for j in killed)


def test_chaos_simulation_deterministic():
    recs = []
    for _ in range(2):
        obs = ChaosObserver()
        sim, _ = _chaos_sim(observer=obs)
        result = sim.run()
        recs.append(json.dumps(
            {"chaos": result.chaos,
             "jobs": [[j.job_id, j.start, j.finish, j.preemptions,
                       j.migrations, j.dropped] for j in result.jobs]},
            sort_keys=True))
    assert recs[0] == recs[1]


def test_observer_is_pure_observation():
    """Attaching an observer must not change the schedule."""
    sim_a, _ = _chaos_sim(observer=None)
    sim_b, _ = _chaos_sim(observer=ChaosObserver())
    ra, rb = sim_a.run(), sim_b.run()
    assert [(j.job_id, j.start, j.finish) for j in ra.jobs] == \
        [(j.job_id, j.start, j.finish) for j in rb.jobs]
    assert ra.chaos is None and rb.chaos is not None


def test_no_faults_byte_identical_to_legacy_simulator():
    """The chaos plumbing is pay-for-play: a Simulator with no faults,
    no observer and no priorities produces the identical schedule the
    pre-chaos simulator did."""
    jobs_a = generate_trace(TraceConfig(num_jobs=60, seed=3, **TRACE_512))
    jobs_b = generate_trace(TraceConfig(num_jobs=60, seed=3, **TRACE_512))
    legacy = Simulator(make_policy("rfold", **MEDIUM), jobs_a).run()
    chaosy = Simulator(make_policy("rfold", **MEDIUM), jobs_b,
                       faults=(), observer=None).run()
    assert json.dumps([[j.job_id, j.start, j.finish, j.dropped,
                        j.slowdown] for j in legacy.jobs]) == \
        json.dumps([[j.job_id, j.start, j.finish, j.dropped,
                     j.slowdown] for j in chaosy.jobs])
    assert legacy.utilization_samples == chaosy.utilization_samples


def test_injector_victims_and_apply_dispatch():
    pol = make_policy("rfold", **SMALL)
    pol.try_place(0, JobShape((4, 4, 4)))
    inj = FaultInjector(pol)
    ev = FaultEvent(0.0, "fault", "node", ((0, 0, 0, 0),))
    assert inj.victims(ev) == [0]
    pol.release(0)
    assert inj.victims(ev) == []
    assert inj.apply(ev) == [(0, 0, 0, 0)]
    repair = FaultEvent(1.0, "repair", "node", ((0, 0, 0, 0),))
    assert inj.victims(repair) == []  # repairs never evict
    assert inj.apply(repair) == [(0, 0, 0, 0)]
    pol.cluster.check_invariants()


def test_observer_finalize_degradation_metrics():
    obs = ChaosObserver()
    sim, faults = _chaos_sim(observer=obs, num_jobs=80)
    result = sim.run()
    ch = result.chaos
    n_faults = sum(1 for f in faults if f.action == "fault")
    assert ch["faults"] == n_faults and ch["repairs"] == n_faults
    assert 0.0 <= ch["util_overall"] <= 1.0
    assert ch["dip_depth"] >= 0.0
    assert ch["max_queue_depth"] >= ch["requeue_depth_max"] >= 0
    if ch["recovered"]:
        assert ch["time_to_recover"] is not None


# ------------------------------------------------- priority preemption
def test_priority_preemption_evicts_lower_priority():
    pol = make_policy("rfold", **SMALL)
    from repro.sim.job import Job
    jobs = [Job(job_id=0, arrival=0.0, duration=100.0,
                shape=JobShape((4, 4, 4)), priority=0),
            Job(job_id=1, arrival=1.0, duration=10.0,
                shape=JobShape((4, 4, 4)), priority=2)]
    obs = ChaosObserver()
    result = Simulator(pol, jobs, observer=obs,
                       priority_preemption=True).run()
    j0, j1 = result.jobs
    assert j1.start == 1.0          # high priority preempts its way in
    assert j0.preemptions == 1
    assert j0.finish > j1.finish    # evicted job resumed after
    # work-preserving: j0 ran 1s before eviction, 99s remain
    assert j0.finish == pytest.approx(j1.finish + 99.0)
    assert obs.preempted == 1


def test_priority_preemption_never_evicts_equal_or_higher():
    pol = make_policy("rfold", **SMALL)
    from repro.sim.job import Job
    jobs = [Job(job_id=0, arrival=0.0, duration=100.0,
                shape=JobShape((4, 4, 4)), priority=1),
            Job(job_id=1, arrival=1.0, duration=10.0,
                shape=JobShape((4, 4, 4)), priority=1)]
    result = Simulator(pol, jobs, priority_preemption=True).run()
    j0, j1 = result.jobs
    assert j0.preemptions == 0
    assert j1.start == pytest.approx(j0.finish)  # plain FIFO wait


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
