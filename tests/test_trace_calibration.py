"""Trace-generator distribution-shape tests + the Philly calibration
preset (ROADMAP trace-calibration first step, PR 4).

The synthetic trace must actually follow the distributions it claims:
truncated-exponential sizes, lognormal durations with the configured
median/tail, Poisson arrivals at the target offered load. The
``philly`` preset is checked against its calibration targets (heavy
mean/median duration ratio, small-job size mass)."""
import math

import numpy as np
import pytest

from repro.traces.generator import (TRACE_PRESETS, TraceConfig,
                                    _truncated_exp_sizes, generate_trace)


def _trace(cfg):
    return generate_trace(cfg)


# ------------------------------------------------------- distributions
def test_duration_median_and_tail_match_config():
    cfg = TraceConfig(num_jobs=20_000, seed=1)
    durs = np.array([j.duration for j in _trace(cfg)])
    # lognormal: median = exp(mu), sigma = std of log durations
    assert np.median(durs) == pytest.approx(cfg.duration_median_s, rel=0.05)
    assert np.std(np.log(durs)) == pytest.approx(cfg.duration_sigma,
                                                 rel=0.03)


def test_sizes_follow_truncated_exponential():
    """Pre-rounding sampler vs the analytic truncated-exp CDF."""
    cfg = TraceConfig()
    rng = np.random.default_rng(2)
    raw = _truncated_exp_sizes(rng, 50_000, cfg.size_scale, cfg.size_max)
    assert raw.min() >= 1 and raw.max() <= cfg.size_max
    fmax = 1.0 - math.exp(-cfg.size_max / cfg.size_scale)
    for s in (64, 256, 1024):
        analytic = (1.0 - math.exp(-s / cfg.size_scale)) / fmax
        empirical = float((raw <= s).mean())
        assert empirical == pytest.approx(analytic, abs=0.02), s


def test_arrivals_hit_target_load():
    cfg = TraceConfig(num_jobs=20_000, seed=3, target_load=1.2)
    jobs = _trace(cfg)
    arrivals = np.array([j.arrival for j in jobs])
    demand = float(np.mean([j.shape.size * j.duration for j in jobs]))
    mean_ia = float(np.mean(np.diff(arrivals)))
    load = demand / (mean_ia * cfg.cluster_xpus)
    # shapes bump sizes slightly (even rounding, feasibility), so the
    # realized load only approximates the target
    assert load == pytest.approx(cfg.target_load, rel=0.1)


# ------------------------------------------------------- philly preset
def test_philly_preset_fields_and_overrides():
    cfg = TraceConfig.preset("philly", num_jobs=7, seed=42)
    assert cfg.duration_sigma == TRACE_PRESETS["philly"]["duration_sigma"]
    assert cfg.size_scale == TRACE_PRESETS["philly"]["size_scale"]
    assert cfg.num_jobs == 7 and cfg.seed == 42
    # untouched fields keep their defaults
    assert cfg.duration_median_s == TraceConfig().duration_median_s
    with pytest.raises(KeyError):
        TraceConfig.preset("borg")


def test_philly_preset_duration_tail():
    """Calibration target: mean/median duration ratio ~ exp(sigma^2/2)
    ~ 10 (Philly's reported hours-scale mean over a 13-minute median),
    vs ~2.7 for the default config."""
    cfg = TraceConfig.preset("philly", num_jobs=50_000, seed=4)
    durs = np.array([j.duration for j in _trace(cfg)])
    ratio = float(np.mean(durs) / np.median(durs))
    expect = math.exp(cfg.duration_sigma ** 2 / 2)
    assert ratio == pytest.approx(expect, rel=0.25)
    assert ratio > 2 * math.exp(TraceConfig().duration_sigma ** 2 / 2)


def test_philly_preset_small_job_mass():
    """The preset moves size mass toward Philly's small-job share:
    clearly more <=16-XPU jobs than the default scale produces."""
    small = {}
    for name, cfg in [("default", TraceConfig(num_jobs=20_000, seed=5)),
                      ("philly", TraceConfig.preset(
                          "philly", num_jobs=20_000, seed=5))]:
        sizes = np.array([j.shape.size for j in _trace(cfg)])
        small[name] = float((sizes <= 16).mean())
    assert small["philly"] > small["default"] + 0.05
    # both stay inside the paper's truncated-exp support
    assert small["philly"] < 1.0


def test_preset_trace_is_deterministic():
    a = _trace(TraceConfig.preset("philly", num_jobs=40, seed=9))
    b = _trace(TraceConfig.preset("philly", num_jobs=40, seed=9))
    assert [(j.arrival, j.duration, j.shape.dims) for j in a] == \
        [(j.arrival, j.duration, j.shape.dims) for j in b]


# ------------------------------------- chaos-layer trace knobs (PR 8)
def _spearman(x, y):
    rx = np.argsort(np.argsort(x)).astype(float)
    ry = np.argsort(np.argsort(y)).astype(float)
    rx -= rx.mean()
    ry -= ry.mean()
    return float((rx * ry).sum() /
                 math.sqrt((rx ** 2).sum() * (ry ** 2).sum()))


def test_default_knobs_are_byte_identical_to_legacy():
    """corr=0, burstiness=0, priority_levels=1 must take the legacy
    sampling path exactly — same RNG draw order, same trace — so every
    pre-chaos result in the repo stays reproducible."""
    legacy = _trace(TraceConfig(num_jobs=300, seed=11))
    explicit = _trace(TraceConfig(num_jobs=300, seed=11,
                                  size_duration_corr=0.0,
                                  arrival_burstiness=0.0,
                                  priority_levels=1))
    assert [(j.arrival, j.duration, j.shape.dims, j.priority)
            for j in legacy] == \
        [(j.arrival, j.duration, j.shape.dims, j.priority)
         for j in explicit]
    assert all(j.priority == 0 for j in legacy)


def test_size_duration_rank_correlation_monotone_in_rho():
    """The Gaussian copula must actually couple size and duration, and
    more rho means more coupling."""
    rhos = [0.0, 0.3, 0.6, 0.9]
    spear = []
    for rho in rhos:
        jobs = _trace(TraceConfig(num_jobs=20_000, seed=12,
                                  size_duration_corr=rho))
        sizes = np.array([j.shape.size for j in jobs], dtype=float)
        durs = np.array([j.duration for j in jobs])
        spear.append(_spearman(sizes, durs))
    assert abs(spear[0]) < 0.05                  # rho=0: uncorrelated
    for lo, hi in zip(spear, spear[1:]):
        assert hi > lo + 0.1                     # strictly increasing
    assert spear[-1] > 0.6                       # rho=0.9: strong


def test_copula_preserves_both_marginals():
    """Coupling must not distort either marginal: sizes still follow
    the truncated exponential, durations still lognormal with the
    configured median/sigma."""
    cfg = TraceConfig(num_jobs=20_000, seed=13, size_duration_corr=0.7)
    jobs = _trace(cfg)
    durs = np.array([j.duration for j in jobs])
    assert np.median(durs) == pytest.approx(cfg.duration_median_s,
                                            rel=0.05)
    assert np.std(np.log(durs)) == pytest.approx(cfg.duration_sigma,
                                                 rel=0.03)
    sizes = np.array([j.shape.size for j in jobs], dtype=float)
    base = np.array([j.shape.size for j in
                     _trace(TraceConfig(num_jobs=20_000, seed=13))],
                    dtype=float)
    # same post-rounding size distribution as the uncorrelated draw
    for q in (0.25, 0.5, 0.75, 0.9):
        assert np.quantile(sizes, q) == pytest.approx(
            np.quantile(base, q), rel=0.15), q


def test_burstiness_preserves_mean_interarrival():
    """Hyperexponential arrivals keep the offered load: the two-phase
    mix is calibrated so 0.75(1-b) + 0.25(1+3b) = 1."""
    ratios, cvs = [], []
    for seed in range(5):
        kw = dict(num_jobs=4000, seed=seed)
        smooth = np.diff([j.arrival for j in _trace(TraceConfig(**kw))])
        spiky = np.diff([j.arrival for j in _trace(
            TraceConfig(arrival_burstiness=0.7, **kw))])
        ratios.append(float(spiky.mean() / smooth.mean()))
        cvs.append(float(spiky.std() / spiky.mean()))
    assert np.mean(ratios) == pytest.approx(1.0, abs=0.1)
    assert min(cvs) > 1.3  # markedly burstier than Poisson's CV=1


def test_priority_levels_assign_uniform_priorities():
    jobs = _trace(TraceConfig(num_jobs=6000, seed=14,
                              priority_levels=3))
    counts = np.bincount([j.priority for j in jobs], minlength=3)
    assert counts.sum() == 6000 and len(counts) == 3
    assert counts.min() > 6000 / 3 * 0.8  # roughly uniform tiers


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
