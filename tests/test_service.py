"""Allocator-as-a-service tests (repro.serve.scheduler + repro.api):
protocol round-trips, pushed topology events, client reconnect,
crash-recovery journal replay, admission control under overload,
simulator-as-client byte-identical parity, and broker sharing between
the daemon and in-process simulation."""
import json

import pytest

from repro.api import (JobShape, Scheduler, SchedulerConfig, Simulator,
                       TraceConfig, generate_trace, make_policy)
from repro.serve.scheduler import (DROPPED, EV_FAULT, EV_MIGRATE,
                                   EV_PREEMPT, EV_RECONFIG, EV_RELEASE,
                                   EV_REPAIR, EV_SETUP, MIGRATED, PLACED,
                                   PREEMPTED, QUEUED, REJECTED,
                                   AllocatorCore)
from repro.sim.fleet import QueryBroker

SMALL = dict(num_xpus=64, cube_n=4)      # one 4^3 cube: trivially full
MEDIUM = dict(num_xpus=512, cube_n=4)    # 8 cubes


def small_scheduler(**kw):
    return Scheduler(SchedulerConfig(policy="rfold", policy_kw=SMALL, **kw))


# ---------------------------------------------------------- round-trips
def test_submit_place_done_roundtrip():
    with small_scheduler() as s:
        r = s.submit((4, 4, 4))
        assert r["outcome"] == PLACED
        assert r["placement"]["shape"] == [4, 4, 4]
        st = s.status()
        assert st["busy_xpus"] == 64 and st["allocated"] == 1
        d = s.done(r["job_id"])
        assert d["ok"] and d["started"] == []
        assert s.status()["busy_xpus"] == 0


def test_fifo_queue_and_drain_on_done():
    with small_scheduler() as s:
        first = s.submit((4, 4, 4))
        second = s.submit((2, 2, 2))
        assert first["outcome"] == PLACED
        assert second["outcome"] == QUEUED  # head-of-line: cluster full
        d = s.done(first["job_id"])
        assert [x["job_id"] for x in d["started"]] == [second["job_id"]]
        assert d["started"][0]["outcome"] == PLACED


def test_infeasible_shape_dropped():
    with small_scheduler() as s:
        r = s.submit((100, 1, 1))  # 100 > 64 XPUs: never placeable
        assert r["outcome"] == DROPPED
        assert s.status()["queue_depth"] == 0


def test_duplicate_and_unknown_ids_error():
    with small_scheduler() as s:
        r = s.submit((4, 4, 4), job_id=7)
        assert r["outcome"] == PLACED
        with pytest.raises(RuntimeError, match="already known"):
            s.submit((2, 2, 2), job_id=7)
        with pytest.raises(RuntimeError, match="not known"):
            s.done(99)


def test_cancel_while_queued():
    with small_scheduler() as s:
        s.submit((4, 4, 4))
        q = s.submit((4, 4, 4))
        assert q["outcome"] == QUEUED
        d = s.done(q["job_id"])  # cancel the queued job
        assert d["ok"] and s.status()["queue_depth"] == 0


def test_bad_requests_keep_daemon_alive():
    with small_scheduler() as s:
        with pytest.raises(RuntimeError, match="unknown op"):
            s.client.call("frobnicate")
        with pytest.raises(RuntimeError, match="shape"):
            s.client.call("submit", shape=[4, 4])
        assert s.status()["ok"]  # daemon survived both


# -------------------------------------------------------------- events
def test_setup_reconfig_release_events():
    with Scheduler(SchedulerConfig(policy="rfold",
                                   policy_kw=MEDIUM)) as s:
        # 128 XPUs across 2 chained cubes: reconfiguration guaranteed.
        r = s.submit((8, 4, 4))
        assert r["outcome"] == PLACED
        s.done(r["job_id"])
        names = [e["event"] for e in s.events(max_wait=2.0)]
        assert names == [EV_SETUP, EV_RECONFIG, EV_RELEASE]


def test_single_cube_job_emits_no_reconfig():
    with small_scheduler() as s:
        r = s.submit((2, 2, 2))
        s.done(r["job_id"])
        evs = s.events(max_wait=2.0)
        assert [e["event"] for e in evs] == [EV_SETUP, EV_RELEASE]
        assert evs[1]["reconfigured"] is False


def test_events_carry_placement_detail():
    with small_scheduler() as s:
        s.submit((4, 4, 4))
        ev = s.events(max_wait=2.0)[0]
        assert ev["event"] == EV_SETUP
        assert "fold" in ev["detail"]
        assert ev["detail"]["cubes"] == [0]  # which cubes got wired up


def test_unsubscribed_client_gets_no_events():
    with small_scheduler() as s:
        other = s.new_client(subscribe=False)
        s.submit((2, 2, 2))
        assert s.events(max_wait=1.0)  # the subscribed handle sees them
        assert other.events(max_wait=0.2) == []
        other.close()


# ----------------------------------------------------------- reconnect
def test_client_reconnect_resumes_session():
    with small_scheduler() as s:
        r = s.submit((4, 4, 4))
        c = s.new_client()
        assert c.status()["allocated"] == 1
        c.close()
        c.connect()  # daemon state is server-side: nothing lost
        assert c.status()["allocated"] == 1
        c.done(r["job_id"])
        assert c.status()["allocated"] == 0
        c.close()


# ----------------------------------------------------------- admission
def test_admission_rejects_when_queue_full():
    with small_scheduler(max_queue=2) as s:
        assert s.submit((4, 4, 4))["outcome"] == PLACED
        assert s.submit((4, 4, 4))["outcome"] == QUEUED
        assert s.submit((4, 4, 4))["outcome"] == QUEUED
        r = s.submit((4, 4, 4))
        assert r["outcome"] == REJECTED
        # Rejection is stateless: no id consumed, no journal entry.
        st = s.status()
        assert st["queue_depth"] == 2 and st["journal_ops"] == 3


def test_rejected_submits_not_replayed(tmp_path):
    cfg = SchedulerConfig(policy="rfold", policy_kw=SMALL, max_queue=1,
                          checkpoint_dir=str(tmp_path), checkpoint_every=1)
    with Scheduler(cfg) as s:
        s.submit((4, 4, 4))
        s.submit((4, 4, 4))
        assert s.submit((4, 4, 4))["outcome"] == REJECTED
        digest = s.status()["state_digest"]
    s2 = Scheduler(cfg).start()
    try:
        st = s2.status()
        assert st["state_digest"] == digest and st["journal_ops"] == 2
    finally:
        s2.stop()


# ------------------------------------------------------ crash recovery
def test_crash_recovery_byte_identical(tmp_path):
    cfg = SchedulerConfig(policy="rfold", policy_kw=MEDIUM,
                          checkpoint_dir=str(tmp_path), checkpoint_every=1)
    s = Scheduler(cfg).start()
    ids = [s.submit((4, 4, 4))["job_id"] for _ in range(6)]
    s.done(ids[2])
    digest, ops = (s.status()[k] for k in ("state_digest", "journal_ops"))
    s.kill()  # crash: no final checkpoint written

    s2 = Scheduler(cfg).start()
    try:
        st = s2.status()
        assert st["state_digest"] == digest
        assert st["journal_ops"] == ops
        assert s2._daemon.core.recovered_ops == ops
        # And the recovered daemon keeps allocating with fresh ids.
        r = s2.submit((4, 4, 4))
        assert r["outcome"] == PLACED and r["job_id"] not in ids
    finally:
        s2.stop()


def test_graceful_stop_checkpoints_without_cadence(tmp_path):
    """checkpoint_every=0 disables periodic snapshots; the final
    checkpoint on graceful shutdown still persists everything."""
    cfg = SchedulerConfig(policy="rfold", policy_kw=SMALL,
                          checkpoint_dir=str(tmp_path), checkpoint_every=0)
    with Scheduler(cfg) as s:
        s.submit((4, 4, 4))
        digest = s.status()["state_digest"]
    core = AllocatorCore.recover(cfg)
    assert core.state_digest() == digest and core.recovered_ops == 1


def test_changed_config_refuses_stale_journal(tmp_path):
    cfg = SchedulerConfig(policy="rfold", policy_kw=SMALL,
                          checkpoint_dir=str(tmp_path), checkpoint_every=1)
    with Scheduler(cfg) as s:
        s.submit((4, 4, 4))
    other = SchedulerConfig(policy="rfold", policy_kw=SMALL, backfill=True,
                            checkpoint_dir=str(tmp_path))
    assert cfg.fingerprint() != other.fingerprint()
    core = AllocatorCore.recover(other)
    assert core.recovered_ops == 0 and not core.journal


def test_fingerprint_ignores_transport_fields(tmp_path):
    a = SchedulerConfig(policy="rfold", port=1234, checkpoint_every=8)
    b = SchedulerConfig(policy="rfold", port=5678, checkpoint_every=99,
                        host="0.0.0.0")
    assert a.fingerprint() == b.fingerprint()


def test_midtrace_restart_matches_uninterrupted_run(tmp_path):
    """Daemon killed mid-trace; the recovered daemon finishes the op
    stream and lands on the same final state as one that never died."""
    ops = ([("submit", (4, 4, 4))] * 5 + [("done", 1)]
           + [("submit", (2, 2, 2))] * 3 + [("done", 3), ("done", 0)])

    def play(sched, stream):
        ids = {}
        for i, (kind, arg) in enumerate(stream):
            if kind == "submit":
                ids[i] = sched.submit(arg)["job_id"]
            else:
                sched.done(arg)

    cfg = SchedulerConfig(policy="rfold", policy_kw=MEDIUM,
                          checkpoint_dir=str(tmp_path), checkpoint_every=1)
    s = Scheduler(cfg).start()
    play(s, ops[:6])
    s.kill()
    s = Scheduler(cfg).start()
    play(s, ops[6:])
    interrupted = s.status()["state_digest"]
    s.stop()

    with Scheduler(SchedulerConfig(policy="rfold",
                                   policy_kw=MEDIUM)) as ref:
        play(ref, ops)
        assert ref.status()["state_digest"] == interrupted


# ------------------------------------------- simulator-as-client parity
def _job_record(jobs):
    return json.dumps(
        [[j.job_id, j.start, j.finish, j.dropped, j.slowdown,
          j.placement_meta] for j in jobs],
        sort_keys=True, default=list)


@pytest.mark.parametrize("policy,kw", [
    ("firstfit", dict(dims=(8, 8, 8))),
    ("folding", dict(dims=(8, 8, 8))),
    ("reconfig", MEDIUM),
    ("rfold", MEDIUM),
    ("rfold_be", MEDIUM),
])
def test_simulator_as_client_byte_identical(policy, kw):
    trace_cfg = TraceConfig(num_jobs=40, cluster_xpus=512, size_max=512,
                            seed=3)
    local = Simulator(make_policy(policy, **kw),
                      generate_trace(trace_cfg)).run()
    with Scheduler(SchedulerConfig(policy=policy, policy_kw=kw)) as s:
        remote = Simulator(s.remote_policy(),
                           generate_trace(trace_cfg)).run()
    assert _job_record(remote.jobs) == _job_record(local.jobs)


def test_remote_policy_contract():
    with small_scheduler() as s:
        pol = s.remote_policy()
        assert pol.name == "rfold" and pol.num_xpus == 64
        assert pol.can_ever_place(JobShape((4, 4, 4)))
        assert not pol.can_ever_place(JobShape((100, 1, 1)))
        p = pol.try_place(0, JobShape((2, 2, 2)))
        assert p.job_id == 0 and p.shape.dims == (2, 2, 2)
        assert isinstance(p.broken_rings, tuple)
        assert pol.try_place(1, JobShape((4, 4, 4))) is None  # full now
        assert pol.utilization() == pytest.approx(8 / 64)
        pol.release(0)
        assert pol.busy_xpus == 0


# ------------------------------------------------------- broker sharing
def test_daemon_shares_query_broker():
    """The daemon registers as one more broker client: its placement
    queries ride the same batched engine path as fleet simulation, and
    results match the unshared daemon bit-for-bit."""
    broker = QueryBroker("numpy", quorum=0)  # drain mode: solo-safe
    with Scheduler(SchedulerConfig(policy="rfold", policy_kw=MEDIUM,
                                   engine="numpy"),
                   mask_client=broker) as shared, \
            Scheduler(SchedulerConfig(policy="rfold",
                                      policy_kw=MEDIUM)) as plain:
        for sched in (shared, plain):
            for dims in [(8, 4, 4), (2, 2, 2), (16, 1, 1)]:
                sched.submit(dims)
        assert (shared.status()["state_digest"]
                == plain.status()["state_digest"])
    assert broker.stats.requests > 0  # daemon queries really brokered


# ------------------------------------------------- chaos ops (PR 8)
def medium_scheduler(**kw):
    return Scheduler(SchedulerConfig(policy="rfold", policy_kw=MEDIUM,
                                     **kw))


def test_preempt_roundtrip_requeues_at_head():
    with medium_scheduler() as s:
        a = s.submit((4, 4, 4))
        b = s.submit((2, 2, 2))
        assert a["outcome"] == b["outcome"] == PLACED
        r = s.preempt(a["job_id"])
        assert r["outcome"] == PREEMPTED
        st = s.status()
        assert st["queue_depth"] == 1 and st["allocated"] == 1
        # deliberately NOT auto-drained: the head would re-place into
        # its own hole. The next scheduling point re-places it.
        d = s.done(b["job_id"])
        assert [x["job_id"] for x in d["started"]] == [a["job_id"]]
        evs = [e["event"] for e in s.events(max_wait=2.0)]
        assert EV_PREEMPT in evs


def test_preempt_requires_allocation():
    with medium_scheduler() as s:
        q = s.submit((4, 4, 4))
        s.preempt(q["job_id"])
        with pytest.raises(RuntimeError, match="not allocated"):
            s.preempt(q["job_id"])  # already queued, not allocated
        with pytest.raises(RuntimeError, match="not"):
            s.preempt(12345)


def test_migrate_replaces_when_space_else_preempts():
    with medium_scheduler() as s:
        a = s.submit((4, 4, 4))
        r = s.migrate(a["job_id"])
        assert r["outcome"] == MIGRATED
        assert r["placement"]["shape"] == [4, 4, 4]
        assert s.status()["allocated"] == 1
        evs = [e["event"] for e in s.events(max_wait=2.0)]
        assert EV_MIGRATE in evs
        # Migration is work-conserving: even in a full cluster the
        # released hole is available to the re-place, so a migrate
        # never degrades an allocated job into a queued one.
        ids = [s.submit((4, 4, 4))["job_id"] for _ in range(7)]
        assert s.status()["busy_xpus"] == 512
        r2 = s.migrate(ids[-1])
        assert r2["outcome"] == MIGRATED
        assert s.status()["queue_depth"] == 0


def test_fault_replan_failure_preempts_victim():
    """When a fault's victims cannot be re-placed (every other cube
    full), the disposition degrades to PREEMPTED: the victim is queued
    at the head, never dropped."""
    with medium_scheduler() as s:
        ids = [s.submit((4, 4, 4))["job_id"] for _ in range(8)]
        assert s.status()["busy_xpus"] == 512
        r = s.fault("node", [(0, 0, 0, 0)])
        assert r["ok"] and len(r["victims"]) == 1
        assert r["victims"][0]["outcome"] == PREEMPTED
        st = s.status()
        assert st["queue_depth"] == 1 and st["allocated"] == 7
        # repair brings the cube back and drains the queued victim
        rep = s.repair("node", [(0, 0, 0, 0)])
        assert [x["job_id"] for x in rep["started"]] == \
            [r["victims"][0]["job_id"]]
        assert s.status()["allocated"] == 8


def test_fault_evicts_and_replans_victims():
    with medium_scheduler() as s:
        a = s.submit((4, 4, 4))
        b = s.submit((2, 4, 8))
        assert a["outcome"] == b["outcome"] == PLACED
        r = s.fault("node", [(0, 0, 0, 0)])
        assert r["ok"] and r["applied"] == [[0, 0, 0, 0]]
        # exactly the job(s) on cube 0 were evicted, each replanned
        assert r["victims"]
        for v in r["victims"]:
            assert v["outcome"] in (PREEMPTED, MIGRATED)
        # plenty of healthy cubes: eviction must not lose capacity
        st = s.status()
        assert st["allocated"] + st["queue_depth"] == 2
        evs = [e["event"] for e in s.events(max_wait=2.0)]
        assert EV_FAULT in evs
        assert EV_MIGRATE in evs or EV_PREEMPT in evs


def test_fault_on_free_nodes_has_no_victims():
    with small_scheduler() as s:
        r = s.fault("node", [(0, 0, 0, 0)])
        assert r["ok"] and r["victims"] == []
        assert r["applied"] == [[0, 0, 0, 0]]
        assert EV_FAULT in [e["event"] for e in s.events(max_wait=2.0)]


def test_repair_restores_capacity_and_drains():
    with small_scheduler() as s:
        s.fault("node", [(0, 0, 0, 0)])
        q = s.submit((4, 4, 4))          # whole cube: blocked by fault
        assert q["outcome"] == QUEUED
        r = s.repair("node", [(0, 0, 0, 0)])
        assert r["ok"] and r["applied"] == [[0, 0, 0, 0]]
        assert [x["job_id"] for x in r["started"]] == [q["job_id"]]
        assert EV_REPAIR in [e["event"] for e in s.events(max_wait=2.0)]


def test_repair_of_never_failed_is_noop():
    with small_scheduler() as s:
        r = s.repair("node", [(0, 1, 2, 3)])
        assert r["ok"] and r["applied"] == []
        assert s.status()["journal_ops"] == 1  # still journaled


def test_ocs_port_fault_over_wire():
    with medium_scheduler() as s:
        a = s.submit((8, 4, 4))  # 2-cube chained job
        assert a["outcome"] == PLACED
        r = s.fault("ocs_port", [0])
        assert r["ok"] and r["applied"] == [0]
        if r["victims"]:  # chained through cube 0: evicted + replanned
            assert all(v["outcome"] in (PREEMPTED, MIGRATED)
                       for v in r["victims"])
        s.repair("ocs_port", [0])
        assert s.status()["ok"]


def test_crash_under_fault_replays_chaos_ops(tmp_path):
    """The chaos ops are journaled as intent and replayed: killing the
    daemon mid-scenario (faults + preempt + migrate + repair in the
    journal, no final checkpoint) must restore a byte-identical state
    digest — including failed masks, cut links and shape bookkeeping."""
    cfg = SchedulerConfig(policy="rfold", policy_kw=MEDIUM,
                          checkpoint_dir=str(tmp_path),
                          checkpoint_every=1)
    s = Scheduler(cfg).start()
    for dims in [(4, 4, 4), (2, 4, 8), (4, 4, 8)]:
        s.submit(dims)  # 256 of 512 XPUs: victims can migrate
    assert s.fault("node", [(0, 0, 0, 0), (1, 0, 0, 0)])["applied"]
    s.fault("ocs_port", [5])
    s.preempt(0)
    s.migrate(1)
    s.repair("node", [(0, 0, 0, 0)])
    before = s.status()
    s.kill()  # crash: no final checkpoint

    s2 = Scheduler(cfg).start()
    try:
        after = s2.status()
        assert after["state_digest"] == before["state_digest"]
        assert after["journal_ops"] == before["journal_ops"]
        # the recovered daemon still knows about the standing fault
        q = s2.submit((4, 4, 4), job_id=900)
        assert q["outcome"] in (PLACED, QUEUED)
    finally:
        s2.stop()
