"""Hypothesis property tests on simulator + allocator invariants."""
from hypothesis import given, settings, strategies as st

from repro.core.allocator import make_policy
from repro.sim.simulator import Simulator
from repro.traces.generator import TraceConfig, generate_trace


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from(["firstfit", "folding"]))
def test_sim_invariants_static(seed, policy):
    cfg = TraceConfig(num_jobs=40, seed=seed, target_load=2.0)
    jobs = generate_trace(cfg)
    pol = make_policy(policy, dims=(8, 8, 8))
    res = Simulator(pol, jobs).run()
    _check_invariants(res, pol)


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 10_000))
def test_sim_invariants_rfold(seed):
    cfg = TraceConfig(num_jobs=30, seed=seed, target_load=2.0)
    jobs = generate_trace(cfg)
    pol = make_policy("rfold", num_xpus=512, cube_n=4)
    res = Simulator(pol, jobs).run()
    _check_invariants(res, pol)
    pol.cluster.check_invariants()


def _check_invariants(res, pol):
    # cluster fully drained at the end
    assert pol.busy_xpus == 0
    for j in res.jobs:
        if j.dropped:
            assert j.start is None
            continue
        if j.finish is None:
            continue
        # causality + runtime >= ideal duration
        assert j.start >= j.arrival
        assert j.finish >= j.start + j.duration - 1e-9
        assert j.jct >= j.duration - 1e-9
    # utilization samples within [0, 1]
    for _, u in res.utilization_samples:
        assert -1e-9 <= u <= 1 + 1e-9
    # FIFO order among started jobs that queued: a job can only start
    # before an earlier-arriving job if that job was already running
    started = [j for j in res.jobs if j.start is not None]
    started.sort(key=lambda j: j.arrival)
    for i in range(1, len(started)):
        prev, cur = started[i - 1], started[i]
        assert cur.start >= prev.start - 1e-9, "FIFO start order violated"


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 100_000))
def test_rfold_never_worse_jcr_than_reconfig(seed):
    """Folding only adds options: RFold's JCR dominates Reconfig's on
    identical traces/cluster."""
    cfg = TraceConfig(num_jobs=25, seed=seed)
    jobs_a = generate_trace(cfg)
    jobs_b = generate_trace(cfg)
    rc = make_policy("reconfig", num_xpus=512, cube_n=4)
    rf = make_policy("rfold", num_xpus=512, cube_n=4)
    jcr_rc = Simulator(rc, jobs_a).run().jcr
    jcr_rf = Simulator(rf, jobs_b).run().jcr
    assert jcr_rf >= jcr_rc - 1e-9
