"""StaticTorus / ReconfigTorus occupancy, exclusivity, fitmask."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import fitmask
from repro.core.folding import enumerate_folds
from repro.core.geometry import JobShape
from repro.core.reconfig import ReconfigTorus
from repro.core.torus import StaticTorus, canon_link


# ----------------------------------------------------------------- fitmask
def test_fitmask_empty_grid():
    occ = np.zeros((4, 4, 4), bool)
    assert fitmask.first_fit_origin(occ, (2, 2, 2)) == (0, 0, 0)
    assert fitmask.count_fits(occ, (4, 4, 4)) == 1
    assert fitmask.count_fits(occ, (5, 1, 1)) == 0


def test_fitmask_blocked():
    occ = np.zeros((4, 4, 4), bool)
    occ[0, 0, 0] = True
    assert fitmask.first_fit_origin(occ, (4, 4, 4)) is None
    assert fitmask.first_fit_origin(occ, (1, 1, 1)) == (0, 0, 1)


@settings(max_examples=50, deadline=None)
@given(st.integers(0, 1000), st.tuples(st.integers(1, 5), st.integers(1, 5),
                                       st.integers(1, 5)))
def test_fitmask_matches_bruteforce(seed, box):
    rng = np.random.default_rng(seed)
    occ = rng.uniform(size=(6, 6, 6)) < 0.3
    m = fitmask.fit_mask(occ, box)
    a, b, c = box
    for i in range(6 - a + 1):
        for j in range(6 - b + 1):
            for k in range(6 - c + 1):
                assert m[i, j, k] == (not occ[i:i+a, j:j+b, k:k+c].any())


# ------------------------------------------------------------ static torus
def test_static_commit_release_invariants():
    t = StaticTorus((8, 8, 8))
    a1 = t.commit_box(1, (0, 0, 0), (2, 2, 2))
    assert t.busy_xpus == 8
    with pytest.raises(ValueError):
        t.commit_box(2, (1, 1, 1), (2, 2, 2))  # overlap
    t.check_invariants()
    t.release(1)
    assert t.busy_xpus == 0
    t.check_invariants()


def test_static_box_links_include_wrap_on_full_span():
    t = StaticTorus((4, 4, 4))
    a = t.commit_box(1, (0, 0, 0), (4, 1, 1))
    wrap_link = canon_link((0, 0, 0), (3, 0, 0))
    assert wrap_link in a.links
    t.check_invariants()


def test_link_exclusivity_enforced():
    t = StaticTorus((8, 8, 8))
    t.commit(1, [(0, 0, 0), (0, 0, 1)], [canon_link((0, 0, 0), (0, 0, 1))])
    with pytest.raises(ValueError):
        t.commit(2, [(0, 0, 2)], [canon_link((0, 0, 0), (0, 0, 1))])


# ---------------------------------------------------------- reconfig torus
def test_reconfig_place_within_one_cube():
    rt = ReconfigTorus(512, 4)  # 8 cubes
    fold = enumerate_folds(JobShape((2, 2, 2)), max_dim=32)[0]
    plan = rt.place_fold(fold)
    assert plan is not None and plan.num_cubes == 1
    assert plan.num_ocs_links == 0
    rt.commit(1, plan)
    rt.check_invariants()
    rt.release(1)
    assert rt.busy_xpus == 0


def test_reconfig_chain_with_wrap():
    rt = ReconfigTorus(512, 4)
    folds = [f for f in enumerate_folds(JobShape((8, 4, 4)), max_dim=32)
             if f.kind == "identity" and f.box == (8, 4, 4)]
    plan = rt.place_fold(folds[0])
    assert plan is not None
    assert plan.num_cubes == 2
    assert plan.wrap == (True, True, True)  # 8 = 2 cubes, full extents
    assert not plan.broken_rings
    # OCS links: chain crossing + wrap closure on x: 2*16; wrap loops y,z
    assert plan.num_ocs_links == 2 * 16 + 16 * 2 * 2


def test_reconfig_alignment_constraint():
    """Misaligned free space cannot host a chained job: fill one cube's
    x=0..1 rows so only offset-2 space remains, then ask for a 2-cube
    chain that needs offset 0 in both."""
    rt = ReconfigTorus(128, 4)  # 2 cubes
    rt.occ[0, :2, :, :] = True  # cube 0: x in 0..1 busy
    rt.bump_epoch()             # direct occ writes must be announced
    folds = [f for f in enumerate_folds(JobShape((8, 4, 4)), max_dim=8)
             if f.kind == "identity"]
    plan = rt.place_fold(folds[0])
    assert plan is None  # needs both cubes fully free


def test_reconfig_too_large_rejected():
    rt = ReconfigTorus(512, 4)
    folds = enumerate_folds(JobShape((64, 1, 1)), max_dim=2048)
    ident = [f for f in folds if f.kind == "identity"]
    # 64x1x1 chain needs 16 cubes; only 8 exist
    assert rt.place_fold(ident[0]) is None


def test_reconfig_dedicated_mode_strands():
    rt = ReconfigTorus(128, 4, dedicate_chained=True)
    folds = [f for f in enumerate_folds(JobShape((8, 1, 1)), max_dim=8)
             if f.kind == "identity"]
    plan = rt.place_fold(folds[0])
    rt.commit(1, plan)
    # both cubes dedicated: nothing else placeable even though 120 free
    fold2 = [f for f in enumerate_folds(JobShape((2, 2, 2)), max_dim=8)
             if f.kind == "identity"]
    assert rt.place_fold(fold2[0]) is None
    rt.check_invariants()
    rt.release(1)
    assert rt.place_fold(fold2[0]) is not None


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000))
def test_reconfig_random_commit_release_invariants(seed):
    rng = np.random.default_rng(seed)
    rt = ReconfigTorus(512, 4)
    live = {}
    jid = 0
    for _ in range(30):
        if live and rng.uniform() < 0.4:
            k = list(live)[rng.integers(len(live))]
            rt.release(k)
            live.pop(k)
        else:
            dims = tuple(int(rng.integers(1, 9)) for _ in range(3))
            folds = enumerate_folds(JobShape(dims), max_dim=32)
            plan = None
            for f in folds:
                plan = rt.place_fold(f)
                if plan:
                    break
            if plan:
                rt.commit(jid, plan)
                live[jid] = True
                jid += 1
        rt.check_invariants()
