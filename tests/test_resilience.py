"""Resilience tests (PR 9): WAL framing + torn-tail truncation,
checkpoint-shard corruption, idempotent retries across daemon crashes,
lease expiry dispositions, SIGKILL crash-loop recovery, broker stepper
watchdog, and the engine failover chain."""
import os
import random
import signal
import struct
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

import repro
from repro.api import (Scheduler, SchedulerClient, SchedulerConfig,
                       failover_candidates)
from repro.eval.runner import record_crc, shard_dir, verify_record
from repro.kernels.fitmask import ops
from repro.serve.scheduler import PLACED, protocol
from repro.serve.scheduler.journal import (MAGIC, JournalWriter,
                                           recover_journal)
from repro.sim.fleet import QueryBroker

SMALL = dict(num_xpus=64, cube_n=4)      # one 4^3 cube: trivially full
MEDIUM = dict(num_xpus=512, cube_n=4)    # 8 cubes


# ------------------------------------------------------------ WAL unit
def _write_wal(path, records):
    w = JournalWriter(path, fsync=False)
    for rec in records:
        w.append(rec)
    w.close()


def test_wal_roundtrip(tmp_path):
    path = str(tmp_path / "a.wal")
    recs = [{"op": "submit", "i": i} for i in range(5)]
    _write_wal(path, recs)
    got, truncated = recover_journal(path)
    assert got == recs and not truncated


def test_wal_missing_file_is_empty_not_error(tmp_path):
    assert recover_journal(str(tmp_path / "never.wal")) == ([], False)


def test_wal_torn_tail_truncated_and_repaired(tmp_path):
    path = str(tmp_path / "a.wal")
    recs = [{"op": "submit", "i": i} for i in range(3)]
    _write_wal(path, recs)
    size = os.path.getsize(path)
    with open(path, "ab") as f:   # SIGKILL mid-append: half a frame
        f.write(struct.pack("<II", 999, 0) + b'{"op": "half')
    got, truncated = recover_journal(path)
    assert got == recs and truncated
    # Repaired back to the last good offset: appends are well-formed.
    assert os.path.getsize(path) == size
    w = JournalWriter(path, fsync=False)
    w.append({"op": "done"})
    w.close()
    assert recover_journal(path) == (recs + [{"op": "done"}], False)


def test_wal_bitflip_stops_at_corrupt_record(tmp_path):
    path = str(tmp_path / "a.wal")
    recs = [{"op": "submit", "i": i} for i in range(5)]
    _write_wal(path, recs)
    data = bytearray(open(path, "rb").read())
    # Walk the frames to the payload of record 2 and flip one bit.
    off = len(MAGIC)
    for _ in range(2):
        length, _crc = struct.unpack_from("<II", data, off)
        off += 8 + length
    data[off + 8 + 2] ^= 0x40
    with open(path, "wb") as f:
        f.write(data)
    got, truncated = recover_journal(path)
    assert got == recs[:2] and truncated


def test_wal_foreign_header_ignored_wholesale(tmp_path):
    path = str(tmp_path / "a.wal")
    with open(path, "wb") as f:
        f.write(b"GARBAGE!" + b"\x01" * 32)
    assert recover_journal(path) == ([], True)
    # Repair leaves a well-formed empty journal behind.
    assert recover_journal(path) == ([], False)


# ------------------------------------------- checkpoint-shard bit-rot
def test_eval_checkpoint_crc_detects_bitflip():
    rec = {"fingerprint": "x", "metrics": {"jcr": 0.5}}
    rec["_crc32"] = record_crc(rec)
    assert verify_record(rec)
    rec["metrics"]["jcr"] = 0.6
    assert not verify_record(rec)
    rec["_crc32"] = "not-a-crc"
    assert not verify_record(rec)


def _daemon_cfg(tmp_path, **kw):
    kw.setdefault("checkpoint_every", 1000)   # keep ops in the WAL
    return SchedulerConfig(policy="rfold", policy_kw=MEDIUM,
                           checkpoint_dir=str(tmp_path / "ckpt"), **kw)


def _snapshot_path(cfg):
    return os.path.join(shard_dir(cfg.checkpoint_dir, cfg.fingerprint()),
                        cfg.checkpoint_name())


@pytest.mark.parametrize("corrupt", ["bitflip", "truncate"])
def test_corrupt_snapshot_never_replays(tmp_path, corrupt):
    cfg = _daemon_cfg(tmp_path, checkpoint_every=1)
    with Scheduler(cfg) as s:
        s.submit((4, 4, 4))
        assert s.status()["journal_ops"] == 1
    path = _snapshot_path(cfg)
    data = bytearray(open(path, "rb").read())
    if corrupt == "bitflip":
        data[len(data) // 2] ^= 0xFF
    else:
        data = data[:len(data) // 2]
    with open(path, "wb") as f:
        f.write(bytes(data))
    # A corrupt shard must start fresh (never crash, never half-replay).
    s2 = Scheduler(cfg).start()
    st = s2.status()
    s2.kill()
    assert st["journal_ops"] == 0 and st["allocated"] == 0


def test_daemon_truncated_wal_recovers_acked_prefix(tmp_path):
    cfg = _daemon_cfg(tmp_path)
    s = Scheduler(cfg).start()
    for dims in [(4, 4, 4), (2, 4, 8), (4, 4, 8)]:
        s.submit(dims)
    n_ops = s.status()["journal_ops"]
    s.kill()   # crash: recovery is WAL-only (no final snapshot)
    core_like = cfg.checkpoint_name() + ".wal"
    wal = os.path.join(shard_dir(cfg.checkpoint_dir, cfg.fingerprint()),
                       core_like)
    with open(wal, "rb") as f:
        data = f.read()
    with open(wal, "wb") as f:   # tear the last record mid-payload
        f.write(data[:-5])
    s2 = Scheduler(cfg).start()
    st = s2.status()
    s2.kill()
    assert st["journal_ops"] == n_ops - 1
    assert st["resilience"]["wal_truncated"] == 1
    assert st["resilience"]["wal_tail_ops"] == n_ops - 1
    # The recovered state is byte-identical to a run that only ever
    # saw the surviving prefix.
    cfg2 = SchedulerConfig(policy="rfold", policy_kw=MEDIUM,
                           checkpoint_dir=str(tmp_path / "control"))
    s3 = Scheduler(cfg2).start()
    for dims in [(4, 4, 4), (2, 4, 8)]:
        s3.submit(dims)
    digest = s3.status()["state_digest"]
    s3.kill()
    assert st["state_digest"] == digest


# --------------------------------------------------- idempotent retry
class _Raw:
    """Wire driver with a fixed client id and explicit request_ids, so
    a byte-identical resend is the genuine retry path."""

    def __init__(self, address, cid="raw"):
        self._c = SchedulerClient(address, client_id=cid, max_retries=0)
        self._cid = cid

    def send(self, i, msg):
        wire = dict(msg, seq=i, client=self._cid,
                    request_id=f"{self._cid}:{i}")
        self._c._sock.sendall(protocol.encode(wire))
        return self._c._await_reply(i, 30.0)

    def close(self):
        self._c.close()


def test_retry_same_request_id_applied_once():
    s = Scheduler(SchedulerConfig(policy="rfold",
                                  policy_kw=MEDIUM)).start()
    c = _Raw(s.address)
    try:
        r1 = c.send(0, {"op": "submit", "shape": [4, 4, 4]})
        assert r1["outcome"] == PLACED
        r2 = c.send(0, {"op": "submit", "shape": [4, 4, 4]})
        assert r2["job_id"] == r1["job_id"]
        st = c.send(1, {"op": "status"})
        assert st["allocated"] == 1   # applied exactly once
        assert st["resilience"]["dedup_hits"] >= 1
    finally:
        c.close()
        s.stop()


def test_dedup_cache_survives_crash(tmp_path):
    cfg = _daemon_cfg(tmp_path)
    s = Scheduler(cfg).start()
    c = _Raw(s.address)
    r1 = c.send(0, {"op": "submit", "shape": [4, 4, 4]})
    c.close()
    s.kill()
    # Replay repopulates the dedup cache from the journaled rids: the
    # retry a reconnecting client sends must still be exactly-once.
    s2 = Scheduler(cfg).start()
    c2 = _Raw(s2.address)
    try:
        before = c2.send(1, {"op": "status"})
        r2 = c2.send(0, {"op": "submit", "shape": [4, 4, 4]})
        after = c2.send(2, {"op": "status"})
        assert r2["job_id"] == r1["job_id"]
        assert after["state_digest"] == before["state_digest"]
        assert after["resilience"]["dedup_hits"] >= 1
    finally:
        c2.close()
        s2.stop()


# --------------------------------------------------------- liveness
def _await_expiry(s, deadline=10.0):
    t0 = time.monotonic()
    while time.monotonic() - t0 < deadline:
        st = s.status()
        if st["resilience"]["lease_expiries"] >= 1:
            return st
        time.sleep(0.05)
    raise AssertionError("lease never expired")


def test_lease_expiry_requeues_dead_clients_jobs():
    cfg = SchedulerConfig(policy="rfold", policy_kw=SMALL,
                          lease_timeout=0.3, lease_policy="requeue")
    s = Scheduler(cfg).start()
    try:
        c = _Raw(s.address, cid="doomed")
        r = c.send(0, {"op": "submit", "shape": [4, 4, 4]})
        assert r["outcome"] == PLACED
        c.close()   # no more heartbeats: the lease lapses
        st = _await_expiry(s)
        assert st["allocated"] == 0
        assert st["queue_depth"] == 1   # work-preserving eviction
    finally:
        s.stop()


def test_lease_expiry_release_frees_capacity():
    cfg = SchedulerConfig(policy="rfold", policy_kw=SMALL,
                          lease_timeout=0.3, lease_policy="release")
    s = Scheduler(cfg).start()
    try:
        c = _Raw(s.address, cid="doomed")
        assert c.send(0, {"op": "submit",
                          "shape": [4, 4, 4]})["outcome"] == PLACED
        c.close()
        st = _await_expiry(s)
        assert st["allocated"] == 0 and st["queue_depth"] == 0
        assert st["busy_xpus"] == 0
    finally:
        s.stop()


def test_facade_heartbeat_keeps_own_lease_alive():
    cfg = SchedulerConfig(policy="rfold", policy_kw=SMALL,
                          lease_timeout=0.3)
    s = Scheduler(cfg).start()
    try:
        assert s.submit((4, 4, 4))["outcome"] == PLACED
        time.sleep(1.0)   # several lease periods
        st = s.status()
        assert st["allocated"] == 1
        assert st["resilience"]["lease_expiries"] == 0
    finally:
        s.stop()


# ----------------------------------------------- client reconnection
def test_client_reconnect_clears_partial_buffer():
    s = Scheduler(SchedulerConfig(policy="rfold",
                                  policy_kw=SMALL)).start()
    c = SchedulerClient(s.address)
    try:
        assert c.status()["ok"]
        c._buf = b'{"torn": '   # half a frame from a dying connection
        c.connect()             # reconnect must not parse stale bytes
        assert c._buf == b""
        assert c.status()["num_xpus"] == 64
    finally:
        c.close()
        s.stop()


# ------------------------------------------------ SIGKILL crash loop
_CHILD = """\
import sys, time
from repro.api import Scheduler, SchedulerConfig
cfg = SchedulerConfig(policy="rfold",
                      policy_kw=dict(num_xpus=512, cube_n=4),
                      checkpoint_dir=sys.argv[1], checkpoint_every=3)
s = Scheduler(cfg).start()
for i, dims in enumerate({shapes!r}):
    s.submit(dims)
    print("acked", i, flush=True)
    time.sleep(0.05)
s.kill()
"""

_SHAPES = [(4, 4, 4), (2, 4, 8), (4, 4, 8), (2, 2, 4),
           (4, 4, 4), (2, 4, 4), (4, 8, 4), (2, 2, 2)]


def test_sigkill_midstream_recovers_acked_prefix(tmp_path):
    """SIGKILL the daemon process at a seeded point mid-stream; a
    fresh daemon on the same store must hold every acknowledged op
    (fsync-before-ack) and match a control run over that prefix."""
    ckpt = str(tmp_path / "ckpt")
    script = tmp_path / "child.py"
    script.write_text(_CHILD.format(shapes=_SHAPES))
    kill_after = random.Random(7).randrange(2, 6)
    src = os.path.dirname(list(repro.__path__)[0])
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (src, env.get("PYTHONPATH")) if p)
    proc = subprocess.Popen([sys.executable, str(script), ckpt],
                            stdout=subprocess.PIPE, text=True, env=env)
    acked = 0
    try:
        for line in proc.stdout:
            if line.startswith("acked"):
                acked += 1
                if acked == kill_after:
                    os.kill(proc.pid, signal.SIGKILL)
                    break
        proc.wait(timeout=60)
    finally:
        proc.stdout.close()
        if proc.poll() is None:
            proc.kill()
            proc.wait()
    assert acked == kill_after

    cfg = SchedulerConfig(policy="rfold", policy_kw=MEDIUM,
                          checkpoint_dir=ckpt, checkpoint_every=3)
    s2 = Scheduler(cfg).start()
    st = s2.status()
    s2.kill()
    # Every acked submit is durable; at most the one op in flight at
    # the kill may additionally have committed.
    assert acked <= st["journal_ops"] <= acked + 1

    control = SchedulerConfig(policy="rfold", policy_kw=MEDIUM,
                              checkpoint_dir=str(tmp_path / "control"))
    s3 = Scheduler(control).start()
    for dims in _SHAPES[:st["journal_ops"]]:
        s3.submit(dims)
    digest = s3.status()["state_digest"]
    s3.kill()
    assert st["state_digest"] == digest


# ------------------------------------------------- broker watchdog
def _occ(rng, b, cell=(6, 6, 6)):
    return rng.random((b,) + cell) < 0.4


def test_dead_stepper_never_hangs_flush():
    """A registered stepper that dies before submitting would park the
    all-active flush trigger forever; the watchdog must reap it so the
    surviving stepper's query still completes — bit-exactly."""
    broker = QueryBroker("numpy")
    rng = np.random.default_rng(0)
    occ = _occ(rng, 3)
    boxes = ((2, 2, 1), (3, 1, 2), (6, 6, 6))
    ref = np.asarray(ops.get_engine("numpy").multibox(occ, boxes))

    def doomed():
        broker.register(thread=threading.current_thread())
        raise RuntimeError("stepper crash before first query")

    t_dead = threading.Thread(target=doomed, daemon=True)
    t_dead.start()
    t_dead.join()

    results = {}

    def survivor():
        broker.register(thread=threading.current_thread())
        try:
            results["planes"] = broker.multibox(occ, boxes)
        finally:
            broker.deactivate()

    t = threading.Thread(target=survivor, daemon=True)
    t.start()
    t.join(timeout=30)
    assert not t.is_alive(), "flush hung behind a dead stepper"
    np.testing.assert_array_equal(results["planes"], ref)
    assert broker.stats.steppers_reaped == 1


def test_stepper_dying_between_queries_shrinks_quorum():
    """Two live steppers coalesce; after one dies mid-run the other's
    next query must flush alone instead of waiting for the ghost."""
    broker = QueryBroker("numpy")
    rng = np.random.default_rng(1)
    boxes = ((2, 2, 2),)
    barrier = threading.Barrier(2, timeout=30)
    out = {}

    def stepper(name, rounds):
        broker.register(thread=threading.current_thread())
        barrier.wait()   # both registered before either's first query
        try:
            for r in range(rounds):
                occ = _occ(np.random.default_rng(hash((name, r)) % 997),
                           2)
                out[(name, r)] = np.asarray(
                    broker.multibox(occ, boxes)).copy()
        finally:
            if rounds > 1:
                broker.deactivate()
            # rounds == 1: die registered — the watchdog must reap us.

    t1 = threading.Thread(target=stepper, args=("long", 3), daemon=True)
    t2 = threading.Thread(target=stepper, args=("short", 1), daemon=True)
    t1.start(), t2.start()
    t1.join(timeout=30), t2.join(timeout=30)
    assert not t1.is_alive() and not t2.is_alive()
    assert broker.stats.steppers_reaped == 1
    for (name, r), planes in out.items():
        occ = _occ(np.random.default_rng(hash((name, r)) % 997), 2)
        np.testing.assert_array_equal(
            planes, np.asarray(ops.get_engine("numpy")
                               .multibox(occ, boxes)))


# ------------------------------------------------- engine failover
def test_failover_candidates_chain():
    assert failover_candidates("pallas") == ("jax", "numpy")
    assert failover_candidates("jax") == ("numpy",)
    assert failover_candidates("numpy") == ()
    assert failover_candidates("no-such-engine") == ()


def test_injected_faults_degrade_to_numpy_with_parity():
    """Two injected faults exhaust the attempt + the single retry on
    the jax engine; the broker must adopt numpy and answer the same
    query bit-exactly, recording the failover in its stats."""
    broker = QueryBroker("jax")
    rng = np.random.default_rng(2)
    occ = _occ(rng, 4)
    boxes = ((2, 2, 1), (3, 1, 2))
    ref = np.asarray(ops.get_engine("numpy").multibox(occ, boxes))
    broker.inject_engine_faults(2)
    np.testing.assert_array_equal(broker.multibox(occ, boxes), ref)
    assert broker.engine_name == "numpy"
    assert broker.stats.engine_retries == 1
    assert broker.stats.engine_failovers == 1
    assert broker.stats.failover_engine == "numpy"
    # Subsequent queries run on the adopted engine without incident.
    np.testing.assert_array_equal(
        broker.free_counts(occ),
        np.asarray(ops.get_engine("numpy").free_counts(occ)))


def test_single_transient_fault_retries_in_place():
    broker = QueryBroker("jax")
    rng = np.random.default_rng(3)
    occ = _occ(rng, 2)
    boxes = ((2, 2, 2),)
    broker.inject_engine_faults(1)
    planes = np.asarray(broker.multibox(occ, boxes))
    np.testing.assert_array_equal(
        planes, np.asarray(ops.get_engine("jax").multibox(occ, boxes)))
    assert broker.engine_name == "jax"   # retry succeeded, no failover
    assert broker.stats.engine_retries == 1
    assert broker.stats.engine_failovers == 0


def test_engine_failure_mid_run_schedules_match_host_oracle():
    """Acceptance: a compiled engine failing mid-simulation degrades
    to numpy and the produced *schedule* is byte-identical to one
    computed against the host oracle from the start."""
    from repro.api import (Simulator, TraceConfig, generate_trace,
                           make_policy)
    from repro.sim.fleet import Fleet, install_mask_client

    cfg = TraceConfig(num_jobs=40, cluster_xpus=512, size_max=512,
                      seed=5)

    def record(result):
        return [[j.job_id, j.start, j.finish, j.dropped, j.slowdown]
                for j in result.jobs]

    ref = record(Simulator(make_policy("rfold", **MEDIUM),
                           generate_trace(cfg)).run())

    fleet = Fleet("jax")
    fleet.broker.inject_engine_faults(2)

    def unit(broker):
        policy = make_policy("rfold", **MEDIUM)
        install_mask_client(policy, broker)
        return Simulator(policy, generate_trace(cfg)).run()

    (res,) = fleet.run([unit])
    assert fleet.broker.engine_name == "numpy"
    assert fleet.broker.stats.engine_failovers == 1
    assert record(res) == ref


def test_custom_engine_instance_is_failover_exempt():
    class Boom:
        def multibox(self, occ, boxes):
            raise RuntimeError("boom")

        def free_counts(self, occ):
            raise RuntimeError("boom")

    broker = QueryBroker(Boom())
    assert broker.engine_name is None
    with pytest.raises(RuntimeError, match="boom"):
        broker.free_counts(_occ(np.random.default_rng(4), 1))
    assert broker.stats.engine_failovers == 0
