"""Resilience tests (PR 9/10): WAL framing + torn-tail truncation,
checkpoint-shard corruption, idempotent retries across daemon crashes,
lease expiry dispositions, SIGKILL crash-loop recovery, broker stepper
watchdog, the engine failover chain — and the replicated scheduler:
standby WAL tailing, epoch-fenced promotion, NOT_LEADER redirects,
stale-reply rejection, sync/async ack modes, heartbeat jitter."""
import os
import random
import signal
import struct
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

import repro
from repro.api import (Scheduler, SchedulerClient, SchedulerConfig,
                       failover_candidates)
from repro.eval.runner import record_crc, shard_dir, verify_record
from repro.kernels.fitmask import ops
from repro.serve.scheduler import PLACED, jittered_interval, protocol
from repro.serve.scheduler.journal import (MAGIC, JournalWriter,
                                           decode_frames, encode_frames,
                                           frame_record, recover_journal)
from repro.sim.fleet import QueryBroker

SMALL = dict(num_xpus=64, cube_n=4)      # one 4^3 cube: trivially full
MEDIUM = dict(num_xpus=512, cube_n=4)    # 8 cubes


# ------------------------------------------------------------ WAL unit
def _write_wal(path, records):
    w = JournalWriter(path, fsync=False)
    for rec in records:
        w.append(rec)
    w.close()


def test_wal_roundtrip(tmp_path):
    path = str(tmp_path / "a.wal")
    recs = [{"op": "submit", "i": i} for i in range(5)]
    _write_wal(path, recs)
    got, truncated = recover_journal(path)
    assert got == recs and not truncated


def test_wal_missing_file_is_empty_not_error(tmp_path):
    assert recover_journal(str(tmp_path / "never.wal")) == ([], False)


def test_wal_torn_tail_truncated_and_repaired(tmp_path):
    path = str(tmp_path / "a.wal")
    recs = [{"op": "submit", "i": i} for i in range(3)]
    _write_wal(path, recs)
    size = os.path.getsize(path)
    with open(path, "ab") as f:   # SIGKILL mid-append: half a frame
        f.write(struct.pack("<II", 999, 0) + b'{"op": "half')
    got, truncated = recover_journal(path)
    assert got == recs and truncated
    # Repaired back to the last good offset: appends are well-formed.
    assert os.path.getsize(path) == size
    w = JournalWriter(path, fsync=False)
    w.append({"op": "done"})
    w.close()
    assert recover_journal(path) == (recs + [{"op": "done"}], False)


def test_wal_bitflip_stops_at_corrupt_record(tmp_path):
    path = str(tmp_path / "a.wal")
    recs = [{"op": "submit", "i": i} for i in range(5)]
    _write_wal(path, recs)
    data = bytearray(open(path, "rb").read())
    # Walk the frames to the payload of record 2 and flip one bit.
    off = len(MAGIC)
    for _ in range(2):
        length, _crc = struct.unpack_from("<II", data, off)
        off += 8 + length
    data[off + 8 + 2] ^= 0x40
    with open(path, "wb") as f:
        f.write(data)
    got, truncated = recover_journal(path)
    assert got == recs[:2] and truncated


def test_frames_roundtrip_and_torn_flag():
    """The wire-side halves of the framing: every intact record comes
    back, a torn trailing frame only sets the flag."""
    recs = [{"op": "submit", "i": i, "shape": [4, 4, i + 1]}
            for i in range(4)]
    blob = encode_frames(recs)
    assert decode_frames(blob) == (recs, False)
    assert decode_frames(blob + frame_record(recs[0])[:7]) == (recs, True)
    assert decode_frames(b"") == ([], False)


def test_torn_tail_every_byte_offset(tmp_path):
    """Exhaustive torn-tail sweep: truncate the WAL at *every* byte
    offset strictly inside the last record; recovery must yield
    exactly the acked prefix (all records but the last), flagged as
    truncated, at every single offset."""
    recs = [{"op": "submit", "i": i, "pad": "x" * (3 * i)}
            for i in range(4)]
    whole = MAGIC + encode_frames(recs)
    last_start = len(MAGIC) + len(encode_frames(recs[:-1]))
    path = str(tmp_path / "torn.wal")
    for cut in range(last_start + 1, len(whole)):
        with open(path, "wb") as f:
            f.write(whole[:cut])
        got, truncated = recover_journal(path, repair=False)
        assert got == recs[:-1], f"cut at byte {cut}"
        assert truncated, f"cut at byte {cut} not flagged"
    # And with repair: the file is truncated back to the acked prefix
    # and a re-recovery is clean.
    with open(path, "wb") as f:
        f.write(whole[:len(whole) - 1])
    assert recover_journal(path, repair=True) == (recs[:-1], True)
    assert os.path.getsize(path) == last_start
    assert recover_journal(path) == (recs[:-1], False)


def test_wal_foreign_header_ignored_wholesale(tmp_path):
    path = str(tmp_path / "a.wal")
    with open(path, "wb") as f:
        f.write(b"GARBAGE!" + b"\x01" * 32)
    assert recover_journal(path) == ([], True)
    # Repair leaves a well-formed empty journal behind.
    assert recover_journal(path) == ([], False)


# ------------------------------------------- checkpoint-shard bit-rot
def test_eval_checkpoint_crc_detects_bitflip():
    rec = {"fingerprint": "x", "metrics": {"jcr": 0.5}}
    rec["_crc32"] = record_crc(rec)
    assert verify_record(rec)
    rec["metrics"]["jcr"] = 0.6
    assert not verify_record(rec)
    rec["_crc32"] = "not-a-crc"
    assert not verify_record(rec)


def _daemon_cfg(tmp_path, **kw):
    kw.setdefault("checkpoint_every", 1000)   # keep ops in the WAL
    return SchedulerConfig(policy="rfold", policy_kw=MEDIUM,
                           checkpoint_dir=str(tmp_path / "ckpt"), **kw)


def _snapshot_path(cfg):
    return os.path.join(shard_dir(cfg.checkpoint_dir, cfg.fingerprint()),
                        cfg.checkpoint_name())


@pytest.mark.parametrize("corrupt", ["bitflip", "truncate"])
def test_corrupt_snapshot_never_replays(tmp_path, corrupt):
    cfg = _daemon_cfg(tmp_path, checkpoint_every=1)
    with Scheduler(cfg) as s:
        s.submit((4, 4, 4))
        assert s.status()["journal_ops"] == 1
    path = _snapshot_path(cfg)
    data = bytearray(open(path, "rb").read())
    if corrupt == "bitflip":
        data[len(data) // 2] ^= 0xFF
    else:
        data = data[:len(data) // 2]
    with open(path, "wb") as f:
        f.write(bytes(data))
    # A corrupt shard must start fresh (never crash, never half-replay).
    s2 = Scheduler(cfg).start()
    st = s2.status()
    s2.kill()
    assert st["journal_ops"] == 0 and st["allocated"] == 0


def test_daemon_truncated_wal_recovers_acked_prefix(tmp_path):
    cfg = _daemon_cfg(tmp_path)
    s = Scheduler(cfg).start()
    for dims in [(4, 4, 4), (2, 4, 8), (4, 4, 8)]:
        s.submit(dims)
    n_ops = s.status()["journal_ops"]
    s.kill()   # crash: recovery is WAL-only (no final snapshot)
    core_like = cfg.checkpoint_name() + ".wal"
    wal = os.path.join(shard_dir(cfg.checkpoint_dir, cfg.fingerprint()),
                       core_like)
    with open(wal, "rb") as f:
        data = f.read()
    with open(wal, "wb") as f:   # tear the last record mid-payload
        f.write(data[:-5])
    s2 = Scheduler(cfg).start()
    st = s2.status()
    s2.kill()
    assert st["journal_ops"] == n_ops - 1
    assert st["resilience"]["wal_truncated"] == 1
    assert st["resilience"]["wal_tail_ops"] == n_ops - 1
    # The recovered state is byte-identical to a run that only ever
    # saw the surviving prefix.
    cfg2 = SchedulerConfig(policy="rfold", policy_kw=MEDIUM,
                           checkpoint_dir=str(tmp_path / "control"))
    s3 = Scheduler(cfg2).start()
    for dims in [(4, 4, 4), (2, 4, 8)]:
        s3.submit(dims)
    digest = s3.status()["state_digest"]
    s3.kill()
    assert st["state_digest"] == digest


# --------------------------------------------------- idempotent retry
class _Raw:
    """Wire driver with a fixed client id and explicit request_ids, so
    a byte-identical resend is the genuine retry path."""

    def __init__(self, address, cid="raw"):
        self._c = SchedulerClient(address, client_id=cid, max_retries=0)
        self._cid = cid

    def send(self, i, msg):
        wire = dict(msg, seq=i, client=self._cid,
                    request_id=f"{self._cid}:{i}")
        self._c._sock.sendall(protocol.encode(wire))
        return self._c._await_reply(i, 30.0)

    def close(self):
        self._c.close()


def test_retry_same_request_id_applied_once():
    s = Scheduler(SchedulerConfig(policy="rfold",
                                  policy_kw=MEDIUM)).start()
    c = _Raw(s.address)
    try:
        r1 = c.send(0, {"op": "submit", "shape": [4, 4, 4]})
        assert r1["outcome"] == PLACED
        r2 = c.send(0, {"op": "submit", "shape": [4, 4, 4]})
        assert r2["job_id"] == r1["job_id"]
        st = c.send(1, {"op": "status"})
        assert st["allocated"] == 1   # applied exactly once
        assert st["resilience"]["dedup_hits"] >= 1
    finally:
        c.close()
        s.stop()


def test_dedup_cache_survives_crash(tmp_path):
    cfg = _daemon_cfg(tmp_path)
    s = Scheduler(cfg).start()
    c = _Raw(s.address)
    r1 = c.send(0, {"op": "submit", "shape": [4, 4, 4]})
    c.close()
    s.kill()
    # Replay repopulates the dedup cache from the journaled rids: the
    # retry a reconnecting client sends must still be exactly-once.
    s2 = Scheduler(cfg).start()
    c2 = _Raw(s2.address)
    try:
        before = c2.send(1, {"op": "status"})
        r2 = c2.send(0, {"op": "submit", "shape": [4, 4, 4]})
        after = c2.send(2, {"op": "status"})
        assert r2["job_id"] == r1["job_id"]
        assert after["state_digest"] == before["state_digest"]
        assert after["resilience"]["dedup_hits"] >= 1
    finally:
        c2.close()
        s2.stop()


# --------------------------------------------------------- liveness
def _await_expiry(s, deadline=10.0):
    t0 = time.monotonic()
    while time.monotonic() - t0 < deadline:
        st = s.status()
        if st["resilience"]["lease_expiries"] >= 1:
            return st
        time.sleep(0.05)
    raise AssertionError("lease never expired")


def test_lease_expiry_requeues_dead_clients_jobs():
    cfg = SchedulerConfig(policy="rfold", policy_kw=SMALL,
                          lease_timeout=0.3, lease_policy="requeue")
    s = Scheduler(cfg).start()
    try:
        c = _Raw(s.address, cid="doomed")
        r = c.send(0, {"op": "submit", "shape": [4, 4, 4]})
        assert r["outcome"] == PLACED
        c.close()   # no more heartbeats: the lease lapses
        st = _await_expiry(s)
        assert st["allocated"] == 0
        assert st["queue_depth"] == 1   # work-preserving eviction
    finally:
        s.stop()


def test_lease_expiry_release_frees_capacity():
    cfg = SchedulerConfig(policy="rfold", policy_kw=SMALL,
                          lease_timeout=0.3, lease_policy="release")
    s = Scheduler(cfg).start()
    try:
        c = _Raw(s.address, cid="doomed")
        assert c.send(0, {"op": "submit",
                          "shape": [4, 4, 4]})["outcome"] == PLACED
        c.close()
        st = _await_expiry(s)
        assert st["allocated"] == 0 and st["queue_depth"] == 0
        assert st["busy_xpus"] == 0
    finally:
        s.stop()


def test_facade_heartbeat_keeps_own_lease_alive():
    cfg = SchedulerConfig(policy="rfold", policy_kw=SMALL,
                          lease_timeout=0.3)
    s = Scheduler(cfg).start()
    try:
        assert s.submit((4, 4, 4))["outcome"] == PLACED
        time.sleep(1.0)   # several lease periods
        st = s.status()
        assert st["allocated"] == 1
        assert st["resilience"]["lease_expiries"] == 0
    finally:
        s.stop()


# ----------------------------------------------- client reconnection
def test_client_reconnect_clears_partial_buffer():
    s = Scheduler(SchedulerConfig(policy="rfold",
                                  policy_kw=SMALL)).start()
    c = SchedulerClient(s.address)
    try:
        assert c.status()["ok"]
        c._buf = b'{"torn": '   # half a frame from a dying connection
        c.connect()             # reconnect must not parse stale bytes
        assert c._buf == b""
        assert c.status()["num_xpus"] == 64
    finally:
        c.close()
        s.stop()


# ------------------------------------------------ SIGKILL crash loop
_CHILD = """\
import sys, time
from repro.api import Scheduler, SchedulerConfig
cfg = SchedulerConfig(policy="rfold",
                      policy_kw=dict(num_xpus=512, cube_n=4),
                      checkpoint_dir=sys.argv[1], checkpoint_every=3)
s = Scheduler(cfg).start()
for i, dims in enumerate({shapes!r}):
    s.submit(dims)
    print("acked", i, flush=True)
    time.sleep(0.05)
s.kill()
"""

_SHAPES = [(4, 4, 4), (2, 4, 8), (4, 4, 8), (2, 2, 4),
           (4, 4, 4), (2, 4, 4), (4, 8, 4), (2, 2, 2)]


def test_sigkill_midstream_recovers_acked_prefix(tmp_path):
    """SIGKILL the daemon process at a seeded point mid-stream; a
    fresh daemon on the same store must hold every acknowledged op
    (fsync-before-ack) and match a control run over that prefix."""
    ckpt = str(tmp_path / "ckpt")
    script = tmp_path / "child.py"
    script.write_text(_CHILD.format(shapes=_SHAPES))
    kill_after = random.Random(7).randrange(2, 6)
    src = os.path.dirname(list(repro.__path__)[0])
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (src, env.get("PYTHONPATH")) if p)
    proc = subprocess.Popen([sys.executable, str(script), ckpt],
                            stdout=subprocess.PIPE, text=True, env=env)
    acked = 0
    try:
        for line in proc.stdout:
            if line.startswith("acked"):
                acked += 1
                if acked == kill_after:
                    os.kill(proc.pid, signal.SIGKILL)
                    break
        proc.wait(timeout=60)
    finally:
        proc.stdout.close()
        if proc.poll() is None:
            proc.kill()
            proc.wait()
    assert acked == kill_after

    cfg = SchedulerConfig(policy="rfold", policy_kw=MEDIUM,
                          checkpoint_dir=ckpt, checkpoint_every=3)
    s2 = Scheduler(cfg).start()
    st = s2.status()
    s2.kill()
    # Every acked submit is durable; at most the one op in flight at
    # the kill may additionally have committed.
    assert acked <= st["journal_ops"] <= acked + 1

    control = SchedulerConfig(policy="rfold", policy_kw=MEDIUM,
                              checkpoint_dir=str(tmp_path / "control"))
    s3 = Scheduler(control).start()
    for dims in _SHAPES[:st["journal_ops"]]:
        s3.submit(dims)
    digest = s3.status()["state_digest"]
    s3.kill()
    assert st["state_digest"] == digest


# ------------------------------------------------- broker watchdog
def _occ(rng, b, cell=(6, 6, 6)):
    return rng.random((b,) + cell) < 0.4


def test_dead_stepper_never_hangs_flush():
    """A registered stepper that dies before submitting would park the
    all-active flush trigger forever; the watchdog must reap it so the
    surviving stepper's query still completes — bit-exactly."""
    broker = QueryBroker("numpy")
    rng = np.random.default_rng(0)
    occ = _occ(rng, 3)
    boxes = ((2, 2, 1), (3, 1, 2), (6, 6, 6))
    ref = np.asarray(ops.get_engine("numpy").multibox(occ, boxes))

    def doomed():
        broker.register(thread=threading.current_thread())
        raise RuntimeError("stepper crash before first query")

    t_dead = threading.Thread(target=doomed, daemon=True)
    t_dead.start()
    t_dead.join()

    results = {}

    def survivor():
        broker.register(thread=threading.current_thread())
        try:
            results["planes"] = broker.multibox(occ, boxes)
        finally:
            broker.deactivate()

    t = threading.Thread(target=survivor, daemon=True)
    t.start()
    t.join(timeout=30)
    assert not t.is_alive(), "flush hung behind a dead stepper"
    np.testing.assert_array_equal(results["planes"], ref)
    assert broker.stats.steppers_reaped == 1


def test_stepper_dying_between_queries_shrinks_quorum():
    """Two live steppers coalesce; after one dies mid-run the other's
    next query must flush alone instead of waiting for the ghost."""
    broker = QueryBroker("numpy")
    rng = np.random.default_rng(1)
    boxes = ((2, 2, 2),)
    barrier = threading.Barrier(2, timeout=30)
    out = {}

    def stepper(name, rounds):
        broker.register(thread=threading.current_thread())
        barrier.wait()   # both registered before either's first query
        try:
            for r in range(rounds):
                occ = _occ(np.random.default_rng(hash((name, r)) % 997),
                           2)
                out[(name, r)] = np.asarray(
                    broker.multibox(occ, boxes)).copy()
        finally:
            if rounds > 1:
                broker.deactivate()
            # rounds == 1: die registered — the watchdog must reap us.

    t1 = threading.Thread(target=stepper, args=("long", 3), daemon=True)
    t2 = threading.Thread(target=stepper, args=("short", 1), daemon=True)
    t1.start(), t2.start()
    t1.join(timeout=30), t2.join(timeout=30)
    assert not t1.is_alive() and not t2.is_alive()
    assert broker.stats.steppers_reaped == 1
    for (name, r), planes in out.items():
        occ = _occ(np.random.default_rng(hash((name, r)) % 997), 2)
        np.testing.assert_array_equal(
            planes, np.asarray(ops.get_engine("numpy")
                               .multibox(occ, boxes)))


# ------------------------------------------------- engine failover
def test_failover_candidates_chain():
    assert failover_candidates("pallas") == ("jax", "numpy")
    assert failover_candidates("jax") == ("numpy",)
    assert failover_candidates("numpy") == ()
    assert failover_candidates("no-such-engine") == ()


def test_injected_faults_degrade_to_numpy_with_parity():
    """Two injected faults exhaust the attempt + the single retry on
    the jax engine; the broker must adopt numpy and answer the same
    query bit-exactly, recording the failover in its stats."""
    broker = QueryBroker("jax")
    rng = np.random.default_rng(2)
    occ = _occ(rng, 4)
    boxes = ((2, 2, 1), (3, 1, 2))
    ref = np.asarray(ops.get_engine("numpy").multibox(occ, boxes))
    broker.inject_engine_faults(2)
    np.testing.assert_array_equal(broker.multibox(occ, boxes), ref)
    assert broker.engine_name == "numpy"
    assert broker.stats.engine_retries == 1
    assert broker.stats.engine_failovers == 1
    assert broker.stats.failover_engine == "numpy"
    # Subsequent queries run on the adopted engine without incident.
    np.testing.assert_array_equal(
        broker.free_counts(occ),
        np.asarray(ops.get_engine("numpy").free_counts(occ)))


def test_single_transient_fault_retries_in_place():
    broker = QueryBroker("jax")
    rng = np.random.default_rng(3)
    occ = _occ(rng, 2)
    boxes = ((2, 2, 2),)
    broker.inject_engine_faults(1)
    planes = np.asarray(broker.multibox(occ, boxes))
    np.testing.assert_array_equal(
        planes, np.asarray(ops.get_engine("jax").multibox(occ, boxes)))
    assert broker.engine_name == "jax"   # retry succeeded, no failover
    assert broker.stats.engine_retries == 1
    assert broker.stats.engine_failovers == 0


def test_engine_failure_mid_run_schedules_match_host_oracle():
    """Acceptance: a compiled engine failing mid-simulation degrades
    to numpy and the produced *schedule* is byte-identical to one
    computed against the host oracle from the start."""
    from repro.api import (Simulator, TraceConfig, generate_trace,
                           make_policy)
    from repro.sim.fleet import Fleet, install_mask_client

    cfg = TraceConfig(num_jobs=40, cluster_xpus=512, size_max=512,
                      seed=5)

    def record(result):
        return [[j.job_id, j.start, j.finish, j.dropped, j.slowdown]
                for j in result.jobs]

    ref = record(Simulator(make_policy("rfold", **MEDIUM),
                           generate_trace(cfg)).run())

    fleet = Fleet("jax")
    fleet.broker.inject_engine_faults(2)

    def unit(broker):
        policy = make_policy("rfold", **MEDIUM)
        install_mask_client(policy, broker)
        return Simulator(policy, generate_trace(cfg)).run()

    (res,) = fleet.run([unit])
    assert fleet.broker.engine_name == "numpy"
    assert fleet.broker.stats.engine_failovers == 1
    assert record(res) == ref


def test_custom_engine_instance_is_failover_exempt():
    class Boom:
        def multibox(self, occ, boxes):
            raise RuntimeError("boom")

        def free_counts(self, occ):
            raise RuntimeError("boom")

    broker = QueryBroker(Boom())
    assert broker.engine_name is None
    with pytest.raises(RuntimeError, match="boom"):
        broker.free_counts(_occ(np.random.default_rng(4), 1))
    assert broker.stats.engine_failovers == 0


# --------------------------------------- replicated scheduler (PR 10)
def _pair(tmp_path, **primary_kw):
    """A primary + warm standby on private checkpoint stores."""
    pri = Scheduler(SchedulerConfig(
        policy="rfold", policy_kw=MEDIUM, checkpoint_every=3,
        checkpoint_dir=str(tmp_path / "pri"), repl_poll=0.1,
        **primary_kw)).start()
    sby = Scheduler(SchedulerConfig(
        policy="rfold", policy_kw=MEDIUM, checkpoint_every=3,
        checkpoint_dir=str(tmp_path / "sby"), repl_poll=0.1,
        role="standby", replicate_from=pri.address,
        **primary_kw)).start()
    return pri, sby


def _await_repl(sby, n_ops, deadline=10.0):
    t0 = time.monotonic()
    while time.monotonic() - t0 < deadline:
        st = sby.status()
        if st["journal_ops"] >= n_ops:
            return st
        time.sleep(0.02)
    raise AssertionError(f"standby never reached {n_ops} ops")


def _await_follower(pri, deadline=10.0):
    t0 = time.monotonic()
    while time.monotonic() - t0 < deadline:
        if pri.status()["repl"]["follower_live"]:
            return
        time.sleep(0.02)
    raise AssertionError("standby never pulled from the primary")


def test_standby_tails_primary_digest_tracks(tmp_path):
    """The replication stream: every journaled op the primary acks
    shows up on the standby, whose state digest tracks the primary's
    record-for-record."""
    pri, sby = _pair(tmp_path)
    try:
        for dims in _SHAPES[:5]:
            pri.submit(dims)
        pri.done(1)
        sp = pri.status()
        ss = _await_repl(sby, sp["journal_ops"])
        assert ss["state_digest"] == sp["state_digest"]
        assert ss["journal_ops"] == sp["journal_ops"]
        assert ss["resilience"]["repl_applied"] == sp["journal_ops"]
        assert ss["role"] == "standby" and sp["role"] == "primary"
    finally:
        sby.kill()
        pri.kill()


def test_standby_refuses_writes_and_redirects(tmp_path):
    """A standby answers writes with NOT_LEADER + the primary's
    address; a client pointed only at the standby follows the
    redirect and the op lands on the primary exactly once."""
    pri, sby = _pair(tmp_path)
    c = SchedulerClient(sby.address, client_id="redir", backoff=0.01)
    try:
        r = c.submit((4, 4, 4))
        assert r["outcome"] == PLACED
        assert c.redirects >= 1
        assert tuple(c.address) == tuple(pri.address)
        assert pri.status()["journal_ops"] == 1
        st = _await_repl(sby, 1)
        assert st["journal_ops"] == 1   # via replication, not the write
    finally:
        c.close()
        sby.kill()
        pri.kill()


def test_promotion_fences_old_primary_journal_side(tmp_path):
    """After a promotion, a request stamped with the new epoch makes
    the old primary fence itself: the write is refused and nothing
    reaches its journal — the no-double-place invariant."""
    pri, sby = _pair(tmp_path)
    c = SchedulerClient([pri.address, sby.address], client_id="fence",
                        backoff=0.01)
    try:
        for dims in _SHAPES[:3]:
            assert c.submit(dims)["ok"]
        _await_repl(sby, 3)
        pr = sby.promote()
        assert pr["promoted"] and pr["epoch"] == 2
        ops_before = pri.status()["journal_ops"]
        stale = SchedulerClient(pri.address, client_id="stale",
                                max_retries=0)
        stale.epoch_seen = pr["epoch"]   # witnessed the new leader
        with pytest.raises(ConnectionError):
            stale._request("submit", shape=[2, 2, 2])
        stale.close()
        sp = pri.status()
        assert sp["fenced"]
        assert sp["repl"]["fenced_rejections"] >= 1
        assert sp["journal_ops"] == ops_before   # zero fenced writes
    finally:
        c.close()
        sby.kill()
        pri.kill()


def test_client_discards_stale_epoch_reply():
    """Client-side fencing: a reply whose epoch is below the client's
    watermark is discarded like a connection failure — a superseded
    leader's ack is not an ack."""
    srv = __import__("socket").socket()
    srv.bind(("127.0.0.1", 0))
    srv.listen(4)
    address = srv.getsockname()[:2]
    done = threading.Event()

    def stale_leader():
        while not done.is_set():
            try:
                conn, _ = srv.accept()
            except OSError:
                return
            with conn, conn.makefile("rb") as f:
                for line in f:
                    msg = protocol.decode(line)
                    conn.sendall(protocol.encode(
                        {"ok": True, "seq": msg.get("seq"), "epoch": 1,
                         "outcome": PLACED, "job_id": 0}))

    t = threading.Thread(target=stale_leader, daemon=True)
    t.start()
    c = SchedulerClient(address, client_id="wm", max_retries=1,
                        backoff=0.01)
    try:
        c.epoch_seen = 3   # witnessed a newer leader elsewhere
        with pytest.raises(ConnectionError, match="epoch"):
            c._request("submit", shape=[2, 2, 2])
        assert c.stale_rejections >= 1
    finally:
        done.set()
        srv.close()
        c.close()


def test_leader_kill_failover_exactly_once_digest_identical(tmp_path):
    """The acceptance scenario in miniature: kill the primary
    mid-stream, promote the standby, resend the last acked rid (the
    replicated dedup cache absorbs it), finish the stream — the final
    digest is byte-identical to an uninterrupted control run."""
    pri, sby = _pair(tmp_path, ack_mode="sync", sync_timeout=2.0)
    c = SchedulerClient([pri.address, sby.address], client_id="fo",
                        backoff=0.02)
    try:
        _await_follower(pri)
        replies = {}
        for i, dims in enumerate(_SHAPES[:4]):
            r = c._request("submit", request_id=f"fo:{i}",
                           shape=list(dims))
            assert r["ok"] and r["replicated"], r
            replies[i] = r
        pri.kill()   # no final checkpoint; clients see a dead socket
        assert sby.promote()["epoch"] == 2
        # Replay the in-flight rid: exactly-once across the failover.
        before = c._request("status")
        r2 = c._request("submit", request_id="fo:3",
                        shape=list(_SHAPES[3]))
        after = c._request("status")
        assert r2["job_id"] == replies[3]["job_id"]
        assert after["state_digest"] == before["state_digest"]
        assert after["resilience"]["dedup_hits"] >= 1
        assert c.epoch_seen == 2
        for i, dims in enumerate(_SHAPES[4:], start=4):
            assert c._request("submit", request_id=f"fo:{i}",
                              shape=list(dims))["ok"]
        final = c._request("status")
    finally:
        c.close()
        sby.kill()
    control = Scheduler(SchedulerConfig(policy="rfold",
                                        policy_kw=MEDIUM)).start()
    for dims in _SHAPES:
        control.submit(dims)
    digest = control.status()["state_digest"]
    control.stop()
    assert final["state_digest"] == digest


def test_sync_ack_degrades_without_follower(tmp_path):
    """ack_mode=sync with no live standby must not stall the service:
    the op acks degraded (replicated=False) and the timeout is
    counted."""
    cfg = SchedulerConfig(policy="rfold", policy_kw=MEDIUM,
                          ack_mode="sync", sync_timeout=0.2)
    s = Scheduler(cfg).start()
    try:
        t0 = time.monotonic()
        r = s.submit((4, 4, 4))
        assert time.monotonic() - t0 < 1.0   # no follower: no wait
        assert r["ok"] and r["replicated"] is False
        assert s.status()["repl"]["sync_timeouts"] >= 1
    finally:
        s.stop()


def test_promoted_standby_recovers_epoch_from_own_wal(tmp_path):
    """The fencing token is journaled state: a promoted standby that
    crashes recovers its epoch (and state) from its own WAL."""
    pri, sby = _pair(tmp_path)
    try:
        for dims in _SHAPES[:3]:
            pri.submit(dims)
        sp = pri.status()
        _await_repl(sby, sp["journal_ops"])
        pri.kill()
        assert sby.promote()["epoch"] == 2
        want = sby.status()
        sby.kill()
        s2 = Scheduler(SchedulerConfig(
            policy="rfold", policy_kw=MEDIUM, checkpoint_every=3,
            checkpoint_dir=str(tmp_path / "sby"))).start()
        st = s2.status()
        s2.kill()
        assert st["epoch"] == 2
        assert st["state_digest"] == want["state_digest"]
        assert st["journal_ops"] == want["journal_ops"]
    finally:
        pass


def test_heartbeat_jitter_bounds():
    """The jittered interval stays inside [1-j, 1+j] of the base for
    any draw, degenerates to the base at jitter=0, and clamps bad
    jitter values instead of going negative."""
    for u in (0.0, 0.25, 0.5, 0.999):
        assert jittered_interval(3.0, 0.0, u) == 3.0
        v = jittered_interval(3.0, 0.25, u)
        assert 3.0 * 0.75 <= v <= 3.0 * 1.25
    assert jittered_interval(3.0, 0.25, 0.0) == pytest.approx(2.25)
    assert jittered_interval(3.0, 5.0, 0.0) == pytest.approx(0.0)
    assert jittered_interval(3.0, -1.0, 0.7) == 3.0


def test_config_validates_replication_fields():
    with pytest.raises(ValueError, match="role"):
        SchedulerConfig(role="observer")
    with pytest.raises(ValueError, match="ack_mode"):
        SchedulerConfig(ack_mode="paxos")
    with pytest.raises(ValueError, match="replicate_from"):
        SchedulerConfig(role="standby")
    # Replication knobs never change the checkpoint identity: a
    # standby shares the primary's fingerprint (the stream id).
    a = SchedulerConfig(policy="rfold", policy_kw=MEDIUM)
    b = SchedulerConfig(policy="rfold", policy_kw=MEDIUM,
                        role="standby", replicate_from=("h", 1),
                        ack_mode="sync")
    assert a.fingerprint() == b.fingerprint()
