"""Per-kernel validation: shape/dtype sweeps asserting allclose against
the pure-jnp oracles (kernels run in interpret mode on CPU)."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.fitmask import kernel as fit_kernel
from repro.kernels.fitmask import ops as fit_ops
from repro.kernels.fitmask import ref as fit_ref
from repro.kernels.flash_attention import kernel as fa_kernel
from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.ssd_scan import kernel as ssd_kernel
from repro.kernels.ssd_scan import ref as ssd_ref

# ------------------------------------------------------------ flash attn
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("s,h,kh,d,bq,bk", [
    (128, 4, 4, 64, 128, 128),    # MHA, single block
    (256, 4, 2, 64, 128, 128),    # GQA 2:1
    (256, 8, 1, 32, 64, 128),     # MQA, mixed blocks
    (192, 2, 2, 128, 128, 64),    # non-multiple seq/block
])
def test_flash_attention_sweep(dtype, s, h, kh, d, bq, bk):
    rng = np.random.default_rng(0)
    q = jnp.array(rng.normal(size=(2, s, h, d)), dtype)
    k = jnp.array(rng.normal(size=(2, s, kh, d)), dtype)
    v = jnp.array(rng.normal(size=(2, s, kh, d)), dtype)
    out = fa_kernel.flash_attention(q, k, v, causal=True, block_q=bq,
                                    block_k=bk, interpret=True)
    ref = fa_ref.attention_reference(q, k, v, causal=True)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("window", [16, 64, 1])
def test_flash_attention_sliding_window(window):
    rng = np.random.default_rng(1)
    q = jnp.array(rng.normal(size=(1, 128, 2, 32)), jnp.float32)
    k = jnp.array(rng.normal(size=(1, 128, 2, 32)), jnp.float32)
    v = jnp.array(rng.normal(size=(1, 128, 2, 32)), jnp.float32)
    out = fa_kernel.flash_attention(q, k, v, causal=True, window=window,
                                    block_q=64, block_k=64, interpret=True)
    ref = fa_ref.attention_reference(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-6, atol=2e-6)


def test_flash_attention_matches_model_path():
    """The einsum path used by the models equals the kernel (arange
    positions)."""
    from repro.models.attention import _gqa_attend
    rng = np.random.default_rng(2)
    b, s, h, kh, d = 2, 128, 4, 2, 64
    q = jnp.array(rng.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.array(rng.normal(size=(b, s, kh, d)), jnp.float32)
    v = jnp.array(rng.normal(size=(b, s, kh, d)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    ref = _gqa_attend(q, k, v, pos, pos, 0)
    out = fa_kernel.flash_attention(q, k, v, causal=True, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# -------------------------------------------------------------- ssd scan
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("s,h,p,n,chunk", [
    (64, 2, 8, 16, 16),
    (128, 3, 16, 8, 32),
    (32, 1, 4, 4, 32),     # single chunk
    (96, 2, 8, 8, 16),     # many chunks
])
def test_ssd_kernel_sweep(dtype, s, h, p, n, chunk):
    rng = np.random.default_rng(3)
    x = jnp.array(rng.normal(size=(2, s, h, p)), dtype)
    dt = jnp.array(rng.uniform(0.01, 0.2, size=(2, s, h)), jnp.float32)
    a = jnp.array(-rng.uniform(0.5, 2.0, size=(h,)), jnp.float32)
    b = jnp.array(rng.normal(size=(2, s, h, n)), dtype)
    c = jnp.array(rng.normal(size=(2, s, h, n)), dtype)
    d = jnp.array(rng.normal(size=(h,)), jnp.float32)
    y_k, s_k = ssd_kernel.ssd_scan_kernel(x, dt, a, b, c, d_skip=d,
                                          chunk=chunk, interpret=True)
    y_r, s_r = ssd_ref.ssd_reference(x, dt, a, b, c, chunk=chunk, d_skip=d)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y_k, np.float32),
                               np.asarray(y_r, np.float32),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r),
                               rtol=1e-4, atol=1e-4)


def test_ssd_chunked_equals_sequential():
    rng = np.random.default_rng(4)
    B, S, H, P, N = 1, 48, 2, 4, 8
    x = jnp.array(rng.normal(size=(B, S, H, P)), jnp.float32)
    dt = jnp.array(rng.uniform(0.01, 0.3, size=(B, S, H)), jnp.float32)
    a = jnp.array(-rng.uniform(0.5, 2.0, size=(H,)), jnp.float32)
    b = jnp.array(rng.normal(size=(B, S, H, N)), jnp.float32)
    c = jnp.array(rng.normal(size=(B, S, H, N)), jnp.float32)
    y1, s1 = ssd_ref.ssd_reference(x, dt, a, b, c, chunk=16)
    y2, s2 = ssd_ref.ssd_sequential_reference(x, dt, a, b, c)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-5, atol=1e-5)


def test_ssd_decode_step_consistent_with_scan():
    """Running ssd_step token by token reproduces the chunked scan."""
    rng = np.random.default_rng(5)
    B, S, H, P, N = 2, 16, 2, 4, 8
    x = jnp.array(rng.normal(size=(B, S, H, P)), jnp.float32)
    dt = jnp.array(rng.uniform(0.01, 0.3, size=(B, S, H)), jnp.float32)
    a = jnp.array(-rng.uniform(0.5, 2.0, size=(H,)), jnp.float32)
    b = jnp.array(rng.normal(size=(B, S, H, N)), jnp.float32)
    c = jnp.array(rng.normal(size=(B, S, H, N)), jnp.float32)
    y_scan, s_scan = ssd_ref.ssd_reference(x, dt, a, b, c, chunk=8)
    st = jnp.zeros((B, H, P, N), jnp.float32)
    ys = []
    for t in range(S):
        y, st = ssd_ref.ssd_step(st, x[:, t], dt[:, t], a, b[:, t], c[:, t])
        ys.append(y)
    np.testing.assert_allclose(np.asarray(jnp.stack(ys, 1)),
                               np.asarray(y_scan), rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------- fitmask
@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10_000),
       st.tuples(st.integers(1, 6), st.integers(1, 6), st.integers(1, 6)),
       st.integers(1, 4))
def test_fitmask_kernel_matches_oracles(seed, box, bsz):
    rng = np.random.default_rng(seed)
    occ = rng.uniform(size=(bsz, 6, 6, 6)) < 0.3
    out_k = np.asarray(fit_kernel.fitmask_batched(jnp.array(occ), box,
                                                  interpret=True))
    out_r = np.asarray(fit_ref.fitmask_reference(jnp.array(occ), box))
    out_n = np.asarray(fit_ops.fitmask(jnp.array(occ), box, engine="numpy"))
    assert (out_k == out_r).all()
    assert (out_k == out_n).all()


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000),
       st.integers(1, 3),
       st.tuples(st.integers(3, 7), st.integers(3, 7), st.integers(3, 7)),
       st.integers(1, 6))
def test_fitmask_multibox_matches_numpy_oracle(seed, bsz, grid, k):
    """The multi-box kernel (one VMEM integral-image pass for all K
    boxes) is bit-exact vs the numpy oracle across random grids, batch
    sizes and box lists — including boxes that fit nowhere or overhang
    the grid entirely (all-zero planes)."""
    from repro.core import fitmask as np_engine
    rng = np.random.default_rng(seed)
    occ = rng.uniform(size=(bsz,) + grid) < 0.3
    # box extents up to 8 on 3..7 grids: not-fitting boxes included
    boxes = tuple(tuple(int(v) for v in rng.integers(1, 9, size=3))
                  for _ in range(k))
    out = np.asarray(fit_kernel.fitmask_multibox(jnp.array(occ), boxes,
                                                 interpret=True))
    assert out.shape == (bsz, k) + grid
    expect = np.zeros((bsz, k) + grid, np.int32)
    for i, box in enumerate(boxes):
        m = np_engine.fit_mask_batched(occ, box)
        if m.size:
            expect[:, i, :m.shape[1], :m.shape[2], :m.shape[3]] = m
    assert (out == expect).all()
    assert (out == np_engine.fit_mask_multi(occ, boxes)).all()


def test_fitmask_multibox_k1_equals_single_box_kernel():
    """Explicit K=1 equivalence: the multi-box kernel degenerates to
    the old single-box kernel output, box by box."""
    rng = np.random.default_rng(7)
    occ = jnp.array(rng.uniform(size=(4, 6, 5, 6)) < 0.35)
    for box in [(1, 1, 1), (2, 3, 2), (6, 5, 6), (4, 4, 4), (7, 1, 1)]:
        single = np.asarray(fit_kernel.fitmask_batched(occ, box,
                                                       interpret=True))
        multi = np.asarray(fit_kernel.fitmask_multibox(occ, (box,),
                                                       interpret=True))
        assert multi.shape[1] == 1
        assert (multi[:, 0] == single).all(), box


def test_fitmask_multibox_empty_box_list():
    occ = jnp.zeros((2, 4, 4, 4), jnp.int32)
    out = fit_kernel.fitmask_multibox(occ, (), interpret=True)
    assert out.shape == (2, 0, 4, 4, 4)


def test_fitmask_batched_cubes_use_case():
    """The reconfig allocator's batched per-cube check."""
    rng = np.random.default_rng(0)
    cubes = rng.uniform(size=(64, 4, 4, 4)) < 0.4
    box = (4, 2, 1)
    out = np.asarray(fit_ops.fitmask(jnp.array(cubes), box, engine="kernel"))
    for i in range(64):
        brute = np.zeros((4, 4, 4), np.int32)
        for y in range(3):
            for z in range(4):
                brute[0, y, z] = not cubes[i, :, y:y + 2, z:z + 1].any()
        assert (out[i] == brute).all()
