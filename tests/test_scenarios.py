"""Named chaos scenarios: the five-entry catalog behind the CI
scenario-matrix wall.

Guarantees under test: every scenario record is byte-deterministic
across identically-seeded runs, each scenario actually exercises what
its name promises (node_churn evicts, multi_tenant preempts by
priority, bursty raises arrival CV, ocs_degraded cuts fabric), and the
degradation metrics in the record are internally consistent.
"""
import json
import math

import pytest

from repro.api import SCENARIOS, Scenario, run_scenario
from repro.traces.generator import TraceConfig, generate_trace


def _record(name, **kw):
    return run_scenario(SCENARIOS[name], num_jobs=60, seed=0, **kw)


def test_catalog_has_the_five_named_scenarios():
    assert sorted(SCENARIOS) == ["bursty", "healthy", "multi_tenant",
                                 "node_churn", "ocs_degraded"]
    for name, sc in SCENARIOS.items():
        assert isinstance(sc, Scenario) and sc.name == name
        assert sc.description  # every entry documents itself


@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_scenario_records_byte_deterministic(name):
    a = json.dumps(_record(name), sort_keys=True)
    b = json.dumps(_record(name), sort_keys=True)
    assert a == b


def test_healthy_scenario_has_no_faults():
    rec = _record("healthy")
    assert rec["num_faults"] == 0
    ch = rec["chaos"]
    assert ch["faults"] == ch["victims"] == ch["preempted"] == 0
    assert ch["dip_depth"] == 0.0


def test_node_churn_evicts_and_accounts_every_victim():
    rec = _record("node_churn")
    assert rec["num_faults"] > 0
    ch = rec["chaos"]
    assert ch["faults"] > 0 and ch["repairs"] == ch["faults"]
    # victims are conserved: preempted + migrated, never dropped
    assert ch["victims"] == ch["preempted"] + ch["migrated"]
    assert ch["killed"] == 0


def test_ocs_degraded_is_fabric_only():
    sc = SCENARIOS["ocs_degraded"]
    assert sc.fault_kw.get("num_fabric_faults", 0) > 0
    assert sc.fault_kw.get("num_node_faults", 0) == 0
    rec = _record("ocs_degraded")
    assert rec["num_faults"] > 0
    assert rec["chaos"]["faults"] > 0


def test_multi_tenant_exercises_priority_preemption():
    rec = _record("multi_tenant")
    ch = rec["chaos"]
    # Fault victims alone produce at most `victims` evictions; the
    # surplus preempt/migrate events are priority preemptions.
    assert ch["preempted"] + ch["migrated"] > ch["victims"]


def test_bursty_raises_arrival_cv_but_keeps_mean():
    burstiness = SCENARIOS["bursty"].trace_kw["arrival_burstiness"]
    assert burstiness > 0
    kw = dict(num_jobs=400, seed=0, cluster_xpus=512, size_max=512)
    smooth = generate_trace(TraceConfig(**kw))
    spiky = generate_trace(TraceConfig(arrival_burstiness=burstiness,
                                       **kw))

    def gaps(jobs):
        a = sorted(j.arrival for j in jobs)
        return [a[i + 1] - a[i] for i in range(len(a) - 1)]

    def cv(xs):
        mu = sum(xs) / len(xs)
        return math.sqrt(sum((x - mu) ** 2 for x in xs) / len(xs)) / mu

    gs, gb = gaps(smooth), gaps(spiky)
    # burstiness preserves the mean inter-arrival (same offered load) …
    assert sum(gb) / len(gb) == pytest.approx(sum(gs) / len(gs), rel=0.15)
    # … while inflating its variability
    assert cv(gb) > cv(gs) + 0.2


def test_scenario_summary_and_chaos_metrics_consistent():
    for name in sorted(SCENARIOS):
        rec = _record(name)
        assert rec["scenario"] == name and rec["policy"] == "rfold"
        s, ch = rec["summary"], rec["chaos"]
        assert 0.0 <= ch["util_overall"] <= 1.0
        assert 0.0 <= s["jcr"] <= 1.0
        assert ch["dip_depth"] >= 0.0
        if ch["faults"] == 0:
            # no degradation window: pre-fault spans the whole run
            assert ch["util_pre_fault"] == pytest.approx(
                ch["util_overall"])
            assert ch["util_dip_min"] is None
        if ch["recovered"]:
            assert ch["time_to_recover"] >= 0.0


def test_policies_comparable_within_scenario():
    """Different policies in the same scenario must face the *same*
    fault timeline (same times, same flat node draws) or the
    cross-policy comparison in BENCH_chaos.json is meaningless."""
    a = _record("node_churn", policy="rfold",
                policy_kw=dict(num_xpus=512, cube_n=4))
    b = _record("node_churn", policy="firstfit",
                policy_kw=dict(dims=(8, 8, 8)))
    assert a["num_faults"] == b["num_faults"]
    assert a["chaos"]["faults"] == b["chaos"]["faults"]


def test_keep_result_returns_full_simulation():
    rec = _record("node_churn", keep_result=True)
    result = rec["_result"]
    assert result.chaos is not None
    assert len(result.jobs) == rec["num_jobs"] == 60
    evicted = sum(j.preemptions + j.migrations for j in result.jobs)
    assert evicted >= rec["chaos"]["victims"]


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
