"""Geometry: Hamiltonian cycles, factorizations, neighbor math."""

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.geometry import (JobShape, cycle_is_valid, factor_pairs,
                                 factorizations3, hamiltonian_cycle_2d,
                                 hamiltonian_cycle_3d, hamiltonian_path_2d,
                                 is_torus_neighbor, rotations, snake_order,
                                 torus_delta, volume)


def test_factorizations3_exact():
    for n in (1, 2, 12, 17, 64, 360):
        for t in factorizations3(n):
            assert volume(t) == n
    assert (4, 4, 4) in factorizations3(64)
    assert all(max(t) <= 16 for t in factorizations3(4096, max_dim=16))


def test_factor_pairs():
    assert set(factor_pairs(6)) == {(1, 6), (2, 3), (3, 2), (6, 1)}
    assert all(a * b == 36 for a, b in factor_pairs(36))


def test_rotations_unique():
    assert len(rotations((1, 2, 3))) == 6
    assert len(rotations((2, 2, 3))) == 3
    assert len(rotations((2, 2, 2))) == 1


@pytest.mark.parametrize("a,b", [(2, 2), (2, 3), (2, 9), (4, 4), (3, 4),
                                 (6, 5), (16, 16), (5, 4)])
def test_hamiltonian_cycle_2d(a, b):
    cyc = hamiltonian_cycle_2d(a, b)
    assert len(cyc) == a * b
    coords = [(i, j, 0) for (i, j) in cyc]
    assert cycle_is_valid(coords, (a, b, 1))


def test_hamiltonian_cycle_2d_rejects_odd():
    with pytest.raises(ValueError):
        hamiltonian_cycle_2d(3, 3)
    with pytest.raises(ValueError):
        hamiltonian_cycle_2d(1, 4)


@pytest.mark.parametrize("dims", [(2, 2, 2), (2, 3, 3), (4, 3, 3),
                                  (2, 2, 9), (4, 4, 4), (6, 5, 5),
                                  (2, 9, 1), (1, 4, 4), (16, 4, 4)])
def test_hamiltonian_cycle_3d(dims):
    cyc = hamiltonian_cycle_3d(dims)
    assert len(cyc) == volume(dims)
    assert cycle_is_valid(cyc, dims)


@settings(max_examples=60, deadline=None)
@given(st.tuples(st.integers(1, 8), st.integers(1, 8), st.integers(1, 8)))
def test_hamiltonian_cycle_3d_property(dims):
    ones = sum(1 for d in dims if d == 1)
    if ones >= 2 or volume(dims) % 2:
        return
    cyc = hamiltonian_cycle_3d(dims)
    assert len(cyc) == volume(dims)
    assert cycle_is_valid(cyc, dims)


def test_torus_delta_wrap():
    assert torus_delta(0, 15, 16, True) == 1
    assert torus_delta(0, 15, 16, False) == 15
    assert torus_delta(3, 5, 16, True) == 2


def test_is_torus_neighbor():
    dims = (4, 4, 4)
    assert is_torus_neighbor((0, 0, 0), (0, 0, 1), dims, (False,) * 3)
    assert is_torus_neighbor((0, 0, 0), (3, 0, 0), dims, (True, False, False))
    assert not is_torus_neighbor((0, 0, 0), (3, 0, 0), dims, (False,) * 3)
    assert not is_torus_neighbor((0, 0, 0), (1, 1, 0), dims, (True,) * 3)
    assert not is_torus_neighbor((0, 0, 0), (0, 0, 0), dims, (True,) * 3)


def test_jobshape_classification():
    assert JobShape((18, 1, 1)).ndim == 1
    assert JobShape((4, 6, 1)).ndim == 2
    assert JobShape((4, 4, 4)).ndim == 3
    assert JobShape((1, 1, 1)).ndim == 1
    assert JobShape((4, 6, 1)).size == 24
    with pytest.raises(ValueError):
        JobShape((0, 1, 1))


def test_snake_order_covers():
    order = snake_order((3, 4))
    assert len(set(order)) == 12


def test_hamiltonian_path_2d():
    p = hamiltonian_path_2d(3, 5)
    assert len(set(p)) == 15
    for u, v in zip(p, p[1:]):
        assert abs(u[0] - v[0]) + abs(u[1] - v[1]) == 1
