"""EngineConfig (the single engine-selection point) and the
constructor-injection API: precedence order, deprecated env-var alias,
deprecated post-hoc setters, and clone propagation."""
import warnings

import pytest

from repro.core import engineconfig
from repro.core.allocator import make_policy
from repro.core.engineconfig import EngineConfig, set_default_engine
from repro.core.maskquery import InlineMaskClient, resolve_mask_client
from repro.core.reconfig import ReconfigTorus
from repro.core.torus import StaticTorus
from repro.kernels.fitmask import ops
from repro.sim.fleet import install_mask_client


@pytest.fixture(autouse=True)
def _clean_selection(monkeypatch):
    """Each test starts from pristine process-wide selection state."""
    monkeypatch.delenv(engineconfig.ENGINE_ENV, raising=False)
    monkeypatch.setattr(engineconfig, "_default_engine", None)
    monkeypatch.setattr(engineconfig, "_env_warned", False)
    yield


# ------------------------------------------------------------- coerce
def test_coerce_spellings():
    assert EngineConfig.coerce(None) == EngineConfig()
    assert EngineConfig.coerce("ref").engine == "ref"
    cfg = EngineConfig(engine="numpy", fleet_size=4)
    assert EngineConfig.coerce(cfg) is cfg
    with pytest.raises(TypeError):
        EngineConfig.coerce(42)


# ---------------------------------------------------------- precedence
def test_resolution_precedence(monkeypatch):
    assert EngineConfig().resolve_name() == "numpy"  # baseline default
    monkeypatch.setenv(engineconfig.ENGINE_ENV, "ref")
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        assert EngineConfig().resolve_name() == "ref"     # env beats numpy
        set_default_engine("numpy")
        assert EngineConfig().resolve_name() == "numpy"   # programmatic beats env
        assert EngineConfig(engine="ref").resolve_name() == "ref"  # explicit wins
    set_default_engine(None)


def test_alias_folding_and_unknown_names():
    assert EngineConfig(engine="kernel").resolve_name() == "pallas"
    with pytest.raises(KeyError):
        EngineConfig(engine="bogus").resolve_name()


def test_env_var_warns_deprecation_once(monkeypatch):
    monkeypatch.setenv(engineconfig.ENGINE_ENV, "ref")
    with pytest.warns(DeprecationWarning, match="deprecated"):
        EngineConfig().resolve_name()
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # second use: silent
        assert EngineConfig().resolve_name() == "ref"


def test_ops_entry_points_delegate_here(monkeypatch):
    set_default_engine("ref")
    assert ops.default_engine_name() == "ref"
    ops.set_default_engine(None)
    assert engineconfig._default_engine is None
    assert ops.default_engine_name() == "numpy"


def test_fleet_kwargs_and_with_engine():
    cfg = EngineConfig(engine="numpy", quorum=0.5, timeout=0.01,
                       max_inflight=3)
    kw = cfg.fleet_kwargs()
    assert kw == {"engine": "numpy", "quorum": 0.5, "timeout": 0.01,
                  "max_inflight": 3}
    assert "max_inflight" not in EngineConfig().fleet_kwargs()
    assert cfg.with_engine("ref").engine == "ref"
    assert cfg.with_engine("ref").quorum == 0.5


def test_mask_client_resolution():
    assert resolve_mask_client(None) is None            # numpy: host path
    assert resolve_mask_client("numpy") is None
    c = resolve_mask_client("ref")
    assert isinstance(c, InlineMaskClient)
    assert resolve_mask_client(EngineConfig(engine="ref")) is c  # interned


# ------------------------------------------------- constructor injection
def test_torus_constructor_injection():
    client = InlineMaskClient("ref")
    t = StaticTorus((4, 4, 4), engine="ref", mask_client=client)
    assert t.engine_config.engine == "ref"
    assert t.mask_client is client
    r = ReconfigTorus(num_xpus=64, cube_n=4, engine=EngineConfig("ref"))
    assert r.engine_config.engine == "ref"


def test_fitmask_engine_kwarg_still_accepted():
    t = StaticTorus((4, 4, 4), fitmask_engine="ref")
    assert t.engine_config.engine == "ref"
    assert t.fitmask_engine == "ref"  # legacy attribute mirrors it


def test_set_mask_client_warns_and_delegates():
    t = StaticTorus((4, 4, 4))
    client = InlineMaskClient("ref")
    with pytest.warns(DeprecationWarning, match="constructor"):
        t.set_mask_client(client)
    assert t.mask_client is client
    r = ReconfigTorus(num_xpus=64, cube_n=4)
    with pytest.warns(DeprecationWarning, match="constructor"):
        r.set_mask_client(client)
    assert r.mask_client is client


def test_install_mask_client_warns_and_delegates():
    pol = make_policy("rfold", num_xpus=64, cube_n=4)
    client = InlineMaskClient("ref")
    with pytest.warns(DeprecationWarning):
        install_mask_client(pol, client)
    assert pol.cluster.mask_client is client


def test_policy_clones_inherit_engine_config():
    pol = make_policy("rfold", num_xpus=64, cube_n=4,
                      engine=EngineConfig(engine="ref", fleet_size=2))
    clone = pol.empty_clone()
    assert clone.cluster.engine_config == pol.cluster.engine_config
    assert clone.cluster.mask_client is None  # probes never share clients

    static = make_policy("folding", dims=(4, 4, 4), engine="ref")
    assert static.empty_clone().torus.engine_config.engine == "ref"
