"""Tests for the parallel evaluation subsystem (repro.eval): seed
derivation stability, pool-vs-serial equivalence, checkpoint
resume-equals-fresh, and fingerprint invalidation."""
import json
import os

import pytest

from repro.eval import (EvalRunner, EvalTask, aggregate_by_label,
                        derive_seed, make_tasks, prune_checkpoints,
                        run_task, table1)
from repro.eval.runner import SHARD_CHARS, iter_checkpoints, shard_dir

# Small matrix: 512-XPU cluster, short traces — seconds, not minutes.
CONFIGS = [
    ("RFold (4^3)", "rfold", dict(num_xpus=512, cube_n=4)),
    ("Reconfig (4^3)", "reconfig", dict(num_xpus=512, cube_n=4)),
]


def _tasks(runs=2, num_jobs=25):
    return make_tasks(CONFIGS, runs=runs, num_jobs=num_jobs, load=1.5,
                      seed0=100)


def _strip_timing(records):
    return [{k: v for k, v in r.items() if k != "sim_s"} for r in records]


# ----------------------------------------------------- seed derivation
def test_derive_seed_depends_only_on_run_idx():
    a = [derive_seed(100, r) for r in range(8)]
    b = [derive_seed(100, r) for r in range(8)]
    assert a == b
    assert len(set(a)) == len(a)        # distinct runs, distinct seeds


def test_task_seeds_paired_across_policies():
    """Every policy sees the same trace seed for run r (paired runs)."""
    tasks = _tasks(runs=3)
    by_run = {}
    for t in tasks:
        by_run.setdefault(t.run_idx, set()).add(t.seed)
    for r, seeds in by_run.items():
        assert len(seeds) == 1, (r, seeds)


def test_records_stable_across_worker_counts(tmp_path):
    """Pool width is an execution detail: identical records for
    workers=0 (inline), 1, and 2 (process pool)."""
    outs = []
    for workers in (0, 1, 2):
        runner = EvalRunner(checkpoint_dir=None, workers=workers)
        outs.append(_strip_timing(runner.run(_tasks())))
    assert outs[0] == outs[1] == outs[2]


# ----------------------------------------------------- checkpoint/resume
def test_resume_from_partial_checkpoint_equals_fresh(tmp_path):
    ckpt = str(tmp_path / "ckpt")
    fresh = EvalRunner(checkpoint_dir=None, workers=0).run(_tasks())

    # populate checkpoints, then delete half of them
    runner = EvalRunner(checkpoint_dir=ckpt, workers=0)
    runner.run(_tasks())
    files = sorted(iter_checkpoints(ckpt))
    assert len(files) == len(_tasks())
    for path in files[::2]:
        os.remove(path)

    resumed_runner = EvalRunner(checkpoint_dir=ckpt, workers=0)
    resumed = resumed_runner.run(_tasks())
    stats = resumed_runner.last_stats
    assert stats["reused_from_checkpoint"] == len(files) - len(files[::2])
    assert stats["executed"] == len(files[::2])
    assert _strip_timing(resumed) == _strip_timing(fresh)

    # aggregates (what the tables are built from) match exactly too
    agg_fresh = aggregate_by_label(fresh)
    agg_resumed = aggregate_by_label(resumed)
    for label in agg_fresh:
        assert agg_fresh[label]["agg"] == agg_resumed[label]["agg"]
        assert table1(agg_fresh) == table1(agg_resumed)


def test_stale_fingerprint_checkpoint_is_rerun(tmp_path):
    """A checkpoint written under a different config (here: num_jobs)
    must not be reused for the new config."""
    ckpt = str(tmp_path / "ckpt")
    EvalRunner(checkpoint_dir=ckpt, workers=0).run(_tasks(num_jobs=20))
    runner = EvalRunner(checkpoint_dir=ckpt, workers=0)
    records = runner.run(_tasks(num_jobs=25))
    assert runner.last_stats["reused_from_checkpoint"] == 0
    assert all(r["summary"]["num_jobs"] == 25 for r in records)


def test_corrupt_checkpoint_is_rerun(tmp_path):
    ckpt = str(tmp_path / "ckpt")
    tasks = _tasks(runs=1)
    EvalRunner(checkpoint_dir=ckpt, workers=0).run(tasks)
    victim = os.path.join(shard_dir(ckpt, tasks[0].fingerprint()),
                          tasks[0].checkpoint_name())
    with open(victim, "w") as f:
        f.write("{not json")
    runner = EvalRunner(checkpoint_dir=ckpt, workers=0)
    runner.run(tasks)
    assert runner.last_stats["executed"] == 1
    with open(victim) as f:
        assert json.load(f)["fingerprint"] == tasks[0].fingerprint()


def test_pool_writes_checkpoints(tmp_path):
    ckpt = str(tmp_path / "ckpt")
    runner = EvalRunner(checkpoint_dir=ckpt, workers=2)
    runner.run(_tasks(runs=1))
    assert sorted(os.path.basename(p) for p in iter_checkpoints(ckpt)) \
        == sorted(t.checkpoint_name() for t in _tasks(runs=1))


# ----------------------------------------------------- sharded store
def test_checkpoints_land_in_fingerprint_shards(tmp_path):
    """Every checkpoint is bucketed under its fingerprint prefix — no
    file sits in the flat root (10k-task sweeps must not pile up in
    one directory)."""
    ckpt = str(tmp_path / "ckpt")
    tasks = _tasks(runs=2)
    EvalRunner(checkpoint_dir=ckpt, workers=0).run(tasks)
    assert not [n for n in os.listdir(ckpt)
                if os.path.isfile(os.path.join(ckpt, n))]
    for t in tasks:
        path = os.path.join(shard_dir(ckpt, t.fingerprint()),
                            t.checkpoint_name())
        assert os.path.exists(path), path
        rel = os.path.relpath(path, ckpt)
        assert rel.split(os.sep)[0] == t.fingerprint()[:SHARD_CHARS]


def test_resume_from_legacy_flat_store(tmp_path):
    """A pre-shard (flat) checkpoint dir keeps resuming: every record
    is reused, nothing re-executes."""
    ckpt = str(tmp_path / "ckpt")
    tasks = _tasks(runs=1)
    EvalRunner(checkpoint_dir=ckpt, workers=0).run(tasks)
    # flatten the store the way the pre-shard runner laid it out
    for path in list(iter_checkpoints(ckpt)):
        os.replace(path, os.path.join(ckpt, os.path.basename(path)))
    for name in os.listdir(ckpt):
        sub = os.path.join(ckpt, name)
        if os.path.isdir(sub):
            os.rmdir(sub)
    runner = EvalRunner(checkpoint_dir=ckpt, workers=0)
    runner.run(tasks)
    assert runner.last_stats["reused_from_checkpoint"] == len(tasks)
    assert runner.last_stats["executed"] == 0


def test_flat_store_cross_label_reuse(tmp_path):
    """The label-independent fingerprint glob also finds legacy flat
    checkpoints written under a different label."""
    ckpt = str(tmp_path / "ckpt")
    t1 = make_tasks([CONFIGS[0]], runs=1, num_jobs=20, load=1.5, seed0=100)
    EvalRunner(checkpoint_dir=ckpt, workers=0).run(t1)
    for path in list(iter_checkpoints(ckpt)):
        os.replace(path, os.path.join(ckpt, os.path.basename(path)))
    relabeled = [("RFold renamed",) + CONFIGS[0][1:]]
    t2 = make_tasks(relabeled, runs=1, num_jobs=20, load=1.5, seed0=100)
    runner = EvalRunner(checkpoint_dir=ckpt, workers=0)
    records = runner.run(t2)
    assert runner.last_stats["reused_from_checkpoint"] == 1
    assert records[0]["label"] == "RFold renamed"


# ----------------------------------------------------- task semantics
def test_run_task_record_shape():
    task = EvalTask(label="RFold (4^3)", policy="rfold",
                    policy_kw=dict(num_xpus=512, cube_n=4),
                    run_idx=0, seed=7, num_jobs=15, load=1.5)
    rec = run_task(task)
    assert rec["fingerprint"] == task.fingerprint()
    assert rec["summary"]["num_jobs"] == 15
    assert 0.0 <= rec["summary"]["jcr"] <= 1.0
    assert len(rec["cdf_levels"]) == len(rec["cdf"]) == 101


def test_sim_kw_reaches_simulator():
    """Tasks carry Simulator kwargs (the ablation driver relies on
    this): backfill=True must change scheduling on a blocking trace."""
    base = EvalTask(label="x", policy="rfold",
                    policy_kw=dict(num_xpus=512, cube_n=4),
                    seed=3, num_jobs=40, load=3.0)
    bf = EvalTask(label="x", policy="rfold",
                  policy_kw=dict(num_xpus=512, cube_n=4),
                  seed=3, num_jobs=40, load=3.0,
                  sim_kw=dict(backfill=True))
    assert base.fingerprint() != bf.fingerprint()
    r_base, r_bf = run_task(base), run_task(bf)
    assert r_bf["summary"]["jct_p50"] <= r_base["summary"]["jct_p50"]


def test_fingerprint_ignores_display_label():
    """Label is display-only: two labels for one config share a
    fingerprint (the ablation arms rely on cross-label reuse)."""
    a = EvalTask(label="RFold (4^3)", policy="rfold", policy_kw={"cube_n": 4})
    b = EvalTask(label="RFold FIFO", policy="rfold", policy_kw={"cube_n": 4})
    assert a.fingerprint() == b.fingerprint()


def test_checkpoint_reused_across_labels(tmp_path):
    """A run checkpointed under one label is reused for the same
    config under a different label, restamped with the new label."""
    ckpt = str(tmp_path / "ckpt")
    t1 = make_tasks([CONFIGS[0]], runs=1, num_jobs=20, load=1.5, seed0=100)
    EvalRunner(checkpoint_dir=ckpt, workers=0).run(t1)
    relabeled = [("RFold renamed",) + CONFIGS[0][1:]]
    t2 = make_tasks(relabeled, runs=1, num_jobs=20, load=1.5, seed0=100)
    runner = EvalRunner(checkpoint_dir=ckpt, workers=0)
    records = runner.run(t2)
    assert runner.last_stats["reused_from_checkpoint"] == 1
    assert records[0]["label"] == "RFold renamed"


def test_fingerprint_covers_every_outcome_field():
    base = EvalTask(label="a", policy="rfold", policy_kw={"cube_n": 4})
    variants = [
        EvalTask(label="a", policy="reconfig", policy_kw={"cube_n": 4}),
        EvalTask(label="a", policy="rfold", policy_kw={"cube_n": 2}),
        EvalTask(label="a", policy="rfold", policy_kw={"cube_n": 4},
                 run_idx=1),
        EvalTask(label="a", policy="rfold", policy_kw={"cube_n": 4},
                 seed=1),
        EvalTask(label="a", policy="rfold", policy_kw={"cube_n": 4},
                 num_jobs=10),
        EvalTask(label="a", policy="rfold", policy_kw={"cube_n": 4},
                 load=2.0),
        EvalTask(label="a", policy="rfold", policy_kw={"cube_n": 4},
                 trace_kw={"size_scale": 128.0}),
        EvalTask(label="a", policy="rfold", policy_kw={"cube_n": 4},
                 sim_kw={"backfill": True}),
    ]
    fps = {t.fingerprint() for t in variants}
    assert base.fingerprint() not in fps
    assert len(fps) == len(variants)


def test_checkpoint_name_is_filesystem_safe():
    t = EvalTask(label="RFold (4^3) / weird:label", policy="rfold")
    name = t.checkpoint_name()
    assert "/" not in name and ":" not in name and " " not in name
    assert name.endswith(f"__{t.fingerprint()}.json")


def test_workers_default_is_cpu_count():
    assert EvalRunner().workers == os.cpu_count()


# ----------------------------------------------------- store pruning
def _flatten_store(ckpt):
    """Rewrite a sharded store into the legacy flat layout."""
    for path in list(iter_checkpoints(ckpt)):
        os.replace(path, os.path.join(ckpt, os.path.basename(path)))
    for name in os.listdir(ckpt):
        sub = os.path.join(ckpt, name)
        if os.path.isdir(sub):
            os.rmdir(sub)


@pytest.mark.parametrize("flat", [False, True])
def test_prune_drops_stale_keeps_current(tmp_path, flat):
    """Prune removes checkpoints whose fingerprint left the task set
    (here: an old num_jobs) and keeps the current ones resumable —
    on sharded and legacy-flat stores alike."""
    ckpt = str(tmp_path / "ckpt")
    stale = _tasks(runs=1, num_jobs=20)
    current = _tasks(runs=1, num_jobs=25)
    EvalRunner(checkpoint_dir=ckpt, workers=0).run(stale)
    EvalRunner(checkpoint_dir=ckpt, workers=0).run(current)
    if flat:
        _flatten_store(ckpt)
    assert len(list(iter_checkpoints(ckpt))) == len(stale) + len(current)

    stats = prune_checkpoints(ckpt, current)
    assert stats["removed"] == len(stale)
    assert stats["kept"] == len(current)
    assert stats["bytes_freed"] > 0

    runner = EvalRunner(checkpoint_dir=ckpt, workers=0)
    runner.run(current)
    assert runner.last_stats["reused_from_checkpoint"] == len(current)


def test_prune_caps_store_size_evicting_oldest(tmp_path):
    """With max_bytes, survivors beyond the cap are evicted oldest-
    mtime first — the newest checkpoints stay resumable."""
    ckpt = str(tmp_path / "ckpt")
    tasks = _tasks(runs=2)
    EvalRunner(checkpoint_dir=ckpt, workers=0).run(tasks)
    paths = sorted(iter_checkpoints(ckpt), key=os.path.getmtime)
    for age, path in enumerate(paths):   # make mtime order deterministic
        os.utime(path, (1000 + age, 1000 + age))
    newest = max(paths, key=os.path.getmtime)
    cap = os.path.getsize(newest)
    stats = prune_checkpoints(ckpt, tasks, max_bytes=cap)
    survivors = list(iter_checkpoints(ckpt))
    assert survivors == [newest]
    assert stats["removed"] == len(paths) - 1


def test_prune_leaves_foreign_files_and_cleans_empty_shards(tmp_path):
    ckpt = str(tmp_path / "ckpt")
    tasks = _tasks(runs=1, num_jobs=20)
    EvalRunner(checkpoint_dir=ckpt, workers=0).run(tasks)
    foreign = os.path.join(ckpt, "notes.json")
    with open(foreign, "w") as f:
        f.write("{}")
    shards_before = [n for n in os.listdir(ckpt)
                     if os.path.isdir(os.path.join(ckpt, n))]
    stats = prune_checkpoints(ckpt, _tasks(runs=1, num_jobs=25))
    assert stats["removed"] == len(tasks)       # every stale ckpt gone
    assert os.path.exists(foreign)              # never ours to delete
    for name in shards_before:                  # emptied shards removed
        assert not os.path.isdir(os.path.join(ckpt, name))


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
