"""End-to-end system tests: the RFold-scheduled cluster driver runs real
training jobs on RFold-allocated meshes; benchmarks entry points work."""
import jax
import pytest

from repro.core.geometry import JobShape
from repro.launch.cluster import RFoldCluster


def test_rfold_cluster_end_to_end():
    """Submit -> place (folded) -> train steps -> release; utilization
    accounting matches the allocations."""
    n_dev = len(jax.devices())
    cluster = RFoldCluster(num_xpus=8, cube_n=2)
    shape = JobShape((min(2, n_dev), 1, 1))
    job = cluster.submit(0, "olmo-1b", shape, seed=0)
    assert job is not None
    assert cluster.utilization() == pytest.approx(shape.size / 8)
    losses = cluster.run_steps(0, steps=2)
    assert len(losses) == 2 and all(l > 0 for l in losses)
    cluster.finish(0)
    assert cluster.utilization() == 0.0


def test_rfold_cluster_rejects_oversized():
    cluster = RFoldCluster(num_xpus=8, cube_n=2)
    assert cluster.submit(1, "olmo-1b", JobShape((64, 1, 1))) is None


def test_paper_eval_functions_run():
    from benchmarks.paper_eval import table1_jcr
    out = table1_jcr(runs=1, num_jobs=40, emit=lambda *a: None)
    assert out["RFold (4^3)"]["jcr"] == 1.0
    assert out["FirstFit (16^3)"]["jcr"] < 0.6


def test_kernels_bench_runs():
    from benchmarks.kernels_bench import bench_fitmask
    rows = []
    bench_fitmask(emit=rows.append)
    assert len(rows) >= 2


def test_roofline_row_math():
    from benchmarks.roofline import roofline_row
    res = {
        "arch": "olmo-1b", "shape": "train_4k", "mesh": "single",
        "chips": 256, "compile_s": 1.0,
        "collectives": {"total_bytes": 50e9},
        "probes": {"extrapolated": {
            "flops": 197e12, "bytes": 819e9, "collective_bytes": 50e9}},
    }
    row = roofline_row(res, {})
    assert row["t_compute_s"] == pytest.approx(1.0)
    assert row["t_memory_s"] == pytest.approx(1.0)
    assert row["t_collective_s"] == pytest.approx(1.0)
    assert row["useful_ratio"] > 0
