"""Boundary coverage for sim/metrics.py and the SimResult properties:
empty traces, all-dropped traces, and utilization-sample /
utilization-CDF monotonicity — the edges the aggregation pipeline
leans on but nothing exercised directly."""
import numpy as np
import pytest

from repro.core.allocator import make_policy
from repro.core.geometry import JobShape
from repro.sim.job import Job
from repro.sim.metrics import (jct_percentiles, summarize,
                               time_weighted_utilization, utilization_cdf)
from repro.sim.simulator import SimResult, Simulator
from repro.traces.generator import TraceConfig, generate_trace


def _job(job_id, shape, arrival=0.0, duration=10.0):
    return Job(job_id=job_id, arrival=arrival, duration=duration,
               shape=JobShape(shape))


# ------------------------------------------------------------- empty
def test_empty_trace_runs_and_summarizes():
    res = Simulator(make_policy("firstfit", dims=(4, 4, 4)), []).run()
    assert res.jobs == [] and res.completed == [] and res.dropped == []
    assert res.jcr == 1.0          # vacuous: nothing arrived, nothing lost
    s = summarize(res)
    assert s["num_jobs"] == 0 and s["num_dropped"] == 0
    assert s["jcr"] == 1.0
    for q in ("p50", "p90", "p99"):
        assert np.isnan(s[f"jct_{q}"])
    assert s["util_mean"] == 0.0   # <2 samples: no time elapsed


def test_empty_trace_utilization_cdf_shape():
    res = Simulator(make_policy("firstfit", dims=(4, 4, 4)), []).run()
    levels, cdf = utilization_cdf(res)
    assert len(levels) == len(cdf) == 101
    assert not np.isnan(cdf).any()


def test_jct_percentiles_no_completions_is_nan():
    res = SimResult(jobs=[], utilization_samples=[], policy_name="x")
    assert all(np.isnan(v) for v in jct_percentiles(res).values())


def test_time_weighted_utilization_underflow_samples():
    res = SimResult(jobs=[], utilization_samples=[(0.0, 0.5)],
                    policy_name="x")
    assert time_weighted_utilization(res) == {"mean": 0.0, "p50": 0.0,
                                              "p90": 0.0}


# -------------------------------------------------------- all-dropped
def test_all_dropped_trace():
    """Every job's shape is incompatible with the cluster (exceeds the
    static torus even when empty): all dropped, none completed, JCR 0,
    and the summary stays finite where it should."""
    jobs = [_job(i, (5, 5, 1), arrival=float(i)) for i in range(6)]
    res = Simulator(make_policy("firstfit", dims=(4, 4, 4)), jobs).run()
    assert len(res.dropped) == 6 and res.completed == []
    assert res.jcr == 0.0
    assert all(not j.scheduled and j.jct is None for j in res.jobs)
    s = summarize(res)
    assert s["num_dropped"] == 6 and s["jcr"] == 0.0
    assert np.isnan(s["jct_p50"])


def test_mixed_drop_jcr_counts_scheduled_only():
    jobs = [_job(0, (2, 2, 1)), _job(1, (5, 5, 1)), _job(2, (2, 1, 1))]
    res = Simulator(make_policy("firstfit", dims=(4, 4, 4)), jobs).run()
    assert len(res.dropped) == 1
    assert res.jcr == pytest.approx(2 / 3)


# ------------------------------------------------------- monotonicity
def _seeded_result():
    jobs = generate_trace(TraceConfig(num_jobs=40, seed=7,
                                      target_load=2.0))
    return Simulator(make_policy("rfold", num_xpus=512, cube_n=4),
                     jobs).run()


def test_utilization_samples_monotone_time_and_bounded():
    res = _seeded_result()
    ts = [t for t, _ in res.utilization_samples]
    us = [u for _, u in res.utilization_samples]
    assert ts == sorted(ts)                      # event time never rewinds
    assert all(0.0 <= u <= 1.0 for u in us)


def test_utilization_cdf_is_a_cdf():
    res = _seeded_result()
    levels, cdf = utilization_cdf(res)
    assert np.all(np.diff(levels) > 0)
    assert np.all(np.diff(cdf) >= -1e-12)        # non-decreasing
    assert cdf[-1] == pytest.approx(1.0)         # all mass at util <= 1
    assert cdf[0] >= 0.0


def test_completed_plus_dropped_plus_running_partition_jobs():
    res = _seeded_result()
    completed = {j.job_id for j in res.completed}
    dropped = {j.job_id for j in res.dropped}
    assert not completed & dropped
    assert completed | dropped <= {j.job_id for j in res.jobs}


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
