"""Unit tests for model substrate internals: RoPE/M-RoPE, norms, router,
capacity behaviour, KV-cache ring buffer, sharding spec helpers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import _cache_write, init_kv_cache
from repro.models.common import (ModelConfig, apply_mrope, apply_rope,
                                 apply_norm, init_norm,
                                 sinusoidal_positions)
from repro.models.ffn import moe_forward_global, moe_forward_local, \
    init_moe, router_probs


def _mini_cfg(**kw):
    base = dict(name="t", arch_type="dense", n_layers=1, d_model=32,
                n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=64)
    base.update(kw)
    return ModelConfig(**base)


# ----------------------------------------------------------------- rope
def test_rope_is_rotation_preserves_norm():
    x = jnp.array(np.random.default_rng(0).normal(size=(1, 8, 2, 16)),
                  jnp.float32)
    pos = jnp.arange(8)[None]
    y = apply_rope(x, pos, 10_000.0)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                               np.linalg.norm(np.asarray(y), axis=-1),
                               rtol=1e-5)


def test_rope_relative_property():
    """<R(p)q, R(p+k)v> depends only on k (the relative offset)."""
    rng = np.random.default_rng(1)
    q = jnp.array(rng.normal(size=(1, 1, 1, 16)), jnp.float32)
    v = jnp.array(rng.normal(size=(1, 1, 1, 16)), jnp.float32)

    def score(p, t):
        qr = apply_rope(q, jnp.array([[p]]), 1e4)
        vr = apply_rope(v, jnp.array([[t]]), 1e4)
        return float(jnp.sum(qr * vr))

    assert score(3, 7) == pytest.approx(score(10, 14), rel=1e-4)
    assert score(0, 4) == pytest.approx(score(100, 104), rel=1e-4)


def test_mrope_equals_rope_when_positions_identical():
    x = jnp.array(np.random.default_rng(2).normal(size=(1, 6, 2, 16)),
                  jnp.float32)
    pos = jnp.arange(6)[None]
    pos3 = jnp.broadcast_to(pos[..., None], (1, 6, 3))
    y1 = apply_rope(x, pos, 1e4)
    y2 = apply_mrope(x, pos3, 1e4, (3, 3, 2))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5)


def test_sinusoidal_shapes():
    s = sinusoidal_positions(10, 16)
    assert s.shape == (10, 16)
    assert float(jnp.abs(s).max()) <= 1.0


# ----------------------------------------------------------------- norms
@pytest.mark.parametrize("norm", ["rmsnorm", "layernorm",
                                  "nonparametric_ln"])
def test_norms_normalize(norm):
    cfg = _mini_cfg(norm_type=norm)
    p = init_norm(cfg)
    x = jnp.array(np.random.default_rng(3).normal(size=(2, 4, 32)) * 7,
                  jnp.float32)
    y = apply_norm(cfg, p, x)
    if norm == "rmsnorm":
        rms = np.sqrt((np.asarray(y) ** 2).mean(-1))
        np.testing.assert_allclose(rms, 1.0, rtol=1e-3)
    else:
        np.testing.assert_allclose(np.asarray(y).mean(-1), 0.0, atol=1e-4)


# ------------------------------------------------------------------- moe
def test_router_topk_softmax_normalized():
    cfg = _mini_cfg(n_experts=8, moe_top_k=2, moe_d_ff=16)
    logits = jnp.array(np.random.default_rng(4).normal(size=(5, 8)),
                       jnp.float32)
    w, idx = router_probs(cfg, logits)
    assert w.shape == (5, 2) and idx.shape == (5, 2)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, rtol=1e-5)
    assert (np.asarray(w) >= 0).all()


def test_router_sigmoid_top1():
    cfg = _mini_cfg(n_experts=8, moe_top_k=1, moe_d_ff=16,
                    router_type="sigmoid")
    logits = jnp.zeros((3, 8))
    w, idx = router_probs(cfg, logits)
    np.testing.assert_allclose(np.asarray(w), 0.5, rtol=1e-6)


def test_moe_capacity_drops_tokens():
    """With capacity 0, every token is dropped -> output is only the
    shared-expert path (here: zero, since no shared experts)."""
    cfg = _mini_cfg(n_experts=4, moe_top_k=1, moe_d_ff=16,
                    capacity_factor=0.0)
    p = init_moe(cfg, jax.random.PRNGKey(0))
    x = jnp.array(np.random.default_rng(5).normal(size=(2, 8, 32)),
                  jnp.float32)
    for fn in (moe_forward_global, moe_forward_local):
        out, aux = fn(cfg, p, x)
        # capacity_factor=0 -> cap=1 slot: at most 1 token per expert
        # contributes; most of the output is exactly zero rows
        zero_rows = (np.abs(np.asarray(out)).sum(-1) < 1e-9).sum()
        assert zero_rows >= 8  # at least half the tokens dropped


def test_moe_local_vs_global_property():
    rng = np.random.default_rng(6)
    for seed in range(3):
        cfg = _mini_cfg(n_experts=4, moe_top_k=2, moe_d_ff=16,
                        capacity_factor=8.0, n_shared_experts=1)
        p = init_moe(cfg, jax.random.PRNGKey(seed))
        x = jnp.array(rng.normal(size=(2, 8, 32)), jnp.float32)
        o1, a1 = moe_forward_global(cfg, p, x)
        o2, a2 = moe_forward_local(cfg, p, x)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                   rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------- cache
def test_kv_cache_ring_buffer_wraps():
    cache = init_kv_cache(batch=1, window=4, n_kv_heads=1, head_dim=2,
                          dtype=jnp.float32)
    for t in range(6):
        k = jnp.full((1, 1, 1, 2), float(t))
        cache = _cache_write(cache, ("k", "v"), (k, k),
                             jnp.array(t, jnp.int32))
    # window 4: slots hold positions 4,5,2,3 (ring)
    assert sorted(np.asarray(cache["slot_pos"][0]).tolist()) == [2, 3, 4, 5]
    assert int(cache["next_pos"]) == 6
    # slot content matches its position
    for slot in range(4):
        pos = int(cache["slot_pos"][0, slot])
        assert float(cache["k"][0, slot, 0, 0]) == float(pos)


def test_sliding_window_masks_old_tokens():
    """Attention with window w must ignore keys older than w."""
    from repro.models.attention import _gqa_attend
    rng = np.random.default_rng(7)
    q = jnp.array(rng.normal(size=(1, 1, 1, 4)), jnp.float32)
    k = jnp.array(rng.normal(size=(1, 8, 1, 4)), jnp.float32)
    v = jnp.array(rng.normal(size=(1, 8, 1, 4)), jnp.float32)
    q_pos = jnp.array([[7]])
    k_pos = jnp.arange(8)[None]
    full = _gqa_attend(q, k, v, q_pos, k_pos, 0)
    w2 = _gqa_attend(q, k, v, q_pos, k_pos, 2)
    # window-2 output equals attention over only the last two keys
    ref = _gqa_attend(q, k[:, 6:], v[:, 6:], q_pos, k_pos[:, 6:], 0)
    np.testing.assert_allclose(np.asarray(w2), np.asarray(ref), rtol=1e-5)
    assert not np.allclose(np.asarray(full), np.asarray(w2))
