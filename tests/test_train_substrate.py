"""Optimizer, data pipeline, checkpointing, grad-accum, serving."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_variant
from repro.train.checkpoint import load_checkpoint, save_checkpoint
from repro.train.data import shard_batch, synthetic_batches
from repro.train.optim import (OptimConfig, adamw_update, global_norm,
                               init_opt_state, lr_at)
from repro.train.train_step import (cross_entropy, train_step,
                                    train_step_accum)
from repro.models import model as lm


@pytest.fixture(scope="module")
def tiny():
    cfg = smoke_variant(get_config("olmo-1b"))
    params = lm.init_model(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_lr_schedule_shape():
    oc = OptimConfig(lr=1.0, warmup_steps=10, total_steps=110,
                     min_lr_ratio=0.1)
    assert float(lr_at(oc, jnp.array(0))) == pytest.approx(0.0)
    assert float(lr_at(oc, jnp.array(10))) == pytest.approx(1.0, rel=1e-3)
    assert float(lr_at(oc, jnp.array(110))) == pytest.approx(0.1, rel=1e-3)
    mid = float(lr_at(oc, jnp.array(60)))
    assert 0.1 < mid < 1.0


def test_adamw_clips_and_decays():
    params = {"w": jnp.ones((4, 4)), "b": jnp.zeros((4,))}
    grads = {"w": jnp.full((4, 4), 100.0), "b": jnp.full((4,), 100.0)}
    st = init_opt_state(params)
    oc = OptimConfig(lr=0.1, clip_norm=1.0, warmup_steps=0, total_steps=10)
    p1, st1, m = adamw_update(oc, params, grads, st)
    assert float(m["grad_norm"]) > 1.0
    assert int(st1["step"]) == 1
    assert not jnp.allclose(p1["w"], params["w"])


def test_cross_entropy_uniform():
    logits = jnp.zeros((2, 3, 7))
    tgt = jnp.zeros((2, 3), jnp.int32)
    assert float(cross_entropy(logits, tgt)) == pytest.approx(np.log(7),
                                                              rel=1e-5)


def test_loss_decreases_over_steps(tiny):
    cfg, params = tiny
    it = synthetic_batches(cfg, batch=2, seq=32, seed=0)
    batch = next(it)
    oc = OptimConfig(lr=3e-3, warmup_steps=0, total_steps=100)
    opt = init_opt_state(params)
    step = jax.jit(lambda p, o, b: train_step(cfg, oc, p, o, b))
    losses = []
    for _ in range(5):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["ce"]))
    assert losses[-1] < losses[0]


def test_grad_accum_matches_full_batch(tiny):
    cfg, params = tiny
    it = synthetic_batches(cfg, batch=4, seq=16, seed=1)
    batch = next(it)
    oc = OptimConfig(lr=1e-3, warmup_steps=0, total_steps=10,
                     clip_norm=1e9)
    opt = init_opt_state(params)
    p_full, _, _ = train_step(cfg, oc, params, opt, batch)
    p_acc, _, _ = train_step_accum(cfg, oc, params, opt, batch, n_micro=2)
    diffs = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a - b).max()), p_full, p_acc)
    # accumulation-order fp differences propagate through Adam's
    # sqrt(nu) normalization; 5e-4 bounds that comfortably
    assert max(jax.tree_util.tree_leaves(diffs)) < 5e-4


def test_synthetic_data_deterministic(tiny):
    cfg, _ = tiny
    a = next(synthetic_batches(cfg, 2, 8, seed=3))
    b = next(synthetic_batches(cfg, 2, 8, seed=3))
    assert (a["tokens"] == b["tokens"]).all()
    assert (a["tokens"] < cfg.vocab_size).all()
    # targets are next tokens
    full = np.asarray(jnp.concatenate([a["tokens"][:, :1], a["targets"]], 1))
    assert (np.asarray(a["tokens"])[:, 1:] == full[:, 1:-1]).all()


def test_checkpoint_roundtrip(tmp_path, tiny):
    cfg, params = tiny
    opt = init_opt_state(params)
    path = os.path.join(tmp_path, "ckpt.npz")
    save_checkpoint(path, params, opt, step=7, meta={"arch": cfg.name})
    zeroed = jax.tree_util.tree_map(jnp.zeros_like, params)
    p2, o2, meta = load_checkpoint(path, zeroed,
                                   jax.tree_util.tree_map(jnp.zeros_like,
                                                          opt))
    assert meta["step"] == 7 and meta["arch"] == cfg.name
    diffs = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a - b).max()), params, p2)
    assert max(jax.tree_util.tree_leaves(diffs)) == 0.0


def test_global_norm():
    t = {"a": jnp.ones((3,)), "b": jnp.full((4,), 2.0)}
    assert float(global_norm(t)) == pytest.approx(np.sqrt(3 + 16))


def test_shard_batch_single_device(tiny):
    cfg, _ = tiny
    mesh = jax.make_mesh((1,), ("data",))
    batch = next(synthetic_batches(cfg, 2, 8, seed=0))
    out = shard_batch(batch, mesh)
    assert out["tokens"].shape == batch["tokens"].shape
