"""Folding: constructions match the paper's examples; every emitted fold
certifies as a ring-product embedding (property-based)."""
from hypothesis import given, settings, strategies as st

from repro.core.folding import (enumerate_folds,
    fold_links,
    ring_edges,
    verify_fold)
from repro.core.geometry import JobShape, volume

FULL_WRAP = (True, True, True)
NO_WRAP = (False, False, False)


# ----------------------------------------------------------------- paper
def test_paper_18x1x1_folds_into_one_cube_sized_box():
    folds = enumerate_folds(JobShape((18, 1, 1)), max_dim=16)
    boxes = {f.box for f in folds if f.kind == "cycle1d"}
    assert (2, 3, 3) in boxes          # fits inside one 4x4x4 cube
    for f in folds:
        if f.kind == "cycle1d":
            ok, broken = verify_fold(f, NO_WRAP)
            assert ok and not broken   # cycles close without wrap links


def test_paper_1x6x4_folds_to_4x2x3():
    folds = enumerate_folds(JobShape((1, 6, 4)), max_dim=16)
    match = [f for f in folds if f.box == (4, 2, 3) and f.kind == "ring_x_ham"]
    assert match
    # the kept 4-ring needs wrap on axis 0 (e.g. a full cube extent)
    ok, broken = verify_fold(match[0], (True, False, False))
    assert ok and not broken
    ok, broken = verify_fold(match[0], NO_WRAP)
    assert ok and broken == [0] or broken == [1]  # kept ring reported


def test_paper_4x8x2_halving_fold_to_4x4x4():
    folds = enumerate_folds(JobShape((4, 8, 2)), max_dim=16)
    match = [f for f in folds if f.box == (4, 4, 4) and f.kind == "halving3d"]
    assert match
    ok, broken = verify_fold(match[0], FULL_WRAP)
    assert ok and not broken
    # without wrap on the doubled axis the B-ring cannot close
    ok, broken = verify_fold(match[0], (True, True, False))
    assert ok and broken


def test_paper_4x8x3_cannot_fold():
    folds = enumerate_folds(JobShape((4, 8, 3)), max_dim=16)
    assert all(f.kind == "identity" for f in folds)
    assert not any(f.box == (4, 4, 6) for f in folds)


def test_odd_rings_have_no_cycle_folds():
    folds = enumerate_folds(JobShape((17, 1, 1)), max_dim=16)
    assert all(f.kind == "identity" for f in folds)


# ------------------------------------------------------------- structure
def test_ring_edges_counts():
    # ring(4) x ring(3): 4*3 nodes; edges 4 per row... ring4 edges = 4,
    # ring3 edges = 3; total = 4*3 + 3*4 = 24
    edges = ring_edges((4, 3, 1))
    assert len(edges) == 24
    edges2 = ring_edges((2, 1, 1))   # 2-ring = single duplex link
    assert len(edges2) == 1


def test_fold_embed_injective_and_links_match():
    folds = enumerate_folds(JobShape((4, 6, 1)), max_dim=16)
    for f in folds:
        coords = set()
        d0, d1, d2 = f.job_dims
        for i in range(d0):
            for j in range(d1):
                for k in range(d2):
                    coords.add(f.embed((i, j, k)))
        assert len(coords) == volume(f.job_dims)
        links = fold_links(f, (0, 0, 0), (16, 16, 16))
        assert len(links) == len(ring_edges(f.job_dims))


@settings(max_examples=80, deadline=None)
@given(st.tuples(st.sampled_from([1, 2, 3, 4, 6, 8, 12, 16, 18, 24]),
                 st.sampled_from([1, 2, 3, 4, 6, 8]),
                 st.sampled_from([1, 2, 3, 4])))
def test_every_fold_certifies(dims):
    """Property: every enumerated fold is a valid homomorphism under full
    wrap, and wrap_required axes are consistent with verify_fold."""
    folds = enumerate_folds(JobShape(dims), max_dim=16)
    if max(dims) <= 16:
        assert folds, dims  # identity always present within max_dim
    for f in folds:
        ok, broken = verify_fold(f, FULL_WRAP)
        assert ok, (dims, str(f))
        assert not broken, (dims, str(f))
        ok2, broken2 = verify_fold(f, NO_WRAP)
        assert ok2, (dims, str(f))
        # any axis reported broken without wrap must be wrap_required
        for ax in broken2:
            pass  # broken axes are job-dim indices; wrap_required is per box
        if not any(f.wrap_required):
            assert not broken2, (dims, str(f))


def test_enumerate_folds_respects_max_dim():
    folds = enumerate_folds(JobShape((64, 1, 1)), max_dim=16)
    assert all(max(f.box) <= 16 for f in folds)
